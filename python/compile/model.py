"""Layer-2 JAX compute graphs, each lowered once to an HLO artifact.

Every graph is a pure function of concrete-shaped arrays; the moment
graphs call the Layer-1 Pallas kernels so they lower into the same HLO
module the Rust runtime executes.  Gradient and predictive graphs are
plain jnp (they are memory-bound elementwise/matvec work where XLA's own
fusion is already optimal; DESIGN.md section 2).

Conventions shared with the Rust side (rust/src/runtime/):
  * batch capacity is fixed at lowering time; shorter logical batches are
    padded and masked by the caller,
  * labels y are +/- 1 floats,
  * every graph returns a tuple (lowered with return_tuple=True) and the
    Rust side unwraps with to_tupleN.
"""

import jax
import jax.numpy as jnp

from .kernels import linreg_lldiff_block, logistic_lldiff_block
from .kernels.ica import ica_lldiff_block_const
from .kernels.common import DEFAULT_BLOCK_M

# Fixed artifact shapes (see DESIGN.md section 2 and artifacts/manifest.txt).
BATCH = 512            # mini-batch capacity of the moment/grad graphs
LOGISTIC_D = 50        # feature dim of the logistic experiments (6.1/6.3)
ICA_D = 4              # sources in the ICA experiment (6.2)
PREDICT_T = 2048       # test-point capacity of the predictive graph


def logistic_lldiff_graph(x, y, mask, theta, theta_p):
    """(BATCH, D) mini-batch -> (sum l, sum l^2) via the Pallas kernel."""
    s, s2 = logistic_lldiff_block(x, y, mask, theta, theta_p,
                                  block_m=DEFAULT_BLOCK_M)
    return (s, s2)


def ica_lldiff_graph(x, mask, w, w_p, const):
    """const = logdet(W') - logdet(W), computed by the caller (Rust LU
    slogdet) — see kernels/ica.py for why it is not lowered here."""
    s, s2 = ica_lldiff_block_const(x, mask, w, w_p, const[0],
                                   block_m=DEFAULT_BLOCK_M)
    return (s, s2)


def linreg_lldiff_graph(x, y, mask, theta, theta_p, lam):
    s, s2 = linreg_lldiff_block(x, y, mask, theta[0], theta_p[0], lam[0],
                                block_m=DEFAULT_BLOCK_M)
    return (s, s2)


def logistic_grad_graph(x, y, mask, theta):
    """Mini-batch gradient of the logistic log-likelihood (for SGLD/MAP)."""
    def nll(t):
        z = y * (x @ t)
        ll = -(jnp.maximum(-z, 0.0) + jnp.log1p(jnp.exp(-jnp.abs(z))))
        return jnp.sum(mask * ll)

    return (jax.grad(nll)(theta),)


def linreg_grad_graph(x, y, mask, theta, lam):
    """Mini-batch gradient of the 1-d linreg log-likelihood (for SGLD)."""
    def ll(t):
        return jnp.sum(mask * (-0.5 * lam[0] * (y - t[0] * x) ** 2))

    return (jax.grad(ll)(theta),)


def logistic_predict_graph(x, theta):
    """p(y=+1 | x) for a panel of test points (risk evaluation)."""
    return (jax.nn.sigmoid(x @ theta),)


def _f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


#: name -> (fn, input specs, input names).  aot.py lowers each entry.
GRAPHS = {
    "logistic_lldiff": (
        logistic_lldiff_graph,
        [_f32(BATCH, LOGISTIC_D), _f32(BATCH), _f32(BATCH),
         _f32(LOGISTIC_D), _f32(LOGISTIC_D)],
        ["x", "y", "mask", "theta", "theta_p"],
    ),
    "ica_lldiff": (
        ica_lldiff_graph,
        [_f32(BATCH, ICA_D), _f32(BATCH), _f32(ICA_D, ICA_D),
         _f32(ICA_D, ICA_D), _f32(1)],
        ["x", "mask", "w", "w_p", "const"],
    ),
    "linreg_lldiff": (
        linreg_lldiff_graph,
        [_f32(BATCH), _f32(BATCH), _f32(BATCH), _f32(1), _f32(1), _f32(1)],
        ["x", "y", "mask", "theta", "theta_p", "lam"],
    ),
    "logistic_grad": (
        logistic_grad_graph,
        [_f32(BATCH, LOGISTIC_D), _f32(BATCH), _f32(BATCH), _f32(LOGISTIC_D)],
        ["x", "y", "mask", "theta"],
    ),
    "linreg_grad": (
        linreg_grad_graph,
        [_f32(BATCH), _f32(BATCH), _f32(BATCH), _f32(1), _f32(1)],
        ["x", "y", "mask", "theta", "lam"],
    ),
    "logistic_predict": (
        logistic_predict_graph,
        [_f32(PREDICT_T, LOGISTIC_D), _f32(LOGISTIC_D)],
        ["x", "theta"],
    ),
}

"""AOT pipeline: lower every Layer-2 graph to HLO *text* artifacts.

HLO text (not ``lowered.compile().serialize()`` and not the serialized
HloModuleProto) is the interchange format: jax >= 0.5 emits protos with
64-bit instruction ids which the xla crate's xla_extension 0.5.1 rejects
(``proto.id() <= INT_MAX``); the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Usage:  cd python && python -m compile.aot --out ../artifacts

Also writes ``manifest.txt`` describing each artifact's entry signature,
parsed by rust/src/runtime/manifest.rs.  Format, one record per line:

    <name> <file> in=<p>:<dtype>:<d0>x<d1>,... out=<dtype>:<dims>,...
"""

import argparse
import os

import jax
from jax._src.lib import xla_client as xc

from .model import GRAPHS


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _dims(shape):
    return "x".join(str(d) for d in shape) if shape else "scalar"


def lower_all(out_dir: str) -> str:
    os.makedirs(out_dir, exist_ok=True)
    manifest_lines = []
    for name, (fn, specs, arg_names) in sorted(GRAPHS.items()):
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)

        outs = jax.eval_shape(fn, *specs)
        in_desc = ",".join(
            f"{arg}:{spec.dtype}:{_dims(spec.shape)}"
            for arg, spec in zip(arg_names, specs)
        )
        out_desc = ",".join(f"{o.dtype}:{_dims(o.shape)}" for o in outs)
        manifest_lines.append(f"{name} {fname} in={in_desc} out={out_desc}")
        print(f"lowered {name}: {len(text)} chars, outs={out_desc}")

    manifest = "\n".join(manifest_lines) + "\n"
    with open(os.path.join(out_dir, "manifest.txt"), "w") as f:
        f.write(manifest)
    return manifest


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--out", default="../artifacts",
                        help="artifact output directory")
    args = parser.parse_args()
    lower_all(args.out)
    print(f"wrote manifest to {args.out}/manifest.txt")


if __name__ == "__main__":
    main()

"""Layer-1 Pallas kernels for the Austerity-MCMC hot path.

Each kernel fuses the per-datapoint log-likelihood difference
``l_i = log p(x_i; theta') - log p(x_i; theta)`` with the masked moment
reduction ``(sum_i l_i, sum_i l_i^2)`` so that only two scalars leave the
kernel.  These moments are exactly what the Layer-3 sequential test
consumes (Alg. 1 of the paper).

All kernels run with ``interpret=True``: the CPU PJRT plugin cannot
execute Mosaic custom-calls, so interpret mode is the correctness path
and TPU performance is estimated analytically (DESIGN.md section Perf).
"""

from .logistic import logistic_lldiff, logistic_lldiff_block
from .ica import ica_lldiff, ica_lldiff_block
from .linreg import linreg_lldiff, linreg_lldiff_block

__all__ = [
    "logistic_lldiff",
    "logistic_lldiff_block",
    "ica_lldiff",
    "ica_lldiff_block",
    "linreg_lldiff",
    "linreg_lldiff_block",
]

"""Pallas kernel: 1-d L1-regularized linear-regression log-lik difference.

The SGLD pitfall experiment (paper section 6.4) uses a 1-d toy model with
Gaussian errors p(y | x, theta) ~ exp(-lambda/2 (y - theta x)^2), so

    l_i = -lambda/2 [ (y_i - theta' x_i)^2 - (y_i - theta x_i)^2 ].

The Laplacian prior enters the MH threshold mu_0 (Layer 3), not l_i.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import DEFAULT_BLOCK_M, pad_batch


def _kernel(x_ref, y_ref, mask_ref, params_ref, sum_ref, sum2_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        sum_ref[...] = jnp.zeros_like(sum_ref)
        sum2_ref[...] = jnp.zeros_like(sum2_ref)

    x = x_ref[...]
    y = y_ref[...]
    mask = mask_ref[...]
    theta = params_ref[0, 0]
    theta_p = params_ref[0, 1]
    lam = params_ref[0, 2]

    r = y - theta * x
    r_p = y - theta_p * x
    l = (-0.5 * lam) * (r_p * r_p - r * r) * mask

    sum_ref[0, 0] += jnp.sum(l)
    sum2_ref[0, 0] += jnp.sum(l * l)


@functools.partial(jax.jit, static_argnames=("block_m",))
def linreg_lldiff_block(x, y, mask, theta, theta_p, lam, *, block_m=DEFAULT_BLOCK_M):
    m = x.shape[0]
    assert m % block_m == 0, (m, block_m)
    params = jnp.stack(
        [jnp.asarray(theta, jnp.float32),
         jnp.asarray(theta_p, jnp.float32),
         jnp.asarray(lam, jnp.float32)]
    ).reshape(1, 3)
    grid = (m // block_m,)
    sum_l, sum_l2 = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m,), lambda i: (i,)),
            pl.BlockSpec((block_m,), lambda i: (i,)),
            pl.BlockSpec((block_m,), lambda i: (i,)),
            pl.BlockSpec((1, 3), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1, 1), jnp.float32),
            jax.ShapeDtypeStruct((1, 1), jnp.float32),
        ],
        interpret=True,
    )(x, y, mask, params)
    return sum_l[0, 0], sum_l2[0, 0]


def linreg_lldiff(x, y, mask, theta, theta_p, lam, *, block_m=DEFAULT_BLOCK_M):
    """Public entry: pads an arbitrary batch length up to the block size."""
    x = pad_batch(x.astype(jnp.float32), block_m)
    y = pad_batch(y.astype(jnp.float32), block_m)
    mask = pad_batch(mask.astype(jnp.float32), block_m)
    return linreg_lldiff_block(x, y, mask, theta, theta_p, lam, block_m=block_m)

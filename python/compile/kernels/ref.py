"""Pure-jnp oracles for every Pallas kernel (the correctness ground truth).

These are written to be as plain and obviously-correct as possible; the
pytest suite (python/tests/) asserts the Pallas kernels match them across
hypothesis-generated shapes/values, and the Layer-2 graphs are built from
the kernels, so this file anchors the whole compute path.
"""

import jax.numpy as jnp


def _softplus(z):
    return jnp.maximum(z, 0.0) + jnp.log1p(jnp.exp(-jnp.abs(z)))


def _log_sigmoid(z):
    return -_softplus(-z)


def _log_cosh(z):
    a = jnp.abs(z)
    return a + jnp.log1p(jnp.exp(-2.0 * a)) - jnp.log(2.0).astype(z.dtype)


def logistic_l(x, y, theta):
    """Per-datapoint logistic log-likelihood log sigmoid(y x^T theta)."""
    return _log_sigmoid(y * (x @ theta))


def logistic_lldiff_ref(x, y, mask, theta, theta_p):
    l = (logistic_l(x, y, theta_p) - logistic_l(x, y, theta)) * mask
    return jnp.sum(l), jnp.sum(l * l)


def ica_logpdf(x, w):
    """log p(x | W) per row of x (paper Eqn in section 6.2)."""
    _, logdet = jnp.linalg.slogdet(w)
    s = x @ w.T
    return logdet - jnp.sum(2.0 * jnp.log(2.0) + 2.0 * _log_cosh(0.5 * s), axis=-1)


def ica_lldiff_ref(x, mask, w, w_p):
    l = (ica_logpdf(x, w_p) - ica_logpdf(x, w)) * mask
    return jnp.sum(l), jnp.sum(l * l)


def linreg_logpdf(x, y, theta, lam):
    return -0.5 * lam * (y - theta * x) ** 2


def linreg_lldiff_ref(x, y, mask, theta, theta_p, lam):
    l = (linreg_logpdf(x, y, theta_p, lam) - linreg_logpdf(x, y, theta, lam)) * mask
    return jnp.sum(l), jnp.sum(l * l)


def logistic_grad_ref(x, y, mask, theta):
    """Gradient of sum_i mask_i log sigmoid(y_i x_i^T theta) w.r.t. theta."""
    z = y * (x @ theta)
    sig = 1.0 / (1.0 + jnp.exp(z))  # sigmoid(-z)
    return (mask * y * sig) @ x


def linreg_grad_ref(x, y, mask, theta, lam):
    """Gradient of sum_i mask_i log p(y_i | x_i, theta) w.r.t. theta."""
    return jnp.sum(mask * lam * (y - theta * x) * x)


def logistic_predict_ref(x, theta):
    """p(y = +1 | x, theta) = sigmoid(x theta)."""
    return 1.0 / (1.0 + jnp.exp(-(x @ theta)))

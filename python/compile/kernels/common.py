"""Shared helpers for the Pallas kernels.

Numerically-stable primitives and the padding logic that lets a kernel
compiled for a fixed block size serve arbitrary batch sizes (the mask
input zeroes out padded rows, matching the Layer-3 contract where the
tail mini-batch of a without-replacement sweep may be short).
"""

import jax.numpy as jnp

# Block size along the batch dimension.  128 rows x 50 features of f32 is
# 25.6 KB -- comfortably VMEM-resident next to the (D, 2) parameter panel,
# and a multiple of the 8x128 VPU tile.
DEFAULT_BLOCK_M = 128


def softplus(z):
    """log(1 + exp(z)) computed stably for large |z|."""
    return jnp.maximum(z, 0.0) + jnp.log1p(jnp.exp(-jnp.abs(z)))


def log_sigmoid(z):
    """log(sigmoid(z)) = -softplus(-z)."""
    return -softplus(-z)


def log_cosh(z):
    """log(cosh(z)) computed stably: |z| + log1p(exp(-2|z|)) - log 2."""
    a = jnp.abs(z)
    return a + jnp.log1p(jnp.exp(-2.0 * a)) - jnp.log(2.0).astype(z.dtype)


def pad_batch(arr, block_m):
    """Pad the leading (batch) axis of ``arr`` up to a multiple of block_m."""
    m = arr.shape[0]
    pad = (-m) % block_m
    if pad == 0:
        return arr
    widths = [(0, pad)] + [(0, 0)] * (arr.ndim - 1)
    return jnp.pad(arr, widths)


def padded_len(m, block_m):
    return m + ((-m) % block_m)

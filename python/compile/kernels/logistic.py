"""Pallas kernel: logistic-regression log-likelihood difference moments.

Per datapoint (features x_i, label y_i in {-1, +1}):

    l_i = log sigmoid(y_i x_i^T theta') - log sigmoid(y_i x_i^T theta)

and the kernel returns the masked moments (sum l_i, sum l_i^2) consumed
by the sequential test.  theta and theta' are stacked into one (D, 2)
panel so a single MXU matmul serves both states; the log-sigmoid tail and
the moment reduction are fused so only two scalars leave VMEM.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import DEFAULT_BLOCK_M, log_sigmoid, pad_batch, padded_len


def _kernel(x_ref, y_ref, mask_ref, theta2_ref, sum_ref, sum2_ref):
    """One batch block: (bm, D) rows against the stacked (D, 2) panel."""
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        sum_ref[...] = jnp.zeros_like(sum_ref)
        sum2_ref[...] = jnp.zeros_like(sum2_ref)

    x = x_ref[...]            # (bm, D)
    y = y_ref[...]            # (bm,)
    mask = mask_ref[...]      # (bm,)
    theta2 = theta2_ref[...]  # (D, 2): column 0 = theta, column 1 = theta'

    # One matmul for both parameter states: z[:, 0] = X theta, z[:, 1] = X theta'.
    z = jnp.dot(x, theta2, preferred_element_type=jnp.float32)  # (bm, 2)
    yz = y[:, None] * z
    # l = log sig(y z') - log sig(y z)
    ll = log_sigmoid(yz)
    l = (ll[:, 1] - ll[:, 0]) * mask

    sum_ref[0, 0] += jnp.sum(l)
    sum2_ref[0, 0] += jnp.sum(l * l)


@functools.partial(jax.jit, static_argnames=("block_m",))
def logistic_lldiff_block(x, y, mask, theta, theta_p, *, block_m=DEFAULT_BLOCK_M):
    """Moments of l_i for a batch whose length is a multiple of block_m."""
    m, d = x.shape
    assert m % block_m == 0, (m, block_m)
    theta2 = jnp.stack([theta, theta_p], axis=1)  # (D, 2)
    grid = (m // block_m,)
    sum_l, sum_l2 = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, d), lambda i: (i, 0)),
            pl.BlockSpec((block_m,), lambda i: (i,)),
            pl.BlockSpec((block_m,), lambda i: (i,)),
            pl.BlockSpec((d, 2), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1, 1), jnp.float32),
            jax.ShapeDtypeStruct((1, 1), jnp.float32),
        ],
        interpret=True,
    )(x, y, mask, theta2)
    return sum_l[0, 0], sum_l2[0, 0]


def logistic_lldiff(x, y, mask, theta, theta_p, *, block_m=DEFAULT_BLOCK_M):
    """Public entry: pads an arbitrary batch length up to the block size."""
    x = pad_batch(x.astype(jnp.float32), block_m)
    y = pad_batch(y.astype(jnp.float32), block_m)
    mask = pad_batch(mask.astype(jnp.float32), block_m)
    return logistic_lldiff_block(
        x, y, mask, theta.astype(jnp.float32), theta_p.astype(jnp.float32),
        block_m=block_m,
    )


def vmem_bytes(block_m, d):
    """Analytic VMEM footprint of one grid step (perf model, DESIGN §Perf)."""
    per_block = block_m * d + 2 * block_m  # x, y, mask
    panel = d * 2
    inter = block_m * 2 * 3                # z, yz, ll
    return 4 * (per_block + panel + inter + 2)

"""Pallas kernel: ICA log-likelihood difference moments.

Model (paper section 6.2): p(x | W) = |det W| prod_j [4 cosh^2(0.5 w_j^T x)]^-1
with W on the Stiefel manifold of orthonormal matrices, so

    log p(x | W) = log|det W| - sum_j (2 log 2 + 2 log cosh(0.5 w_j^T x)).

Per datapoint:

    l_i = log p(x_i | W') - log p(x_i | W)
        = const + sum_j 2 log cosh(0.5 s_ij) - 2 log cosh(0.5 s'_ij)

where s = x W^T, s' = x W'^T and const = log|det W'| - log|det W| (the
2 log 2 terms cancel).  The determinant difference is constant across the
batch, computed once in Layer 2 (jnp.linalg.slogdet) and fed to the
kernel as a scalar so it participates in l_i *before* squaring.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import DEFAULT_BLOCK_M, log_cosh, pad_batch


def _kernel(x_ref, mask_ref, w2_ref, const_ref, sum_ref, sum2_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        sum_ref[...] = jnp.zeros_like(sum_ref)
        sum2_ref[...] = jnp.zeros_like(sum2_ref)

    x = x_ref[...]          # (bm, D)
    mask = mask_ref[...]    # (bm,)
    w2 = w2_ref[...]        # (2D, D): rows 0..D = W, rows D..2D = W'
    const = const_ref[0, 0]

    # One matmul for both unmixing matrices: s2[:, :D] = x W^T, s2[:, D:] = x W'^T.
    s2 = jnp.dot(x, w2.T, preferred_element_type=jnp.float32)  # (bm, 2D)
    lc = 2.0 * log_cosh(0.5 * s2)
    d = w2.shape[1]
    l = (const + jnp.sum(lc[:, :d], axis=1) - jnp.sum(lc[:, d:], axis=1)) * mask

    sum_ref[0, 0] += jnp.sum(l)
    sum2_ref[0, 0] += jnp.sum(l * l)


@functools.partial(jax.jit, static_argnames=("block_m",))
def ica_lldiff_block_const(x, mask, w, w_p, const, *, block_m=DEFAULT_BLOCK_M):
    """Moments of l_i with the logdet difference supplied as a scalar.

    The slogdet is NOT computed here: jnp.linalg.slogdet lowers to a
    TYPED_FFI LAPACK custom-call that xla_extension 0.5.1 cannot execute,
    so the AOT artifact takes `const = logdet(W') - logdet(W)` as an
    input (computed by the Rust coordinator's LU slogdet, or by the
    python wrapper below for the in-process path).
    """
    m, d = x.shape
    assert m % block_m == 0, (m, block_m)
    assert w.shape == (d, d) and w_p.shape == (d, d)
    w2 = jnp.concatenate([w, w_p], axis=0)  # (2D, D)
    const = jnp.asarray(const, jnp.float32).reshape(1, 1)
    grid = (m // block_m,)
    sum_l, sum_l2 = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, d), lambda i: (i, 0)),
            pl.BlockSpec((block_m,), lambda i: (i,)),
            pl.BlockSpec((2 * d, d), lambda i: (0, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1, 1), jnp.float32),
            jax.ShapeDtypeStruct((1, 1), jnp.float32),
        ],
        interpret=True,
    )(x, mask, w2, const)
    return sum_l[0, 0], sum_l2[0, 0]


def ica_lldiff_block(x, mask, w, w_p, *, block_m=DEFAULT_BLOCK_M):
    """Moments of l_i; computes the logdet difference in-process."""
    _, logdet = jnp.linalg.slogdet(w)
    _, logdet_p = jnp.linalg.slogdet(w_p)
    const = (logdet_p - logdet).astype(jnp.float32)
    return ica_lldiff_block_const(x, mask, w, w_p, const, block_m=block_m)


def ica_lldiff(x, mask, w, w_p, *, block_m=DEFAULT_BLOCK_M):
    """Public entry: pads an arbitrary batch length up to the block size."""
    x = pad_batch(x.astype(jnp.float32), block_m)
    mask = pad_batch(mask.astype(jnp.float32), block_m)
    return ica_lldiff_block(
        x, mask, w.astype(jnp.float32), w_p.astype(jnp.float32), block_m=block_m
    )

"""Layer-2 graph correctness: shapes and values vs the jnp oracles."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.kernels import ref


def _rng(seed=0):
    return np.random.default_rng(seed)


def _logistic_inputs(rng):
    x = rng.normal(size=(model.BATCH, model.LOGISTIC_D)).astype(np.float32)
    y = np.where(rng.random(model.BATCH) < 0.5, -1.0, 1.0).astype(np.float32)
    mask = (rng.random(model.BATCH) < 0.9).astype(np.float32)
    theta = (0.1 * rng.normal(size=model.LOGISTIC_D)).astype(np.float32)
    theta_p = (theta + 0.01 * rng.normal(size=model.LOGISTIC_D)).astype(np.float32)
    return x, y, mask, theta, theta_p


def test_logistic_lldiff_graph_matches_ref():
    x, y, mask, theta, theta_p = _logistic_inputs(_rng(0))
    s, s2 = model.logistic_lldiff_graph(x, y, mask, theta, theta_p)
    rs, rs2 = ref.logistic_lldiff_ref(x, y, mask, theta, theta_p)
    np.testing.assert_allclose(s, rs, rtol=3e-4, atol=1e-4)
    np.testing.assert_allclose(s2, rs2, rtol=3e-4, atol=1e-4)


def test_ica_lldiff_graph_matches_ref():
    rng = _rng(1)
    x = rng.normal(size=(model.BATCH, model.ICA_D)).astype(np.float32)
    mask = np.ones(model.BATCH, np.float32)
    q, r = np.linalg.qr(rng.normal(size=(model.ICA_D, model.ICA_D)))
    w = (q * np.sign(np.diag(r))).astype(np.float32)
    q, r = np.linalg.qr(rng.normal(size=(model.ICA_D, model.ICA_D)))
    w_p = (q * np.sign(np.diag(r))).astype(np.float32)
    const = np.array(
        [np.linalg.slogdet(w_p)[1] - np.linalg.slogdet(w)[1]], np.float32
    )
    s, s2 = model.ica_lldiff_graph(x, mask, w, w_p, const)
    rs, rs2 = ref.ica_lldiff_ref(x, mask, w, w_p)
    np.testing.assert_allclose(s, rs, rtol=3e-4, atol=5e-4)
    np.testing.assert_allclose(s2, rs2, rtol=3e-4, atol=5e-4)


def test_linreg_lldiff_graph_matches_ref():
    rng = _rng(2)
    x = rng.normal(size=model.BATCH).astype(np.float32)
    y = (0.5 * x + rng.normal(size=model.BATCH) / 3.0).astype(np.float32)
    mask = np.ones(model.BATCH, np.float32)
    s, s2 = model.linreg_lldiff_graph(
        x, y, mask,
        np.array([0.4], np.float32), np.array([0.55], np.float32),
        np.array([3.0], np.float32),
    )
    rs, rs2 = ref.linreg_lldiff_ref(x, y, mask, 0.4, 0.55, 3.0)
    np.testing.assert_allclose(s, rs, rtol=3e-4, atol=1e-4)
    np.testing.assert_allclose(s2, rs2, rtol=3e-4, atol=1e-4)


def test_logistic_grad_graph_matches_ref():
    x, y, mask, theta, _ = _logistic_inputs(_rng(3))
    (g,) = model.logistic_grad_graph(x, y, mask, theta)
    rg = ref.logistic_grad_ref(x, y, mask, theta)
    assert g.shape == (model.LOGISTIC_D,)
    np.testing.assert_allclose(g, rg, rtol=1e-4, atol=1e-4)


def test_linreg_grad_graph_matches_ref():
    rng = _rng(4)
    x = rng.normal(size=model.BATCH).astype(np.float32)
    y = (0.5 * x + rng.normal(size=model.BATCH) / 3.0).astype(np.float32)
    mask = (rng.random(model.BATCH) < 0.8).astype(np.float32)
    (g,) = model.linreg_grad_graph(
        x, y, mask, np.array([0.3], np.float32), np.array([3.0], np.float32)
    )
    rg = ref.linreg_grad_ref(x, y, mask, 0.3, 3.0)
    np.testing.assert_allclose(g[0], rg, rtol=1e-4, atol=1e-4)


def test_logistic_predict_graph_matches_ref():
    rng = _rng(5)
    x = rng.normal(size=(model.PREDICT_T, model.LOGISTIC_D)).astype(np.float32)
    theta = (0.1 * rng.normal(size=model.LOGISTIC_D)).astype(np.float32)
    (p,) = model.logistic_predict_graph(x, theta)
    rp = ref.logistic_predict_ref(x, theta)
    assert p.shape == (model.PREDICT_T,)
    np.testing.assert_allclose(p, rp, rtol=1e-5, atol=1e-6)
    assert float(jnp.min(p)) >= 0.0 and float(jnp.max(p)) <= 1.0


def test_graph_registry_shapes_consistent():
    """Every GRAPHS entry must eval_shape without error and name all args."""
    for name, (fn, specs, arg_names) in model.GRAPHS.items():
        assert len(specs) == len(arg_names), name
        outs = jax.eval_shape(fn, *specs)
        assert len(outs) >= 1, name
        for o in outs:
            assert o.dtype == jnp.float32, name

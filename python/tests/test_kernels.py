"""Pallas kernel vs pure-jnp oracle — the core L1 correctness signal.

Hypothesis sweeps batch sizes (including non-multiples of the block size,
exercising the pad+mask path), feature dims, parameter scales, and mask
patterns; every case asserts the fused kernel moments match ref.py.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import (
    ica_lldiff,
    linreg_lldiff,
    logistic_lldiff,
)
from compile.kernels import ref
from compile.kernels.common import DEFAULT_BLOCK_M, pad_batch, padded_len

RTOL = 3e-4
ATOL = 1e-4


def _rng(seed):
    return np.random.default_rng(seed)


# --------------------------------------------------------------------------
# logistic
# --------------------------------------------------------------------------

@settings(max_examples=40, deadline=None)
@given(
    m=st.integers(1, 400),
    d=st.integers(1, 60),
    scale=st.floats(1e-3, 3.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_logistic_matches_ref(m, d, scale, seed):
    rng = _rng(seed)
    x = rng.normal(size=(m, d)).astype(np.float32)
    y = np.where(rng.random(m) < 0.5, -1.0, 1.0).astype(np.float32)
    mask = np.ones(m, np.float32)
    theta = rng.normal(size=d).astype(np.float32)
    theta_p = (theta + scale * rng.normal(size=d)).astype(np.float32)

    s, s2 = logistic_lldiff(x, y, mask, theta, theta_p, block_m=64)
    rs, rs2 = ref.logistic_lldiff_ref(x, y, mask, theta, theta_p)
    np.testing.assert_allclose(s, rs, rtol=RTOL, atol=ATOL)
    np.testing.assert_allclose(s2, rs2, rtol=RTOL, atol=ATOL)


@settings(max_examples=20, deadline=None)
@given(m=st.integers(2, 200), seed=st.integers(0, 2**31 - 1))
def test_logistic_mask_zeroes_rows(m, seed):
    """Masked-out rows must contribute exactly nothing."""
    rng = _rng(seed)
    d = 5
    x = rng.normal(size=(m, d)).astype(np.float32)
    y = np.where(rng.random(m) < 0.5, -1.0, 1.0).astype(np.float32)
    theta = rng.normal(size=d).astype(np.float32)
    theta_p = rng.normal(size=d).astype(np.float32)
    mask = (rng.random(m) < 0.6).astype(np.float32)
    keep = mask > 0
    if keep.sum() == 0:
        return

    s_full, s2_full = logistic_lldiff(x, y, mask, theta, theta_p, block_m=64)
    s_sub, s2_sub = logistic_lldiff(
        x[keep], y[keep], np.ones(int(keep.sum()), np.float32),
        theta, theta_p, block_m=64,
    )
    np.testing.assert_allclose(s_full, s_sub, rtol=RTOL, atol=ATOL)
    np.testing.assert_allclose(s2_full, s2_sub, rtol=RTOL, atol=ATOL)


def test_logistic_identical_theta_zero():
    rng = _rng(1)
    m, d = 100, 10
    x = rng.normal(size=(m, d)).astype(np.float32)
    y = np.ones(m, np.float32)
    theta = rng.normal(size=d).astype(np.float32)
    s, s2 = logistic_lldiff(x, y, np.ones(m, np.float32), theta, theta)
    assert float(s) == 0.0
    assert float(s2) == 0.0


def test_logistic_large_logits_stable():
    """Extreme logits must not overflow (stable softplus)."""
    m, d = 64, 3
    x = np.full((m, d), 40.0, np.float32)
    y = np.ones(m, np.float32)
    theta = np.full(d, 10.0, np.float32)
    theta_p = np.full(d, -10.0, np.float32)
    s, s2 = logistic_lldiff(x, y, np.ones(m, np.float32), theta, theta_p)
    assert np.isfinite(float(s)) and np.isfinite(float(s2))
    rs, rs2 = ref.logistic_lldiff_ref(x, y, np.ones(m, np.float32), theta, theta_p)
    np.testing.assert_allclose(s, rs, rtol=1e-4)


# --------------------------------------------------------------------------
# ICA
# --------------------------------------------------------------------------

def _random_orthonormal(rng, d):
    q, r = np.linalg.qr(rng.normal(size=(d, d)))
    return (q * np.sign(np.diag(r))).astype(np.float32)


@settings(max_examples=30, deadline=None)
@given(
    m=st.integers(1, 300),
    d=st.integers(2, 8),
    seed=st.integers(0, 2**31 - 1),
)
def test_ica_matches_ref(m, d, seed):
    rng = _rng(seed)
    x = rng.normal(size=(m, d)).astype(np.float32)
    mask = np.ones(m, np.float32)
    w = _random_orthonormal(rng, d)
    w_p = _random_orthonormal(rng, d)
    s, s2 = ica_lldiff(x, mask, w, w_p, block_m=64)
    rs, rs2 = ref.ica_lldiff_ref(x, mask, w, w_p)
    np.testing.assert_allclose(s, rs, rtol=RTOL, atol=5 * ATOL)
    np.testing.assert_allclose(s2, rs2, rtol=RTOL, atol=5 * ATOL)


def test_ica_nonorthonormal_logdet():
    """General (non-Stiefel) W: the slogdet constant must be included."""
    rng = _rng(7)
    m, d = 128, 4
    x = rng.normal(size=(m, d)).astype(np.float32)
    w = (np.eye(d) * 2.0).astype(np.float32)        # logdet = d log 2
    w_p = np.eye(d, dtype=np.float32)               # logdet = 0
    s, s2 = ica_lldiff(x, np.ones(m, np.float32), w, w_p)
    rs, rs2 = ref.ica_lldiff_ref(x, np.ones(m, np.float32), w, w_p)
    np.testing.assert_allclose(s, rs, rtol=RTOL, atol=ATOL)
    np.testing.assert_allclose(s2, rs2, rtol=RTOL, atol=ATOL)


def test_ica_identical_w_zero():
    rng = _rng(3)
    m, d = 96, 4
    x = rng.normal(size=(m, d)).astype(np.float32)
    w = _random_orthonormal(rng, d)
    s, s2 = ica_lldiff(x, np.ones(m, np.float32), w, w)
    np.testing.assert_allclose(float(s), 0.0, atol=1e-5)
    np.testing.assert_allclose(float(s2), 0.0, atol=1e-5)


# --------------------------------------------------------------------------
# linreg (SGLD toy)
# --------------------------------------------------------------------------

@settings(max_examples=30, deadline=None)
@given(
    m=st.integers(1, 400),
    theta=st.floats(-2.0, 2.0),
    dtheta=st.floats(-0.5, 0.5),
    lam=st.floats(0.1, 10.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_linreg_matches_ref(m, theta, dtheta, lam, seed):
    rng = _rng(seed)
    x = rng.normal(size=m).astype(np.float32)
    y = (0.5 * x + rng.normal(size=m) / 3.0).astype(np.float32)
    mask = np.ones(m, np.float32)
    s, s2 = linreg_lldiff(x, y, mask, theta, theta + dtheta, lam, block_m=64)
    rs, rs2 = ref.linreg_lldiff_ref(x, y, mask, theta, theta + dtheta, lam)
    np.testing.assert_allclose(s, rs, rtol=RTOL, atol=ATOL)
    np.testing.assert_allclose(s2, rs2, rtol=RTOL, atol=ATOL)


# --------------------------------------------------------------------------
# padding helpers
# --------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(m=st.integers(1, 1000), block=st.sampled_from([32, 64, 128]))
def test_padded_len_properties(m, block):
    p = padded_len(m, block)
    assert p >= m
    assert p % block == 0
    assert p - m < block


def test_pad_batch_preserves_prefix():
    rng = _rng(0)
    a = rng.normal(size=(37, 3)).astype(np.float32)
    p = np.asarray(pad_batch(a, 64))
    assert p.shape == (64, 3)
    np.testing.assert_array_equal(p[:37], a)
    np.testing.assert_array_equal(p[37:], 0.0)


# --------------------------------------------------------------------------
# ICA const-input path (the AOT artifact takes logdet diff as a scalar)
# --------------------------------------------------------------------------

def test_ica_const_path_matches_wrapper():
    """The artifact-shaped entry (const as input) must equal the wrapper
    that computes slogdet in-process."""
    from compile.kernels.ica import ica_lldiff_block, ica_lldiff_block_const

    rng = _rng(11)
    m, d = 128, 4
    x = rng.normal(size=(m, d)).astype(np.float32)
    mask = np.ones(m, np.float32)
    w = _random_orthonormal(rng, d)
    w_p = (2.0 * np.eye(d)).astype(np.float32)  # non-trivial logdet
    s1, s21 = ica_lldiff_block(x, mask, w, w_p)
    const = np.float32(np.linalg.slogdet(w_p)[1] - np.linalg.slogdet(w)[1])
    s2, s22 = ica_lldiff_block_const(x, mask, w, w_p, const)
    np.testing.assert_allclose(s1, s2, rtol=1e-6)
    np.testing.assert_allclose(s21, s22, rtol=1e-6)


@settings(max_examples=10, deadline=None)
@given(block=st.sampled_from([32, 64, 128, 256]), seed=st.integers(0, 2**31 - 1))
def test_logistic_block_size_invariance(block, seed):
    """The block size is a tiling choice: results must not depend on it."""
    rng = _rng(seed)
    m, d = 200, 12
    x = rng.normal(size=(m, d)).astype(np.float32)
    y = np.where(rng.random(m) < 0.5, -1.0, 1.0).astype(np.float32)
    mask = np.ones(m, np.float32)
    theta = rng.normal(size=d).astype(np.float32)
    theta_p = (theta + 0.05 * rng.normal(size=d)).astype(np.float32)
    s_ref, s2_ref = logistic_lldiff(x, y, mask, theta, theta_p, block_m=128)
    s, s2 = logistic_lldiff(x, y, mask, theta, theta_p, block_m=block)
    np.testing.assert_allclose(s, s_ref, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(s2, s2_ref, rtol=1e-5, atol=1e-5)


def test_vmem_estimate_within_tpu_budget():
    """Analytic perf model: the default block fits VMEM comfortably."""
    from compile.kernels.logistic import vmem_bytes
    b = vmem_bytes(128, 50)
    assert b < 64 * 1024, f"block VMEM {b} bytes"
    # even a 512-row block at D=50 stays far below a 16 MB VMEM core
    assert vmem_bytes(512, 50) < 1024 * 1024

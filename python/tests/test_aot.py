"""AOT pipeline tests: HLO-text artifacts are well-formed and executable.

The critical invariant is the interchange format: HLO *text* that the
xla crate's 0.5.1 parser accepts, entry computation returning a tuple.
We additionally round-trip one artifact through jax's own XLA client and
compare against the oracle — the same thing the Rust runtime does.
"""

import os

import numpy as np
import pytest
import jax
from jax._src.lib import xla_client as xc

from compile import aot, model
from compile.kernels import ref


@pytest.fixture(scope="module")
def artifacts(tmp_path_factory):
    out = str(tmp_path_factory.mktemp("artifacts"))
    manifest = aot.lower_all(out)
    return out, manifest


def test_manifest_covers_all_graphs(artifacts):
    out, manifest = artifacts
    names = {line.split()[0] for line in manifest.strip().splitlines()}
    assert names == set(model.GRAPHS.keys())


def test_artifacts_are_hlo_text(artifacts):
    out, manifest = artifacts
    for line in manifest.strip().splitlines():
        name, fname = line.split()[:2]
        path = os.path.join(out, fname)
        text = open(path).read()
        assert "HloModule" in text, name
        assert "ENTRY" in text, name
        # jax >= 0.5 proto ids overflow xla 0.5.1; text must be the format.
        assert not text.startswith("\x08"), "binary proto leaked"


def test_manifest_shapes_parse(artifacts):
    out, manifest = artifacts
    for line in manifest.strip().splitlines():
        fields = line.split()
        assert len(fields) == 4, line
        assert fields[2].startswith("in=") and fields[3].startswith("out=")
        for part in fields[2][3:].split(","):
            arg, dtype, dims = part.split(":")
            assert dtype == "float32"
            assert dims == "scalar" or all(
                int(d) > 0 for d in dims.split("x")
            )


def test_hlo_text_reparses_and_executes(artifacts):
    """Round-trip logistic_lldiff text through XLA and check the numbers.

    Mirrors what the Rust runtime does: parse the HLO text back into a
    module (the parser reassigns instruction ids, which is why text is the
    interchange format), compile it on the CPU PJRT client, execute, and
    compare against the oracle.
    """
    out, _ = artifacts
    text = open(os.path.join(out, "logistic_lldiff.hlo.txt")).read()
    proto = xc._xla.hlo_module_from_text(text).as_serialized_hlo_module_proto()
    comp = xc.XlaComputation(proto)
    mlir = xc._xla.mlir.xla_computation_to_mlir_module(comp)
    backend = jax.devices("cpu")[0].client
    exe = backend.compile_and_load(mlir, backend.local_devices())

    rng = np.random.default_rng(0)
    x = rng.normal(size=(model.BATCH, model.LOGISTIC_D)).astype(np.float32)
    y = np.where(rng.random(model.BATCH) < 0.5, -1.0, 1.0).astype(np.float32)
    mask = np.ones(model.BATCH, np.float32)
    theta = (0.1 * rng.normal(size=model.LOGISTIC_D)).astype(np.float32)
    theta_p = (theta + 0.01 * rng.normal(size=model.LOGISTIC_D)).astype(np.float32)

    args = [backend.buffer_from_pyval(v)
            for v in (x, y, mask, theta, theta_p)]
    got = [np.asarray(o) for o in exe.execute(args)]
    rs, rs2 = ref.logistic_lldiff_ref(x, y, mask, theta, theta_p)
    np.testing.assert_allclose(got[0], rs, rtol=3e-4, atol=1e-4)
    np.testing.assert_allclose(got[1], rs2, rtol=3e-4, atol=1e-4)

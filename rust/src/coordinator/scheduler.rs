//! Mini-batch scheduler: uniform draws *without replacement* from the
//! datapoint population, amortized O(batch) per draw.
//!
//! Alg. 1 consumes the population in growing prefixes; a naive
//! implementation reshuffles all N indices every MH step (O(N) even when
//! the test stops after 500 points). We instead keep one persistent
//! permutation buffer and lazily Fisher-Yates only the prefix actually
//! consumed: position k swaps with a uniform position in [k, N). Because
//! each step's prefix is re-randomized against the whole buffer, every
//! step sees an exchangeable uniform without-replacement sample no matter
//! what earlier steps consumed.
//!
//! The drawn `&[u32]` slice is the exact index type the moments kernels
//! take (`LlDiffModel::lldiff_moments`), so acceptance rules feed it to
//! the kernels directly — there is no per-stage widening copy anywhere.

use crate::coordinator::checkpoint::{BinReader, BinWriter, CkptError, Persist};
use crate::data::sharded::{check_u32_indexable, DataTooLarge};
use crate::stats::Pcg64;

pub struct MinibatchScheduler {
    indices: Vec<u32>,
    /// consumed prefix length of the current draw
    pos: usize,
}

impl MinibatchScheduler {
    /// Build the persistent permutation buffer over `n` datapoints.
    /// The buffer stores indices as `u32`, so the population is
    /// validated against the `u32` index space *before* allocation —
    /// a too-tall population is a typed [`DataTooLarge`] error, never a
    /// silent `n as u32` truncation (the global index space can exceed
    /// `u32` once the store is sharded; per-shard index spaces stay
    /// narrow).
    pub fn new(n: usize) -> Result<Self, DataTooLarge> {
        assert!(n > 0, "scheduler needs a non-empty population");
        check_u32_indexable("minibatch scheduler", n)?;
        Ok(MinibatchScheduler { indices: (0..n as u32).collect(), pos: 0 })
    }

    pub fn n(&self) -> usize {
        self.indices.len()
    }

    /// Start a fresh without-replacement draw (call once per MH step).
    pub fn reset(&mut self) {
        self.pos = 0;
    }

    /// Number of indices handed out since the last reset.
    pub fn consumed(&self) -> usize {
        self.pos
    }

    pub fn remaining(&self) -> usize {
        self.indices.len() - self.pos
    }

    /// Draw the next mini-batch of up to `m` fresh indices; returns the
    /// drawn slice (empty once the population is exhausted).
    pub fn next_batch(&mut self, m: usize, rng: &mut Pcg64) -> &[u32] {
        let n = self.indices.len();
        let take = m.min(n - self.pos);
        let start = self.pos;
        for k in start..start + take {
            let j = k + rng.below(n - k);
            self.indices.swap(k, j);
        }
        self.pos += take;
        &self.indices[start..self.pos]
    }

    /// The full prefix consumed so far in this draw.
    pub fn consumed_slice(&self) -> &[u32] {
        &self.indices[..self.pos]
    }
}

/// The permutation buffer is chain state, not a temporary: `reset` only
/// rewinds `pos`, so the buffer order carries across steps and feeds every
/// future draw. Checkpoints must therefore persist it verbatim —
/// restoring a freshly shuffled scheduler would break resume bit-identity.
impl Persist for MinibatchScheduler {
    fn persist(&self, w: &mut BinWriter) {
        self.indices.persist(w);
        w.put_usize(self.pos);
    }

    fn restore(r: &mut BinReader<'_>) -> Result<Self, CkptError> {
        let indices = Vec::<u32>::restore(r)?;
        let pos = r.usize_()?;
        let n = indices.len();
        if n == 0 || n > u32::MAX as usize {
            return Err(CkptError::Corrupt("scheduler population size out of range"));
        }
        if pos > n {
            return Err(CkptError::Corrupt("scheduler position past population"));
        }
        let mut seen = vec![false; n];
        for &i in &indices {
            if (i as usize) >= n || std::mem::replace(&mut seen[i as usize], true) {
                return Err(CkptError::Corrupt("scheduler buffer is not a permutation"));
            }
        }
        Ok(MinibatchScheduler { indices, pos })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit;

    #[test]
    fn batches_are_disjoint_and_in_range() {
        testkit::forall(64, |rng| {
            let n = rng.below(500) + 10;
            let m = rng.below(50) + 1;
            let mut sched = MinibatchScheduler::new(n).unwrap();
            sched.reset();
            let mut seen = std::collections::HashSet::new();
            loop {
                let batch: Vec<u32> = sched.next_batch(m, rng).to_vec();
                if batch.is_empty() {
                    break;
                }
                for &i in &batch {
                    assert!((i as usize) < n);
                    assert!(seen.insert(i), "duplicate index {i}");
                }
            }
            assert_eq!(seen.len(), n, "must exhaust the population");
        });
    }

    #[test]
    #[cfg(target_pointer_width = "64")]
    fn too_tall_population_is_a_typed_error_not_a_truncation() {
        // validated before the index buffer is allocated, so this is
        // cheap even though the population would be > 4 Gi entries
        let err = MinibatchScheduler::new(u32::MAX as usize + 1).unwrap_err();
        assert_eq!(err.what, "minibatch scheduler");
        assert_eq!(err.n, u32::MAX as usize + 1);
        // the exact boundary still works... as a type; don't allocate
        // 16 GiB in a unit test to prove it.
        assert!(MinibatchScheduler::new(1).is_ok());
    }

    #[test]
    fn consumed_slice_is_the_draw_prefix() {
        let mut sched = MinibatchScheduler::new(50).unwrap();
        let mut rng = Pcg64::seeded(3);
        sched.reset();
        let first: Vec<u32> = sched.next_batch(7, &mut rng).to_vec();
        let second: Vec<u32> = sched.next_batch(5, &mut rng).to_vec();
        let prefix: Vec<u32> = first.iter().chain(&second).copied().collect();
        assert_eq!(sched.consumed_slice(), &prefix[..]);
        assert_eq!(sched.consumed(), 12);
    }

    #[test]
    fn tail_batch_is_short() {
        let mut rng = Pcg64::seeded(0);
        let mut sched = MinibatchScheduler::new(10).unwrap();
        sched.reset();
        assert_eq!(sched.next_batch(7, &mut rng).len(), 7);
        assert_eq!(sched.next_batch(7, &mut rng).len(), 3);
        assert_eq!(sched.next_batch(7, &mut rng).len(), 0);
        assert_eq!(sched.consumed(), 10);
    }

    use crate::stats::Pcg64;

    #[test]
    fn persist_roundtrip_resumes_identical_draw_sequence() {
        let mut rng = Pcg64::seeded(7);
        let mut sched = MinibatchScheduler::new(200).unwrap();
        // consume a few steps so the permutation is non-trivial and the
        // draw is mid-flight
        for _ in 0..3 {
            sched.reset();
            sched.next_batch(37, &mut rng);
        }
        let mut w = BinWriter::new();
        sched.persist(&mut w);
        let bytes = w.into_bytes();
        let mut restored = MinibatchScheduler::restore(&mut BinReader::new(&bytes)).unwrap();
        assert_eq!(restored.n(), sched.n());
        assert_eq!(restored.consumed(), sched.consumed());
        let mut rng_b = rng.clone();
        for _ in 0..5 {
            sched.reset();
            restored.reset();
            let a: Vec<u32> = sched.next_batch(29, &mut rng).to_vec();
            let b: Vec<u32> = restored.next_batch(29, &mut rng_b).to_vec();
            assert_eq!(a, b);
        }
    }

    #[test]
    fn restore_rejects_non_permutations() {
        let encode = |indices: &Vec<u32>, pos: usize| {
            let mut w = BinWriter::new();
            indices.persist(&mut w);
            w.put_usize(pos);
            w.into_bytes()
        };
        // duplicate index
        let bytes = encode(&vec![0, 1, 1, 3], 0);
        assert!(MinibatchScheduler::restore(&mut BinReader::new(&bytes)).is_err());
        // out-of-range index
        let bytes = encode(&vec![0, 1, 9], 0);
        assert!(MinibatchScheduler::restore(&mut BinReader::new(&bytes)).is_err());
        // position past the population
        let bytes = encode(&vec![0, 1, 2], 4);
        assert!(MinibatchScheduler::restore(&mut BinReader::new(&bytes)).is_err());
        // empty population
        let bytes = encode(&vec![], 0);
        assert!(MinibatchScheduler::restore(&mut BinReader::new(&bytes)).is_err());
    }

    #[test]
    fn draws_are_uniform_across_steps() {
        // after many reset+draw cycles, every index appears in the first
        // batch roughly equally often (exchangeability across steps).
        let n = 20;
        let m = 5;
        let steps = 40_000;
        let mut rng = Pcg64::seeded(1);
        let mut sched = MinibatchScheduler::new(n).unwrap();
        let mut counts = vec![0usize; n];
        for _ in 0..steps {
            sched.reset();
            for &i in sched.next_batch(m, &mut rng) {
                counts[i as usize] += 1;
            }
        }
        let expect = steps * m / n; // 10_000
        for (i, &c) in counts.iter().enumerate() {
            assert!(
                (c as f64 - expect as f64).abs() < 0.05 * expect as f64,
                "index {i}: {c} vs {expect}"
            );
        }
    }

    #[test]
    fn pairwise_inclusion_is_uniform() {
        // second-order exchangeability: each unordered pair co-occurs in
        // the first batch with roughly equal frequency.
        let n = 8;
        let m = 3;
        let steps = 60_000;
        let mut rng = Pcg64::seeded(2);
        let mut sched = MinibatchScheduler::new(n).unwrap();
        let mut counts = vec![vec![0usize; n]; n];
        for _ in 0..steps {
            sched.reset();
            let batch: Vec<u32> = sched.next_batch(m, &mut rng).to_vec();
            for a in 0..batch.len() {
                for b in a + 1..batch.len() {
                    let (i, j) = (batch[a] as usize, batch[b] as usize);
                    counts[i.min(j)][i.max(j)] += 1;
                }
            }
        }
        // pairs per step: C(3,2)=3, total pairs C(8,2)=28
        let expect = steps * 3 / 28;
        for i in 0..n {
            for j in i + 1..n {
                let c = counts[i][j];
                assert!(
                    (c as f64 - expect as f64).abs() < 0.08 * expect as f64,
                    "pair ({i},{j}): {c} vs {expect}"
                );
            }
        }
    }
}

//! Metropolis-Hastings step orchestration: the exact O(N) test and the
//! approximate sequential test behind one interface (paper §2 and §4).

use crate::coordinator::austerity::{seq_mh_test, seq_mh_test_cached, SeqTestConfig, SeqTestOutcome};
use crate::coordinator::scheduler::MinibatchScheduler;
use crate::models::traits::{full_scan_moments, CachedLlDiff, LlDiffModel, Proposal};
use crate::stats::Pcg64;

/// Which accept/reject test to run.
#[derive(Clone, Debug)]
pub enum MhMode {
    /// Classic full-data test (epsilon = 0 baseline).
    Exact,
    /// Sequential approximate test with the given configuration.
    Approx(SeqTestConfig),
}

impl MhMode {
    pub fn approx(eps: f64, batch: usize) -> MhMode {
        if eps <= 0.0 {
            MhMode::Exact
        } else {
            MhMode::Approx(SeqTestConfig::new(eps, batch))
        }
    }

    /// Approximate test with an explicit bound sequence (e.g. the
    /// Wang-Tsiatis / O'Brien-Fleming designs of supp. D).
    pub fn approx_with_bound(bound: crate::coordinator::austerity::BoundSeq, batch: usize) -> MhMode {
        MhMode::Approx(SeqTestConfig { batch_size: batch, bound })
    }
}

/// Result of one MH step.
#[derive(Clone, Copy, Debug)]
pub struct StepInfo {
    pub accepted: bool,
    /// Datapoints examined by the accept/reject test.
    pub n_used: usize,
    /// Sequential-test stages (1 for exact).
    pub stages: usize,
}

/// Reusable per-chain scratch (avoids per-step allocation).
pub struct MhScratch {
    pub sched: MinibatchScheduler,
    idx_buf: Vec<usize>,
}

impl MhScratch {
    pub fn new(n: usize) -> Self {
        MhScratch { sched: MinibatchScheduler::new(n), idx_buf: Vec::new() }
    }
}

/// Execute one MH accept/reject decision for a proposed move.
///
/// `proposal.log_correction` must be
/// `log[rho(cur) q(prop|cur) / (rho(prop) q(cur|prop))]` so that
/// `mu_0 = (ln u + log_correction) / N` (Eqn. 2). On acceptance `cur` is
/// overwritten with the proposal's parameter.
pub fn mh_step<M: LlDiffModel>(
    model: &M,
    cur: &mut M::Param,
    proposal: Proposal<M::Param>,
    mode: &MhMode,
    scratch: &mut MhScratch,
    rng: &mut Pcg64,
) -> StepInfo {
    let n = model.n() as f64;
    let u = rng.uniform_pos();

    // A proposal with -inf correction (zero prior mass at cur — cannot
    // happen for valid chains) or +inf (zero prior mass at prop) resolves
    // without data.
    if proposal.log_correction == f64::INFINITY {
        return StepInfo { accepted: false, n_used: 0, stages: 0 };
    }
    let mu0 = (u.ln() + proposal.log_correction) / n;

    let (accepted, outcome): (bool, Option<SeqTestOutcome>) = match mode {
        MhMode::Exact => {
            // chunked full scan through the reusable scratch buffer: no
            // length-N index vector, no per-step allocation
            let (s, _) = model.full_moments_buf(cur, &proposal.param, &mut scratch.idx_buf);
            (s / n > mu0, None)
        }
        MhMode::Approx(cfg) => {
            let out = seq_mh_test(
                model,
                cur,
                &proposal.param,
                mu0,
                cfg,
                &mut scratch.sched,
                rng,
                &mut scratch.idx_buf,
            );
            (out.accept, Some(out))
        }
    };

    if accepted {
        *cur = proposal.param;
    }
    match outcome {
        Some(o) => StepInfo { accepted, n_used: o.n_used, stages: o.stages },
        None => StepInfo { accepted, n_used: model.n(), stages: 1 },
    }
}

/// `mh_step` on the state-caching fast path: current-side per-datapoint
/// statistics live in `cache` across steps, so each decision computes
/// only the proposal side (and a rejected step leaves the cache valid
/// for free). Decisions are bit-identical to `mh_step` under the same
/// RNG stream — regression-tested in `tests/integration_engine.rs`.
pub fn mh_step_cached<M: CachedLlDiff>(
    model: &M,
    cur: &mut M::Param,
    cache: &mut M::Cache,
    proposal: Proposal<M::Param>,
    mode: &MhMode,
    scratch: &mut MhScratch,
    rng: &mut Pcg64,
) -> StepInfo {
    let n = model.n() as f64;
    let u = rng.uniform_pos();

    if proposal.log_correction == f64::INFINITY {
        return StepInfo { accepted: false, n_used: 0, stages: 0 };
    }
    let mu0 = (u.ln() + proposal.log_correction) / n;

    model.begin_step(cache);
    let (accepted, outcome): (bool, Option<SeqTestOutcome>) = match mode {
        MhMode::Exact => {
            let (s, _) =
                cached_full_moments(model, cache, &proposal.param, &mut scratch.idx_buf);
            (s / n > mu0, None)
        }
        MhMode::Approx(cfg) => {
            let out = seq_mh_test_cached(
                model,
                cache,
                &proposal.param,
                mu0,
                cfg,
                &mut scratch.sched,
                rng,
                &mut scratch.idx_buf,
            );
            (out.accept, Some(out))
        }
    };
    model.end_step(cache, &proposal.param, accepted);

    if accepted {
        *cur = proposal.param;
    }
    match outcome {
        Some(o) => StepInfo { accepted, n_used: o.n_used, stages: o.stages },
        None => StepInfo { accepted, n_used: model.n(), stages: 1 },
    }
}

/// Full-population moments through the cache; shares `full_scan_moments`
/// with the uncached exact path, so both accumulate in the same order
/// (bit-identity by construction).
fn cached_full_moments<M: CachedLlDiff>(
    model: &M,
    cache: &mut M::Cache,
    prop: &M::Param,
    buf: &mut Vec<usize>,
) -> (f64, f64) {
    full_scan_moments(model.n(), buf, |idx| model.cached_moments(cache, idx, prop))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::traits::testutil::FixedPopulation;
    use crate::models::traits::ProposalKernel;

    #[test]
    fn exact_step_uses_all_data() {
        let model = FixedPopulation { ls: vec![1.0; 100] };
        let mut scratch = MhScratch::new(100);
        let mut rng = Pcg64::seeded(0);
        let mut cur = ();
        let info = mh_step(
            &model,
            &mut cur,
            Proposal { param: (), log_correction: 0.0 },
            &MhMode::Exact,
            &mut scratch,
            &mut rng,
        );
        assert_eq!(info.n_used, 100);
        // mean l = 1 -> acceptance prob = min(1, e^{100}) = 1
        assert!(info.accepted);
    }

    #[test]
    fn certain_rejection() {
        let model = FixedPopulation { ls: vec![-10.0; 100] };
        let mut scratch = MhScratch::new(100);
        let mut rng = Pcg64::seeded(1);
        let mut cur = ();
        for _ in 0..20 {
            let info = mh_step(
                &model,
                &mut cur,
                Proposal { param: (), log_correction: 0.0 },
                &MhMode::Exact,
                &mut scratch,
                &mut rng,
            );
            assert!(!info.accepted);
        }
    }

    #[test]
    fn infinite_correction_rejects_without_data() {
        let model = FixedPopulation { ls: vec![1.0; 50] };
        let mut scratch = MhScratch::new(50);
        let mut rng = Pcg64::seeded(2);
        let mut cur = ();
        let info = mh_step(
            &model,
            &mut cur,
            Proposal { param: (), log_correction: f64::INFINITY },
            &MhMode::Exact,
            &mut scratch,
            &mut rng,
        );
        assert!(!info.accepted);
        assert_eq!(info.n_used, 0);
    }

    #[test]
    fn exact_acceptance_rate_matches_formula() {
        // With constant l and correction c, Pa = min(1, exp(N*l - c)).
        let n = 40;
        let l = 0.01; // exp(0.4 - c)
        let c = 0.6f64;
        let want = (n as f64 * l - c).exp(); // ~0.819
        let model = FixedPopulation { ls: vec![l; n] };
        let mut scratch = MhScratch::new(n);
        let mut rng = Pcg64::seeded(3);
        let trials = 40_000;
        let mut acc = 0usize;
        let mut cur = ();
        for _ in 0..trials {
            let info = mh_step(
                &model,
                &mut cur,
                Proposal { param: (), log_correction: c },
                &MhMode::Exact,
                &mut scratch,
                &mut rng,
            );
            if info.accepted {
                acc += 1;
            }
        }
        let rate = acc as f64 / trials as f64;
        assert!((rate - want).abs() < 0.01, "rate {rate} want {want}");
    }

    #[test]
    fn approx_matches_exact_acceptance_when_unambiguous() {
        // Wide margin between mu and mu0: approximate acceptance rate must
        // track the exact one closely even with a large epsilon.
        let n = 10_000;
        let mut rng = Pcg64::seeded(4);
        let ls: Vec<f64> = (0..n).map(|_| 3e-4 + 1e-4 * rng.normal()).collect();
        let model = FixedPopulation { ls };
        let want = {
            // Pa = E_u[mu > mu0(u)] = min(1, exp(N mu)); N*mu = 3.0
            let nm: f64 = 3.0;
            nm.exp().min(1.0)
        };
        assert_eq!(want, 1.0);
        let mut scratch = MhScratch::new(n);
        let mode = MhMode::approx(0.05, 500);
        let mut acc = 0;
        let mut cur = ();
        for _ in 0..200 {
            let info = mh_step(
                &model,
                &mut cur,
                Proposal { param: (), log_correction: 0.0 },
                &mode,
                &mut scratch,
                &mut rng,
            );
            assert!(info.n_used <= n);
            if info.accepted {
                acc += 1;
            }
        }
        assert!(acc >= 195, "acc={acc}");
    }

    #[test]
    fn cached_step_matches_uncached_step_exactly() {
        use crate::data::synthetic::linreg_toy;
        use crate::models::LinRegModel;

        let model = LinRegModel::new(linreg_toy(3_000, 0), 3.0, 4950.0);
        let kernel = |cur: &f64, rng: &mut Pcg64| Proposal {
            param: cur + rng.normal_scaled(0.0, 0.005),
            log_correction: 0.0,
        };
        for mode in [MhMode::Exact, MhMode::approx(0.05, 300)] {
            let mut rng_a = Pcg64::new(11, 4);
            let mut rng_b = Pcg64::new(11, 4);
            let mut scratch_a = MhScratch::new(model.n());
            let mut scratch_b = MhScratch::new(model.n());
            let mut cur_a = 0.45f64;
            let mut cur_b = 0.45f64;
            let mut cache = model.init_cache(&cur_b);
            for step in 0..150 {
                let prop_a = kernel.propose(&cur_a, &mut rng_a);
                let prop_b = kernel.propose(&cur_b, &mut rng_b);
                assert_eq!(prop_a.param.to_bits(), prop_b.param.to_bits());
                let a = mh_step(&model, &mut cur_a, prop_a, &mode, &mut scratch_a, &mut rng_a);
                let b = mh_step_cached(
                    &model,
                    &mut cur_b,
                    &mut cache,
                    prop_b,
                    &mode,
                    &mut scratch_b,
                    &mut rng_b,
                );
                assert_eq!(a.accepted, b.accepted, "step {step}");
                assert_eq!(a.n_used, b.n_used, "step {step}");
                assert_eq!(a.stages, b.stages, "step {step}");
                assert_eq!(cur_a.to_bits(), cur_b.to_bits(), "step {step}");
            }
        }
    }

    #[test]
    fn approx_mode_zero_eps_is_exact() {
        match MhMode::approx(0.0, 500) {
            MhMode::Exact => {}
            _ => panic!("eps=0 must map to exact"),
        }
    }

    #[test]
    fn kernel_closure_integration() {
        // A full little chain on the fixed population with a dummy kernel.
        let model = FixedPopulation { ls: vec![0.0; 500] };
        let kernel = |_: &(), _: &mut Pcg64| Proposal { param: (), log_correction: 0.0 };
        let mut scratch = MhScratch::new(500);
        let mut rng = Pcg64::seeded(5);
        let mut cur = ();
        let mut acc = 0;
        for _ in 0..100 {
            let p = kernel.propose(&cur, &mut rng);
            let info = mh_step(&model, &mut cur, p, &MhMode::approx(0.1, 100), &mut scratch, &mut rng);
            if info.accepted {
                acc += 1;
            }
        }
        // mu = 0 = mu0 mean: accepts iff ln u < 0 which is always true...
        // actually mu0 = ln(u)/N < 0 = mu always, so all accepted.
        assert_eq!(acc, 100);
    }
}

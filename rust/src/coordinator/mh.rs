//! Metropolis-Hastings step orchestration: one step driver over the
//! pluggable acceptance-test layer (`coordinator::accept`), with the
//! exact O(N) rule, the paper's sequential test, the minibatch Barker
//! test and the confidence sampler behind one `MhMode` enum.
//!
//! The step drivers wrap the model in a `MomentsSource`
//! (`ModelMoments` uncached / `CachedMoments` cached) so acceptance
//! rules see one population interface: gathered mini-batch moments fed
//! straight from the scheduler's `&[u32]` slice, plus a full-population
//! scan that runs the deterministic chunk-parallel driver when the
//! chain's scratch carries spare worker threads (`scan_threads > 1`,
//! wired up by the engine when `threads > chains`).

use crate::coordinator::accept::{
    AcceptanceTest, AusterityTest, BarkerTest, ConfidenceConfig, ConfidenceTest, ExactTest,
    MomentsSource, StageTrace,
};
use crate::coordinator::austerity::SeqTestConfig;
use crate::coordinator::executor::IntraPar;
use crate::coordinator::scheduler::MinibatchScheduler;
use crate::models::traits::{
    full_scan_moments_par, CachedLlDiff, LlDiffModel, Proposal, ScanScratch,
};
use crate::stats::Pcg64;

/// Which accept/reject rule to run. A closed enum over the four
/// `AcceptanceTest` members, so configurations stay `Clone`/`Debug` and
/// experiments can switch rules from data; `MhMode` itself implements
/// `AcceptanceTest` by delegation, and every step/chain/engine entry
/// point is generic over the trait, so custom rules plug in without
/// touching this enum.
#[derive(Clone, Debug)]
pub enum MhMode {
    /// Classic full-data test (epsilon = 0 baseline).
    Exact,
    /// Sequential approximate test with the given configuration
    /// (paper Alg. 1).
    Approx(SeqTestConfig),
    /// Noise-corrected minibatch Barker test (Seita et al. 2017).
    Barker(BarkerTest),
    /// Empirical-Bernstein confidence sampler (Bardenet et al.).
    Confidence(ConfidenceConfig),
}

impl MhMode {
    pub fn approx(eps: f64, batch: usize) -> MhMode {
        if eps <= 0.0 {
            MhMode::Exact
        } else {
            MhMode::Approx(SeqTestConfig::new(eps, batch))
        }
    }

    /// Approximate test with an explicit bound sequence (e.g. the
    /// Wang-Tsiatis / O'Brien-Fleming designs of supp. D).
    pub fn approx_with_bound(bound: crate::coordinator::austerity::BoundSeq, batch: usize) -> MhMode {
        MhMode::Approx(SeqTestConfig { batch_size: batch, bound })
    }

    /// Barker test at noise target `sigma` (builds / reuses the shared
    /// correction table).
    pub fn barker(sigma: f64, batch: usize) -> MhMode {
        MhMode::Barker(BarkerTest::new(sigma, batch))
    }

    /// Confidence sampler with wrong-decision budget `delta` per test.
    pub fn confidence(delta: f64, batch: usize) -> MhMode {
        MhMode::Confidence(ConfidenceConfig::new(delta, batch))
    }
}

impl AcceptanceTest for MhMode {
    fn name(&self) -> &'static str {
        match self {
            MhMode::Exact => ExactTest.name(),
            MhMode::Approx(_) => "austerity",
            MhMode::Barker(t) => t.name(),
            MhMode::Confidence(_) => "confidence",
        }
    }

    #[allow(clippy::too_many_arguments)]
    fn decide<S: MomentsSource>(
        &self,
        n_total: usize,
        log_correction: f64,
        moments: S,
        sched: &mut MinibatchScheduler,
        idx_buf: &mut Vec<u32>,
        trace: &mut Vec<StageTrace>,
        rng: &mut Pcg64,
    ) -> crate::coordinator::accept::AcceptOutcome {
        match self {
            MhMode::Exact => {
                ExactTest.decide(n_total, log_correction, moments, sched, idx_buf, trace, rng)
            }
            MhMode::Approx(cfg) => AusterityTest { cfg: *cfg }
                .decide(n_total, log_correction, moments, sched, idx_buf, trace, rng),
            MhMode::Barker(t) => {
                t.decide(n_total, log_correction, moments, sched, idx_buf, trace, rng)
            }
            MhMode::Confidence(cfg) => ConfidenceTest { cfg: *cfg }
                .decide(n_total, log_correction, moments, sched, idx_buf, trace, rng),
        }
    }
}

/// Result of one MH step.
#[derive(Clone, Copy, Debug)]
pub struct StepInfo {
    pub accepted: bool,
    /// Datapoints examined by the accept/reject test.
    pub n_used: usize,
    /// Test stages (1 for exact, 0 for a data-free rejection).
    pub stages: usize,
    /// Numerical-guard trips in the decision (nonzero only under a
    /// `coordinator::guard::Guarded` rule).
    pub guard_trips: u32,
}

/// Reusable per-chain scratch (avoids per-step allocation): the
/// without-replacement scheduler, the chunk index buffer for
/// closure-backed full scans, the per-stage trace of the last decision,
/// and the deterministic-scan workspace (worker count + per-chunk
/// partials).
pub struct MhScratch {
    pub sched: MinibatchScheduler,
    pub idx_buf: Vec<u32>,
    /// Stage-by-stage record of the most recent decision (capacity is
    /// reused; cleared by every `decide`).
    pub trace: Vec<StageTrace>,
    /// Full-scan workspace; `scan.threads() > 1` enables the
    /// deterministic intra-step parallel scan on the exact path.
    pub scan: ScanScratch,
}

impl MhScratch {
    pub fn new(n: usize) -> Self {
        Self::with_scan_threads(n, 1)
    }

    /// Scratch whose exact-rule full scans may run as up to
    /// `scan_threads` concurrent spans on the process-global executor
    /// pool (bit-identical to serial for any value).
    pub fn with_scan_threads(n: usize, scan_threads: usize) -> Self {
        Self::with_scan_pool(n, &IntraPar::threads(scan_threads))
    }

    /// Scratch whose exact-rule full scans draw on the specific
    /// intra-step grant `intra` — span width plus the (shared) executor
    /// pool the spans run on. This is what `scratch_par` builds so every
    /// chain of a launch multiplexes over one pool.
    pub fn with_scan_pool(n: usize, intra: &IntraPar) -> Self {
        MhScratch {
            sched: MinibatchScheduler::new(n).expect("population exceeds the u32 index space"),
            idx_buf: Vec::new(),
            trace: Vec::new(),
            scan: ScanScratch::from_intra(intra, n),
        }
    }
}

/// The uncached model as a `MomentsSource`: gathered batches go straight
/// to `lldiff_moments`; full scans run the range-based chunked driver
/// (parallel when `scan` carries workers), bit-identical to the serial
/// gathered scan by the `lldiff_range_moments` contract.
pub struct ModelMoments<'a, M: LlDiffModel> {
    pub model: &'a M,
    pub cur: &'a M::Param,
    pub prop: &'a M::Param,
    pub scan: &'a mut ScanScratch,
}

impl<M: LlDiffModel + Sync> MomentsSource for ModelMoments<'_, M> {
    fn batch(&mut self, idx: &[u32]) -> (f64, f64) {
        self.model.lldiff_moments(idx, self.cur, self.prop)
    }

    fn full_scan(&mut self, n_total: usize, _idx_buf: &mut Vec<u32>) -> (f64, f64) {
        let (model, cur, prop) = (self.model, self.cur, self.prop);
        full_scan_moments_par(n_total, self.scan, |a, b| {
            model.lldiff_range_moments(a, b, cur, prop)
        })
    }
}

/// The cached model as a `MomentsSource` (proposal side computed,
/// current side served from the per-chain cache); full scans go through
/// `CachedLlDiff::cached_full_scan`, which splits the cache into
/// chunk-aligned lanes for the parallel driver.
pub struct CachedMoments<'a, M: CachedLlDiff> {
    pub model: &'a M,
    pub cache: &'a mut M::Cache,
    pub prop: &'a M::Param,
    pub scan: &'a mut ScanScratch,
}

impl<M: CachedLlDiff + Sync> MomentsSource for CachedMoments<'_, M> {
    fn batch(&mut self, idx: &[u32]) -> (f64, f64) {
        self.model.cached_moments(self.cache, idx, self.prop)
    }

    fn full_scan(&mut self, n_total: usize, _idx_buf: &mut Vec<u32>) -> (f64, f64) {
        debug_assert_eq!(n_total, self.model.n());
        self.model.cached_full_scan(self.cache, self.prop, self.scan)
    }
}

/// Execute one MH accept/reject decision for a proposed move under any
/// `AcceptanceTest`.
///
/// `proposal.log_correction` must be
/// `log[rho(cur) q(prop|cur) / (rho(prop) q(cur|prop))]` so that
/// `mu_0 = (ln u + log_correction) / N` (Eqn. 2). On acceptance `cur` is
/// overwritten with the proposal's parameter.
pub fn mh_step<M, T>(
    model: &M,
    cur: &mut M::Param,
    proposal: Proposal<M::Param>,
    mode: &T,
    scratch: &mut MhScratch,
    rng: &mut Pcg64,
) -> StepInfo
where
    M: LlDiffModel + Sync,
    T: AcceptanceTest,
{
    let MhScratch { sched, idx_buf, trace, scan } = scratch;
    let cur_ref: &M::Param = cur;
    let out = mode.decide(
        model.n(),
        proposal.log_correction,
        ModelMoments { model, cur: cur_ref, prop: &proposal.param, scan },
        sched,
        idx_buf,
        trace,
        rng,
    );
    if out.accept {
        *cur = proposal.param;
    }
    StepInfo {
        accepted: out.accept,
        n_used: out.n_used,
        stages: out.stages,
        guard_trips: out.guard_trips,
    }
}

/// `mh_step` on the state-caching fast path: current-side per-datapoint
/// statistics live in `cache` across steps, so each decision computes
/// only the proposal side (and a rejected step leaves the cache valid
/// for free). Decisions are bit-identical to `mh_step` under the same
/// RNG stream for every acceptance rule — the moments source is the
/// only thing that differs, and the `CachedLlDiff` contract makes it
/// return identical bits. Regression-tested in
/// `tests/integration_engine.rs` and `tests/integration_accept.rs`.
pub fn mh_step_cached<M, T>(
    model: &M,
    cur: &mut M::Param,
    cache: &mut M::Cache,
    proposal: Proposal<M::Param>,
    mode: &T,
    scratch: &mut MhScratch,
    rng: &mut Pcg64,
) -> StepInfo
where
    M: CachedLlDiff + Sync,
    T: AcceptanceTest,
{
    model.begin_step(cache);
    let MhScratch { sched, idx_buf, trace, scan } = scratch;
    let out = mode.decide(
        model.n(),
        proposal.log_correction,
        CachedMoments { model, cache: &mut *cache, prop: &proposal.param, scan },
        sched,
        idx_buf,
        trace,
        rng,
    );
    model.end_step(cache, &proposal.param, out.accept);
    if out.accept {
        *cur = proposal.param;
    }
    StepInfo {
        accepted: out.accept,
        n_used: out.n_used,
        stages: out.stages,
        guard_trips: out.guard_trips,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::traits::testutil::FixedPopulation;
    use crate::models::traits::ProposalKernel;

    #[test]
    fn exact_step_uses_all_data() {
        let model = FixedPopulation { ls: vec![1.0; 100] };
        let mut scratch = MhScratch::new(100);
        let mut rng = Pcg64::seeded(0);
        let mut cur = ();
        let info = mh_step(
            &model,
            &mut cur,
            Proposal { param: (), log_correction: 0.0 },
            &MhMode::Exact,
            &mut scratch,
            &mut rng,
        );
        assert_eq!(info.n_used, 100);
        // mean l = 1 -> acceptance prob = min(1, e^{100}) = 1
        assert!(info.accepted);
    }

    #[test]
    fn certain_rejection() {
        let model = FixedPopulation { ls: vec![-10.0; 100] };
        let mut scratch = MhScratch::new(100);
        let mut rng = Pcg64::seeded(1);
        let mut cur = ();
        for _ in 0..20 {
            let info = mh_step(
                &model,
                &mut cur,
                Proposal { param: (), log_correction: 0.0 },
                &MhMode::Exact,
                &mut scratch,
                &mut rng,
            );
            assert!(!info.accepted);
        }
    }

    #[test]
    fn infinite_correction_rejects_without_data() {
        let model = FixedPopulation { ls: vec![1.0; 50] };
        let mut scratch = MhScratch::new(50);
        let mut rng = Pcg64::seeded(2);
        let mut cur = ();
        for mode in [
            MhMode::Exact,
            MhMode::approx(0.05, 10),
            MhMode::barker(1.0, 10),
            MhMode::confidence(0.05, 10),
        ] {
            let info = mh_step(
                &model,
                &mut cur,
                Proposal { param: (), log_correction: f64::INFINITY },
                &mode,
                &mut scratch,
                &mut rng,
            );
            assert!(!info.accepted);
            assert_eq!(info.n_used, 0);
            assert_eq!(info.stages, 0);
        }
    }

    #[test]
    fn exact_acceptance_rate_matches_formula() {
        // With constant l and correction c, Pa = min(1, exp(N*l - c)).
        let n = 40;
        let l = 0.01; // exp(0.4 - c)
        let c = 0.6f64;
        let want = (n as f64 * l - c).exp(); // ~0.819
        let model = FixedPopulation { ls: vec![l; n] };
        let mut scratch = MhScratch::new(n);
        let mut rng = Pcg64::seeded(3);
        let trials = 40_000;
        let mut acc = 0usize;
        let mut cur = ();
        for _ in 0..trials {
            let info = mh_step(
                &model,
                &mut cur,
                Proposal { param: (), log_correction: c },
                &MhMode::Exact,
                &mut scratch,
                &mut rng,
            );
            if info.accepted {
                acc += 1;
            }
        }
        let rate = acc as f64 / trials as f64;
        assert!((rate - want).abs() < 0.01, "rate {rate} want {want}");
    }

    #[test]
    fn approx_matches_exact_acceptance_when_unambiguous() {
        // Wide margin between mu and mu0: every budgeted rule's
        // acceptance rate must track the exact one closely.
        let n = 10_000;
        let mut rng = Pcg64::seeded(4);
        let ls: Vec<f64> = (0..n).map(|_| 3e-4 + 1e-4 * rng.normal()).collect();
        let model = FixedPopulation { ls };
        // Pa = min(1, exp(N mu)); N*mu = 3.0 -> accept ~ always (the
        // Barker rule accepts with logistic(3) ~ 0.95)
        let mut scratch = MhScratch::new(n);
        for (mode, min_acc) in [
            (MhMode::approx(0.05, 500), 195usize),
            (MhMode::confidence(0.05, 500), 195),
            (MhMode::barker(1.0, 500), 180),
        ] {
            let mut acc = 0;
            let mut cur = ();
            for _ in 0..200 {
                let info = mh_step(
                    &model,
                    &mut cur,
                    Proposal { param: (), log_correction: 0.0 },
                    &mode,
                    &mut scratch,
                    &mut rng,
                );
                assert!(info.n_used <= n);
                if info.accepted {
                    acc += 1;
                }
            }
            assert!(acc >= min_acc, "mode {mode:?}: acc={acc}");
        }
    }

    #[test]
    fn cached_step_matches_uncached_step_exactly() {
        use crate::data::synthetic::linreg_toy;
        use crate::models::LinRegModel;

        let model = LinRegModel::new(linreg_toy(3_000, 0), 3.0, 4950.0).expect("population exceeds the u32 index space");
        let kernel = |cur: &f64, rng: &mut Pcg64| Proposal {
            param: cur + rng.normal_scaled(0.0, 0.005),
            log_correction: 0.0,
        };
        for mode in [
            MhMode::Exact,
            MhMode::approx(0.05, 300),
            MhMode::barker(1.0, 300),
            MhMode::confidence(0.05, 300),
        ] {
            let mut rng_a = Pcg64::new(11, 4);
            let mut rng_b = Pcg64::new(11, 4);
            let mut scratch_a = MhScratch::new(model.n());
            let mut scratch_b = MhScratch::new(model.n());
            let mut cur_a = 0.45f64;
            let mut cur_b = 0.45f64;
            let mut cache = model.init_cache(&cur_b);
            for step in 0..150 {
                let prop_a = kernel.propose(&cur_a, &mut rng_a);
                let prop_b = kernel.propose(&cur_b, &mut rng_b);
                assert_eq!(prop_a.param.to_bits(), prop_b.param.to_bits());
                let a = mh_step(&model, &mut cur_a, prop_a, &mode, &mut scratch_a, &mut rng_a);
                let b = mh_step_cached(
                    &model,
                    &mut cur_b,
                    &mut cache,
                    prop_b,
                    &mode,
                    &mut scratch_b,
                    &mut rng_b,
                );
                assert_eq!(a.accepted, b.accepted, "mode {mode:?} step {step}");
                assert_eq!(a.n_used, b.n_used, "mode {mode:?} step {step}");
                assert_eq!(a.stages, b.stages, "mode {mode:?} step {step}");
                assert_eq!(cur_a.to_bits(), cur_b.to_bits(), "mode {mode:?} step {step}");
            }
        }
    }

    #[test]
    fn scan_threads_do_not_change_step_decisions() {
        // the deterministic parallel scan: exact-rule chains with 1, 2
        // and 8 scan workers make bit-identical decisions, cached and
        // uncached
        use crate::data::synthetic::linreg_toy;
        use crate::models::LinRegModel;

        let model = LinRegModel::new(linreg_toy(3_000, 0), 3.0, 4950.0).expect("population exceeds the u32 index space");
        let kernel = |cur: &f64, rng: &mut Pcg64| Proposal {
            param: cur + rng.normal_scaled(0.0, 0.005),
            log_correction: 0.0,
        };
        let run = |threads: usize, cached: bool| {
            let mut rng = Pcg64::new(5, 6);
            let mut scratch = MhScratch::with_scan_threads(model.n(), threads);
            let mut cur = 0.45f64;
            let mut cache = model.init_cache(&cur);
            let mut trail = Vec::new();
            for _ in 0..40 {
                let p = kernel.propose(&cur, &mut rng);
                let info = if cached {
                    mh_step_cached(
                        &model, &mut cur, &mut cache, p, &MhMode::Exact, &mut scratch, &mut rng,
                    )
                } else {
                    mh_step(&model, &mut cur, p, &MhMode::Exact, &mut scratch, &mut rng)
                };
                trail.push((info.accepted, cur.to_bits()));
            }
            trail
        };
        let base = run(1, false);
        for threads in [2usize, 8] {
            assert_eq!(run(threads, false), base, "uncached threads {threads}");
            assert_eq!(run(threads, true), base, "cached threads {threads}");
        }
        assert_eq!(run(1, true), base);
    }

    #[test]
    fn approx_mode_zero_eps_is_exact() {
        match MhMode::approx(0.0, 500) {
            MhMode::Exact => {}
            _ => panic!("eps=0 must map to exact"),
        }
    }

    #[test]
    fn mode_names_label_the_rules() {
        assert_eq!(MhMode::Exact.name(), "exact");
        assert_eq!(MhMode::approx(0.05, 100).name(), "austerity");
        assert_eq!(MhMode::barker(1.0, 100).name(), "barker");
        assert_eq!(MhMode::confidence(0.05, 100).name(), "confidence");
    }

    #[test]
    fn kernel_closure_integration() {
        // A full little chain on the fixed population with a dummy kernel.
        let model = FixedPopulation { ls: vec![0.0; 500] };
        let kernel = |_: &(), _: &mut Pcg64| Proposal { param: (), log_correction: 0.0 };
        let mut scratch = MhScratch::new(500);
        let mut rng = Pcg64::seeded(5);
        let mut cur = ();
        let mut acc = 0;
        for _ in 0..100 {
            let p = kernel.propose(&cur, &mut rng);
            let info = mh_step(&model, &mut cur, p, &MhMode::approx(0.1, 100), &mut scratch, &mut rng);
            if info.accepted {
                acc += 1;
            }
        }
        // mu = 0 = mu0 mean: accepts iff ln u < 0 which is always true...
        // actually mu0 = ln(u)/N < 0 = mu always, so all accepted.
        assert_eq!(acc, 100);
    }
}

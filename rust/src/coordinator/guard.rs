//! Numerical guard layer: catch non-finite log-likelihood moments where
//! they enter the acceptance tests.
//!
//! A single NaN or infinite `lldiff` silently poisons every statistic
//! downstream of a decision — the running mean, the Student-t tail, the
//! Bernstein bound — and all four rules then limp to population
//! exhaustion and decide on garbage. [`Guarded`] wraps any
//! [`AcceptanceTest`] and interposes on its [`MomentsSource`]: every
//! mini-batch and full-scan moment pair is checked for finiteness, trips
//! are counted, and a [`GuardPolicy`] decides what a tripped decision
//! means:
//!
//! * [`GuardPolicy::Warn`] — count only; the decision stands (default).
//! * [`GuardPolicy::RejectProposal`] — force-reject the proposal, so the
//!   chain stays on its last finite state and keeps running.
//! * [`GuardPolicy::Abort`] — panic; under the engine's per-chain panic
//!   isolation this downs exactly one chain (`ChainStatus::Failed`)
//!   while the rest of the launch completes.
//!
//! The wrapper is decision-transparent: it only observes moment values,
//! so a `Warn`-guarded run makes bit-identical decisions to an unguarded
//! one (the guard is why `Session` wraps every rule unconditionally).
//! Trip counts surface per chain as `ChainStats::guard_trips`.

use crate::coordinator::accept::{AcceptOutcome, AcceptanceTest, MomentsSource, StageTrace};
use crate::coordinator::scheduler::MinibatchScheduler;
use crate::stats::Pcg64;

/// What to do when a non-finite moment reaches an acceptance test.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum GuardPolicy {
    /// Count the trip and let the decision stand.
    #[default]
    Warn,
    /// Force-reject the proposal that produced non-finite moments.
    RejectProposal,
    /// Panic — the engine's panic isolation turns this into a
    /// `ChainStatus::Failed` for the offending chain only.
    Abort,
}

/// `MomentsSource` interposer: delegates, then checks `(sum, sum_sq)`
/// for finiteness. Full scans stay on the source's own (possibly
/// parallel, range-based) path, so guarded moments are bit-identical to
/// unguarded ones.
struct GuardedSource<'a, S> {
    inner: S,
    trips: &'a mut u32,
}

impl<S> GuardedSource<'_, S> {
    #[inline]
    fn check(&mut self, moments: (f64, f64)) -> (f64, f64) {
        if !moments.0.is_finite() || !moments.1.is_finite() {
            *self.trips += 1;
        }
        moments
    }
}

impl<S: MomentsSource> MomentsSource for GuardedSource<'_, S> {
    fn batch(&mut self, idx: &[u32]) -> (f64, f64) {
        let m = self.inner.batch(idx);
        self.check(m)
    }

    fn full_scan(&mut self, n_total: usize, idx_buf: &mut Vec<u32>) -> (f64, f64) {
        let m = self.inner.full_scan(n_total, idx_buf);
        self.check(m)
    }
}

/// An acceptance rule wrapped with a numerical guard. Constructed by
/// `Session` around whatever rule the user picked; usable directly with
/// the lower-level engine entry points too.
#[derive(Clone, Debug)]
pub struct Guarded<T> {
    pub rule: T,
    pub policy: GuardPolicy,
}

impl<T> Guarded<T> {
    pub fn new(rule: T, policy: GuardPolicy) -> Self {
        Guarded { rule, policy }
    }
}

impl<T: AcceptanceTest> AcceptanceTest for Guarded<T> {
    fn name(&self) -> &'static str {
        self.rule.name()
    }

    fn decide<S: MomentsSource>(
        &self,
        n_total: usize,
        log_correction: f64,
        moments: S,
        sched: &mut MinibatchScheduler,
        idx_buf: &mut Vec<u32>,
        trace: &mut Vec<StageTrace>,
        rng: &mut Pcg64,
    ) -> AcceptOutcome {
        let mut trips = 0u32;
        let mut out = self.rule.decide(
            n_total,
            log_correction,
            GuardedSource { inner: moments, trips: &mut trips },
            sched,
            idx_buf,
            trace,
            rng,
        );
        if trips > 0 {
            match self.policy {
                GuardPolicy::Warn => {}
                GuardPolicy::RejectProposal => out.accept = false,
                GuardPolicy::Abort => panic!(
                    "numerical guard: non-finite log-likelihood moments reached the {} \
                     acceptance test ({trips} tripped stage(s))",
                    self.rule.name()
                ),
            }
        }
        out.guard_trips = trips;
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::accept::{AusterityTest, ExactTest};
    use crate::models::traits::testutil::FixedPopulation;
    use crate::models::traits::LlDiffModel;

    fn harness(n: usize) -> (MinibatchScheduler, Vec<u32>, Vec<StageTrace>) {
        (MinibatchScheduler::new(n).expect("population exceeds the u32 index space"), Vec::new(), Vec::new())
    }

    fn decide<T: AcceptanceTest>(
        test: &T,
        model: &FixedPopulation,
        rng: &mut Pcg64,
    ) -> AcceptOutcome {
        let (mut sched, mut buf, mut trace) = harness(model.n());
        test.decide(
            model.n(),
            0.0,
            |idx: &[u32]| model.lldiff_moments(idx, &(), &()),
            &mut sched,
            &mut buf,
            &mut trace,
            rng,
        )
    }

    #[test]
    fn finite_population_never_trips_and_matches_unguarded_bits() {
        let model = FixedPopulation { ls: vec![0.01; 200] };
        for policy in [GuardPolicy::Warn, GuardPolicy::RejectProposal, GuardPolicy::Abort] {
            let mut a = Pcg64::seeded(5);
            let mut b = Pcg64::seeded(5);
            let plain = decide(&ExactTest, &model, &mut a);
            let wrapped = decide(&Guarded::new(ExactTest, policy), &model, &mut b);
            assert_eq!(wrapped.guard_trips, 0);
            assert_eq!(plain.accept, wrapped.accept);
            assert_eq!(plain.n_used, wrapped.n_used);
            assert_eq!(plain.stat.to_bits(), wrapped.stat.to_bits());
            assert_eq!(a.next_u64(), b.next_u64(), "rng stream position must match");
        }
    }

    #[test]
    fn warn_counts_trips_but_lets_decision_stand() {
        let mut ls = vec![0.5; 100];
        ls[17] = f64::NAN;
        let model = FixedPopulation { ls };
        let mut rng = Pcg64::seeded(1);
        let out = decide(&Guarded::new(ExactTest, GuardPolicy::Warn), &model, &mut rng);
        assert!(out.guard_trips > 0);
    }

    #[test]
    fn reject_proposal_forces_rejection() {
        // a population so favorable the exact rule would always accept
        let mut ls = vec![1.0; 100];
        ls[3] = f64::INFINITY;
        let model = FixedPopulation { ls };
        for seed in 0..20 {
            let mut rng = Pcg64::seeded(seed);
            let out =
                decide(&Guarded::new(ExactTest, GuardPolicy::RejectProposal), &model, &mut rng);
            assert!(!out.accept);
            assert!(out.guard_trips > 0);
        }
    }

    #[test]
    fn abort_panics_with_rule_name() {
        let mut ls = vec![0.1; 64];
        ls[0] = f64::NAN;
        let model = FixedPopulation { ls };
        let err = std::panic::catch_unwind(|| {
            let mut rng = Pcg64::seeded(2);
            let rule = Guarded::new(AusterityTest::new(0.05, 16), GuardPolicy::Abort);
            decide(&rule, &model, &mut rng)
        })
        .expect_err("must panic");
        let msg = err.downcast_ref::<String>().cloned().unwrap_or_default();
        assert!(msg.contains("numerical guard"), "msg: {msg}");
        assert!(msg.contains("austerity"), "msg: {msg}");
    }

    #[test]
    fn sequential_rule_terminates_under_nan_and_counts_stages() {
        // NaN comparisons are false, so the austerity loop runs to
        // population exhaustion and still returns — the guard's job is
        // to notice, not to rescue the decision
        let model = FixedPopulation { ls: vec![f64::NAN; 128] };
        let mut rng = Pcg64::seeded(3);
        let rule = Guarded::new(AusterityTest::new(0.05, 32), GuardPolicy::Warn);
        let out = decide(&rule, &model, &mut rng);
        assert_eq!(out.n_used, 128, "must exhaust the population, not hang");
        assert!(out.guard_trips > 0);
    }
}

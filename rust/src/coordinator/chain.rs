//! Markov-chain driver: advances any `TransitionKernel` under a step,
//! wall-clock or datapoint budget, collecting test-function values,
//! acceptance and data-use statistics — the harness every experiment in
//! §6 (and supp. E/F) runs on.

use std::cell::Cell;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::coordinator::accept::AcceptanceTest;
use crate::coordinator::checkpoint::{
    BinReader, BinWriter, ChainCheckpoint, CheckpointSpec, Persist, ShardStamp, StoreLayer,
};
use crate::coordinator::executor::IntraPar;
use crate::coordinator::kernel::{CachedMhKernel, MhKernel, TransitionKernel};
use crate::coordinator::supervise::ProgressBoard;
use crate::models::traits::{CachedLlDiff, LlDiffModel, ProposalKernel};
use crate::stats::Pcg64;

thread_local! {
    static CHAIN_CTX: Cell<(usize, usize)> = const { Cell::new((usize::MAX, usize::MAX)) };
}

/// The `(chain, step)` coordinate the current thread's chain driver is
/// executing, or `(usize::MAX, usize::MAX)` outside a driver loop. Steps
/// are 0-based (step `s` is the `s+1`-th transition); the engine sets the
/// chain id per task, the drivers update the step every iteration.
/// `testkit::FaultyModel` reads this to place scripted faults; a
/// standalone `drive_chain` (no engine) reports chain `usize::MAX`.
pub fn current_chain_step() -> (usize, usize) {
    CHAIN_CTX.with(|c| c.get())
}

/// RAII guard installing a `(chain, step)` context on the current
/// thread and restoring the previous one on drop — including during
/// unwinding. The engine wraps each chain task in one, and the scan
/// layer wraps each pooled span task in one, so persistent executor
/// workers never leak one chain's coordinates into the next task they
/// claim (a fresh scoped thread started clean; a pool worker does not).
pub(crate) struct ScopedChainCtx {
    prev: (usize, usize),
}

impl ScopedChainCtx {
    pub(crate) fn enter(ctx: (usize, usize)) -> Self {
        ScopedChainCtx { prev: CHAIN_CTX.with(|c| c.replace(ctx)) }
    }
}

impl Drop for ScopedChainCtx {
    fn drop(&mut self) {
        CHAIN_CTX.with(|c| c.set(self.prev));
    }
}

fn set_current_step(step: usize) {
    CHAIN_CTX.with(|c| {
        let (chain, _) = c.get();
        c.set((chain, step));
    });
}

/// Summary statistics of one chain run.
#[derive(Clone, Debug, Default)]
pub struct ChainStats {
    pub steps: usize,
    pub accepted: usize,
    /// Total datapoint likelihood (or potential-pair) evaluations
    /// consumed by the kernel's decisions.
    pub data_used: u64,
    /// Steps whose decision tripped a numerical guard (non-finite
    /// log-likelihood moments; see `coordinator::guard`).
    pub guard_trips: u64,
    /// Checkpoint writes that failed (disk full, permissions, torn
    /// renames). Non-fatal: the chain keeps sampling on the previous
    /// generation; the engine surfaces the count for alerting. Not
    /// persisted inside checkpoints — each (re)run counts its own.
    pub ckpt_failures: u64,
    pub wall: Duration,
}

impl ChainStats {
    pub fn acceptance_rate(&self) -> f64 {
        if self.steps == 0 {
            0.0
        } else {
            self.accepted as f64 / self.steps as f64
        }
    }

    /// Mean fraction of the dataset consumed per MH test.
    pub fn mean_data_fraction(&self, n: usize) -> f64 {
        if self.steps == 0 {
            0.0
        } else {
            self.data_used as f64 / (self.steps as f64 * n as f64)
        }
    }
}

/// Stop condition for a run.
#[derive(Clone, Copy, Debug)]
pub enum Budget {
    /// Stop after this many transitions.
    Steps(usize),
    /// Stop once this much wall-clock time has elapsed (inherently
    /// timing-dependent; use `Data` for reproducible cost budgets).
    Wall(Duration),
    /// Stop once the chain has consumed this many cumulative datapoint
    /// evaluations — the natural x-axis of the paper's risk-vs-cost
    /// curves, and deterministic unlike wall budgets. The step that
    /// crosses the budget completes; no further step starts.
    Data(u64),
}

/// A recorded sample: the test-function value and the cumulative cost at
/// which it was collected (for risk-vs-time curves).
#[derive(Clone, Copy, Debug)]
pub struct Sample {
    pub value: f64,
    /// Seconds since chain start when the sample was recorded.
    pub at_secs: f64,
    /// Cumulative datapoint evaluations when the sample was recorded.
    pub at_data: u64,
}

/// The single chain loop behind every sampler family: budget check,
/// kernel step, burn-in/thinned recording. Builds the kernel's
/// chain-local scratch once, so the steady state allocates nothing.
pub fn drive_chain<T, F>(
    kernel: &T,
    init: T::State,
    budget: Budget,
    burn_in: usize,
    thin: usize,
    f: F,
    rng: &mut Pcg64,
) -> (Vec<Sample>, ChainStats)
where
    T: TransitionKernel,
    F: FnMut(&T::State) -> f64,
{
    drive_chain_par(kernel, init, budget, burn_in, thin, f, rng, 1)
}

/// `drive_chain` for a chain allowed to run up to `intra_threads`
/// concurrent scan spans inside a step, drawn from the shared executor
/// pool (the engine's spare-worker path when `threads > chains`).
/// Intra-step parallelism is deterministic by construction — samples
/// are bit-identical to `drive_chain` — so this only changes wall time.
#[allow(clippy::too_many_arguments)]
pub fn drive_chain_par<T, F>(
    kernel: &T,
    init: T::State,
    budget: Budget,
    burn_in: usize,
    thin: usize,
    f: F,
    rng: &mut Pcg64,
    intra_threads: usize,
) -> (Vec<Sample>, ChainStats)
where
    T: TransitionKernel,
    F: FnMut(&T::State) -> f64,
{
    let mut scratch = kernel.scratch_par(&init, &IntraPar::threads(intra_threads.max(1)));
    let mut cur = init;
    let mut stats = ChainStats::default();
    let mut samples = Vec::new();
    drive_loop(
        kernel,
        &mut cur,
        &mut scratch,
        &mut stats,
        &mut samples,
        budget,
        burn_in,
        thin,
        f,
        rng,
        Duration::ZERO,
        None,
        None,
        None,
        None,
        |_, _, _, _, _, _| {},
    );
    (samples, stats)
}

/// Where and how one chain's checkpoints are written: the spec (cadence,
/// directory, generations retained), the store layer the bytes go
/// through (the real filesystem, or `testkit::fault::FaultyStore` under
/// test), and the identity stamped into every payload.
pub(crate) struct CkptSink<'a> {
    pub spec: &'a CheckpointSpec,
    pub store: &'a Arc<dyn StoreLayer>,
    pub chain: usize,
    pub base_seed: u64,
    pub shard: ShardStamp,
}

/// Engine-side options of the resumable chain driver
/// (`drive_chain_ckpt`): the plain budget knobs plus checkpoint writing,
/// a checkpoint to resume from, a progress slot for panic forensics, and
/// the supervisor's cooperative abort flag.
pub(crate) struct DriveCfg<'a> {
    pub budget: Budget,
    pub burn_in: usize,
    pub thin: usize,
    /// Intra-step scan grant (width + pool) for `scratch_par`.
    pub intra: IntraPar,
    /// Checkpoint destination when checkpoint writing is on.
    pub checkpoint: Option<CkptSink<'a>>,
    /// A previously captured checkpoint to continue from.
    pub resume: Option<ChainCheckpoint>,
    /// Published before every step: the 0-based index of the step being
    /// executed, read by the engine when the chain dies mid-step and
    /// sampled by the stall watchdog.
    pub progress: Option<&'a AtomicU64>,
    /// Checked at every step boundary; when set (quorum lost), the loop
    /// exits early with whatever it has — a cooperative stop, so a chain
    /// hung *inside* a step cannot be interrupted (see
    /// `coordinator::supervise`).
    pub abort: Option<&'a AtomicBool>,
    /// Caller-raised cooperative cancel (`CancelToken`): polled at the
    /// same step boundary as `abort`. Unlike an abort, a cancelled
    /// checkpointing chain flushes one final generation on exit so the
    /// run can `--resume` later.
    pub cancel: Option<&'a AtomicBool>,
    /// This chain's lane of the live progress board, published after
    /// every completed step.
    pub board: Option<(&'a ProgressBoard, usize)>,
}

/// The chain loop every driver shares: budget check, step, stat
/// accumulation, burn-in/thinned recording, then the `after_step` hook
/// (a no-op for the plain drivers, the checkpoint writer for the
/// resumable one). `prior` offsets the clock for resumed chains.
#[allow(clippy::too_many_arguments)]
fn drive_loop<T, F, C>(
    kernel: &T,
    cur: &mut T::State,
    scratch: &mut T::Scratch,
    stats: &mut ChainStats,
    samples: &mut Vec<Sample>,
    budget: Budget,
    burn_in: usize,
    thin: usize,
    mut f: F,
    rng: &mut Pcg64,
    prior: Duration,
    progress: Option<&AtomicU64>,
    abort: Option<&AtomicBool>,
    cancel: Option<&AtomicBool>,
    board: Option<(&ProgressBoard, usize)>,
    mut after_step: C,
) where
    T: TransitionKernel,
    F: FnMut(&T::State) -> f64,
    C: FnMut(&T::State, &T::Scratch, &Pcg64, &mut ChainStats, &[Sample], Duration),
{
    assert!(thin >= 1);
    let start = Instant::now();
    loop {
        if let Some(flag) = abort {
            if flag.load(Ordering::Relaxed) {
                break;
            }
        }
        if let Some(flag) = cancel {
            if flag.load(Ordering::Relaxed) {
                break;
            }
        }
        match budget {
            Budget::Steps(s) => {
                if stats.steps >= s {
                    break;
                }
            }
            Budget::Wall(d) => {
                if prior + start.elapsed() >= d {
                    break;
                }
            }
            Budget::Data(d) => {
                if stats.data_used >= d {
                    break;
                }
            }
        }
        if let Some(p) = progress {
            p.store(stats.steps as u64, Ordering::Relaxed);
        }
        set_current_step(stats.steps);
        let outcome = kernel.step(cur, scratch, rng);
        stats.steps += 1;
        stats.accepted += outcome.accepted as usize;
        stats.data_used += outcome.data_used;
        stats.guard_trips += outcome.guard_trips as u64;
        if let Some((b, c)) = board {
            b.publish(c, stats.steps as u64, stats.accepted as u64, stats.data_used);
        }
        if stats.steps > burn_in && (stats.steps - burn_in) % thin == 0 {
            samples.push(Sample {
                value: f(cur),
                at_secs: (prior + start.elapsed()).as_secs_f64(),
                at_data: stats.data_used,
            });
        }
        after_step(cur, scratch, rng, stats, samples, prior + start.elapsed());
    }
    stats.wall = prior + start.elapsed();
}

/// Serialize the chain's full resumable identity (state, scratch, RNG
/// position, stats, samples) and write it as one rotated checkpoint
/// generation. On success `next_gen` advances; on failure the chain
/// keeps its previous generation, bumps `ChainStats::ckpt_failures`,
/// and will retry the same generation number at the next write point —
/// checkpoint write failures are non-fatal by contract.
#[allow(clippy::too_many_arguments)]
fn write_generation<T>(
    kernel: &T,
    sink: &CkptSink<'_>,
    state: &T::State,
    scratch: &T::Scratch,
    rng: &Pcg64,
    stats: &mut ChainStats,
    samples: &[Sample],
    elapsed: Duration,
    next_gen: &mut u64,
) where
    T: TransitionKernel,
    T::State: Persist,
{
    let mut sw = BinWriter::new();
    state.persist(&mut sw);
    let mut kw = BinWriter::new();
    kernel.save_scratch(scratch, &mut kw);
    let ck = ChainCheckpoint {
        chain: sink.chain,
        base_seed: sink.base_seed,
        shard: sink.shard,
        generation: *next_gen,
        steps: stats.steps,
        accepted: stats.accepted,
        data_used: stats.data_used,
        guard_trips: stats.guard_trips,
        wall_secs: elapsed.as_secs_f64(),
        rng: rng.state_parts(),
        samples: samples.to_vec(),
        state: sw.into_bytes(),
        scratch: kw.into_bytes(),
    };
    match ck.write_rotated(sink.store.as_ref(), &sink.spec.dir, sink.spec.retain) {
        Ok(()) => *next_gen += 1,
        Err(e) => {
            stats.ckpt_failures += 1;
            eprintln!(
                "engine: chain {}: checkpoint g{next_gen} write failed (continuing): {e}",
                sink.chain,
            );
        }
    }
}

/// `drive_chain_par` with checkpoint/resume: restores state, stats,
/// samples, RNG position and cross-step scratch from `cfg.resume`, then
/// continues the loop, writing a rotated [`ChainCheckpoint`] generation
/// every `spec.every` completed steps (keeping the newest
/// `spec.retain`). A resumed chain replays the uninterrupted run bit for
/// bit (draw values, acceptance counters, data accounting); wall-clock
/// fields are offset by the checkpoint's elapsed time but are inherently
/// timing-dependent. Corrupt or mismatched payloads panic, which the
/// engine's supervision layer retries or reports as a failed chain;
/// checkpoint *write* failures are non-fatal — they bump
/// `ChainStats::ckpt_failures` and the chain keeps sampling on its
/// previous generation.
pub(crate) fn drive_chain_ckpt<T, F>(
    kernel: &T,
    init: T::State,
    cfg: DriveCfg<'_>,
    f: F,
    rng: &mut Pcg64,
) -> (Vec<Sample>, ChainStats)
where
    T: TransitionKernel,
    T::State: Persist,
    F: FnMut(&T::State) -> f64,
{
    let DriveCfg { budget, burn_in, thin, intra, checkpoint, resume, progress, abort, cancel, board } =
        cfg;
    let (mut cur, mut stats, mut samples, prior, scratch_bytes, mut next_gen) = match resume {
        Some(ck) => {
            let mut r = BinReader::new(&ck.state);
            let cur = T::State::restore(&mut r)
                .and_then(|s| r.finish().map(|_| s))
                .unwrap_or_else(|e| panic!("corrupt checkpoint state: {e}"));
            let stats = ChainStats {
                steps: ck.steps,
                accepted: ck.accepted,
                data_used: ck.data_used,
                guard_trips: ck.guard_trips,
                ckpt_failures: 0,
                wall: Duration::from_secs_f64(ck.wall_secs),
            };
            *rng = Pcg64::from_parts(ck.rng);
            let gen = ck.generation + 1;
            (cur, stats, ck.samples, Duration::from_secs_f64(ck.wall_secs), Some(ck.scratch), gen)
        }
        None => (init, ChainStats::default(), Vec::new(), Duration::ZERO, None, 1),
    };
    // scratch is rebuilt from the (restored) state — this is what
    // regenerates the cached path's likelihood cache — then the
    // cross-step pieces (scheduler permutations, counters) are restored
    let mut scratch = kernel.scratch_par(&cur, &intra);
    if let Some(bytes) = scratch_bytes {
        let mut r = BinReader::new(&bytes);
        kernel
            .restore_scratch(&mut scratch, &mut r)
            .and_then(|_| r.finish())
            .unwrap_or_else(|e| panic!("corrupt checkpoint scratch: {e}"));
    }
    drive_loop(
        kernel,
        &mut cur,
        &mut scratch,
        &mut stats,
        &mut samples,
        budget,
        burn_in,
        thin,
        f,
        rng,
        prior,
        progress,
        abort,
        cancel,
        board,
        |state, scratch, rng, stats, samples, elapsed| {
            if let Some(sink) = &checkpoint {
                if sink.spec.every > 0 && stats.steps % sink.spec.every == 0 {
                    write_generation(
                        kernel, sink, state, scratch, rng, stats, samples, elapsed, &mut next_gen,
                    );
                }
            }
        },
    );
    // A cooperative stop (cancel or abort) exits between cadence
    // points; flush one final generation so whatever the chain sampled
    // survives and a `--resume` can finish the interrupted run. Skipped
    // when the cadence writer just covered this exact step count.
    let interrupted = cancel.is_some_and(|f| f.load(Ordering::Relaxed))
        || abort.is_some_and(|f| f.load(Ordering::Relaxed));
    if interrupted {
        if let Some(sink) = &checkpoint {
            if stats.steps > 0 && (sink.spec.every == 0 || stats.steps % sink.spec.every != 0) {
                let elapsed = stats.wall;
                write_generation(
                    kernel, sink, &cur, &scratch, rng, &mut stats, &samples, elapsed, &mut next_gen,
                );
            }
        }
    }
    (samples, stats)
}

/// Internal: run one MH chain under any acceptance rule (`&MhMode` or a
/// concrete `AcceptanceTest`); `f` maps the current parameter to the
/// scalar test function recorded every `thin` steps after `burn_in`
/// steps. A `session::Session` launch with K = 1 replays this bit for
/// bit (chain 0 steps on `Pcg64::new(seed, STREAM_BASE)`); kept `pub`
/// (hidden) as the same-seed bit-identity oracle for the integration
/// tests.
#[doc(hidden)]
#[allow(clippy::too_many_arguments)]
pub fn run_chain<M, K, T, F>(
    model: &M,
    kernel: &K,
    mode: &T,
    init: M::Param,
    budget: Budget,
    burn_in: usize,
    thin: usize,
    f: F,
    rng: &mut Pcg64,
) -> (Vec<Sample>, ChainStats)
where
    M: LlDiffModel + Sync,
    K: ProposalKernel<M::Param>,
    T: AcceptanceTest,
    F: FnMut(&M::Param) -> f64,
{
    drive_chain(
        &MhKernel { model, proposal: kernel, mode },
        init,
        budget,
        burn_in,
        thin,
        f,
        rng,
    )
}

/// Internal: `run_chain` on the state-caching fast path — per-datapoint
/// statistics of the current parameter persist across steps in a
/// model-provided cache, so each MH test only evaluates the proposal
/// side. Produces bit-identical samples to `run_chain` under the same
/// RNG stream. Kept `pub` (hidden) as the bit-identity oracle; use
/// `session::Session`, which picks this path automatically for cached
/// models.
#[doc(hidden)]
#[allow(clippy::too_many_arguments)]
pub fn run_chain_cached<M, K, T, F>(
    model: &M,
    kernel: &K,
    mode: &T,
    init: M::Param,
    budget: Budget,
    burn_in: usize,
    thin: usize,
    f: F,
    rng: &mut Pcg64,
) -> (Vec<Sample>, ChainStats)
where
    M: CachedLlDiff + Sync,
    K: ProposalKernel<M::Param>,
    T: AcceptanceTest,
    F: FnMut(&M::Param) -> f64,
{
    drive_chain(
        &CachedMhKernel { model, proposal: kernel, mode },
        init,
        budget,
        burn_in,
        thin,
        f,
        rng,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::mh::MhMode;
    use crate::models::traits::Proposal;
    use crate::stats::welford::Welford;

    /// 1-d Gaussian posterior as a fake "population": N datapoints each
    /// contributing (1/N) of the N(0,1) log density. l_i identical =>
    /// exact and approximate tests agree trivially; good for testing the
    /// chain machinery itself.
    struct GaussTarget {
        n: usize,
    }

    impl LlDiffModel for GaussTarget {
        type Param = f64;

        fn n(&self) -> usize {
            self.n
        }

        fn lldiff(&self, _i: usize, cur: &f64, prop: &f64) -> f64 {
            (0.5 * (cur * cur - prop * prop)) / self.n as f64
        }
    }

    fn rw_kernel(sigma: f64) -> impl Fn(&f64, &mut Pcg64) -> Proposal<f64> {
        move |cur: &f64, rng: &mut Pcg64| Proposal {
            param: cur + rng.normal_scaled(0.0, sigma),
            log_correction: 0.0,
        }
    }

    #[test]
    fn chain_samples_standard_normal() {
        let model = GaussTarget { n: 50 };
        let kernel = rw_kernel(1.5);
        let mut rng = Pcg64::seeded(0);
        let (samples, stats) = run_chain(
            &model,
            &kernel,
            &MhMode::Exact,
            0.0,
            Budget::Steps(60_000),
            2_000,
            1,
            |&p| p,
            &mut rng,
        );
        let mut w = Welford::new();
        for s in &samples {
            w.add(s.value);
        }
        assert!(w.mean().abs() < 0.05, "mean {}", w.mean());
        assert!((w.var_pop() - 1.0).abs() < 0.1, "var {}", w.var_pop());
        assert!(stats.acceptance_rate() > 0.2 && stats.acceptance_rate() < 0.9);
    }

    #[test]
    fn burn_in_and_thin_respected() {
        let model = GaussTarget { n: 10 };
        let kernel = rw_kernel(1.0);
        let mut rng = Pcg64::seeded(1);
        let (samples, stats) = run_chain(
            &model,
            &kernel,
            &MhMode::Exact,
            0.0,
            Budget::Steps(1_000),
            100,
            9,
            |&p| p,
            &mut rng,
        );
        assert_eq!(stats.steps, 1_000);
        assert_eq!(samples.len(), 100); // (1000-100)/9 = 100
    }

    #[test]
    fn wall_budget_terminates() {
        let model = GaussTarget { n: 10 };
        let kernel = rw_kernel(1.0);
        let mut rng = Pcg64::seeded(2);
        let start = Instant::now();
        let (_, stats) = run_chain(
            &model,
            &kernel,
            &MhMode::Exact,
            0.0,
            Budget::Wall(Duration::from_millis(50)),
            0,
            1,
            |&p| p,
            &mut rng,
        );
        assert!(start.elapsed() < Duration::from_secs(5));
        assert!(stats.steps > 0);
    }

    #[test]
    fn data_usage_counts_accumulate() {
        let model = GaussTarget { n: 100 };
        let kernel = rw_kernel(1.0);
        let mut rng = Pcg64::seeded(3);
        let (_, stats) = run_chain(
            &model,
            &kernel,
            &MhMode::Exact,
            0.0,
            Budget::Steps(50),
            0,
            1,
            |&p| p,
            &mut rng,
        );
        assert_eq!(stats.data_used, 50 * 100);
        assert!((stats.mean_data_fraction(100) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn data_budget_matches_equivalent_step_budget() {
        // Exact MH consumes exactly N per step, so Budget::Data(k * N)
        // must reproduce Budget::Steps(k) bit for bit.
        let model = GaussTarget { n: 40 };
        let kernel = rw_kernel(1.0);
        let run = |budget: Budget| {
            let mut rng = Pcg64::seeded(9);
            run_chain(&model, &kernel, &MhMode::Exact, 0.0, budget, 0, 1, |&p| p, &mut rng)
        };
        let (sa, sta) = run(Budget::Steps(250));
        let (sb, stb) = run(Budget::Data(250 * 40));
        assert_eq!(sta.steps, stb.steps);
        assert_eq!(sta.data_used, stb.data_used);
        let va: Vec<u64> = sa.iter().map(|s| s.value.to_bits()).collect();
        let vb: Vec<u64> = sb.iter().map(|s| s.value.to_bits()).collect();
        assert_eq!(va, vb);
    }
}

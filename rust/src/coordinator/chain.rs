//! Markov-chain driver: runs proposal kernel + MH test for a step or
//! time budget, collecting test-function values, acceptance and data-use
//! statistics — the harness every experiment in §6 runs on.

use std::time::{Duration, Instant};

use crate::coordinator::mh::{mh_step, mh_step_cached, MhMode, MhScratch, StepInfo};
use crate::models::traits::{CachedLlDiff, LlDiffModel, Proposal, ProposalKernel};
use crate::stats::Pcg64;

/// Summary statistics of one chain run.
#[derive(Clone, Debug, Default)]
pub struct ChainStats {
    pub steps: usize,
    pub accepted: usize,
    /// Total datapoint likelihood evaluations consumed by MH tests.
    pub data_used: u64,
    pub wall: Duration,
}

impl ChainStats {
    pub fn acceptance_rate(&self) -> f64 {
        if self.steps == 0 {
            0.0
        } else {
            self.accepted as f64 / self.steps as f64
        }
    }

    /// Mean fraction of the dataset consumed per MH test.
    pub fn mean_data_fraction(&self, n: usize) -> f64 {
        if self.steps == 0 {
            0.0
        } else {
            self.data_used as f64 / (self.steps as f64 * n as f64)
        }
    }
}

/// Stop condition for a run.
#[derive(Clone, Copy, Debug)]
pub enum Budget {
    Steps(usize),
    Wall(Duration),
}

/// A recorded sample: the test-function value and the cumulative cost at
/// which it was collected (for risk-vs-time curves).
#[derive(Clone, Copy, Debug)]
pub struct Sample {
    pub value: f64,
    /// Seconds since chain start when the sample was recorded.
    pub at_secs: f64,
    /// Cumulative datapoint evaluations when the sample was recorded.
    pub at_data: u64,
}

/// The single chain loop behind both `run_chain` variants: budget check,
/// propose, step, burn-in/thinned recording. `step` performs one MH
/// decision and mutates the parameter in place.
#[allow(clippy::too_many_arguments)]
fn drive_chain<P, K, F, S>(
    kernel: &K,
    mut cur: P,
    budget: Budget,
    burn_in: usize,
    thin: usize,
    mut f: F,
    rng: &mut Pcg64,
    mut step: S,
) -> (Vec<Sample>, ChainStats)
where
    K: ProposalKernel<P>,
    F: FnMut(&P) -> f64,
    S: FnMut(&mut P, Proposal<P>, &mut Pcg64) -> StepInfo,
{
    assert!(thin >= 1);
    let mut stats = ChainStats::default();
    let mut samples = Vec::new();
    let start = Instant::now();

    loop {
        match budget {
            Budget::Steps(s) => {
                if stats.steps >= s {
                    break;
                }
            }
            Budget::Wall(d) => {
                if start.elapsed() >= d {
                    break;
                }
            }
        }
        let proposal = kernel.propose(&cur, rng);
        let info = step(&mut cur, proposal, rng);
        stats.steps += 1;
        stats.accepted += info.accepted as usize;
        stats.data_used += info.n_used as u64;
        if stats.steps > burn_in && (stats.steps - burn_in) % thin == 0 {
            samples.push(Sample {
                value: f(&cur),
                at_secs: start.elapsed().as_secs_f64(),
                at_data: stats.data_used,
            });
        }
    }
    stats.wall = start.elapsed();
    (samples, stats)
}

/// Run a chain; `f` maps the current parameter to the scalar test
/// function recorded every `thin` steps after `burn_in` steps.
#[allow(clippy::too_many_arguments)]
pub fn run_chain<M, K, F>(
    model: &M,
    kernel: &K,
    mode: &MhMode,
    init: M::Param,
    budget: Budget,
    burn_in: usize,
    thin: usize,
    f: F,
    rng: &mut Pcg64,
) -> (Vec<Sample>, ChainStats)
where
    M: LlDiffModel,
    K: ProposalKernel<M::Param>,
    F: FnMut(&M::Param) -> f64,
{
    let mut scratch = MhScratch::new(model.n());
    drive_chain(kernel, init, budget, burn_in, thin, f, rng, |cur, proposal, rng| {
        mh_step(model, cur, proposal, mode, &mut scratch, rng)
    })
}

/// `run_chain` on the state-caching fast path: per-datapoint statistics
/// of the current parameter persist across steps in a model-provided
/// cache, so each MH test only evaluates the proposal side. Produces
/// bit-identical samples to `run_chain` under the same RNG stream.
#[allow(clippy::too_many_arguments)]
pub fn run_chain_cached<M, K, F>(
    model: &M,
    kernel: &K,
    mode: &MhMode,
    init: M::Param,
    budget: Budget,
    burn_in: usize,
    thin: usize,
    f: F,
    rng: &mut Pcg64,
) -> (Vec<Sample>, ChainStats)
where
    M: CachedLlDiff,
    K: ProposalKernel<M::Param>,
    F: FnMut(&M::Param) -> f64,
{
    let mut scratch = MhScratch::new(model.n());
    let mut cache = model.init_cache(&init);
    drive_chain(kernel, init, budget, burn_in, thin, f, rng, |cur, proposal, rng| {
        mh_step_cached(model, cur, &mut cache, proposal, mode, &mut scratch, rng)
    })
}

/// Run `n_chains` independent chains in parallel (std threads), seeding
/// each from `base_seed + chain index`. Kept for API compatibility; the
/// `engine` module is the full-featured multi-chain front end (worker
/// pools, observers, cross-chain diagnostics).
#[allow(clippy::too_many_arguments)]
pub fn run_chains_parallel<M, K, F>(
    model: &M,
    kernel: &K,
    mode: &MhMode,
    init: M::Param,
    budget: Budget,
    burn_in: usize,
    thin: usize,
    f: F,
    base_seed: u64,
    n_chains: usize,
) -> Vec<(Vec<Sample>, ChainStats)>
where
    M: LlDiffModel + Sync,
    K: ProposalKernel<M::Param> + Sync,
    M::Param: Clone + Send,
    F: Fn(&M::Param) -> f64 + Sync,
{
    std::thread::scope(|scope| {
        let handles: Vec<_> = (0..n_chains)
            .map(|c| {
                let init = init.clone();
                let f = &f;
                scope.spawn(move || {
                    let mut rng = Pcg64::new(base_seed, 1000 + c as u64);
                    run_chain(model, kernel, mode, init, budget, burn_in, thin, |p| f(p), &mut rng)
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().expect("chain panicked")).collect()
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::traits::Proposal;
    use crate::stats::welford::Welford;

    /// 1-d Gaussian posterior as a fake "population": N datapoints each
    /// contributing (1/N) of the N(0,1) log density. l_i identical =>
    /// exact and approximate tests agree trivially; good for testing the
    /// chain machinery itself.
    struct GaussTarget {
        n: usize,
    }

    impl LlDiffModel for GaussTarget {
        type Param = f64;

        fn n(&self) -> usize {
            self.n
        }

        fn lldiff(&self, _i: usize, cur: &f64, prop: &f64) -> f64 {
            (0.5 * (cur * cur - prop * prop)) / self.n as f64
        }
    }

    fn rw_kernel(sigma: f64) -> impl Fn(&f64, &mut Pcg64) -> Proposal<f64> {
        move |cur: &f64, rng: &mut Pcg64| Proposal {
            param: cur + rng.normal_scaled(0.0, sigma),
            log_correction: 0.0,
        }
    }

    #[test]
    fn chain_samples_standard_normal() {
        let model = GaussTarget { n: 50 };
        let kernel = rw_kernel(1.5);
        let mut rng = Pcg64::seeded(0);
        let (samples, stats) = run_chain(
            &model,
            &kernel,
            &MhMode::Exact,
            0.0,
            Budget::Steps(60_000),
            2_000,
            1,
            |&p| p,
            &mut rng,
        );
        let mut w = Welford::new();
        for s in &samples {
            w.add(s.value);
        }
        assert!(w.mean().abs() < 0.05, "mean {}", w.mean());
        assert!((w.var_pop() - 1.0).abs() < 0.1, "var {}", w.var_pop());
        assert!(stats.acceptance_rate() > 0.2 && stats.acceptance_rate() < 0.9);
    }

    #[test]
    fn burn_in_and_thin_respected() {
        let model = GaussTarget { n: 10 };
        let kernel = rw_kernel(1.0);
        let mut rng = Pcg64::seeded(1);
        let (samples, stats) = run_chain(
            &model,
            &kernel,
            &MhMode::Exact,
            0.0,
            Budget::Steps(1_000),
            100,
            9,
            |&p| p,
            &mut rng,
        );
        assert_eq!(stats.steps, 1_000);
        assert_eq!(samples.len(), 100); // (1000-100)/9 = 100
    }

    #[test]
    fn wall_budget_terminates() {
        let model = GaussTarget { n: 10 };
        let kernel = rw_kernel(1.0);
        let mut rng = Pcg64::seeded(2);
        let start = Instant::now();
        let (_, stats) = run_chain(
            &model,
            &kernel,
            &MhMode::Exact,
            0.0,
            Budget::Wall(Duration::from_millis(50)),
            0,
            1,
            |&p| p,
            &mut rng,
        );
        assert!(start.elapsed() < Duration::from_secs(5));
        assert!(stats.steps > 0);
    }

    #[test]
    fn data_usage_counts_accumulate() {
        let model = GaussTarget { n: 100 };
        let kernel = rw_kernel(1.0);
        let mut rng = Pcg64::seeded(3);
        let (_, stats) = run_chain(
            &model,
            &kernel,
            &MhMode::Exact,
            0.0,
            Budget::Steps(50),
            0,
            1,
            |&p| p,
            &mut rng,
        );
        assert_eq!(stats.data_used, 50 * 100);
        assert!((stats.mean_data_fraction(100) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn parallel_chains_differ_and_are_deterministic() {
        let model = GaussTarget { n: 20 };
        let kernel = rw_kernel(1.0);
        let run = || {
            run_chains_parallel(
                &model,
                &kernel,
                &MhMode::Exact,
                0.0,
                Budget::Steps(500),
                0,
                1,
                |&p| p,
                42,
                4,
            )
        };
        let a = run();
        let b = run();
        assert_eq!(a.len(), 4);
        // chains differ from each other
        assert_ne!(
            a[0].0.last().unwrap().value,
            a[1].0.last().unwrap().value
        );
        // but the whole ensemble is reproducible
        for (ca, cb) in a.iter().zip(&b) {
            assert_eq!(ca.0.len(), cb.0.len());
            assert_eq!(ca.0.last().unwrap().value, cb.0.last().unwrap().value);
        }
    }
}

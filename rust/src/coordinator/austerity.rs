//! The approximate Metropolis-Hastings test (paper Alg. 1) — the core
//! contribution: a sequential hypothesis test that decides accept/reject
//! from a growing without-replacement sample of log-likelihood
//! differences, stopping as soon as the Student-t tail probability
//! `delta = 1 - F_{n-1}(|t|)` drops below the knob `epsilon`.

use crate::coordinator::accept::{MomentsSource, StageTrace};
use crate::coordinator::scheduler::MinibatchScheduler;
use crate::models::traits::{CachedLlDiff, LlDiffModel};
use crate::stats::student_t::{t_sf, t_inv};
use crate::stats::welford::MomentAccumulator;
use crate::stats::Pcg64;

/// Per-stage decision bound.
#[derive(Clone, Copy, Debug)]
pub enum BoundSeq {
    /// Constant error threshold epsilon per stage (Pocock design — the
    /// paper's default knob).
    Pocock { eps: f64 },
    /// Wang-Tsiatis family: z-bound G_j = g0 * pi_j^delta. delta = 0 is
    /// Pocock with g0 = Phi^{-1}(1 - eps); delta = -0.5 is
    /// O'Brien-Fleming (supp. D).
    WangTsiatis { g0: f64, delta: f64 },
}

impl BoundSeq {
    /// The per-stage error threshold eps_j given the data proportion pi_j.
    /// (For a z-bound G_j this is the one-sided tail Phi(-G_j); the
    /// runtime test then compares the Student-t tail against it, which
    /// recovers |z| > G_j under the paper's CLT assumption.)
    pub fn eps_at(&self, pi_j: f64) -> f64 {
        match *self {
            BoundSeq::Pocock { eps } => eps,
            BoundSeq::WangTsiatis { g0, delta } => {
                let g = g0 * pi_j.powf(delta);
                crate::stats::normal::phi_sf(g)
            }
        }
    }

    /// The per-stage z-bound G_j (used by the DP error analysis).
    pub fn bound_at(&self, pi_j: f64) -> f64 {
        match *self {
            BoundSeq::Pocock { eps } => crate::stats::normal::phi_inv(1.0 - eps),
            BoundSeq::WangTsiatis { g0, delta } => g0 * pi_j.powf(delta),
        }
    }
}

/// Configuration of the sequential test.
#[derive(Clone, Copy, Debug)]
pub struct SeqTestConfig {
    /// Mini-batch increment m (paper recommends ~500).
    pub batch_size: usize,
    /// Decision bound sequence (knob epsilon).
    pub bound: BoundSeq,
}

impl SeqTestConfig {
    pub fn new(eps: f64, batch_size: usize) -> Self {
        // eps = 0.5 is meaningful (paper §6.4: always decide on the first
        // mini-batch); anything above is a no-op test.
        assert!((0.0..=0.5).contains(&eps), "epsilon in [0, 0.5]: got {eps}");
        assert!(batch_size >= 2);
        SeqTestConfig { batch_size, bound: BoundSeq::Pocock { eps } }
    }
}

/// Outcome of one sequential test.
#[derive(Clone, Copy, Debug)]
pub struct SeqTestOutcome {
    pub accept: bool,
    /// Datapoints consumed.
    pub n_used: usize,
    /// Mini-batch stages run.
    pub stages: usize,
    /// Final sample mean of the l_i.
    pub mean: f64,
    /// Final test statistic.
    pub t_stat: f64,
}

/// Run the sequential approximate MH test (Alg. 1).
///
/// `mu0` is the threshold from Eqn. 2 (computed by the caller from u, the
/// prior ratio and the proposal ratio). The scheduler must belong to the
/// same population as `model` (same N) and is reset here. The kernels
/// consume the scheduler's drawn `&[u32]` slice directly — no index
/// staging buffer exists on this path.
pub fn seq_mh_test<M: LlDiffModel>(
    model: &M,
    cur: &M::Param,
    prop: &M::Param,
    mu0: f64,
    cfg: &SeqTestConfig,
    sched: &mut MinibatchScheduler,
    rng: &mut Pcg64,
) -> SeqTestOutcome {
    debug_assert_eq!(model.n(), sched.n());
    seq_test_core(
        model.n(),
        &mut |idx: &[u32]| model.lldiff_moments(idx, cur, prop),
        mu0,
        cfg,
        sched,
        rng,
        None,
    )
}

/// `seq_mh_test` on the state-caching fast path: moments are served from
/// the model's activation cache (current side cached, proposal side
/// computed), which is bit-identical to the uncached test by the
/// `CachedLlDiff` contract. The caller owns the step protocol
/// (`begin_step` before, `end_step` after).
#[allow(clippy::too_many_arguments)]
pub fn seq_mh_test_cached<M: CachedLlDiff>(
    model: &M,
    cache: &mut M::Cache,
    prop: &M::Param,
    mu0: f64,
    cfg: &SeqTestConfig,
    sched: &mut MinibatchScheduler,
    rng: &mut Pcg64,
) -> SeqTestOutcome {
    debug_assert_eq!(model.n(), sched.n());
    seq_test_core(
        model.n(),
        &mut |idx: &[u32]| model.cached_moments(cache, idx, prop),
        mu0,
        cfg,
        sched,
        rng,
        None,
    )
}

/// The sequential test itself, abstracted over the moments backend so
/// the cached and uncached paths — and the `AusterityTest` member of the
/// acceptance-test layer — share one decision procedure (any divergence
/// here would break their bit-identity guarantee). `trace`, when given,
/// records one `(n, delta, eps_j)` entry per stage; it never influences
/// the decision or the RNG stream.
#[allow(clippy::too_many_arguments)]
pub(crate) fn seq_test_core<S: MomentsSource>(
    n_total: usize,
    moments: &mut S,
    mu0: f64,
    cfg: &SeqTestConfig,
    sched: &mut MinibatchScheduler,
    rng: &mut Pcg64,
    mut trace: Option<&mut Vec<StageTrace>>,
) -> SeqTestOutcome {
    sched.reset();
    let mut acc = MomentAccumulator::new();
    let mut stages = 0usize;

    loop {
        let batch = sched.next_batch(cfg.batch_size, rng);
        let drawn = batch.len();
        debug_assert!(drawn > 0, "population exhausted without decision");
        let (s, s2) = moments.batch(batch);
        acc.add_batch(s, s2, drawn);
        stages += 1;

        let n = acc.n();
        let t = acc.t_statistic(mu0, n_total);
        // delta = 1 - F_{n-1}(|t|); infinite t (all data, s = 0) gives 0.
        let delta = t_sf(t.abs(), (n - 1).max(1) as f64);
        let pi_j = n as f64 / n_total as f64;
        let eps_j = cfg.bound.eps_at(pi_j);
        if let Some(tr) = trace.as_mut() {
            tr.push(StageTrace { n_used: n, stat: delta, threshold: eps_j });
        }

        if delta < eps_j || n == n_total {
            return SeqTestOutcome {
                accept: acc.mean() > mu0,
                n_used: n,
                stages,
                mean: acc.mean(),
                t_stat: t,
            };
        }
    }
}

/// The z-quantile matching a per-stage epsilon with nu dof (diagnostic;
/// the runtime test uses the tail probability directly).
pub fn t_threshold(eps: f64, nu: f64) -> f64 {
    t_inv(1.0 - eps, nu)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::traits::testutil::FixedPopulation;
    use crate::testkit;

    fn run(
        ls: Vec<f64>,
        mu0: f64,
        eps: f64,
        m: usize,
        seed: u64,
    ) -> SeqTestOutcome {
        let model = FixedPopulation { ls };
        let mut sched = MinibatchScheduler::new(model.n()).expect("population exceeds the u32 index space");
        let mut rng = Pcg64::seeded(seed);
        seq_mh_test(&model, &(), &(), mu0, &SeqTestConfig::new(eps, m), &mut sched, &mut rng)
    }

    #[test]
    fn obvious_accept_decides_early() {
        // population mean 1.0, tiny spread, mu0 = 0 -> immediate accept.
        let mut rng = Pcg64::seeded(0);
        let ls: Vec<f64> = (0..10_000).map(|_| 1.0 + 0.01 * rng.normal()).collect();
        let out = run(ls, 0.0, 0.05, 500, 1);
        assert!(out.accept);
        assert_eq!(out.stages, 1);
        assert_eq!(out.n_used, 500);
    }

    #[test]
    fn obvious_reject_decides_early() {
        let mut rng = Pcg64::seeded(1);
        let ls: Vec<f64> = (0..10_000).map(|_| -0.5 + 0.01 * rng.normal()).collect();
        let out = run(ls, 0.0, 0.05, 500, 2);
        assert!(!out.accept);
        assert_eq!(out.stages, 1);
    }

    #[test]
    fn ambiguous_case_consumes_more_data() {
        // mean exactly at mu0: needs all (or nearly all) the data.
        let mut rng = Pcg64::seeded(2);
        let ls: Vec<f64> = (0..5_000).map(|_| rng.normal()).collect();
        let mean = ls.iter().sum::<f64>() / ls.len() as f64;
        let out = run(ls, mean, 0.01, 500, 3);
        assert!(out.n_used > 2_000, "used {}", out.n_used);
    }

    #[test]
    fn exhausting_data_matches_exact_decision() {
        // When the test runs to n = N the decision must equal mean > mu0.
        testkit::forall(64, |rng| {
            let n = rng.below(2_000) + 100;
            let ls: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let mean = ls.iter().sum::<f64>() / n as f64;
            // mu0 very near the true mean forces a full scan
            let mu0 = mean + 1e-12;
            let model = FixedPopulation { ls };
            let mut sched = MinibatchScheduler::new(n).expect("population exceeds the u32 index space");
            let out =
                seq_mh_test(&model, &(), &(), mu0, &SeqTestConfig::new(1e-9, 100), &mut sched, rng);
            assert_eq!(out.n_used, n);
            assert_eq!(out.accept, mean > mu0, "exact decision mismatch");
        });
    }

    #[test]
    fn epsilon_zero_always_scans_everything() {
        let mut rng = Pcg64::seeded(4);
        let ls: Vec<f64> = (0..3_000).map(|_| 2.0 + rng.normal()).collect();
        let out = run(ls, 0.0, 0.0, 500, 5);
        assert_eq!(out.n_used, 3_000);
        assert!(out.accept);
    }

    #[test]
    fn larger_epsilon_uses_no_more_data() {
        // Monotonicity: a looser test can only stop earlier (same draws).
        testkit::forall(32, |rng| {
            let n = 4_000;
            let shift = rng.normal_scaled(0.0, 0.05);
            let ls: Vec<f64> = (0..n).map(|_| shift + rng.normal()).collect();
            let seed = rng.next_u64();
            let mut used = Vec::new();
            for &eps in &[0.01, 0.05, 0.2] {
                let model = FixedPopulation { ls: ls.clone() };
                let mut sched = MinibatchScheduler::new(n).expect("population exceeds the u32 index space");
                let mut r = Pcg64::seeded(seed);
                let out = seq_mh_test(
                    &model,
                    &(),
                    &(),
                    0.0,
                    &SeqTestConfig::new(eps, 400),
                    &mut sched,
                    &mut r,
                );
                used.push(out.n_used);
            }
            assert!(used[0] >= used[1] && used[1] >= used[2], "{used:?}");
        });
    }

    #[test]
    fn decision_error_rate_bounded_by_analysis() {
        // For a population with mu clearly != mu0, repeated tests almost
        // always agree with the exact decision.
        let mut rng = Pcg64::seeded(6);
        let n = 20_000;
        let ls: Vec<f64> = (0..n).map(|_| 0.05 + rng.normal()).collect();
        let mean = ls.iter().sum::<f64>() / n as f64;
        let exact = mean > 0.0;
        let model = FixedPopulation { ls };
        let mut sched = MinibatchScheduler::new(n).expect("population exceeds the u32 index space");
        let mut wrong = 0;
        let trials = 200;
        for s in 0..trials {
            let mut r = Pcg64::new(100 + s, 0);
            let out = seq_mh_test(
                &model,
                &(),
                &(),
                0.0,
                &SeqTestConfig::new(0.05, 500),
                &mut sched,
                &mut r,
            );
            if out.accept != exact {
                wrong += 1;
            }
        }
        // sequential error is bounded by a small multiple of eps in the
        // non-worst case; allow generous slack for test stability
        assert!(wrong < 30, "wrong = {wrong}/{trials}");
    }

    #[test]
    fn wang_tsiatis_bounds_shrink_with_pi_for_obf() {
        let b = BoundSeq::WangTsiatis { g0: 2.0, delta: -0.5 };
        assert!(b.bound_at(0.04) > b.bound_at(0.5));
        assert!(b.bound_at(0.5) > b.bound_at(1.0));
        // eps_at inverts through the normal tail
        assert!(b.eps_at(0.04) < b.eps_at(1.0));
    }

    #[test]
    fn pocock_bound_constant() {
        let b = BoundSeq::Pocock { eps: 0.05 };
        assert_eq!(b.eps_at(0.1), 0.05);
        let g = b.bound_at(0.3);
        assert!((crate::stats::normal::phi_sf(g) - 0.05).abs() < 1e-10);
    }

    #[test]
    fn t_threshold_edges_are_defined_not_nan() {
        // eps = 0 ("never stop early") must give an infinite threshold,
        // eps = 0.5 a zero threshold, and tiny-nu thresholds must be
        // finite — the first stage of a batch-2 test runs at nu = 1.
        assert_eq!(t_threshold(0.0, 1.0), f64::INFINITY);
        assert_eq!(t_threshold(0.5, 7.0), 0.0);
        for &nu in &[1.0, 2.0, 3.0] {
            for &eps in &[1e-12, 1e-6, 0.01, 0.2] {
                let t = t_threshold(eps, nu);
                assert!(t.is_finite() && t > 0.0, "eps={eps} nu={nu}: {t}");
            }
        }
    }

    /// Supp. D as a regression test: across a seeded grid of designs
    /// (Pocock and Wang-Tsiatis/O'Brien-Fleming bounds x epsilon levels),
    /// the measured fraction of decisions that disagree with the exact
    /// rule `mean > mu0` stays within the configured per-stage error
    /// budget (plus binomial counting slack). The populations put mu a
    /// few first-batch standard errors away from mu0 — the regime the
    /// paper's error analysis targets; adversarially small margins are
    /// covered by the DP analysis in `coordinator::dp`, not this bound.
    #[test]
    fn calibration_wrong_decision_rate_bounded_across_designs() {
        let n = 20_000usize;
        let m = 500usize;
        let trials = 300u64;
        let mut gen = Pcg64::seeded(0xca11b);
        // sigma_l = 1 => first-batch standard error of the mean ~ 1/sqrt(m)
        let ls: Vec<f64> = (0..n).map(|_| gen.normal()).collect();
        let mean = ls.iter().sum::<f64>() / n as f64;
        let margin = 2.5 / (m as f64).sqrt();
        let model = FixedPopulation { ls };

        for &eps in &[0.02f64, 0.05, 0.1] {
            let designs = [
                BoundSeq::Pocock { eps },
                // O'Brien-Fleming-shaped Wang-Tsiatis design scaled to
                // spend eps at the full-data stage
                BoundSeq::WangTsiatis {
                    g0: crate::stats::normal::phi_inv(1.0 - eps),
                    delta: -0.5,
                },
            ];
            for bound in designs {
                let cfg = SeqTestConfig { batch_size: m, bound };
                for &side in &[-1.0, 1.0] {
                    let mu0 = mean + side * margin;
                    let exact = mean > mu0;
                    let mut sched = MinibatchScheduler::new(n).expect("population exceeds the u32 index space");
                    let mut wrong = 0usize;
                    for s in 0..trials {
                        let mut rng = Pcg64::new(7_000 + s, 3);
                        let out =
                            seq_mh_test(&model, &(), &(), mu0, &cfg, &mut sched, &mut rng);
                        wrong += (out.accept != exact) as usize;
                    }
                    let frac = wrong as f64 / trials as f64;
                    // eps budget + 3-sigma binomial slack on 300 trials
                    let slack = 3.0 * (eps * (1.0 - eps) / trials as f64).sqrt();
                    assert!(
                        frac <= eps + slack,
                        "bound {bound:?} eps {eps} side {side}: wrong {frac:.4} > {eps} + {slack:.4}"
                    );
                }
            }
        }
    }
}

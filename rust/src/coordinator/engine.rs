//! Parallel multi-chain execution engine: K independent chains
//! multiplexed over the persistent executor pool
//! (`coordinator::executor`), per-chain RNG streams, merged statistics
//! and cross-chain convergence diagnostics (split R-hat / ESS).
//!
//! Design rules (see DESIGN.md §Engine and §Executor layer):
//!
//! * **Determinism**: chain `c` always runs on `Pcg64::new(base_seed,
//!   STREAM_BASE + c)`, regardless of how chains are packed onto pool
//!   workers — the same configuration produces bit-identical samples
//!   whether it runs on 1 worker or 16 (for step budgets; wall budgets
//!   are inherently timing-dependent).
//! * **No shared mutable state**: the model is shared immutably
//!   (`M: Sync`); every chain owns its scratch, RNG, cache and observer.
//! * **Observers**: per-chain stateful test functions created by a
//!   factory and returned with the results, so experiments can stream
//!   vector statistics (predictive means, inclusion counts) without a
//!   second pass over samples.
//! * **One pool, shared**: by default a launch draws its chain tasks —
//!   and the chains' intra-step scan spans — from the process-global
//!   `Executor`, grown once to the requested width; concurrent launches
//!   therefore share fixed hardware instead of each spawning its own
//!   threads, and the steady state spawns zero threads per step.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use crate::coordinator::accept::AcceptanceTest;
use crate::coordinator::chain::{
    drive_chain_ckpt, Budget, ChainStats, CkptSink, DriveCfg, Sample, ScopedChainCtx,
};
use crate::coordinator::checkpoint::{
    fs_store, validate_manifest, write_manifest, ChainCheckpoint, CheckpointSpec, ManifestInfo,
    Persist, ShardStamp, StoreLayer, DEFAULT_RETAIN,
};
use crate::coordinator::executor::{Executor, IntraPar};
use crate::coordinator::kernel::{CachedMhKernel, MhKernel, TransitionKernel};
use crate::coordinator::supervise::{
    spawn_watchdog, CancelToken, LaunchError, ProgressBoard, RetryPolicy, WatchState,
};
use crate::metrics::convergence::{cross_chain, Convergence};
use crate::models::traits::{CachedLlDiff, LlDiffModel, ProposalKernel};
use crate::stats::Pcg64;

/// RNG stream id of chain 0 (chain `c` uses `STREAM_BASE + c`); matches
/// the historical single-chain convention so seeds stay stable.
pub const STREAM_BASE: u64 = 1000;

/// Configuration of one engine launch.
#[derive(Clone, Debug)]
pub struct EngineConfig {
    /// Number of independent chains K.
    pub chains: usize,
    /// Worker threads; 0 means one worker per chain.
    pub threads: usize,
    /// Base seed; chain `c` draws from stream `STREAM_BASE + c`.
    pub base_seed: u64,
    /// Per-chain stop condition.
    pub budget: Budget,
    pub burn_in: usize,
    pub thin: usize,
    /// Write per-chain checkpoints while running.
    pub checkpoint: Option<CheckpointSpec>,
    /// Resume chains from checkpoints in this directory (chains without a
    /// checkpoint file start fresh).
    pub resume: Option<PathBuf>,
    /// Run on this executor pool instead of the process-global one. The
    /// pinned pool is taken as-is — never grown — so a launch can be
    /// deliberately oversubscribed (more chain/scan tasks than workers)
    /// and still completes, just with less overlap.
    pub executor: Option<Executor>,
    /// Shard membership of this launch (default: unsharded). Stamped
    /// into every checkpoint; resume refuses checkpoints carrying a
    /// different stamp.
    pub shard: ShardStamp,
    /// Restart failed chains from their last good checkpoint (default:
    /// no retries — a failed chain stays `ChainStatus::Failed`).
    pub retry: RetryPolicy,
    /// Flag chains whose step counter has not advanced within this
    /// window as `ChainStatus::Stalled` (default: no watchdog).
    pub stall_after: Option<Duration>,
    /// Healthy-chain quorum in `[0, 1]`: when the fraction of chains
    /// neither failed nor stalled drops below it, the launch aborts with
    /// `LaunchError::QuorumLost` (default 0 — degrade, never abort).
    pub min_chains: f64,
    /// Kernel/backend label written into the checkpoint manifest and
    /// validated on resume; empty below the session layer.
    pub kernel_label: &'static str,
    /// Acceptance-rule label for the manifest; empty below the session
    /// layer.
    pub rule_label: &'static str,
    /// Byte-level access to the checkpoint directory; the production
    /// filesystem store unless the fault-injection testkit swaps one in.
    pub store: Arc<dyn StoreLayer>,
    /// Caller-raised cooperative cancel (the serve layer's
    /// `DELETE /jobs/:id` and shutdown drain): polled at every step
    /// boundary next to the watchdog's abort. Cancelled chains stop
    /// cleanly with what they have and flush a final checkpoint
    /// generation, so a cancelled job can later resume (default: no
    /// token — the launch runs to its budget).
    pub cancel: Option<CancelToken>,
    /// Live progress counters published after every completed step
    /// (steps / acceptances / datapoint evaluations per chain); must be
    /// sized to `chains` (checked at launch).
    pub board: Option<Arc<ProgressBoard>>,
}

impl EngineConfig {
    pub fn new(chains: usize, base_seed: u64, budget: Budget) -> Self {
        EngineConfig {
            chains,
            threads: 0,
            base_seed,
            budget,
            burn_in: 0,
            thin: 1,
            checkpoint: None,
            resume: None,
            executor: None,
            shard: ShardStamp::default(),
            retry: RetryPolicy::none(),
            stall_after: None,
            min_chains: 0.0,
            kernel_label: "",
            rule_label: "",
            store: fs_store(),
            cancel: None,
            board: None,
        }
    }

    pub fn burn_in(mut self, burn_in: usize) -> Self {
        self.burn_in = burn_in;
        self
    }

    pub fn thin(mut self, thin: usize) -> Self {
        assert!(thin >= 1);
        self.thin = thin;
        self
    }

    pub fn threads(mut self, threads: usize) -> Self {
        self.threads = threads;
        self
    }

    /// Checkpoint every `every` completed steps into `dir` (see
    /// `coordinator::checkpoint`).
    pub fn checkpoint(mut self, every: usize, dir: impl Into<PathBuf>) -> Self {
        assert!(every >= 1, "checkpoint interval must be at least 1 step");
        self.checkpoint =
            Some(CheckpointSpec { every, dir: dir.into(), retain: DEFAULT_RETAIN });
        self
    }

    /// Keep the newest `k` checkpoint generations per chain (default 2:
    /// the newest plus one torn-write fallback). No-op until
    /// `checkpoint` is also set.
    pub fn retain_checkpoints(mut self, k: usize) -> Self {
        assert!(k >= 1, "must retain at least one checkpoint generation");
        if let Some(spec) = &mut self.checkpoint {
            spec.retain = k;
        }
        self
    }

    /// Restart failed chains from their last good checkpoint under
    /// `policy`.
    pub fn retry(mut self, policy: RetryPolicy) -> Self {
        self.retry = policy;
        self
    }

    /// Run the stall watchdog: chains not advancing within `window` are
    /// flagged `ChainStatus::Stalled`.
    pub fn stall_after(mut self, window: Duration) -> Self {
        assert!(window > Duration::ZERO, "stall window must be positive");
        self.stall_after = Some(window);
        self
    }

    /// Abort the launch (typed `LaunchError::QuorumLost`) when fewer
    /// than `fraction` of the chains remain healthy. Only meaningful
    /// together with `stall_after`, which drives the quorum checks.
    pub fn min_chains(mut self, fraction: f64) -> Self {
        assert!(
            (0.0..=1.0).contains(&fraction),
            "min_chains is a fraction in [0, 1]"
        );
        self.min_chains = fraction;
        self
    }

    /// Route checkpoint I/O through `store` (the fault-injection hook;
    /// production launches keep the default filesystem store).
    pub fn store(mut self, store: Arc<dyn StoreLayer>) -> Self {
        self.store = store;
        self
    }

    /// Manifest labels for the kernel/backend and acceptance rule,
    /// validated on resume (set by the session layer).
    pub fn labels(mut self, kernel: &'static str, rule: &'static str) -> Self {
        self.kernel_label = kernel;
        self.rule_label = rule;
        self
    }

    /// Resume chains from the checkpoints in `dir`; chains without a
    /// checkpoint file start fresh, mismatched or corrupt files fail that
    /// chain.
    pub fn resume(mut self, dir: impl Into<PathBuf>) -> Self {
        self.resume = Some(dir.into());
        self
    }

    /// Pin the launch to `exec` instead of the process-global pool (see
    /// the `executor` field for the oversubscription semantics).
    pub fn executor(mut self, exec: Executor) -> Self {
        self.executor = Some(exec);
        self
    }

    /// Stamp this launch as one shard of an embarrassingly-parallel run
    /// (see `session::Session::shards`). Checkpoints written by the
    /// launch carry the stamp and resume validates it.
    pub fn shard(mut self, stamp: ShardStamp) -> Self {
        assert!(stamp.count >= 1 && stamp.index < stamp.count, "invalid shard stamp");
        self.shard = stamp;
        self
    }

    /// Poll `token` at every step boundary; when raised, every chain
    /// stops cleanly at its next step with what it has (see the `cancel`
    /// field for the checkpoint-flush semantics).
    pub fn cancel_token(mut self, token: CancelToken) -> Self {
        self.cancel = Some(token);
        self
    }

    /// Publish per-step progress into `board` (one lane per chain; the
    /// launch asserts the sizes match).
    pub fn progress_board(mut self, board: Arc<ProgressBoard>) -> Self {
        self.board = Some(board);
        self
    }
}

/// Per-chain stateful test function. Implemented for any
/// `FnMut(&P) -> f64 + Send` closure; implement it on a struct when the
/// chain should accumulate vector statistics you need back afterwards.
pub trait ChainObserver<P>: Send {
    /// Called for every recorded (post-burn-in, thinned) state; the
    /// return value becomes the recorded `Sample::value`.
    fn observe(&mut self, param: &P) -> f64;
}

impl<P, F: FnMut(&P) -> f64 + Send> ChainObserver<P> for F {
    fn observe(&mut self, param: &P) -> f64 {
        self(param)
    }
}

/// How one chain of a launch ended. Failures carry the 0-based index of
/// the step the chain was executing when it died and the panic message.
/// When several apply, the most severe wins: `Failed` over `Stalled`
/// over `Recovered` over `Completed`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ChainStatus {
    Completed,
    /// Completed, but only after recovery: `retries` counts restart
    /// attempts plus checkpoint generations skipped past corruption.
    /// The draws are bit-identical to a never-failed run.
    Recovered { retries: usize },
    /// Completed (or was aborted by quorum loss), but the watchdog
    /// caught it frozen at `step` for at least `stall_after`.
    Stalled { step: usize },
    Failed { step: usize, reason: String },
}

impl ChainStatus {
    pub fn is_failed(&self) -> bool {
        matches!(self, ChainStatus::Failed { .. })
    }

    pub fn is_recovered(&self) -> bool {
        matches!(self, ChainStatus::Recovered { .. })
    }

    pub fn is_stalled(&self) -> bool {
        matches!(self, ChainStatus::Stalled { .. })
    }
}

/// One chain's output.
#[derive(Clone, Debug)]
pub struct ChainRun {
    pub chain: usize,
    pub samples: Vec<Sample>,
    pub stats: ChainStats,
}

/// Everything one engine launch produced.
pub struct EngineResult<O> {
    /// Samples and statistics of the chains that completed, in chain
    /// order (`ChainRun::chain` keeps the original index). Equal in
    /// length to `statuses` only when every chain completed.
    pub runs: Vec<ChainRun>,
    /// Observers of the completed chains, in `runs` order.
    pub observers: Vec<O>,
    /// Per-chain outcome for all K launched chains, in chain order.
    pub statuses: Vec<ChainStatus>,
    /// Counters summed over completed chains; `merged.wall` is the
    /// slowest single chain (not the launch duration — chains may share
    /// workers).
    pub merged: ChainStats,
    /// Wall-clock duration of the stepping itself: first chain task
    /// submitted to last one finished. Pool construction (growing the
    /// shared executor) happens before this clock starts, so
    /// `steps_per_sec` / `data_per_sec` measure sampling, not thread
    /// startup. Equals roughly max(chain walls) when every chain has its
    /// own worker, and approaches their sum as the pool shrinks.
    pub wall: std::time::Duration,
    /// Cross-chain split R-hat / ESS over the recorded sample values.
    pub convergence: Convergence,
}

impl<O> EngineResult<O> {
    /// Number of launched chains that failed.
    pub fn failed_chains(&self) -> usize {
        self.statuses.iter().filter(|s| s.is_failed()).count()
    }

    /// Number of chains that completed only after supervised recovery.
    pub fn recovered_chains(&self) -> usize {
        self.statuses.iter().filter(|s| s.is_recovered()).count()
    }

    /// Number of chains the watchdog flagged as stalled.
    pub fn stalled_chains(&self) -> usize {
        self.statuses.iter().filter(|s| s.is_stalled()).count()
    }

    /// Recorded values per chain (for custom diagnostics).
    pub fn values(&self) -> Vec<Vec<f64>> {
        self.runs
            .iter()
            .map(|r| r.samples.iter().map(|s| s.value).collect())
            .collect()
    }

    /// Aggregate steps per wall-clock second of the launch (NaN for a
    /// zero-duration launch, rather than a misleading 0).
    pub fn steps_per_sec(&self) -> f64 {
        let secs = self.wall.as_secs_f64();
        if secs > 0.0 {
            self.merged.steps as f64 / secs
        } else {
            f64::NAN
        }
    }

    /// Aggregate datapoint evaluations per wall-clock second — the
    /// throughput axis of `Budget::Data` runs, which budget in
    /// evaluations rather than steps (`merged.data_used` is the amount
    /// consumed; reports surface both).
    pub fn data_per_sec(&self) -> f64 {
        let secs = self.wall.as_secs_f64();
        if secs > 0.0 {
            self.merged.data_used as f64 / secs
        } else {
            f64::NAN
        }
    }
}

/// A task of `parallel_map_result` that panicked: which one, and the
/// panic message.
#[derive(Clone, Debug)]
pub struct TaskError {
    pub task: usize,
    pub reason: String,
}

fn panic_reason(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else {
        "panic with non-string payload".to_string()
    }
}

/// Run `tasks` independent jobs with at most `threads` of them in
/// flight at once (0 = all concurrent), returning per-task results in
/// task order. Task `i` always receives index `i`, so any deterministic
/// task function yields identical results regardless of the concurrency
/// cap. A panicking task is isolated: it becomes `Err(TaskError)` in
/// its own slot and every other task still runs to completion. Tasks
/// run on the process-global executor pool, grown once to the requested
/// width — no threads are spawned per call.
pub fn parallel_map_result<T, F>(tasks: usize, threads: usize, f: F) -> Vec<Result<T, TaskError>>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let cap = if threads == 0 { tasks } else { threads.min(tasks) };
    let exec = if cap > 1 {
        let exec = Executor::global();
        exec.ensure_workers(cap - 1);
        Some(exec)
    } else {
        None
    };
    parallel_map_result_on(exec.as_ref(), tasks, cap, &f)
}

/// `parallel_map_result` on an explicit pool handle (or serially when
/// `exec` is `None`): the engine resolves its pool once per launch and
/// routes the chain fan-out through here so pool setup stays outside
/// the launch clock.
fn parallel_map_result_on<T, F>(
    exec: Option<&Executor>,
    tasks: usize,
    cap: usize,
    f: &F,
) -> Vec<Result<T, TaskError>>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let run_one = |i: usize| -> Result<T, TaskError> {
        catch_unwind(AssertUnwindSafe(|| f(i)))
            .map_err(|p| TaskError { task: i, reason: panic_reason(p.as_ref()) })
    };
    let exec = match exec {
        Some(e) if cap > 1 && tasks > 1 => e,
        _ => return (0..tasks).map(run_one).collect(),
    };
    let slots: Vec<Mutex<Option<Result<T, TaskError>>>> =
        (0..tasks).map(|_| Mutex::new(None)).collect();
    // run_one catches task panics, so the scope's own panic path (which
    // would re-raise a payload here) is never taken for task failures;
    // slots can only stay empty if a pool worker is killed from outside.
    exec.scope_capped(tasks, cap, |i| {
        let res = run_one(i);
        *slots[i].lock().unwrap_or_else(|e| e.into_inner()) = Some(res);
    });
    slots
        .into_iter()
        .enumerate()
        .map(|(i, s)| {
            s.into_inner().unwrap_or_else(|e| e.into_inner()).unwrap_or_else(|| {
                Err(TaskError { task: i, reason: "task result missing (worker died)".into() })
            })
        })
        .collect()
}

/// `parallel_map_result` for infallible tasks; panics naming the failing
/// task if one of them does panic.
pub fn parallel_map<T, F>(tasks: usize, threads: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    parallel_map_result(tasks, threads, f)
        .into_iter()
        .map(|r| r.unwrap_or_else(|e| panic!("engine task {} panicked: {}", e.task, e.reason)))
        .collect()
}

/// A checkpoint adopted by a resuming (or retrying) chain, plus how
/// many newer torn/corrupt generations the loader had to skip to reach
/// it — skips count as recovery events in `ChainStatus::Recovered`.
struct ResumeLoad {
    ck: ChainCheckpoint,
    skipped: usize,
}

/// Load chain `c`'s newest loadable checkpoint for a resuming launch; no
/// generation files means "start fresh", a directory where every
/// generation is corrupt — or a structurally valid checkpoint belonging
/// to a different run — panics (downed by the per-chain isolation, not
/// the launch).
fn load_resume(
    store: &dyn StoreLayer,
    dir: &Path,
    chain: usize,
    base_seed: u64,
    shard: ShardStamp,
) -> Option<ResumeLoad> {
    match ChainCheckpoint::load_latest(store, dir, chain) {
        Ok(None) => None,
        Ok(Some((ck, skipped))) => {
            if ck.chain != chain || ck.base_seed != base_seed {
                panic!(
                    "chain {chain}: checkpoint belongs to a different run \
                     (chain {}, base seed {})",
                    ck.chain, ck.base_seed
                );
            }
            if ck.shard != shard {
                panic!(
                    "chain {chain}: checkpoint belongs to a different shard layout \
                     ({}, expected {})",
                    ck.shard, shard
                );
            }
            Some(ResumeLoad { ck, skipped })
        }
        Err(e) => panic!("chain {chain}: cannot load checkpoint: {e}"),
    }
}

/// Internal: run K chains of any `TransitionKernel`, one observer per
/// chain — the engine path behind `session::KernelSession`, which is
/// the public front door. Kept `pub` (hidden) so the integration tests
/// can use it as the same-seed bit-identity oracle. Chain `c` starts
/// from a clone of `init` and steps on `Pcg64::new(base_seed,
/// STREAM_BASE + c)`, so a launch is bit-reproducible for any pool size
/// (for step and data budgets).
///
/// When the pool has more workers than chains (`threads > chains`), the
/// spare capacity is handed to the chains as *intra-step* workers
/// (`threads / chains` each) — kernels with a parallelizable step (the
/// MH exact-rule full scan) use them through `scratch_par`. Intra-step
/// parallelism is deterministic by construction, so this keeps the
/// bit-reproducibility guarantee while filling the pool at K = 1.
///
/// A panicking chain is isolated (`ChainStatus::Failed`) — or, under a
/// `RetryPolicy`, restarted from its last good checkpoint; checkpoint
/// and resume options on `cfg` flow through to `drive_chain_ckpt`.
/// Panics on `LaunchError` (quorum loss, refused resume) — use
/// [`run_engine_kernel_result`] for the typed error.
#[doc(hidden)]
pub fn run_engine_kernel<T, OF, O>(
    kernel: &T,
    init: T::State,
    cfg: &EngineConfig,
    make_observer: OF,
) -> EngineResult<O>
where
    T: TransitionKernel + Sync,
    T::State: Sync + Persist,
    OF: Fn(usize) -> O + Sync,
    O: ChainObserver<T::State>,
{
    run_engine_kernel_result(kernel, init, cfg, make_observer)
        .unwrap_or_else(|e| panic!("engine launch failed: {e}"))
}

/// The manifest view of a launch configuration (what resume validation
/// compares against the directory's `manifest.json`).
fn manifest_info(cfg: &EngineConfig) -> ManifestInfo<'_> {
    let (every, retain) =
        cfg.checkpoint.as_ref().map_or((0, DEFAULT_RETAIN), |s| (s.every, s.retain));
    ManifestInfo {
        chains: cfg.chains,
        base_seed: cfg.base_seed,
        burn_in: cfg.burn_in,
        thin: cfg.thin,
        every,
        retain,
        budget: &cfg.budget,
        shard: cfg.shard,
        kernel: cfg.kernel_label,
        rule: cfg.rule_label,
    }
}

/// [`run_engine_kernel`] with typed launch errors: a resume whose
/// manifest describes a different launch is refused up front, and a
/// `min_chains` quorum loss aborts with `LaunchError::QuorumLost`
/// instead of returning a silently thin report.
#[doc(hidden)]
pub fn run_engine_kernel_result<T, OF, O>(
    kernel: &T,
    init: T::State,
    cfg: &EngineConfig,
    make_observer: OF,
) -> Result<EngineResult<O>, LaunchError>
where
    T: TransitionKernel + Sync,
    T::State: Sync + Persist,
    OF: Fn(usize) -> O + Sync,
    O: ChainObserver<T::State>,
{
    assert!(cfg.chains >= 1, "need at least one chain");
    if let Some(board) = &cfg.board {
        assert_eq!(
            board.chains(),
            cfg.chains,
            "progress board sized for {} chains, launch has {}",
            board.chains(),
            cfg.chains,
        );
    }
    // Resolve the pool BEFORE the launch clock starts: growing the
    // global pool (or none of it, for a pinned pool) is one-time thread
    // construction that must not pollute steps_per_sec / data_per_sec.
    let parallelism = if cfg.threads == 0 { cfg.chains } else { cfg.threads };
    let cap = if cfg.threads == 0 { cfg.chains } else { cfg.threads.min(cfg.chains) };
    let exec = match &cfg.executor {
        Some(e) => Some(e.clone()),
        None if parallelism > 1 => {
            let exec = Executor::global();
            exec.ensure_workers(parallelism - 1);
            Some(exec)
        }
        None => None,
    };
    let intra_w = if cfg.threads > cfg.chains { cfg.threads / cfg.chains } else { 1 };
    let intra = match &exec {
        Some(e) if intra_w > 1 => IntraPar::on(intra_w, e.clone()),
        _ => IntraPar::serial(),
    };
    // Validate the resume directory's manifest BEFORE (re)writing our
    // own: when a launch resumes from its own checkpoint dir, writing
    // first would overwrite the evidence a stale configuration leaves.
    if let Some(dir) = &cfg.resume {
        validate_manifest(cfg.store.as_ref(), dir, &manifest_info(cfg))?;
    }
    if let Some(spec) = &cfg.checkpoint {
        std::fs::create_dir_all(&spec.dir)
            .unwrap_or_else(|e| panic!("cannot create checkpoint dir: {e}"));
        write_manifest(cfg.store.as_ref(), &spec.dir, &manifest_info(cfg))
            .unwrap_or_else(|e| panic!("cannot write checkpoint manifest: {e}"));
    }
    // 0-based index of the step each chain is executing, published before
    // every step — read back for `ChainStatus::Failed` forensics when a
    // chain dies mid-step, and sampled by the stall watchdog.
    let progress: Arc<Vec<AtomicU64>> =
        Arc::new((0..cfg.chains).map(|_| AtomicU64::new(0)).collect());
    let watch = Arc::new(WatchState::new(cfg.chains));
    let watchdog = cfg
        .stall_after
        .map(|window| spawn_watchdog(Arc::clone(&watch), Arc::clone(&progress), window, cfg.min_chains));
    let init = &init;
    let progress_ref = &progress;
    let watch_ref = &watch;
    let intra = &intra;
    let start = std::time::Instant::now();
    let results = parallel_map_result_on(exec.as_ref(), cfg.chains, cap, &|c| {
        watch_ref.started[c].store(true, Ordering::Relaxed);
        let mut attempt = 0usize; // retries burned so far
        let mut restarts = 0usize; // in-run recovery events
        loop {
            let outcome = catch_unwind(AssertUnwindSafe(|| {
                // pool workers are persistent and may carry another
                // chain's stale (chain, step) context — scope this
                // chain's over the attempt
                let _ctx = ScopedChainCtx::enter((c, usize::MAX));
                let mut rng = Pcg64::new(cfg.base_seed, STREAM_BASE + c as u64);
                let mut obs = make_observer(c);
                // a retry prefers this launch's own (fresher) checkpoints
                // over the directory the launch originally resumed from;
                // with neither, the attempt replays from scratch — still
                // bit-identical, just more expensive
                let resume_dir = if attempt > 0 {
                    cfg.checkpoint.as_ref().map(|s| s.dir.as_path()).or(cfg.resume.as_deref())
                } else {
                    cfg.resume.as_deref()
                };
                let resume = resume_dir.and_then(|dir| {
                    load_resume(cfg.store.as_ref(), dir, c, cfg.base_seed, cfg.shard)
                });
                let skipped = resume.as_ref().map_or(0, |r| r.skipped);
                let (samples, stats) = drive_chain_ckpt(
                    kernel,
                    init.clone(),
                    DriveCfg {
                        budget: cfg.budget,
                        burn_in: cfg.burn_in,
                        thin: cfg.thin,
                        intra: intra.clone(),
                        checkpoint: cfg.checkpoint.as_ref().map(|spec| CkptSink {
                            spec,
                            store: &cfg.store,
                            chain: c,
                            base_seed: cfg.base_seed,
                            shard: cfg.shard,
                        }),
                        resume: resume.map(|r| r.ck),
                        progress: Some(&progress_ref[c]),
                        abort: Some(&watch_ref.abort),
                        cancel: cfg.cancel.as_ref().map(|t| t.flag()),
                        board: cfg.board.as_ref().map(|b| (b.as_ref(), c)),
                    },
                    |p| obs.observe(p),
                    &mut rng,
                );
                (ChainRun { chain: c, samples, stats }, obs, skipped)
            }));
            match outcome {
                Ok((run, obs, skipped)) => {
                    watch_ref.retries[c].store((restarts + skipped) as u64, Ordering::Relaxed);
                    watch_ref.done[c].store(true, Ordering::Relaxed);
                    return (run, obs);
                }
                Err(payload) => {
                    if attempt >= cfg.retry.max_retries {
                        watch_ref.retries[c].store(restarts as u64, Ordering::Relaxed);
                        watch_ref.failed[c].store(true, Ordering::Relaxed);
                        // hand the original payload to the task-level
                        // catch: zero-retry launches report exactly the
                        // pre-supervision reason
                        std::panic::resume_unwind(payload);
                    }
                    attempt += 1;
                    restarts += 1;
                    eprintln!(
                        "engine: chain {c} failed ({}); retry {attempt} of {} \
                         from the last good checkpoint",
                        panic_reason(payload.as_ref()),
                        cfg.retry.max_retries,
                    );
                    let nap = cfg.retry.backoff_before(attempt);
                    if !nap.is_zero() {
                        std::thread::sleep(nap);
                    }
                }
            }
        }
    });
    let wall = start.elapsed();
    if let Some(handle) = watchdog {
        watch.stop();
        handle.join().ok();
    }
    let mut statuses = Vec::with_capacity(cfg.chains);
    let mut pairs = Vec::with_capacity(cfg.chains);
    for (c, res) in results.into_iter().enumerate() {
        let retries = watch.retries[c].load(Ordering::Relaxed) as usize;
        match res {
            Ok(pair) => {
                let status = if let Some(step) = watch.first_stall(c) {
                    ChainStatus::Stalled { step: step as usize }
                } else if retries > 0 {
                    ChainStatus::Recovered { retries }
                } else {
                    ChainStatus::Completed
                };
                statuses.push(status);
                pairs.push(pair);
            }
            Err(e) => {
                let step = progress[c].load(Ordering::Relaxed) as usize;
                let reason = if retries > 0 {
                    format!("{} (after {retries} retries)", e.reason)
                } else {
                    e.reason
                };
                statuses.push(ChainStatus::Failed { step, reason });
            }
        }
    }
    if watch.quorum_lost.load(Ordering::Relaxed) {
        return Err(LaunchError::QuorumLost {
            healthy: watch.quorum_healthy.load(Ordering::Relaxed),
            required: watch.quorum_required.load(Ordering::Relaxed),
            failed: statuses.iter().filter(|s| s.is_failed()).count(),
            stalled: statuses.iter().filter(|s| s.is_stalled()).count(),
            chains: cfg.chains,
        });
    }
    Ok(finish(pairs, statuses, wall))
}

/// Internal: run K MH chains of `model` under `mode` — any
/// `AcceptanceTest` (`&MhMode` or a concrete rule) — one observer per
/// chain. This is the uncached launch behind `session::Session`, which
/// is the public front door; kept `pub` (hidden) as the bit-identity
/// oracle for `tests/integration_session.rs`.
#[doc(hidden)]
pub fn run_engine<M, K, T, OF, O>(
    model: &M,
    kernel: &K,
    mode: &T,
    init: M::Param,
    cfg: &EngineConfig,
    make_observer: OF,
) -> EngineResult<O>
where
    M: LlDiffModel + Sync,
    M::Param: Persist,
    K: ProposalKernel<M::Param> + Sync,
    T: AcceptanceTest + Sync,
    OF: Fn(usize) -> O + Sync,
    O: ChainObserver<M::Param>,
{
    run_engine_kernel(&MhKernel { model, proposal: kernel, mode }, init, cfg, make_observer)
}

/// [`run_engine`] with typed launch errors (see
/// [`run_engine_kernel_result`]); the session layer routes through this.
#[doc(hidden)]
pub fn run_engine_result<M, K, T, OF, O>(
    model: &M,
    kernel: &K,
    mode: &T,
    init: M::Param,
    cfg: &EngineConfig,
    make_observer: OF,
) -> Result<EngineResult<O>, LaunchError>
where
    M: LlDiffModel + Sync,
    M::Param: Persist,
    K: ProposalKernel<M::Param> + Sync,
    T: AcceptanceTest + Sync,
    OF: Fn(usize) -> O + Sync,
    O: ChainObserver<M::Param>,
{
    run_engine_kernel_result(&MhKernel { model, proposal: kernel, mode }, init, cfg, make_observer)
}

/// Internal: `run_engine` on the state-caching fast path — each chain
/// owns a model cache (`CachedLlDiff`), halving hot-path FLOPs per
/// decision. `session::Session` selects this path automatically for
/// cached models; kept `pub` (hidden) as the bit-identity oracle.
#[doc(hidden)]
pub fn run_engine_cached<M, K, T, OF, O>(
    model: &M,
    kernel: &K,
    mode: &T,
    init: M::Param,
    cfg: &EngineConfig,
    make_observer: OF,
) -> EngineResult<O>
where
    M: CachedLlDiff + Sync,
    M::Param: Persist,
    K: ProposalKernel<M::Param> + Sync,
    T: AcceptanceTest + Sync,
    OF: Fn(usize) -> O + Sync,
    O: ChainObserver<M::Param>,
{
    run_engine_kernel(
        &CachedMhKernel { model, proposal: kernel, mode },
        init,
        cfg,
        make_observer,
    )
}

/// [`run_engine_cached`] with typed launch errors (see
/// [`run_engine_kernel_result`]); the session layer routes through this.
#[doc(hidden)]
pub fn run_engine_cached_result<M, K, T, OF, O>(
    model: &M,
    kernel: &K,
    mode: &T,
    init: M::Param,
    cfg: &EngineConfig,
    make_observer: OF,
) -> Result<EngineResult<O>, LaunchError>
where
    M: CachedLlDiff + Sync,
    M::Param: Persist,
    K: ProposalKernel<M::Param> + Sync,
    T: AcceptanceTest + Sync,
    OF: Fn(usize) -> O + Sync,
    O: ChainObserver<M::Param>,
{
    run_engine_kernel_result(
        &CachedMhKernel { model, proposal: kernel, mode },
        init,
        cfg,
        make_observer,
    )
}

fn finish<O>(
    pairs: Vec<(ChainRun, O)>,
    statuses: Vec<ChainStatus>,
    wall: std::time::Duration,
) -> EngineResult<O> {
    let mut merged = ChainStats::default();
    for (run, _) in &pairs {
        merged.steps += run.stats.steps;
        merged.accepted += run.stats.accepted;
        merged.data_used += run.stats.data_used;
        merged.guard_trips += run.stats.guard_trips;
        merged.ckpt_failures += run.stats.ckpt_failures;
        merged.wall = merged.wall.max(run.stats.wall);
    }
    let series: Vec<Vec<f64>> = pairs
        .iter()
        .map(|(r, _)| r.samples.iter().map(|s| s.value).collect())
        .collect();
    let mut convergence = cross_chain(&series);
    // a launch degraded below two chains by failures has no meaningful
    // cross-chain mixing estimate (a deliberate K=1 launch is different:
    // split R-hat over one chain's halves is still informative)
    if statuses.iter().any(ChainStatus::is_failed) && pairs.len() < 2 {
        convergence.rhat = f64::NAN;
    }
    let (runs, observers): (Vec<ChainRun>, Vec<O>) = pairs.into_iter().unzip();
    EngineResult { runs, observers, statuses, merged, wall, convergence }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::mh::MhMode;
    use crate::models::traits::Proposal;

    /// 1-d Gaussian posterior split over N identical "datapoints".
    struct GaussTarget {
        n: usize,
    }

    impl LlDiffModel for GaussTarget {
        type Param = f64;

        fn n(&self) -> usize {
            self.n
        }

        fn lldiff(&self, _i: usize, cur: &f64, prop: &f64) -> f64 {
            (0.5 * (cur * cur - prop * prop)) / self.n as f64
        }
    }

    fn rw_kernel(sigma: f64) -> impl Fn(&f64, &mut Pcg64) -> Proposal<f64> + Sync {
        move |cur: &f64, rng: &mut Pcg64| Proposal {
            param: cur + rng.normal_scaled(0.0, sigma),
            log_correction: 0.0,
        }
    }

    #[test]
    fn parallel_map_is_ordered_and_pool_size_invariant() {
        let serial = parallel_map(13, 1, |i| i * i);
        for threads in [0usize, 2, 3, 8] {
            assert_eq!(parallel_map(13, threads, |i| i * i), serial);
        }
        assert_eq!(serial[5], 25);
        assert!(parallel_map(0, 4, |i| i).is_empty());
    }

    #[test]
    fn parallel_map_result_isolates_panics_to_their_slot() {
        for threads in [1usize, 0, 3] {
            let res = parallel_map_result(7, threads, |i| {
                if i == 3 {
                    panic!("boom {i}");
                }
                i * 2
            });
            for (i, r) in res.iter().enumerate() {
                if i == 3 {
                    let e = r.as_ref().expect_err("task 3 must fail");
                    assert_eq!(e.task, 3);
                    assert!(e.reason.contains("boom 3"), "reason: {}", e.reason);
                } else {
                    assert_eq!(*r.as_ref().expect("other tasks survive"), i * 2);
                }
            }
        }
    }

    #[test]
    fn parallel_map_names_the_failing_task() {
        let err = std::panic::catch_unwind(|| {
            parallel_map(4, 2, |i| if i == 1 { panic!("dead") } else { i })
        })
        .expect_err("must propagate");
        let msg = err.downcast_ref::<String>().cloned().unwrap_or_default();
        assert!(msg.contains("task 1"), "msg: {msg}");
        assert!(msg.contains("dead"), "msg: {msg}");
    }

    #[test]
    fn engine_is_deterministic_across_thread_counts() {
        let model = GaussTarget { n: 50 };
        let kernel = rw_kernel(1.0);
        let run = |threads: usize| {
            let cfg = EngineConfig::new(4, 42, Budget::Steps(300))
                .burn_in(20)
                .threads(threads);
            run_engine(&model, &kernel, &MhMode::Exact, 0.0, &cfg, |_c| |p: &f64| *p)
        };
        let a = run(1);
        let b = run(4);
        let c = run(3);
        assert_eq!(a.runs.len(), 4);
        for ((ra, rb), rc) in a.runs.iter().zip(&b.runs).zip(&c.runs) {
            assert_eq!(ra.chain, rb.chain);
            assert_eq!(ra.stats.steps, rb.stats.steps);
            assert_eq!(ra.stats.accepted, rb.stats.accepted);
            let va: Vec<f64> = ra.samples.iter().map(|s| s.value).collect();
            let vb: Vec<f64> = rb.samples.iter().map(|s| s.value).collect();
            let vc: Vec<f64> = rc.samples.iter().map(|s| s.value).collect();
            assert_eq!(va, vb);
            assert_eq!(va, vc);
        }
        // chains explore independently
        assert_ne!(
            a.runs[0].samples.last().unwrap().value,
            a.runs[1].samples.last().unwrap().value
        );
    }

    #[test]
    fn merged_stats_sum_chains() {
        let model = GaussTarget { n: 30 };
        let kernel = rw_kernel(1.0);
        let cfg = EngineConfig::new(3, 7, Budget::Steps(200));
        let res = run_engine(&model, &kernel, &MhMode::Exact, 0.0, &cfg, |_c| |p: &f64| *p);
        assert_eq!(res.merged.steps, 600);
        assert_eq!(res.merged.data_used, 600 * 30);
        let acc_sum: usize = res.runs.iter().map(|r| r.stats.accepted).sum();
        assert_eq!(res.merged.accepted, acc_sum);
        assert!(res.steps_per_sec() > 0.0);
    }

    #[test]
    fn observers_come_back_in_chain_order() {
        struct Counter {
            chain: usize,
            seen: usize,
        }
        impl ChainObserver<f64> for Counter {
            fn observe(&mut self, p: &f64) -> f64 {
                self.seen += 1;
                *p
            }
        }
        let model = GaussTarget { n: 20 };
        let kernel = rw_kernel(1.0);
        let cfg = EngineConfig::new(3, 9, Budget::Steps(100)).burn_in(10).thin(3);
        let res = run_engine(&model, &kernel, &MhMode::Exact, 0.0, &cfg, |c| Counter {
            chain: c,
            seen: 0,
        });
        for (c, (obs, run)) in res.observers.iter().zip(&res.runs).enumerate() {
            assert_eq!(obs.chain, c);
            assert_eq!(run.chain, c);
            assert_eq!(obs.seen, run.samples.len());
            assert_eq!(obs.seen, 30); // (100 - 10) / 3
        }
    }

    #[test]
    fn well_mixed_chains_have_rhat_near_one() {
        let model = GaussTarget { n: 40 };
        let kernel = rw_kernel(1.5);
        let cfg = EngineConfig::new(4, 5, Budget::Steps(20_000)).burn_in(2_000);
        let res = run_engine(&model, &kernel, &MhMode::Exact, 0.0, &cfg, |_c| |p: &f64| *p);
        let rhat = res.convergence.rhat;
        assert!(rhat.is_finite() && (rhat - 1.0).abs() < 0.05, "rhat {rhat}");
        assert!(res.convergence.ess > 100.0, "ess {}", res.convergence.ess);
    }
}

//! Optimal sequential test design (paper §5.2, supp. D): choose the
//! mini-batch size m and the knob epsilon minimizing expected data usage
//! subject to a tolerance on the acceptance-probability error.
//!
//! Two designs:
//!  * average design (Eqn. 7): constrain the average |Delta| over an
//!    empirical distribution of (theta, theta') pairs from a trial run;
//!  * worst-case design (Eqn. 8): constrain E(0, m, eps), the worst-case
//!    single-test error (conservative — no trial run needed).

use crate::coordinator::delta::{delta_accept_prob, expected_data_usage, PairStats, SeqTestTable};
use crate::coordinator::dp::analyze_pocock;

/// Candidate grid for the search.
#[derive(Clone, Debug)]
pub struct DesignGrid {
    pub m_grid: Vec<usize>,
    pub eps_grid: Vec<f64>,
    /// DP density cells.
    pub dp_grid: usize,
    /// mu_std table nodes and extent.
    pub table_points: usize,
    pub mu_max: f64,
    /// quadrature panels per side for Delta / usage integrals.
    pub panels: usize,
}

impl Default for DesignGrid {
    fn default() -> Self {
        DesignGrid {
            m_grid: vec![100, 200, 400, 600, 1000, 2000, 5000],
            eps_grid: vec![1e-4, 5e-4, 0.001, 0.005, 0.01, 0.02, 0.05, 0.1, 0.2],
            dp_grid: 96,
            table_points: 21,
            mu_max: 12.0,
            panels: 16,
        }
    }
}

/// A chosen configuration and its predicted performance.
#[derive(Clone, Copy, Debug)]
pub struct DesignChoice {
    pub m: usize,
    pub eps: f64,
    /// predicted average data usage (fraction of N)
    pub data_usage: f64,
    /// predicted error (avg |Delta| for average design, E(0) for worst)
    pub error: f64,
}

/// Worst-case design (Eqn. 8): min pi_bar(0) s.t. E(0) <= tol.
pub fn worst_case_design(n: usize, tol: f64, grid: &DesignGrid) -> Option<DesignChoice> {
    let mut best: Option<DesignChoice> = None;
    for &m in &grid.m_grid {
        for &eps in &grid.eps_grid {
            let a = analyze_pocock(0.0, m, n, eps, grid.dp_grid);
            if a.error > tol {
                continue;
            }
            let cand = DesignChoice { m, eps, data_usage: a.expected_pi, error: a.error };
            if best.map_or(true, |b| cand.data_usage < b.data_usage) {
                best = Some(cand);
            }
        }
    }
    best
}

/// Predicted average performance of one (m, eps) cell over a training set
/// of pair statistics: (avg |Delta|, avg E_u[pi_bar]).
pub fn evaluate_design(
    n: usize,
    train: &[PairStats],
    m: usize,
    eps: f64,
    grid: &DesignGrid,
) -> (f64, f64) {
    let table = SeqTestTable::build(m, n, eps, grid.mu_max, grid.table_points, grid.dp_grid);
    evaluate_with_table(n, train, &table, grid.panels)
}

/// Same, reusing a prebuilt table.
pub fn evaluate_with_table(
    n: usize,
    train: &[PairStats],
    table: &SeqTestTable,
    panels: usize,
) -> (f64, f64) {
    assert!(!train.is_empty());
    let mut sum_abs_delta = 0.0;
    let mut sum_usage = 0.0;
    for p in train {
        sum_abs_delta += delta_accept_prob(n, p, table, panels).abs();
        sum_usage += expected_data_usage(n, p, table, panels);
    }
    let k = train.len() as f64;
    (sum_abs_delta / k, sum_usage / k)
}

/// Average design (Eqn. 7): min avg E_u[pi_bar] s.t. avg |Delta| <= tol,
/// over the empirical (theta, theta') distribution in `train`.
pub fn average_design(
    n: usize,
    train: &[PairStats],
    tol: f64,
    grid: &DesignGrid,
) -> Option<DesignChoice> {
    let mut best: Option<DesignChoice> = None;
    for &m in &grid.m_grid {
        for &eps in &grid.eps_grid {
            let (avg_delta, avg_usage) = evaluate_design(n, train, m, eps, grid);
            if avg_delta > tol {
                continue;
            }
            let cand = DesignChoice { m, eps, data_usage: avg_usage, error: avg_delta };
            if best.map_or(true, |b| cand.data_usage < b.data_usage) {
                best = Some(cand);
            }
        }
    }
    best
}

/// Wang-Tsiatis generalized-bound design (supp. D): search over the
/// batch size m, the base bound G0 and the shape exponent delta in
/// G_j = G0 * pi_j^delta (delta = 0 Pocock, -0.5 O'Brien-Fleming),
/// minimizing average data usage subject to avg |Delta| <= tol.
#[derive(Clone, Copy, Debug)]
pub struct WtChoice {
    pub m: usize,
    pub g0: f64,
    pub delta_exp: f64,
    pub data_usage: f64,
    pub error: f64,
}

pub fn wang_tsiatis_design(
    n: usize,
    train: &[PairStats],
    tol: f64,
    grid: &DesignGrid,
    g0_grid: &[f64],
    delta_grid: &[f64],
) -> Option<WtChoice> {
    let mut best: Option<WtChoice> = None;
    for &m in &grid.m_grid {
        let pis = crate::coordinator::dp::uniform_pis(m, n);
        if pis.len() < 2 {
            continue;
        }
        for &g0 in g0_grid {
            for &de in delta_grid {
                let bounds: Vec<f64> =
                    pis[..pis.len() - 1].iter().map(|&p| g0 * p.powf(de)).collect();
                let table = SeqTestTable::build_with_bounds(
                    &pis,
                    &bounds,
                    grid.mu_max,
                    grid.table_points,
                    grid.dp_grid,
                );
                let (err, usage) = evaluate_with_table(n, train, &table, grid.panels);
                if err > tol {
                    continue;
                }
                let cand = WtChoice { m, g0, delta_exp: de, data_usage: usage, error: err };
                if best.map_or(true, |b| cand.data_usage < b.data_usage) {
                    best = Some(cand);
                }
            }
        }
    }
    best
}

/// Average design with m fixed (the §5.2 heuristic, Fig. 6 triangles).
pub fn fixed_m_design(
    n: usize,
    train: &[PairStats],
    m: usize,
    tol: f64,
    grid: &DesignGrid,
) -> Option<DesignChoice> {
    let sub = DesignGrid { m_grid: vec![m], ..grid.clone() };
    average_design(n, train, tol, &sub)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_grid() -> DesignGrid {
        DesignGrid {
            m_grid: vec![200, 500, 1000],
            eps_grid: vec![0.001, 0.005, 0.01, 0.05, 0.1],
            dp_grid: 64,
            table_points: 13,
            mu_max: 10.0,
            panels: 8,
        }
    }

    fn train_set() -> Vec<PairStats> {
        // mostly-decisive pairs (|mu_std| >> 1) plus one ambiguous one,
        // the mix a real trial run produces (N = 10^4, sigma_l/sqrt(N) = 0.01)
        vec![
            PairStats { mu: 0.05, sigma_l: 1.0, log_correction: 0.0 },
            PairStats { mu: -0.04, sigma_l: 0.8, log_correction: 0.5 },
            PairStats { mu: 3e-3, sigma_l: 1.0, log_correction: -0.2 },
            PairStats { mu: 0.0, sigma_l: 1.2, log_correction: 0.0 },
        ]
    }

    #[test]
    fn worst_case_design_meets_tolerance() {
        let g = small_grid();
        let d = worst_case_design(10_000, 0.05, &g).expect("feasible");
        let a = analyze_pocock(0.0, d.m, 10_000, d.eps, g.dp_grid);
        assert!(a.error <= 0.05 + 1e-9);
        assert!((a.expected_pi - d.data_usage).abs() < 1e-12);
    }

    #[test]
    fn worst_case_infeasible_returns_none() {
        let g = small_grid();
        // an impossible tolerance with a loose eps grid
        let d = worst_case_design(10_000, 1e-12, &g);
        assert!(d.is_none());
    }

    #[test]
    fn looser_tolerance_uses_less_data() {
        let g = small_grid();
        let tight = worst_case_design(10_000, 0.01, &g).unwrap();
        let loose = worst_case_design(10_000, 0.2, &g).unwrap();
        assert!(loose.data_usage <= tight.data_usage + 1e-12);
    }

    #[test]
    fn average_design_beats_worst_case_usage() {
        // The central claim of Fig. 6(b): for the same tolerance the
        // average design consumes less data.
        let g = small_grid();
        let n = 10_000;
        let train = train_set();
        let avg = average_design(n, &train, 0.03, &g).expect("avg feasible");
        let worst = worst_case_design(n, 0.03, &g).expect("worst feasible");
        let (_, worst_usage) = evaluate_design(n, &train, worst.m, worst.eps, &g);
        assert!(
            avg.data_usage <= worst_usage + 1e-9,
            "avg {} vs worst-projected {}",
            avg.data_usage,
            worst_usage
        );
    }

    #[test]
    fn average_design_constraint_active() {
        let g = small_grid();
        let d = average_design(10_000, &train_set(), 0.06, &g).unwrap();
        assert!(d.error <= 0.06 + 1e-9);
    }

    #[test]
    fn wang_tsiatis_design_at_least_as_good_as_pocock() {
        // The WT family contains Pocock (delta = 0), so the generalized
        // search can only improve on the eps-grid-matched Pocock choice.
        let g = small_grid();
        let n = 10_000;
        let train = train_set();
        let pocock = average_design(n, &train, 0.03, &g);
        let wt = wang_tsiatis_design(
            n,
            &train,
            0.03,
            &g,
            &[1.5, 2.0, 2.5, 3.0],
            &[0.0, -0.25, -0.5],
        );
        let wt = wt.expect("wt feasible");
        assert!(wt.error <= 0.03 + 1e-9);
        if let Some(p) = pocock {
            // generous slack: the grids are different discretizations
            assert!(
                wt.data_usage <= p.data_usage + 0.1,
                "wt {} vs pocock {}",
                wt.data_usage,
                p.data_usage
            );
        }
    }

    #[test]
    fn fixed_m_is_feasible_subset() {
        let g = small_grid();
        let n = 10_000;
        let train = train_set();
        let free = average_design(n, &train, 0.05, &g).unwrap();
        if let Some(fixed) = fixed_m_design(n, &train, 500, 0.05, &g) {
            assert_eq!(fixed.m, 500);
            // the free search can only do at least as well
            assert!(free.data_usage <= fixed.data_usage + 1e-9);
        }
    }
}

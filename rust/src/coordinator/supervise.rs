//! Chain supervision: retry policies, the stall watchdog, and quorum
//! degradation — the layer that turns per-chain fault *reporting* (PR 6)
//! into fault *recovery*.
//!
//! **State machine.** Each chain moves through
//! `Running → (Failed | Stalled) → Recovering → (Recovered | Failed)`:
//!
//! * a worker panic (scripted fault, `GuardPolicy::Abort`, or a genuine
//!   bug) is caught at the task boundary; under a [`RetryPolicy`] the
//!   engine restarts the chain from its newest loadable checkpoint
//!   generation (or from scratch when the launch is not checkpointing),
//!   sleeping a linearly-growing backoff between attempts;
//! * because a checkpoint captures the PCG stream position and the
//!   scheduler scratch exactly, the replay is **bit-identical**: a chain
//!   that failed once and recovered produces the same draws as one that
//!   never failed (`ChainStatus::Recovered` records how many recovery
//!   events it took);
//! * a chain whose step counter has not advanced within `stall_after`
//!   is flagged `Stalled` by the watchdog thread (built on the engine's
//!   existing per-chain progress counters — zero new dependencies);
//! * when the healthy fraction drops below the `min_chains` quorum, the
//!   watchdog raises the abort flag: responsive chains stop at their
//!   next step boundary and the launch returns
//!   [`LaunchError::QuorumLost`] instead of a silently thin report.
//!
//! **Honesty note.** Rust cannot preempt a thread, so a truly hung step
//! (a deadlocked scan, an infinite loop in a likelihood) is *detected*
//! and *reported*, and the rest of the launch degrades or aborts around
//! it — but the hung worker itself only exits with the process. The
//! watchdog's job is to make sure nobody waits on it forever.
//!
//! Observer caveat: observers are not checkpointed, so a recovered (or
//! resumed) chain's observer sees only post-recovery samples. The
//! recorded draws themselves are exact.

use std::fmt;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::coordinator::checkpoint::CkptError;

/// How many times a failed chain is restarted from its last good
/// checkpoint, and how long to wait between attempts (the sleep grows
/// linearly: `backoff`, `2 * backoff`, ...). The default policy retries
/// nothing — failures surface as `ChainStatus::Failed`, exactly the
/// pre-supervision behavior.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct RetryPolicy {
    /// Restart attempts per chain after its first failure.
    pub max_retries: usize,
    /// Base sleep before each restart (linear backoff; zero = retry
    /// immediately).
    pub backoff: Duration,
}

impl RetryPolicy {
    /// No retries: a failed chain stays failed (the default).
    pub fn none() -> Self {
        RetryPolicy { max_retries: 0, backoff: Duration::ZERO }
    }

    /// Retry up to `max_retries` times with no backoff.
    pub fn retries(max_retries: usize) -> Self {
        RetryPolicy { max_retries, backoff: Duration::ZERO }
    }

    pub fn new(max_retries: usize, backoff: Duration) -> Self {
        RetryPolicy { max_retries, backoff }
    }

    /// Sleep before retry attempt `attempt` (1-based).
    pub(crate) fn backoff_before(&self, attempt: usize) -> Duration {
        self.backoff * attempt.min(u32::MAX as usize) as u32
    }
}

impl Default for RetryPolicy {
    fn default() -> Self {
        Self::none()
    }
}

/// Cooperative cancellation handle for a launch. The caller keeps one
/// clone and hands another to the engine
/// (`Session::cancel_token` / `EngineConfig::cancel`); raising it asks
/// every chain to stop at its next step boundary — the same poll point
/// the watchdog's abort uses — so a cancelled launch returns cleanly
/// with everything sampled so far. Unlike an abort, a cancel also
/// flushes a final checkpoint generation when the launch is
/// checkpointing, so a cancelled job can later `--resume` to
/// completion. Cancellation is one-way and idempotent: there is no
/// un-cancel, and raising the token twice is harmless.
#[derive(Clone, Debug, Default)]
pub struct CancelToken {
    flag: Arc<AtomicBool>,
}

impl CancelToken {
    pub fn new() -> Self {
        Self::default()
    }

    /// Ask every chain holding this token to stop at its next step
    /// boundary.
    pub fn cancel(&self) {
        self.flag.store(true, Ordering::Relaxed);
    }

    pub fn is_cancelled(&self) -> bool {
        self.flag.load(Ordering::Relaxed)
    }

    /// The raw flag the chain driver polls.
    pub(crate) fn flag(&self) -> &AtomicBool {
        &self.flag
    }
}

/// Live per-chain progress counters published after every completed
/// step (`Session::progress_board` / `EngineConfig::board`): steps
/// done, proposals accepted, datapoint evaluations consumed. The serve
/// layer polls [`ProgressBoard::snapshot`] to answer `GET /jobs/:id`
/// without touching the chains; readers see values at most one step
/// stale (plain relaxed atomics — no locks on the hot path).
#[derive(Debug, Default)]
pub struct ProgressBoard {
    steps: Vec<AtomicU64>,
    accepted: Vec<AtomicU64>,
    data_used: Vec<AtomicU64>,
}

impl ProgressBoard {
    /// A board with one lane per chain, all counters zero.
    pub fn new(chains: usize) -> Self {
        ProgressBoard {
            steps: (0..chains).map(|_| AtomicU64::new(0)).collect(),
            accepted: (0..chains).map(|_| AtomicU64::new(0)).collect(),
            data_used: (0..chains).map(|_| AtomicU64::new(0)).collect(),
        }
    }

    /// Number of chain lanes (must match the launch's `chains`).
    pub fn chains(&self) -> usize {
        self.steps.len()
    }

    /// Publish chain `c`'s running totals (called by the chain driver
    /// after every step).
    pub(crate) fn publish(&self, c: usize, steps: u64, accepted: u64, data_used: u64) {
        self.steps[c].store(steps, Ordering::Relaxed);
        self.accepted[c].store(accepted, Ordering::Relaxed);
        self.data_used[c].store(data_used, Ordering::Relaxed);
    }

    /// Point-in-time copy of every lane.
    pub fn snapshot(&self) -> ProgressSnapshot {
        ProgressSnapshot {
            steps: self.steps.iter().map(|a| a.load(Ordering::Relaxed)).collect(),
            accepted: self.accepted.iter().map(|a| a.load(Ordering::Relaxed)).collect(),
            data_used: self.data_used.iter().map(|a| a.load(Ordering::Relaxed)).collect(),
        }
    }
}

/// Point-in-time copy of a [`ProgressBoard`].
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct ProgressSnapshot {
    /// Steps completed, per chain.
    pub steps: Vec<u64>,
    /// Proposals accepted, per chain.
    pub accepted: Vec<u64>,
    /// Datapoint likelihood evaluations consumed, per chain.
    pub data_used: Vec<u64>,
}

impl ProgressSnapshot {
    pub fn total_steps(&self) -> u64 {
        self.steps.iter().sum()
    }

    pub fn total_accepted(&self) -> u64 {
        self.accepted.iter().sum()
    }

    pub fn total_data_used(&self) -> u64 {
        self.data_used.iter().sum()
    }

    /// Pooled acceptance rate so far (zero before any step completes).
    pub fn acceptance_rate(&self) -> f64 {
        let steps = self.total_steps();
        if steps == 0 {
            0.0
        } else {
            self.total_accepted() as f64 / steps as f64
        }
    }
}

/// Why a supervised launch could not produce a report.
#[derive(Debug)]
pub enum LaunchError {
    /// The checkpoint directory refused the resume (manifest describes
    /// a different launch, or every generation of a chain is corrupt).
    Resume(CkptError),
    /// The stall watchdog saw the healthy-chain count drop below the
    /// `min_chains` quorum and aborted the launch.
    QuorumLost {
        /// Chains still advancing when the quorum check failed.
        healthy: usize,
        /// `ceil(min_chains * chains)` — the healthy count required.
        required: usize,
        /// Chains down with exhausted retries at abort time.
        failed: usize,
        /// Chains flagged by the stall watchdog at abort time.
        stalled: usize,
        /// Total chains in the launch.
        chains: usize,
    },
}

impl fmt::Display for LaunchError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            LaunchError::Resume(e) => write!(f, "resume refused: {e}"),
            LaunchError::QuorumLost { healthy, required, failed, stalled, chains } => write!(
                f,
                "quorum lost: only {healthy} of {chains} chains healthy \
                 (required {required}; {failed} failed, {stalled} stalled)"
            ),
        }
    }
}

impl std::error::Error for LaunchError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            LaunchError::Resume(e) => Some(e),
            LaunchError::QuorumLost { .. } => None,
        }
    }
}

impl From<CkptError> for LaunchError {
    fn from(e: CkptError) -> Self {
        LaunchError::Resume(e)
    }
}

/// The healthy-chain count a `min_chains` fraction demands of a launch
/// (`0` disables the quorum entirely).
pub(crate) fn required_quorum(min_chains: f64, chains: usize) -> usize {
    if min_chains <= 0.0 || chains == 0 {
        0
    } else {
        ((min_chains * chains as f64).ceil() as usize).min(chains)
    }
}

/// Sentinel for "this chain never stalled" in [`WatchState::stalled_at`].
pub(crate) const NEVER_STALLED: u64 = u64::MAX;

/// Shared supervision scoreboard: chain tasks publish lifecycle flags,
/// the watchdog publishes stall verdicts and the abort signal, and the
/// engine reads everything back when assembling statuses. All fields are
/// plain atomics — lock-free on both sides.
#[derive(Debug)]
pub(crate) struct WatchState {
    /// Chain task entered (distinguishes "queued behind the worker cap"
    /// from "started and not advancing" — only started chains can stall).
    pub started: Vec<AtomicBool>,
    /// Chain task returned successfully.
    pub done: Vec<AtomicBool>,
    /// Chain task failed with retries exhausted.
    pub failed: Vec<AtomicBool>,
    /// Watchdog's *current* verdict (clears if the chain advances again).
    pub stalled_now: Vec<AtomicBool>,
    /// Step at which the chain was first flagged stalled; sticky
    /// ([`NEVER_STALLED`] until then) — a stall is reported even if the
    /// chain later limps to completion.
    pub stalled_at: Vec<AtomicU64>,
    /// Recovery events per chain: in-run restarts plus checkpoint
    /// generations skipped at load time.
    pub retries: Vec<AtomicU64>,
    /// Raised by the watchdog on quorum loss; responsive chains stop at
    /// their next step boundary.
    pub abort: AtomicBool,
    /// Set together with `abort` — tells the engine the launch must
    /// return [`LaunchError::QuorumLost`].
    pub quorum_lost: AtomicBool,
    pub quorum_healthy: AtomicUsize,
    pub quorum_required: AtomicUsize,
    stop: AtomicBool,
}

impl WatchState {
    pub fn new(chains: usize) -> Self {
        WatchState {
            started: (0..chains).map(|_| AtomicBool::new(false)).collect(),
            done: (0..chains).map(|_| AtomicBool::new(false)).collect(),
            failed: (0..chains).map(|_| AtomicBool::new(false)).collect(),
            stalled_now: (0..chains).map(|_| AtomicBool::new(false)).collect(),
            stalled_at: (0..chains).map(|_| AtomicU64::new(NEVER_STALLED)).collect(),
            retries: (0..chains).map(|_| AtomicU64::new(0)).collect(),
            abort: AtomicBool::new(false),
            quorum_lost: AtomicBool::new(false),
            quorum_healthy: AtomicUsize::new(0),
            quorum_required: AtomicUsize::new(0),
            stop: AtomicBool::new(false),
        }
    }

    /// Tell the watchdog to exit at its next tick.
    pub fn stop(&self) {
        self.stop.store(true, Ordering::Relaxed);
    }

    fn stopped(&self) -> bool {
        self.stop.load(Ordering::Relaxed)
    }

    /// The step at which chain `c` first stalled, if it ever did.
    pub fn first_stall(&self, c: usize) -> Option<u64> {
        match self.stalled_at[c].load(Ordering::Relaxed) {
            NEVER_STALLED => None,
            step => Some(step),
        }
    }
}

/// Start the stall watchdog: samples the per-chain progress counters at
/// a fraction of `stall_after`, flags chains that stop advancing, and
/// aborts the launch when the healthy count drops below the quorum.
pub(crate) fn spawn_watchdog(
    watch: Arc<WatchState>,
    progress: Arc<Vec<AtomicU64>>,
    stall_after: Duration,
    min_chains: f64,
) -> JoinHandle<()> {
    std::thread::Builder::new()
        .name("austerity-watchdog".into())
        .spawn(move || {
            let chains = progress.len();
            let tick =
                (stall_after / 8).clamp(Duration::from_millis(1), Duration::from_millis(200));
            let required = required_quorum(min_chains, chains);
            let mut last_step = vec![NEVER_STALLED; chains];
            let mut last_change = vec![Instant::now(); chains];
            while !watch.stopped() {
                std::thread::sleep(tick);
                if watch.stopped() {
                    return;
                }
                let now = Instant::now();
                for c in 0..chains {
                    let live = watch.started[c].load(Ordering::Relaxed)
                        && !watch.done[c].load(Ordering::Relaxed)
                        && !watch.failed[c].load(Ordering::Relaxed);
                    if !live {
                        // queued, finished, or failed chains are not
                        // "stalled"; keep their clocks fresh so a chain
                        // that starts (or retries) late gets a full
                        // stall_after window
                        watch.stalled_now[c].store(false, Ordering::Relaxed);
                        last_change[c] = now;
                        continue;
                    }
                    let step = progress[c].load(Ordering::Relaxed);
                    if step != last_step[c] {
                        last_step[c] = step;
                        last_change[c] = now;
                        watch.stalled_now[c].store(false, Ordering::Relaxed);
                    } else if now.duration_since(last_change[c]) >= stall_after {
                        if !watch.stalled_now[c].swap(true, Ordering::Relaxed) {
                            // sticky first-stall step for forensics
                            let _ = watch.stalled_at[c].compare_exchange(
                                NEVER_STALLED,
                                step,
                                Ordering::Relaxed,
                                Ordering::Relaxed,
                            );
                        }
                    }
                }
                if required > 0 {
                    let mut healthy = 0usize;
                    let mut failed = 0usize;
                    for c in 0..chains {
                        if watch.failed[c].load(Ordering::Relaxed) {
                            failed += 1;
                        } else if !watch.stalled_now[c].load(Ordering::Relaxed) {
                            healthy += 1;
                        }
                    }
                    if healthy < required {
                        watch.quorum_healthy.store(healthy, Ordering::Relaxed);
                        watch.quorum_required.store(required, Ordering::Relaxed);
                        watch.quorum_lost.store(true, Ordering::Relaxed);
                        watch.abort.store(true, Ordering::Relaxed);
                        return;
                    }
                }
            }
        })
        .expect("spawn the stall-watchdog thread")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quorum_arithmetic() {
        assert_eq!(required_quorum(0.0, 8), 0);
        assert_eq!(required_quorum(-1.0, 8), 0);
        assert_eq!(required_quorum(0.5, 8), 4);
        assert_eq!(required_quorum(0.5, 7), 4); // ceil
        assert_eq!(required_quorum(1.0, 3), 3);
        assert_eq!(required_quorum(2.0, 3), 3); // clamped
        assert_eq!(required_quorum(0.01, 4), 1);
        assert_eq!(required_quorum(1.0, 0), 0);
    }

    #[test]
    fn retry_backoff_grows_linearly() {
        let p = RetryPolicy::new(3, Duration::from_millis(10));
        assert_eq!(p.backoff_before(1), Duration::from_millis(10));
        assert_eq!(p.backoff_before(3), Duration::from_millis(30));
        assert_eq!(RetryPolicy::retries(2).backoff_before(2), Duration::ZERO);
        assert_eq!(RetryPolicy::default(), RetryPolicy::none());
    }

    #[test]
    fn cancel_token_is_shared_and_idempotent() {
        let tok = CancelToken::new();
        let peer = tok.clone();
        assert!(!tok.is_cancelled());
        peer.cancel();
        assert!(tok.is_cancelled(), "clone raises the shared flag");
        peer.cancel(); // idempotent
        assert!(tok.is_cancelled());
        assert!(tok.flag().load(Ordering::Relaxed));
        assert!(!CancelToken::default().is_cancelled());
    }

    #[test]
    fn progress_board_snapshots_published_lanes() {
        let board = ProgressBoard::new(3);
        assert_eq!(board.chains(), 3);
        assert_eq!(board.snapshot(), ProgressSnapshot::default_for(3));
        board.publish(0, 10, 4, 1000);
        board.publish(2, 7, 7, 350);
        let snap = board.snapshot();
        assert_eq!(snap.steps, vec![10, 0, 7]);
        assert_eq!(snap.accepted, vec![4, 0, 7]);
        assert_eq!(snap.data_used, vec![1000, 0, 350]);
        assert_eq!(snap.total_steps(), 17);
        assert_eq!(snap.total_accepted(), 11);
        assert_eq!(snap.total_data_used(), 1350);
        assert!((snap.acceptance_rate() - 11.0 / 17.0).abs() < 1e-15);
        assert_eq!(ProgressSnapshot::default().acceptance_rate(), 0.0);
    }

    impl ProgressSnapshot {
        fn default_for(chains: usize) -> Self {
            ProgressSnapshot {
                steps: vec![0; chains],
                accepted: vec![0; chains],
                data_used: vec![0; chains],
            }
        }
    }

    #[test]
    fn watchdog_flags_a_frozen_chain_and_clears_a_moving_one() {
        let watch = Arc::new(WatchState::new(2));
        let progress: Arc<Vec<AtomicU64>> = Arc::new((0..2).map(|_| AtomicU64::new(0)).collect());
        for c in 0..2 {
            watch.started[c].store(true, Ordering::Relaxed);
        }
        let handle = spawn_watchdog(
            Arc::clone(&watch),
            Arc::clone(&progress),
            Duration::from_millis(40),
            0.0,
        );
        // chain 0 advances every few ms; chain 1 freezes at step 5
        progress[1].store(5, Ordering::Relaxed);
        for i in 0..40u64 {
            progress[0].store(i, Ordering::Relaxed);
            std::thread::sleep(Duration::from_millis(5));
        }
        assert!(!watch.stalled_now[0].load(Ordering::Relaxed), "moving chain flagged");
        assert!(watch.stalled_now[1].load(Ordering::Relaxed), "frozen chain not flagged");
        assert_eq!(watch.first_stall(1), Some(5));
        // the frozen chain wakes up: the live verdict clears, the
        // sticky first-stall record survives
        for i in 6..30u64 {
            progress[1].store(i, Ordering::Relaxed);
            std::thread::sleep(Duration::from_millis(5));
        }
        assert!(!watch.stalled_now[1].load(Ordering::Relaxed), "recovered chain still flagged");
        assert_eq!(watch.first_stall(1), Some(5));
        assert!(!watch.quorum_lost.load(Ordering::Relaxed), "no quorum configured");
        watch.stop();
        handle.join().unwrap();
    }

    #[test]
    fn watchdog_aborts_on_quorum_loss() {
        let watch = Arc::new(WatchState::new(2));
        let progress: Arc<Vec<AtomicU64>> = Arc::new((0..2).map(|_| AtomicU64::new(0)).collect());
        for c in 0..2 {
            watch.started[c].store(true, Ordering::Relaxed);
        }
        // both chains frozen, quorum demands both healthy
        let handle = spawn_watchdog(
            Arc::clone(&watch),
            Arc::clone(&progress),
            Duration::from_millis(20),
            1.0,
        );
        let deadline = Instant::now() + Duration::from_secs(5);
        while !watch.quorum_lost.load(Ordering::Relaxed) && Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(5));
        }
        assert!(watch.quorum_lost.load(Ordering::Relaxed), "quorum loss not detected");
        assert!(watch.abort.load(Ordering::Relaxed), "abort flag not raised");
        assert!(watch.quorum_healthy.load(Ordering::Relaxed) < 2);
        assert_eq!(watch.quorum_required.load(Ordering::Relaxed), 2);
        handle.join().unwrap(); // the watchdog exits by itself on abort
        watch.stop();
    }
}

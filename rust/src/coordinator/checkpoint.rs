//! Chain checkpoint/resume: versioned snapshots with bit-identical replay.
//!
//! A [`ChainCheckpoint`] captures everything a chain needs to continue as
//! if it had never stopped: the kernel `State`, the PCG64 stream position,
//! the kernel's cross-step scratch (minibatch-scheduler permutations,
//! annealing counters — serialized through
//! `TransitionKernel::save_scratch`), the budget consumed so far and the
//! samples recorded so far. Everything except wall-clock time is exact,
//! so a resumed chain produces draws, acceptance counters and data
//! accounting bit-identical to an uninterrupted same-seed run (`Wall`
//! budgets terminate at a timing-dependent step and are therefore the one
//! budget kind without a bit-identity guarantee).
//!
//! **Format (v3).** One file per chain *generation*,
//! `chain-<c>.g<g>.ckpt`, in a compact little-endian binary framing
//! ([`BinWriter`]/[`BinReader`]) headed by a magic word and a format
//! version and sealed by a CRC32 (IEEE) trailer over everything before
//! it; unknown versions are rejected, never reinterpreted, and a payload
//! whose trailer does not match is [`CkptError::Corrupt`] — a single
//! flipped bit cannot replay as a subtly different chain. Writers rotate
//! generations (`1, 2, 3, ...`) and prune to the newest
//! [`CheckpointSpec::retain`]; [`ChainCheckpoint::load_latest`] walks the
//! surviving generations newest-first and silently falls back past
//! torn/corrupt/short files, so one bad write costs `every` steps of
//! replay, not the whole resume. All file traffic goes through a
//! [`StoreLayer`] (atomic temp-file + rename writes by default) so the
//! fault-injection testkit can script torn writes, bit flips, short
//! reads, and ENOSPC at exact (chain, generation) points. A
//! human-readable `manifest.json` (hand-rolled writer, same dialect as
//! `RunReport::to_json`) records the launch configuration; on resume the
//! engine cross-checks it ([`validate_manifest`]) so a checkpoint
//! directory cannot be silently adopted by a different configuration,
//! model, or acceptance rule.
//!
//! The cached MH path deliberately does **not** serialize its per-datapoint
//! cache: `CachedLlDiff::init_cache` rebuilds it from the restored state,
//! and the cached-vs-uncached bit-identity contract makes the rebuilt
//! cache equivalent to the persisted one at a fraction of the disk cost.

use std::fmt;
use std::fs;
use std::io::Write as _;
use std::path::{Path, PathBuf};
use std::sync::Arc;

use crate::coordinator::chain::{Budget, Sample};

/// File magic of a chain checkpoint ("AUCK" little-endian).
pub const CKPT_MAGIC: u32 = 0x4b43_5541;
/// Current checkpoint format version. v2 added the shard stamp
/// (index/count/row range) to the header; v3 added the generation
/// counter and the CRC32 integrity trailer. Older versions are rejected
/// with [`CkptError::Version`] rather than silently reinterpreted — a
/// pre-v3 file has no trailer, so "adopting" it would mean trusting
/// unverified bytes.
pub const CKPT_VERSION: u32 = 3;

/// How many checkpoint generations each chain keeps by default: the
/// newest plus one fallback for torn-write recovery.
pub const DEFAULT_RETAIN: usize = 2;

// ---------------------------------------------------------------------------
// CRC32 (IEEE 802.3, reflected) — table built at compile time, zero deps.

const fn crc32_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 { 0xedb8_8320 ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
}

static CRC32_TABLE: [u32; 256] = crc32_table();

/// CRC32 (IEEE) of `bytes` — the checksum sealed into every v3
/// checkpoint trailer.
pub fn crc32(bytes: &[u8]) -> u32 {
    let mut c = 0xffff_ffffu32;
    for &b in bytes {
        c = CRC32_TABLE[((c ^ b as u32) & 0xff) as usize] ^ (c >> 8);
    }
    !c
}

// ---------------------------------------------------------------------------
// Errors

/// Why a checkpoint could not be written or restored.
#[derive(Debug)]
pub enum CkptError {
    Io(std::io::Error),
    /// Truncated or malformed payload.
    Corrupt(&'static str),
    /// A checkpoint from an unknown format version.
    Version { found: u32 },
    /// A structurally valid checkpoint that does not match the run
    /// (wrong chain id, seed, or model size).
    Mismatch(String),
    /// The checkpoint directory's `manifest.json` describes a different
    /// launch (chains, seed, budget kind, shard layout, kernel, or rule)
    /// than the one trying to resume from it.
    ManifestMismatch(String),
}

impl fmt::Display for CkptError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            CkptError::Io(e) => write!(f, "checkpoint I/O error: {e}"),
            CkptError::Corrupt(what) => write!(f, "corrupt checkpoint: {what}"),
            CkptError::Version { found } => {
                write!(f, "unsupported checkpoint version {found} (expected {CKPT_VERSION})")
            }
            CkptError::Mismatch(what) => write!(f, "checkpoint mismatch: {what}"),
            CkptError::ManifestMismatch(what) => {
                write!(f, "checkpoint manifest mismatch: {what}")
            }
        }
    }
}

impl std::error::Error for CkptError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            CkptError::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for CkptError {
    fn from(e: std::io::Error) -> Self {
        CkptError::Io(e)
    }
}

// ---------------------------------------------------------------------------
// Store layer

/// Byte-level access to the checkpoint directory. Production uses
/// [`FsStore`] (plain filesystem with atomic temp-file + rename writes);
/// the fault-injection testkit wraps it to script torn writes, bit
/// flips, short reads, and ENOSPC at exact (chain, generation) points —
/// mirroring how `FaultyModel` scripts compute faults.
pub trait StoreLayer: Send + Sync + fmt::Debug {
    /// Read the whole file at `path`.
    fn read(&self, path: &Path) -> std::io::Result<Vec<u8>>;
    /// Write `bytes` to `path` atomically (the previous content of
    /// `path`, if any, must survive an interrupted write).
    fn write_atomic(&self, path: &Path, bytes: &[u8]) -> std::io::Result<()>;
    /// Remove the file at `path` (used when pruning old generations).
    fn remove(&self, path: &Path) -> std::io::Result<()>;
}

/// The production [`StoreLayer`]: plain filesystem access with
/// temp-file + `rename` atomicity and `sync_all` before the rename.
#[derive(Clone, Copy, Debug, Default)]
pub struct FsStore;

impl StoreLayer for FsStore {
    fn read(&self, path: &Path) -> std::io::Result<Vec<u8>> {
        fs::read(path)
    }

    fn write_atomic(&self, path: &Path, bytes: &[u8]) -> std::io::Result<()> {
        let mut tmp = path.as_os_str().to_owned();
        tmp.push(".tmp");
        let tmp = PathBuf::from(tmp);
        let mut f = fs::File::create(&tmp)?;
        f.write_all(bytes)?;
        f.sync_all()?;
        drop(f);
        fs::rename(&tmp, path)
    }

    fn remove(&self, path: &Path) -> std::io::Result<()> {
        fs::remove_file(path)
    }
}

/// The default store shared by engine launches that did not pin one.
pub fn fs_store() -> Arc<dyn StoreLayer> {
    Arc::new(FsStore)
}

// ---------------------------------------------------------------------------
// Binary framing

/// Little-endian binary encoder for checkpoint payloads.
#[derive(Default)]
pub struct BinWriter {
    buf: Vec<u8>,
}

impl BinWriter {
    pub fn new() -> Self {
        BinWriter { buf: Vec::new() }
    }

    pub fn put_u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn put_u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn put_usize(&mut self, v: usize) {
        self.put_u64(v as u64);
    }

    /// Exact bit pattern — NaN payloads and signed zeros survive.
    pub fn put_f64(&mut self, v: f64) {
        self.put_u64(v.to_bits());
    }

    pub fn put_bool(&mut self, v: bool) {
        self.buf.push(v as u8);
    }

    /// Length-prefixed byte block (for nested payloads).
    pub fn put_bytes(&mut self, b: &[u8]) {
        self.put_u64(b.len() as u64);
        self.buf.extend_from_slice(b);
    }

    pub fn into_bytes(self) -> Vec<u8> {
        self.buf
    }
}

/// Little-endian binary decoder; every read is bounds-checked.
pub struct BinReader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> BinReader<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        BinReader { buf, pos: 0 }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8], CkptError> {
        let end = self.pos.checked_add(n).ok_or(CkptError::Corrupt("length overflow"))?;
        if end > self.buf.len() {
            return Err(CkptError::Corrupt("truncated payload"));
        }
        let out = &self.buf[self.pos..end];
        self.pos = end;
        Ok(out)
    }

    pub fn u32(&mut self) -> Result<u32, CkptError> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub fn u64(&mut self) -> Result<u64, CkptError> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub fn usize_(&mut self) -> Result<usize, CkptError> {
        usize::try_from(self.u64()?).map_err(|_| CkptError::Corrupt("usize overflow"))
    }

    pub fn f64(&mut self) -> Result<f64, CkptError> {
        Ok(f64::from_bits(self.u64()?))
    }

    pub fn bool_(&mut self) -> Result<bool, CkptError> {
        match self.take(1)?[0] {
            0 => Ok(false),
            1 => Ok(true),
            _ => Err(CkptError::Corrupt("invalid bool byte")),
        }
    }

    /// Length-prefixed byte block.
    pub fn bytes(&mut self) -> Result<&'a [u8], CkptError> {
        let len = self.usize_()?;
        self.take(len)
    }

    /// Assert the payload was consumed exactly.
    pub fn finish(&self) -> Result<(), CkptError> {
        if self.pos == self.buf.len() {
            Ok(())
        } else {
            Err(CkptError::Corrupt("trailing bytes"))
        }
    }
}

// ---------------------------------------------------------------------------
// Persist

/// Binary serialization of kernel states (and their building blocks) for
/// checkpointing. Round-tripping must be exact: `restore(persist(x)) == x`
/// down to float bit patterns, so a resumed chain replays bit-identically.
pub trait Persist: Sized {
    fn persist(&self, w: &mut BinWriter);
    fn restore(r: &mut BinReader<'_>) -> Result<Self, CkptError>;
}

impl Persist for () {
    fn persist(&self, _w: &mut BinWriter) {}
    fn restore(_r: &mut BinReader<'_>) -> Result<Self, CkptError> {
        Ok(())
    }
}

impl Persist for bool {
    fn persist(&self, w: &mut BinWriter) {
        w.put_bool(*self);
    }
    fn restore(r: &mut BinReader<'_>) -> Result<Self, CkptError> {
        r.bool_()
    }
}

impl Persist for u32 {
    fn persist(&self, w: &mut BinWriter) {
        w.put_u32(*self);
    }
    fn restore(r: &mut BinReader<'_>) -> Result<Self, CkptError> {
        r.u32()
    }
}

impl Persist for u64 {
    fn persist(&self, w: &mut BinWriter) {
        w.put_u64(*self);
    }
    fn restore(r: &mut BinReader<'_>) -> Result<Self, CkptError> {
        r.u64()
    }
}

impl Persist for usize {
    fn persist(&self, w: &mut BinWriter) {
        w.put_usize(*self);
    }
    fn restore(r: &mut BinReader<'_>) -> Result<Self, CkptError> {
        r.usize_()
    }
}

impl Persist for f64 {
    fn persist(&self, w: &mut BinWriter) {
        w.put_f64(*self);
    }
    fn restore(r: &mut BinReader<'_>) -> Result<Self, CkptError> {
        r.f64()
    }
}

impl<T: Persist> Persist for Vec<T> {
    fn persist(&self, w: &mut BinWriter) {
        w.put_usize(self.len());
        for x in self {
            x.persist(w);
        }
    }
    fn restore(r: &mut BinReader<'_>) -> Result<Self, CkptError> {
        let len = r.usize_()?;
        // guard against a corrupt length amplifying into a huge alloc:
        // each element consumes at least one byte of payload
        if len > r.buf.len() {
            return Err(CkptError::Corrupt("vec length exceeds payload"));
        }
        let mut out = Vec::with_capacity(len);
        for _ in 0..len {
            out.push(T::restore(r)?);
        }
        Ok(out)
    }
}

impl Persist for Sample {
    fn persist(&self, w: &mut BinWriter) {
        w.put_f64(self.value);
        w.put_f64(self.at_secs);
        w.put_u64(self.at_data);
    }
    fn restore(r: &mut BinReader<'_>) -> Result<Self, CkptError> {
        Ok(Sample { value: r.f64()?, at_secs: r.f64()?, at_data: r.u64()? })
    }
}

// ---------------------------------------------------------------------------
// Chain checkpoint

/// Which shard of an embarrassingly-parallel run a chain belongs to.
/// Stamped into every checkpoint so a resume cannot silently continue a
/// shard-2-of-8 chain against shard 5's data (or against an unsharded
/// run). The default stamp (`0 of 1`, empty row range) is the unsharded
/// run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ShardStamp {
    /// Shard index in `0..count`.
    pub index: usize,
    /// Total shard count (1 = unsharded).
    pub count: usize,
    /// Global row range `[start, end)` this shard owns (0, 0 when
    /// unsharded — the chain sees the whole population).
    pub start: usize,
    pub end: usize,
}

impl Default for ShardStamp {
    fn default() -> Self {
        ShardStamp { index: 0, count: 1, start: 0, end: 0 }
    }
}

impl fmt::Display for ShardStamp {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "shard {}/{} rows [{}, {})", self.index, self.count, self.start, self.end)
    }
}

/// Everything one chain needs to resume bit-identically: budget
/// accounting, recorded samples, RNG stream position, and the
/// kernel-encoded state and scratch payloads.
#[derive(Clone, Debug)]
pub struct ChainCheckpoint {
    /// Engine chain index (stream `STREAM_BASE + chain`).
    pub chain: usize,
    /// Engine base seed; resuming under a different seed is refused.
    pub base_seed: u64,
    /// Shard membership; resuming under a different shard layout is
    /// refused (v2+).
    pub shard: ShardStamp,
    /// Rotation generation (1-based, monotone per chain). Sealed into
    /// the payload so a renamed file cannot masquerade as a different
    /// generation (v3+).
    pub generation: u64,
    pub steps: usize,
    pub accepted: usize,
    pub data_used: u64,
    pub guard_trips: u64,
    /// Wall seconds consumed before the checkpoint (resumed chains offset
    /// their clocks by this; the one inexact field).
    pub wall_secs: f64,
    /// PCG64 stream position (`Pcg64::state_parts`).
    pub rng: [u64; 4],
    pub samples: Vec<Sample>,
    /// `Persist`-encoded kernel state.
    pub state: Vec<u8>,
    /// `TransitionKernel::save_scratch` payload (scheduler permutations,
    /// annealing counters, ...).
    pub scratch: Vec<u8>,
}

impl ChainCheckpoint {
    /// Encode the payload and seal it with the CRC32 trailer (4 LE
    /// bytes over everything before it).
    pub fn encode(&self) -> Vec<u8> {
        let mut w = BinWriter::new();
        w.put_u32(CKPT_MAGIC);
        w.put_u32(CKPT_VERSION);
        w.put_usize(self.chain);
        w.put_u64(self.base_seed);
        w.put_usize(self.shard.index);
        w.put_usize(self.shard.count);
        w.put_usize(self.shard.start);
        w.put_usize(self.shard.end);
        w.put_u64(self.generation);
        w.put_usize(self.steps);
        w.put_usize(self.accepted);
        w.put_u64(self.data_used);
        w.put_u64(self.guard_trips);
        w.put_f64(self.wall_secs);
        for part in self.rng {
            w.put_u64(part);
        }
        self.samples.persist(&mut w);
        w.put_bytes(&self.state);
        w.put_bytes(&self.scratch);
        let mut bytes = w.into_bytes();
        let crc = crc32(&bytes);
        bytes.extend_from_slice(&crc.to_le_bytes());
        bytes
    }

    pub fn decode(bytes: &[u8]) -> Result<Self, CkptError> {
        // The version word is readable before the trailer check so a
        // pre-v3 (trailer-less) file reports `Version`, not a confusing
        // CRC failure; v3+ payloads must pass the trailer first.
        if bytes.len() < 8 {
            return Err(CkptError::Corrupt("truncated payload"));
        }
        if u32::from_le_bytes(bytes[0..4].try_into().unwrap()) != CKPT_MAGIC {
            return Err(CkptError::Corrupt("bad magic"));
        }
        let version = u32::from_le_bytes(bytes[4..8].try_into().unwrap());
        if version != CKPT_VERSION {
            return Err(CkptError::Version { found: version });
        }
        if bytes.len() < 12 {
            return Err(CkptError::Corrupt("truncated payload"));
        }
        let (payload, trailer) = bytes.split_at(bytes.len() - 4);
        let stored = u32::from_le_bytes(trailer.try_into().unwrap());
        if crc32(payload) != stored {
            return Err(CkptError::Corrupt("crc mismatch"));
        }
        let mut r = BinReader::new(payload);
        let _magic = r.u32()?;
        let _version = r.u32()?;
        let ck = ChainCheckpoint {
            chain: r.usize_()?,
            base_seed: r.u64()?,
            shard: ShardStamp {
                index: r.usize_()?,
                count: r.usize_()?,
                start: r.usize_()?,
                end: r.usize_()?,
            },
            generation: r.u64()?,
            steps: r.usize_()?,
            accepted: r.usize_()?,
            data_used: r.u64()?,
            guard_trips: r.u64()?,
            wall_secs: r.f64()?,
            rng: [r.u64()?, r.u64()?, r.u64()?, r.u64()?],
            samples: Vec::restore(&mut r)?,
            state: r.bytes()?.to_vec(),
            scratch: r.bytes()?.to_vec(),
        };
        if ck.shard.count == 0 || ck.shard.index >= ck.shard.count || ck.shard.start > ck.shard.end
        {
            return Err(CkptError::Corrupt("invalid shard stamp"));
        }
        if ck.generation == 0 {
            return Err(CkptError::Corrupt("invalid generation"));
        }
        r.finish()?;
        Ok(ck)
    }

    /// Write this checkpoint's generation file through `store` (the
    /// production store renames a temp file over the target, so an
    /// interrupted write never destroys an existing generation).
    pub fn write_atomic(&self, store: &dyn StoreLayer, dir: &Path) -> Result<(), CkptError> {
        store.write_atomic(&gen_path(dir, self.chain, self.generation), &self.encode())?;
        Ok(())
    }

    /// Write this generation, then prune the chain's oldest generations
    /// down to `retain` files (best-effort; a failed prune never fails
    /// the write that preceded it).
    pub fn write_rotated(
        &self,
        store: &dyn StoreLayer,
        dir: &Path,
        retain: usize,
    ) -> Result<(), CkptError> {
        self.write_atomic(store, dir)?;
        prune_generations(store, dir, self.chain, retain.max(1));
        Ok(())
    }

    /// Load chain `c`'s newest loadable checkpoint from `dir`, walking
    /// generations newest-first and silently skipping torn, corrupt, or
    /// unreadable files. Returns the checkpoint together with how many
    /// newer generations had to be skipped (`> 0` means the chain
    /// recovered past a bad file). `Ok(None)` when no generation files
    /// exist (the chain never reached a checkpoint boundary — it resumes
    /// from scratch); an error only when files exist but none decode.
    pub fn load_latest(
        store: &dyn StoreLayer,
        dir: &Path,
        chain: usize,
    ) -> Result<Option<(Self, usize)>, CkptError> {
        let gens = list_generations(dir, chain)?;
        if gens.is_empty() {
            return Ok(None);
        }
        let mut skipped = 0usize;
        let mut last_err = CkptError::Corrupt("no loadable generation");
        for &g in gens.iter().rev() {
            match store.read(&gen_path(dir, chain, g)) {
                Ok(bytes) => match Self::decode(&bytes) {
                    Ok(ck) if ck.generation == g => return Ok(Some((ck, skipped))),
                    Ok(_) => last_err = CkptError::Corrupt("generation label mismatch"),
                    Err(e) => last_err = e,
                },
                // racing a prune is not a fault; anything else is
                Err(e) if e.kind() == std::io::ErrorKind::NotFound => {}
                Err(e) => last_err = CkptError::Io(e),
            }
            skipped += 1;
        }
        Err(last_err)
    }
}

/// Generation `g` checkpoint file of chain `c` under `dir`.
pub fn gen_path(dir: &Path, chain: usize, generation: u64) -> PathBuf {
    dir.join(format!("chain-{chain}.g{generation}.ckpt"))
}

/// Parse a `chain-<c>.g<g>.ckpt` file name into `(chain, generation)`.
pub(crate) fn parse_gen_name(name: &str) -> Option<(usize, u64)> {
    let rest = name.strip_prefix("chain-")?.strip_suffix(".ckpt")?;
    let (chain, gen) = rest.split_once(".g")?;
    Some((chain.parse().ok()?, gen.parse().ok()?))
}

/// All on-disk generations of chain `c` under `dir`, sorted ascending.
/// A missing directory reads as "no generations" rather than an error —
/// a fresh launch has not created it yet.
pub fn list_generations(dir: &Path, chain: usize) -> Result<Vec<u64>, CkptError> {
    let entries = match fs::read_dir(dir) {
        Ok(entries) => entries,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(Vec::new()),
        Err(e) => return Err(CkptError::Io(e)),
    };
    let mut gens = Vec::new();
    for entry in entries {
        let entry = entry?;
        if let Some(name) = entry.file_name().to_str() {
            if let Some((c, g)) = parse_gen_name(name) {
                if c == chain {
                    gens.push(g);
                }
            }
        }
    }
    gens.sort_unstable();
    Ok(gens)
}

/// Best-effort removal of chain `c`'s oldest generations, keeping the
/// newest `retain` files. Removal failures are ignored: an unprunable
/// old generation wastes disk but never blocks sampling.
pub fn prune_generations(store: &dyn StoreLayer, dir: &Path, chain: usize, retain: usize) {
    let Ok(gens) = list_generations(dir, chain) else { return };
    if gens.len() > retain {
        for &g in &gens[..gens.len() - retain] {
            store.remove(&gen_path(dir, chain, g)).ok();
        }
    }
}

/// Where and how often to checkpoint: every `every` completed steps,
/// rotating up to `retain` generation files per chain under `dir`.
#[derive(Clone, Debug)]
pub struct CheckpointSpec {
    pub every: usize,
    pub dir: PathBuf,
    /// Generations kept per chain (`>= 1`); older files are pruned
    /// after each successful write.
    pub retain: usize,
}

// ---------------------------------------------------------------------------
// Manifest (observability only — resume reads the binary files)

/// Render a float as JSON (`null` for non-finite values).
pub(crate) fn json_num(v: f64) -> String {
    if v.is_finite() {
        format!("{v}")
    } else {
        "null".to_string()
    }
}

/// Escape a string into a JSON literal.
pub(crate) fn json_str(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

/// What the manifest records about a checkpointing launch — the fields
/// a resume must agree on before it may adopt the directory.
#[derive(Clone, Debug)]
pub struct ManifestInfo<'a> {
    pub chains: usize,
    pub base_seed: u64,
    pub burn_in: usize,
    pub thin: usize,
    pub every: usize,
    pub retain: usize,
    pub budget: &'a Budget,
    pub shard: ShardStamp,
    /// Kernel/backend label (`session_backend()`); empty when launched
    /// below the session layer, in which case validation skips it.
    pub kernel: &'a str,
    /// Acceptance-rule label (`AcceptanceTest::name`); empty when
    /// launched below the session layer.
    pub rule: &'a str,
}

fn budget_kind(budget: &Budget) -> (&'static str, f64) {
    match budget {
        Budget::Steps(s) => ("steps", *s as f64),
        Budget::Wall(d) => ("wall_secs", d.as_secs_f64()),
        Budget::Data(d) => ("data", *d as f64),
    }
}

/// Write `manifest.json` describing a checkpointing launch (atomically,
/// like the chain files). Resume cross-checks it via
/// [`validate_manifest`]; the binary chain files stay self-contained.
pub(crate) fn write_manifest(
    store: &dyn StoreLayer,
    dir: &Path,
    info: &ManifestInfo<'_>,
) -> Result<(), CkptError> {
    let (kind, per_chain) = budget_kind(info.budget);
    let json = format!(
        "{{\"format_version\":{CKPT_VERSION},\"chains\":{},\"base_seed\":{},\
         \"burn_in\":{},\"thin\":{},\"checkpoint_every\":{},\"retain\":{},\
         \"shard\":{{\"index\":{},\"count\":{}}},\"kernel\":{},\"rule\":{},\
         \"budget\":{{\"kind\":{},\"per_chain\":{}}}}}\n",
        info.chains,
        info.base_seed,
        info.burn_in,
        info.thin,
        info.every,
        info.retain,
        info.shard.index,
        info.shard.count,
        json_str(info.kernel),
        json_str(info.rule),
        json_str(kind),
        json_num(per_chain),
    );
    store.write_atomic(&dir.join("manifest.json"), json.as_bytes())?;
    Ok(())
}

/// Extract the raw token after `"key":` in our own manifest dialect
/// (flat values: numbers, strings, or one-level objects).
fn manifest_field<'t>(text: &'t str, key: &str) -> Option<&'t str> {
    let pat = format!("\"{key}\":");
    let at = text.find(&pat)? + pat.len();
    let rest = &text[at..];
    let bytes = rest.as_bytes();
    match bytes.first()? {
        b'"' => {
            let mut i = 1;
            while i < bytes.len() {
                match bytes[i] {
                    b'\\' => i += 2,
                    b'"' => return Some(&rest[..=i]),
                    _ => i += 1,
                }
            }
            None
        }
        b'{' => {
            let mut depth = 0usize;
            for (i, b) in bytes.iter().enumerate() {
                match b {
                    b'{' => depth += 1,
                    b'}' => {
                        depth -= 1;
                        if depth == 0 {
                            return Some(&rest[..=i]);
                        }
                    }
                    _ => {}
                }
            }
            None
        }
        _ => {
            let end = rest.find([',', '}', '\n']).unwrap_or(rest.len());
            Some(rest[..end].trim())
        }
    }
}

fn check_field(
    text: &str,
    key: &str,
    expect: &str,
    mismatches: &mut Vec<String>,
) {
    match manifest_field(text, key) {
        Some(found) if found == expect => {}
        Some(found) => mismatches.push(format!("{key}: manifest has {found}, run has {expect}")),
        // a hand-edited or older manifest may lack a field; only a
        // *conflicting* value refuses the resume
        None => {}
    }
}

/// Cross-check a checkpoint directory's `manifest.json` against the
/// resuming launch. Chains, seed, burn-in, thinning, budget *kind*,
/// shard layout, format version, and (when both sides carry them) the
/// kernel/rule labels must agree; the budget *amount* may differ — a
/// resume legitimately extends the budget. A missing manifest is
/// tolerated (the binary files are self-contained and carry their own
/// chain/seed/shard stamps).
pub(crate) fn validate_manifest(
    store: &dyn StoreLayer,
    dir: &Path,
    info: &ManifestInfo<'_>,
) -> Result<(), CkptError> {
    let bytes = match store.read(&dir.join("manifest.json")) {
        Ok(bytes) => bytes,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(()),
        Err(e) => return Err(CkptError::Io(e)),
    };
    let text = String::from_utf8_lossy(&bytes).into_owned();
    let mut bad = Vec::new();
    check_field(&text, "format_version", &CKPT_VERSION.to_string(), &mut bad);
    check_field(&text, "chains", &info.chains.to_string(), &mut bad);
    check_field(&text, "base_seed", &info.base_seed.to_string(), &mut bad);
    check_field(&text, "burn_in", &info.burn_in.to_string(), &mut bad);
    check_field(&text, "thin", &info.thin.to_string(), &mut bad);
    let (kind, _) = budget_kind(info.budget);
    if let Some(budget) = manifest_field(&text, "budget") {
        check_field(budget, "kind", &json_str(kind), &mut bad);
    }
    if let Some(shard) = manifest_field(&text, "shard") {
        check_field(shard, "index", &info.shard.index.to_string(), &mut bad);
        check_field(shard, "count", &info.shard.count.to_string(), &mut bad);
    }
    if !info.kernel.is_empty() {
        match manifest_field(&text, "kernel") {
            Some(found) if found == "\"\"" || found == json_str(info.kernel) => {}
            Some(found) => {
                bad.push(format!("kernel: manifest has {found}, run has {}", json_str(info.kernel)))
            }
            None => {}
        }
    }
    if !info.rule.is_empty() {
        match manifest_field(&text, "rule") {
            Some(found) if found == "\"\"" || found == json_str(info.rule) => {}
            Some(found) => {
                bad.push(format!("rule: manifest has {found}, run has {}", json_str(info.rule)))
            }
            None => {}
        }
    }
    if bad.is_empty() {
        Ok(())
    } else {
        Err(CkptError::ManifestMismatch(bad.join("; ")))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    fn temp_dir(tag: &str) -> PathBuf {
        static UNIQ: AtomicUsize = AtomicUsize::new(0);
        let dir = std::env::temp_dir().join(format!(
            "austerity-ckpt-{tag}-{}-{}",
            std::process::id(),
            UNIQ.fetch_add(1, Ordering::Relaxed)
        ));
        fs::create_dir_all(&dir).unwrap();
        dir
    }

    fn sample_ckpt() -> ChainCheckpoint {
        ChainCheckpoint {
            chain: 2,
            base_seed: 42,
            shard: ShardStamp { index: 1, count: 4, start: 2500, end: 5000 },
            generation: 7,
            steps: 137,
            accepted: 55,
            data_used: 12_345,
            guard_trips: 3,
            wall_secs: 0.25,
            rng: [1, u64::MAX, 3, 0xdead_beef],
            samples: vec![
                Sample { value: -0.5, at_secs: 0.1, at_data: 100 },
                Sample { value: f64::NAN, at_secs: 0.2, at_data: 200 },
            ],
            state: vec![9, 8, 7],
            scratch: vec![],
        }
    }

    #[test]
    fn roundtrip_is_exact() {
        let ck = sample_ckpt();
        let back = ChainCheckpoint::decode(&ck.encode()).unwrap();
        assert_eq!(back.chain, ck.chain);
        assert_eq!(back.base_seed, ck.base_seed);
        assert_eq!(back.shard, ck.shard);
        assert_eq!(back.generation, ck.generation);
        assert_eq!(back.steps, ck.steps);
        assert_eq!(back.accepted, ck.accepted);
        assert_eq!(back.data_used, ck.data_used);
        assert_eq!(back.guard_trips, ck.guard_trips);
        assert_eq!(back.wall_secs.to_bits(), ck.wall_secs.to_bits());
        assert_eq!(back.rng, ck.rng);
        assert_eq!(back.samples.len(), ck.samples.len());
        for (a, b) in back.samples.iter().zip(&ck.samples) {
            // NaN bit patterns included
            assert_eq!(a.value.to_bits(), b.value.to_bits());
            assert_eq!(a.at_data, b.at_data);
        }
        assert_eq!(back.state, ck.state);
        assert_eq!(back.scratch, ck.scratch);
    }

    #[test]
    fn corrupt_payloads_are_rejected_not_panicked() {
        let bytes = sample_ckpt().encode();
        // truncations at every prefix length must error, never panic
        for cut in 0..bytes.len() {
            assert!(ChainCheckpoint::decode(&bytes[..cut]).is_err(), "cut={cut}");
        }
        // bad magic
        let mut bad = bytes.clone();
        bad[0] ^= 0xff;
        assert!(matches!(ChainCheckpoint::decode(&bad), Err(CkptError::Corrupt(_))));
        // future version
        let mut vnext = bytes.clone();
        vnext[4..8].copy_from_slice(&(CKPT_VERSION + 1).to_le_bytes());
        assert!(matches!(
            ChainCheckpoint::decode(&vnext),
            Err(CkptError::Version { found }) if found == CKPT_VERSION + 1
        ));
        // trailing garbage shifts the trailer, so the CRC catches it
        let mut long = bytes.clone();
        long.push(0);
        assert!(ChainCheckpoint::decode(&long).is_err());
    }

    #[test]
    fn single_bit_flips_anywhere_fail_the_crc() {
        let bytes = sample_ckpt().encode();
        // flip one bit in every byte past the header words: either the
        // CRC trailer or (for flips inside the trailer itself) the
        // recomputed checksum must refuse the payload
        for at in 8..bytes.len() {
            let mut bad = bytes.clone();
            bad[at] ^= 0x10;
            assert!(ChainCheckpoint::decode(&bad).is_err(), "flip at byte {at}");
        }
    }

    #[test]
    fn crc32_matches_the_ieee_reference_vector() {
        // the canonical IEEE 802.3 check value for "123456789"
        assert_eq!(crc32(b"123456789"), 0xcbf4_3926);
        assert_eq!(crc32(b""), 0);
    }

    #[test]
    fn old_checkpoint_versions_are_rejected_not_misread() {
        // pre-v3 files have no CRC trailer (and v1 no shard stamp): the
        // loader must refuse them by version, never trust their bytes
        for old in [1u32, 2] {
            let mut bytes = sample_ckpt().encode();
            bytes[4..8].copy_from_slice(&old.to_le_bytes());
            assert!(matches!(
                ChainCheckpoint::decode(&bytes),
                Err(CkptError::Version { found }) if found == old
            ));
        }
    }

    #[test]
    fn nonsense_shard_stamps_are_corrupt() {
        let mut ck = sample_ckpt();
        ck.shard = ShardStamp { index: 4, count: 4, start: 0, end: 0 };
        assert!(matches!(
            ChainCheckpoint::decode(&ck.encode()),
            Err(CkptError::Corrupt("invalid shard stamp"))
        ));
        ck.shard = ShardStamp { index: 0, count: 0, start: 0, end: 0 };
        assert!(ChainCheckpoint::decode(&ck.encode()).is_err());
        // the default (unsharded) stamp is always valid
        ck.shard = ShardStamp::default();
        assert_eq!(ChainCheckpoint::decode(&ck.encode()).unwrap().shard, ShardStamp::default());
    }

    #[test]
    fn persist_primitives_roundtrip_bitwise() {
        let mut w = BinWriter::new();
        true.persist(&mut w);
        3.7f64.persist(&mut w);
        f64::NAN.persist(&mut w);
        (-0.0f64).persist(&mut w);
        vec![1u32, 2, 3].persist(&mut w);
        vec![true, false].persist(&mut w);
        7usize.persist(&mut w);
        ().persist(&mut w);
        let bytes = w.into_bytes();
        let mut r = BinReader::new(&bytes);
        assert!(bool::restore(&mut r).unwrap());
        assert_eq!(f64::restore(&mut r).unwrap(), 3.7);
        assert_eq!(f64::restore(&mut r).unwrap().to_bits(), f64::NAN.to_bits());
        assert_eq!(f64::restore(&mut r).unwrap().to_bits(), (-0.0f64).to_bits());
        assert_eq!(Vec::<u32>::restore(&mut r).unwrap(), vec![1, 2, 3]);
        assert_eq!(Vec::<bool>::restore(&mut r).unwrap(), vec![true, false]);
        assert_eq!(usize::restore(&mut r).unwrap(), 7);
        <()>::restore(&mut r).unwrap();
        r.finish().unwrap();
    }

    #[test]
    fn atomic_write_then_load_latest() {
        let dir = temp_dir("atomic");
        let store = FsStore;
        let ck = sample_ckpt();
        assert!(ChainCheckpoint::load_latest(&store, &dir, 2).unwrap().is_none());
        ck.write_atomic(&store, &dir).unwrap();
        let (back, skipped) =
            ChainCheckpoint::load_latest(&store, &dir, 2).unwrap().expect("present");
        assert_eq!(back.steps, ck.steps);
        assert_eq!(back.generation, 7);
        assert_eq!(skipped, 0);
        // no temp droppings left behind
        assert!(!dir.join("chain-2.g7.ckpt.tmp").exists());
        // other chains stay absent
        assert!(ChainCheckpoint::load_latest(&store, &dir, 0).unwrap().is_none());
        fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn gen_names_parse_and_reject_strangers() {
        assert_eq!(parse_gen_name("chain-3.g12.ckpt"), Some((3, 12)));
        assert_eq!(parse_gen_name("chain-0.g1.ckpt"), Some((0, 1)));
        assert_eq!(parse_gen_name("chain-0.g1.ckpt.tmp"), None);
        assert_eq!(parse_gen_name("chain-0.ckpt"), None); // pre-v3 name
        assert_eq!(parse_gen_name("manifest.json"), None);
        assert_eq!(parse_gen_name("chain-x.g1.ckpt"), None);
    }

    #[test]
    fn rotation_prunes_to_retain_and_falls_back_past_torn_generations() {
        let dir = temp_dir("rotate");
        let store = FsStore;
        let mut ck = sample_ckpt();
        for g in 1..=5u64 {
            ck.generation = g;
            ck.steps = 100 * g as usize;
            ck.write_rotated(&store, &dir, 3).unwrap();
        }
        assert_eq!(list_generations(&dir, 2).unwrap(), vec![3, 4, 5]);

        // tear the newest generation mid-file: load falls back to g4
        let newest = gen_path(&dir, 2, 5);
        let bytes = fs::read(&newest).unwrap();
        fs::write(&newest, &bytes[..bytes.len() / 2]).unwrap();
        let (back, skipped) =
            ChainCheckpoint::load_latest(&store, &dir, 2).unwrap().expect("fallback");
        assert_eq!(back.generation, 4);
        assert_eq!(back.steps, 400);
        assert_eq!(skipped, 1);

        // corrupt every survivor: now loading is an error, not a fresh start
        for g in 3..=5u64 {
            fs::write(gen_path(&dir, 2, g), b"junk").unwrap();
        }
        assert!(ChainCheckpoint::load_latest(&store, &dir, 2).is_err());
        fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn renamed_generation_files_are_refused() {
        // the generation is sealed into the payload: copying g2's bytes
        // into a g9 file name must not load as generation 9
        let dir = temp_dir("rename");
        let store = FsStore;
        let mut ck = sample_ckpt();
        ck.generation = 2;
        ck.write_atomic(&store, &dir).unwrap();
        fs::copy(gen_path(&dir, 2, 2), gen_path(&dir, 2, 9)).unwrap();
        let (back, skipped) =
            ChainCheckpoint::load_latest(&store, &dir, 2).unwrap().expect("fallback");
        assert_eq!(back.generation, 2, "must fall back to the honestly-named file");
        assert_eq!(skipped, 1);
        fs::remove_dir_all(&dir).ok();
    }

    fn info<'a>(budget: &'a Budget, kernel: &'a str, rule: &'a str) -> ManifestInfo<'a> {
        ManifestInfo {
            chains: 4,
            base_seed: 42,
            burn_in: 10,
            thin: 2,
            every: 50,
            retain: 2,
            budget,
            shard: ShardStamp::default(),
            kernel,
            rule,
        }
    }

    #[test]
    fn manifest_is_written_and_valid_jsonish() {
        let dir = temp_dir("manifest");
        let budget = Budget::Steps(1_000);
        write_manifest(&FsStore, &dir, &info(&budget, "cached", "austerity")).unwrap();
        let text = fs::read_to_string(dir.join("manifest.json")).unwrap();
        assert!(text.contains("\"chains\":4"));
        assert!(text.contains("\"kind\":\"steps\""));
        assert!(text.contains("\"kernel\":\"cached\""));
        assert!(text.contains("\"rule\":\"austerity\""));
        assert_eq!(text.matches('{').count(), text.matches('}').count());
        fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn manifest_validation_accepts_the_writer_and_refuses_strangers() {
        let dir = temp_dir("validate");
        let store = FsStore;
        let budget = Budget::Steps(1_000);
        let written = info(&budget, "cached", "austerity");
        write_manifest(&store, &dir, &written).unwrap();

        // the writing configuration round-trips
        validate_manifest(&store, &dir, &written).unwrap();

        // a bigger budget of the same kind is a legitimate extension
        let extended = Budget::Steps(5_000);
        validate_manifest(&store, &dir, &info(&extended, "cached", "austerity")).unwrap();

        // a sub-session launch with no labels skips the label checks
        validate_manifest(&store, &dir, &info(&budget, "", "")).unwrap();

        // wrong seed, rule, kernel, or budget kind all refuse
        let mut wrong_seed = info(&budget, "cached", "austerity");
        wrong_seed.base_seed = 7;
        for (label, bad) in [
            ("seed", wrong_seed),
            ("rule", info(&budget, "cached", "exact")),
            ("kernel", info(&budget, "uncached", "austerity")),
        ] {
            match validate_manifest(&store, &dir, &bad) {
                Err(CkptError::ManifestMismatch(msg)) => {
                    assert!(!msg.is_empty(), "{label}: empty message")
                }
                other => panic!("{label}: expected ManifestMismatch, got {other:?}"),
            }
        }
        let wall = Budget::Wall(std::time::Duration::from_secs(5));
        assert!(matches!(
            validate_manifest(&store, &dir, &info(&wall, "cached", "austerity")),
            Err(CkptError::ManifestMismatch(_))
        ));

        // a missing manifest is tolerated (binary files self-validate)
        fs::remove_file(dir.join("manifest.json")).unwrap();
        validate_manifest(&store, &dir, &written).unwrap();
        fs::remove_dir_all(&dir).ok();
    }
}

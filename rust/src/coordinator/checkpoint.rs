//! Chain checkpoint/resume: versioned snapshots with bit-identical replay.
//!
//! A [`ChainCheckpoint`] captures everything a chain needs to continue as
//! if it had never stopped: the kernel `State`, the PCG64 stream position,
//! the kernel's cross-step scratch (minibatch-scheduler permutations,
//! annealing counters — serialized through
//! `TransitionKernel::save_scratch`), the budget consumed so far and the
//! samples recorded so far. Everything except wall-clock time is exact,
//! so a resumed chain produces draws, acceptance counters and data
//! accounting bit-identical to an uninterrupted same-seed run (`Wall`
//! budgets terminate at a timing-dependent step and are therefore the one
//! budget kind without a bit-identity guarantee).
//!
//! **Format.** One file per chain, `chain-<c>.ckpt`, in a compact
//! little-endian binary framing ([`BinWriter`]/[`BinReader`]) headed by a
//! magic word and a format version; unknown versions are rejected, never
//! reinterpreted. Files are written atomically (temp file + rename) so a
//! crash mid-write leaves the previous checkpoint intact. A human-readable
//! `manifest.json` (hand-rolled writer, same dialect as
//! `RunReport::to_json`) records the launch configuration for
//! observability; resume reads only the binary files, which are
//! self-contained.
//!
//! The cached MH path deliberately does **not** serialize its per-datapoint
//! cache: `CachedLlDiff::init_cache` rebuilds it from the restored state,
//! and the cached-vs-uncached bit-identity contract makes the rebuilt
//! cache equivalent to the persisted one at a fraction of the disk cost.

use std::fmt;
use std::fs;
use std::io::Write as _;
use std::path::{Path, PathBuf};

use crate::coordinator::chain::{Budget, Sample};

/// File magic of a chain checkpoint ("AUCK" little-endian).
pub const CKPT_MAGIC: u32 = 0x4b43_5541;
/// Current checkpoint format version. v2 added the shard stamp
/// (index/count/row range) to the header; v1 files are rejected with
/// [`CkptError::Version`] rather than silently read as shard 0 of 1 —
/// a v1 run predates sharding and must be restarted, not adopted.
pub const CKPT_VERSION: u32 = 2;

// ---------------------------------------------------------------------------
// Errors

/// Why a checkpoint could not be written or restored.
#[derive(Debug)]
pub enum CkptError {
    Io(std::io::Error),
    /// Truncated or malformed payload.
    Corrupt(&'static str),
    /// A checkpoint from an unknown format version.
    Version { found: u32 },
    /// A structurally valid checkpoint that does not match the run
    /// (wrong chain id, seed, or model size).
    Mismatch(String),
}

impl fmt::Display for CkptError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            CkptError::Io(e) => write!(f, "checkpoint I/O error: {e}"),
            CkptError::Corrupt(what) => write!(f, "corrupt checkpoint: {what}"),
            CkptError::Version { found } => {
                write!(f, "unsupported checkpoint version {found} (expected {CKPT_VERSION})")
            }
            CkptError::Mismatch(what) => write!(f, "checkpoint mismatch: {what}"),
        }
    }
}

impl std::error::Error for CkptError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            CkptError::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for CkptError {
    fn from(e: std::io::Error) -> Self {
        CkptError::Io(e)
    }
}

// ---------------------------------------------------------------------------
// Binary framing

/// Little-endian binary encoder for checkpoint payloads.
#[derive(Default)]
pub struct BinWriter {
    buf: Vec<u8>,
}

impl BinWriter {
    pub fn new() -> Self {
        BinWriter { buf: Vec::new() }
    }

    pub fn put_u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn put_u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn put_usize(&mut self, v: usize) {
        self.put_u64(v as u64);
    }

    /// Exact bit pattern — NaN payloads and signed zeros survive.
    pub fn put_f64(&mut self, v: f64) {
        self.put_u64(v.to_bits());
    }

    pub fn put_bool(&mut self, v: bool) {
        self.buf.push(v as u8);
    }

    /// Length-prefixed byte block (for nested payloads).
    pub fn put_bytes(&mut self, b: &[u8]) {
        self.put_u64(b.len() as u64);
        self.buf.extend_from_slice(b);
    }

    pub fn into_bytes(self) -> Vec<u8> {
        self.buf
    }
}

/// Little-endian binary decoder; every read is bounds-checked.
pub struct BinReader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> BinReader<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        BinReader { buf, pos: 0 }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8], CkptError> {
        let end = self.pos.checked_add(n).ok_or(CkptError::Corrupt("length overflow"))?;
        if end > self.buf.len() {
            return Err(CkptError::Corrupt("truncated payload"));
        }
        let out = &self.buf[self.pos..end];
        self.pos = end;
        Ok(out)
    }

    pub fn u32(&mut self) -> Result<u32, CkptError> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub fn u64(&mut self) -> Result<u64, CkptError> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub fn usize_(&mut self) -> Result<usize, CkptError> {
        usize::try_from(self.u64()?).map_err(|_| CkptError::Corrupt("usize overflow"))
    }

    pub fn f64(&mut self) -> Result<f64, CkptError> {
        Ok(f64::from_bits(self.u64()?))
    }

    pub fn bool_(&mut self) -> Result<bool, CkptError> {
        match self.take(1)?[0] {
            0 => Ok(false),
            1 => Ok(true),
            _ => Err(CkptError::Corrupt("invalid bool byte")),
        }
    }

    /// Length-prefixed byte block.
    pub fn bytes(&mut self) -> Result<&'a [u8], CkptError> {
        let len = self.usize_()?;
        self.take(len)
    }

    /// Assert the payload was consumed exactly.
    pub fn finish(&self) -> Result<(), CkptError> {
        if self.pos == self.buf.len() {
            Ok(())
        } else {
            Err(CkptError::Corrupt("trailing bytes"))
        }
    }
}

// ---------------------------------------------------------------------------
// Persist

/// Binary serialization of kernel states (and their building blocks) for
/// checkpointing. Round-tripping must be exact: `restore(persist(x)) == x`
/// down to float bit patterns, so a resumed chain replays bit-identically.
pub trait Persist: Sized {
    fn persist(&self, w: &mut BinWriter);
    fn restore(r: &mut BinReader<'_>) -> Result<Self, CkptError>;
}

impl Persist for () {
    fn persist(&self, _w: &mut BinWriter) {}
    fn restore(_r: &mut BinReader<'_>) -> Result<Self, CkptError> {
        Ok(())
    }
}

impl Persist for bool {
    fn persist(&self, w: &mut BinWriter) {
        w.put_bool(*self);
    }
    fn restore(r: &mut BinReader<'_>) -> Result<Self, CkptError> {
        r.bool_()
    }
}

impl Persist for u32 {
    fn persist(&self, w: &mut BinWriter) {
        w.put_u32(*self);
    }
    fn restore(r: &mut BinReader<'_>) -> Result<Self, CkptError> {
        r.u32()
    }
}

impl Persist for u64 {
    fn persist(&self, w: &mut BinWriter) {
        w.put_u64(*self);
    }
    fn restore(r: &mut BinReader<'_>) -> Result<Self, CkptError> {
        r.u64()
    }
}

impl Persist for usize {
    fn persist(&self, w: &mut BinWriter) {
        w.put_usize(*self);
    }
    fn restore(r: &mut BinReader<'_>) -> Result<Self, CkptError> {
        r.usize_()
    }
}

impl Persist for f64 {
    fn persist(&self, w: &mut BinWriter) {
        w.put_f64(*self);
    }
    fn restore(r: &mut BinReader<'_>) -> Result<Self, CkptError> {
        r.f64()
    }
}

impl<T: Persist> Persist for Vec<T> {
    fn persist(&self, w: &mut BinWriter) {
        w.put_usize(self.len());
        for x in self {
            x.persist(w);
        }
    }
    fn restore(r: &mut BinReader<'_>) -> Result<Self, CkptError> {
        let len = r.usize_()?;
        // guard against a corrupt length amplifying into a huge alloc:
        // each element consumes at least one byte of payload
        if len > r.buf.len() {
            return Err(CkptError::Corrupt("vec length exceeds payload"));
        }
        let mut out = Vec::with_capacity(len);
        for _ in 0..len {
            out.push(T::restore(r)?);
        }
        Ok(out)
    }
}

impl Persist for Sample {
    fn persist(&self, w: &mut BinWriter) {
        w.put_f64(self.value);
        w.put_f64(self.at_secs);
        w.put_u64(self.at_data);
    }
    fn restore(r: &mut BinReader<'_>) -> Result<Self, CkptError> {
        Ok(Sample { value: r.f64()?, at_secs: r.f64()?, at_data: r.u64()? })
    }
}

// ---------------------------------------------------------------------------
// Chain checkpoint

/// Which shard of an embarrassingly-parallel run a chain belongs to.
/// Stamped into every checkpoint so a resume cannot silently continue a
/// shard-2-of-8 chain against shard 5's data (or against an unsharded
/// run). The default stamp (`0 of 1`, empty row range) is the unsharded
/// run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ShardStamp {
    /// Shard index in `0..count`.
    pub index: usize,
    /// Total shard count (1 = unsharded).
    pub count: usize,
    /// Global row range `[start, end)` this shard owns (0, 0 when
    /// unsharded — the chain sees the whole population).
    pub start: usize,
    pub end: usize,
}

impl Default for ShardStamp {
    fn default() -> Self {
        ShardStamp { index: 0, count: 1, start: 0, end: 0 }
    }
}

impl fmt::Display for ShardStamp {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "shard {}/{} rows [{}, {})", self.index, self.count, self.start, self.end)
    }
}

/// Everything one chain needs to resume bit-identically: budget
/// accounting, recorded samples, RNG stream position, and the
/// kernel-encoded state and scratch payloads.
#[derive(Clone, Debug)]
pub struct ChainCheckpoint {
    /// Engine chain index (stream `STREAM_BASE + chain`).
    pub chain: usize,
    /// Engine base seed; resuming under a different seed is refused.
    pub base_seed: u64,
    /// Shard membership; resuming under a different shard layout is
    /// refused (v2+).
    pub shard: ShardStamp,
    pub steps: usize,
    pub accepted: usize,
    pub data_used: u64,
    pub guard_trips: u64,
    /// Wall seconds consumed before the checkpoint (resumed chains offset
    /// their clocks by this; the one inexact field).
    pub wall_secs: f64,
    /// PCG64 stream position (`Pcg64::state_parts`).
    pub rng: [u64; 4],
    pub samples: Vec<Sample>,
    /// `Persist`-encoded kernel state.
    pub state: Vec<u8>,
    /// `TransitionKernel::save_scratch` payload (scheduler permutations,
    /// annealing counters, ...).
    pub scratch: Vec<u8>,
}

impl ChainCheckpoint {
    pub fn encode(&self) -> Vec<u8> {
        let mut w = BinWriter::new();
        w.put_u32(CKPT_MAGIC);
        w.put_u32(CKPT_VERSION);
        w.put_usize(self.chain);
        w.put_u64(self.base_seed);
        w.put_usize(self.shard.index);
        w.put_usize(self.shard.count);
        w.put_usize(self.shard.start);
        w.put_usize(self.shard.end);
        w.put_usize(self.steps);
        w.put_usize(self.accepted);
        w.put_u64(self.data_used);
        w.put_u64(self.guard_trips);
        w.put_f64(self.wall_secs);
        for part in self.rng {
            w.put_u64(part);
        }
        self.samples.persist(&mut w);
        w.put_bytes(&self.state);
        w.put_bytes(&self.scratch);
        w.into_bytes()
    }

    pub fn decode(bytes: &[u8]) -> Result<Self, CkptError> {
        let mut r = BinReader::new(bytes);
        if r.u32()? != CKPT_MAGIC {
            return Err(CkptError::Corrupt("bad magic"));
        }
        let version = r.u32()?;
        if version != CKPT_VERSION {
            return Err(CkptError::Version { found: version });
        }
        let ck = ChainCheckpoint {
            chain: r.usize_()?,
            base_seed: r.u64()?,
            shard: ShardStamp {
                index: r.usize_()?,
                count: r.usize_()?,
                start: r.usize_()?,
                end: r.usize_()?,
            },
            steps: r.usize_()?,
            accepted: r.usize_()?,
            data_used: r.u64()?,
            guard_trips: r.u64()?,
            wall_secs: r.f64()?,
            rng: [r.u64()?, r.u64()?, r.u64()?, r.u64()?],
            samples: Vec::restore(&mut r)?,
            state: r.bytes()?.to_vec(),
            scratch: r.bytes()?.to_vec(),
        };
        if ck.shard.count == 0 || ck.shard.index >= ck.shard.count || ck.shard.start > ck.shard.end
        {
            return Err(CkptError::Corrupt("invalid shard stamp"));
        }
        r.finish()?;
        Ok(ck)
    }

    /// Write `chain-<c>.ckpt` into `dir` atomically: the payload goes to a
    /// temp file first and is renamed over the target, so an interrupted
    /// write never destroys the previous checkpoint.
    pub fn write_atomic(&self, dir: &Path) -> Result<(), CkptError> {
        let tmp = dir.join(format!("chain-{}.ckpt.tmp", self.chain));
        let bytes = self.encode();
        let mut f = fs::File::create(&tmp)?;
        f.write_all(&bytes)?;
        f.sync_all()?;
        drop(f);
        fs::rename(&tmp, chain_path(dir, self.chain))?;
        Ok(())
    }

    /// Load chain `c`'s checkpoint from `dir`. `Ok(None)` when the file
    /// does not exist (the chain never reached a checkpoint boundary —
    /// it resumes from scratch); decode failures are errors.
    pub fn load(dir: &Path, chain: usize) -> Result<Option<Self>, CkptError> {
        match fs::read(chain_path(dir, chain)) {
            Ok(bytes) => Self::decode(&bytes).map(Some),
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => Ok(None),
            Err(e) => Err(CkptError::Io(e)),
        }
    }
}

/// Checkpoint file of chain `c` under `dir`.
pub fn chain_path(dir: &Path, chain: usize) -> PathBuf {
    dir.join(format!("chain-{chain}.ckpt"))
}

/// Where and how often to checkpoint: every `every` completed steps, one
/// file per chain under `dir`.
#[derive(Clone, Debug)]
pub struct CheckpointSpec {
    pub every: usize,
    pub dir: PathBuf,
}

// ---------------------------------------------------------------------------
// Manifest (observability only — resume reads the binary files)

/// Render a float as JSON (`null` for non-finite values).
pub(crate) fn json_num(v: f64) -> String {
    if v.is_finite() {
        format!("{v}")
    } else {
        "null".to_string()
    }
}

/// Escape a string into a JSON literal.
pub(crate) fn json_str(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

/// Write `manifest.json` describing a checkpointing launch (atomically,
/// like the chain files). Purely informational: resume never parses it.
pub(crate) fn write_manifest(
    dir: &Path,
    chains: usize,
    base_seed: u64,
    burn_in: usize,
    thin: usize,
    every: usize,
    budget: &Budget,
) -> Result<(), CkptError> {
    let (kind, per_chain) = match budget {
        Budget::Steps(s) => ("steps", *s as f64),
        Budget::Wall(d) => ("wall_secs", d.as_secs_f64()),
        Budget::Data(d) => ("data", *d as f64),
    };
    let json = format!(
        "{{\"format_version\":{CKPT_VERSION},\"chains\":{chains},\"base_seed\":{base_seed},\
         \"burn_in\":{burn_in},\"thin\":{thin},\"checkpoint_every\":{every},\
         \"budget\":{{\"kind\":{},\"per_chain\":{}}}}}\n",
        json_str(kind),
        json_num(per_chain),
    );
    let tmp = dir.join("manifest.json.tmp");
    let mut f = fs::File::create(&tmp)?;
    f.write_all(json.as_bytes())?;
    f.sync_all()?;
    drop(f);
    fs::rename(&tmp, dir.join("manifest.json"))?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    fn temp_dir(tag: &str) -> PathBuf {
        static UNIQ: AtomicUsize = AtomicUsize::new(0);
        let dir = std::env::temp_dir().join(format!(
            "austerity-ckpt-{tag}-{}-{}",
            std::process::id(),
            UNIQ.fetch_add(1, Ordering::Relaxed)
        ));
        fs::create_dir_all(&dir).unwrap();
        dir
    }

    fn sample_ckpt() -> ChainCheckpoint {
        ChainCheckpoint {
            chain: 2,
            base_seed: 42,
            shard: ShardStamp { index: 1, count: 4, start: 2500, end: 5000 },
            steps: 137,
            accepted: 55,
            data_used: 12_345,
            guard_trips: 3,
            wall_secs: 0.25,
            rng: [1, u64::MAX, 3, 0xdead_beef],
            samples: vec![
                Sample { value: -0.5, at_secs: 0.1, at_data: 100 },
                Sample { value: f64::NAN, at_secs: 0.2, at_data: 200 },
            ],
            state: vec![9, 8, 7],
            scratch: vec![],
        }
    }

    #[test]
    fn roundtrip_is_exact() {
        let ck = sample_ckpt();
        let back = ChainCheckpoint::decode(&ck.encode()).unwrap();
        assert_eq!(back.chain, ck.chain);
        assert_eq!(back.base_seed, ck.base_seed);
        assert_eq!(back.shard, ck.shard);
        assert_eq!(back.steps, ck.steps);
        assert_eq!(back.accepted, ck.accepted);
        assert_eq!(back.data_used, ck.data_used);
        assert_eq!(back.guard_trips, ck.guard_trips);
        assert_eq!(back.wall_secs.to_bits(), ck.wall_secs.to_bits());
        assert_eq!(back.rng, ck.rng);
        assert_eq!(back.samples.len(), ck.samples.len());
        for (a, b) in back.samples.iter().zip(&ck.samples) {
            // NaN bit patterns included
            assert_eq!(a.value.to_bits(), b.value.to_bits());
            assert_eq!(a.at_data, b.at_data);
        }
        assert_eq!(back.state, ck.state);
        assert_eq!(back.scratch, ck.scratch);
    }

    #[test]
    fn corrupt_payloads_are_rejected_not_panicked() {
        let bytes = sample_ckpt().encode();
        // truncations at every prefix length must error, never panic
        for cut in 0..bytes.len() {
            assert!(ChainCheckpoint::decode(&bytes[..cut]).is_err(), "cut={cut}");
        }
        // bad magic
        let mut bad = bytes.clone();
        bad[0] ^= 0xff;
        assert!(matches!(ChainCheckpoint::decode(&bad), Err(CkptError::Corrupt(_))));
        // future version
        let mut vnext = bytes.clone();
        vnext[4..8].copy_from_slice(&(CKPT_VERSION + 1).to_le_bytes());
        assert!(matches!(
            ChainCheckpoint::decode(&vnext),
            Err(CkptError::Version { found }) if found == CKPT_VERSION + 1
        ));
        // trailing garbage
        let mut long = bytes.clone();
        long.push(0);
        assert!(ChainCheckpoint::decode(&long).is_err());
    }

    #[test]
    fn v1_checkpoints_are_versioned_out_not_misread() {
        // A pre-sharding (v1) file has no shard stamp; the loader must
        // refuse it by version before attempting the v2 layout.
        let mut bytes = sample_ckpt().encode();
        bytes[4..8].copy_from_slice(&1u32.to_le_bytes());
        assert!(matches!(
            ChainCheckpoint::decode(&bytes),
            Err(CkptError::Version { found: 1 })
        ));
    }

    #[test]
    fn nonsense_shard_stamps_are_corrupt() {
        let mut ck = sample_ckpt();
        ck.shard = ShardStamp { index: 4, count: 4, start: 0, end: 0 };
        assert!(matches!(
            ChainCheckpoint::decode(&ck.encode()),
            Err(CkptError::Corrupt("invalid shard stamp"))
        ));
        ck.shard = ShardStamp { index: 0, count: 0, start: 0, end: 0 };
        assert!(ChainCheckpoint::decode(&ck.encode()).is_err());
        // the default (unsharded) stamp is always valid
        ck.shard = ShardStamp::default();
        assert_eq!(ChainCheckpoint::decode(&ck.encode()).unwrap().shard, ShardStamp::default());
    }

    #[test]
    fn persist_primitives_roundtrip_bitwise() {
        let mut w = BinWriter::new();
        true.persist(&mut w);
        3.7f64.persist(&mut w);
        f64::NAN.persist(&mut w);
        (-0.0f64).persist(&mut w);
        vec![1u32, 2, 3].persist(&mut w);
        vec![true, false].persist(&mut w);
        7usize.persist(&mut w);
        ().persist(&mut w);
        let bytes = w.into_bytes();
        let mut r = BinReader::new(&bytes);
        assert!(bool::restore(&mut r).unwrap());
        assert_eq!(f64::restore(&mut r).unwrap(), 3.7);
        assert_eq!(f64::restore(&mut r).unwrap().to_bits(), f64::NAN.to_bits());
        assert_eq!(f64::restore(&mut r).unwrap().to_bits(), (-0.0f64).to_bits());
        assert_eq!(Vec::<u32>::restore(&mut r).unwrap(), vec![1, 2, 3]);
        assert_eq!(Vec::<bool>::restore(&mut r).unwrap(), vec![true, false]);
        assert_eq!(usize::restore(&mut r).unwrap(), 7);
        <()>::restore(&mut r).unwrap();
        r.finish().unwrap();
    }

    #[test]
    fn atomic_write_then_load() {
        let dir = temp_dir("atomic");
        let ck = sample_ckpt();
        assert!(ChainCheckpoint::load(&dir, 2).unwrap().is_none());
        ck.write_atomic(&dir).unwrap();
        let back = ChainCheckpoint::load(&dir, 2).unwrap().expect("present");
        assert_eq!(back.steps, ck.steps);
        // no temp droppings left behind
        assert!(!dir.join("chain-2.ckpt.tmp").exists());
        // other chains stay absent
        assert!(ChainCheckpoint::load(&dir, 0).unwrap().is_none());
        fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn manifest_is_written_and_valid_jsonish() {
        let dir = temp_dir("manifest");
        write_manifest(&dir, 4, 42, 10, 2, 50, &Budget::Steps(1_000)).unwrap();
        let text = fs::read_to_string(dir.join("manifest.json")).unwrap();
        assert!(text.contains("\"chains\":4"));
        assert!(text.contains("\"kind\":\"steps\""));
        assert_eq!(text.matches('{').count(), text.matches('}').count());
        fs::remove_dir_all(&dir).ok();
    }
}

//! The pluggable acceptance-test layer: one trait for the budgeted
//! accept/reject decision, four rules behind it.
//!
//! The paper's sequential t-test is one member of a family of budgeted
//! approximations to the exact N-point Metropolis-Hastings decision.
//! `AcceptanceTest` is that family's contract: given the proposal's
//! `log_correction`, a `MomentsSource` over the population of
//! log-likelihood differences, a without-replacement scheduler and scratch
//! buffers, decide accept/reject, report the datapoints consumed and a
//! per-stage trace. The four members:
//!
//! | rule             | decision                                            | knob |
//! |------------------|-----------------------------------------------------|------|
//! | `ExactTest`      | full scan, `mean l > mu0(u)` (paper §2)             | —    |
//! | `AusterityTest`  | sequential Student-t test (paper Alg. 1)            | eps  |
//! | `BarkerTest`     | noise-corrected minibatch Barker test (Seita et al. 2017) | sigma |
//! | `ConfidenceTest` | empirical-Bernstein adaptive subsampling (Bardenet et al.) | delta |
//!
//! **RNG contract.** Each rule consumes the per-chain stream in a fixed
//! order. `ExactTest` draws only the MH uniform `u`; `AusterityTest`
//! draws `u` then the scheduler's batch draws — exactly the order of the
//! pre-refactor `mh_step` (regression-tested in
//! `tests/integration_accept.rs`). `ConfidenceTest` draws `u` then batch
//! draws; `BarkerTest` draws no `u` (the logistic noise replaces it):
//! batch draws, then the top-up normal, then `X_corr`.
//!
//! **Index protocol.** Sequential rules feed the scheduler's drawn
//! `&[u32]` slice to `MomentsSource::batch` directly — no widening copy,
//! no staging buffer. `ExactTest` calls `MomentsSource::full_scan`,
//! which model-backed sources serve with range-based chunked scans
//! (serial or deterministically parallel — `models::traits`); closure
//! sources fall back to the gathered chunk scan through `idx_buf`.
//! Both produce identical bits by the `lldiff_range_moments` contract.
//!
//! **Bit-identity.** The cached and uncached step paths wrap the same
//! kernels (`ModelMoments` / `CachedMoments` in `coordinator::mh`), so a
//! cached chain makes decisions bit-identical to an uncached one for
//! every rule, at every scan thread count.

#![allow(clippy::too_many_arguments)]

use std::sync::Arc;

use crate::coordinator::austerity::{seq_test_core, SeqTestConfig};
use crate::coordinator::scheduler::MinibatchScheduler;
use crate::models::traits::full_scan_moments;
use crate::stats::logistic_corr::LogisticCorrection;
use crate::stats::welford::MomentAccumulator;
use crate::stats::Pcg64;

/// The population of log-likelihood differences as the acceptance rules
/// see it: gathered mini-batch moments plus a full-population scan.
/// Implemented by the model-backed sources in `coordinator::mh` (which
/// route full scans through the deterministic chunk-parallel drivers)
/// and by any `FnMut(&[u32]) -> (f64, f64)` closure (serial fallback).
pub trait MomentsSource {
    /// `(sum_i l_i, sum_i l_i^2)` over the drawn indices.
    fn batch(&mut self, idx: &[u32]) -> (f64, f64);

    /// Full-population moments in `FULL_SCAN_CHUNK` chunks reduced in
    /// chunk order. The default streams chunk index sets through
    /// `idx_buf` into `batch`; model-backed sources override with
    /// range-based (possibly parallel) scans that return identical bits.
    fn full_scan(&mut self, n_total: usize, idx_buf: &mut Vec<u32>) -> (f64, f64) {
        full_scan_moments(n_total, idx_buf, |idx| self.batch(idx))
    }
}

impl<F: FnMut(&[u32]) -> (f64, f64)> MomentsSource for F {
    fn batch(&mut self, idx: &[u32]) -> (f64, f64) {
        self(idx)
    }
}

/// One recorded stage of a decision: how much data had been consumed and
/// the rule-specific statistic/threshold pair that was compared.
///
/// * exact — `stat` = `mean - mu0`, `threshold` = 0;
/// * austerity — `stat` = Student-t tail `delta`, `threshold` = `eps_j`;
/// * barker — `stat` = estimator std of `Delta_hat`, `threshold` = sigma;
/// * confidence — `stat` = `mean - mu0`, `threshold` = Bernstein bound.
#[derive(Clone, Copy, Debug)]
pub struct StageTrace {
    pub n_used: usize,
    pub stat: f64,
    pub threshold: f64,
}

/// What a decision reported back to the step driver.
#[derive(Clone, Copy, Debug)]
pub struct AcceptOutcome {
    pub accept: bool,
    /// Datapoints examined.
    pub n_used: usize,
    /// Mini-batch stages run (1 for exact, 0 for a data-free rejection).
    pub stages: usize,
    /// Final sample mean of the l_i (NaN for a data-free rejection).
    pub mean: f64,
    /// Rule-specific final statistic (t for austerity, `Delta_hat` for
    /// barker, `mean - mu0` for exact/confidence).
    pub stat: f64,
    /// Stages whose moments tripped a numerical guard (always 0 from the
    /// bare rules; `coordinator::guard::Guarded` fills it in).
    pub guard_trips: u32,
}

impl AcceptOutcome {
    /// A proposal with zero prior mass (`log_correction = +inf`) is
    /// rejected without touching data or the scheduler.
    pub(crate) fn rejected_free() -> Self {
        AcceptOutcome {
            accept: false,
            n_used: 0,
            stages: 0,
            mean: f64::NAN,
            stat: f64::NEG_INFINITY,
            guard_trips: 0,
        }
    }
}

/// A budgeted accept/reject rule for one proposed MH move.
///
/// `moments` serves the population `(sum l, sum l^2)` — the same source
/// type for the cached and uncached step paths. Implementations must
/// clear and then fill `trace` (one entry per stage) and draw from `rng`
/// in a fixed, documented order.
pub trait AcceptanceTest {
    /// Short label for experiment CSVs and benches.
    fn name(&self) -> &'static str;

    /// Decide accept/reject for a proposal over a population of
    /// `n_total` log-likelihood differences.
    fn decide<S: MomentsSource>(
        &self,
        n_total: usize,
        log_correction: f64,
        moments: S,
        sched: &mut MinibatchScheduler,
        idx_buf: &mut Vec<u32>,
        trace: &mut Vec<StageTrace>,
        rng: &mut Pcg64,
    ) -> AcceptOutcome;
}

// ---------------------------------------------------------------------------
// Exact

/// The classic full-data MH test: `mean l > (ln u + log_correction)/N`.
#[derive(Clone, Copy, Debug, Default)]
pub struct ExactTest;

impl AcceptanceTest for ExactTest {
    fn name(&self) -> &'static str {
        "exact"
    }

    fn decide<S: MomentsSource>(
        &self,
        n_total: usize,
        log_correction: f64,
        mut moments: S,
        _sched: &mut MinibatchScheduler,
        idx_buf: &mut Vec<u32>,
        trace: &mut Vec<StageTrace>,
        rng: &mut Pcg64,
    ) -> AcceptOutcome {
        trace.clear();
        let u = rng.uniform_pos();
        if log_correction == f64::INFINITY {
            return AcceptOutcome::rejected_free();
        }
        let n = n_total as f64;
        let mu0 = (u.ln() + log_correction) / n;
        // chunked full scan (serial or deterministically parallel —
        // the source decides; results are bit-identical either way)
        let (s, _) = moments.full_scan(n_total, idx_buf);
        let mean = s / n;
        let accept = mean > mu0;
        trace.push(StageTrace { n_used: n_total, stat: mean - mu0, threshold: 0.0 });
        AcceptOutcome { accept, n_used: n_total, stages: 1, mean, stat: mean - mu0, guard_trips: 0 }
    }
}

// ---------------------------------------------------------------------------
// Austerity (paper Alg. 1)

/// The paper's sequential Student-t test as an `AcceptanceTest`. The
/// decision loop is `austerity::seq_test_core` — the same code the
/// standalone `seq_mh_test` entry points run — so porting onto the trait
/// changed no decision bits.
#[derive(Clone, Copy, Debug)]
pub struct AusterityTest {
    pub cfg: SeqTestConfig,
}

impl AusterityTest {
    pub fn new(eps: f64, batch_size: usize) -> Self {
        AusterityTest { cfg: SeqTestConfig::new(eps, batch_size) }
    }
}

impl AcceptanceTest for AusterityTest {
    fn name(&self) -> &'static str {
        "austerity"
    }

    fn decide<S: MomentsSource>(
        &self,
        n_total: usize,
        log_correction: f64,
        mut moments: S,
        sched: &mut MinibatchScheduler,
        _idx_buf: &mut Vec<u32>,
        trace: &mut Vec<StageTrace>,
        rng: &mut Pcg64,
    ) -> AcceptOutcome {
        trace.clear();
        let u = rng.uniform_pos();
        if log_correction == f64::INFINITY {
            return AcceptOutcome::rejected_free();
        }
        let mu0 = (u.ln() + log_correction) / n_total as f64;
        let out = seq_test_core(n_total, &mut moments, mu0, &self.cfg, sched, rng, Some(trace));
        AcceptOutcome {
            accept: out.accept,
            n_used: out.n_used,
            stages: out.stages,
            mean: out.mean,
            stat: out.t_stat,
            guard_trips: 0,
        }
    }
}

// ---------------------------------------------------------------------------
// Barker (Seita et al. 2017)

/// Noise-corrected minibatch Barker test.
///
/// The exact Barker rule accepts with probability
/// `g(Delta) = 1/(1 + e^-Delta)` where `Delta = N*mean(l) -
/// log_correction` is the log MH ratio — equivalently, accept iff
/// `Delta + V > 0` with `V ~ Logistic(0, 1)`. The minibatch estimate
/// `Delta_hat` already carries ~`N(0, sd^2)` subsampling noise; the test
/// grows the sample until `sd <= sigma`, tops the noise up to exactly
/// `sigma` with an extra normal draw, and adds `X_corr ~ C_sigma`
/// (`stats::logistic_corr`) so the total perturbation is logistic.
/// Exhausting the population degenerates to the *exact* Barker test
/// (sd = 0, full-noise draw), so the decision is always well defined.
#[derive(Clone, Debug)]
pub struct BarkerTest {
    /// Target noise level sigma of the corrected decision (<= 1.1).
    pub sigma: f64,
    /// Mini-batch increment m.
    pub batch_size: usize,
    corr: Arc<LogisticCorrection>,
}

impl BarkerTest {
    pub fn new(sigma: f64, batch_size: usize) -> Self {
        assert!(batch_size >= 2, "barker batch_size >= 2");
        BarkerTest { sigma, batch_size, corr: LogisticCorrection::shared(sigma) }
    }

    /// The tabulated correction distribution backing this test.
    pub fn correction(&self) -> &LogisticCorrection {
        &self.corr
    }
}

impl AcceptanceTest for BarkerTest {
    fn name(&self) -> &'static str {
        "barker"
    }

    fn decide<S: MomentsSource>(
        &self,
        n_total: usize,
        log_correction: f64,
        mut moments: S,
        sched: &mut MinibatchScheduler,
        _idx_buf: &mut Vec<u32>,
        trace: &mut Vec<StageTrace>,
        rng: &mut Pcg64,
    ) -> AcceptOutcome {
        trace.clear();
        if log_correction == f64::INFINITY {
            return AcceptOutcome::rejected_free();
        }
        let n = n_total as f64;
        sched.reset();
        let mut acc = MomentAccumulator::new();
        let mut stages = 0usize;
        loop {
            let batch = sched.next_batch(self.batch_size, rng);
            let drawn = batch.len();
            debug_assert!(drawn > 0, "population exhausted without decision");
            let (s, s2) = moments.batch(batch);
            acc.add_batch(s, s2, drawn);
            stages += 1;

            let used = acc.n();
            // std of Delta_hat = N * mean(l_batch): finite-population
            // corrected, exactly 0 once the scan is complete
            let sd = n * acc.mean_std_fpc(n_total);
            trace.push(StageTrace { n_used: used, stat: sd, threshold: self.sigma });

            if sd <= self.sigma || used == n_total {
                let delta_hat = n * acc.mean() - log_correction;
                let top_up = (self.sigma * self.sigma - sd * sd).max(0.0);
                let x_nc = if top_up > 0.0 { top_up.sqrt() * rng.normal() } else { 0.0 };
                let x_corr = self.corr.sample(rng);
                return AcceptOutcome {
                    accept: delta_hat + x_nc + x_corr > 0.0,
                    n_used: used,
                    stages,
                    mean: acc.mean(),
                    stat: delta_hat,
                    guard_trips: 0,
                };
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Confidence sampler (Bardenet, Doucet & Holmes)

/// Configuration of the empirical-Bernstein confidence test.
#[derive(Clone, Copy, Debug)]
pub struct ConfidenceConfig {
    /// Total wrong-decision budget per test; the stage schedule spends
    /// `delta_t = delta / 2^t`.
    pub delta: f64,
    /// First mini-batch size; later batches grow geometrically.
    pub batch_size: usize,
    /// Batch growth factor (Bardenet et al. recommend geometric growth).
    pub grow: f64,
    /// A-priori bound on the range of the l_i (the paper's
    /// C_{theta,theta'}). `None` falls back to `range_kappa *
    /// sample_std` — the practical variant when no Lipschitz bound is
    /// available (heuristic: the bound is then only approximate).
    pub range: Option<f64>,
    /// Multiplier for the empirical range fallback.
    pub range_kappa: f64,
}

impl ConfidenceConfig {
    pub fn new(delta: f64, batch_size: usize) -> Self {
        assert!(delta > 0.0 && delta < 1.0, "confidence delta in (0, 1): got {delta}");
        assert!(batch_size >= 2, "confidence batch_size >= 2");
        ConfidenceConfig { delta, batch_size, grow: 2.0, range: None, range_kappa: 4.0 }
    }

    /// Use a known bound on the spread of the l_i instead of the
    /// empirical fallback.
    pub fn with_range(mut self, range: f64) -> Self {
        assert!(range > 0.0);
        self.range = Some(range);
        self
    }
}

/// Bardenet-style adaptive subsampling: stop as soon as the
/// empirical-Bernstein concentration bound
/// `c_n = sigma_hat * sqrt(2 log(3/delta_t)/n) + 6 R log(3/delta_t)/n`
/// separates the running mean from `mu0`; the exact decision is forced
/// when the scan completes.
#[derive(Clone, Copy, Debug)]
pub struct ConfidenceTest {
    pub cfg: ConfidenceConfig,
}

impl ConfidenceTest {
    pub fn new(delta: f64, batch_size: usize) -> Self {
        ConfidenceTest { cfg: ConfidenceConfig::new(delta, batch_size) }
    }
}

impl AcceptanceTest for ConfidenceTest {
    fn name(&self) -> &'static str {
        "confidence"
    }

    fn decide<S: MomentsSource>(
        &self,
        n_total: usize,
        log_correction: f64,
        mut moments: S,
        sched: &mut MinibatchScheduler,
        _idx_buf: &mut Vec<u32>,
        trace: &mut Vec<StageTrace>,
        rng: &mut Pcg64,
    ) -> AcceptOutcome {
        trace.clear();
        let u = rng.uniform_pos();
        if log_correction == f64::INFINITY {
            return AcceptOutcome::rejected_free();
        }
        let n = n_total as f64;
        let mu0 = (u.ln() + log_correction) / n;
        sched.reset();
        let mut acc = MomentAccumulator::new();
        let mut stages = 0usize;
        let mut want = self.cfg.batch_size;
        loop {
            let batch = sched.next_batch(want, rng);
            let drawn = batch.len();
            debug_assert!(drawn > 0, "population exhausted without decision");
            let (s, s2) = moments.batch(batch);
            acc.add_batch(s, s2, drawn);
            stages += 1;

            let used = acc.n();
            let mean = acc.mean();
            if used == n_total {
                // complete scan: the decision is exact
                trace.push(StageTrace { n_used: used, stat: mean - mu0, threshold: 0.0 });
                return AcceptOutcome {
                    accept: mean > mu0,
                    n_used: used,
                    stages,
                    mean,
                    stat: mean - mu0,
                    guard_trips: 0,
                };
            }
            let sigma_hat = acc.sample_std();
            // geometric error spending: sum_t delta/2^t < delta
            let delta_t = self.cfg.delta / (1u64 << stages.min(50)) as f64;
            let log3d = (3.0 / delta_t).ln();
            let range = self.cfg.range.unwrap_or(self.cfg.range_kappa * sigma_hat);
            let un = used as f64;
            let bound = sigma_hat * (2.0 * log3d / un).sqrt() + 6.0 * range * log3d / un;
            trace.push(StageTrace { n_used: used, stat: mean - mu0, threshold: bound });
            if (mean - mu0).abs() > bound {
                return AcceptOutcome {
                    accept: mean > mu0,
                    n_used: used,
                    stages,
                    mean,
                    stat: mean - mu0,
                    guard_trips: 0,
                };
            }
            want = (want as f64 * self.cfg.grow).ceil() as usize;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::traits::testutil::FixedPopulation;
    use crate::models::traits::LlDiffModel;
    use crate::stats::logistic_corr::logistic_cdf;

    /// Run one decision of `test` against a fixed l-population.
    fn decide_once<T: AcceptanceTest>(
        test: &T,
        model: &FixedPopulation,
        log_correction: f64,
        rng: &mut Pcg64,
        sched: &mut MinibatchScheduler,
        buf: &mut Vec<u32>,
        trace: &mut Vec<StageTrace>,
    ) -> AcceptOutcome {
        test.decide(
            model.n(),
            log_correction,
            |idx: &[u32]| model.lldiff_moments(idx, &(), &()),
            sched,
            buf,
            trace,
            rng,
        )
    }

    fn harness(n: usize) -> (MinibatchScheduler, Vec<u32>, Vec<StageTrace>) {
        (MinibatchScheduler::new(n).expect("population exceeds the u32 index space"), Vec::new(), Vec::new())
    }

    #[test]
    fn exact_test_acceptance_rate_matches_formula() {
        // Pa = min(1, exp(N*l - c))
        let n = 40;
        let (l, c) = (0.01, 0.6f64);
        let want = (n as f64 * l - c).exp(); // ~0.819
        let model = FixedPopulation { ls: vec![l; n] };
        let (mut sched, mut buf, mut trace) = harness(n);
        let mut rng = Pcg64::seeded(3);
        let mut acc = 0usize;
        let trials = 40_000;
        for _ in 0..trials {
            let out = decide_once(&ExactTest, &model, c, &mut rng, &mut sched, &mut buf, &mut trace);
            assert_eq!(out.n_used, n);
            assert_eq!(out.stages, 1);
            acc += out.accept as usize;
        }
        let rate = acc as f64 / trials as f64;
        assert!((rate - want).abs() < 0.01, "rate {rate} want {want}");
    }

    #[test]
    fn infinite_correction_rejects_without_data_for_every_rule() {
        let model = FixedPopulation { ls: vec![1.0; 64] };
        let (mut sched, mut buf, mut trace) = harness(64);
        let mut rng = Pcg64::seeded(0);
        let exact = ExactTest;
        let aust = AusterityTest::new(0.05, 8);
        let barker = BarkerTest::new(1.0, 8);
        let conf = ConfidenceTest::new(0.05, 8);

        macro_rules! check {
            ($t:expr) => {{
                let out = decide_once(
                    &$t,
                    &model,
                    f64::INFINITY,
                    &mut rng,
                    &mut sched,
                    &mut buf,
                    &mut trace,
                );
                assert!(!out.accept);
                assert_eq!(out.n_used, 0);
                assert_eq!(out.stages, 0);
            }};
        }
        check!(exact);
        check!(aust);
        check!(barker);
        check!(conf);
    }

    #[test]
    fn austerity_trait_is_bit_identical_to_seq_mh_test() {
        // the trait port must replay the standalone entry point exactly:
        // same u draw, same scheduler draws, same decision
        use crate::coordinator::austerity::seq_mh_test;
        let mut gen = Pcg64::seeded(11);
        let n = 4_000;
        let ls: Vec<f64> = (0..n).map(|_| 0.001 + 0.02 * gen.normal()).collect();
        let model = FixedPopulation { ls };
        let test = AusterityTest::new(0.05, 300);
        for seed in 0..20u64 {
            let (mut sched_a, mut buf_a, mut trace) = harness(n);
            let mut rng_a = Pcg64::new(77, seed);
            let out_a =
                decide_once(&test, &model, 0.3, &mut rng_a, &mut sched_a, &mut buf_a, &mut trace);

            let mut rng_b = Pcg64::new(77, seed);
            let u = rng_b.uniform_pos();
            let mu0 = (u.ln() + 0.3) / n as f64;
            let mut sched_b = MinibatchScheduler::new(n).expect("population exceeds the u32 index space");
            let out_b = seq_mh_test(&model, &(), &(), mu0, &test.cfg, &mut sched_b, &mut rng_b);
            assert_eq!(out_a.accept, out_b.accept, "seed {seed}");
            assert_eq!(out_a.n_used, out_b.n_used, "seed {seed}");
            assert_eq!(out_a.stages, out_b.stages, "seed {seed}");
            assert_eq!(out_a.stat.to_bits(), out_b.t_stat.to_bits(), "seed {seed}");
            assert_eq!(out_a.stages, trace.len(), "trace records every stage");
            // the two generators must be in the same stream position
            assert_eq!(rng_a.next_u64(), rng_b.next_u64());
        }
    }

    #[test]
    fn barker_acceptance_matches_logistic_probability() {
        // constant population: zero variance => the first batch pins the
        // mean exactly, the decision is the exact Barker rule, so the
        // acceptance rate must be logistic(Delta).
        let n = 400;
        let l = 0.005;
        let c = 1.0;
        let delta = n as f64 * l - c; // = 1.0
        let want = logistic_cdf(delta);
        let model = FixedPopulation { ls: vec![l; n] };
        let test = BarkerTest::new(1.0, 100);
        let (mut sched, mut buf, mut trace) = harness(n);
        let mut rng = Pcg64::seeded(5);
        let trials = 40_000;
        let mut acc = 0usize;
        for _ in 0..trials {
            let out = decide_once(&test, &model, c, &mut rng, &mut sched, &mut buf, &mut trace);
            assert_eq!(out.n_used, 100);
            assert_eq!(out.stages, 1);
            assert!((out.stat - delta).abs() < 1e-9);
            acc += out.accept as usize;
        }
        let rate = acc as f64 / trials as f64;
        assert!((rate - want).abs() < 0.012, "rate {rate} want {want}");
    }

    #[test]
    fn barker_consumes_more_data_when_noisy() {
        let mut gen = Pcg64::seeded(1);
        let n = 10_000;
        // per-point spread large enough that one batch of 500 leaves
        // sd(Delta_hat) = N*sigma_l/sqrt(500) ~ 4.5 >> 1
        let ls: Vec<f64> = (0..n).map(|_| 0.01 * gen.normal()).collect();
        let model = FixedPopulation { ls };
        let test = BarkerTest::new(1.0, 500);
        let (mut sched, mut buf, mut trace) = harness(n);
        let mut rng = Pcg64::seeded(2);
        let out = decide_once(&test, &model, 0.0, &mut rng, &mut sched, &mut buf, &mut trace);
        assert!(out.stages > 1, "stages {}", out.stages);
        assert_eq!(out.stages, trace.len());
        // trace sds decrease toward the sigma target
        for w in trace.windows(2) {
            assert!(w[1].stat <= w[0].stat * 1.5, "sd should shrink: {trace:?}");
        }
    }

    #[test]
    fn barker_exhausts_to_exact_barker_on_hard_populations() {
        // spread so large the sd target is unreachable: the test must
        // run to n = N and still decide (sd -> 0 via the fpc).
        let mut gen = Pcg64::seeded(3);
        let n = 300;
        let ls: Vec<f64> = (0..n).map(|_| 0.5 * gen.normal()).collect();
        let model = FixedPopulation { ls };
        let test = BarkerTest::new(0.5, 100);
        let (mut sched, mut buf, mut trace) = harness(n);
        let mut rng = Pcg64::seeded(4);
        let out = decide_once(&test, &model, 0.0, &mut rng, &mut sched, &mut buf, &mut trace);
        assert_eq!(out.n_used, n);
        assert_eq!(out.stages, 3);
    }

    #[test]
    fn confidence_obvious_cases_decide_on_first_batch() {
        let mut gen = Pcg64::seeded(6);
        let n = 10_000;
        let ls: Vec<f64> = (0..n).map(|_| 1.0 + 0.01 * gen.normal()).collect();
        let model = FixedPopulation { ls };
        let test = ConfidenceTest::new(0.05, 500);
        let (mut sched, mut buf, mut trace) = harness(n);
        let mut rng = Pcg64::seeded(7);
        // mu0 far below the mean: ln u < 0 so mu0 <= -something/n < 1
        let out = decide_once(&test, &model, 0.0, &mut rng, &mut sched, &mut buf, &mut trace);
        assert!(out.accept);
        assert_eq!(out.stages, 1);
        assert_eq!(out.n_used, 500);
    }

    #[test]
    fn confidence_exhaustion_matches_exact_decision() {
        crate::testkit::forall(32, |gen| {
            let n = gen.below(1_500) + 64;
            let ls: Vec<f64> = (0..n).map(|_| gen.normal()).collect();
            let mean = ls.iter().sum::<f64>() / n as f64;
            let model = FixedPopulation { ls };
            // log_correction that puts mu0 within a hair of the mean for
            // u ~ 1: ln(u) ~ -1 typical; pick c = mean * n so mu0 =
            // mean + ln(u)/n, forcing many stages
            let c = mean * n as f64;
            let test = ConfidenceTest::new(1e-6, 64);
            let (mut sched, mut buf, mut trace) = harness(n);
            let seed = gen.next_u64();
            let mut rng = Pcg64::seeded(seed);
            let out = decide_once(&test, &model, c, &mut rng, &mut sched, &mut buf, &mut trace);
            // replay the u draw to recover mu0 and the exact decision
            let mut rng2 = Pcg64::seeded(seed);
            let mu0 = (rng2.uniform_pos().ln() + c) / n as f64;
            assert_eq!(out.accept, mean > mu0, "decision must match exact");
            if out.n_used == n {
                assert_eq!(out.stages, trace.len());
            }
        });
    }

    #[test]
    fn confidence_tighter_delta_uses_no_less_data() {
        // the stage schedule is delta-independent, so runs share scheduler
        // prefixes and a tighter budget can only stop later
        let mut gen = Pcg64::seeded(8);
        let n = 8_000;
        let shift = 0.02;
        let ls: Vec<f64> = (0..n).map(|_| shift + gen.normal()).collect();
        let model = FixedPopulation { ls };
        let mut used = Vec::new();
        for &delta in &[1e-6, 1e-3, 0.2] {
            let test = ConfidenceTest::new(delta, 200);
            let (mut sched, mut buf, mut trace) = harness(n);
            let mut rng = Pcg64::seeded(99);
            let out = decide_once(&test, &model, 0.0, &mut rng, &mut sched, &mut buf, &mut trace);
            used.push(out.n_used);
        }
        assert!(used[0] >= used[1] && used[1] >= used[2], "{used:?}");
    }

    #[test]
    fn confidence_wrong_decision_rate_bounded() {
        // clear-margin population: the wrong-decision rate must be far
        // below the delta budget
        let mut gen = Pcg64::seeded(9);
        let n = 20_000;
        let ls: Vec<f64> = (0..n).map(|_| 0.05 + gen.normal()).collect();
        let mean = ls.iter().sum::<f64>() / n as f64;
        let model = FixedPopulation { ls };
        let test = ConfidenceTest::new(0.05, 500);
        let (mut sched, mut buf, mut trace) = harness(n);
        let mut wrong = 0usize;
        let trials = 200;
        for s in 0..trials {
            let mut rng = Pcg64::new(1_000 + s, 0);
            let mut rng2 = Pcg64::new(1_000 + s, 0);
            let out = decide_once(&test, &model, 0.0, &mut rng, &mut sched, &mut buf, &mut trace);
            let mu0 = rng2.uniform_pos().ln() / n as f64;
            wrong += (out.accept != (mean > mu0)) as usize;
        }
        assert!(wrong <= 15, "wrong {wrong}/{trials}");
    }

    #[test]
    fn trace_has_one_entry_per_stage_for_every_rule() {
        let mut gen = Pcg64::seeded(10);
        let n = 3_000;
        let ls: Vec<f64> = (0..n).map(|_| 0.002 + 0.05 * gen.normal()).collect();
        let model = FixedPopulation { ls };
        let (mut sched, mut buf, mut trace) = harness(n);
        let mut rng = Pcg64::seeded(12);
        let aust = AusterityTest::new(0.01, 250);
        let barker = BarkerTest::new(1.0, 250);
        let conf = ConfidenceTest::new(0.01, 250);
        macro_rules! check {
            ($t:expr) => {{
                let out =
                    decide_once(&$t, &model, 0.0, &mut rng, &mut sched, &mut buf, &mut trace);
                assert_eq!(out.stages, trace.len(), "{}", $t.name());
                assert!(trace.iter().all(|s| s.n_used > 0));
            }};
        }
        check!(ExactTest);
        check!(aust);
        check!(barker);
        check!(conf);
    }
}

//! Persistent work-sharing executor: one long-lived pool of parked
//! worker threads that both the engine's K-chain fan-out and the
//! chains' intra-step scan spans draw from, replacing the per-launch
//! and per-step `std::thread::scope` spawns (OS-thread churn on the
//! exact-rule hot path).
//!
//! Task model — a chunk queue over parked workers:
//!
//! * An [`Executor::scope`] call publishes one *job*: `tasks` closure
//!   invocations indexed `0..tasks`, claimed one index at a time from a
//!   shared counter. Pool workers (and the submitting thread, which
//!   always helps) claim the next unclaimed index, run it, and repeat —
//!   a deque-free cousin of work stealing: idle workers pull from
//!   whichever live job still has unclaimed tasks, so spare capacity
//!   flows to whoever has work left, across concurrent launches.
//! * **Determinism**: task `i` always receives index `i`; *which
//!   thread* runs it is scheduling-dependent, so reproducibility is the
//!   task function's contract (the scan layer ties every result bit to
//!   the chunk index, never to the thread; see DESIGN.md §Executor
//!   layer).
//! * **Blocking discipline**: scan-span tasks are leaves (they never
//!   block); a chain task blocks only on its *own* scan scopes; and a
//!   submitter claims only from its own job while waiting, so it can
//!   always drain the scope without a single pool worker. Every scope
//!   therefore completes even on a pool far smaller than the submitted
//!   parallelism (the oversubscription guarantee).
//! * **Panics**: every task runs under `catch_unwind`; the first panic
//!   payload of a job is re-raised in the submitting thread once the
//!   job has fully drained, so a panicking scan span surfaces inside
//!   its chain's task and downs only that chain (the engine's per-chain
//!   isolation is itself a task-level `catch_unwind` on this pool).

use std::any::Any;
use std::collections::VecDeque;
use std::fmt;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::{Arc, Condvar, Mutex, MutexGuard, OnceLock};

/// Poison-proof lock. Pool code never runs user closures while holding
/// a lock, so poisoning cannot indicate a broken invariant here — and a
/// panicking task must not wedge every later launch.
fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

/// One published scope: `tasks` closure invocations behind a claim
/// counter. `f` is the submitting stack frame's closure with its
/// lifetime erased; see the SAFETY argument in
/// [`Executor::scope_capped`].
struct Job {
    tasks: usize,
    /// At most this many tasks of the job in flight at once.
    cap: usize,
    f: &'static (dyn Fn(usize) + Sync),
    prog: Mutex<JobProg>,
    /// Signalled on every task completion; the submitter waits here.
    done_cv: Condvar,
}

struct JobProg {
    /// Next unclaimed task index (claims are handed out in order).
    next: usize,
    running: usize,
    done: usize,
    /// First panic payload observed among this job's tasks.
    panic: Option<Box<dyn Any + Send>>,
}

enum Claim {
    Task(usize),
    /// At the concurrency cap right now; may become claimable later.
    Saturated,
    /// Every task claimed; nothing left for anyone.
    Drained,
}

impl Job {
    fn try_claim(&self) -> Claim {
        let mut p = lock(&self.prog);
        if p.next >= self.tasks {
            return Claim::Drained;
        }
        if p.running >= self.cap {
            return Claim::Saturated;
        }
        p.next += 1;
        p.running += 1;
        Claim::Task(p.next - 1)
    }

    /// Run claimed task `i`, record its completion, wake the submitter,
    /// and — if the job still has unclaimed tasks — re-wake the pool so
    /// the freed cap slot is refilled.
    fn run_claimed(&self, i: usize, shared: &Shared) {
        let result = catch_unwind(AssertUnwindSafe(|| (self.f)(i)));
        let mut p = lock(&self.prog);
        p.running -= 1;
        p.done += 1;
        if let Err(payload) = result {
            if p.panic.is_none() {
                p.panic = Some(payload);
            }
        }
        let more = p.next < self.tasks;
        drop(p);
        self.done_cv.notify_all();
        if more {
            // lock-then-notify so a worker that just found every job
            // saturated cannot park between our update and the wakeup
            let _st = lock(&shared.state);
            shared.work_cv.notify_all();
        }
    }
}

struct PoolState {
    /// Live jobs in submission order; drained entries are pruned lazily
    /// by scanning workers and eagerly by their submitter at scope exit.
    queue: VecDeque<Arc<Job>>,
    workers: usize,
    shutdown: bool,
}

struct Shared {
    state: Mutex<PoolState>,
    /// Signalled when the queue gains claimable work (job pushed, cap
    /// slot freed) and at shutdown.
    work_cv: Condvar,
}

fn worker_loop(shared: Arc<Shared>) {
    let mut st = lock(&shared.state);
    loop {
        if st.shutdown {
            return;
        }
        // oldest job with a claimable task wins (FIFO keeps chain tasks
        // ahead of scan spans submitted after them, and launches fair)
        let mut claimed = None;
        let mut i = 0;
        while i < st.queue.len() {
            let job = Arc::clone(&st.queue[i]);
            match job.try_claim() {
                Claim::Task(t) => {
                    claimed = Some((job, t));
                    break;
                }
                Claim::Saturated => i += 1,
                Claim::Drained => {
                    st.queue.remove(i);
                }
            }
        }
        match claimed {
            Some((job, mut t)) => {
                drop(st);
                // greedily stay on the same job while it has work:
                // span tasks of one scan then run back to back with
                // their columns streaming through the same core
                loop {
                    job.run_claimed(t, &shared);
                    match job.try_claim() {
                        Claim::Task(nt) => t = nt,
                        _ => break,
                    }
                }
                st = lock(&shared.state);
            }
            None => st = shared.work_cv.wait(st).unwrap_or_else(|e| e.into_inner()),
        }
    }
}

struct PoolOwner {
    shared: Arc<Shared>,
}

impl Drop for PoolOwner {
    fn drop(&mut self) {
        let mut st = lock(&self.shared.state);
        st.shutdown = true;
        drop(st);
        self.shared.work_cv.notify_all();
    }
}

/// Cloneable handle to a persistent worker pool. All clones share the
/// same workers; the threads exit when the last handle drops (the
/// process-wide [`Executor::global`] pool lives for the program).
#[derive(Clone)]
pub struct Executor {
    owner: Arc<PoolOwner>,
}

impl fmt::Debug for Executor {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("Executor").field("workers", &self.workers()).finish()
    }
}

static GLOBAL: OnceLock<Executor> = OnceLock::new();

impl Executor {
    /// A pool with exactly `workers` background threads. The submitting
    /// thread of every [`Executor::scope`] also runs tasks, so `new(W)`
    /// gives a single scope `W + 1`-way parallelism — and `new(0)` is a
    /// valid, purely submitter-driven pool.
    pub fn new(workers: usize) -> Self {
        let exec = Executor {
            owner: Arc::new(PoolOwner {
                shared: Arc::new(Shared {
                    state: Mutex::new(PoolState {
                        queue: VecDeque::new(),
                        workers: 0,
                        shutdown: false,
                    }),
                    work_cv: Condvar::new(),
                }),
            }),
        };
        exec.ensure_workers(workers);
        exec
    }

    /// The process-wide shared pool: every launch and pooled scan that
    /// does not pin an explicit pool multiplexes over this one, so many
    /// small concurrent sessions share fixed hardware instead of each
    /// spawning its own threads.
    pub fn global() -> Executor {
        GLOBAL.get_or_init(|| Executor::new(0)).clone()
    }

    /// Grow the pool to at least `workers` background threads (never
    /// shrinks; idle threads park on a condvar and cost nothing on the
    /// hot path).
    pub fn ensure_workers(&self, workers: usize) {
        let shared = &self.owner.shared;
        let mut st = lock(&shared.state);
        while st.workers < workers {
            let id = st.workers;
            st.workers += 1;
            let sh = Arc::clone(shared);
            std::thread::Builder::new()
                .name(format!("austerity-worker-{id}"))
                .spawn(move || worker_loop(sh))
                .expect("executor: cannot spawn pool worker");
        }
    }

    /// Current background-thread count.
    pub fn workers(&self) -> usize {
        lock(&self.owner.shared.state).workers
    }

    /// Run `f(i)` for every `i in 0..tasks` across the pool and the
    /// calling thread, returning when all of them have finished. Every
    /// task runs exactly once even if some panic; the first panic
    /// payload is re-raised here after the job drains.
    pub fn scope<F>(&self, tasks: usize, f: F)
    where
        F: Fn(usize) + Sync,
    {
        self.scope_capped(tasks, usize::MAX, f);
    }

    /// [`Executor::scope`] with at most `cap` tasks in flight at once —
    /// the engine uses this to honour a `threads` limit below the chain
    /// count without giving up dynamic task claiming.
    pub fn scope_capped<F>(&self, tasks: usize, cap: usize, f: F)
    where
        F: Fn(usize) + Sync,
    {
        if tasks == 0 {
            return;
        }
        let cap = cap.max(1);
        if tasks == 1 || cap == 1 || self.workers() == 0 {
            // nothing to hand out: run inline, preserving the pooled
            // contract (every task runs; first panic re-raised at the
            // end)
            let mut first_panic: Option<Box<dyn Any + Send>> = None;
            for i in 0..tasks {
                if let Err(p) = catch_unwind(AssertUnwindSafe(|| f(i))) {
                    first_panic.get_or_insert(p);
                }
            }
            if let Some(p) = first_panic {
                resume_unwind(p);
            }
            return;
        }
        let f_ref: &(dyn Fn(usize) + Sync) = &f;
        // SAFETY: the erased borrow is only dereferenced by claimed
        // tasks, claims stop at `tasks`, and this frame does not return
        // until `done == tasks` — every task's completion happens-before
        // the final `done` read in the wait loop below (both under
        // `prog`). Queue stragglers holding the drained job afterwards
        // only read its counters, never `f`.
        let f_static: &'static (dyn Fn(usize) + Sync) = unsafe {
            std::mem::transmute::<&(dyn Fn(usize) + Sync), &'static (dyn Fn(usize) + Sync)>(f_ref)
        };
        let job = Arc::new(Job {
            tasks,
            cap,
            f: f_static,
            prog: Mutex::new(JobProg { next: 0, running: 0, done: 0, panic: None }),
            done_cv: Condvar::new(),
        });
        let shared = &self.owner.shared;
        {
            let mut st = lock(&shared.state);
            st.queue.push_back(Arc::clone(&job));
        }
        shared.work_cv.notify_all();
        // help-first: the submitter claims from its OWN job only, so it
        // can always drain the scope without any pool worker and never
        // wanders into another scope's (possibly blocking) tasks.
        let payload = {
            let mut p = lock(&job.prog);
            loop {
                if p.next < tasks && p.running < cap {
                    let t = p.next;
                    p.next += 1;
                    p.running += 1;
                    drop(p);
                    job.run_claimed(t, shared);
                    p = lock(&job.prog);
                } else if p.done == tasks {
                    break p.panic.take();
                } else {
                    p = job.done_cv.wait(p).unwrap_or_else(|e| e.into_inner());
                }
            }
        };
        // eagerly drop the drained job from the queue (workers also
        // prune lazily; this keeps the queue short and the erased
        // closure unreachable the moment the scope ends)
        {
            let mut st = lock(&shared.state);
            st.queue.retain(|j| !Arc::ptr_eq(j, &job));
        }
        if let Some(p) = payload {
            resume_unwind(p);
        }
    }
}

/// Intra-step parallelism grant for one chain: how many scan spans its
/// full scans may run concurrently (`width`) and the pool those spans
/// run on. Carried into `TransitionKernel::scratch_par` so kernels size
/// their scan workspace against the right pool; a grant wider than the
/// pool just multiplexes (completion is guaranteed by the blocking
/// discipline above).
#[derive(Clone, Debug)]
pub struct IntraPar {
    width: usize,
    exec: Option<Executor>,
}

impl IntraPar {
    /// No intra-step parallelism: scans run serially on the chain's
    /// thread, touching no pool at all.
    pub fn serial() -> Self {
        IntraPar { width: 1, exec: None }
    }

    /// Up to `width` concurrent spans drawn from the shared global pool
    /// (grown to `width - 1` background workers up front, so no scan
    /// ever pays thread construction).
    pub fn threads(width: usize) -> Self {
        let width = width.max(1);
        if width == 1 {
            return Self::serial();
        }
        let exec = Executor::global();
        exec.ensure_workers(width - 1);
        IntraPar { width, exec: Some(exec) }
    }

    /// Up to `width` concurrent spans drawn from a specific pool, taken
    /// as-is (the engine hands launches their pinned pool through
    /// here).
    pub fn on(width: usize, exec: Executor) -> Self {
        IntraPar { width: width.max(1), exec: Some(exec) }
    }

    /// Maximum concurrent scan spans this grant allows.
    pub fn width(&self) -> usize {
        self.width
    }

    /// The pool spans run on (`None` for a serial grant).
    pub fn executor(&self) -> Option<&Executor> {
        self.exec.as_ref()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::time::Duration;

    #[test]
    fn every_task_runs_exactly_once_for_any_pool_size() {
        for workers in [0usize, 1, 3, 8] {
            let pool = Executor::new(workers);
            let hits: Vec<AtomicUsize> = (0..97).map(|_| AtomicUsize::new(0)).collect();
            pool.scope(97, |i| {
                hits[i].fetch_add(1, Ordering::Relaxed);
            });
            assert!(
                hits.iter().all(|h| h.load(Ordering::Relaxed) == 1),
                "workers {workers}"
            );
        }
    }

    #[test]
    fn cap_bounds_in_flight_tasks() {
        let pool = Executor::new(7);
        let live = AtomicUsize::new(0);
        let peak = AtomicUsize::new(0);
        pool.scope_capped(40, 3, |_| {
            let now = live.fetch_add(1, Ordering::SeqCst) + 1;
            peak.fetch_max(now, Ordering::SeqCst);
            std::thread::sleep(Duration::from_millis(1));
            live.fetch_sub(1, Ordering::SeqCst);
        });
        let peak = peak.load(Ordering::SeqCst);
        assert!((1..=3).contains(&peak), "peak {peak}");
    }

    #[test]
    fn first_panic_reaches_the_submitter_after_the_job_drains() {
        let pool = Executor::new(2);
        let ran = AtomicUsize::new(0);
        let err = catch_unwind(AssertUnwindSafe(|| {
            pool.scope(11, |i| {
                ran.fetch_add(1, Ordering::SeqCst);
                if i == 4 {
                    panic!("span 4 died");
                }
            });
        }))
        .expect_err("the scope must re-raise");
        assert_eq!(ran.load(Ordering::SeqCst), 11, "the other tasks still run");
        let msg = err.downcast_ref::<&str>().copied().unwrap_or_default();
        assert_eq!(msg, "span 4 died");
    }

    #[test]
    fn nested_scopes_complete_on_an_undersized_pool() {
        // 4 outer tasks each opening a 4-task inner scope on a 1-worker
        // pool: submitters drain their own scopes, so no claim
        // interleaving can deadlock this.
        let pool = Executor::new(1);
        let total = AtomicUsize::new(0);
        pool.scope(4, |_| {
            pool.scope(4, |_| {
                total.fetch_add(1, Ordering::Relaxed);
            });
        });
        assert_eq!(total.load(Ordering::Relaxed), 16);
    }

    #[test]
    fn concurrent_submitters_share_one_pool() {
        let pool = Executor::new(2);
        let total = AtomicUsize::new(0);
        std::thread::scope(|s| {
            for _ in 0..3 {
                let pool = pool.clone();
                let total = &total;
                s.spawn(move || {
                    pool.scope(50, |_| {
                        total.fetch_add(1, Ordering::Relaxed);
                    });
                });
            }
        });
        assert_eq!(total.load(Ordering::Relaxed), 150);
    }

    #[test]
    fn pool_only_grows() {
        let pool = Executor::new(2);
        pool.ensure_workers(1);
        assert_eq!(pool.workers(), 2);
        pool.ensure_workers(4);
        assert_eq!(pool.workers(), 4);
    }

    #[test]
    fn serial_grant_touches_no_pool() {
        let g = IntraPar::serial();
        assert_eq!(g.width(), 1);
        assert!(g.executor().is_none());
        assert!(IntraPar::threads(1).executor().is_none());
        let wide = IntraPar::threads(3);
        assert_eq!(wide.width(), 3);
        assert!(wide.executor().is_some());
    }
}

//! The unified front-end of the sampling stack: one builder
//! ([`Session`] for Metropolis-Hastings over a model, [`KernelSession`]
//! for any [`TransitionKernel`]) that configures a multi-chain launch
//! and returns one typed [`RunReport`].
//!
//! `Session::run` picks the engine path itself: models with a
//! per-datapoint likelihood cache (`CachedLlDiff` — e.g. the logistic
//! and linear-regression workloads) run the cached fast path, everything
//! else the uncached kernel, through the model-side
//! `LlDiffModel::session_launch` hook. The choice never changes results:
//! cached and uncached decisions are bit-identical by contract, and a
//! `Session` launch replays the legacy `run_engine*` / `run_chain*`
//! entry points bit for bit under the same seed (the oracle contract of
//! `tests/integration_session.rs`).
//!
//! ```text
//! let report = Session::new(&model)
//!     .kernel(&proposal)
//!     .rule(MhMode::confidence(0.05, 500))
//!     .chains(4)
//!     .seed(7)
//!     .budget(Budget::Data(5_000_000))
//!     .burn_in(100)
//!     .thin(2)
//!     .record(Param::all())
//!     .init(theta0)
//!     .run();
//! println!("{}", report.to_json());
//! ```

use std::path::PathBuf;
use std::sync::Arc;
use std::time::Duration;

use crate::coordinator::accept::AcceptanceTest;
use crate::coordinator::chain::{Budget, ChainStats};
use crate::coordinator::checkpoint::{
    fs_store, json_num, json_str, CheckpointSpec, Persist, ShardStamp, StoreLayer, DEFAULT_RETAIN,
};
use crate::coordinator::engine::{
    run_engine_kernel_result, ChainRun, ChainStatus, EngineConfig, EngineResult,
};
use crate::coordinator::executor::Executor;
use crate::coordinator::guard::{GuardPolicy, Guarded};
use crate::coordinator::kernel::TransitionKernel;
use crate::coordinator::mh::MhMode;
use crate::coordinator::record::{PerChain, RecordDefault, RecordSpec, Replicate};
use crate::coordinator::supervise::{CancelToken, LaunchError, ProgressBoard, RetryPolicy};
use crate::data::sharded::{even_rows, DataTooLarge};
use crate::metrics::convergence::Convergence;
use crate::models::traits::{LlDiffModel, PriorTempered, ProposalKernel, ShardableModel};
use crate::samplers::gibbs::{gaussian_product, GaussianMoments, MergeError};
use crate::stats::welford::Welford;

/// Per-shard seed stride (the 64-bit golden-ratio increment): shard `s`
/// of a sharded launch runs under `seed + s * STRIDE` (wrapping), so the
/// shards' chain streams are decorrelated without reserving stream ids.
const SHARD_SEED_STRIDE: u64 = 0x9E37_79B9_7F4A_7C15;

/// Placeholder proposal-kernel type of a freshly built [`Session`]; it
/// implements no `ProposalKernel`, so `run()` only compiles once
/// `Session::kernel` has been called.
pub struct NoProposal;

/// Shared launch configuration of both session flavours.
#[derive(Clone, Debug)]
struct LaunchCfg {
    chains: usize,
    threads: usize,
    seed: u64,
    budget: Option<Budget>,
    burn_in: usize,
    thin: usize,
    checkpoint_every: Option<usize>,
    checkpoint_dir: Option<PathBuf>,
    retain: usize,
    resume: Option<PathBuf>,
    guard: GuardPolicy,
    executor: Option<Executor>,
    shards: usize,
    retry: RetryPolicy,
    stall_after: Option<Duration>,
    min_chains: f64,
    store: Option<Arc<dyn StoreLayer>>,
    cancel: Option<CancelToken>,
    board: Option<Arc<ProgressBoard>>,
}

impl LaunchCfg {
    fn new() -> Self {
        LaunchCfg {
            chains: 1,
            threads: 0,
            seed: 0,
            budget: None,
            burn_in: 0,
            thin: 1,
            checkpoint_every: None,
            checkpoint_dir: None,
            retain: DEFAULT_RETAIN,
            resume: None,
            guard: GuardPolicy::default(),
            executor: None,
            shards: 1,
            retry: RetryPolicy::none(),
            stall_after: None,
            min_chains: 0.0,
            store: None,
            cancel: None,
            board: None,
        }
    }

    fn engine_config(&self, who: &'static str) -> EngineConfig {
        let budget = self
            .budget
            .unwrap_or_else(|| panic!("{who}: call .budget(..) before .run()"));
        let checkpoint = match (self.checkpoint_every, &self.checkpoint_dir) {
            (Some(every), Some(dir)) => {
                Some(CheckpointSpec { every, dir: dir.clone(), retain: self.retain })
            }
            (None, None) => None,
            _ => panic!("{who}: checkpoint_every and checkpoint_dir must be set together"),
        };
        // paired-flag rule: a resumed launch must keep checkpointing, or
        // a crash after the resume would silently lose everything the
        // first run saved past its last generation — and a supervised
        // retry would have nowhere fresher than the original directory
        // to restart from. Enforced here, at build time, so the mistake
        // surfaces before any sampling happens.
        assert!(
            self.resume.is_none() || checkpoint.is_some(),
            "{who}: .resume_from(..) requires .checkpoint_every(..) and .checkpoint_dir(..) \
             (resume continues a checkpointed run — pair the flags)"
        );
        EngineConfig {
            chains: self.chains,
            threads: self.threads,
            base_seed: self.seed,
            budget,
            burn_in: self.burn_in,
            thin: self.thin,
            checkpoint,
            resume: self.resume.clone(),
            executor: self.executor.clone(),
            shard: ShardStamp::default(),
            retry: self.retry,
            stall_after: self.stall_after,
            min_chains: self.min_chains,
            kernel_label: "",
            rule_label: "",
            store: self.store.clone().unwrap_or_else(fs_store),
            cancel: self.cancel.clone(),
            board: self.board.clone(),
        }
    }
}

/// Builder for a multi-chain Metropolis-Hastings launch over an
/// [`LlDiffModel`]: pick a proposal kernel and an acceptance rule, set
/// the budget, run, get a [`RunReport`]. See the module docs for the
/// shape; defaults are 1 chain, seed 0, no burn-in, no thinning, one
/// worker per chain, and recording coordinate 0 of the chain state.
pub struct Session<'a, M: LlDiffModel, K = NoProposal, T = MhMode, R = RecordDefault> {
    model: &'a M,
    proposal: Option<&'a K>,
    rule: T,
    record: R,
    init: Option<M::Param>,
    cfg: LaunchCfg,
}

impl<'a, M: LlDiffModel> Session<'a, M> {
    /// Start configuring a launch over `model` (exact rule until
    /// [`Session::rule`] picks another).
    pub fn new(model: &'a M) -> Self {
        Session {
            model,
            proposal: None,
            rule: MhMode::Exact,
            record: RecordDefault,
            init: None,
            cfg: LaunchCfg::new(),
        }
    }
}

impl<'a, M: LlDiffModel, K, T, R> Session<'a, M, K, T, R> {
    /// Set the proposal kernel (required before `run`).
    pub fn kernel<K2>(self, proposal: &'a K2) -> Session<'a, M, K2, T, R> {
        Session {
            model: self.model,
            proposal: Some(proposal),
            rule: self.rule,
            record: self.record,
            init: self.init,
            cfg: self.cfg,
        }
    }

    /// Set the acceptance rule — an [`MhMode`] or any custom
    /// [`AcceptanceTest`].
    pub fn rule<T2>(self, rule: T2) -> Session<'a, M, K, T2, R> {
        Session {
            model: self.model,
            proposal: self.proposal,
            rule,
            record: self.record,
            init: self.init,
            cfg: self.cfg,
        }
    }

    /// Record via a cloned per-chain prototype observer (e.g.
    /// `record::Param::all()`, `record::ScalarFn::new(..)`).
    pub fn record<O: Clone>(self, prototype: O) -> Session<'a, M, K, T, Replicate<O>> {
        Session {
            model: self.model,
            proposal: self.proposal,
            rule: self.rule,
            record: Replicate(prototype),
            init: self.init,
            cfg: self.cfg,
        }
    }

    /// Record via a `Fn(chain) -> observer` factory (for observers that
    /// are not `Clone`, or that need the chain index).
    pub fn record_with<F>(self, factory: F) -> Session<'a, M, K, T, PerChain<F>> {
        Session {
            model: self.model,
            proposal: self.proposal,
            rule: self.rule,
            record: PerChain(factory),
            init: self.init,
            cfg: self.cfg,
        }
    }

    /// Initial chain state (required before `run`; every chain starts
    /// from a clone).
    pub fn init(mut self, init: M::Param) -> Self {
        self.init = Some(init);
        self
    }

    /// Number of independent chains K (default 1).
    pub fn chains(mut self, chains: usize) -> Self {
        self.cfg.chains = chains;
        self
    }

    /// Base RNG seed; chain `c` draws from stream `STREAM_BASE + c`.
    pub fn seed(mut self, seed: u64) -> Self {
        self.cfg.seed = seed;
        self
    }

    /// Per-chain stop condition (required before `run`).
    pub fn budget(mut self, budget: Budget) -> Self {
        self.cfg.budget = Some(budget);
        self
    }

    /// Steps discarded before recording starts (default 0).
    pub fn burn_in(mut self, burn_in: usize) -> Self {
        self.cfg.burn_in = burn_in;
        self
    }

    /// Record every `thin`-th post-burn-in step (default 1).
    pub fn thin(mut self, thin: usize) -> Self {
        assert!(thin >= 1);
        self.cfg.thin = thin;
        self
    }

    /// Worker threads (default 0 = one per chain; more than `chains`
    /// hands the spare workers to the chains' intra-step scans). This
    /// sizes the shared persistent executor pool the launch draws from —
    /// grown once, before the launch clock starts — unless
    /// [`Session::executor`] pins an explicit pool.
    pub fn threads(mut self, threads: usize) -> Self {
        self.cfg.threads = threads;
        self
    }

    /// Run on this executor pool instead of the process-global one. The
    /// pinned pool is taken as-is (never grown), so a launch can be
    /// deliberately oversubscribed and still completes deterministically.
    pub fn executor(mut self, exec: Executor) -> Self {
        self.cfg.executor = Some(exec);
        self
    }

    /// Checkpoint every `every` completed steps (pair with
    /// [`Session::checkpoint_dir`]).
    pub fn checkpoint_every(mut self, every: usize) -> Self {
        assert!(every >= 1, "checkpoint interval must be at least 1 step");
        self.cfg.checkpoint_every = Some(every);
        self
    }

    /// Directory receiving one `chain-<c>.ckpt` per chain plus a
    /// `manifest.json` (created if missing).
    pub fn checkpoint_dir(mut self, dir: impl Into<PathBuf>) -> Self {
        self.cfg.checkpoint_dir = Some(dir.into());
        self
    }

    /// Resume chains from the checkpoints in `dir`. Chains without a
    /// checkpoint file start fresh; a resumed chain replays the
    /// uninterrupted same-seed run bit for bit (see
    /// `coordinator::checkpoint`). Must be paired with
    /// [`Session::checkpoint_every`] / [`Session::checkpoint_dir`] — a
    /// resumed launch keeps checkpointing (enforced at build time).
    pub fn resume_from(mut self, dir: impl Into<PathBuf>) -> Self {
        self.cfg.resume = Some(dir.into());
        self
    }

    /// Keep the newest `k` checkpoint generations per chain (default 2:
    /// the newest plus one torn-write fallback).
    pub fn retain_checkpoints(mut self, k: usize) -> Self {
        assert!(k >= 1, "must retain at least one checkpoint generation");
        self.cfg.retain = k;
        self
    }

    /// Restart failed chains from their last good checkpoint under
    /// `policy` (default: no retries). A recovered chain's draws are
    /// bit-identical to a never-failed run.
    pub fn retry(mut self, policy: RetryPolicy) -> Self {
        self.cfg.retry = policy;
        self
    }

    /// Flag chains whose step counter has not advanced within `window`
    /// as [`ChainStatus::Stalled`] (default: no watchdog).
    pub fn stall_after(mut self, window: Duration) -> Self {
        assert!(window > Duration::ZERO, "stall window must be positive");
        self.cfg.stall_after = Some(window);
        self
    }

    /// Abort the launch (`LaunchError::QuorumLost` from
    /// [`Session::try_run`]) when fewer than `fraction` of the chains
    /// remain healthy; pair with [`Session::stall_after`], which drives
    /// the checks. Default 0: degrade, never abort.
    pub fn min_chains(mut self, fraction: f64) -> Self {
        assert!((0.0..=1.0).contains(&fraction), "min_chains is a fraction in [0, 1]");
        self.cfg.min_chains = fraction;
        self
    }

    /// Route checkpoint I/O through `store` (the fault-injection hook —
    /// see `testkit::fault::FaultyStore`; production launches keep the
    /// default filesystem store).
    pub fn checkpoint_store(mut self, store: Arc<dyn StoreLayer>) -> Self {
        self.cfg.store = Some(store);
        self
    }

    /// Numerical-guard policy applied where log-likelihood moments enter
    /// the acceptance test (default [`GuardPolicy::Warn`]: count trips in
    /// `ChainStats::guard_trips`, never alter decisions).
    pub fn guard(mut self, policy: GuardPolicy) -> Self {
        self.cfg.guard = policy;
        self
    }

    /// Poll `token` at every step boundary: when the caller raises it
    /// (job cancellation, daemon shutdown), every chain stops cleanly at
    /// its next step with everything sampled so far — and, when the
    /// launch is checkpointing, flushes one final generation so the run
    /// can [`Session::resume_from`] later. Chain statuses stay
    /// `Completed`; the caller holding the token knows it cancelled.
    pub fn cancel_token(mut self, token: CancelToken) -> Self {
        self.cfg.cancel = Some(token);
        self
    }

    /// Publish live per-chain progress (steps, acceptances, datapoint
    /// evaluations) into `board` after every step — the poll surface
    /// behind `austerity serve`'s `GET /jobs/:id`. The board must have
    /// one lane per chain ([`Session::chains`]; checked at launch).
    pub fn progress_board(mut self, board: Arc<ProgressBoard>) -> Self {
        self.cfg.board = Some(board);
        self
    }

    /// Split the launch into `shards` embarrassingly-parallel
    /// sub-posterior runs (default 1 = ordinary launch). Each shard runs
    /// the full chain configuration against its own row range of the
    /// data under the 1/shards-tempered prior; launch with
    /// [`Session::run_sharded`], which returns one [`RunReport`] per
    /// shard plus the consensus (Gaussian-product) combination.
    pub fn shards(mut self, shards: usize) -> Self {
        assert!(shards >= 1, "need at least one shard");
        self.cfg.shards = shards;
        self
    }
}

impl<'a, M, K, T, R> Session<'a, M, K, T, R>
where
    M: LlDiffModel + Sync,
    M::Param: Persist,
    K: ProposalKernel<M::Param> + Sync,
    T: AcceptanceTest + Sync,
    R: RecordSpec<M::Param> + Sync,
{
    /// Launch the chains and collect the typed report. Dispatches to the
    /// cached engine path automatically when the model implements
    /// `CachedLlDiff` (via `LlDiffModel::session_launch`); results are
    /// bit-identical either way. The acceptance rule always runs behind
    /// the numerical guard ([`Session::guard`]; the default `Warn` policy
    /// is decision-transparent, so guarded and bare launches match bit
    /// for bit).
    pub fn run(self) -> RunReport<R::Observer> {
        self.try_run().unwrap_or_else(|e| panic!("Session: launch failed: {e}"))
    }

    /// [`Session::run`] with typed launch errors instead of panics:
    /// `LaunchError::Resume` when the checkpoint manifest refuses the
    /// configuration, `LaunchError::QuorumLost` when the healthy-chain
    /// fraction drops below [`Session::min_chains`]. Per-chain failures
    /// still degrade (see `RunReport::statuses`) — only launch-level
    /// faults surface here.
    pub fn try_run(self) -> Result<RunReport<R::Observer>, LaunchError> {
        assert!(
            self.cfg.shards == 1,
            "Session: .shards({}) was set — launch with .run_sharded()",
            self.cfg.shards
        );
        let Session { model, proposal, rule, record, init, cfg } = self;
        let proposal = proposal.expect("Session: call .kernel(..) before .run()");
        let init = init.expect("Session: call .init(..) before .run()");
        let rule = Guarded::new(rule, cfg.guard);
        let ecfg = cfg
            .engine_config("Session")
            .labels(model.session_backend(), rule.name());
        let result = model.session_launch(proposal, &rule, init, &ecfg, |c| record.make(c))?;
        Ok(RunReport::from_engine(
            result,
            rule.name(),
            model.session_backend(),
            Some(model.n()),
            &ecfg,
        ))
    }
}

impl<'a, M, K, T, R> Session<'a, M, K, T, R>
where
    M: ShardableModel + Sync,
    M::Param: Persist + Clone,
    K: ProposalKernel<M::Param> + Sync,
    T: AcceptanceTest + Sync,
    R: RecordSpec<M::Param> + Sync,
{
    /// Launch the configured run as `shards` independent sub-posterior
    /// runs (embarrassingly-parallel MCMC): shard `s` gets its own even
    /// row range of the data ([`ShardableModel::shard_model`]), the
    /// 1/shards-tempered prior (so the shard product reproduces the
    /// prior exactly once), a decorrelated base seed, and — when
    /// checkpointing — its own `shard-<s>` subdirectory. Returns one
    /// full [`RunReport`] per shard (each stamped with its
    /// [`ShardInfo`]) inside a [`ShardReport`], whose
    /// [`ShardReport::combined`] forms the consensus Gaussian-product
    /// posterior over the recorded scalar.
    ///
    /// With `shards == 1` this is an ordinary [`Session::run`] launch
    /// over the whole dataset: the prior tempering is an exact no-op
    /// (`log_correction * 1.0`) and the row range is the full
    /// population, so results are bit-identical to `run()`.
    pub fn run_sharded(self) -> Result<ShardReport<R::Observer>, ShardedError> {
        let Session { model, proposal, rule, record, init, cfg } = self;
        let proposal = proposal.expect("Session: call .kernel(..) before .run_sharded()");
        let init = init.expect("Session: call .init(..) before .run_sharded()");
        let shards = cfg.shards;
        let tempered = PriorTempered::new(proposal, shards);
        let rule = Guarded::new(rule, cfg.guard);
        let base = cfg
            .engine_config("Session")
            .labels(model.session_backend(), rule.name());
        let mut reports = Vec::with_capacity(shards);
        for s in 0..shards {
            let sub = model.shard_model(s, shards)?;
            let (start, end) = even_rows(model.n(), s, shards);
            let stamp = ShardStamp { index: s, count: shards, start, end };
            let mut ecfg = base.clone();
            ecfg.base_seed = cfg.seed.wrapping_add((s as u64).wrapping_mul(SHARD_SEED_STRIDE));
            ecfg.shard = stamp;
            if let Some(spec) = &mut ecfg.checkpoint {
                spec.dir = spec.dir.join(format!("shard-{s}"));
            }
            if let Some(dir) = &mut ecfg.resume {
                *dir = dir.join(format!("shard-{s}"));
            }
            let result =
                sub.session_launch(&tempered, &rule, init.clone(), &ecfg, |c| record.make(c))?;
            let mut report = RunReport::from_engine(
                result,
                rule.name(),
                sub.session_backend(),
                Some(sub.n()),
                &ecfg,
            );
            report.shard = Some(ShardInfo { index: s, count: shards, start, end });
            reports.push(report);
        }
        Ok(ShardReport { shards: reports })
    }
}

/// Why a [`Session::run_sharded`] launch could not run: the data split
/// overflowed the u32 index space, or one shard's launch failed
/// (manifest refusal, quorum loss).
#[derive(Debug)]
pub enum ShardedError {
    Data(DataTooLarge),
    Launch(LaunchError),
}

impl std::fmt::Display for ShardedError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ShardedError::Data(e) => write!(f, "{e}"),
            ShardedError::Launch(e) => write!(f, "{e}"),
        }
    }
}

impl std::error::Error for ShardedError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            ShardedError::Data(e) => Some(e),
            ShardedError::Launch(e) => Some(e),
        }
    }
}

impl From<DataTooLarge> for ShardedError {
    fn from(e: DataTooLarge) -> Self {
        ShardedError::Data(e)
    }
}

impl From<LaunchError> for ShardedError {
    fn from(e: LaunchError) -> Self {
        ShardedError::Launch(e)
    }
}

/// Which slice of a sharded launch a [`RunReport`] covers.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ShardInfo {
    /// Shard index in `0..count`.
    pub index: usize,
    /// Total shard count of the launch.
    pub count: usize,
    /// Global row range `[start, end)` of the shard's data.
    pub start: usize,
    pub end: usize,
}

impl ShardInfo {
    /// Number of rows this shard owns.
    pub fn rows(&self) -> usize {
        self.end - self.start
    }
}

/// Everything a sharded launch produced: one full per-shard
/// [`RunReport`] (chains, draws, counters, convergence — each stamped
/// with its [`ShardInfo`]) plus the consensus combination.
pub struct ShardReport<O> {
    /// Per-shard reports, in shard order.
    pub shards: Vec<RunReport<O>>,
}

impl<O> ShardReport<O> {
    /// Consensus (Gaussian-product) combination of the per-shard
    /// posteriors over the recorded scalar: each shard contributes its
    /// pooled mean/variance weighted by precision (Scott et al. CMC).
    /// Shards degraded below two draws (all chains failed or aborted)
    /// are left out, so one downed shard never poisons the consensus of
    /// the survivors — how many were dropped is
    /// [`ShardReport::degraded_shards`], and `to_json` stamps them.
    /// Errors if a *contributing* shard's variance is zero/non-finite,
    /// or no shard contributes at all.
    pub fn combined(&self) -> Result<GaussianMoments, MergeError> {
        let parts: Vec<GaussianMoments> = self
            .shards
            .iter()
            .map(|r| {
                let std = r.pooled_std();
                let n = r.runs.iter().map(|c| c.samples.len() as u64).sum();
                GaussianMoments { mean: r.pooled_mean(), var: std * std, n }
            })
            .filter(|g| g.n >= 2)
            .collect();
        gaussian_product(&parts)
    }

    /// Chains that failed across all shards.
    pub fn failed_chains(&self) -> usize {
        self.shards.iter().map(|r| r.failed_chains()).sum()
    }

    /// Shards with fewer than two draws (every chain failed or was
    /// aborted) — excluded from [`ShardReport::combined`].
    pub fn degraded_shards(&self) -> usize {
        self.shards
            .iter()
            .filter(|r| r.runs.iter().map(|c| c.samples.len()).sum::<usize>() < 2)
            .count()
    }

    /// Counters summed over every shard's completed chains.
    pub fn merged(&self) -> ChainStats {
        let mut m = ChainStats::default();
        for r in &self.shards {
            m.steps += r.merged.steps;
            m.accepted += r.merged.accepted;
            m.data_used += r.merged.data_used;
            m.guard_trips += r.merged.guard_trips;
            m.ckpt_failures += r.merged.ckpt_failures;
            m.wall = m.wall.max(r.merged.wall);
        }
        m
    }

    /// Serialize the whole sharded launch: every shard's full
    /// [`RunReport::to_json`] object (each stamped with its shard info
    /// and per-chain statuses, so a downed shard is visible), the
    /// consensus combination (`null` when it cannot be formed), and the
    /// launch-wide failure counters.
    pub fn to_json(&self) -> String {
        let mut s = String::with_capacity(1024 * self.shards.len().max(1));
        s.push_str("{\"shards\":[");
        for (i, r) in self.shards.iter().enumerate() {
            if i > 0 {
                s.push(',');
            }
            s.push_str(&r.to_json());
        }
        s.push_str("],");
        match self.combined() {
            Ok(g) => s.push_str(&format!(
                "\"consensus\":{{\"mean\":{},\"var\":{},\"n\":{}}},",
                json_num(g.mean),
                json_num(g.var),
                g.n
            )),
            Err(_) => s.push_str("\"consensus\":null,"),
        }
        s.push_str(&format!(
            "\"failed_chains\":{},\"degraded_shards\":{}}}",
            self.failed_chains(),
            self.degraded_shards()
        ));
        s
    }
}

/// Builder for a multi-chain launch of any [`TransitionKernel`] (SGLD,
/// Gibbs / Potts sweeps, pseudo-marginal, adaptive-epsilon, ...): the
/// same configuration surface and [`RunReport`] as [`Session`], minus
/// the model/rule split the MH families have. Chain states without
/// [`crate::coordinator::record::Components`] must set a recorder
/// explicitly.
pub struct KernelSession<'a, T: TransitionKernel, R = RecordDefault> {
    kernel: &'a T,
    label: &'static str,
    record: R,
    init: Option<T::State>,
    n_data: Option<usize>,
    cfg: LaunchCfg,
}

impl<'a, T: TransitionKernel> KernelSession<'a, T> {
    /// Start configuring a launch of `kernel`.
    pub fn new(kernel: &'a T) -> Self {
        KernelSession {
            kernel,
            label: "kernel",
            record: RecordDefault,
            init: None,
            n_data: None,
            cfg: LaunchCfg::new(),
        }
    }
}

impl<'a, T: TransitionKernel, R> KernelSession<'a, T, R> {
    /// Name the launch in the report (`report.rule`; default
    /// `"kernel"`).
    pub fn label(mut self, label: &'static str) -> Self {
        self.label = label;
        self
    }

    /// Dataset size N for `mean_data_fraction` accounting (the generic
    /// kernel hides its model, so the report cannot infer it).
    pub fn data_size(mut self, n: usize) -> Self {
        self.n_data = Some(n);
        self
    }

    /// Record via a cloned per-chain prototype observer.
    pub fn record<O: Clone>(self, prototype: O) -> KernelSession<'a, T, Replicate<O>> {
        KernelSession {
            kernel: self.kernel,
            label: self.label,
            record: Replicate(prototype),
            init: self.init,
            n_data: self.n_data,
            cfg: self.cfg,
        }
    }

    /// Record via a `Fn(chain) -> observer` factory.
    pub fn record_with<F>(self, factory: F) -> KernelSession<'a, T, PerChain<F>> {
        KernelSession {
            kernel: self.kernel,
            label: self.label,
            record: PerChain(factory),
            init: self.init,
            n_data: self.n_data,
            cfg: self.cfg,
        }
    }

    /// Initial chain state (required before `run`).
    pub fn init(mut self, init: T::State) -> Self {
        self.init = Some(init);
        self
    }

    /// Number of independent chains K (default 1).
    pub fn chains(mut self, chains: usize) -> Self {
        self.cfg.chains = chains;
        self
    }

    /// Base RNG seed; chain `c` draws from stream `STREAM_BASE + c`.
    pub fn seed(mut self, seed: u64) -> Self {
        self.cfg.seed = seed;
        self
    }

    /// Per-chain stop condition (required before `run`).
    pub fn budget(mut self, budget: Budget) -> Self {
        self.cfg.budget = Some(budget);
        self
    }

    /// Steps discarded before recording starts (default 0).
    pub fn burn_in(mut self, burn_in: usize) -> Self {
        self.cfg.burn_in = burn_in;
        self
    }

    /// Record every `thin`-th post-burn-in step (default 1).
    pub fn thin(mut self, thin: usize) -> Self {
        assert!(thin >= 1);
        self.cfg.thin = thin;
        self
    }

    /// Worker threads (default 0 = one per chain). Sizes the shared
    /// persistent executor pool the launch draws from, unless
    /// [`KernelSession::executor`] pins an explicit pool.
    pub fn threads(mut self, threads: usize) -> Self {
        self.cfg.threads = threads;
        self
    }

    /// Run on this executor pool instead of the process-global one
    /// (taken as-is, never grown).
    pub fn executor(mut self, exec: Executor) -> Self {
        self.cfg.executor = Some(exec);
        self
    }

    /// Checkpoint every `every` completed steps (pair with
    /// [`KernelSession::checkpoint_dir`]).
    pub fn checkpoint_every(mut self, every: usize) -> Self {
        assert!(every >= 1, "checkpoint interval must be at least 1 step");
        self.cfg.checkpoint_every = Some(every);
        self
    }

    /// Directory receiving one `chain-<c>.ckpt` per chain plus a
    /// `manifest.json` (created if missing).
    pub fn checkpoint_dir(mut self, dir: impl Into<PathBuf>) -> Self {
        self.cfg.checkpoint_dir = Some(dir.into());
        self
    }

    /// Resume chains from the checkpoints in `dir` (missing files start
    /// fresh; see `coordinator::checkpoint`). Must be paired with
    /// [`KernelSession::checkpoint_every`] /
    /// [`KernelSession::checkpoint_dir`] (enforced at build time).
    pub fn resume_from(mut self, dir: impl Into<PathBuf>) -> Self {
        self.cfg.resume = Some(dir.into());
        self
    }

    /// Keep the newest `k` checkpoint generations per chain (default 2).
    pub fn retain_checkpoints(mut self, k: usize) -> Self {
        assert!(k >= 1, "must retain at least one checkpoint generation");
        self.cfg.retain = k;
        self
    }

    /// Restart failed chains from their last good checkpoint under
    /// `policy` (default: no retries).
    pub fn retry(mut self, policy: RetryPolicy) -> Self {
        self.cfg.retry = policy;
        self
    }

    /// Flag chains not advancing within `window` as
    /// [`ChainStatus::Stalled`] (default: no watchdog).
    pub fn stall_after(mut self, window: Duration) -> Self {
        assert!(window > Duration::ZERO, "stall window must be positive");
        self.cfg.stall_after = Some(window);
        self
    }

    /// Abort (`LaunchError::QuorumLost` from [`KernelSession::try_run`])
    /// when fewer than `fraction` of the chains remain healthy.
    pub fn min_chains(mut self, fraction: f64) -> Self {
        assert!((0.0..=1.0).contains(&fraction), "min_chains is a fraction in [0, 1]");
        self.cfg.min_chains = fraction;
        self
    }

    /// Route checkpoint I/O through `store` (the fault-injection hook).
    pub fn checkpoint_store(mut self, store: Arc<dyn StoreLayer>) -> Self {
        self.cfg.store = Some(store);
        self
    }

    /// Poll `token` at every step boundary; when raised, every chain
    /// stops cleanly at its next step (see [`Session::cancel_token`]).
    pub fn cancel_token(mut self, token: CancelToken) -> Self {
        self.cfg.cancel = Some(token);
        self
    }

    /// Publish live per-chain progress into `board` after every step
    /// (see [`Session::progress_board`]).
    pub fn progress_board(mut self, board: Arc<ProgressBoard>) -> Self {
        self.cfg.board = Some(board);
        self
    }
}

impl<'a, T, R> KernelSession<'a, T, R>
where
    T: TransitionKernel + Sync,
    T::State: Sync + Persist,
    R: RecordSpec<T::State> + Sync,
{
    /// Launch the chains over the generic-kernel engine path and collect
    /// the typed report.
    pub fn run(self) -> RunReport<R::Observer> {
        self.try_run().unwrap_or_else(|e| panic!("KernelSession: launch failed: {e}"))
    }

    /// [`KernelSession::run`] with typed launch errors (manifest
    /// refusal, quorum loss) instead of panics.
    pub fn try_run(self) -> Result<RunReport<R::Observer>, LaunchError> {
        let KernelSession { kernel, label, record, init, n_data, cfg } = self;
        let init = init.expect("KernelSession: call .init(..) before .run()");
        let ecfg = cfg.engine_config("KernelSession").labels("kernel", label);
        let result = run_engine_kernel_result(kernel, init, &ecfg, |c| record.make(c))?;
        Ok(RunReport::from_engine(result, label, "kernel", n_data, &ecfg))
    }
}

/// Everything one session launch produced, typed: per-chain draws and
/// counters, the pooled statistics, cross-chain convergence diagnostics,
/// and the budget accounting — plus [`RunReport::to_json`] for
/// machine-readable output (`austerity sample --json`).
pub struct RunReport<O> {
    /// Acceptance-rule (or kernel label) of the launch.
    pub rule: &'static str,
    /// Engine path taken: `"cached"`, `"uncached"`, `"pjrt"` (uncached
    /// engine over the AOT Pallas backend) or `"kernel"`.
    pub backend: &'static str,
    /// Dataset size N, when known (MH sessions always know it).
    pub n_data: Option<usize>,
    /// Number of chains launched.
    pub chains: usize,
    /// Base seed of the launch.
    pub seed: u64,
    /// Per-chain stop condition the launch ran under.
    pub budget: Budget,
    /// Burn-in steps per chain.
    pub burn_in: usize,
    /// Thinning interval.
    pub thin: usize,
    /// Samples and statistics of the chains that completed, in chain
    /// order (`ChainRun::chain` keeps the original index).
    pub runs: Vec<ChainRun>,
    /// Observers of the completed chains, in `runs` order.
    pub observers: Vec<O>,
    /// Per-chain outcome for all launched chains, in chain order; failed
    /// chains carry the step index and panic reason.
    pub statuses: Vec<ChainStatus>,
    /// Counters summed over completed chains (`wall` is the slowest
    /// single chain).
    pub merged: ChainStats,
    /// Wall-clock duration of the whole launch.
    pub wall: Duration,
    /// Cross-chain split R-hat / ESS over the recorded scalar stream.
    pub convergence: Convergence,
    /// Set when this report is one shard of a [`Session::run_sharded`]
    /// launch (`None` for ordinary runs).
    pub shard: Option<ShardInfo>,
}

impl<O> RunReport<O> {
    fn from_engine(
        result: EngineResult<O>,
        rule: &'static str,
        backend: &'static str,
        n_data: Option<usize>,
        cfg: &EngineConfig,
    ) -> Self {
        let EngineResult { runs, observers, statuses, merged, wall, convergence } = result;
        RunReport {
            rule,
            backend,
            n_data,
            chains: cfg.chains,
            seed: cfg.base_seed,
            budget: cfg.budget,
            burn_in: cfg.burn_in,
            thin: cfg.thin,
            runs,
            observers,
            statuses,
            merged,
            wall,
            convergence,
            shard: None,
        }
    }

    /// Recorded scalar values per chain.
    pub fn values(&self) -> Vec<Vec<f64>> {
        self.runs
            .iter()
            .map(|r| r.samples.iter().map(|s| s.value).collect())
            .collect()
    }

    /// Number of launched chains that failed (panic or guard abort).
    pub fn failed_chains(&self) -> usize {
        self.statuses.iter().filter(|s| s.is_failed()).count()
    }

    /// Number of chains that completed only after supervised recovery
    /// (restart from checkpoint, or a fallback past a torn generation).
    pub fn recovered_chains(&self) -> usize {
        self.statuses.iter().filter(|s| s.is_recovered()).count()
    }

    /// Number of chains the stall watchdog flagged.
    pub fn stalled_chains(&self) -> usize {
        self.statuses.iter().filter(|s| s.is_stalled()).count()
    }

    /// Pooled acceptance rate over all chains.
    pub fn acceptance_rate(&self) -> f64 {
        self.merged.acceptance_rate()
    }

    /// Mean fraction of the dataset consumed per decision (NaN when the
    /// dataset size is unknown — see [`KernelSession::data_size`]).
    pub fn mean_data_fraction(&self) -> f64 {
        match self.n_data {
            Some(n) if n > 0 => self.merged.mean_data_fraction(n),
            _ => f64::NAN,
        }
    }

    /// Aggregate steps per wall-clock second of the launch.
    pub fn steps_per_sec(&self) -> f64 {
        per_sec(self.merged.steps as f64, self.wall)
    }

    /// Aggregate datapoint evaluations per second — the throughput axis
    /// of `Budget::Data` runs.
    pub fn data_per_sec(&self) -> f64 {
        per_sec(self.merged.data_used as f64, self.wall)
    }

    /// Fraction of the configured per-chain budget actually consumed
    /// (steps for `Budget::Steps`, datapoint evaluations for
    /// `Budget::Data` — both summed over chains and divided by `chains ×
    /// target`; the slowest single chain's own wall time for
    /// `Budget::Wall`, since chains sharing workers stretch the launch
    /// wall without any chain exceeding its budget). Slightly above 1 is
    /// normal: the step that crosses a budget completes.
    pub fn budget_consumed(&self) -> f64 {
        let k = self.chains.max(1) as f64;
        match self.budget {
            Budget::Steps(s) if s > 0 => self.merged.steps as f64 / (s as f64 * k),
            Budget::Data(d) if d > 0 => self.merged.data_used as f64 / (d as f64 * k),
            Budget::Wall(d) if d.as_secs_f64() > 0.0 => {
                self.merged.wall.as_secs_f64() / d.as_secs_f64()
            }
            _ => f64::NAN,
        }
    }

    /// Cross-chain split R-hat of the recorded scalar stream.
    pub fn rhat(&self) -> f64 {
        self.convergence.rhat
    }

    /// Total effective sample size across chains.
    pub fn ess(&self) -> f64 {
        self.convergence.ess
    }

    /// Mean of all recorded scalar values.
    pub fn pooled_mean(&self) -> f64 {
        self.convergence.pooled_mean
    }

    /// Sample standard deviation of all recorded scalar values (NaN with
    /// fewer than two draws).
    pub fn pooled_std(&self) -> f64 {
        let mut w = Welford::new();
        for r in &self.runs {
            for s in &r.samples {
                w.add(s.value);
            }
        }
        w.std_sample()
    }

    /// Serialize the report (configuration, totals, convergence, budget
    /// accounting, per-chain counters and draws) as a JSON object, via
    /// the crate's hand-rolled writer — no serde. Non-finite numbers
    /// serialize as `null`.
    pub fn to_json(&self) -> String {
        let mut s = String::with_capacity(1024 + 16 * self.convergence.n_samples);
        s.push('{');
        s.push_str(&format!(
            "\"rule\":{},\"backend\":{},",
            json_str(self.rule),
            json_str(self.backend)
        ));
        match self.n_data {
            Some(n) => s.push_str(&format!("\"n_data\":{n},")),
            None => s.push_str("\"n_data\":null,"),
        }
        s.push_str(&format!(
            "\"chains\":{},\"seed\":{},\"burn_in\":{},\"thin\":{},",
            self.chains, self.seed, self.burn_in, self.thin
        ));
        match &self.shard {
            Some(sh) => s.push_str(&format!(
                "\"shard\":{{\"index\":{},\"count\":{},\"rows\":[{},{}]}},",
                sh.index, sh.count, sh.start, sh.end
            )),
            None => s.push_str("\"shard\":null,"),
        }
        let (kind, per_chain) = match self.budget {
            Budget::Steps(k) => ("steps", k as f64),
            Budget::Wall(d) => ("wall_secs", d.as_secs_f64()),
            Budget::Data(d) => ("data", d as f64),
        };
        s.push_str(&format!(
            "\"budget\":{{\"kind\":\"{kind}\",\"per_chain\":{},\"consumed_fraction\":{}}},",
            json_num(per_chain),
            json_num(self.budget_consumed())
        ));
        s.push_str(&format!(
            "\"totals\":{{\"steps\":{},\"accepted\":{},\"data_used\":{},\"guard_trips\":{},\
             \"ckpt_failures\":{},\"wall_secs\":{},\"acceptance_rate\":{},\
             \"mean_data_fraction\":{},\"steps_per_sec\":{},\"data_per_sec\":{}}},",
            self.merged.steps,
            self.merged.accepted,
            self.merged.data_used,
            self.merged.guard_trips,
            self.merged.ckpt_failures,
            json_num(self.wall.as_secs_f64()),
            json_num(self.acceptance_rate()),
            json_num(self.mean_data_fraction()),
            json_num(self.steps_per_sec()),
            json_num(self.data_per_sec())
        ));
        s.push_str(&format!(
            "\"convergence\":{{\"rhat\":{},\"ess\":{},\"pooled_mean\":{},\"n_samples\":{}}},",
            json_num(self.convergence.rhat),
            json_num(self.convergence.ess),
            json_num(self.convergence.pooled_mean),
            self.convergence.n_samples
        ));
        s.push_str(&format!(
            "\"failed_chains\":{},\"recovered_chains\":{},\"stalled_chains\":{},",
            self.failed_chains(),
            self.recovered_chains(),
            self.stalled_chains()
        ));
        s.push_str("\"chain_status\":[");
        for (i, st) in self.statuses.iter().enumerate() {
            if i > 0 {
                s.push(',');
            }
            // completed flavours carry the chain's guard-trip count so
            // the service layer can alert on numerical-instability
            // trends without digging into per_chain
            let trips = self
                .runs
                .iter()
                .find(|r| r.chain == i)
                .map_or(0, |r| r.stats.guard_trips);
            match st {
                ChainStatus::Completed => s.push_str(&format!(
                    "{{\"chain\":{i},\"status\":\"completed\",\"guard_trips\":{trips}}}"
                )),
                ChainStatus::Recovered { retries } => s.push_str(&format!(
                    "{{\"chain\":{i},\"status\":\"recovered\",\"retries\":{retries},\
                     \"guard_trips\":{trips}}}"
                )),
                ChainStatus::Stalled { step } => s.push_str(&format!(
                    "{{\"chain\":{i},\"status\":\"stalled\",\"step\":{step},\
                     \"guard_trips\":{trips}}}"
                )),
                ChainStatus::Failed { step, reason } => s.push_str(&format!(
                    "{{\"chain\":{i},\"status\":\"failed\",\"step\":{step},\"reason\":{}}}",
                    json_str(reason)
                )),
            }
        }
        s.push_str("],");
        s.push_str("\"per_chain\":[");
        for (i, run) in self.runs.iter().enumerate() {
            if i > 0 {
                s.push(',');
            }
            s.push_str(&format!(
                "{{\"chain\":{},\"steps\":{},\"accepted\":{},\"data_used\":{},\
                 \"guard_trips\":{},\"ckpt_failures\":{},\"wall_secs\":{},\"draws\":[",
                run.chain,
                run.stats.steps,
                run.stats.accepted,
                run.stats.data_used,
                run.stats.guard_trips,
                run.stats.ckpt_failures,
                json_num(run.stats.wall.as_secs_f64())
            ));
            for (j, smp) in run.samples.iter().enumerate() {
                if j > 0 {
                    s.push(',');
                }
                s.push_str(&json_num(smp.value));
            }
            s.push_str("]}");
        }
        s.push_str("]}");
        s
    }
}

fn per_sec(count: f64, wall: Duration) -> f64 {
    let secs = wall.as_secs_f64();
    if secs > 0.0 {
        count / secs
    } else {
        f64::NAN
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::record::{Param, ScalarFn};
    use crate::models::traits::Proposal;
    use crate::stats::Pcg64;

    /// 1-d Gaussian posterior split over N identical "datapoints" (the
    /// engine's own test target).
    struct GaussTarget {
        n: usize,
    }

    impl LlDiffModel for GaussTarget {
        type Param = f64;

        fn n(&self) -> usize {
            self.n
        }

        fn lldiff(&self, _i: usize, cur: &f64, prop: &f64) -> f64 {
            (0.5 * (cur * cur - prop * prop)) / self.n as f64
        }
    }

    fn rw_kernel(sigma: f64) -> impl Fn(&f64, &mut Pcg64) -> Proposal<f64> + Sync {
        move |cur: &f64, rng: &mut Pcg64| Proposal {
            param: cur + rng.normal_scaled(0.0, sigma),
            log_correction: 0.0,
        }
    }

    impl ShardableModel for GaussTarget {
        fn shard_model(&self, shard: usize, shards: usize) -> Result<Self, DataTooLarge> {
            let (start, end) = even_rows(self.n, shard, shards);
            Ok(GaussTarget { n: end - start })
        }
    }

    #[test]
    fn session_matches_legacy_engine_bitwise() {
        let model = GaussTarget { n: 50 };
        let kernel = rw_kernel(1.0);
        let cfg = EngineConfig::new(3, 42, Budget::Steps(200)).burn_in(20).thin(2);
        let legacy = crate::coordinator::engine::run_engine(
            &model,
            &kernel,
            &MhMode::Exact,
            0.0,
            &cfg,
            |_c| |p: &f64| *p,
        );
        let report = Session::new(&model)
            .kernel(&kernel)
            .chains(3)
            .seed(42)
            .budget(Budget::Steps(200))
            .burn_in(20)
            .thin(2)
            .init(0.0)
            .run();
        assert_eq!(report.rule, "exact");
        assert_eq!(report.backend, "uncached");
        assert_eq!(report.chains, 3);
        assert_eq!(report.merged.steps, legacy.merged.steps);
        assert_eq!(report.merged.accepted, legacy.merged.accepted);
        assert_eq!(report.merged.data_used, legacy.merged.data_used);
        for (a, b) in report.runs.iter().zip(&legacy.runs) {
            let va: Vec<u64> = a.samples.iter().map(|s| s.value.to_bits()).collect();
            let vb: Vec<u64> = b.samples.iter().map(|s| s.value.to_bits()).collect();
            assert_eq!(va, vb);
        }
    }

    #[test]
    fn session_default_record_is_component_zero() {
        let model = GaussTarget { n: 30 };
        let kernel = rw_kernel(1.0);
        let run = |explicit: bool| {
            let s = Session::new(&model)
                .kernel(&kernel)
                .chains(2)
                .seed(5)
                .budget(Budget::Steps(100));
            if explicit {
                s.record(Param::index(0)).init(0.0).run().values()
            } else {
                s.init(0.0).run().values()
            }
        };
        assert_eq!(run(false), run(true));
    }

    #[test]
    fn report_accounting_and_budget_fraction() {
        let model = GaussTarget { n: 25 };
        let kernel = rw_kernel(1.0);
        let report = Session::new(&model)
            .kernel(&kernel)
            .chains(2)
            .seed(9)
            .budget(Budget::Data(25 * 40))
            .init(0.0)
            .run();
        // exact rule consumes N per step: 40 steps per chain, exactly
        assert_eq!(report.merged.steps, 80);
        assert_eq!(report.merged.data_used, 2 * 25 * 40);
        assert!((report.budget_consumed() - 1.0).abs() < 1e-12);
        assert!((report.mean_data_fraction() - 1.0).abs() < 1e-12);
        assert!(report.steps_per_sec() > 0.0);
        assert!(report.data_per_sec() > report.steps_per_sec());
        assert_eq!(report.n_data, Some(25));
    }

    #[test]
    fn kernel_session_runs_transition_kernels() {
        struct Counter;
        impl TransitionKernel for Counter {
            type State = f64;
            type Scratch = ();

            fn scratch(&self, _: &f64) {}

            fn step(
                &self,
                state: &mut f64,
                _: &mut (),
                _: &mut Pcg64,
            ) -> crate::coordinator::kernel::StepOutcome {
                *state += 1.0;
                crate::coordinator::kernel::StepOutcome {
                    accepted: true,
                    data_used: 5,
                    guard_trips: 0,
                }
            }
        }
        let report = KernelSession::new(&Counter)
            .label("counter")
            .data_size(5)
            .chains(2)
            .budget(Budget::Steps(10))
            .record(ScalarFn::new(|s: &f64| *s))
            .init(0.0)
            .run();
        assert_eq!(report.rule, "counter");
        assert_eq!(report.backend, "kernel");
        assert_eq!(report.merged.steps, 20);
        assert_eq!(report.merged.data_used, 100);
        assert!((report.mean_data_fraction() - 1.0).abs() < 1e-12);
        assert_eq!(report.values()[0].last().copied(), Some(10.0));
    }

    #[test]
    fn json_report_is_well_formed() {
        let model = GaussTarget { n: 20 };
        let kernel = rw_kernel(1.0);
        let report = Session::new(&model)
            .kernel(&kernel)
            .rule(MhMode::Exact)
            .chains(2)
            .seed(3)
            .budget(Budget::Steps(12))
            .burn_in(2)
            .init(0.0)
            .run();
        let json = report.to_json();
        assert!(json.starts_with('{') && json.ends_with('}'));
        for key in [
            "\"rule\":\"exact\"",
            "\"backend\":\"uncached\"",
            "\"n_data\":20",
            "\"budget\":{\"kind\":\"steps\",\"per_chain\":12",
            "\"totals\":{\"steps\":24",
            "\"convergence\":{",
            "\"per_chain\":[{\"chain\":0",
            "\"draws\":[",
        ] {
            assert!(json.contains(key), "missing {key} in {json}");
        }
        assert!(!json.contains("NaN") && !json.contains("inf"), "{json}");
        // balanced braces/brackets (the writer is hand-rolled)
        let depth = json.chars().fold((0i64, 0i64), |(b, k), c| match c {
            '{' => (b + 1, k),
            '}' => (b - 1, k),
            '[' => (b, k + 1),
            ']' => (b, k - 1),
            _ => (b, k),
        });
        assert_eq!(depth, (0, 0));
    }

    #[test]
    fn one_shard_run_matches_plain_run_bitwise() {
        let model = GaussTarget { n: 40 };
        let kernel = rw_kernel(1.0);
        let build = || {
            Session::new(&model)
                .kernel(&kernel)
                .chains(2)
                .seed(13)
                .budget(Budget::Steps(150))
                .burn_in(10)
                .init(0.0)
        };
        let plain = build().run();
        let sharded = build().shards(1).run_sharded().unwrap();
        assert_eq!(sharded.shards.len(), 1);
        let shard = &sharded.shards[0];
        assert_eq!(shard.shard, Some(ShardInfo { index: 0, count: 1, start: 0, end: 40 }));
        assert_eq!(shard.merged.steps, plain.merged.steps);
        assert_eq!(shard.merged.accepted, plain.merged.accepted);
        for (a, b) in shard.runs.iter().zip(&plain.runs) {
            let va: Vec<u64> = a.samples.iter().map(|s| s.value.to_bits()).collect();
            let vb: Vec<u64> = b.samples.iter().map(|s| s.value.to_bits()).collect();
            assert_eq!(va, vb);
        }
    }

    #[test]
    fn sharded_run_reports_per_shard_accounting_and_combines() {
        let model = GaussTarget { n: 41 };
        let kernel = rw_kernel(1.0);
        let report = Session::new(&model)
            .kernel(&kernel)
            .chains(2)
            .seed(7)
            .budget(Budget::Steps(300))
            .burn_in(50)
            .shards(3)
            .run_sharded()
            .unwrap();
        assert_eq!(report.shards.len(), 3);
        let mut rows = 0;
        for (s, r) in report.shards.iter().enumerate() {
            let info = r.shard.expect("per-shard stamp");
            assert_eq!(info.index, s);
            assert_eq!(info.count, 3);
            rows += info.rows();
            assert_eq!(r.n_data, Some(info.rows()));
            assert_eq!(r.failed_chains(), 0);
            assert!(r.merged.steps > 0);
            // the stamp rides into the JSON for per-shard accounting
            let json = r.to_json();
            assert!(json.contains(&format!("\"shard\":{{\"index\":{s},\"count\":3")), "{json}");
        }
        assert_eq!(rows, 41, "shards tile the population");
        // shard seeds are decorrelated: not all first draws identical
        let firsts: Vec<u64> = report
            .shards
            .iter()
            .map(|r| r.runs[0].samples[0].value.to_bits())
            .collect();
        assert!(firsts.windows(2).any(|w| w[0] != w[1]), "{firsts:?}");
        // consensus combination exists and is finite
        let g = report.combined().unwrap();
        assert!(g.mean.is_finite() && g.var > 0.0 && g.n > 0);
        assert_eq!(report.merged().steps, 3 * 2 * 300);
    }

    #[test]
    #[should_panic(expected = "run_sharded")]
    fn plain_run_refuses_a_sharded_config() {
        let model = GaussTarget { n: 10 };
        let kernel = rw_kernel(1.0);
        let _ = Session::new(&model)
            .kernel(&kernel)
            .budget(Budget::Steps(5))
            .init(0.0)
            .shards(2)
            .run();
    }

    #[test]
    fn unsharded_json_reports_shard_null() {
        let model = GaussTarget { n: 10 };
        let kernel = rw_kernel(1.0);
        let report = Session::new(&model)
            .kernel(&kernel)
            .budget(Budget::Steps(5))
            .init(0.0)
            .run();
        assert!(report.to_json().contains("\"shard\":null,"));
    }

    #[test]
    fn pre_raised_cancel_token_stops_before_any_step() {
        let model = GaussTarget { n: 10 };
        let kernel = rw_kernel(1.0);
        let tok = CancelToken::new();
        tok.cancel();
        let report = Session::new(&model)
            .kernel(&kernel)
            .chains(2)
            .seed(4)
            .budget(Budget::Steps(10_000))
            .cancel_token(tok)
            .init(0.0)
            .run();
        // cancelled at the first step boundary: zero steps, clean
        // Completed statuses — the caller holding the token knows why
        assert_eq!(report.merged.steps, 0);
        assert_eq!(report.failed_chains(), 0);
        assert!(report.statuses.iter().all(|s| *s == ChainStatus::Completed));
    }

    #[test]
    fn progress_board_reaches_the_budget_totals() {
        let model = GaussTarget { n: 10 };
        let kernel = rw_kernel(1.0);
        let board = Arc::new(ProgressBoard::new(3));
        let report = Session::new(&model)
            .kernel(&kernel)
            .chains(3)
            .seed(6)
            .budget(Budget::Steps(123))
            .progress_board(Arc::clone(&board))
            .init(0.0)
            .run();
        let snap = board.snapshot();
        assert_eq!(snap.steps, vec![123, 123, 123]);
        assert_eq!(snap.total_steps() as usize, report.merged.steps);
        assert_eq!(snap.total_accepted() as usize, report.merged.accepted);
        assert_eq!(snap.total_data_used(), report.merged.data_used);
        assert!((snap.acceptance_rate() - report.acceptance_rate()).abs() < 1e-15);
    }

    #[test]
    #[should_panic(expected = "progress board sized for")]
    fn mis_sized_progress_board_is_refused() {
        let model = GaussTarget { n: 10 };
        let kernel = rw_kernel(1.0);
        let _ = Session::new(&model)
            .kernel(&kernel)
            .chains(2)
            .budget(Budget::Steps(5))
            .progress_board(Arc::new(ProgressBoard::new(3)))
            .init(0.0)
            .run();
    }

    #[test]
    fn json_num_handles_non_finite() {
        assert_eq!(json_num(1.5), "1.5");
        assert_eq!(json_num(f64::NAN), "null");
        assert_eq!(json_num(f64::INFINITY), "null");
    }

    #[test]
    fn json_str_escapes_labels() {
        assert_eq!(json_str("exact"), "\"exact\"");
        assert_eq!(json_str("my \"fast\" run"), "\"my \\\"fast\\\" run\"");
        assert_eq!(json_str("a\\b\nc"), "\"a\\\\b\\nc\"");
        assert_eq!(json_str("\u{1}"), "\"\\u0001\"");
    }
}

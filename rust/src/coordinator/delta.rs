//! Error in the MH acceptance probability (paper Eqn. 6 / supp. B):
//!
//!   Delta(theta, theta') = int_{Pa}^{1} E(mu_std(u)) du
//!                        - int_{0}^{Pa} E(mu_std(u)) du
//!
//! where mu_std(u) = (mu - mu0(u)) sqrt(N-1) / sigma_l and
//! Pa = min(1, exp(N mu - c)) with c the prior/proposal log correction.
//! E(mu_std) comes from the random-walk DP; since a design search
//! evaluates Delta at many (mu, sigma_l) pairs we precompute E and pi_bar
//! on a |mu_std| grid once per test configuration and interpolate.

use crate::coordinator::dp::{analyze_walk, uniform_pis};
use crate::stats::quadrature::gauss_legendre_composite;

/// Precomputed E(|mu_std|) and pi_bar(|mu_std|) for one test config.
/// Both are even functions of mu_std (the walk mirrors), so the grid
/// covers [0, mu_max].
#[derive(Clone, Debug)]
pub struct SeqTestTable {
    mu_grid: Vec<f64>,
    err: Vec<f64>,
    pi: Vec<f64>,
    /// pi_bar limit for |mu_std| -> inf (one mini-batch always decides).
    pi_floor: f64,
}

impl SeqTestTable {
    /// Build the table for a Pocock test with batch `m`, population `n`,
    /// knob `eps`. `points` grid nodes on [0, mu_max], DP grid `grid`.
    pub fn build(m: usize, n: usize, eps: f64, mu_max: f64, points: usize, grid: usize) -> Self {
        let pis = uniform_pis(m, n);
        let g = crate::stats::normal::phi_inv(1.0 - eps.clamp(1e-12, 0.5 - 1e-12));
        let bounds = vec![g; pis.len().saturating_sub(1)];
        Self::build_with_bounds(&pis, &bounds, mu_max, points, grid)
    }

    /// Build for arbitrary stage proportions and bounds.
    pub fn build_with_bounds(
        pis: &[f64],
        bounds: &[f64],
        mu_max: f64,
        points: usize,
        grid: usize,
    ) -> Self {
        assert!(points >= 2 && mu_max > 0.0);
        // Quadratic spacing: dense near 0 where E varies fastest.
        let mu_grid: Vec<f64> = (0..points)
            .map(|i| {
                let t = i as f64 / (points - 1) as f64;
                mu_max * t * t
            })
            .collect();
        let mut err = Vec::with_capacity(points);
        let mut pi = Vec::with_capacity(points);
        for &mu in &mu_grid {
            let a = analyze_walk(mu, pis, bounds, grid);
            err.push(a.error);
            pi.push(a.expected_pi);
        }
        let pi_floor = pis.first().copied().unwrap_or(1.0);
        SeqTestTable { mu_grid, err, pi, pi_floor }
    }

    fn interp(&self, xs: &[f64], mu_std: f64, tail: f64) -> f64 {
        let a = mu_std.abs();
        let grid = &self.mu_grid;
        if a >= *grid.last().unwrap() {
            return tail;
        }
        // binary search for the segment
        let mut lo = 0usize;
        let mut hi = grid.len() - 1;
        while hi - lo > 1 {
            let mid = (lo + hi) / 2;
            if grid[mid] <= a {
                lo = mid;
            } else {
                hi = mid;
            }
        }
        let t = (a - grid[lo]) / (grid[hi] - grid[lo]);
        xs[lo] * (1.0 - t) + xs[hi] * t
    }

    /// Interpolated sequential-test error E(mu_std).
    pub fn error(&self, mu_std: f64) -> f64 {
        self.interp(&self.err, mu_std, 0.0)
    }

    /// Interpolated expected data usage pi_bar(mu_std).
    pub fn data_usage(&self, mu_std: f64) -> f64 {
        self.interp(&self.pi, mu_std, self.pi_floor)
    }
}

/// One (theta, theta') pair reduced to the sufficient statistics the
/// analysis needs: population mean mu, population std sigma_l, and the
/// prior/proposal log correction c (so mu0(u) = (ln u + c)/N).
#[derive(Clone, Copy, Debug)]
pub struct PairStats {
    pub mu: f64,
    pub sigma_l: f64,
    pub log_correction: f64,
}

/// Exact acceptance probability Pa = min(1, exp(N mu - c)).
pub fn exact_accept_prob(n: usize, p: &PairStats) -> f64 {
    let log_pa = n as f64 * p.mu - p.log_correction;
    if log_pa >= 0.0 {
        1.0
    } else {
        log_pa.exp()
    }
}

/// mu_std(u) for a given uniform draw u (paper §5.1).
pub fn mu_std_of_u(n: usize, p: &PairStats, u: f64) -> f64 {
    let mu0 = (u.ln() + p.log_correction) / n as f64;
    if p.sigma_l <= 0.0 {
        return if p.mu > mu0 { f64::INFINITY } else { f64::NEG_INFINITY };
    }
    (p.mu - mu0) * ((n as f64 - 1.0).sqrt()) / p.sigma_l
}

/// Delta, the signed error in the acceptance probability (Eqn. 6), via
/// composite Gauss-Legendre on each side of the kink at Pa.
pub fn delta_accept_prob(n: usize, p: &PairStats, table: &SeqTestTable, panels: usize) -> f64 {
    let pa = exact_accept_prob(n, p);
    let e = |u: f64| table.error(mu_std_of_u(n, p, u));
    let upper = gauss_legendre_composite(pa, 1.0, panels.max(1), e);
    let lower = gauss_legendre_composite(0.0, pa, panels.max(1), e);
    upper - lower
}

/// Approximate acceptance probability P_{a,eps} = Pa + Delta.
pub fn approx_accept_prob(n: usize, p: &PairStats, table: &SeqTestTable, panels: usize) -> f64 {
    (exact_accept_prob(n, p) + delta_accept_prob(n, p, table, panels)).clamp(0.0, 1.0)
}

/// Expected data usage marginalized over u: E_u[pi_bar(mu_std(u))].
pub fn expected_data_usage(n: usize, p: &PairStats, table: &SeqTestTable, panels: usize) -> f64 {
    let f = |u: f64| table.data_usage(mu_std_of_u(n, p, u));
    let pa = exact_accept_prob(n, p);
    // split at the kink for accuracy
    gauss_legendre_composite(0.0, pa, panels.max(1), f)
        + gauss_legendre_composite(pa, 1.0, panels.max(1), f)
}

/// Average |E| over u (the blue-cross series of supp. Fig. 11).
pub fn mean_abs_error(n: usize, p: &PairStats, table: &SeqTestTable, panels: usize) -> f64 {
    let e = |u: f64| table.error(mu_std_of_u(n, p, u));
    let pa = exact_accept_prob(n, p);
    gauss_legendre_composite(0.0, pa, panels.max(1), e)
        + gauss_legendre_composite(pa, 1.0, panels.max(1), e)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn table() -> SeqTestTable {
        SeqTestTable::build(500, 12_214, 0.05, 12.0, 25, 128)
    }

    #[test]
    fn table_error_decreasing_in_mu() {
        let t = table();
        assert!(t.error(0.0) > t.error(1.0));
        assert!(t.error(1.0) > t.error(5.0));
        assert!(t.error(20.0) == 0.0); // beyond grid -> 0 tail
        // symmetry
        assert!((t.error(-2.0) - t.error(2.0)).abs() < 1e-15);
    }

    #[test]
    fn table_matches_direct_dp_at_nodes() {
        let t = table();
        let direct = crate::coordinator::dp::analyze_pocock(3.0, 500, 12_214, 0.05, 128);
        assert!((t.error(3.0) - direct.error).abs() < 5e-3);
        assert!((t.data_usage(3.0) - direct.expected_pi).abs() < 2e-2);
    }

    #[test]
    fn exact_accept_prob_formula() {
        let p = PairStats { mu: 0.0, sigma_l: 1.0, log_correction: 0.0 };
        assert_eq!(exact_accept_prob(100, &p), 1.0);
        let p = PairStats { mu: -0.01, sigma_l: 1.0, log_correction: 0.0 };
        assert!((exact_accept_prob(100, &p) - (-1.0f64).exp()).abs() < 1e-12);
    }

    #[test]
    fn delta_small_when_margin_large() {
        // |N mu| >> sigma_l sqrt(N): every u decides correctly; Delta ~ 0.
        // (realistic pair scale: mu ~ O(1)/N, sigma_l ~ proposal step)
        let t = table();
        let p = PairStats { mu: 2e-3, sigma_l: 0.01, log_correction: 0.0 };
        let d = delta_accept_prob(12_214, &p, &t, 32);
        assert!(d.abs() < 1e-9, "delta={d}");
    }

    #[test]
    fn delta_bounded_by_worst_case() {
        let t = table();
        let worst = t.error(0.0);
        for &(mu, c) in &[(0.0, 0.0), (1e-4, 0.5), (-2e-4, -1.0), (5e-5, 2.0)] {
            let p = PairStats { mu, sigma_l: 0.8, log_correction: c };
            let d = delta_accept_prob(12_214, &p, &t, 32);
            assert!(d.abs() <= worst + 1e-9, "mu={mu} c={c}: {d} vs {worst}");
        }
    }

    #[test]
    fn approx_prob_in_unit_interval_and_tracks_exact() {
        // Pair scale as a real chain produces: N mu - c of order 1,
        // sigma_l of order the proposal step, so mu_std(u) spans O(1)
        // and the u-errors partly cancel (supp. B / Fig. 12).
        let t = table();
        for &(mu, c) in &[(2e-4, 0.0), (-1e-4, 0.3), (0.0, -0.7), (3e-4, 4.0)] {
            let p = PairStats { mu, sigma_l: 0.01, log_correction: c };
            let pa = exact_accept_prob(12_214, &p);
            let pae = approx_accept_prob(12_214, &p, &t, 32);
            assert!((0.0..=1.0).contains(&pae));
            assert!((pae - pa).abs() < 0.15, "pa={pa} pae={pae}");
        }
    }

    #[test]
    fn data_usage_between_floor_and_one() {
        let t = table();
        for &mu in &[0.0, 5e-5, 1e-3] {
            let p = PairStats { mu, sigma_l: 1.0, log_correction: 0.0 };
            let d = expected_data_usage(12_214, &p, &t, 32);
            assert!(d >= 500.0 / 12_214.0 - 1e-9 && d <= 1.0 + 1e-9, "{d}");
        }
    }

    #[test]
    fn zero_sigma_population_is_deterministic() {
        let t = table();
        let p = PairStats { mu: 1e-3, sigma_l: 0.0, log_correction: 0.0 };
        // mu_std = +inf for u < Pa: error 0 everywhere -> delta 0
        let d = delta_accept_prob(12_214, &p, &t, 16);
        assert!(d.abs() < 1e-12);
    }
}

//! Gaussian-random-walk dynamic program for the sequential test
//! (paper §5.1 + supplementary A, Proposition 2).
//!
//! Under the CLT assumptions the z-statistics across stages follow
//!
//!   z_j | z_{j-1} ~ N( mu_std * (pi_j - pi_{j-1}) / (1 - pi_{j-1})
//!                        / sqrt(pi_j (1 - pi_j))
//!                      + z_{j-1} * sqrt( pi_{j-1} (1 - pi_j)
//!                                        / (pi_j (1 - pi_{j-1})) ),
//!                     (pi_j - pi_{j-1}) / (pi_j (1 - pi_{j-1})) )
//!
//! Thresholding |z_j| at G_j maps the sequential test onto a first-
//! passage problem; discretizing the surviving density on a grid gives
//! the O(L^2 J) dynamic program of the paper for the test error
//! E(mu_std) (Eqn. 19) and the expected data usage pi_bar (Eqn. 20).

use crate::stats::normal::phi_cdf;

/// Result of the DP (or simulation) analysis of one sequential test.
#[derive(Clone, Copy, Debug)]
pub struct SeqAnalysis {
    /// Probability of a wrong final decision, E(mu_std) (Eqn. 19/21).
    pub error: f64,
    /// P(decide mu < mu0 before the final stage).
    pub err_low: f64,
    /// P(decide mu > mu0 before the final stage).
    pub err_high: f64,
    /// Expected proportion of data consumed, pi_bar (Eqn. 20).
    pub expected_pi: f64,
    /// P(test reaches the final, full-data stage), P(j' = J).
    pub p_final: f64,
}

/// Data proportions pi_j = min(j m / N, 1) for batch size m, population N.
pub fn uniform_pis(m: usize, n: usize) -> Vec<f64> {
    assert!(m >= 1 && n >= 1);
    let mut pis = Vec::new();
    let mut used = 0usize;
    while used < n {
        used = (used + m).min(n);
        pis.push(used as f64 / n as f64);
    }
    pis
}

/// Random-walk transition coefficients at stage j: m_j = a + b z_{j-1},
/// sd = sigma (Proposition 2, Eqns. 11-12).
pub fn stage_coeffs(mu_std: f64, pi_prev: f64, pi_j: f64) -> (f64, f64, f64) {
    debug_assert!(pi_j > pi_prev && pi_j < 1.0);
    let a = mu_std * (pi_j - pi_prev) / (1.0 - pi_prev) / (pi_j * (1.0 - pi_j)).sqrt();
    let b = ((pi_prev / pi_j) * ((1.0 - pi_j) / (1.0 - pi_prev))).sqrt();
    let var = (pi_j - pi_prev) / (pi_j * (1.0 - pi_prev));
    (a, b, var.sqrt())
}

/// DP analysis of the sequential test with per-stage z-bounds `bounds`
/// (length >= pis.len() - 1; the final stage is a forced exact decision).
/// `grid` is the number of density cells L (paper's discretization).
pub fn analyze_walk(mu_std: f64, pis: &[f64], bounds: &[f64], grid: usize) -> SeqAnalysis {
    let j_max = pis.len();
    assert!(j_max >= 1);
    assert!((pis[j_max - 1] - 1.0).abs() < 1e-12, "last pi must be 1");
    assert!(bounds.len() + 1 >= j_max, "need a bound for every non-final stage");
    assert!(grid >= 8);

    if j_max == 1 {
        // Single full-data stage: decision always exact.
        return SeqAnalysis { error: 0.0, err_low: 0.0, err_high: 0.0, expected_pi: 1.0, p_final: 1.0 };
    }

    let mut err_low = 0.0f64;
    let mut err_high = 0.0f64;
    let mut expected_pi = 0.0f64;

    // Surviving density over grid cells of the previous stage.
    let mut density: Vec<f64> = Vec::new();
    let mut centers: Vec<f64> = Vec::new();

    for j in 0..j_max - 1 {
        let pi_prev = if j == 0 { 0.0 } else { pis[j - 1] };
        let pi_j = pis[j];
        let g = bounds[j];
        let (a, b, sd) = stage_coeffs(mu_std, pi_prev, pi_j);

        // New grid on [-g, g].
        let h = 2.0 * g / grid as f64;
        let new_centers: Vec<f64> = (0..grid).map(|k| -g + (k as f64 + 0.5) * h).collect();
        let mut new_density = vec![0.0f64; grid];
        let mut dec_low = 0.0f64;
        let mut dec_high = 0.0f64;

        // Sources: stage 0 has a single deterministic source of mass 1.
        let sources: &[(f64, f64)] = if j == 0 {
            &[(0.0, 1.0)]
        } else {
            // pack (center, mass) pairs lazily below
            &[]
        };
        let mut scratch_pairs: Vec<(f64, f64)> = Vec::new();
        let src_iter: &[(f64, f64)] = if j == 0 {
            sources
        } else {
            scratch_pairs.extend(centers.iter().copied().zip(density.iter().copied()));
            &scratch_pairs
        };

        for &(z_prev, mass) in src_iter {
            if mass <= 0.0 {
                continue;
            }
            let mean = a + b * z_prev;
            // tail masses
            let low = phi_cdf((-g - mean) / sd);
            let high = 1.0 - phi_cdf((g - mean) / sd);
            dec_low += mass * low;
            dec_high += mass * high;
            // interior cells: reuse edge CDF evaluations
            let mut prev_cdf = phi_cdf((-g - mean) / sd);
            for k in 0..grid {
                let upper = -g + (k as f64 + 1.0) * h;
                let c = phi_cdf((upper - mean) / sd);
                new_density[k] += mass * (c - prev_cdf);
                prev_cdf = c;
            }
        }

        err_low += dec_low;
        err_high += dec_high;
        expected_pi += pi_j * (dec_low + dec_high);
        density = new_density;
        centers = new_centers;

        // Early exit: once the surviving mass is negligible the remaining
        // stages contribute nothing measurable to error or usage.
        if density.iter().sum::<f64>() < 1e-12 {
            break;
        }
    }

    let p_final: f64 = density.iter().sum();
    expected_pi += p_final; // final stage consumes pi = 1

    // Final stage decides exactly: wrong side mass is zero unless
    // mu_std == 0, where the paper defines E as half the early mass.
    let error = if mu_std > 0.0 {
        err_low
    } else if mu_std < 0.0 {
        err_high
    } else {
        0.5 * (err_low + err_high)
    };

    SeqAnalysis { error, err_low, err_high, expected_pi, p_final }
}

/// Convenience: Pocock analysis with constant bound from epsilon.
pub fn analyze_pocock(mu_std: f64, m: usize, n: usize, eps: f64, grid: usize) -> SeqAnalysis {
    let pis = uniform_pis(m, n);
    let g = crate::stats::normal::phi_inv(1.0 - eps.clamp(1e-12, 0.5 - 1e-12));
    let bounds = vec![g; pis.len().saturating_sub(1)];
    analyze_walk(mu_std, &pis, &bounds, grid)
}

/// Monte-Carlo simulation of the same random walk (validation of the DP,
/// and the "simulation" series of Figs. 1/10).
pub fn simulate_walk(
    mu_std: f64,
    pis: &[f64],
    bounds: &[f64],
    sims: usize,
    rng: &mut crate::stats::Pcg64,
) -> SeqAnalysis {
    let j_max = pis.len();
    let mut err_low = 0usize;
    let mut err_high = 0usize;
    let mut reached_final = 0usize;
    let mut pi_sum = 0.0f64;

    for _ in 0..sims {
        let mut z = 0.0f64;
        let mut decided = false;
        for j in 0..j_max - 1 {
            let pi_prev = if j == 0 { 0.0 } else { pis[j - 1] };
            let (a, b, sd) = stage_coeffs(mu_std, pi_prev, pis[j]);
            z = a + b * z + sd * rng.normal();
            if z < -bounds[j] {
                err_low += 1;
                pi_sum += pis[j];
                decided = true;
                break;
            }
            if z > bounds[j] {
                err_high += 1;
                pi_sum += pis[j];
                decided = true;
                break;
            }
        }
        if !decided {
            reached_final += 1;
            pi_sum += 1.0;
        }
    }

    let s = sims as f64;
    let (el, eh) = (err_low as f64 / s, err_high as f64 / s);
    let error = if mu_std > 0.0 {
        el
    } else if mu_std < 0.0 {
        eh
    } else {
        0.5 * (el + eh)
    };
    SeqAnalysis {
        error,
        err_low: el,
        err_high: eh,
        expected_pi: pi_sum / s,
        p_final: reached_final as f64 / s,
    }
}

/// Worst-case error bound E(0) (Eqn. 21) for the Pocock test.
pub fn worst_case_error(m: usize, n: usize, eps: f64, grid: usize) -> f64 {
    analyze_pocock(0.0, m, n, eps, grid).error
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stats::Pcg64;

    #[test]
    fn uniform_pis_shape() {
        let pis = uniform_pis(500, 1200);
        assert_eq!(pis.len(), 3);
        assert!((pis[0] - 500.0 / 1200.0).abs() < 1e-12);
        assert!((pis[2] - 1.0).abs() < 1e-12);
        assert_eq!(uniform_pis(2000, 1200), vec![1.0]);
    }

    #[test]
    fn single_stage_is_exact() {
        let a = analyze_walk(0.7, &[1.0], &[], 64);
        assert_eq!(a.error, 0.0);
        assert_eq!(a.expected_pi, 1.0);
        assert_eq!(a.p_final, 1.0);
    }

    #[test]
    fn worst_case_symmetric() {
        let a = analyze_pocock(0.0, 500, 10_000, 0.05, 256);
        assert!((a.err_low - a.err_high).abs() < 1e-6, "{a:?}");
        assert!((a.error - 0.5 * (a.err_low + a.err_high)).abs() < 1e-12);
        // mass conservation
        assert!((a.err_low + a.err_high + a.p_final - 1.0).abs() < 1e-6);
    }

    #[test]
    fn error_decreases_away_from_mu0() {
        let e0 = analyze_pocock(0.0, 500, 10_000, 0.05, 256).error;
        let e2 = analyze_pocock(2.0, 500, 10_000, 0.05, 256).error;
        let e10 = analyze_pocock(10.0, 500, 10_000, 0.05, 256).error;
        assert!(e0 > e2 && e2 > e10, "{e0} {e2} {e10}");
        assert!(e10 < 1e-3);
    }

    #[test]
    fn data_usage_decreases_away_from_mu0() {
        let p0 = analyze_pocock(0.0, 500, 10_000, 0.05, 256).expected_pi;
        let p5 = analyze_pocock(5.0, 500, 10_000, 0.05, 256).expected_pi;
        let p20 = analyze_pocock(20.0, 500, 10_000, 0.05, 256).expected_pi;
        assert!(p0 > p5 && p5 > p20, "{p0} {p5} {p20}");
        // far from mu0 a single batch should essentially always decide
        assert!((p20 - 500.0 / 10_000.0).abs() < 0.01, "p20={p20}");
    }

    #[test]
    fn smaller_eps_means_less_error_more_data() {
        let tight = analyze_pocock(1.0, 500, 10_000, 0.005, 256);
        let loose = analyze_pocock(1.0, 500, 10_000, 0.2, 256);
        assert!(tight.error < loose.error);
        assert!(tight.expected_pi > loose.expected_pi);
    }

    #[test]
    fn dp_matches_simulation() {
        let mut rng = Pcg64::seeded(0);
        for &mu_std in &[0.0, 0.8, -1.5, 3.0] {
            let pis = uniform_pis(500, 12_214);
            let g = crate::stats::normal::phi_inv(1.0 - 0.05);
            let bounds = vec![g; pis.len() - 1];
            let dp = analyze_walk(mu_std, &pis, &bounds, 400);
            let sim = simulate_walk(mu_std, &pis, &bounds, 60_000, &mut rng);
            assert!(
                (dp.error - sim.error).abs() < 0.01,
                "mu_std={mu_std}: dp {} sim {}",
                dp.error,
                sim.error
            );
            assert!(
                (dp.expected_pi - sim.expected_pi).abs() < 0.01,
                "mu_std={mu_std}: dp {} sim {}",
                dp.expected_pi,
                sim.expected_pi
            );
        }
    }

    #[test]
    fn error_bounded_by_worst_case() {
        let worst = worst_case_error(500, 12_214, 0.05, 300);
        for &mu in &[0.2, 0.9, 2.5, -4.0] {
            let e = analyze_pocock(mu, 500, 12_214, 0.05, 300).error;
            assert!(e <= worst + 1e-6, "mu={mu}: {e} > {worst}");
        }
    }

    #[test]
    fn grid_refinement_converges() {
        let coarse = analyze_pocock(0.5, 500, 10_000, 0.05, 64).error;
        let fine = analyze_pocock(0.5, 500, 10_000, 0.05, 512).error;
        let finer = analyze_pocock(0.5, 500, 10_000, 0.05, 1024).error;
        assert!((fine - finer).abs() < (coarse - finer).abs() + 1e-9);
        assert!((fine - finer).abs() < 2e-4, "{fine} vs {finer}");
    }

    #[test]
    fn obf_bounds_shift_usage_earlier_decisions_later() {
        // O'Brien-Fleming spends little alpha early: more early survival,
        // but same-ish worst-case error. Check it runs and conserves mass.
        let pis = uniform_pis(500, 10_000);
        let g0 = 2.0;
        let bounds: Vec<f64> = pis[..pis.len() - 1]
            .iter()
            .map(|&p| g0 * p.powf(-0.5))
            .collect();
        let a = analyze_walk(0.0, &pis, &bounds, 256);
        assert!((a.err_low + a.err_high + a.p_final - 1.0).abs() < 1e-6);
        // early bounds are larger than Pocock's G(0.023)~2: fewer early stops
        let pocock = analyze_walk(0.0, &pis, &vec![2.0; pis.len() - 1], 256);
        assert!(a.p_final > pocock.p_final);
    }
}

//! Adaptive epsilon — the paper's future-work extension (§7):
//!
//! > "a better algorithm can be obtained by adapting this threshold over
//! >  time. An adaptive algorithm can tune bias and variance
//! >  contributions in such a way that at every moment our risk (the sum
//! >  of squared bias and variance) is as low as possible."
//!
//! Risk = B^2 + V with B growing ~linearly in the acceptance error
//! (Theorem 1, and Delta itself is ~linear in eps for small eps) and
//! V ~ sigma^2 tau / t after t effective samples. Minimizing
//! `(c1 eps)^2 + c2 / t` over eps at a given t — subject to the fact
//! that smaller eps costs more data per step, so t grows more slowly —
//! yields an annealing schedule eps_t ~ t^(-1/2): both terms then decay
//! together at O(1/t). `EpsSchedule::Anneal` implements exactly that
//! (with a floor), and `run_adaptive_chain` re-arms the sequential test
//! per step. The ablation bench (`exp::ablation`) compares fixed
//! epsilons against the schedule on the logistic risk curve.

use crate::coordinator::chain::{drive_chain, Budget, ChainStats, Sample};
use crate::coordinator::checkpoint::{BinReader, BinWriter, CkptError, Persist};
use crate::coordinator::executor::IntraPar;
use crate::coordinator::kernel::{restore_sched, StepOutcome, TransitionKernel};
use crate::coordinator::mh::{mh_step, MhMode, MhScratch};
use crate::models::traits::{LlDiffModel, ProposalKernel};
use crate::stats::Pcg64;

/// Epsilon as a function of the step index.
#[derive(Clone, Debug)]
pub enum EpsSchedule {
    Fixed(f64),
    /// eps_t = max(eps_min, eps0 * (tau / (tau + t))^gamma);
    /// gamma = 0.5 equalizes the bias^2 and variance decay rates.
    Anneal { eps0: f64, eps_min: f64, tau: f64, gamma: f64 },
}

impl EpsSchedule {
    /// Default annealing: start loose (0.2), floor at 0.005, gamma 1/2.
    pub fn default_anneal() -> Self {
        EpsSchedule::Anneal { eps0: 0.2, eps_min: 0.005, tau: 100.0, gamma: 0.5 }
    }

    pub fn eps_at(&self, step: usize) -> f64 {
        match *self {
            EpsSchedule::Fixed(e) => e,
            EpsSchedule::Anneal { eps0, eps_min, tau, gamma } => {
                (eps0 * (tau / (tau + step as f64)).powf(gamma)).max(eps_min)
            }
        }
    }
}

/// The adaptive-epsilon MH family as a `TransitionKernel`: the test's
/// error knob is re-armed per step from the schedule (the step counter
/// lives in the chain-local scratch, so parallel chains anneal
/// independently and deterministically).
pub struct AdaptiveMhKernel<'a, M, K> {
    pub model: &'a M,
    pub proposal: &'a K,
    pub schedule: &'a EpsSchedule,
    /// Sequential-test mini-batch increment m.
    pub batch: usize,
}

/// Per-chain scratch: the usual MH workspace plus the step counter the
/// schedule is evaluated at.
pub struct AdaptiveScratch {
    mh: MhScratch,
    step: usize,
}

impl<M, K> TransitionKernel for AdaptiveMhKernel<'_, M, K>
where
    M: LlDiffModel + Sync,
    K: ProposalKernel<M::Param>,
{
    type State = M::Param;
    type Scratch = AdaptiveScratch;

    fn scratch(&self, _init: &M::Param) -> AdaptiveScratch {
        AdaptiveScratch { mh: MhScratch::new(self.model.n()), step: 0 }
    }

    fn scratch_par(&self, _init: &M::Param, intra: &IntraPar) -> AdaptiveScratch {
        AdaptiveScratch { mh: MhScratch::with_scan_pool(self.model.n(), intra), step: 0 }
    }

    fn step(
        &self,
        state: &mut M::Param,
        scratch: &mut AdaptiveScratch,
        rng: &mut Pcg64,
    ) -> StepOutcome {
        let mode = MhMode::approx(self.schedule.eps_at(scratch.step), self.batch);
        scratch.step += 1;
        let proposal = self.proposal.propose(state, rng);
        let info = mh_step(self.model, state, proposal, &mode, &mut scratch.mh, rng);
        StepOutcome {
            accepted: info.accepted,
            data_used: info.n_used as u64,
            guard_trips: info.guard_trips,
        }
    }

    // The annealing step counter drives the epsilon schedule, so a
    // resumed chain must pick the schedule up exactly where it stopped.
    fn save_scratch(&self, scratch: &AdaptiveScratch, w: &mut BinWriter) {
        scratch.mh.sched.persist(w);
        w.put_usize(scratch.step);
    }

    fn restore_scratch(
        &self,
        scratch: &mut AdaptiveScratch,
        r: &mut BinReader<'_>,
    ) -> Result<(), CkptError> {
        restore_sched(&mut scratch.mh.sched, self.model.n(), r)?;
        scratch.step = r.usize_()?;
        Ok(())
    }
}

/// `run_chain` with a per-step epsilon schedule.
#[allow(clippy::too_many_arguments)]
pub fn run_adaptive_chain<M, K, F>(
    model: &M,
    kernel: &K,
    schedule: &EpsSchedule,
    batch: usize,
    init: M::Param,
    budget: Budget,
    burn_in: usize,
    thin: usize,
    f: F,
    rng: &mut Pcg64,
) -> (Vec<Sample>, ChainStats)
where
    M: LlDiffModel + Sync,
    K: ProposalKernel<M::Param>,
    F: FnMut(&M::Param) -> f64,
{
    drive_chain(
        &AdaptiveMhKernel { model, proposal: kernel, schedule, batch },
        init,
        budget,
        burn_in,
        thin,
        f,
        rng,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::two_class_gaussian;
    use crate::models::LogisticModel;
    use crate::samplers::GaussianRandomWalk;

    #[test]
    fn schedule_monotone_decreasing_with_floor() {
        let s = EpsSchedule::default_anneal();
        let mut prev = f64::INFINITY;
        for step in [0usize, 10, 100, 1_000, 100_000] {
            let e = s.eps_at(step);
            assert!(e <= prev + 1e-15);
            assert!(e >= 0.005 - 1e-15);
            prev = e;
        }
        assert_eq!(s.eps_at(10_000_000), 0.005);
        assert_eq!(EpsSchedule::Fixed(0.1).eps_at(12345), 0.1);
    }

    #[test]
    fn adaptive_chain_uses_more_data_over_time() {
        let model = LogisticModel::new(two_class_gaussian(8_000, 6, 1.2, 0), 10.0).expect("population exceeds the u32 index space");
        let init = model.map_estimate(40);
        let kernel = GaussianRandomWalk::new(0.02, 10.0);
        let mut rng = Pcg64::seeded(0);
        let schedule =
            EpsSchedule::Anneal { eps0: 0.3, eps_min: 0.001, tau: 30.0, gamma: 1.0 };
        let (samples, stats) = run_adaptive_chain(
            &model,
            &kernel,
            &schedule,
            400,
            init,
            Budget::Steps(600),
            0,
            1,
            |_| 0.0,
            &mut rng,
        );
        assert_eq!(stats.steps, 600);
        // early chunk uses less data per step than the late chunk
        let early = samples[99].at_data as f64 / 100.0;
        let late = (samples[599].at_data - samples[499].at_data) as f64 / 100.0;
        assert!(late > early, "early {early} late {late}");
    }

    #[test]
    fn adaptive_matches_fixed_when_schedule_constant() {
        let model = LogisticModel::new(two_class_gaussian(4_000, 4, 1.2, 1), 10.0).expect("population exceeds the u32 index space");
        let init = model.map_estimate(30);
        let kernel = GaussianRandomWalk::new(0.02, 10.0);
        let run = |sched: EpsSchedule| {
            let mut rng = Pcg64::seeded(7);
            run_adaptive_chain(
                &model, &kernel, &sched, 400, init.clone(),
                Budget::Steps(200), 0, 1, |t| t[0], &mut rng,
            )
        };
        let (a, sa) = run(EpsSchedule::Fixed(0.05));
        let (b, sb) = run(EpsSchedule::Anneal {
            eps0: 0.05,
            eps_min: 0.05,
            tau: 1.0,
            gamma: 0.5,
        });
        assert_eq!(sa.accepted, sb.accepted);
        assert_eq!(sa.data_used, sb.data_used);
        assert_eq!(a.last().unwrap().value, b.last().unwrap().value);
    }
}

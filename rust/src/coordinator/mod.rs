//! The Layer-3 coordinator: the paper's contribution.
//!
//! * `austerity` — the sequential approximate MH test (Alg. 1)
//! * `mh` — exact + approximate MH step orchestration (plus the
//!   state-caching fast path `mh_step_cached`)
//! * `chain` — single-chain driver with budgets and thinning
//! * `engine` — parallel multi-chain engine: worker pool, per-chain RNG
//!   streams and observers, merged stats, split R-hat / ESS
//! * `scheduler` — without-replacement mini-batch scheduling
//! * `dp` — Gaussian-random-walk error/usage dynamic program (§5.1)
//! * `delta` — acceptance-probability error via quadrature (Eqn. 6)
//! * `design` — optimal test design, average & worst-case (§5.2)

pub mod adaptive;
pub mod austerity;
pub mod chain;
pub mod delta;
pub mod design;
pub mod dp;
pub mod engine;
pub mod mh;
pub mod scheduler;

pub use adaptive::{run_adaptive_chain, EpsSchedule};
pub use austerity::{seq_mh_test, seq_mh_test_cached, BoundSeq, SeqTestConfig, SeqTestOutcome};
pub use chain::{run_chain, run_chain_cached, run_chains_parallel, Budget, ChainStats, Sample};
pub use delta::{PairStats, SeqTestTable};
pub use design::{average_design, wang_tsiatis_design, worst_case_design, DesignChoice, DesignGrid, WtChoice};
pub use dp::{analyze_pocock, analyze_walk, simulate_walk, uniform_pis, SeqAnalysis};
pub use engine::{
    parallel_map, run_engine, run_engine_cached, ChainObserver, ChainRun, EngineConfig,
    EngineResult,
};
pub use mh::{mh_step, mh_step_cached, MhMode, MhScratch, StepInfo};
pub use scheduler::MinibatchScheduler;

//! The Layer-3 coordinator: the paper's contribution.
//!
//! * `session` — **the front door**: the `Session` / `KernelSession`
//!   builders configuring a multi-chain launch (model, proposal kernel,
//!   acceptance rule, budget, recording) and returning one typed
//!   `RunReport` with JSON serialization
//! * `record` — built-in per-chain observers (`Param`, `ScalarFn`,
//!   `VecMean`, `Thinned`) and the `RecordSpec` factories behind
//!   `Session::record`
//! * `accept` — the pluggable acceptance-test layer: one trait
//!   (`AcceptanceTest`) behind the exact scan, the paper's sequential
//!   test, the minibatch Barker test and the confidence sampler
//! * `austerity` — the sequential approximate MH test (Alg. 1)
//! * `mh` — MH step orchestration over any acceptance test (plus the
//!   state-caching fast path `mh_step_cached`)
//! * `kernel` — the `TransitionKernel` step abstraction every sampler
//!   family implements (MH exact/approx ± cache here; SGLD ± correction,
//!   pseudo-marginal, Gibbs/Potts sweeps next to their samplers), so one
//!   driver and one engine serve them all
//! * `chain` — generic single-chain driver (`drive_chain`) with step /
//!   wall / datapoint budgets and thinning
//! * `checkpoint` — versioned binary chain checkpoints (`Persist`,
//!   `ChainCheckpoint`) behind `Session::checkpoint_every` /
//!   `resume_from`: CRC32-sealed v3 framing, rotated generations per
//!   chain, manifest validation on resume, all written atomically
//!   through a swappable `StoreLayer` for crash-consistent resume with
//!   bit-identical replay
//! * `supervise` — the self-healing layer over the engine: per-chain
//!   restart-from-checkpoint under a `RetryPolicy`, the stall watchdog
//!   over the progress counters, and the `min_chains` quorum policy
//!   (typed `LaunchError` when the launch cannot continue); also the
//!   caller-facing `CancelToken` (cooperative cancel at step
//!   boundaries) and `ProgressBoard` (live per-chain progress) the
//!   serve layer builds on
//! * `guard` — numerical-guard layer (`GuardPolicy`, `Guarded`)
//!   screening the log-likelihood moments entering any acceptance test
//!   for NaN/Inf poisoning
//! * `engine` — parallel multi-chain engine over any kernel: per-chain
//!   RNG streams and observers, merged stats, split R-hat / ESS. Its
//!   `run_engine*` launchers (and `chain`'s `run_chain*`) are internal —
//!   `Session` dispatches to them and replays them bit for bit; they
//!   stay exported only as the same-seed oracle for the integration
//!   tests
//! * `executor` — the persistent work-sharing pool (`Executor`) both
//!   the engine's chain fan-out and the chains' intra-step scan spans
//!   draw from, so concurrent launches multiplex over fixed hardware
//!   with zero per-step thread spawns
//! * `adaptive` — adaptive-epsilon MH kernel (paper §7 future work)
//! * `scheduler` — without-replacement mini-batch scheduling
//! * `dp` — Gaussian-random-walk error/usage dynamic program (§5.1)
//! * `delta` — acceptance-probability error via quadrature (Eqn. 6)
//! * `design` — optimal test design, average & worst-case (§5.2)

pub mod accept;
pub mod adaptive;
pub mod austerity;
pub mod chain;
pub mod checkpoint;
pub mod delta;
pub mod design;
pub mod dp;
pub mod engine;
pub mod executor;
pub mod guard;
pub mod kernel;
pub mod mh;
pub mod record;
pub mod scheduler;
pub mod session;
pub mod supervise;

pub use accept::{
    AcceptOutcome, AcceptanceTest, AusterityTest, BarkerTest, ConfidenceConfig, ConfidenceTest,
    ExactTest, MomentsSource, StageTrace,
};
pub use adaptive::{run_adaptive_chain, AdaptiveMhKernel, EpsSchedule};
pub use austerity::{seq_mh_test, seq_mh_test_cached, BoundSeq, SeqTestConfig, SeqTestOutcome};
pub use chain::{current_chain_step, drive_chain, drive_chain_par, Budget, ChainStats, Sample};
pub use checkpoint::{
    crc32, fs_store, BinReader, BinWriter, ChainCheckpoint, CheckpointSpec, CkptError, FsStore,
    Persist, ShardStamp, StoreLayer, DEFAULT_RETAIN,
};
pub use delta::{PairStats, SeqTestTable};
pub use design::{average_design, wang_tsiatis_design, worst_case_design, DesignChoice, DesignGrid, WtChoice};
pub use dp::{analyze_pocock, analyze_walk, simulate_walk, uniform_pis, SeqAnalysis};
pub use engine::{
    parallel_map, parallel_map_result, ChainObserver, ChainRun, ChainStatus, EngineConfig,
    EngineResult, TaskError,
};
pub use executor::{Executor, IntraPar};
pub use guard::{GuardPolicy, Guarded};
pub use kernel::{CachedMhKernel, CachedMhScratch, MhKernel, StepOutcome, TransitionKernel};
pub use mh::{mh_step, mh_step_cached, CachedMoments, MhMode, MhScratch, ModelMoments, StepInfo};
pub use record::{
    Components, Param, PerChain, RecordDefault, RecordSpec, Replicate, ScalarFn, Thinned, VecMean,
};
pub use scheduler::MinibatchScheduler;
pub use session::{
    KernelSession, NoProposal, RunReport, Session, ShardInfo, ShardReport, ShardedError,
};
pub use supervise::{CancelToken, LaunchError, ProgressBoard, ProgressSnapshot, RetryPolicy};

// Legacy launch entry points, demoted to internal shims behind
// `Session` / `KernelSession`: re-exported (hidden) solely so the
// integration tests can replay them as the same-seed bit-identity
// oracle of the front-end.
#[doc(hidden)]
pub use chain::{run_chain, run_chain_cached};
#[doc(hidden)]
pub use engine::{run_engine, run_engine_cached, run_engine_kernel};

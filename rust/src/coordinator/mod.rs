//! The Layer-3 coordinator: the paper's contribution.
//!
//! * `austerity` — the sequential approximate MH test (Alg. 1)
//! * `mh` — exact + approximate MH step orchestration
//! * `chain` — chain driver with budgets, thinning, parallel replicas
//! * `scheduler` — without-replacement mini-batch scheduling
//! * `dp` — Gaussian-random-walk error/usage dynamic program (§5.1)
//! * `delta` — acceptance-probability error via quadrature (Eqn. 6)
//! * `design` — optimal test design, average & worst-case (§5.2)

pub mod adaptive;
pub mod austerity;
pub mod chain;
pub mod delta;
pub mod design;
pub mod dp;
pub mod mh;
pub mod scheduler;

pub use adaptive::{run_adaptive_chain, EpsSchedule};
pub use austerity::{seq_mh_test, BoundSeq, SeqTestConfig, SeqTestOutcome};
pub use chain::{run_chain, run_chains_parallel, Budget, ChainStats, Sample};
pub use delta::{PairStats, SeqTestTable};
pub use design::{average_design, wang_tsiatis_design, worst_case_design, DesignChoice, DesignGrid, WtChoice};
pub use dp::{analyze_pocock, analyze_walk, simulate_walk, uniform_pis, SeqAnalysis};
pub use mh::{mh_step, MhMode, MhScratch, StepInfo};
pub use scheduler::MinibatchScheduler;

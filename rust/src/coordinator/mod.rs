//! The Layer-3 coordinator: the paper's contribution.
//!
//! * `accept` — the pluggable acceptance-test layer: one trait
//!   (`AcceptanceTest`) behind the exact scan, the paper's sequential
//!   test, the minibatch Barker test and the confidence sampler
//! * `austerity` — the sequential approximate MH test (Alg. 1)
//! * `mh` — MH step orchestration over any acceptance test (plus the
//!   state-caching fast path `mh_step_cached`)
//! * `kernel` — the `TransitionKernel` step abstraction every sampler
//!   family implements (MH exact/approx ± cache here; SGLD ± correction,
//!   pseudo-marginal, Gibbs/Potts sweeps next to their samplers), so one
//!   driver and one engine serve them all
//! * `chain` — generic single-chain driver (`drive_chain`) with step /
//!   wall / datapoint budgets and thinning
//! * `engine` — parallel multi-chain engine over any kernel
//!   (`run_engine_kernel`): worker pool, per-chain RNG streams and
//!   observers, merged stats, split R-hat / ESS
//! * `adaptive` — adaptive-epsilon MH kernel (paper §7 future work)
//! * `scheduler` — without-replacement mini-batch scheduling
//! * `dp` — Gaussian-random-walk error/usage dynamic program (§5.1)
//! * `delta` — acceptance-probability error via quadrature (Eqn. 6)
//! * `design` — optimal test design, average & worst-case (§5.2)

pub mod accept;
pub mod adaptive;
pub mod austerity;
pub mod chain;
pub mod delta;
pub mod design;
pub mod dp;
pub mod engine;
pub mod kernel;
pub mod mh;
pub mod scheduler;

pub use accept::{
    AcceptOutcome, AcceptanceTest, AusterityTest, BarkerTest, ConfidenceConfig, ConfidenceTest,
    ExactTest, MomentsSource, StageTrace,
};
pub use adaptive::{run_adaptive_chain, AdaptiveMhKernel, EpsSchedule};
pub use austerity::{seq_mh_test, seq_mh_test_cached, BoundSeq, SeqTestConfig, SeqTestOutcome};
pub use chain::{
    drive_chain, drive_chain_par, run_chain, run_chain_cached, Budget, ChainStats, Sample,
};
pub use delta::{PairStats, SeqTestTable};
pub use design::{average_design, wang_tsiatis_design, worst_case_design, DesignChoice, DesignGrid, WtChoice};
pub use dp::{analyze_pocock, analyze_walk, simulate_walk, uniform_pis, SeqAnalysis};
pub use engine::{
    parallel_map, run_engine, run_engine_cached, run_engine_kernel, ChainObserver, ChainRun,
    EngineConfig, EngineResult,
};
pub use kernel::{CachedMhKernel, CachedMhScratch, MhKernel, StepOutcome, TransitionKernel};
pub use mh::{mh_step, mh_step_cached, CachedMoments, MhMode, MhScratch, ModelMoments, StepInfo};
pub use scheduler::MinibatchScheduler;

//! Built-in observer library for the `Session` front-end: the common
//! per-chain test functions callers used to hand-roll against
//! `ChainObserver`, packaged as small reusable structs.
//!
//! * [`Param`] — record a parameter component (or the full vector) of
//!   every retained draw;
//! * [`ScalarFn`] — a named wrapper around an arbitrary scalar test
//!   function `f(&state) -> f64`;
//! * [`VecMean`] — stream a vector-valued test function (a predictive
//!   panel, say) into a running mean, mergeable across chains;
//! * [`Thinned`] — run a heavyweight inner observer only every k-th
//!   retained draw.
//!
//! A `Session` turns one of these into K per-chain observers through
//! [`RecordSpec`]: [`Replicate`] clones a prototype per chain
//! (`Session::record`), [`PerChain`] calls a factory with the chain
//! index (`Session::record_with`), and [`RecordDefault`] falls back to
//! `Param::index(0)` when the caller never asked for anything else.

use crate::coordinator::engine::ChainObserver;
use crate::metrics::predictive::PredictiveMean;

/// Chain states whose coordinates can be read as `f64` — what the
/// default recorders operate on. Implemented for the scalar and
/// `Vec<f64>` parameter types of the MH model zoo; states with richer
/// structure (`RjState`, spin configurations, Stiefel matrices) are
/// recorded through [`ScalarFn`] / [`VecMean`] / custom observers
/// instead.
pub trait Components {
    /// Number of recordable coordinates.
    fn n_components(&self) -> usize;

    /// Coordinate `j` (callers keep `j < n_components()`).
    fn component(&self, j: usize) -> f64;

    /// All coordinates as an owned vector.
    fn to_vec(&self) -> Vec<f64> {
        (0..self.n_components()).map(|j| self.component(j)).collect()
    }
}

impl Components for f64 {
    fn n_components(&self) -> usize {
        1
    }

    fn component(&self, _j: usize) -> f64 {
        *self
    }
}

impl Components for Vec<f64> {
    fn n_components(&self) -> usize {
        self.len()
    }

    fn component(&self, j: usize) -> f64 {
        self[j]
    }

    fn to_vec(&self) -> Vec<f64> {
        self.clone()
    }
}

#[derive(Clone, Copy, Debug)]
enum ParamMode {
    Index(usize),
    All,
}

/// Record parameter coordinates of every retained draw.
///
/// * `Param::index(j)` — the recorded scalar stream (and so the engine's
///   R-hat / ESS) is coordinate `j`;
/// * `Param::all()` — additionally stores the full parameter vector per
///   retained draw (`draws()`), with coordinate 0 as the scalar stream.
#[derive(Clone, Debug)]
pub struct Param {
    mode: ParamMode,
    draws: Vec<Vec<f64>>,
}

impl Param {
    /// Record coordinate `j` as the scalar stream.
    pub fn index(j: usize) -> Param {
        Param { mode: ParamMode::Index(j), draws: Vec::new() }
    }

    /// Record the full parameter vector of every retained draw.
    pub fn all() -> Param {
        Param { mode: ParamMode::All, draws: Vec::new() }
    }

    /// Full vectors recorded by `Param::all` (empty for `Param::index`).
    pub fn draws(&self) -> &[Vec<f64>] {
        &self.draws
    }

    /// Consume the observer, returning the recorded vectors.
    pub fn into_draws(self) -> Vec<Vec<f64>> {
        self.draws
    }
}

impl<P: Components> ChainObserver<P> for Param {
    fn observe(&mut self, p: &P) -> f64 {
        match self.mode {
            ParamMode::Index(j) => p.component(j),
            ParamMode::All => {
                self.draws.push(p.to_vec());
                p.component(0)
            }
        }
    }
}

/// A named scalar test-function observer: records `f(&state)` for every
/// retained draw. Equivalent to passing the bare closure, but clonable
/// composition (`Session::record`, [`Thinned`]) gets a nameable type.
#[derive(Clone, Debug)]
pub struct ScalarFn<F>(F);

impl<F> ScalarFn<F> {
    pub fn new(f: F) -> Self {
        ScalarFn(f)
    }
}

impl<P, F: FnMut(&P) -> f64 + Send> ChainObserver<P> for ScalarFn<F> {
    fn observe(&mut self, p: &P) -> f64 {
        (self.0)(p)
    }
}

/// Streams a vector-valued test function into a running per-coordinate
/// mean (a [`PredictiveMean`]): the predictive-panel observer of the
/// risk figures. Per-chain accumulators merge across the engine's
/// chains via [`VecMean::merged`]. The recorded scalar stream is 0 — use
/// a second launch (or a custom observer) when cross-chain diagnostics
/// of a scalar are also needed.
#[derive(Clone, Debug)]
pub struct VecMean<F> {
    f: F,
    acc: PredictiveMean,
}

impl<F> VecMean<F> {
    /// Accumulate the running mean of `f(&state)` over `dim`-point
    /// vectors.
    pub fn new(dim: usize, f: F) -> Self {
        VecMean { f, acc: PredictiveMean::new(dim) }
    }

    /// This chain's accumulator.
    pub fn accumulator(&self) -> &PredictiveMean {
        &self.acc
    }

    /// Merge the per-chain accumulators an engine launch handed back
    /// into one pooled estimate.
    pub fn merged(observers: &[VecMean<F>]) -> PredictiveMean {
        let dim = observers.first().map(|o| o.acc.len()).unwrap_or(0);
        let mut pm = PredictiveMean::new(dim);
        for o in observers {
            pm.merge(&o.acc);
        }
        pm
    }
}

impl<P, F: FnMut(&P) -> Vec<f64> + Send> ChainObserver<P> for VecMean<F> {
    fn observe(&mut self, p: &P) -> f64 {
        let v = (self.f)(p);
        self.acc.add(&v);
        0.0
    }
}

/// Runs a heavyweight inner observer every `every`-th retained draw
/// (e.g. a `VecMean` over a large predictive panel). Between refreshes
/// the recorded scalar repeats the last computed value — prefer the
/// engine-level `Session::thin` when the scalar stream itself should be
/// thinned; `Thinned` is for decoupling an expensive accumulator from
/// the retention rate.
#[derive(Clone, Debug)]
pub struct Thinned<O> {
    inner: O,
    every: usize,
    seen: usize,
    last: f64,
}

impl<O> Thinned<O> {
    pub fn new(inner: O, every: usize) -> Self {
        assert!(every >= 1, "Thinned: every must be >= 1");
        Thinned { inner, every, seen: 0, last: f64::NAN }
    }

    pub fn inner(&self) -> &O {
        &self.inner
    }

    pub fn into_inner(self) -> O {
        self.inner
    }
}

impl<P, O: ChainObserver<P>> ChainObserver<P> for Thinned<O> {
    fn observe(&mut self, p: &P) -> f64 {
        if self.seen % self.every == 0 {
            self.last = self.inner.observe(p);
        }
        self.seen += 1;
        self.last
    }
}

/// How a `Session` builds one observer per chain.
pub trait RecordSpec<P> {
    type Observer: ChainObserver<P>;

    /// Build chain `chain`'s observer.
    fn make(&self, chain: usize) -> Self::Observer;
}

/// Clone one observer prototype per chain (`Session::record`).
pub struct Replicate<O>(pub O);

impl<P, O: ChainObserver<P> + Clone> RecordSpec<P> for Replicate<O> {
    type Observer = O;

    fn make(&self, _chain: usize) -> O {
        self.0.clone()
    }
}

/// Build each chain's observer from a `Fn(chain) -> observer` factory
/// (`Session::record_with`).
pub struct PerChain<F>(pub F);

impl<P, O, F> RecordSpec<P> for PerChain<F>
where
    O: ChainObserver<P>,
    F: Fn(usize) -> O,
{
    type Observer = O;

    fn make(&self, chain: usize) -> O {
        (self.0)(chain)
    }
}

/// The recorder a `Session` uses when the caller never set one: record
/// coordinate 0 of the chain state.
#[derive(Clone, Copy, Debug, Default)]
pub struct RecordDefault;

impl<P: Components> RecordSpec<P> for RecordDefault {
    type Observer = Param;

    fn make(&self, _chain: usize) -> Param {
        Param::index(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn observe_all<O: ChainObserver<Vec<f64>>>(obs: &mut O, states: &[Vec<f64>]) -> Vec<f64> {
        states.iter().map(|s| obs.observe(s)).collect()
    }

    #[test]
    fn param_index_records_component() {
        let mut p = Param::index(1);
        let vals = observe_all(&mut p, &[vec![1.0, 2.0], vec![3.0, 4.0]]);
        assert_eq!(vals, vec![2.0, 4.0]);
        assert!(p.draws().is_empty());
    }

    #[test]
    fn param_all_keeps_full_vectors() {
        let mut p = Param::all();
        let vals = observe_all(&mut p, &[vec![1.0, 2.0], vec![3.0, 4.0]]);
        assert_eq!(vals, vec![1.0, 3.0]); // scalar stream is component 0
        assert_eq!(p.draws(), &[vec![1.0, 2.0], vec![3.0, 4.0]]);
        assert_eq!(p.into_draws().len(), 2);
    }

    #[test]
    fn scalar_components() {
        let x = 2.5f64;
        assert_eq!(x.n_components(), 1);
        assert_eq!(x.component(0), 2.5);
        assert_eq!(Components::to_vec(&x), vec![2.5]);
        let mut p = Param::index(0);
        assert_eq!(p.observe(&x), 2.5);
    }

    #[test]
    fn scalar_fn_wraps_closure() {
        let mut s = ScalarFn::new(|v: &Vec<f64>| v.iter().sum());
        assert_eq!(s.observe(&vec![1.0, 2.0, 3.0]), 6.0);
    }

    #[test]
    fn vec_mean_accumulates_and_merges() {
        let mk = || VecMean::new(2, |v: &Vec<f64>| vec![v[0], 2.0 * v[0]]);
        let mut a = mk();
        let mut b = mk();
        a.observe(&vec![1.0]);
        a.observe(&vec![3.0]);
        b.observe(&vec![5.0]);
        let pooled = VecMean::merged(&[a, b]);
        assert_eq!(pooled.count(), 3);
        let m = pooled.mean();
        assert!((m[0] - 3.0).abs() < 1e-12);
        assert!((m[1] - 6.0).abs() < 1e-12);
    }

    #[test]
    fn thinned_runs_inner_every_kth() {
        let mut t = Thinned::new(Param::all(), 3);
        for i in 0..7 {
            let v = t.observe(&vec![i as f64]);
            // refreshed at draws 0, 3, 6; repeats in between
            assert_eq!(v, ((i / 3) * 3) as f64, "draw {i}");
        }
        assert_eq!(t.inner().draws().len(), 3);
        assert_eq!(t.into_inner().into_draws(), vec![vec![0.0], vec![3.0], vec![6.0]]);
    }

    #[test]
    fn record_specs_build_observers() {
        let rep = Replicate(Param::index(0));
        let mut o: Param = RecordSpec::<Vec<f64>>::make(&rep, 3);
        assert_eq!(o.observe(&vec![7.0]), 7.0);

        let per = PerChain(|c: usize| ScalarFn::new(move |_: &Vec<f64>| c as f64));
        let mut o = RecordSpec::<Vec<f64>>::make(&per, 2);
        assert_eq!(o.observe(&vec![0.0]), 2.0);

        let mut o: Param = RecordSpec::<Vec<f64>>::make(&RecordDefault, 0);
        assert_eq!(o.observe(&vec![9.0, 1.0]), 9.0);
    }
}

//! The transition-kernel step abstraction: one trait every sampler
//! family implements, so one chain driver and one multi-chain engine
//! serve them all (see DESIGN.md §Transition-kernel layer).
//!
//! The tall-data literature (Bardenet, Doucet & Holmes 2015; Seita et
//! al. 2017) frames exact/approximate MH, corrected SGLD, pseudo-marginal
//! chains and subsampled Gibbs sweeps as instances of one *subsampled
//! transition kernel*: a Markov move whose accept/advance decision
//! consumes a data-dependent number of likelihood (or potential-pair)
//! evaluations. `TransitionKernel` is exactly that interface:
//!
//! * `State` — the chain state the kernel advances (a parameter vector,
//!   an `RjState`, a spin configuration, a parameter + auxiliary weight);
//! * `Scratch` — chain-local reusable workspace (schedulers, index
//!   buffers, likelihood caches) built once per chain so the steady
//!   state allocates nothing and parallel chains never contend;
//! * `step` — one transition: mutate the state in place, return the
//!   accept flag and the datapoint-evaluation cost, which the driver
//!   accumulates into `ChainStats`.
//!
//! The MH families live here (`MhKernel`, `CachedMhKernel`); the
//! non-MH families implement the trait next to their samplers
//! (`samplers::{SgldKernel, PmKernel, GibbsSweepKernel,
//! PottsSweepKernel}`) and the adaptive-epsilon chain in
//! `coordinator::adaptive::AdaptiveMhKernel`.

use crate::coordinator::accept::AcceptanceTest;
use crate::coordinator::checkpoint::{BinReader, BinWriter, CkptError, Persist};
use crate::coordinator::executor::IntraPar;
use crate::coordinator::mh::{mh_step, mh_step_cached, MhMode, MhScratch};
use crate::coordinator::scheduler::MinibatchScheduler;
use crate::models::traits::{CachedLlDiff, LlDiffModel, ProposalKernel};
use crate::stats::Pcg64;

/// What one transition reported: the deltas the chain driver folds into
/// `ChainStats` (steps are counted by the driver itself).
#[derive(Clone, Copy, Debug)]
pub struct StepOutcome {
    /// Did the chain move? (Always true for Gibbs-style sweeps.)
    pub accepted: bool,
    /// Datapoint (or potential-pair) evaluations consumed by this step.
    pub data_used: u64,
    /// Numerical-guard trips during this step's decision (0 unless the
    /// kernel routes through `coordinator::guard::Guarded`).
    pub guard_trips: u32,
}

/// One sampler family: a Markov transition over `State` with chain-local
/// `Scratch`, stepped by `drive_chain` / `run_engine_kernel`.
pub trait TransitionKernel {
    /// Chain state advanced by `step`.
    type State: Clone + Send;
    /// Chain-local workspace; built once per chain via `scratch`.
    type Scratch;

    /// Build the per-chain scratch for a chain starting at `init`
    /// (schedulers, buffers, likelihood caches seeded from the state).
    fn scratch(&self, init: &Self::State) -> Self::Scratch;

    /// `scratch` for a chain granted intra-step parallelism: `intra`
    /// names the span width and the shared executor pool the chain may
    /// draw on *inside* a step (the engine grants `threads / chains`
    /// spans on its pool when it has more workers than chains). Kernels
    /// with a parallelizable step (the MH families' exact-rule full
    /// scan) override this; the default ignores the grant — intra-step
    /// parallelism never changes results, only wall time.
    fn scratch_par(&self, init: &Self::State, intra: &IntraPar) -> Self::Scratch {
        let _ = intra;
        self.scratch(init)
    }

    /// Perform one transition, mutating `state` in place.
    fn step(
        &self,
        state: &mut Self::State,
        scratch: &mut Self::Scratch,
        rng: &mut Pcg64,
    ) -> StepOutcome;

    /// Serialize the scratch state that persists *across* steps (scheduler
    /// permutations, annealing counters) for a checkpoint. Per-decision
    /// temporaries (index buffers, traces, rebuildable likelihood caches)
    /// must be skipped. The default persists nothing — correct only for
    /// kernels whose scratch carries no cross-step state.
    fn save_scratch(&self, scratch: &Self::Scratch, w: &mut BinWriter) {
        let _ = (scratch, w);
    }

    /// Inverse of `save_scratch`, applied to a freshly built scratch
    /// (`scratch_par` on the restored state) at resume.
    fn restore_scratch(
        &self,
        scratch: &mut Self::Scratch,
        r: &mut BinReader<'_>,
    ) -> Result<(), CkptError> {
        let _ = (scratch, r);
        Ok(())
    }
}

/// Shared restore guard for the scheduler-carrying kernels: the persisted
/// scheduler must cover the same population as the model the kernel now
/// runs against.
pub(crate) fn restore_sched(
    sched: &mut MinibatchScheduler,
    n_expected: usize,
    r: &mut BinReader<'_>,
) -> Result<(), CkptError> {
    let restored = MinibatchScheduler::restore(r)?;
    if restored.n() != n_expected {
        return Err(CkptError::Mismatch(format!(
            "scheduler covers {} datapoints, model has {n_expected}",
            restored.n()
        )));
    }
    *sched = restored;
    Ok(())
}

/// Metropolis-Hastings under any `AcceptanceTest` (exact full-data scan,
/// the paper's sequential test, the Barker test, the confidence sampler,
/// or a custom rule — `T` defaults to the `MhMode` enum): propose via
/// `proposal`, decide via `mh_step`. This is the family every
/// `run_chain` / `run_engine` call runs on.
pub struct MhKernel<'a, M, K, T = MhMode> {
    pub model: &'a M,
    pub proposal: &'a K,
    pub mode: &'a T,
}

impl<M, K, T> TransitionKernel for MhKernel<'_, M, K, T>
where
    M: LlDiffModel + Sync,
    K: ProposalKernel<M::Param>,
    T: AcceptanceTest,
{
    type State = M::Param;
    type Scratch = MhScratch;

    fn scratch(&self, _init: &M::Param) -> MhScratch {
        MhScratch::new(self.model.n())
    }

    fn scratch_par(&self, _init: &M::Param, intra: &IntraPar) -> MhScratch {
        MhScratch::with_scan_pool(self.model.n(), intra)
    }

    fn step(&self, state: &mut M::Param, scratch: &mut MhScratch, rng: &mut Pcg64) -> StepOutcome {
        let proposal = self.proposal.propose(state, rng);
        let info = mh_step(self.model, state, proposal, self.mode, scratch, rng);
        StepOutcome {
            accepted: info.accepted,
            data_used: info.n_used as u64,
            guard_trips: info.guard_trips,
        }
    }

    fn save_scratch(&self, scratch: &MhScratch, w: &mut BinWriter) {
        scratch.sched.persist(w);
    }

    fn restore_scratch(
        &self,
        scratch: &mut MhScratch,
        r: &mut BinReader<'_>,
    ) -> Result<(), CkptError> {
        restore_sched(&mut scratch.sched, self.model.n(), r)
    }
}

/// Per-chain scratch of the cached MH family: the usual `MhScratch` plus
/// the model's per-datapoint likelihood cache (owned by the chain, never
/// by the shared model).
pub struct CachedMhScratch<M: CachedLlDiff> {
    pub mh: MhScratch,
    pub cache: M::Cache,
}

/// `MhKernel` on the state-caching fast path (`CachedLlDiff`): decisions
/// are bit-identical to the uncached kernel under the same RNG stream
/// for every acceptance rule — the contract regression-tested in
/// `tests/integration_engine.rs` and `tests/integration_accept.rs`.
pub struct CachedMhKernel<'a, M, K, T = MhMode> {
    pub model: &'a M,
    pub proposal: &'a K,
    pub mode: &'a T,
}

impl<M, K, T> TransitionKernel for CachedMhKernel<'_, M, K, T>
where
    M: CachedLlDiff + Sync,
    K: ProposalKernel<M::Param>,
    T: AcceptanceTest,
{
    type State = M::Param;
    type Scratch = CachedMhScratch<M>;

    fn scratch(&self, init: &M::Param) -> CachedMhScratch<M> {
        CachedMhScratch { mh: MhScratch::new(self.model.n()), cache: self.model.init_cache(init) }
    }

    fn scratch_par(&self, init: &M::Param, intra: &IntraPar) -> CachedMhScratch<M> {
        CachedMhScratch {
            mh: MhScratch::with_scan_pool(self.model.n(), intra),
            cache: self.model.init_cache(init),
        }
    }

    fn step(
        &self,
        state: &mut M::Param,
        scratch: &mut CachedMhScratch<M>,
        rng: &mut Pcg64,
    ) -> StepOutcome {
        let proposal = self.proposal.propose(state, rng);
        let info = mh_step_cached(
            self.model,
            state,
            &mut scratch.cache,
            proposal,
            self.mode,
            &mut scratch.mh,
            rng,
        );
        StepOutcome {
            accepted: info.accepted,
            data_used: info.n_used as u64,
            guard_trips: info.guard_trips,
        }
    }

    // The likelihood cache is deliberately NOT serialized: `scratch_par`
    // rebuilds it from the restored state via `init_cache`, and the
    // cached-vs-uncached bit-identity contract makes the rebuild exact.
    fn save_scratch(&self, scratch: &CachedMhScratch<M>, w: &mut BinWriter) {
        scratch.mh.sched.persist(w);
    }

    fn restore_scratch(
        &self,
        scratch: &mut CachedMhScratch<M>,
        r: &mut BinReader<'_>,
    ) -> Result<(), CkptError> {
        restore_sched(&mut scratch.mh.sched, self.model.n(), r)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::chain::{drive_chain, Budget};
    use crate::models::traits::Proposal;

    /// Dummy kernel: deterministic counter state, fixed per-step cost.
    struct Counter {
        cost: u64,
    }

    impl TransitionKernel for Counter {
        type State = u64;
        type Scratch = ();

        fn scratch(&self, _: &u64) {}

        fn step(&self, state: &mut u64, _: &mut (), _: &mut Pcg64) -> StepOutcome {
            *state += 1;
            StepOutcome { accepted: true, data_used: self.cost, guard_trips: 0 }
        }
    }

    #[test]
    fn data_budget_stops_at_cumulative_cost() {
        let kernel = Counter { cost: 7 };
        let mut rng = Pcg64::seeded(0);
        let (samples, stats) =
            drive_chain(&kernel, 0u64, Budget::Data(70), 0, 1, |&s| s as f64, &mut rng);
        // 10 steps of cost 7 reach exactly 70
        assert_eq!(stats.steps, 10);
        assert_eq!(stats.data_used, 70);
        assert_eq!(samples.len(), 10);
        assert_eq!(samples.last().unwrap().value, 10.0);
        assert_eq!(samples.last().unwrap().at_data, 70);
    }

    #[test]
    fn data_budget_is_inclusive_of_overshoot() {
        // a step that crosses the budget still completes; the NEXT step
        // does not start.
        let kernel = Counter { cost: 9 };
        let mut rng = Pcg64::seeded(0);
        let (_, stats) = drive_chain(&kernel, 0u64, Budget::Data(20), 0, 1, |&s| s as f64, &mut rng);
        assert_eq!(stats.steps, 3); // 9, 18, 27 >= 20 after the third
        assert_eq!(stats.data_used, 27);
    }

    #[test]
    fn mh_kernel_matches_manual_propose_step_loop() {
        use crate::models::traits::testutil::FixedPopulation;

        let model = FixedPopulation { ls: vec![0.002; 400] };
        let proposal = |_: &(), _: &mut Pcg64| Proposal { param: (), log_correction: 0.4 };
        let mode = MhMode::Exact;

        // manual loop (the pre-refactor shape of run_chain)
        let mut rng_a = Pcg64::new(3, 5);
        let mut scratch = MhScratch::new(model.n());
        let mut accepted_a = 0usize;
        let mut cur = ();
        for _ in 0..200 {
            let p = proposal.propose(&cur, &mut rng_a);
            let info = mh_step(&model, &mut cur, p, &mode, &mut scratch, &mut rng_a);
            accepted_a += info.accepted as usize;
        }

        // the same chain through the kernel + driver
        let kernel = MhKernel { model: &model, proposal: &proposal, mode: &mode };
        let mut rng_b = Pcg64::new(3, 5);
        let (_, stats) = drive_chain(&kernel, (), Budget::Steps(200), 0, 1, |_| 0.0, &mut rng_b);
        assert_eq!(stats.accepted, accepted_a);
        assert_eq!(stats.data_used, 200 * 400);
    }
}

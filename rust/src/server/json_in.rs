//! Hand-rolled zero-dependency JSON *reader* — the mirror of the
//! crate's hand-rolled writer (`RunReport::to_json` and the `json_num`
//! / `json_str` helpers in `coordinator::checkpoint`).
//!
//! Strictness contract (pinned by `tests/integration_serve.rs`):
//!
//! * **No non-finite numbers.** The writer emits `null` for NaN/Inf;
//!   the reader enforces the same contract from the other side —
//!   `NaN`, `Infinity`, `1e999` and friends are typed
//!   [`JsonError::NonFinite`] rejections, never a silent `f64::NAN`
//!   smuggled into a job spec.
//! * **No duplicate keys.** Last-one-wins parsing silently drops half
//!   of a conflicting job spec; we reject instead
//!   ([`JsonError::DuplicateKey`]).
//! * **No trailing garbage.** A value must consume the whole input
//!   ([`JsonError::TrailingGarbage`]) — `{"a":1}}` and `{}{}` are
//!   errors, exactly what a framed HTTP body should guarantee.
//! * Strict JSON grammar otherwise: no comments, no single quotes, no
//!   leading zeros, no unescaped control characters, `\uXXXX` escapes
//!   with surrogate pairs, and a nesting-depth limit so a hostile body
//!   cannot blow the stack.
//!
//! Round-trip contract: `parse(&v.write()) == Ok(v)` for every tree
//! this module can produce. The writer keeps integers and floats
//! distinguishable (`Num` always renders with a `.` or exponent —
//! Rust's shortest-roundtrip `f64` Display never loses bits), so the
//! round trip is exact down to f64 bit patterns.

use std::fmt;

/// Maximum array/object nesting the parser accepts. Deep enough for
/// any legitimate job spec or report by orders of magnitude; shallow
/// enough that a `[[[[…` bomb fails fast instead of overflowing the
/// recursive-descent stack.
pub const MAX_DEPTH: usize = 64;

/// A parsed JSON value. Integers that fit `i64` are kept exact
/// (`Int`); everything else numeric is an `Num` (f64). Object member
/// order is preserved (the writer emits deterministic key order, and
/// keeping it makes round-trip comparisons trivial).
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Int(i64),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    /// Object member lookup (None for non-objects and missing keys).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(members) => members.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn is_null(&self) -> bool {
        matches!(self, Json::Null)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Json::Int(i) => Some(*i),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Int(i) if *i >= 0 => Some(*i as u64),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_u64().and_then(|v| usize::try_from(v).ok())
    }

    /// Numeric view: floats as-is, integers widened (exact up to 2^53,
    /// and the writer never emits draws outside that — they come from
    /// f64s in the first place).
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            Json::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(items) => Some(items),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&[(String, Json)]> {
        match self {
            Json::Obj(members) => Some(members),
            _ => None,
        }
    }

    /// Serialize back out through the same conventions as the crate's
    /// writer: non-finite floats become `null`, floats always carry a
    /// `.` so they reparse as `Num` (not `Int`), strings escape
    /// exactly like `json_str`.
    pub fn write(&self) -> String {
        let mut out = String::new();
        self.write_into(&mut out);
        out
    }

    fn write_into(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Int(i) => out.push_str(&i.to_string()),
            Json::Num(n) => {
                if n.is_finite() {
                    // Rust f64 Display is shortest-roundtrip and never
                    // emits an exponent; add ".0" to integral values so
                    // the reader keeps Int and Num distinguishable
                    let s = format!("{n}");
                    out.push_str(&s);
                    if !s.contains('.') {
                        out.push_str(".0");
                    }
                } else {
                    out.push_str("null");
                }
            }
            Json::Str(s) => out.push_str(&crate::coordinator::checkpoint::json_str(s)),
            Json::Arr(items) => {
                out.push('[');
                for (i, v) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write_into(out);
                }
                out.push(']');
            }
            Json::Obj(members) => {
                out.push('{');
                for (i, (k, v)) in members.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push_str(&crate::coordinator::checkpoint::json_str(k));
                    out.push(':');
                    v.write_into(out);
                }
                out.push('}');
            }
        }
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.write())
    }
}

/// Typed parse failure, with the byte offset where it was detected.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum JsonError {
    /// Input ended inside a value.
    Eof,
    /// Unexpected byte (shown) where a value/token was required.
    Unexpected { at: usize, found: char },
    /// `NaN`/`Infinity` token, or a literal that overflows f64 — the
    /// writer-side `null` convention is the only spelling of
    /// non-finite this crate accepts.
    NonFinite { at: usize },
    /// Same key twice in one object.
    DuplicateKey { at: usize, key: String },
    /// A complete value was parsed but bytes remain.
    TrailingGarbage { at: usize },
    /// Malformed `\` escape inside a string.
    BadEscape { at: usize },
    /// Number breaks the JSON grammar (leading zero, bare `.`, …).
    BadNumber { at: usize },
    /// Raw control character (U+0000..U+001F) inside a string.
    ControlChar { at: usize },
    /// Nesting beyond [`MAX_DEPTH`].
    TooDeep { at: usize },
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            JsonError::Eof => write!(f, "unexpected end of input"),
            JsonError::Unexpected { at, found } => {
                write!(f, "unexpected character {found:?} at byte {at}")
            }
            JsonError::NonFinite { at } => write!(
                f,
                "non-finite number at byte {at} (NaN/Infinity are not JSON; \
                 this API writes them as null)"
            ),
            JsonError::DuplicateKey { at, key } => {
                write!(f, "duplicate object key {key:?} at byte {at}")
            }
            JsonError::TrailingGarbage { at } => {
                write!(f, "trailing garbage after the value, starting at byte {at}")
            }
            JsonError::BadEscape { at } => write!(f, "bad string escape at byte {at}"),
            JsonError::BadNumber { at } => write!(f, "malformed number at byte {at}"),
            JsonError::ControlChar { at } => {
                write!(f, "raw control character in string at byte {at}")
            }
            JsonError::TooDeep { at } => {
                write!(f, "nesting deeper than {MAX_DEPTH} at byte {at}")
            }
        }
    }
}

impl std::error::Error for JsonError {}

/// Parse one complete JSON value; the whole input must be consumed
/// (modulo surrounding whitespace).
pub fn parse(src: &str) -> Result<Json, JsonError> {
    let mut p = Parser { bytes: src.as_bytes(), pos: 0 };
    p.skip_ws();
    let v = p.value(0)?;
    p.skip_ws();
    if p.pos < p.bytes.len() {
        return Err(JsonError::TrailingGarbage { at: p.pos });
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while let Some(&b) = self.bytes.get(self.pos) {
            if b == b' ' || b == b'\t' || b == b'\n' || b == b'\r' {
                self.pos += 1;
            } else {
                break;
            }
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn unexpected(&self) -> JsonError {
        match self.peek() {
            None => JsonError::Eof,
            Some(b) => JsonError::Unexpected { at: self.pos, found: b as char },
        }
    }

    /// Consume `lit` if it starts here.
    fn eat(&mut self, lit: &str) -> bool {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            true
        } else {
            false
        }
    }

    fn value(&mut self, depth: usize) -> Result<Json, JsonError> {
        if depth > MAX_DEPTH {
            return Err(JsonError::TooDeep { at: self.pos });
        }
        let at = self.pos;
        match self.peek().ok_or(JsonError::Eof)? {
            b'n' => {
                if self.eat("null") {
                    Ok(Json::Null)
                } else if self.eat("nan") {
                    Err(JsonError::NonFinite { at })
                } else {
                    Err(self.unexpected())
                }
            }
            b't' => {
                if self.eat("true") {
                    Ok(Json::Bool(true))
                } else {
                    Err(self.unexpected())
                }
            }
            b'f' => {
                if self.eat("false") {
                    Ok(Json::Bool(false))
                } else {
                    Err(self.unexpected())
                }
            }
            // the common non-JSON spellings of non-finite get the typed
            // rejection rather than a generic "unexpected character"
            b'N' => {
                if self.eat("NaN") {
                    Err(JsonError::NonFinite { at })
                } else {
                    Err(self.unexpected())
                }
            }
            b'I' => {
                if self.eat("Infinity") || self.eat("Inf") {
                    Err(JsonError::NonFinite { at })
                } else {
                    Err(self.unexpected())
                }
            }
            b'i' => {
                if self.eat("inf") {
                    Err(JsonError::NonFinite { at })
                } else {
                    Err(self.unexpected())
                }
            }
            b'"' => self.string().map(Json::Str),
            b'[' => {
                self.pos += 1;
                let mut items = Vec::new();
                self.skip_ws();
                if self.peek() == Some(b']') {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                loop {
                    self.skip_ws();
                    items.push(self.value(depth + 1)?);
                    self.skip_ws();
                    match self.peek() {
                        Some(b',') => self.pos += 1,
                        Some(b']') => {
                            self.pos += 1;
                            return Ok(Json::Arr(items));
                        }
                        _ => return Err(self.unexpected()),
                    }
                }
            }
            b'{' => {
                self.pos += 1;
                let mut members: Vec<(String, Json)> = Vec::new();
                self.skip_ws();
                if self.peek() == Some(b'}') {
                    self.pos += 1;
                    return Ok(Json::Obj(members));
                }
                loop {
                    self.skip_ws();
                    let key_at = self.pos;
                    if self.peek() != Some(b'"') {
                        return Err(self.unexpected());
                    }
                    let key = self.string()?;
                    if members.iter().any(|(k, _)| *k == key) {
                        return Err(JsonError::DuplicateKey { at: key_at, key });
                    }
                    self.skip_ws();
                    if self.peek() != Some(b':') {
                        return Err(self.unexpected());
                    }
                    self.pos += 1;
                    self.skip_ws();
                    let val = self.value(depth + 1)?;
                    members.push((key, val));
                    self.skip_ws();
                    match self.peek() {
                        Some(b',') => self.pos += 1,
                        Some(b'}') => {
                            self.pos += 1;
                            return Ok(Json::Obj(members));
                        }
                        _ => return Err(self.unexpected()),
                    }
                }
            }
            b'-' | b'0'..=b'9' => self.number(),
            _ => Err(self.unexpected()),
        }
    }

    /// Parse a string (cursor on the opening quote).
    fn string(&mut self) -> Result<String, JsonError> {
        debug_assert_eq!(self.peek(), Some(b'"'));
        self.pos += 1;
        let mut out = String::new();
        loop {
            let at = self.pos;
            match self.peek().ok_or(JsonError::Eof)? {
                b'"' => {
                    self.pos += 1;
                    return Ok(out);
                }
                b'\\' => {
                    self.pos += 1;
                    match self.peek().ok_or(JsonError::Eof)? {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            self.pos += 1;
                            let hi = self.hex4().ok_or(JsonError::BadEscape { at })?;
                            let ch = if (0xD800..0xDC00).contains(&hi) {
                                // high surrogate: a \uXXXX low surrogate
                                // must follow
                                if !self.eat("\\u") {
                                    return Err(JsonError::BadEscape { at });
                                }
                                let lo = self.hex4().ok_or(JsonError::BadEscape { at })?;
                                if !(0xDC00..0xE000).contains(&lo) {
                                    return Err(JsonError::BadEscape { at });
                                }
                                let cp =
                                    0x10000 + ((hi - 0xD800) << 10) + (lo - 0xDC00);
                                char::from_u32(cp).ok_or(JsonError::BadEscape { at })?
                            } else if (0xDC00..0xE000).contains(&hi) {
                                // lone low surrogate
                                return Err(JsonError::BadEscape { at });
                            } else {
                                char::from_u32(hi).ok_or(JsonError::BadEscape { at })?
                            };
                            out.push(ch);
                            // hex4 leaves the cursor after the digits;
                            // skip the shared `pos += 1` below
                            continue;
                        }
                        _ => return Err(JsonError::BadEscape { at }),
                    }
                    self.pos += 1;
                }
                b if b < 0x20 => return Err(JsonError::ControlChar { at }),
                _ => {
                    // multi-byte UTF-8 sequences pass through verbatim:
                    // the input is &str, so they are guaranteed valid
                    let s = &self.bytes[self.pos..];
                    let step = utf8_len(s[0]);
                    for i in 0..step {
                        out.push_str(
                            std::str::from_utf8(&s[i..i + 1]).unwrap_or(""),
                        );
                    }
                    self.pos += step;
                }
            }
        }
    }

    /// Read exactly four hex digits, advancing past them.
    fn hex4(&mut self) -> Option<u32> {
        let s = self.bytes.get(self.pos..self.pos + 4)?;
        let mut v = 0u32;
        for &b in s {
            v = v * 16 + (b as char).to_digit(16)?;
        }
        self.pos += 4;
        Some(v)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let at = self.pos;
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
            // catch "-Infinity" / "-inf" / "-nan" with the typed error
            if matches!(self.peek(), Some(b'I') | Some(b'i') | Some(b'N') | Some(b'n')) {
                let rest = &self.bytes[self.pos..];
                for lit in ["Infinity", "Inf", "inf", "NaN", "nan"] {
                    if rest.starts_with(lit.as_bytes()) {
                        return Err(JsonError::NonFinite { at });
                    }
                }
                return Err(self.unexpected());
            }
        }
        // integer part: 0, or [1-9][0-9]*
        match self.peek() {
            Some(b'0') => {
                self.pos += 1;
                if matches!(self.peek(), Some(b'0'..=b'9')) {
                    return Err(JsonError::BadNumber { at });
                }
            }
            Some(b'1'..=b'9') => {
                while matches!(self.peek(), Some(b'0'..=b'9')) {
                    self.pos += 1;
                }
            }
            _ => return Err(JsonError::BadNumber { at }),
        }
        let mut is_float = false;
        if self.peek() == Some(b'.') {
            is_float = true;
            self.pos += 1;
            if !matches!(self.peek(), Some(b'0'..=b'9')) {
                return Err(JsonError::BadNumber { at });
            }
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            is_float = true;
            self.pos += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.pos += 1;
            }
            if !matches!(self.peek(), Some(b'0'..=b'9')) {
                return Err(JsonError::BadNumber { at });
            }
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])
            .expect("number slice is ASCII");
        if !is_float {
            // keep i64-sized integers exact; larger literals fall
            // through to the float path below
            if let Ok(i) = text.parse::<i64>() {
                return Ok(Json::Int(i));
            }
        }
        let v: f64 = text.parse().map_err(|_| JsonError::BadNumber { at })?;
        if !v.is_finite() {
            // e.g. 1e999 overflows to +Inf — same contract as the
            // explicit Infinity tokens
            return Err(JsonError::NonFinite { at });
        }
        Ok(Json::Num(v))
    }
}

/// Byte length of the UTF-8 sequence starting with `first` (input is a
/// valid &str, so the lead byte is trustworthy).
fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn p(src: &str) -> Json {
        parse(src).unwrap_or_else(|e| panic!("parse {src:?}: {e}"))
    }

    #[test]
    fn scalars_parse() {
        assert_eq!(p("null"), Json::Null);
        assert_eq!(p("true"), Json::Bool(true));
        assert_eq!(p("false"), Json::Bool(false));
        assert_eq!(p("42"), Json::Int(42));
        assert_eq!(p("-7"), Json::Int(-7));
        assert_eq!(p("0"), Json::Int(0));
        assert_eq!(p("3.25"), Json::Num(3.25));
        assert_eq!(p("-0.5"), Json::Num(-0.5));
        assert_eq!(p("1e3"), Json::Num(1000.0));
        assert_eq!(p("2.5E-2"), Json::Num(0.025));
        assert_eq!(p("\"hi\""), Json::Str("hi".into()));
        assert_eq!(p("  [1, 2]  "), Json::Arr(vec![Json::Int(1), Json::Int(2)]));
    }

    #[test]
    fn nested_structures_parse_with_order_preserved() {
        let v = p(r#"{"b":[1,{"x":null}],"a":"s"}"#);
        let obj = v.as_obj().unwrap();
        assert_eq!(obj[0].0, "b");
        assert_eq!(obj[1].0, "a");
        assert_eq!(v.get("a").and_then(Json::as_str), Some("s"));
        assert_eq!(v.get("b").and_then(Json::as_arr).map(|a| a.len()), Some(2));
        assert!(v.get("b").unwrap().as_arr().unwrap()[1].get("x").unwrap().is_null());
    }

    #[test]
    fn string_escapes_decode() {
        assert_eq!(p(r#""a\"b\\c\/d\n\t\r\b\f""#), Json::Str("a\"b\\c/d\n\t\r\u{8}\u{c}".into()));
        assert_eq!(p(r#""Aé""#), Json::Str("Aé".into()));
        // surrogate pair: U+1D11E musical G clef
        assert_eq!(p(r#""𝄞""#), Json::Str("\u{1D11E}".into()));
        // raw multi-byte UTF-8 passes through
        assert_eq!(p("\"héllo → €\""), Json::Str("héllo → €".into()));
    }

    #[test]
    fn non_finite_is_a_typed_rejection() {
        for src in [
            "NaN", "nan", "Infinity", "-Infinity", "inf", "-inf", "Inf", "-nan", "1e999",
            "-1e999", "[1, NaN]", r#"{"eps": Infinity}"#,
        ] {
            match parse(src) {
                Err(JsonError::NonFinite { .. }) => {}
                other => panic!("{src:?} -> {other:?}, wanted NonFinite"),
            }
        }
    }

    #[test]
    fn duplicate_keys_are_rejected() {
        match parse(r#"{"a":1,"b":2,"a":3}"#) {
            Err(JsonError::DuplicateKey { key, .. }) => assert_eq!(key, "a"),
            other => panic!("{other:?}"),
        }
        // nested objects each get their own key space
        assert!(parse(r#"{"a":{"a":1},"b":{"a":2}}"#).is_ok());
    }

    #[test]
    fn trailing_garbage_is_rejected() {
        for src in ["{} {}", "1 2", "[1]]", "null x", "{\"a\":1}tail"] {
            match parse(src) {
                Err(JsonError::TrailingGarbage { .. }) | Err(JsonError::Unexpected { .. }) => {}
                other => panic!("{src:?} -> {other:?}"),
            }
        }
        // specifically: a complete value plus garbage is TrailingGarbage
        assert!(matches!(parse("{} {}"), Err(JsonError::TrailingGarbage { .. })));
    }

    #[test]
    fn malformed_inputs_are_typed_errors_not_panics() {
        assert!(matches!(parse(""), Err(JsonError::Eof)));
        assert!(matches!(parse("{"), Err(JsonError::Eof)));
        assert!(matches!(parse("\"abc"), Err(JsonError::Eof)));
        assert!(matches!(parse("01"), Err(JsonError::BadNumber { .. })));
        assert!(matches!(parse("1."), Err(JsonError::BadNumber { .. })));
        assert!(matches!(parse("-"), Err(JsonError::BadNumber { .. })));
        assert!(matches!(parse("1e"), Err(JsonError::BadNumber { .. })));
        assert!(matches!(parse(r#""\q""#), Err(JsonError::BadEscape { .. })));
        assert!(matches!(parse(r#""\ud834""#), Err(JsonError::BadEscape { .. })));
        assert!(matches!(parse("\"a\nb\""), Err(JsonError::ControlChar { .. })));
        assert!(matches!(parse("{1:2}"), Err(JsonError::Unexpected { .. })));
        assert!(matches!(parse("[1,]"), Err(JsonError::Unexpected { .. })));
        let bomb = "[".repeat(MAX_DEPTH + 2);
        assert!(matches!(parse(&bomb), Err(JsonError::TooDeep { .. })));
    }

    #[test]
    fn big_integers_fall_back_to_float_or_reject() {
        assert_eq!(p("9223372036854775807"), Json::Int(i64::MAX));
        // beyond i64: becomes a float (finite), not an error
        match p("92233720368547758080") {
            Json::Num(v) => assert!(v.is_finite()),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn write_round_trips_bit_exactly() {
        let tree = Json::Obj(vec![
            ("rule".into(), Json::Str("austerity".into())),
            ("eps".into(), Json::Num(0.05)),
            ("steps".into(), Json::Int(4000)),
            ("whole".into(), Json::Num(2.0)), // integral float stays a float
            ("bad".into(), Json::Null),
            (
                "draws".into(),
                Json::Arr(vec![
                    Json::Num(-1.2345678912345679e-7),
                    Json::Num(f64::MIN_POSITIVE),
                    Json::Num(1.0 / 3.0),
                    Json::Bool(false),
                ]),
            ),
            ("label".into(), Json::Str("quote \" slash \\ nl \n".into())),
        ]);
        let text = tree.write();
        assert_eq!(parse(&text), Ok(tree.clone()), "round trip of {text}");
        // and a second trip is a fixed point
        assert_eq!(parse(&p(&text).write()), Ok(tree));
    }

    #[test]
    fn write_renders_non_finite_as_null() {
        assert_eq!(Json::Num(f64::NAN).write(), "null");
        assert_eq!(Json::Arr(vec![Json::Num(f64::INFINITY)]).write(), "[null]");
    }
}

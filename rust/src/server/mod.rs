//! The `austerity serve` daemon: a long-lived job server over the
//! sampling engine (`austerity serve --addr 127.0.0.1:7878`).
//!
//! Clients POST JSON job specs — which built-in synthetic model
//! (logistic / linreg / conjugate-Gaussian, with size and seed),
//! which acceptance rule and budget, how many chains, checkpoint and
//! retry knobs — and poll for incremental progress and the final
//! `RunReport`. Many jobs run concurrently, all multiplexed over the
//! crate's shared global [`Executor`] pool, so a saturated server
//! degrades throughput but never correctness:
//!
//! * **Determinism** — same job spec + seed → bit-identical draws
//!   regardless of concurrent load, because chains own their RNG
//!   streams and the executor only decides *where* work runs, never
//!   *what* is computed (`tests/integration_serve.rs` pins this).
//! * **Typed backpressure** — at most `--max-jobs` jobs run at once,
//!   at most `--max-queue` wait; beyond that, admission returns 429.
//! * **Graceful shutdown** — SIGINT/SIGTERM (or `POST /shutdown`)
//!   stops admissions, waits up to the drain deadline for running
//!   jobs, then raises every job's cancel token; chains flush a final
//!   checkpoint at the next step boundary, so a later job with
//!   `"resume": true` finishes the interrupted run. A second signal
//!   aborts immediately.
//!
//! Module map:
//!
//! * [`json_in`] — strict zero-dep JSON reader (mirror of the crate's
//!   writer; rejects NaN/Inf, duplicate keys, trailing garbage)
//! * [`http`] — minimal HTTP/1.1 framing over `std::net`
//! * [`spec`] — typed job specs with admission-time validation
//! * [`registry`] — job table, bounded FIFO admission, lifecycle
//! * [`jobs`] — spec → `Session` launch → `RunReport` JSON
//! * [`handlers`] — endpoint routing (pure, unit-testable)
//!
//! Everything is hand-rolled on `std` — the daemon adds no
//! dependencies, like the rest of the crate.

pub mod handlers;
pub mod http;
pub mod jobs;
pub mod json_in;
pub mod registry;
pub mod spec;

use std::io::ErrorKind;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use crate::coordinator::executor::Executor;
use registry::{Registry, RegistryCfg};

/// Construction knobs for [`Server::bind`] (the `serve` CLI flags).
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// Listen address, e.g. `127.0.0.1:7878` (`:0` picks a free port).
    pub addr: SocketAddr,
    /// Concurrent jobs (= runner threads).
    pub max_jobs: usize,
    /// Admission queue capacity beyond the running jobs.
    pub max_queue: usize,
    /// How long shutdown waits for running jobs before cancelling them.
    pub drain: Duration,
    /// Worker threads to pre-warm in the shared executor pool
    /// (0 = leave the pool as-is; chains grow it on demand).
    pub threads: usize,
    /// Server-side default checkpoint root: jobs without explicit
    /// checkpoint config get `<root>/job-<id>` at `ckpt_every`.
    pub ckpt_root: Option<PathBuf>,
    pub ckpt_every: Option<usize>,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            addr: "127.0.0.1:7878".parse().expect("static addr parses"),
            max_jobs: 4,
            max_queue: 64,
            drain: Duration::from_secs(5),
            threads: 0,
            ckpt_root: None,
            ckpt_every: None,
        }
    }
}

/// The bound daemon: listener + registry + runner threads.
pub struct Server {
    listener: TcpListener,
    registry: Arc<Registry>,
    runners: Vec<JoinHandle<()>>,
    shutdown: Arc<AtomicBool>,
    drain: Duration,
}

impl Server {
    /// Bind the listener and spawn the runner threads. The server does
    /// not accept connections until [`Server::run`].
    pub fn bind(cfg: ServeConfig) -> std::io::Result<Server> {
        let listener = TcpListener::bind(cfg.addr)?;
        // nonblocking so the accept loop can poll the shutdown flags
        listener.set_nonblocking(true)?;
        if cfg.threads > 0 {
            // the accept loop itself is a thread; pre-warm the rest
            Executor::global().ensure_workers(cfg.threads.saturating_sub(1).max(1));
        }
        let registry = Arc::new(Registry::new(RegistryCfg {
            max_jobs: cfg.max_jobs,
            max_queue: cfg.max_queue,
            ckpt_root: cfg.ckpt_root.clone(),
            ckpt_every: cfg.ckpt_every,
        }));
        let runners = (0..cfg.max_jobs.max(1))
            .map(|i| {
                let reg = Arc::clone(&registry);
                std::thread::Builder::new()
                    .name(format!("austerity-runner-{i}"))
                    .spawn(move || runner_loop(&reg))
                    .expect("spawn runner thread")
            })
            .collect();
        Ok(Server {
            listener,
            registry,
            runners,
            shutdown: Arc::new(AtomicBool::new(false)),
            drain: cfg.drain,
        })
    }

    /// The actual bound address (port resolved when `:0` was asked).
    pub fn local_addr(&self) -> SocketAddr {
        self.listener.local_addr().expect("bound listener has an address")
    }

    /// Handle for programmatic shutdown (tests, embedding): store
    /// `true` and the accept loop exits into the drain sequence.
    pub fn shutdown_flag(&self) -> Arc<AtomicBool> {
        Arc::clone(&self.shutdown)
    }

    /// Registry handle (tests and embedding).
    pub fn registry(&self) -> Arc<Registry> {
        Arc::clone(&self.registry)
    }

    /// Serve until shutdown is requested (signal, `POST /shutdown`, or
    /// [`Server::shutdown_flag`]), then drain and exit.
    pub fn run(self) {
        let Server { listener, registry, runners, shutdown, drain } = self;
        let mut connections: Vec<JoinHandle<()>> = Vec::new();
        loop {
            if shutdown.load(Ordering::Relaxed) || signal::interrupted() {
                break;
            }
            match listener.accept() {
                Ok((stream, _peer)) => {
                    let reg = Arc::clone(&registry);
                    let stop = Arc::clone(&shutdown);
                    let handle = std::thread::Builder::new()
                        .name("austerity-conn".into())
                        .spawn(move || handle_connection(stream, &reg, &stop))
                        .expect("spawn connection thread");
                    connections.push(handle);
                    // reap finished connection threads so the vec stays small
                    connections.retain(|h| !h.is_finished());
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => {
                    std::thread::sleep(Duration::from_millis(10));
                }
                Err(e) => {
                    eprintln!("serve: accept failed: {e}");
                    std::thread::sleep(Duration::from_millis(50));
                }
            }
        }

        // -- graceful shutdown -----------------------------------------
        // 1. stop admissions (new POSTs get 503 while we drain)
        registry.begin_drain();
        eprintln!("serve: draining (up to {:.1}s)...", drain.as_secs_f64());
        // 2. give running jobs the drain window to finish on their own
        let idle = registry.await_idle(drain);
        if !idle {
            // 3. past the deadline: cancel cooperatively; chains flush a
            //    final checkpoint at the next step boundary, so these
            //    jobs are resumable
            eprintln!("serve: drain deadline passed; cancelling running jobs");
            registry.cancel_running();
            if !registry.await_idle(Duration::from_secs(10)) {
                eprintln!("serve: jobs still running after cancel; abandoning");
            }
        }
        // 4. wake blocked runners and join them
        registry.close();
        for h in runners {
            let _ = h.join();
        }
        for h in connections {
            let _ = h.join();
        }
        eprintln!("serve: shut down cleanly");
    }
}

/// One runner thread: claim jobs until the registry closes. A panic
/// inside a launch is caught and recorded as a job failure — one bad
/// job never takes a runner (or the daemon) down.
fn runner_loop(reg: &Registry) {
    while let Some((id, spec, live)) = reg.next_job() {
        let outcome = catch_unwind(AssertUnwindSafe(|| jobs::run_job(&spec, Some(&live))));
        let outcome = match outcome {
            Ok(res) => res,
            Err(payload) => Err(format!("job panicked: {}", panic_reason(&payload))),
        };
        reg.finish(id, outcome);
    }
}

/// Render a panic payload (local copy of the engine's private helper).
fn panic_reason(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "opaque panic payload".to_string()
    }
}

/// Serve one connection: read a request, route it, write the response.
/// Framing errors get their 4xx; socket errors just drop the
/// connection. Never panics the daemon.
fn handle_connection(mut stream: TcpStream, reg: &Registry, shutdown: &AtomicBool) {
    // a stuck peer must not pin a thread forever
    let _ = stream.set_read_timeout(Some(Duration::from_secs(10)));
    let _ = stream.set_write_timeout(Some(Duration::from_secs(10)));
    let _ = stream.set_nonblocking(false);
    match http::read_request(&mut stream) {
        Ok(req) => {
            let (resp, stop) = handlers::route(&req, reg);
            if stop {
                shutdown.store(true, Ordering::Relaxed);
            }
            if let Err(e) = resp.write_to(&mut stream) {
                eprintln!("serve: response write failed: {e}");
            }
        }
        Err(e) => {
            if let Some(resp) = e.response() {
                let _ = resp.write_to(&mut stream);
            }
        }
    }
}

/// Process-signal plumbing for graceful shutdown, built on the raw
/// libc `signal(2)` entry point so the daemon stays zero-dependency.
/// The handler is async-signal-safe: it only increments an atomic.
pub mod signal {
    use std::sync::atomic::{AtomicUsize, Ordering};

    static SIGNAL_COUNT: AtomicUsize = AtomicUsize::new(0);

    /// Has a termination signal arrived since the handlers were
    /// installed?
    pub fn interrupted() -> bool {
        SIGNAL_COUNT.load(Ordering::Relaxed) > 0
    }

    #[cfg(unix)]
    mod imp {
        use super::SIGNAL_COUNT;
        use std::sync::atomic::Ordering;

        const SIGINT: i32 = 2;
        const SIGTERM: i32 = 15;

        extern "C" {
            fn signal(signum: i32, handler: extern "C" fn(i32)) -> isize;
        }

        extern "C" fn on_signal(_signum: i32) {
            // first signal: request graceful drain; second: the user
            // really means it — abort (abort() is async-signal-safe)
            if SIGNAL_COUNT.fetch_add(1, Ordering::Relaxed) >= 1 {
                std::process::abort();
            }
        }

        pub fn install() {
            unsafe {
                signal(SIGINT, on_signal);
                signal(SIGTERM, on_signal);
            }
        }
    }

    #[cfg(not(unix))]
    mod imp {
        pub fn install() {}
    }

    /// Install SIGINT/SIGTERM handlers (unix; no-op elsewhere). First
    /// signal drains gracefully, second aborts.
    pub fn install_signal_handlers() {
        imp::install();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::{Read, Write};

    fn start(cfg: ServeConfig) -> (SocketAddr, Arc<AtomicBool>, std::thread::JoinHandle<()>) {
        let srv = Server::bind(cfg).unwrap();
        let addr = srv.local_addr();
        let stop = srv.shutdown_flag();
        let t = std::thread::spawn(move || srv.run());
        (addr, stop, t)
    }

    fn http(addr: SocketAddr, method: &str, path: &str, body: &str) -> (u16, String) {
        let mut s = TcpStream::connect(addr).unwrap();
        let req = format!(
            "{method} {path} HTTP/1.1\r\nHost: t\r\nContent-Length: {}\r\n\r\n{body}",
            body.len()
        );
        s.write_all(req.as_bytes()).unwrap();
        let mut raw = String::new();
        s.read_to_string(&mut raw).unwrap();
        let status: u16 = raw
            .split_whitespace()
            .nth(1)
            .and_then(|s| s.parse().ok())
            .unwrap_or_else(|| panic!("no status line in {raw:?}"));
        let body = raw.split_once("\r\n\r\n").map(|(_, b)| b.to_string()).unwrap_or_default();
        (status, body)
    }

    fn local_cfg() -> ServeConfig {
        ServeConfig {
            addr: "127.0.0.1:0".parse().unwrap(),
            max_jobs: 2,
            max_queue: 4,
            drain: Duration::from_secs(2),
            ..ServeConfig::default()
        }
    }

    #[test]
    fn healthz_round_trips_over_a_real_socket() {
        let (addr, stop, t) = start(local_cfg());
        let (status, body) = http(addr, "GET", "/healthz", "");
        assert_eq!(status, 200);
        assert!(body.contains("\"status\":\"ok\""), "{body}");
        stop.store(true, Ordering::Relaxed);
        t.join().unwrap();
    }

    #[test]
    fn submit_runs_to_done_and_serves_the_report() {
        let (addr, stop, t) = start(local_cfg());
        let spec = r#"{"model":{"kind":"conjugate","n":64,"data_seed":2},
                       "rule":{"kind":"exact"},"chains":2,"seed":9,
                       "budget":{"kind":"steps","steps":60}}"#;
        let (status, body) = http(addr, "POST", "/jobs", spec);
        assert_eq!(status, 202, "{body}");
        assert!(body.contains("\"id\":0"), "{body}");
        // poll until terminal
        let mut last = String::new();
        for _ in 0..400 {
            let (s, b) = http(addr, "GET", "/jobs/0", "");
            assert_eq!(s, 200);
            last = b;
            if last.contains("\"state\":\"done\"") {
                break;
            }
            std::thread::sleep(Duration::from_millis(10));
        }
        assert!(last.contains("\"state\":\"done\""), "{last}");
        let (s, report) = http(addr, "GET", "/jobs/0/result", "");
        assert_eq!(s, 200);
        assert!(report.contains("\"rule\":\"exact\""), "{report}");
        json_in::parse(&report).expect("report must satisfy the strict reader");
        stop.store(true, Ordering::Relaxed);
        t.join().unwrap();
    }

    #[test]
    fn malformed_frames_and_specs_never_kill_the_daemon() {
        let (addr, stop, t) = start(local_cfg());
        // raw garbage instead of HTTP
        {
            let mut s = TcpStream::connect(addr).unwrap();
            s.write_all(b"\x00\x01\x02 garbage\r\n\r\n").unwrap();
            let mut out = Vec::new();
            let _ = s.read_to_end(&mut out);
        }
        // bad spec
        let (s, _) = http(addr, "POST", "/jobs", "{\"model\":");
        assert_eq!(s, 400);
        // the daemon is still alive
        let (s, _) = http(addr, "GET", "/healthz", "");
        assert_eq!(s, 200);
        stop.store(true, Ordering::Relaxed);
        t.join().unwrap();
    }

    #[test]
    fn post_shutdown_drains_the_server() {
        let (addr, _stop, t) = start(local_cfg());
        let (s, body) = http(addr, "POST", "/shutdown", "");
        assert_eq!(s, 200);
        assert!(body.contains("shutting_down"), "{body}");
        t.join().unwrap(); // run() returns on its own
    }
}

//! Endpoint routing: one parsed [`Request`] in, one [`Response`] out.
//!
//! The API surface (all bodies JSON):
//!
//! | method & path          | behaviour                                             |
//! |------------------------|-------------------------------------------------------|
//! | `GET /healthz`         | liveness + queue/running counts                       |
//! | `POST /jobs`           | admit a job spec → `202 {"id": …}`; 400/429/503       |
//! | `GET /jobs/:id`        | incremental progress + running R-hat/ESS              |
//! | `GET /jobs/:id/result` | full `RunReport` JSON; 409 while unfinished           |
//! | `DELETE /jobs/:id`     | cooperative cancel                                    |
//! | `POST /shutdown`       | graceful shutdown (same path as SIGINT)               |
//!
//! Routing is pure — no I/O — so every branch is unit-testable
//! without a socket.

use crate::server::http::{Request, Response};
use crate::server::registry::{AdmitError, JobOutcome, JobState, Registry};

/// Dispatch one request. The second return is `true` when the request
/// asked for server shutdown (`POST /shutdown`).
pub fn route(req: &Request, reg: &Registry) -> (Response, bool) {
    let resp = match (req.method.as_str(), req.path.as_str()) {
        ("GET", "/healthz") => Response::json(200, reg.healthz_json()),
        ("POST", "/jobs") => post_job(req, reg),
        ("POST", "/shutdown") => {
            return (Response::json(200, "{\"shutting_down\":true}"), true)
        }
        (method, path) => {
            if let Some(rest) = path.strip_prefix("/jobs/") {
                job_route(method, rest, reg)
            } else if matches!(path, "/healthz" | "/jobs" | "/shutdown") {
                Response::error(405, "method not allowed on this path")
            } else {
                Response::error(404, "no such endpoint")
            }
        }
    };
    (resp, false)
}

fn post_job(req: &Request, reg: &Registry) -> Response {
    match reg.submit(&req.body) {
        Ok(id) => Response::json(202, format!("{{\"id\":{id},\"state\":\"queued\"}}")),
        Err(AdmitError::Spec(why)) => Response::error(400, &why),
        Err(AdmitError::QueueFull { cap }) => {
            Response::error(429, &format!("admission queue is full (capacity {cap}); retry later"))
        }
        Err(AdmitError::Draining) => {
            Response::error(503, "server is draining for shutdown; not admitting jobs")
        }
    }
}

fn job_route(method: &str, rest: &str, reg: &Registry) -> Response {
    let (id_text, sub) = match rest.split_once('/') {
        Some((id, sub)) => (id, Some(sub)),
        None => (rest, None),
    };
    let Ok(id) = id_text.parse::<usize>() else {
        return Response::error(404, "job IDs are non-negative integers");
    };
    match (method, sub) {
        ("GET", None) => match reg.status_json(id) {
            Some(doc) => Response::json(200, doc),
            None => Response::error(404, "no such job"),
        },
        ("GET", Some("result")) => match reg.outcome(id) {
            None => Response::error(404, "no such job"),
            Some(JobOutcome::Pending) => {
                Response::error(409, "job has not finished; poll GET /jobs/:id")
            }
            Some(JobOutcome::Report(json)) => Response::json(200, json),
            Some(JobOutcome::CancelledEarly) => {
                Response::error(409, "job was cancelled before producing a report")
            }
            Some(JobOutcome::Failed(why)) => Response::error(500, &why),
        },
        ("DELETE", None) => match reg.cancel(id) {
            None => Response::error(404, "no such job"),
            Some(JobState::Running) => {
                Response::json(200, format!("{{\"id\":{id},\"state\":\"cancelling\"}}"))
            }
            Some(state) => Response::json(
                200,
                format!("{{\"id\":{id},\"state\":\"{}\"}}", state.as_str()),
            ),
        },
        ("GET" | "DELETE", Some(_)) => Response::error(404, "no such endpoint"),
        _ => Response::error(405, "method not allowed on this path"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::server::registry::RegistryCfg;

    fn reg() -> Registry {
        Registry::new(RegistryCfg { max_jobs: 1, max_queue: 2, ckpt_root: None, ckpt_every: None })
    }

    fn req(method: &str, path: &str, body: &str) -> Request {
        Request { method: method.into(), path: path.into(), body: body.into() }
    }

    const SPEC: &str =
        r#"{"model":{"kind":"conjugate","n":64},"budget":{"kind":"steps","steps":10}}"#;

    #[test]
    fn submit_poll_cancel_flow() {
        let r = reg();
        let (resp, _) = route(&req("POST", "/jobs", SPEC), &r);
        assert_eq!(resp.status, 202);
        assert!(resp.body.contains("\"id\":0"), "{}", resp.body);

        let (resp, _) = route(&req("GET", "/jobs/0", ""), &r);
        assert_eq!(resp.status, 200);
        assert!(resp.body.contains("\"state\":\"queued\""), "{}", resp.body);

        let (resp, _) = route(&req("GET", "/jobs/0/result", ""), &r);
        assert_eq!(resp.status, 409);

        let (resp, _) = route(&req("DELETE", "/jobs/0", ""), &r);
        assert_eq!(resp.status, 200);
        assert!(resp.body.contains("\"state\":\"cancelled\""), "{}", resp.body);
    }

    #[test]
    fn malformed_spec_is_a_400_with_the_parser_message() {
        let r = reg();
        let (resp, _) = route(&req("POST", "/jobs", "{\"seed\":NaN}"), &r);
        assert_eq!(resp.status, 400);
        assert!(resp.body.contains("non-finite"), "{}", resp.body);
        let (resp, _) = route(&req("POST", "/jobs", "{}"), &r);
        assert_eq!(resp.status, 400);
        assert!(resp.body.contains("model"), "{}", resp.body);
    }

    #[test]
    fn backpressure_maps_to_429() {
        let r = reg();
        route(&req("POST", "/jobs", SPEC), &r);
        route(&req("POST", "/jobs", SPEC), &r);
        let (resp, _) = route(&req("POST", "/jobs", SPEC), &r);
        assert_eq!(resp.status, 429);
        assert!(resp.body.contains("capacity 2"), "{}", resp.body);
    }

    #[test]
    fn drain_maps_to_503() {
        let r = reg();
        r.begin_drain();
        let (resp, _) = route(&req("POST", "/jobs", SPEC), &r);
        assert_eq!(resp.status, 503);
    }

    #[test]
    fn unknown_paths_ids_and_methods() {
        let r = reg();
        assert_eq!(route(&req("GET", "/nope", ""), &r).0.status, 404);
        assert_eq!(route(&req("GET", "/jobs/99", ""), &r).0.status, 404);
        assert_eq!(route(&req("GET", "/jobs/zebra", ""), &r).0.status, 404);
        assert_eq!(route(&req("GET", "/jobs/0/zebra", ""), &r).0.status, 404);
        assert_eq!(route(&req("PUT", "/jobs/0", ""), &r).0.status, 405);
        assert_eq!(route(&req("DELETE", "/healthz", ""), &r).0.status, 405);
        assert_eq!(route(&req("GET", "/healthz", ""), &r).0.status, 200);
    }

    #[test]
    fn shutdown_flag_is_signalled() {
        let r = reg();
        let (resp, stop) = route(&req("POST", "/shutdown", ""), &r);
        assert_eq!(resp.status, 200);
        assert!(stop);
        assert!(!route(&req("GET", "/healthz", ""), &r).1);
    }
}

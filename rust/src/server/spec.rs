//! Typed job specifications: the JSON body of `POST /jobs` decoded
//! into a validated [`JobSpec`].
//!
//! Validation philosophy: *reject loudly at admission time*. Every
//! field is checked before a job enters the queue — unknown keys,
//! wrong types, out-of-range knobs and incoherent flag pairings all
//! come back as a typed [`SpecError`] rendered into the 400 body, so
//! a misconfigured client never discovers its mistake as a worker
//! panic minutes later.
//!
//! A spec fully determines a run: `(spec, seed)` → bit-identical
//! draws no matter how loaded the server is, because the models are
//! synthesized deterministically from `(n, d, data_seed)` and the
//! chains draw from the same per-chain RNG streams `Session` always
//! uses.

use std::path::PathBuf;
use std::time::Duration;

use crate::coordinator::chain::Budget;
use crate::coordinator::mh::MhMode;
use crate::coordinator::supervise::RetryPolicy;
use crate::server::json_in::{self, Json, JsonError};
use crate::stats::logistic_corr::{SIGMA_MAX, SIGMA_MIN};

/// Hard cap on `chains` per job: enough for any real launch, small
/// enough that one hostile spec cannot allocate unbounded lanes.
pub const MAX_CHAINS: usize = 256;
/// Hard cap on synthetic dataset size per job.
pub const MAX_DATA: usize = 5_000_000;

/// Which built-in synthetic model the job samples.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ModelSpec {
    /// d-dimensional logistic regression on a two-class Gaussian
    /// mixture (`exp::population::two_class_gaussian`).
    Logistic { n: usize, d: usize, data_seed: u64 },
    /// Scalar linear-regression toy with the heavy Laplace prior.
    Linreg { n: usize, data_seed: u64 },
    /// Conjugate Gaussian mean model (closed-form posterior — the
    /// testkit's ground-truth workhorse).
    Conjugate { n: usize, data_seed: u64 },
}

impl ModelSpec {
    /// Dataset size — the `N` the acceptance rules batch over.
    pub fn n(&self) -> usize {
        match self {
            ModelSpec::Logistic { n, .. }
            | ModelSpec::Linreg { n, .. }
            | ModelSpec::Conjugate { n, .. } => *n,
        }
    }

    pub fn kind(&self) -> &'static str {
        match self {
            ModelSpec::Logistic { .. } => "logistic",
            ModelSpec::Linreg { .. } => "linreg",
            ModelSpec::Conjugate { .. } => "conjugate",
        }
    }
}

/// Which acceptance rule drives the MH decisions.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum RuleSpec {
    Exact,
    /// The paper's sequential test at error budget `eps`.
    Austerity { eps: f64, batch: Option<usize> },
    /// Noise-corrected minibatch Barker test at noise target `sigma`.
    Barker { sigma: f64, batch: Option<usize> },
    /// Concentration-bound confidence sampler at level `delta`.
    Confidence { delta: f64, batch: Option<usize> },
}

impl RuleSpec {
    pub fn label(&self) -> &'static str {
        match self {
            RuleSpec::Exact => "exact",
            RuleSpec::Austerity { .. } => "austerity",
            RuleSpec::Barker { .. } => "barker",
            RuleSpec::Confidence { .. } => "confidence",
        }
    }

    /// Resolve to the engine-facing [`MhMode`] for a dataset of `n`
    /// points, validating every knob against the same bounds the CLI
    /// enforces.
    pub fn mh_mode(&self, n: usize) -> Result<MhMode, SpecError> {
        let default_batch = 500.min(n / 4).max(16).min(n.max(1));
        let resolve = |batch: Option<usize>| -> Result<usize, SpecError> {
            match batch {
                None => Ok(default_batch),
                Some(b) if b >= 1 && b <= n => Ok(b),
                Some(b) => Err(SpecError::BadValue {
                    field: "batch",
                    why: format!("must be in [1, n={n}]: got {b}"),
                }),
            }
        };
        match *self {
            RuleSpec::Exact => Ok(MhMode::Exact),
            RuleSpec::Austerity { eps, batch } => {
                if !(eps > 0.0 && eps < 1.0) {
                    return Err(SpecError::BadValue {
                        field: "eps",
                        why: format!("must be in (0, 1): got {eps}"),
                    });
                }
                Ok(MhMode::approx(eps, resolve(batch)?))
            }
            RuleSpec::Barker { sigma, batch } => {
                if !(SIGMA_MIN..=SIGMA_MAX).contains(&sigma) {
                    return Err(SpecError::BadValue {
                        field: "sigma",
                        why: format!("must be in [{SIGMA_MIN}, {SIGMA_MAX}]: got {sigma}"),
                    });
                }
                Ok(MhMode::barker(sigma, resolve(batch)?))
            }
            RuleSpec::Confidence { delta, batch } => {
                if !(delta > 0.0 && delta < 1.0) {
                    return Err(SpecError::BadValue {
                        field: "delta",
                        why: format!("must be in (0, 1): got {delta}"),
                    });
                }
                Ok(MhMode::confidence(delta, resolve(batch)?))
            }
        }
    }
}

/// A fully validated job: everything `server::jobs::run_job` needs.
#[derive(Clone, Debug, PartialEq)]
pub struct JobSpec {
    pub model: ModelSpec,
    /// Proposal step size (model-specific default when absent).
    pub sigma_prop: Option<f64>,
    pub rule: RuleSpec,
    pub chains: usize,
    pub seed: u64,
    pub budget: Budget,
    pub burn_in: usize,
    pub thin: usize,
    /// Checkpoint cadence in steps; `checkpoint_dir` resolved at
    /// admission (explicit, or `<ckpt_root>/job-<id>` server default).
    pub checkpoint_every: Option<usize>,
    pub checkpoint_dir: Option<PathBuf>,
    /// Resume from `checkpoint_dir` instead of starting fresh.
    pub resume: bool,
    pub retries: usize,
    pub retry_backoff_ms: u64,
}

impl JobSpec {
    pub fn retry_policy(&self) -> RetryPolicy {
        RetryPolicy::new(self.retries, Duration::from_millis(self.retry_backoff_ms))
    }
}

/// Why a job spec was refused. Rendered into the 400 response body.
#[derive(Clone, Debug, PartialEq)]
pub enum SpecError {
    /// The body was not valid JSON at all.
    Json(JsonError),
    /// Top level was not a JSON object.
    NotAnObject,
    /// A required field is absent.
    Missing { field: &'static str },
    /// Field present with the wrong JSON type.
    BadType { field: &'static str, want: &'static str },
    /// Field parsed but fails validation.
    BadValue { field: &'static str, why: String },
    /// Key this API does not know — likely a typo'd knob; rejecting
    /// beats silently ignoring it.
    UnknownField { field: String },
    /// `model.kind` / `rule.kind` outside the built-in set.
    UnknownKind { field: &'static str, got: String },
}

impl std::fmt::Display for SpecError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SpecError::Json(e) => write!(f, "invalid JSON: {e}"),
            SpecError::NotAnObject => write!(f, "job spec must be a JSON object"),
            SpecError::Missing { field } => write!(f, "missing required field {field:?}"),
            SpecError::BadType { field, want } => {
                write!(f, "field {field:?} must be {want}")
            }
            SpecError::BadValue { field, why } => write!(f, "field {field:?} {why}"),
            SpecError::UnknownField { field } => {
                write!(f, "unknown field {field:?} (strict parsing: typos are rejected)")
            }
            SpecError::UnknownKind { field, got } => {
                write!(f, "unknown {field} kind {got:?}")
            }
        }
    }
}

impl std::error::Error for SpecError {}

impl From<JsonError> for SpecError {
    fn from(e: JsonError) -> Self {
        SpecError::Json(e)
    }
}

/// Parse and validate a job spec from a raw request body.
pub fn parse_spec(body: &str) -> Result<JobSpec, SpecError> {
    let tree = json_in::parse(body)?;
    spec_from_json(&tree)
}

// -- field helpers ----------------------------------------------------

fn want_obj<'a>(v: &'a Json) -> Result<&'a [(String, Json)], SpecError> {
    v.as_obj().ok_or(SpecError::NotAnObject)
}

fn opt_usize(v: &Json, field: &'static str) -> Result<usize, SpecError> {
    v.as_usize().ok_or(SpecError::BadType { field, want: "a non-negative integer" })
}

fn opt_u64(v: &Json, field: &'static str) -> Result<u64, SpecError> {
    v.as_u64().ok_or(SpecError::BadType { field, want: "a non-negative integer" })
}

fn opt_f64(v: &Json, field: &'static str) -> Result<f64, SpecError> {
    v.as_f64().ok_or(SpecError::BadType { field, want: "a number" })
}

fn opt_str<'a>(v: &'a Json, field: &'static str) -> Result<&'a str, SpecError> {
    v.as_str().ok_or(SpecError::BadType { field, want: "a string" })
}

fn opt_bool(v: &Json, field: &'static str) -> Result<bool, SpecError> {
    v.as_bool().ok_or(SpecError::BadType { field, want: "a boolean" })
}

fn bounded(field: &'static str, v: usize, lo: usize, hi: usize) -> Result<usize, SpecError> {
    if (lo..=hi).contains(&v) {
        Ok(v)
    } else {
        Err(SpecError::BadValue { field, why: format!("must be in [{lo}, {hi}]: got {v}") })
    }
}

fn model_from_json(v: &Json) -> Result<ModelSpec, SpecError> {
    let members = v.as_obj().ok_or(SpecError::BadType { field: "model", want: "an object" })?;
    let mut kind: Option<&str> = None;
    let mut n: Option<usize> = None;
    let mut d: Option<usize> = None;
    let mut data_seed: u64 = 0;
    for (k, val) in members {
        match k.as_str() {
            "kind" => kind = Some(opt_str(val, "model.kind")?),
            "n" => n = Some(opt_usize(val, "model.n")?),
            "d" => d = Some(opt_usize(val, "model.d")?),
            "data_seed" => data_seed = opt_u64(val, "model.data_seed")?,
            other => {
                return Err(SpecError::UnknownField { field: format!("model.{other}") })
            }
        }
    }
    let kind = kind.ok_or(SpecError::Missing { field: "model.kind" })?;
    match kind {
        "logistic" => {
            let n = bounded("model.n", n.unwrap_or(2_000), 16, MAX_DATA)?;
            let d = bounded("model.d", d.unwrap_or(20), 1, 512)?;
            Ok(ModelSpec::Logistic { n, d, data_seed })
        }
        "linreg" => {
            if d.is_some() {
                return Err(SpecError::BadValue {
                    field: "model.d",
                    why: "does not apply to the scalar linreg model".into(),
                });
            }
            let n = bounded("model.n", n.unwrap_or(2_000), 16, MAX_DATA)?;
            Ok(ModelSpec::Linreg { n, data_seed })
        }
        "conjugate" => {
            if d.is_some() {
                return Err(SpecError::BadValue {
                    field: "model.d",
                    why: "does not apply to the scalar conjugate model".into(),
                });
            }
            let n = bounded("model.n", n.unwrap_or(1_000), 16, MAX_DATA)?;
            Ok(ModelSpec::Conjugate { n, data_seed })
        }
        other => Err(SpecError::UnknownKind { field: "model", got: other.to_string() }),
    }
}

fn rule_from_json(v: &Json) -> Result<RuleSpec, SpecError> {
    let members = v.as_obj().ok_or(SpecError::BadType { field: "rule", want: "an object" })?;
    let mut kind: Option<&str> = None;
    let mut eps: Option<f64> = None;
    let mut sigma: Option<f64> = None;
    let mut delta: Option<f64> = None;
    let mut batch: Option<usize> = None;
    for (k, val) in members {
        match k.as_str() {
            "kind" => kind = Some(opt_str(val, "rule.kind")?),
            "eps" => eps = Some(opt_f64(val, "rule.eps")?),
            "sigma" => sigma = Some(opt_f64(val, "rule.sigma")?),
            "delta" => delta = Some(opt_f64(val, "rule.delta")?),
            "batch" => batch = Some(opt_usize(val, "rule.batch")?),
            other => return Err(SpecError::UnknownField { field: format!("rule.{other}") }),
        }
    }
    let kind = kind.ok_or(SpecError::Missing { field: "rule.kind" })?;
    let reject_knob = |name: &'static str, present: bool| -> Result<(), SpecError> {
        if present {
            Err(SpecError::BadValue {
                field: name,
                why: format!("does not apply to rule kind {kind:?}"),
            })
        } else {
            Ok(())
        }
    };
    match kind {
        "exact" => {
            reject_knob("rule.eps", eps.is_some())?;
            reject_knob("rule.sigma", sigma.is_some())?;
            reject_knob("rule.delta", delta.is_some())?;
            reject_knob("rule.batch", batch.is_some())?;
            Ok(RuleSpec::Exact)
        }
        "austerity" => {
            reject_knob("rule.sigma", sigma.is_some())?;
            reject_knob("rule.delta", delta.is_some())?;
            Ok(RuleSpec::Austerity { eps: eps.unwrap_or(0.05), batch })
        }
        "barker" => {
            reject_knob("rule.eps", eps.is_some())?;
            reject_knob("rule.delta", delta.is_some())?;
            Ok(RuleSpec::Barker { sigma: sigma.unwrap_or(1.0), batch })
        }
        "confidence" => {
            reject_knob("rule.eps", eps.is_some())?;
            reject_knob("rule.sigma", sigma.is_some())?;
            Ok(RuleSpec::Confidence { delta: delta.unwrap_or(0.05), batch })
        }
        other => Err(SpecError::UnknownKind { field: "rule", got: other.to_string() }),
    }
}

fn budget_from_json(v: &Json) -> Result<Budget, SpecError> {
    let members =
        v.as_obj().ok_or(SpecError::BadType { field: "budget", want: "an object" })?;
    let mut kind: Option<&str> = None;
    let mut steps: Option<usize> = None;
    let mut data: Option<u64> = None;
    for (k, val) in members {
        match k.as_str() {
            "kind" => kind = Some(opt_str(val, "budget.kind")?),
            "steps" => steps = Some(opt_usize(val, "budget.steps")?),
            "data" => data = Some(opt_u64(val, "budget.data")?),
            other => {
                return Err(SpecError::UnknownField { field: format!("budget.{other}") })
            }
        }
    }
    match kind.ok_or(SpecError::Missing { field: "budget.kind" })? {
        "steps" => {
            let s = steps.ok_or(SpecError::Missing { field: "budget.steps" })?;
            if s == 0 {
                return Err(SpecError::BadValue {
                    field: "budget.steps",
                    why: "must be >= 1".into(),
                });
            }
            Ok(Budget::Steps(s))
        }
        "data" => {
            let d = data.ok_or(SpecError::Missing { field: "budget.data" })?;
            if d == 0 {
                return Err(SpecError::BadValue {
                    field: "budget.data",
                    why: "must be >= 1".into(),
                });
            }
            Ok(Budget::Data(d))
        }
        // a wall-clock budget is timing-dependent and would break the
        // bit-identity contract the server advertises — refuse it
        "wall" => Err(SpecError::BadValue {
            field: "budget.kind",
            why: "wall budgets are not reproducible under server load; use steps or data"
                .into(),
        }),
        other => Err(SpecError::UnknownKind { field: "budget", got: other.to_string() }),
    }
}

fn spec_from_json(tree: &Json) -> Result<JobSpec, SpecError> {
    let members = want_obj(tree)?;
    let mut model: Option<ModelSpec> = None;
    let mut sigma_prop: Option<f64> = None;
    let mut rule: Option<RuleSpec> = None;
    let mut chains: usize = 2;
    let mut seed: u64 = 0;
    let mut budget: Option<Budget> = None;
    let mut burn_in: usize = 0;
    let mut thin: usize = 1;
    let mut checkpoint_every: Option<usize> = None;
    let mut checkpoint_dir: Option<PathBuf> = None;
    let mut resume = false;
    let mut retries: usize = 0;
    let mut retry_backoff_ms: u64 = 0;

    for (k, v) in members {
        match k.as_str() {
            "model" => model = Some(model_from_json(v)?),
            "proposal_sigma" => {
                let s = opt_f64(v, "proposal_sigma")?;
                if !(s > 0.0) {
                    return Err(SpecError::BadValue {
                        field: "proposal_sigma",
                        why: format!("must be > 0: got {s}"),
                    });
                }
                sigma_prop = Some(s);
            }
            "rule" => rule = Some(rule_from_json(v)?),
            "chains" => chains = bounded("chains", opt_usize(v, "chains")?, 1, MAX_CHAINS)?,
            "seed" => seed = opt_u64(v, "seed")?,
            "budget" => budget = Some(budget_from_json(v)?),
            "burn_in" => burn_in = opt_usize(v, "burn_in")?,
            "thin" => {
                thin = opt_usize(v, "thin")?;
                if thin == 0 {
                    return Err(SpecError::BadValue {
                        field: "thin",
                        why: "must be >= 1".into(),
                    });
                }
            }
            "checkpoint_every" => {
                let e = opt_usize(v, "checkpoint_every")?;
                if e == 0 {
                    return Err(SpecError::BadValue {
                        field: "checkpoint_every",
                        why: "must be >= 1".into(),
                    });
                }
                checkpoint_every = Some(e);
            }
            "checkpoint_dir" => {
                checkpoint_dir = Some(PathBuf::from(opt_str(v, "checkpoint_dir")?))
            }
            "resume" => resume = opt_bool(v, "resume")?,
            "retries" => retries = bounded("retries", opt_usize(v, "retries")?, 0, 16)?,
            "retry_backoff_ms" => retry_backoff_ms = opt_u64(v, "retry_backoff_ms")?,
            other => return Err(SpecError::UnknownField { field: other.to_string() }),
        }
    }

    let model = model.ok_or(SpecError::Missing { field: "model" })?;
    let rule = rule.unwrap_or(RuleSpec::Austerity { eps: 0.05, batch: None });
    let budget = budget.ok_or(SpecError::Missing { field: "budget" })?;

    // the same pairing rule the CLI enforces: a cadence without a
    // directory (or vice versa at resume time) is a config bug
    if checkpoint_dir.is_some() && checkpoint_every.is_none() {
        return Err(SpecError::BadValue {
            field: "checkpoint_dir",
            why: "requires checkpoint_every (pair the knobs)".into(),
        });
    }
    if resume && checkpoint_every.is_none() {
        return Err(SpecError::BadValue {
            field: "resume",
            why: "requires checkpoint_every (resume continues a checkpointed run)".into(),
        });
    }
    // validate the rule knobs against the model's N now, not at run time
    rule.mh_mode(model.n())?;

    Ok(JobSpec {
        model,
        sigma_prop,
        rule,
        chains,
        seed,
        budget,
        burn_in,
        thin,
        checkpoint_every,
        checkpoint_dir,
        resume,
        retries,
        retry_backoff_ms,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn minimal_spec_fills_defaults() {
        let spec = parse_spec(
            r#"{"model":{"kind":"conjugate","n":500},"budget":{"kind":"steps","steps":100}}"#,
        )
        .unwrap();
        assert_eq!(spec.model, ModelSpec::Conjugate { n: 500, data_seed: 0 });
        assert_eq!(spec.rule, RuleSpec::Austerity { eps: 0.05, batch: None });
        assert_eq!(spec.chains, 2);
        assert_eq!(spec.seed, 0);
        assert_eq!(spec.budget, Budget::Steps(100));
        assert_eq!((spec.burn_in, spec.thin), (0, 1));
        assert!(!spec.resume && spec.checkpoint_every.is_none());
    }

    #[test]
    fn full_spec_round_trips_every_knob() {
        let spec = parse_spec(
            r#"{
              "model": {"kind": "logistic", "n": 800, "d": 5, "data_seed": 9},
              "proposal_sigma": 0.02,
              "rule": {"kind": "barker", "sigma": 0.9, "batch": 64},
              "chains": 4, "seed": 123,
              "budget": {"kind": "data", "data": 50000},
              "burn_in": 10, "thin": 2,
              "checkpoint_every": 50, "checkpoint_dir": "/tmp/ck",
              "retries": 2, "retry_backoff_ms": 5
            }"#,
        )
        .unwrap();
        assert_eq!(spec.model, ModelSpec::Logistic { n: 800, d: 5, data_seed: 9 });
        assert_eq!(spec.sigma_prop, Some(0.02));
        assert_eq!(spec.rule, RuleSpec::Barker { sigma: 0.9, batch: Some(64) });
        assert_eq!(spec.budget, Budget::Data(50_000));
        assert_eq!(spec.checkpoint_every, Some(50));
        assert_eq!(spec.checkpoint_dir.as_deref(), Some(std::path::Path::new("/tmp/ck")));
        assert_eq!(spec.retries, 2);
    }

    #[test]
    fn unknown_fields_are_rejected_at_every_level() {
        for body in [
            r#"{"model":{"kind":"conjugate"},"budget":{"kind":"steps","steps":1},"zebra":1}"#,
            r#"{"model":{"kind":"conjugate","zebra":1},"budget":{"kind":"steps","steps":1}}"#,
            r#"{"model":{"kind":"conjugate"},"rule":{"kind":"exact","zebra":1},"budget":{"kind":"steps","steps":1}}"#,
            r#"{"model":{"kind":"conjugate"},"budget":{"kind":"steps","steps":1,"zebra":1}}"#,
        ] {
            assert!(
                matches!(parse_spec(body), Err(SpecError::UnknownField { .. })),
                "{body}"
            );
        }
    }

    #[test]
    fn incoherent_specs_get_typed_errors() {
        // wall budget refused by name
        let e = parse_spec(
            r#"{"model":{"kind":"conjugate"},"budget":{"kind":"wall","steps":1}}"#,
        )
        .unwrap_err();
        assert!(matches!(e, SpecError::BadValue { field: "budget.kind", .. }), "{e}");
        // resume without checkpointing
        let e = parse_spec(
            r#"{"model":{"kind":"conjugate"},"budget":{"kind":"steps","steps":1},"resume":true}"#,
        )
        .unwrap_err();
        assert!(matches!(e, SpecError::BadValue { field: "resume", .. }), "{e}");
        // dir without cadence
        let e = parse_spec(
            r#"{"model":{"kind":"conjugate"},"budget":{"kind":"steps","steps":1},"checkpoint_dir":"/tmp/x"}"#,
        )
        .unwrap_err();
        assert!(matches!(e, SpecError::BadValue { field: "checkpoint_dir", .. }), "{e}");
        // rule knob out of range
        let e = parse_spec(
            r#"{"model":{"kind":"conjugate"},"rule":{"kind":"austerity","eps":2.0},"budget":{"kind":"steps","steps":1}}"#,
        )
        .unwrap_err();
        assert!(matches!(e, SpecError::BadValue { field: "eps", .. }), "{e}");
        // batch larger than the dataset
        let e = parse_spec(
            r#"{"model":{"kind":"conjugate","n":100},"rule":{"kind":"austerity","batch":500},"budget":{"kind":"steps","steps":1}}"#,
        )
        .unwrap_err();
        assert!(matches!(e, SpecError::BadValue { field: "batch", .. }), "{e}");
        // knob for the wrong rule
        let e = parse_spec(
            r#"{"model":{"kind":"conjugate"},"rule":{"kind":"exact","eps":0.1},"budget":{"kind":"steps","steps":1}}"#,
        )
        .unwrap_err();
        assert!(matches!(e, SpecError::BadValue { field: "rule.eps", .. }), "{e}");
        // d on a scalar model
        let e = parse_spec(
            r#"{"model":{"kind":"linreg","d":3},"budget":{"kind":"steps","steps":1}}"#,
        )
        .unwrap_err();
        assert!(matches!(e, SpecError::BadValue { field: "model.d", .. }), "{e}");
    }

    #[test]
    fn parser_level_failures_pass_through_typed() {
        assert!(matches!(parse_spec("not json"), Err(SpecError::Json(_))));
        assert!(matches!(
            parse_spec(r#"{"model":{"kind":"conjugate"},"budget":{"kind":"steps","steps":NaN}}"#),
            Err(SpecError::Json(JsonError::NonFinite { .. }))
        ));
        assert!(matches!(
            parse_spec(r#"{"seed":1,"seed":2}"#),
            Err(SpecError::Json(JsonError::DuplicateKey { .. }))
        ));
        assert!(matches!(
            parse_spec(r#"{"model":{"kind":"conjugate"}} extra"#),
            Err(SpecError::Json(JsonError::TrailingGarbage { .. }))
        ));
        assert!(matches!(parse_spec("[1,2]"), Err(SpecError::NotAnObject)));
    }

    #[test]
    fn mh_mode_resolves_with_cli_default_batch() {
        let rule = RuleSpec::Austerity { eps: 0.05, batch: None };
        // n=2000 -> 500.min(500).max(16) = 500
        assert!(matches!(rule.mh_mode(2_000), Ok(MhMode::Approx { .. })));
        // tiny n clamps the floor to n itself
        assert!(rule.mh_mode(20).is_ok());
    }
}

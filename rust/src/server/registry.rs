//! The job registry: admission, queueing, lifecycle, and progress.
//!
//! One [`Registry`] instance is shared by the accept loop (admission +
//! status queries), the runner threads (claim / finish), and the
//! shutdown path (drain / cancel / close). All state lives behind a
//! single mutex with two condvars:
//!
//! * `work` — wakes runner threads when a job is queued (or the
//!   registry closes);
//! * `idle` — wakes the drain path when the last running job finishes.
//!
//! Admission control is a bounded FIFO: at most `max_jobs` jobs run at
//! once (one per runner thread), at most `max_queue` more wait. Beyond
//! that, [`AdmitError::QueueFull`] maps to the 429 the API promises —
//! typed backpressure, never an unbounded pile-up.
//!
//! Job IDs are indices into an append-only slot vector: they stay
//! valid for the daemon's lifetime, so a client can poll a finished
//! job long after it completed.

use std::collections::VecDeque;
use std::path::PathBuf;
use std::sync::{Arc, Condvar, Mutex, MutexGuard};
use std::time::{Duration, Instant};

use crate::coordinator::checkpoint::{json_num, json_str};
use crate::metrics::convergence::cross_chain;
use crate::server::jobs::JobLive;
use crate::server::spec::{parse_spec, JobSpec};

/// Where a job is in its lifecycle.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum JobState {
    /// Admitted, waiting for a runner slot.
    Queued,
    /// A runner thread is driving its chains.
    Running,
    /// Finished; the `RunReport` JSON is available.
    Done,
    /// The launch failed; the error string is available.
    Failed,
    /// Cancelled before or during execution.
    Cancelled,
}

impl JobState {
    pub fn as_str(self) -> &'static str {
        match self {
            JobState::Queued => "queued",
            JobState::Running => "running",
            JobState::Done => "done",
            JobState::Failed => "failed",
            JobState::Cancelled => "cancelled",
        }
    }

    pub fn is_terminal(self) -> bool {
        matches!(self, JobState::Done | JobState::Failed | JobState::Cancelled)
    }
}

/// Why admission refused a job.
#[derive(Clone, Debug, PartialEq)]
pub enum AdmitError {
    /// The bounded queue is at capacity → 429.
    QueueFull { cap: usize },
    /// The server is draining for shutdown → 503.
    Draining,
    /// The spec failed parsing/validation → 400 (rendered message).
    Spec(String),
}

/// The result view `GET /jobs/:id/result` serves.
#[derive(Clone, Debug, PartialEq)]
pub enum JobOutcome {
    /// Still queued or running → 409.
    Pending,
    /// The full `RunReport` JSON.
    Report(String),
    /// Cancelled before a report was produced.
    CancelledEarly,
    /// Launch failure, rendered.
    Failed(String),
}

/// Registry construction knobs (from the `serve` CLI flags).
#[derive(Clone, Debug)]
pub struct RegistryCfg {
    /// Concurrent jobs (= runner threads).
    pub max_jobs: usize,
    /// Waiting jobs beyond the running ones.
    pub max_queue: usize,
    /// When set, jobs that request checkpointing without an explicit
    /// directory get `<ckpt_root>/job-<id>`; when `ckpt_every` is also
    /// set, *every* job is checkpointed at that cadence by default —
    /// the knob behind "shutdown flushes, `resume` finishes".
    pub ckpt_root: Option<PathBuf>,
    pub ckpt_every: Option<usize>,
}

struct JobSlot {
    spec: Arc<JobSpec>,
    state: JobState,
    live: JobLive,
    result: Option<String>,
    error: Option<String>,
    /// Set when DELETE arrived while the job ran: `finish` maps the
    /// (cooperatively stopped) report to `Cancelled`, not `Done`.
    cancel_requested: bool,
    /// FIFO stamp assigned when a runner claimed the job — lets tests
    /// assert admission order directly.
    admitted_seq: Option<u64>,
}

struct RegState {
    jobs: Vec<JobSlot>,
    queue: VecDeque<usize>,
    running: usize,
    draining: bool,
    closed: bool,
    admit_seq: u64,
}

/// Shared job table + admission queue. See module docs.
pub struct Registry {
    state: Mutex<RegState>,
    work: Condvar,
    idle: Condvar,
    cfg: RegistryCfg,
}

impl Registry {
    pub fn new(cfg: RegistryCfg) -> Self {
        Registry {
            state: Mutex::new(RegState {
                jobs: Vec::new(),
                queue: VecDeque::new(),
                running: 0,
                draining: false,
                closed: false,
                admit_seq: 0,
            }),
            work: Condvar::new(),
            idle: Condvar::new(),
            cfg,
        }
    }

    fn lock(&self) -> MutexGuard<'_, RegState> {
        self.state.lock().unwrap_or_else(|e| e.into_inner())
    }

    /// Parse, validate and enqueue a job. Returns its ID.
    pub fn submit(&self, body: &str) -> Result<usize, AdmitError> {
        let mut spec = parse_spec(body).map_err(|e| AdmitError::Spec(e.to_string()))?;
        let mut st = self.lock();
        if st.draining || st.closed {
            return Err(AdmitError::Draining);
        }
        if st.queue.len() >= self.cfg.max_queue {
            return Err(AdmitError::QueueFull { cap: self.cfg.max_queue });
        }
        let id = st.jobs.len();
        // server-side checkpoint defaults: give the job a directory
        // (and cadence, if configured) under the checkpoint root
        if let Some(root) = &self.cfg.ckpt_root {
            if spec.checkpoint_every.is_none() {
                spec.checkpoint_every = self.cfg.ckpt_every;
            }
            if spec.checkpoint_every.is_some() && spec.checkpoint_dir.is_none() {
                spec.checkpoint_dir = Some(root.join(format!("job-{id}")));
            }
        }
        let live = JobLive::new(spec.chains);
        st.jobs.push(JobSlot {
            spec: Arc::new(spec),
            state: JobState::Queued,
            live,
            result: None,
            error: None,
            cancel_requested: false,
            admitted_seq: None,
        });
        st.queue.push_back(id);
        drop(st);
        self.work.notify_one();
        Ok(id)
    }

    /// Runner-thread entry: block until a job is available, claim it
    /// (FIFO), and return its handles. `None` once the registry is
    /// closed and the queue is empty — the runner should exit.
    pub fn next_job(&self) -> Option<(usize, Arc<JobSpec>, JobLive)> {
        let mut st = self.lock();
        loop {
            if let Some(id) = st.queue.pop_front() {
                let seq = st.admit_seq;
                st.admit_seq += 1;
                st.running += 1;
                let slot = &mut st.jobs[id];
                slot.state = JobState::Running;
                slot.admitted_seq = Some(seq);
                return Some((id, Arc::clone(&slot.spec), slot.live.clone()));
            }
            if st.closed {
                return None;
            }
            st = self.work.wait(st).unwrap_or_else(|e| e.into_inner());
        }
    }

    /// Runner-thread exit: record the outcome and release the slot.
    pub fn finish(&self, id: usize, outcome: Result<String, String>) {
        let mut st = self.lock();
        st.running = st.running.saturating_sub(1);
        let slot = &mut st.jobs[id];
        match outcome {
            // a cooperatively-cancelled launch still returns a report;
            // the cancel request wins over "done"
            Ok(report) => {
                slot.result = Some(report);
                slot.state = if slot.cancel_requested {
                    JobState::Cancelled
                } else {
                    JobState::Done
                };
            }
            Err(e) => {
                slot.error = Some(e);
                slot.state = if slot.cancel_requested {
                    JobState::Cancelled
                } else {
                    JobState::Failed
                };
            }
        }
        drop(st);
        self.idle.notify_all();
    }

    /// Cooperative cancel. Queued jobs cancel immediately; running jobs
    /// get their token raised and settle as `Cancelled` when the chains
    /// notice (next step boundary). Terminal jobs are left unchanged.
    /// `None` for unknown IDs.
    pub fn cancel(&self, id: usize) -> Option<JobState> {
        let mut st = self.lock();
        let exists = id < st.jobs.len();
        if !exists {
            return None;
        }
        match st.jobs[id].state {
            JobState::Queued => {
                st.queue.retain(|&q| q != id);
                let slot = &mut st.jobs[id];
                slot.state = JobState::Cancelled;
                slot.cancel_requested = true;
                Some(JobState::Cancelled)
            }
            JobState::Running => {
                let slot = &mut st.jobs[id];
                slot.cancel_requested = true;
                slot.live.cancel.cancel();
                Some(JobState::Running)
            }
            terminal => Some(terminal),
        }
    }

    /// Current state of a job (`None` for unknown IDs).
    pub fn state_of(&self, id: usize) -> Option<JobState> {
        self.lock().jobs.get(id).map(|s| s.state)
    }

    /// FIFO claim stamp (test hook for admission-order assertions).
    pub fn admitted_seq(&self, id: usize) -> Option<u64> {
        self.lock().jobs.get(id).and_then(|s| s.admitted_seq)
    }

    /// Incremental progress document for `GET /jobs/:id`: lifecycle
    /// state plus live counters and running convergence diagnostics
    /// computed over the draws recorded *so far*.
    pub fn status_json(&self, id: usize) -> Option<String> {
        let (state, spec, live, cancel_requested) = {
            let st = self.lock();
            let slot = st.jobs.get(id)?;
            (slot.state, Arc::clone(&slot.spec), slot.live.clone(), slot.cancel_requested)
        };
        // snapshot outside the registry lock: cross_chain over long
        // series must not stall admissions
        let snap = live.board.snapshot();
        let series = live.series_snapshot();
        let conv = cross_chain(&series);
        let draws: usize = series.iter().map(|s| s.len()).sum();
        let mut out = String::with_capacity(256);
        out.push_str("{\"id\":");
        out.push_str(&id.to_string());
        out.push_str(",\"state\":");
        out.push_str(&json_str(state.as_str()));
        out.push_str(",\"cancel_requested\":");
        out.push_str(if cancel_requested { "true" } else { "false" });
        out.push_str(",\"model\":");
        out.push_str(&json_str(spec.model.kind()));
        out.push_str(",\"rule\":");
        out.push_str(&json_str(spec.rule.label()));
        out.push_str(",\"chains\":");
        out.push_str(&spec.chains.to_string());
        out.push_str(",\"progress\":{\"steps\":");
        out.push_str(&snap.total_steps().to_string());
        out.push_str(",\"accepted\":");
        out.push_str(&snap.total_accepted().to_string());
        out.push_str(",\"data_used\":");
        out.push_str(&snap.total_data_used().to_string());
        out.push_str(",\"acceptance_rate\":");
        out.push_str(&json_num(snap.acceptance_rate()));
        out.push_str(",\"draws\":");
        out.push_str(&draws.to_string());
        out.push_str(",\"per_chain_steps\":[");
        for (i, s) in snap.steps.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            out.push_str(&s.to_string());
        }
        out.push_str("]},\"convergence\":{\"rhat\":");
        out.push_str(&json_num(conv.rhat));
        out.push_str(",\"ess\":");
        out.push_str(&json_num(conv.ess));
        out.push_str(",\"pooled_mean\":");
        out.push_str(&json_num(conv.pooled_mean));
        out.push_str(",\"n_samples\":");
        out.push_str(&conv.n_samples.to_string());
        out.push_str("}}");
        Some(out)
    }

    /// The result view (`None` for unknown IDs).
    pub fn outcome(&self, id: usize) -> Option<JobOutcome> {
        let st = self.lock();
        let slot = st.jobs.get(id)?;
        Some(match slot.state {
            JobState::Queued | JobState::Running => JobOutcome::Pending,
            JobState::Done => JobOutcome::Report(
                slot.result.clone().unwrap_or_else(|| "{}".into()),
            ),
            JobState::Cancelled => match &slot.result {
                // the flushed partial report is still useful; serve it
                Some(r) => JobOutcome::Report(r.clone()),
                None => JobOutcome::CancelledEarly,
            },
            JobState::Failed => {
                JobOutcome::Failed(slot.error.clone().unwrap_or_else(|| "unknown".into()))
            }
        })
    }

    /// `GET /healthz` document: queue/running/terminal counts.
    pub fn healthz_json(&self) -> String {
        let st = self.lock();
        let mut done = 0usize;
        let mut failed = 0usize;
        let mut cancelled = 0usize;
        for j in &st.jobs {
            match j.state {
                JobState::Done => done += 1,
                JobState::Failed => failed += 1,
                JobState::Cancelled => cancelled += 1,
                _ => {}
            }
        }
        format!(
            "{{\"status\":\"ok\",\"draining\":{},\"jobs\":{{\"queued\":{},\"running\":{},\"done\":{done},\"failed\":{failed},\"cancelled\":{cancelled}}},\"max_jobs\":{},\"max_queue\":{}}}",
            st.draining, st.queue.len(), st.running, self.cfg.max_jobs, self.cfg.max_queue,
        )
    }

    /// Stop admitting new jobs (submissions now get 503). Queued and
    /// running jobs continue.
    pub fn begin_drain(&self) {
        self.lock().draining = true;
    }

    /// Raise every running job's cancel token and cancel everything
    /// still queued (the impatient half of shutdown, after the drain
    /// deadline passes). Running jobs flush a final checkpoint at the
    /// next step boundary, so `resume` can finish them later.
    pub fn cancel_running(&self) {
        let ids: Vec<usize> = {
            let st = self.lock();
            (0..st.jobs.len())
                .filter(|&i| !st.jobs[i].state.is_terminal())
                .collect()
        };
        for id in ids {
            self.cancel(id);
        }
    }

    /// Block until no job is queued or running, or the deadline passes.
    /// Returns `true` when idle.
    pub fn await_idle(&self, timeout: Duration) -> bool {
        let deadline = Instant::now() + timeout;
        let mut st = self.lock();
        loop {
            if st.running == 0 && st.queue.is_empty() {
                return true;
            }
            let Some(left) = deadline.checked_duration_since(Instant::now()) else {
                return false;
            };
            let (g, _) = self
                .idle
                .wait_timeout(st, left)
                .unwrap_or_else(|e| e.into_inner());
            st = g;
        }
    }

    /// Final shutdown: wake every blocked runner so `next_job` returns
    /// `None` and the threads exit.
    pub fn close(&self) {
        self.lock().closed = true;
        self.work.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn reg(max_jobs: usize, max_queue: usize) -> Registry {
        Registry::new(RegistryCfg { max_jobs, max_queue, ckpt_root: None, ckpt_every: None })
    }

    const SPEC: &str =
        r#"{"model":{"kind":"conjugate","n":64},"budget":{"kind":"steps","steps":10}}"#;

    #[test]
    fn lifecycle_queued_running_done() {
        let r = reg(1, 8);
        let id = r.submit(SPEC).unwrap();
        assert_eq!(r.state_of(id), Some(JobState::Queued));
        assert_eq!(r.outcome(id), Some(JobOutcome::Pending));
        let (claimed, _spec, _live) = r.next_job().unwrap();
        assert_eq!(claimed, id);
        assert_eq!(r.state_of(id), Some(JobState::Running));
        r.finish(id, Ok("{\"ok\":true}".into()));
        assert_eq!(r.state_of(id), Some(JobState::Done));
        assert_eq!(r.outcome(id), Some(JobOutcome::Report("{\"ok\":true}".into())));
    }

    #[test]
    fn bounded_queue_rejects_with_capacity() {
        let r = reg(1, 2);
        r.submit(SPEC).unwrap();
        r.submit(SPEC).unwrap();
        match r.submit(SPEC) {
            Err(AdmitError::QueueFull { cap }) => assert_eq!(cap, 2),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn bad_specs_are_refused_at_admission() {
        let r = reg(1, 8);
        assert!(matches!(r.submit("not json"), Err(AdmitError::Spec(_))));
        assert!(matches!(
            r.submit(r#"{"model":{"kind":"zebra"},"budget":{"kind":"steps","steps":1}}"#),
            Err(AdmitError::Spec(_))
        ));
        // nothing was enqueued
        assert!(r.healthz_json().contains("\"queued\":0"));
    }

    #[test]
    fn queued_cancel_is_immediate_and_skips_execution() {
        let r = reg(1, 8);
        let a = r.submit(SPEC).unwrap();
        let b = r.submit(SPEC).unwrap();
        assert_eq!(r.cancel(a), Some(JobState::Cancelled));
        assert_eq!(r.state_of(a), Some(JobState::Cancelled));
        assert_eq!(r.outcome(a), Some(JobOutcome::CancelledEarly));
        // the runner now claims b, not the cancelled a
        let (claimed, ..) = r.next_job().unwrap();
        assert_eq!(claimed, b);
    }

    #[test]
    fn running_cancel_raises_the_token_and_wins_over_done() {
        let r = reg(1, 8);
        let id = r.submit(SPEC).unwrap();
        let (_, _, live) = r.next_job().unwrap();
        assert!(!live.cancel.is_cancelled());
        assert_eq!(r.cancel(id), Some(JobState::Running));
        assert!(live.cancel.is_cancelled(), "token must be shared with the runner");
        // the runner returns its flushed partial report
        r.finish(id, Ok("{\"partial\":true}".into()));
        assert_eq!(r.state_of(id), Some(JobState::Cancelled));
        assert_eq!(r.outcome(id), Some(JobOutcome::Report("{\"partial\":true}".into())));
    }

    #[test]
    fn drain_refuses_new_work_but_keeps_old() {
        let r = reg(1, 8);
        let id = r.submit(SPEC).unwrap();
        r.begin_drain();
        assert!(matches!(r.submit(SPEC), Err(AdmitError::Draining)));
        assert_eq!(r.state_of(id), Some(JobState::Queued));
        let (claimed, ..) = r.next_job().unwrap();
        assert_eq!(claimed, id);
        r.finish(id, Ok("{}".into()));
        assert!(r.await_idle(Duration::from_millis(10)));
    }

    #[test]
    fn close_unblocks_runners() {
        let r = Arc::new(reg(1, 8));
        let r2 = Arc::clone(&r);
        let t = std::thread::spawn(move || r2.next_job().is_none());
        std::thread::sleep(Duration::from_millis(20));
        r.close();
        assert!(t.join().unwrap(), "blocked runner must see None after close()");
    }

    #[test]
    fn fifo_order_is_stamped() {
        let r = reg(2, 8);
        let a = r.submit(SPEC).unwrap();
        let b = r.submit(SPEC).unwrap();
        let c = r.submit(SPEC).unwrap();
        for _ in 0..3 {
            let (id, ..) = r.next_job().unwrap();
            r.finish(id, Ok("{}".into()));
        }
        let (sa, sb, sc) =
            (r.admitted_seq(a).unwrap(), r.admitted_seq(b).unwrap(), r.admitted_seq(c).unwrap());
        assert!(sa < sb && sb < sc, "claims must follow submission order: {sa} {sb} {sc}");
    }

    #[test]
    fn server_side_checkpoint_defaults_are_applied() {
        let dir = std::env::temp_dir().join("austerity_registry_ckpt_root");
        let r = Registry::new(RegistryCfg {
            max_jobs: 1,
            max_queue: 8,
            ckpt_root: Some(dir.clone()),
            ckpt_every: Some(25),
        });
        let id = r.submit(SPEC).unwrap();
        let (_, spec, _) = r.next_job().unwrap();
        assert_eq!(spec.checkpoint_every, Some(25));
        assert_eq!(spec.checkpoint_dir.as_deref(), Some(dir.join(format!("job-{id}")).as_path()));
    }

    #[test]
    fn status_json_reports_live_progress() {
        let r = reg(1, 8);
        let id = r.submit(SPEC).unwrap();
        let (_, _, live) = r.next_job().unwrap();
        live.board.publish(0, 7, 3, 420);
        live.series[0].lock().unwrap().extend([0.5; 8]);
        let s = r.status_json(id).unwrap();
        assert!(s.contains("\"state\":\"running\""), "{s}");
        assert!(s.contains("\"steps\":7"), "{s}");
        assert!(s.contains("\"accepted\":3"), "{s}");
        assert!(s.contains("\"data_used\":420"), "{s}");
        assert!(s.contains("\"draws\":8"), "{s}");
        // the document itself satisfies the strict reader
        crate::server::json_in::parse(&s).unwrap();
        assert!(r.status_json(999).is_none());
    }
}

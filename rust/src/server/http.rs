//! Minimal HTTP/1.1 framing over `std::net::TcpStream` — just enough
//! protocol for the job API, hand-rolled so the daemon stays
//! zero-dependency like the rest of the crate.
//!
//! Scope (deliberate):
//!
//! * one request per connection (`Connection: close` on every
//!   response) — no keep-alive state machine to get wrong;
//! * request line + headers up to [`MAX_HEAD`] bytes, body framed by
//!   `Content-Length` up to [`MAX_BODY`] bytes — chunked encoding is
//!   rejected rather than half-implemented;
//! * query strings are stripped from the path (the API is purely
//!   path + JSON body);
//! * every response is `application/json` with an explicit
//!   `Content-Length`.
//!
//! Oversized or malformed frames surface as [`HttpError`] and the
//! accept loop answers with the matching 4xx — a hostile peer can
//! never panic the daemon or hold a runner thread.

use std::io::{Read, Write};
use std::net::TcpStream;

/// Cap on the request line + headers (bytes). 431 beyond this.
pub const MAX_HEAD: usize = 16 * 1024;
/// Cap on the request body (bytes). 413 beyond this. Job specs are a
/// few hundred bytes; 1 MiB leaves generous headroom.
pub const MAX_BODY: usize = 1024 * 1024;

/// A parsed request: method, path (query stripped), and raw body.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Request {
    pub method: String,
    pub path: String,
    pub body: String,
}

/// Framing failure while reading a request. Each maps to one status.
#[derive(Debug)]
pub enum HttpError {
    /// Socket error or connection closed mid-frame.
    Io(std::io::Error),
    /// Request line/headers unparsable → 400.
    BadRequest(&'static str),
    /// Headers exceeded [`MAX_HEAD`] → 431.
    HeadTooLarge,
    /// Body exceeded [`MAX_BODY`] (declared or actual) → 413.
    BodyTooLarge,
}

impl HttpError {
    /// The response this framing error earns, if the socket is still
    /// writable (Io errors get none — the peer is gone).
    pub fn response(&self) -> Option<Response> {
        match self {
            HttpError::Io(_) => None,
            HttpError::BadRequest(why) => Some(Response::error(400, why)),
            HttpError::HeadTooLarge => Some(Response::error(431, "request headers too large")),
            HttpError::BodyTooLarge => Some(Response::error(413, "request body too large")),
        }
    }
}

impl From<std::io::Error> for HttpError {
    fn from(e: std::io::Error) -> Self {
        HttpError::Io(e)
    }
}

/// Read one request from the stream (blocking, honouring whatever
/// read timeout the caller set on the socket).
pub fn read_request(stream: &mut TcpStream) -> Result<Request, HttpError> {
    // accumulate until the blank line terminating the header block
    let mut buf: Vec<u8> = Vec::with_capacity(1024);
    let mut chunk = [0u8; 1024];
    let head_end = loop {
        if let Some(pos) = find_head_end(&buf) {
            break pos;
        }
        if buf.len() > MAX_HEAD {
            return Err(HttpError::HeadTooLarge);
        }
        let n = stream.read(&mut chunk)?;
        if n == 0 {
            return Err(HttpError::BadRequest("connection closed before headers ended"));
        }
        buf.extend_from_slice(&chunk[..n]);
    };
    if head_end > MAX_HEAD {
        return Err(HttpError::HeadTooLarge);
    }

    let head = std::str::from_utf8(&buf[..head_end])
        .map_err(|_| HttpError::BadRequest("headers are not valid UTF-8"))?;
    let mut lines = head.split("\r\n");
    let request_line = lines.next().unwrap_or("");
    let mut parts = request_line.split(' ');
    let method = parts.next().unwrap_or("").to_string();
    let target = parts.next().unwrap_or("");
    let version = parts.next().unwrap_or("");
    if method.is_empty() || target.is_empty() || !version.starts_with("HTTP/1.") {
        return Err(HttpError::BadRequest("malformed request line"));
    }
    // the API keys purely off the path; drop any query string
    let path = target.split('?').next().unwrap_or(target).to_string();

    let mut content_length: usize = 0;
    for line in lines {
        let Some((name, value)) = line.split_once(':') else { continue };
        let name = name.trim().to_ascii_lowercase();
        let value = value.trim();
        if name == "content-length" {
            content_length = value
                .parse()
                .map_err(|_| HttpError::BadRequest("unparsable Content-Length"))?;
        } else if name == "transfer-encoding" && !value.eq_ignore_ascii_case("identity") {
            return Err(HttpError::BadRequest("chunked bodies are not supported"));
        }
    }
    if content_length > MAX_BODY {
        return Err(HttpError::BodyTooLarge);
    }

    // bytes already pulled past the header terminator belong to the body
    let body_start = head_end + 4; // skip \r\n\r\n
    let mut body: Vec<u8> = buf[body_start.min(buf.len())..].to_vec();
    if body.len() > content_length {
        // pipelined extra bytes: one request per connection, ignore them
        body.truncate(content_length);
    }
    while body.len() < content_length {
        let n = stream.read(&mut chunk)?;
        if n == 0 {
            return Err(HttpError::BadRequest("connection closed mid-body"));
        }
        let need = content_length - body.len();
        body.extend_from_slice(&chunk[..n.min(need)]);
    }
    let body = String::from_utf8(body)
        .map_err(|_| HttpError::BadRequest("body is not valid UTF-8"))?;

    Ok(Request { method, path, body })
}

fn find_head_end(buf: &[u8]) -> Option<usize> {
    buf.windows(4).position(|w| w == b"\r\n\r\n")
}

/// A response ready to serialize: status code + JSON body.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Response {
    pub status: u16,
    pub body: String,
}

impl Response {
    /// 200 with a pre-serialized JSON body.
    pub fn json(status: u16, body: impl Into<String>) -> Self {
        Response { status, body: body.into() }
    }

    /// An error payload `{"error": "..."}` with proper escaping.
    pub fn error(status: u16, message: &str) -> Self {
        let body = format!(
            "{{\"error\":{}}}",
            crate::coordinator::checkpoint::json_str(message)
        );
        Response { status, body }
    }

    /// Serialize onto the socket. Errors are returned (the caller just
    /// drops the connection — nothing more to salvage).
    pub fn write_to(&self, stream: &mut TcpStream) -> std::io::Result<()> {
        let head = format!(
            "HTTP/1.1 {} {}\r\nContent-Type: application/json\r\nContent-Length: {}\r\nConnection: close\r\n\r\n",
            self.status,
            status_text(self.status),
            self.body.len(),
        );
        stream.write_all(head.as_bytes())?;
        stream.write_all(self.body.as_bytes())?;
        stream.flush()
    }
}

/// Reason phrases for the statuses this API emits.
pub fn status_text(status: u16) -> &'static str {
    match status {
        200 => "OK",
        202 => "Accepted",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        409 => "Conflict",
        413 => "Payload Too Large",
        429 => "Too Many Requests",
        431 => "Request Header Fields Too Large",
        500 => "Internal Server Error",
        503 => "Service Unavailable",
        _ => "Unknown",
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::net::{TcpListener, TcpStream};

    /// Push raw bytes through a loopback socket and read one request.
    fn roundtrip(raw: &[u8]) -> Result<Request, HttpError> {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let raw = raw.to_vec();
        let writer = std::thread::spawn(move || {
            let mut s = TcpStream::connect(addr).unwrap();
            s.write_all(&raw).unwrap();
            // keep the socket open briefly so reads see EOF only after data
            s.shutdown(std::net::Shutdown::Write).ok();
        });
        let (mut conn, _) = listener.accept().unwrap();
        let out = read_request(&mut conn);
        writer.join().unwrap();
        out
    }

    #[test]
    fn parses_post_with_body() {
        let req = roundtrip(
            b"POST /jobs?trace=1 HTTP/1.1\r\nHost: x\r\nContent-Length: 7\r\n\r\n{\"a\":1}",
        )
        .unwrap();
        assert_eq!(req.method, "POST");
        assert_eq!(req.path, "/jobs"); // query stripped
        assert_eq!(req.body, "{\"a\":1}");
    }

    #[test]
    fn parses_get_without_body() {
        let req = roundtrip(b"GET /healthz HTTP/1.1\r\n\r\n").unwrap();
        assert_eq!(req.method, "GET");
        assert_eq!(req.path, "/healthz");
        assert_eq!(req.body, "");
    }

    #[test]
    fn rejects_malformed_frames() {
        assert!(matches!(roundtrip(b"NOT-HTTP\r\n\r\n"), Err(HttpError::BadRequest(_))));
        assert!(matches!(
            roundtrip(b"GET / HTTP/1.1\r\nContent-Length: zebra\r\n\r\n"),
            Err(HttpError::BadRequest(_))
        ));
        assert!(matches!(
            roundtrip(b"POST / HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\n"),
            Err(HttpError::BadRequest(_))
        ));
        assert!(matches!(
            roundtrip(b"GET / HTTP/1.1\r\nContent-Length: 99999999\r\n\r\n"),
            Err(HttpError::BodyTooLarge)
        ));
        // headers never terminated and huge
        let mut raw = b"GET / HTTP/1.1\r\n".to_vec();
        raw.extend(std::iter::repeat(b'x').take(MAX_HEAD + 16));
        assert!(matches!(roundtrip(&raw), Err(HttpError::HeadTooLarge)));
    }

    #[test]
    fn truncated_body_is_a_bad_request() {
        assert!(matches!(
            roundtrip(b"POST /jobs HTTP/1.1\r\nContent-Length: 50\r\n\r\nshort"),
            Err(HttpError::BadRequest(_))
        ));
    }

    #[test]
    fn response_serializes_with_length_and_close() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let reader = std::thread::spawn(move || {
            let mut s = TcpStream::connect(addr).unwrap();
            let mut out = String::new();
            s.read_to_string(&mut out).unwrap();
            out
        });
        let (mut conn, _) = listener.accept().unwrap();
        Response::error(429, "queue full").write_to(&mut conn).unwrap();
        drop(conn);
        let text = reader.join().unwrap();
        assert!(text.starts_with("HTTP/1.1 429 Too Many Requests\r\n"), "{text}");
        assert!(text.contains("Content-Type: application/json\r\n"));
        assert!(text.contains("Connection: close\r\n"));
        assert!(text.ends_with("{\"error\":\"queue full\"}"), "{text}");
        let len: usize = text
            .lines()
            .find_map(|l| l.strip_prefix("Content-Length: "))
            .unwrap()
            .trim()
            .parse()
            .unwrap();
        assert_eq!(len, "{\"error\":\"queue full\"}".len());
    }
}

//! Job execution: one validated [`JobSpec`] → one `Session` launch →
//! the `RunReport` JSON body served by `GET /jobs/:id/result`.
//!
//! Determinism contract: `run_job(spec, _)` is a pure function of the
//! spec. The synthetic dataset comes from `(kind, n, d, data_seed)`,
//! chain `c` draws from the same `Pcg64` stream `Session` always
//! assigns (`STREAM_BASE + c`), and the live-progress instrumentation
//! below observes the chains without perturbing them — so a job
//! submitted to a saturated server produces draws bit-identical to the
//! same spec run solo (regression-tested in
//! `tests/integration_serve.rs`).

use std::sync::{Arc, Mutex};

use crate::coordinator::session::Session;
use crate::coordinator::supervise::{CancelToken, LaunchError, ProgressBoard};
use crate::models::traits::{LlDiffModel, ProposalKernel};
use crate::models::{LinRegModel, LogisticModel};
use crate::coordinator::record::Components;
use crate::samplers::{GaussianRandomWalk, ScalarRandomWalk};
use crate::server::spec::{JobSpec, ModelSpec};
use crate::testkit::models::ConjugateGaussian;

/// The handles a *running* job shares with the registry: the
/// cooperative cancel token, the live per-chain progress counters, and
/// the recorded-draw series the status endpoint computes running
/// R-hat/ESS from.
#[derive(Clone)]
pub struct JobLive {
    pub cancel: CancelToken,
    pub board: Arc<ProgressBoard>,
    /// Per-chain recorded values, appended as the chains run. Locked
    /// per chain so concurrent chains never contend on one mutex.
    pub series: Arc<Vec<Mutex<Vec<f64>>>>,
}

impl JobLive {
    pub fn new(chains: usize) -> Self {
        JobLive {
            cancel: CancelToken::new(),
            board: Arc::new(ProgressBoard::new(chains)),
            series: Arc::new((0..chains).map(|_| Mutex::new(Vec::new())).collect()),
        }
    }

    /// Clone of every chain's recorded values so far.
    pub fn series_snapshot(&self) -> Vec<Vec<f64>> {
        self.series
            .iter()
            .map(|m| m.lock().unwrap_or_else(|e| e.into_inner()).clone())
            .collect()
    }
}

/// The scalar a job records per retained step: component 0 of the
/// parameter — exactly what the default `RecordDefault` observer
/// (`Param::index(0)`) records, so instrumented and plain runs emit
/// identical draw streams.
fn observed<P: Components>(p: &P) -> f64 {
    p.component(0)
}

/// Run one job to completion (or cancellation). `live` threads in the
/// server-side instrumentation; `None` runs the identical launch bare
/// (the bit-identity oracle the integration tests compare against).
///
/// Returns the `RunReport` JSON on success, a rendered error on launch
/// failure (bad resume manifest, quorum loss, oversized dataset).
pub fn run_job(spec: &JobSpec, live: Option<&JobLive>) -> Result<String, String> {
    match spec.model {
        ModelSpec::Logistic { n, d, data_seed } => {
            let data = crate::data::synthetic::two_class_gaussian(n, d, 1.2, data_seed);
            let model = LogisticModel::new(data, 10.0).map_err(|e| e.to_string())?;
            let kernel =
                GaussianRandomWalk::new(spec.sigma_prop.unwrap_or(0.01), model.prior_precision);
            let init = model.map_estimate(60);
            launch(&model, &kernel, init, spec, live)
        }
        ModelSpec::Linreg { n, data_seed } => {
            let data = crate::data::synthetic::linreg_toy(n, data_seed);
            let model = LinRegModel::new(data, 3.0, 4950.0).map_err(|e| e.to_string())?;
            let kernel = ScalarRandomWalk {
                sigma: spec.sigma_prop.unwrap_or(0.1),
                log_prior: |t: f64| -4950.0 * t.abs(),
            };
            launch(&model, &kernel, 0.5, spec, live)
        }
        ModelSpec::Conjugate { n, data_seed } => {
            let model = ConjugateGaussian::synthetic(n, 1.0, 1.0, 0.0, 3.0, data_seed);
            let kernel = model.rw_proposal(spec.sigma_prop.unwrap_or(0.5));
            launch(&model, &kernel, 0.0, spec, live)
        }
    }
}

fn launch<M, K>(
    model: &M,
    kernel: &K,
    init: M::Param,
    spec: &JobSpec,
    live: Option<&JobLive>,
) -> Result<String, String>
where
    M: LlDiffModel + Sync,
    M::Param: crate::coordinator::checkpoint::Persist + Components,
    K: ProposalKernel<M::Param> + Sync,
{
    let mode = spec.rule.mh_mode(model.n()).map_err(|e| e.to_string())?;

    let mut session = Session::new(model)
        .kernel(kernel)
        .rule(mode)
        .init(init)
        .chains(spec.chains)
        .seed(spec.seed)
        .budget(spec.budget)
        .burn_in(spec.burn_in)
        .thin(spec.thin)
        .retry(spec.retry_policy());
    if let Some(every) = spec.checkpoint_every {
        session = session.checkpoint_every(every);
    }
    if let Some(dir) = &spec.checkpoint_dir {
        session = session.checkpoint_dir(dir.clone());
        if spec.resume {
            session = session.resume_from(dir.clone());
        }
    }

    let report = match live {
        Some(l) => {
            let series = Arc::clone(&l.series);
            session
                .cancel_token(l.cancel.clone())
                .progress_board(Arc::clone(&l.board))
                .record_with(move |c: usize| {
                    let sink = Arc::clone(&series);
                    move |p: &M::Param| {
                        let v = observed(p);
                        sink[c].lock().unwrap_or_else(|e| e.into_inner()).push(v);
                        v
                    }
                })
                .try_run()
                .map_err(render_launch_error)?
                .to_json()
        }
        None => session.try_run().map_err(render_launch_error)?.to_json(),
    };
    Ok(report)
}

fn render_launch_error(e: LaunchError) -> String {
    format!("launch failed: {e}")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::chain::Budget;
    use crate::server::spec::{parse_spec, RuleSpec};

    fn tiny_spec() -> JobSpec {
        JobSpec {
            model: ModelSpec::Conjugate { n: 64, data_seed: 3 },
            sigma_prop: None,
            rule: RuleSpec::Exact,
            chains: 2,
            seed: 11,
            budget: Budget::Steps(40),
            burn_in: 0,
            thin: 1,
            checkpoint_every: None,
            checkpoint_dir: None,
            resume: false,
            retries: 0,
            retry_backoff_ms: 0,
        }
    }

    #[test]
    fn instrumented_run_matches_bare_run_bit_for_bit() {
        let spec = tiny_spec();
        let bare = run_job(&spec, None).unwrap();
        let live = JobLive::new(spec.chains);
        let wired = run_job(&spec, Some(&live)).unwrap();
        assert_eq!(bare, wired, "instrumentation must not perturb the chains");
        // and the live series saw exactly the recorded draws
        let series = live.series_snapshot();
        assert_eq!(series.len(), 2);
        assert_eq!(series[0].len(), 40);
        assert_eq!(series[1].len(), 40);
        // board reached the budget
        let snap = live.board.snapshot();
        assert_eq!(snap.steps, vec![40, 40]);
    }

    #[test]
    fn spec_parsed_from_json_runs_end_to_end() {
        let spec = parse_spec(
            r#"{"model":{"kind":"linreg","n":128,"data_seed":1},
                "rule":{"kind":"austerity","eps":0.1,"batch":32},
                "chains":1,"seed":5,"budget":{"kind":"steps","steps":25}}"#,
        )
        .unwrap();
        let json = run_job(&spec, None).unwrap();
        assert!(json.contains("\"rule\":\"austerity\""), "{json}");
        assert!(json.contains("\"draws\":["), "{json}");
        // the report itself must reparse under the strict reader
        crate::server::json_in::parse(&json)
            .unwrap_or_else(|e| panic!("report JSON must satisfy the strict reader: {e}"));
    }

    #[test]
    fn pre_cancelled_job_returns_a_report_with_zero_steps() {
        let spec = tiny_spec();
        let live = JobLive::new(spec.chains);
        live.cancel.cancel();
        let json = run_job(&spec, Some(&live)).unwrap();
        assert!(json.contains("\"steps\":0"), "{json}");
        assert!(live.series_snapshot().iter().all(|s| s.is_empty()));
    }
}

//! Synthetic dataset generators — the data substitutions of DESIGN.md §4.
//!
//! Each generator replaces a dataset the paper used but which is not
//! available here (MNIST 7v9 PCA-50, 1.95M mixed audio, MiniBooNE) with a
//! synthetic equivalent that preserves the statistical structure the
//! experiment depends on: N, D, class overlap / source kurtosis / sparse
//! ground truth. All generators are deterministic given the seed.

use super::dataset::{Dataset, Unsupervised};
use super::linalg::{random_orthonormal, Mat};
use crate::stats::Pcg64;

/// Substitute for MNIST 7-vs-9 after PCA to 50 dims (paper §6.1):
/// two overlapping class-conditional Gaussians with anisotropic spectrum
/// (PCA-like decaying variances), N total points, labels +/- 1.
///
/// `sep` controls class overlap; 1.2 yields ~90% Bayes accuracy, similar
/// to a logistic fit on 7-vs-9 PCA features.
pub fn two_class_gaussian(n: usize, d: usize, sep: f64, seed: u64) -> Dataset {
    let mut rng = Pcg64::new(seed, 1);
    // PCA-like spectrum: std_j decays as 1/sqrt(1+j).
    let stds: Vec<f64> = (0..d).map(|j| 1.0 / (1.0 + j as f64).sqrt()).collect();
    // Class mean direction concentrated on the leading components.
    let dir: Vec<f64> = (0..d).map(|j| (-0.15 * j as f64).exp()).collect();
    let norm = dir.iter().map(|v| v * v).sum::<f64>().sqrt();

    let mut x = Vec::with_capacity(n * d);
    let mut y = Vec::with_capacity(n);
    for i in 0..n {
        let label = if i % 2 == 0 { 1.0 } else { -1.0 };
        for j in 0..d {
            let mean = label * sep * 0.5 * dir[j] / norm;
            x.push(mean + stds[j] * rng.normal());
        }
        y.push(label);
    }
    Dataset::new(x, y, n, d)
}

/// Source kinds for the ICA mixture (paper §6.2 substitution).
#[derive(Clone, Copy, Debug)]
enum Source {
    /// Laplacian marginal — stands in for the classical-music recording
    /// (speech/music amplitudes are famously super-Gaussian).
    Laplace,
    /// AR(1) with heavy-tailed innovations — street/traffic noise:
    /// temporally correlated with impulsive events.
    HeavyAr,
    /// Plain Gaussian source.
    Gauss,
}

/// ICA dataset: 4 sources (2 super-Gaussian, 2 Gaussian) mixed by a
/// random orthonormal matrix (pre-whitened convention). Returns the
/// observations and the true unmixing matrix `W0` (= A^T).
pub fn ica_mixture(n: usize, seed: u64) -> (Unsupervised, Mat) {
    let kinds = [Source::Laplace, Source::HeavyAr, Source::Gauss, Source::Gauss];
    let d = kinds.len();
    let mut rng = Pcg64::new(seed, 2);
    let mixing = random_orthonormal(d, &mut rng); // A (orthonormal)

    // Generate sources with unit variance.
    let mut s = vec![0.0f64; n * d];
    let mut ar_state;
    for (j, kind) in kinds.iter().enumerate() {
        match kind {
            Source::Laplace => {
                // Var of Laplace(b) is 2b^2; b = 1/sqrt(2) gives unit var.
                let b = std::f64::consts::FRAC_1_SQRT_2;
                for i in 0..n {
                    s[i * d + j] = rng.laplace(b);
                }
            }
            Source::HeavyAr => {
                let a = 0.7f64;
                let innov_scale = (1.0 - a * a).sqrt();
                ar_state = 0.0;
                for i in 0..n {
                    // Student-t-ish innovation: normal / sqrt(chi2-ish)
                    let u = rng.uniform_pos();
                    let heavy = rng.normal() / u.sqrt().max(0.25);
                    ar_state = a * ar_state + innov_scale * 0.55 * heavy;
                    s[i * d + j] = ar_state;
                }
                // normalize to ~unit variance empirically
                let var: f64 =
                    (0..n).map(|i| s[i * d + j] * s[i * d + j]).sum::<f64>() / n as f64;
                let scale = 1.0 / var.sqrt();
                for i in 0..n {
                    s[i * d + j] *= scale;
                }
            }
            Source::Gauss => {
                for i in 0..n {
                    s[i * d + j] = rng.normal();
                }
            }
        }
    }

    // x_i = A s_i
    let mut x = vec![0.0f64; n * d];
    let mut tmp_in = vec![0.0f64; d];
    let mut tmp_out = vec![0.0f64; d];
    for i in 0..n {
        tmp_in.copy_from_slice(&s[i * d..(i + 1) * d]);
        mixing.matvec(&tmp_in, &mut tmp_out);
        x[i * d..(i + 1) * d].copy_from_slice(&tmp_out);
    }

    let w0 = mixing.transpose(); // inverse of an orthonormal A
    (Unsupervised::new(x, n, d), w0)
}

/// The SGLD pitfall toy (paper §6.4): y = 0.5 x + xi, xi ~ N(0, 1/3),
/// N = 10000 by default, 1-d predictor x ~ N(0, 1).
pub fn linreg_toy(n: usize, seed: u64) -> Dataset {
    let mut rng = Pcg64::new(seed, 3);
    let noise_std = (1.0f64 / 3.0).sqrt();
    let mut x = Vec::with_capacity(n);
    let mut y = Vec::with_capacity(n);
    for _ in 0..n {
        let xi = rng.normal();
        x.push(xi);
        y.push(0.5 * xi + noise_std * rng.normal());
    }
    Dataset::new(x, y, n, 1)
}

/// MiniBooNE substitute (paper §6.3): n x d logistic data where only
/// `k_active` features carry signal (sparse ground truth) and the
/// intercept is tuned to give roughly `pos_rate` positives. Feature 0 is
/// the constant-1 column the paper appends.
pub fn sparse_logistic(
    n: usize,
    d: usize,
    k_active: usize,
    pos_rate: f64,
    seed: u64,
) -> (Dataset, Vec<f64>) {
    assert!(k_active < d);
    let mut rng = Pcg64::new(seed, 4);
    // True coefficients: first feature is the intercept column.
    let mut beta = vec![0.0f64; d];
    let mut active: Vec<usize> = (1..d).collect();
    rng.shuffle(&mut active);
    for &j in active.iter().take(k_active) {
        let mag = 0.4 + 0.6 * rng.uniform();
        beta[j] = if rng.uniform() < 0.5 { -mag } else { mag };
    }
    // Intercept tuned for the target positive rate under the random
    // feature logits S ~ N(0, sum beta^2): the logistic-normal mean
    // approximation E[sigmoid(b0 + S)] ~ sigmoid(b0 / sqrt(1 + pi s2/8))
    // inverts to b0 = logit(rate) * sqrt(1 + pi s2 / 8).
    let s2: f64 = beta[1..].iter().map(|b| b * b).sum();
    beta[0] = (pos_rate / (1.0 - pos_rate)).ln()
        * (1.0 + std::f64::consts::PI * s2 / 8.0).sqrt();

    let mut x = Vec::with_capacity(n * d);
    let mut y = Vec::with_capacity(n);
    for _ in 0..n {
        let mut logit = 0.0;
        for j in 0..d {
            let v = if j == 0 { 1.0 } else { rng.normal() };
            x.push(v);
            logit += beta[j] * v;
        }
        let p = 1.0 / (1.0 + (-logit).exp());
        y.push(if rng.uniform() < p { 1.0 } else { -1.0 });
    }
    (Dataset::new(x, y, n, d), beta)
}

/// Dense binary MRF with triple-clique potentials (paper supp. F.1):
/// D variables, all C(D,3) potentials, log psi ~ N(0, sigma^2).
/// Returned as the flattened log-potential tables; indexing lives in
/// `models::mrf`.
pub fn mrf_potentials(d: usize, sigma: f64, seed: u64) -> Vec<f64> {
    let n_triples = d * (d - 1) * (d - 2) / 6;
    let mut rng = Pcg64::new(seed, 5);
    let mut tables = Vec::with_capacity(n_triples * 8);
    for _ in 0..n_triples * 8 {
        tables.push(rng.normal_scaled(0.0, sigma));
    }
    tables
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stats::welford::Welford;

    #[test]
    fn two_class_shapes_and_balance() {
        let ds = two_class_gaussian(1000, 50, 1.2, 0);
        assert_eq!(ds.n(), 1000);
        assert_eq!(ds.d(), 50);
        let pos = ds.labels().iter().filter(|&&y| y > 0.0).count();
        assert_eq!(pos, 500);
    }

    #[test]
    fn two_class_is_separated_but_overlapping() {
        let ds = two_class_gaussian(4000, 10, 1.2, 1);
        // project on feature 0: class means differ, distributions overlap
        let mut pos = Welford::new();
        let mut neg = Welford::new();
        for i in 0..ds.n() {
            let v = ds.row(i)[0];
            if ds.label(i) > 0.0 {
                pos.add(v);
            } else {
                neg.add(v);
            }
        }
        assert!(pos.mean() > neg.mean() + 0.1);
        assert!(pos.mean() - neg.mean() < 4.0 * pos.std_sample());
    }

    #[test]
    fn deterministic_given_seed() {
        let a = two_class_gaussian(100, 5, 1.0, 7);
        let b = two_class_gaussian(100, 5, 1.0, 7);
        assert_eq!(a.features(), b.features());
        let c = two_class_gaussian(100, 5, 1.0, 8);
        assert_ne!(a.features(), c.features());
    }

    #[test]
    fn ica_sources_unmix_with_w0() {
        let n = 20_000;
        let (obs, w0) = ica_mixture(n, 3);
        // applying W0 to x recovers sources; check kurtosis signature:
        // component 0 (Laplace) has excess kurtosis ~3, Gaussians ~0.
        let d = obs.d();
        let mut y = vec![0.0; d];
        let mut m4 = vec![0.0f64; d];
        let mut m2 = vec![0.0f64; d];
        for i in 0..n {
            w0.matvec(obs.row(i), &mut y);
            for j in 0..d {
                m2[j] += y[j] * y[j];
                m4[j] += y[j].powi(4);
            }
        }
        let kurt: Vec<f64> = (0..d)
            .map(|j| (m4[j] / n as f64) / (m2[j] / n as f64).powi(2) - 3.0)
            .collect();
        assert!(kurt[0] > 1.5, "laplace kurtosis {kurt:?}");
        assert!(kurt[1] > 1.0, "heavy-AR kurtosis {kurt:?}");
        assert!(kurt[2].abs() < 0.5 && kurt[3].abs() < 0.5, "gauss {kurt:?}");
    }

    #[test]
    fn ica_observations_roughly_white() {
        let n = 30_000;
        let (obs, _) = ica_mixture(n, 4);
        let d = obs.d();
        // covariance ~ identity since A orthonormal, unit-var sources
        for a in 0..d {
            for b in a..d {
                let c: f64 = (0..n).map(|i| obs.row(i)[a] * obs.row(i)[b]).sum::<f64>()
                    / n as f64;
                let want = if a == b { 1.0 } else { 0.0 };
                assert!((c - want).abs() < 0.1, "cov[{a}{b}]={c}");
            }
        }
    }

    #[test]
    fn linreg_toy_slope_recoverable() {
        let ds = linreg_toy(10_000, 5);
        let sxy: f64 = (0..ds.n()).map(|i| ds.row(i)[0] * ds.label(i)).sum();
        let sxx: f64 = (0..ds.n()).map(|i| ds.row(i)[0] * ds.row(i)[0]).sum();
        let slope = sxy / sxx;
        assert!((slope - 0.5).abs() < 0.02, "slope={slope}");
    }

    #[test]
    fn sparse_logistic_rate_and_sparsity() {
        let (ds, beta) = sparse_logistic(20_000, 51, 12, 0.28, 6);
        let pos = ds.labels().iter().filter(|&&y| y > 0.0).count() as f64 / 20_000.0;
        assert!((pos - 0.28).abs() < 0.08, "pos rate {pos}");
        let active = beta[1..].iter().filter(|&&b| b != 0.0).count();
        assert_eq!(active, 12);
        // constant column
        for i in 0..100 {
            assert_eq!(ds.row(i)[0], 1.0);
        }
    }

    #[test]
    fn mrf_potentials_sized() {
        let d = 10;
        let t = mrf_potentials(d, 0.02, 9);
        assert_eq!(t.len(), d * (d - 1) * (d - 2) / 6 * 8);
        let var: f64 = t.iter().map(|v| v * v).sum::<f64>() / t.len() as f64;
        assert!((var - 0.02f64 * 0.02).abs() < 1e-4, "var={var}");
    }
}

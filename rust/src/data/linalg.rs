//! Small dense linear algebra for the ICA substrate (D is 4-8, so simple
//! O(D^3) routines are exactly right): matvec, matmul, QR-based random
//! orthonormal matrices, LU slogdet, skew-symmetric matrix exponential.

use crate::coordinator::checkpoint::{BinReader, BinWriter, CkptError, Persist};

/// Row-major dense square matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct Mat {
    pub d: usize,
    pub a: Vec<f64>,
}

impl Persist for Mat {
    fn persist(&self, w: &mut BinWriter) {
        w.put_usize(self.d);
        self.a.persist(w);
    }

    fn restore(r: &mut BinReader<'_>) -> Result<Self, CkptError> {
        let d = r.usize_()?;
        let a = Vec::<f64>::restore(r)?;
        if a.len() != d * d {
            return Err(CkptError::Corrupt("matrix payload is not d*d"));
        }
        Ok(Mat { d, a })
    }
}

impl Mat {
    pub fn zeros(d: usize) -> Self {
        Mat { d, a: vec![0.0; d * d] }
    }

    pub fn eye(d: usize) -> Self {
        let mut m = Mat::zeros(d);
        for i in 0..d {
            m[(i, i)] = 1.0;
        }
        m
    }

    pub fn from_rows(rows: &[&[f64]]) -> Self {
        let d = rows.len();
        let mut a = Vec::with_capacity(d * d);
        for r in rows {
            assert_eq!(r.len(), d);
            a.extend_from_slice(r);
        }
        Mat { d, a }
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        &self.a[i * self.d..(i + 1) * self.d]
    }

    pub fn transpose(&self) -> Mat {
        let d = self.d;
        let mut t = Mat::zeros(d);
        for i in 0..d {
            for j in 0..d {
                t[(j, i)] = self[(i, j)];
            }
        }
        t
    }

    pub fn matmul(&self, other: &Mat) -> Mat {
        let d = self.d;
        assert_eq!(d, other.d);
        let mut out = Mat::zeros(d);
        for i in 0..d {
            for k in 0..d {
                let aik = self[(i, k)];
                if aik == 0.0 {
                    continue;
                }
                for j in 0..d {
                    out[(i, j)] += aik * other[(k, j)];
                }
            }
        }
        out
    }

    /// y = A x.
    pub fn matvec(&self, x: &[f64], y: &mut [f64]) {
        let d = self.d;
        assert_eq!(x.len(), d);
        assert_eq!(y.len(), d);
        for i in 0..d {
            let mut s = 0.0;
            let row = self.row(i);
            for j in 0..d {
                s += row[j] * x[j];
            }
            y[i] = s;
        }
    }

    pub fn scale(&self, s: f64) -> Mat {
        Mat { d: self.d, a: self.a.iter().map(|v| v * s).collect() }
    }

    pub fn add(&self, other: &Mat) -> Mat {
        assert_eq!(self.d, other.d);
        Mat {
            d: self.d,
            a: self.a.iter().zip(&other.a).map(|(a, b)| a + b).collect(),
        }
    }

    pub fn frobenius_dist(&self, other: &Mat) -> f64 {
        self.a
            .iter()
            .zip(&other.a)
            .map(|(a, b)| (a - b) * (a - b))
            .sum::<f64>()
            .sqrt()
    }

    /// log|det A| and the sign of det via partial-pivot LU.
    pub fn slogdet(&self) -> (f64, f64) {
        let d = self.d;
        let mut lu = self.a.clone();
        let mut sign = 1.0f64;
        let mut logdet = 0.0f64;
        for col in 0..d {
            // pivot
            let mut p = col;
            let mut best = lu[col * d + col].abs();
            for r in col + 1..d {
                let v = lu[r * d + col].abs();
                if v > best {
                    best = v;
                    p = r;
                }
            }
            if best == 0.0 {
                return (-1.0, f64::NEG_INFINITY);
            }
            if p != col {
                for j in 0..d {
                    lu.swap(col * d + j, p * d + j);
                }
                sign = -sign;
            }
            let piv = lu[col * d + col];
            sign *= piv.signum();
            logdet += piv.abs().ln();
            for r in col + 1..d {
                let f = lu[r * d + col] / piv;
                lu[r * d + col] = f;
                for j in col + 1..d {
                    lu[r * d + j] -= f * lu[col * d + j];
                }
            }
        }
        (sign, logdet)
    }

    /// Matrix inverse via Gauss-Jordan (small D only).
    pub fn inverse(&self) -> Mat {
        let d = self.d;
        let mut aug = vec![0.0; d * 2 * d];
        for i in 0..d {
            for j in 0..d {
                aug[i * 2 * d + j] = self[(i, j)];
            }
            aug[i * 2 * d + d + i] = 1.0;
        }
        for col in 0..d {
            let mut p = col;
            let mut best = aug[col * 2 * d + col].abs();
            for r in col + 1..d {
                let v = aug[r * 2 * d + col].abs();
                if v > best {
                    best = v;
                    p = r;
                }
            }
            assert!(best > 1e-300, "singular matrix");
            if p != col {
                for j in 0..2 * d {
                    aug.swap(col * 2 * d + j, p * 2 * d + j);
                }
            }
            let piv = aug[col * 2 * d + col];
            for j in 0..2 * d {
                aug[col * 2 * d + j] /= piv;
            }
            for r in 0..d {
                if r == col {
                    continue;
                }
                let f = aug[r * 2 * d + col];
                if f == 0.0 {
                    continue;
                }
                for j in 0..2 * d {
                    aug[r * 2 * d + j] -= f * aug[col * 2 * d + j];
                }
            }
        }
        let mut inv = Mat::zeros(d);
        for i in 0..d {
            for j in 0..d {
                inv[(i, j)] = aug[i * 2 * d + d + j];
            }
        }
        inv
    }

    /// Matrix exponential via scaling-and-squaring + Taylor (small norms).
    pub fn expm(&self) -> Mat {
        let d = self.d;
        let norm: f64 = self.a.iter().map(|v| v.abs()).fold(0.0, f64::max) * d as f64;
        let squarings = norm.log2().ceil().max(0.0) as u32 + 1;
        let scaled = self.scale(1.0 / f64::powi(2.0, squarings as i32));
        // Taylor to order 12 on the scaled matrix.
        let mut result = Mat::eye(d);
        let mut term = Mat::eye(d);
        for k in 1..=12 {
            term = term.matmul(&scaled).scale(1.0 / k as f64);
            result = result.add(&term);
        }
        for _ in 0..squarings {
            result = result.matmul(&result);
        }
        result
    }

    /// Max |A A^T - I| entry: orthonormality defect.
    pub fn orthonormal_defect(&self) -> f64 {
        let g = self.matmul(&self.transpose());
        let mut worst = 0.0f64;
        for i in 0..self.d {
            for j in 0..self.d {
                let want = if i == j { 1.0 } else { 0.0 };
                worst = worst.max((g[(i, j)] - want).abs());
            }
        }
        worst
    }
}

impl std::ops::Index<(usize, usize)> for Mat {
    type Output = f64;
    #[inline]
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        &self.a[i * self.d + j]
    }
}

impl std::ops::IndexMut<(usize, usize)> for Mat {
    #[inline]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        &mut self.a[i * self.d + j]
    }
}

/// Random orthonormal matrix (Haar-ish via modified Gram-Schmidt on a
/// Gaussian matrix, with sign correction from the R diagonal).
pub fn random_orthonormal(d: usize, rng: &mut crate::stats::Pcg64) -> Mat {
    loop {
        let mut m = Mat::zeros(d);
        for v in m.a.iter_mut() {
            *v = rng.normal();
        }
        if let Some(q) = gram_schmidt(&m) {
            return q;
        }
    }
}

/// Modified Gram-Schmidt orthonormalization of rows; None if near-singular.
fn gram_schmidt(m: &Mat) -> Option<Mat> {
    let d = m.d;
    let mut q = m.clone();
    for i in 0..d {
        for j in 0..i {
            let dot: f64 = (0..d).map(|k| q[(i, k)] * q[(j, k)]).sum();
            for k in 0..d {
                let v = q[(j, k)];
                q[(i, k)] -= dot * v;
            }
        }
        let norm: f64 = (0..d).map(|k| q[(i, k)] * q[(i, k)]).sum::<f64>().sqrt();
        if norm < 1e-10 {
            return None;
        }
        for k in 0..d {
            q[(i, k)] /= norm;
        }
    }
    Some(q)
}

/// Random skew-symmetric matrix with N(0, sigma^2) upper-triangle entries.
pub fn random_skew(d: usize, sigma: f64, rng: &mut crate::stats::Pcg64) -> Mat {
    let mut k = Mat::zeros(d);
    for i in 0..d {
        for j in i + 1..d {
            let v = rng.normal_scaled(0.0, sigma);
            k[(i, j)] = v;
            k[(j, i)] = -v;
        }
    }
    k
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stats::Pcg64;
    use crate::testkit;

    #[test]
    fn matmul_identity() {
        let mut rng = Pcg64::seeded(0);
        let m = random_orthonormal(5, &mut rng);
        let i = Mat::eye(5);
        assert!(m.matmul(&i).frobenius_dist(&m) < 1e-12);
    }

    #[test]
    fn random_orthonormal_is_orthonormal() {
        testkit::forall(32, |rng| {
            let d = rng.below(7) + 2;
            let q = random_orthonormal(d, rng);
            assert!(q.orthonormal_defect() < 1e-10, "defect {}", q.orthonormal_defect());
            let (_, logdet) = q.slogdet();
            assert!(logdet.abs() < 1e-9, "logdet {logdet}");
        });
    }

    #[test]
    fn slogdet_known() {
        let m = Mat::from_rows(&[&[2.0, 0.0], &[0.0, 3.0]]);
        let (s, l) = m.slogdet();
        assert_eq!(s, 1.0);
        assert!((l - 6.0f64.ln()).abs() < 1e-12);
        let m = Mat::from_rows(&[&[0.0, 1.0], &[1.0, 0.0]]); // det = -1
        let (s, l) = m.slogdet();
        assert_eq!(s, -1.0);
        assert!(l.abs() < 1e-12);
    }

    #[test]
    fn inverse_round_trip() {
        testkit::forall(32, |rng| {
            let d = rng.below(5) + 2;
            let mut m = Mat::zeros(d);
            for v in m.a.iter_mut() {
                *v = rng.normal();
            }
            m = m.add(&Mat::eye(d).scale(3.0)); // keep well-conditioned
            let inv = m.inverse();
            let defect = m.matmul(&inv).frobenius_dist(&Mat::eye(d));
            assert!(defect < 1e-8, "defect {defect}");
        });
    }

    #[test]
    fn expm_skew_is_orthonormal() {
        testkit::forall(32, |rng| {
            let d = rng.below(6) + 2;
            let k = random_skew(d, 0.5, rng);
            let r = k.expm();
            assert!(r.orthonormal_defect() < 1e-9, "defect {}", r.orthonormal_defect());
        });
    }

    #[test]
    fn expm_matches_series_small() {
        // exp of 2x2 rotation generator: [[0,-t],[t,0]] -> rotation matrix
        let t = 0.7f64;
        let k = Mat::from_rows(&[&[0.0, -t], &[t, 0.0]]);
        let r = k.expm();
        assert!((r[(0, 0)] - t.cos()).abs() < 1e-12);
        assert!((r[(0, 1)] + t.sin()).abs() < 1e-12);
        assert!((r[(1, 0)] - t.sin()).abs() < 1e-12);
        assert!((r[(1, 1)] - t.cos()).abs() < 1e-12);
    }

    #[test]
    fn expm_inverse_is_negative_exponent() {
        let mut rng = Pcg64::seeded(5);
        let k = random_skew(4, 0.3, &mut rng);
        let a = k.expm();
        let b = k.scale(-1.0).expm();
        assert!(a.matmul(&b).frobenius_dist(&Mat::eye(4)) < 1e-10);
    }

    #[test]
    fn matvec_matches_matmul() {
        let mut rng = Pcg64::seeded(6);
        let m = random_orthonormal(4, &mut rng);
        let x = [1.0, -2.0, 0.5, 3.0];
        let mut y = [0.0; 4];
        m.matvec(&x, &mut y);
        for i in 0..4 {
            let want: f64 = (0..4).map(|j| m[(i, j)] * x[j]).sum();
            assert!((y[i] - want).abs() < 1e-14);
        }
    }
}

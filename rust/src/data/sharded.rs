//! Sharded columnar store: the out-of-core-ready data layer behind the
//! tall-data scan path.
//!
//! [`ShardedColumnar`] splits the row index space `[0, n)` into
//! [`SEGMENT_ALIGN`]-aligned segments, each held as an independent
//! [`Columnar`] block materialized through a [`SegmentSource`]. Because
//! every segment boundary is a multiple of `SEGMENT_ALIGN` — and the
//! scan drivers partition work into `FULL_SCAN_CHUNK = SEGMENT_ALIGN`
//! chunks reduced in chunk-index order — a full scan over the sharded
//! store decomposes into exactly the same lane blocks as over the
//! unsharded store, and is therefore bit-identical to it at any shard
//! count × thread count (DESIGN.md §2c).
//!
//! The `SegmentSource` indirection is what makes the store
//! out-of-core-ready: today the only source is the in-RAM row-major
//! [`Dataset`]; a memory-mapped or on-disk source only needs to produce
//! the same `Columnar` segments. Row indices *within* a segment stay
//! `u32` (the minibatch index type); the *global* row space is `usize`,
//! so a sharded store can in principle exceed the `u32` ceiling that a
//! single segment — and the global minibatch scheduler — must respect.
//! Every path that narrows a row count to `u32` validates first and
//! reports a typed [`DataTooLarge`] instead of truncating or aborting.

use std::fmt;

use crate::data::columnar::{Columnar, LANES};
use crate::data::dataset::Dataset;

/// Segment boundary quantum (rows). `models::traits::FULL_SCAN_CHUNK`
/// is defined in terms of this constant, and it is a multiple of
/// `LANES`, so chunk-aligned lane blocks never straddle a segment
/// boundary.
pub const SEGMENT_ALIGN: usize = 512;

const _: () = assert!(SEGMENT_ALIGN % LANES == 0);

/// A row-index space was asked to cover more rows than its `u32` index
/// type can address. Returned (never panicked) by every constructor on
/// the data-index path, so `Session::run()` surfaces it as a launch
/// error instead of a process abort.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct DataTooLarge {
    /// Which index space overflowed ("minibatch scheduler",
    /// "columnar segment", ...).
    pub what: &'static str,
    /// The offending row count.
    pub n: usize,
}

impl fmt::Display for DataTooLarge {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "{}: {} rows exceed the u32 index space (max {}); \
             shard the store to keep per-segment indices narrow",
            self.what,
            self.n,
            u32::MAX
        )
    }
}

impl std::error::Error for DataTooLarge {}

/// Validate that `n` rows fit the `u32` index space *before* anything
/// proportional to `n` is allocated, so the failure is a cheap typed
/// error rather than an OOM or a silent truncation.
pub fn check_u32_indexable(what: &'static str, n: usize) -> Result<(), DataTooLarge> {
    if n > u32::MAX as usize {
        Err(DataTooLarge { what, n })
    } else {
        Ok(())
    }
}

/// Where segments come from. `ShardedColumnar` never assumes the rows
/// live in RAM — it asks the source to materialize one aligned row
/// range at a time, which is the whole out-of-core contract.
pub trait SegmentSource {
    /// Total rows in the source.
    fn n(&self) -> usize;

    /// Features per row.
    fn d(&self) -> usize;

    /// Materialize rows `[start, end)` as one columnar segment.
    fn load_segment(&self, start: usize, end: usize) -> Result<Columnar, DataTooLarge>;
}

impl SegmentSource for Dataset {
    fn n(&self) -> usize {
        Dataset::n(self)
    }

    fn d(&self) -> usize {
        Dataset::d(self)
    }

    fn load_segment(&self, start: usize, end: usize) -> Result<Columnar, DataTooLarge> {
        Columnar::from_rows(self, start, end)
    }
}

/// Rows per segment for an `n`-row store split `shards` ways: the
/// smallest `SEGMENT_ALIGN`-aligned length that covers `n` in at most
/// `shards` segments. Every segment but the last has exactly this many
/// rows; the last may be short.
pub fn segment_rows(n: usize, shards: usize) -> usize {
    assert!(shards >= 1, "need at least one shard");
    n.div_ceil(SEGMENT_ALIGN).div_ceil(shards).max(1) * SEGMENT_ALIGN
}

/// Aligned row range `[start, end)` of segment `shard` of `shards` over
/// `n` rows — the layout `ShardedColumnar::from_source` realizes.
/// Trailing shards collapse to empty ranges when `n` has fewer than
/// `shards` alignment chunks.
pub fn shard_rows(n: usize, shard: usize, shards: usize) -> (usize, usize) {
    let rows = segment_rows(n, shards);
    let start = (shard * rows).min(n);
    (start, (start + rows).min(n))
}

/// Even (unaligned) row range of shard `shard` of `shards` — the split
/// the embarrassingly-parallel mode uses for its per-shard subset
/// posteriors, where balance matters and alignment does not (each shard
/// builds its own independently padded store). Never empty for
/// `shards <= n`.
pub fn even_rows(n: usize, shard: usize, shards: usize) -> (usize, usize) {
    assert!(shards >= 1 && shard < shards);
    (shard * n / shards, (shard + 1) * n / shards)
}

/// Feature-major store sharded into `SEGMENT_ALIGN`-aligned
/// [`Columnar`] segments.
///
/// The method surface mirrors `Columnar`'s lane-block kernels exactly,
/// with a single-segment fast path, so the models' moments kernels are
/// agnostic to the shard count. Aligned blocks (every block the scan
/// drivers produce) resolve to one segment and delegate; a block that
/// straddles a boundary — only reachable from unaligned ad-hoc ranges —
/// falls back to the routed per-row dots, which are bit-identical by
/// the columnar accumulation contract.
#[derive(Clone, Debug)]
pub struct ShardedColumnar {
    segments: Vec<Columnar>,
    /// Rows per segment (all but the last); multiple of `SEGMENT_ALIGN`.
    seg_rows: usize,
    n: usize,
    d: usize,
}

impl ShardedColumnar {
    /// Build the store from `src` in at most `shards` aligned segments.
    pub fn from_source<S: SegmentSource>(src: &S, shards: usize) -> Result<Self, DataTooLarge> {
        let (n, d) = (src.n(), src.d());
        assert!(n >= 1, "sharded store needs at least one row");
        let seg_rows = segment_rows(n, shards);
        let count = n.div_ceil(seg_rows);
        let mut segments = Vec::with_capacity(count);
        for s in 0..count {
            let start = s * seg_rows;
            let end = (start + seg_rows).min(n);
            segments.push(src.load_segment(start, end)?);
        }
        Ok(ShardedColumnar { segments, seg_rows, n, d })
    }

    /// `from_source` over an in-RAM row-major dataset.
    pub fn from_dataset(data: &Dataset, shards: usize) -> Result<Self, DataTooLarge> {
        Self::from_source(data, shards)
    }

    #[inline]
    pub fn n(&self) -> usize {
        self.n
    }

    #[inline]
    pub fn d(&self) -> usize {
        self.d
    }

    /// Number of segments actually realized (≤ the requested shard
    /// count when `n` has fewer alignment chunks).
    #[inline]
    pub fn shards(&self) -> usize {
        self.segments.len()
    }

    /// Global row range `[start, end)` held by segment `s`.
    pub fn shard_range(&self, s: usize) -> (usize, usize) {
        let start = s * self.seg_rows;
        (start, (start + self.seg_rows).min(self.n))
    }

    /// Segment `s` as a plain columnar block.
    #[inline]
    pub fn segment(&self, s: usize) -> &Columnar {
        &self.segments[s]
    }

    /// (segment, local row) of global row `i`.
    #[inline]
    fn locate(&self, i: usize) -> (usize, usize) {
        debug_assert!(i < self.n);
        (i / self.seg_rows, i % self.seg_rows)
    }

    #[inline]
    pub fn label(&self, i: usize) -> f64 {
        if self.segments.len() == 1 {
            return self.segments[0].label(i);
        }
        let (s, r) = self.locate(i);
        self.segments[s].label(r)
    }

    /// Feature value `(i, j)`.
    #[inline]
    pub fn value(&self, i: usize, j: usize) -> f64 {
        if self.segments.len() == 1 {
            return self.segments[0].value(i, j);
        }
        let (s, r) = self.locate(i);
        self.segments[s].value(r, j)
    }

    /// `d == 1` helper for the regression kernels: feature 0 and label
    /// of row `i` in one lookup.
    #[inline]
    pub fn xy1(&self, i: usize) -> (f64, f64) {
        if self.segments.len() == 1 {
            let seg = &self.segments[0];
            return (seg.value(i, 0), seg.label(i));
        }
        let (s, r) = self.locate(i);
        let seg = &self.segments[s];
        (seg.value(r, 0), seg.label(r))
    }

    /// Single-row dot product `x_i . t` (canonical accumulation order).
    #[inline]
    pub fn row_dot(&self, i: usize, t: &[f64]) -> f64 {
        if self.segments.len() == 1 {
            return self.segments[0].row_dot(i, t);
        }
        let (s, r) = self.locate(i);
        self.segments[s].row_dot(r, t)
    }

    /// Single-row dual dot product; each side bit-identical to
    /// `row_dot`.
    #[inline]
    pub fn row_dot2(&self, i: usize, a: &[f64], b: &[f64]) -> (f64, f64) {
        if self.segments.len() == 1 {
            return self.segments[0].row_dot2(i, a, b);
        }
        let (s, r) = self.locate(i);
        self.segments[s].row_dot2(r, a, b)
    }

    /// Dual dot products for `LANES` consecutive rows starting at
    /// `base` (the full-scan fast path).
    #[inline]
    pub fn block_dot2_seq(
        &self,
        base: usize,
        a: &[f64],
        b: &[f64],
        z0: &mut [f64; LANES],
        z1: &mut [f64; LANES],
    ) {
        if self.segments.len() == 1 {
            return self.segments[0].block_dot2_seq(base, a, b, z0, z1);
        }
        let (s, r) = self.locate(base);
        let seg = &self.segments[s];
        if r + LANES <= seg.padded_n() {
            return seg.block_dot2_seq(r, a, b, z0, z1);
        }
        // Unaligned block straddling a segment boundary (never produced
        // by the chunk-aligned scan drivers): routed per-row dots,
        // bit-identical by the columnar accumulation contract; rows in
        // the lane padding contribute exact zeros as in the unsharded
        // store.
        *z0 = [0.0; LANES];
        *z1 = [0.0; LANES];
        for k in 0..LANES {
            let i = base + k;
            if i < self.n {
                let (w0, w1) = self.row_dot2(i, a, b);
                z0[k] = w0;
                z1[k] = w1;
            }
        }
    }

    /// Dual dot products for the first `LANES` gathered rows of `idx`
    /// (the minibatch path). Global gather indices stay `u32` — the
    /// minibatch scheduler validates its population fits.
    #[inline]
    pub fn block_dot2_gather(
        &self,
        idx: &[u32],
        a: &[f64],
        b: &[f64],
        z0: &mut [f64; LANES],
        z1: &mut [f64; LANES],
    ) {
        if self.segments.len() == 1 {
            return self.segments[0].block_dot2_gather(idx, a, b, z0, z1);
        }
        debug_assert!(idx.len() >= LANES);
        if let Some((s, local)) = self.same_segment(idx) {
            return self.segments[s].block_dot2_gather(&local, a, b, z0, z1);
        }
        *z0 = [0.0; LANES];
        *z1 = [0.0; LANES];
        for k in 0..LANES {
            let (w0, w1) = self.row_dot2(idx[k] as usize, a, b);
            z0[k] = w0;
            z1[k] = w1;
        }
    }

    /// Single-parameter variant of `block_dot2_seq` (cached path:
    /// proposal side only).
    #[inline]
    pub fn block_dot_seq(&self, base: usize, t: &[f64], z: &mut [f64; LANES]) {
        if self.segments.len() == 1 {
            return self.segments[0].block_dot_seq(base, t, z);
        }
        let (s, r) = self.locate(base);
        let seg = &self.segments[s];
        if r + LANES <= seg.padded_n() {
            return seg.block_dot_seq(r, t, z);
        }
        *z = [0.0; LANES];
        for k in 0..LANES {
            let i = base + k;
            if i < self.n {
                z[k] = self.row_dot(i, t);
            }
        }
    }

    /// Single-parameter variant of `block_dot2_gather`.
    #[inline]
    pub fn block_dot_gather(&self, idx: &[u32], t: &[f64], z: &mut [f64; LANES]) {
        if self.segments.len() == 1 {
            return self.segments[0].block_dot_gather(idx, t, z);
        }
        debug_assert!(idx.len() >= LANES);
        if let Some((s, local)) = self.same_segment(idx) {
            return self.segments[s].block_dot_gather(&local, t, z);
        }
        *z = [0.0; LANES];
        for k in 0..LANES {
            z[k] = self.row_dot(idx[k] as usize, t);
        }
    }

    /// If the first `LANES` indices all land in one segment, translate
    /// them to that segment's local index space.
    #[inline]
    fn same_segment(&self, idx: &[u32]) -> Option<(usize, [u32; LANES])> {
        let s = idx[0] as usize / self.seg_rows;
        let base = s * self.seg_rows;
        let mut local = [0u32; LANES];
        for k in 0..LANES {
            let i = idx[k] as usize;
            if i / self.seg_rows != s {
                return None;
            }
            local[k] = (i - base) as u32;
        }
        Some((s, local))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stats::Pcg64;

    fn random_dataset(n: usize, d: usize, seed: u64) -> Dataset {
        let mut rng = Pcg64::seeded(seed);
        let x: Vec<f64> = (0..n * d).map(|_| rng.normal()).collect();
        let y: Vec<f64> = (0..n).map(|_| if rng.uniform() < 0.5 { -1.0 } else { 1.0 }).collect();
        Dataset::new(x, y, n, d)
    }

    #[test]
    fn layout_is_aligned_covering_and_ordered() {
        for (n, shards) in [(1usize, 1usize), (512, 1), (513, 2), (4096, 8), (5 * 512 + 123, 4)] {
            let rows = segment_rows(n, shards);
            assert_eq!(rows % SEGMENT_ALIGN, 0);
            let mut covered = 0;
            for s in 0..shards {
                let (a, b) = shard_rows(n, s, shards);
                assert_eq!(a, covered.min(n));
                assert!(a == b || a % SEGMENT_ALIGN == 0);
                covered = b;
            }
            assert_eq!(covered, n, "n={n} shards={shards}");
        }
    }

    #[test]
    fn even_rows_partition_without_empties() {
        for (n, shards) in [(10usize, 3usize), (1000, 8), (7, 7)] {
            let mut covered = 0;
            for s in 0..shards {
                let (a, b) = even_rows(n, s, shards);
                assert_eq!(a, covered);
                assert!(b > a, "empty shard {s} for n={n} k={shards}");
                covered = b;
            }
            assert_eq!(covered, n);
        }
    }

    #[test]
    fn check_u32_indexable_is_typed_and_cheap() {
        assert!(check_u32_indexable("x", u32::MAX as usize).is_ok());
        let err = check_u32_indexable("scheduler", u32::MAX as usize + 1).unwrap_err();
        assert_eq!(err.what, "scheduler");
        assert_eq!(err.n, u32::MAX as usize + 1);
        let msg = err.to_string();
        assert!(msg.contains("u32 index space"), "msg: {msg}");
    }

    #[test]
    fn sharded_accessors_match_unsharded_bits() {
        let data = random_dataset(3 * SEGMENT_ALIGN + 77, 5, 9);
        let solo = Columnar::from_dataset(&data).unwrap();
        let mut rng = Pcg64::seeded(10);
        let a: Vec<f64> = (0..5).map(|_| rng.normal()).collect();
        let b: Vec<f64> = (0..5).map(|_| rng.normal()).collect();
        for shards in [1usize, 2, 3, 8] {
            let sc = ShardedColumnar::from_dataset(&data, shards).unwrap();
            assert_eq!(sc.n(), data.n());
            assert!(sc.shards() <= shards.max(1));
            for i in [0usize, 511, 512, 513, 1024, sc.n() - 1] {
                assert_eq!(sc.label(i).to_bits(), solo.label(i).to_bits());
                assert_eq!(sc.value(i, 3).to_bits(), solo.value(i, 3).to_bits());
                assert_eq!(sc.row_dot(i, &a).to_bits(), solo.row_dot(i, &a).to_bits());
                let (s0, s1) = sc.row_dot2(i, &a, &b);
                let (w0, w1) = solo.row_dot2(i, &a, &b);
                assert_eq!(s0.to_bits(), w0.to_bits());
                assert_eq!(s1.to_bits(), w1.to_bits());
            }
        }
    }

    #[test]
    fn seq_blocks_match_including_boundary_straddlers() {
        let data = random_dataset(2 * SEGMENT_ALIGN + 40, 4, 11);
        let solo = Columnar::from_dataset(&data).unwrap();
        let sc = ShardedColumnar::from_dataset(&data, 2).unwrap();
        assert_eq!(sc.shards(), 2);
        let mut rng = Pcg64::seeded(12);
        let a: Vec<f64> = (0..4).map(|_| rng.normal()).collect();
        let b: Vec<f64> = (0..4).map(|_| rng.normal()).collect();
        // aligned bases (scan path), the boundary straddler 509, and the
        // very last full block
        for base in [0usize, 504, 509, 512, 1016, 1024, 2 * SEGMENT_ALIGN + 32] {
            let (mut z0, mut z1) = ([0.0; LANES], [0.0; LANES]);
            let (mut w0, mut w1) = ([0.0; LANES], [0.0; LANES]);
            sc.block_dot2_seq(base, &a, &b, &mut z0, &mut z1);
            solo.block_dot2_seq(base, &a, &b, &mut w0, &mut w1);
            assert_eq!(z0.map(f64::to_bits), w0.map(f64::to_bits), "base {base}");
            assert_eq!(z1.map(f64::to_bits), w1.map(f64::to_bits), "base {base}");
            let mut zs = [0.0; LANES];
            let mut ws = [0.0; LANES];
            sc.block_dot_seq(base, &b, &mut zs);
            solo.block_dot_seq(base, &b, &mut ws);
            assert_eq!(zs.map(f64::to_bits), ws.map(f64::to_bits), "base {base}");
        }
    }

    #[test]
    fn gathered_blocks_match_within_and_across_segments() {
        let data = random_dataset(2 * SEGMENT_ALIGN + 16, 6, 13);
        let solo = Columnar::from_dataset(&data).unwrap();
        let sc = ShardedColumnar::from_dataset(&data, 4).unwrap();
        let mut rng = Pcg64::seeded(14);
        let a: Vec<f64> = (0..6).map(|_| rng.normal()).collect();
        let b: Vec<f64> = (0..6).map(|_| rng.normal()).collect();
        let within: Vec<u32> = vec![3, 100, 511, 8, 42, 7, 250, 0];
        let across: Vec<u32> = vec![3, 600, 511, 1025, 42, 512, 250, 1039];
        for idx in [&within, &across] {
            let (mut z0, mut z1) = ([0.0; LANES], [0.0; LANES]);
            let (mut w0, mut w1) = ([0.0; LANES], [0.0; LANES]);
            sc.block_dot2_gather(idx, &a, &b, &mut z0, &mut z1);
            solo.block_dot2_gather(idx, &a, &b, &mut w0, &mut w1);
            assert_eq!(z0.map(f64::to_bits), w0.map(f64::to_bits));
            assert_eq!(z1.map(f64::to_bits), w1.map(f64::to_bits));
            let mut zs = [0.0; LANES];
            let mut ws = [0.0; LANES];
            sc.block_dot_gather(idx, &b, &mut zs);
            solo.block_dot_gather(idx, &b, &mut ws);
            assert_eq!(zs.map(f64::to_bits), ws.map(f64::to_bits));
        }
    }

    #[test]
    fn shard_ranges_tile_the_store() {
        let data = random_dataset(5 * SEGMENT_ALIGN + 123, 2, 15);
        let sc = ShardedColumnar::from_dataset(&data, 4).unwrap();
        let mut covered = 0;
        for s in 0..sc.shards() {
            let (a, b) = sc.shard_range(s);
            assert_eq!(a, covered);
            assert_eq!(sc.segment(s).n(), b - a);
            covered = b;
        }
        assert_eq!(covered, sc.n());
    }
}

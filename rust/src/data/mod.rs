//! Datasets and the small linear-algebra kit the models sit on.

pub mod columnar;
pub mod dataset;
pub mod linalg;
pub mod sharded;
pub mod synthetic;

pub use columnar::{Columnar, LANES};
pub use dataset::{Dataset, Unsupervised};
pub use linalg::Mat;
pub use sharded::{DataTooLarge, SegmentSource, ShardedColumnar, SEGMENT_ALIGN};

//! Datasets and the small linear-algebra kit the models sit on.

pub mod dataset;
pub mod linalg;
pub mod synthetic;

pub use dataset::{Dataset, Unsupervised};
pub use linalg::Mat;

//! Feature-major (structure-of-arrays) dataset storage and the
//! lane-blocked dot-product building blocks of the likelihood hot path.
//!
//! The row-major `Dataset` keeps datapoint `i` as `d` consecutive
//! doubles; computing `x_i . theta` there is a per-row dot product that
//! ends in a horizontal reduction, and a full-population scan walks N
//! such reductions. `Columnar` transposes the storage: feature `j` is
//! one contiguous, lane-padded column, so a scan processes `LANES`
//! *rows at a time* — each lane owns an independent accumulator chain,
//! the inner loop is a pure mul-add per lane the compiler can keep in
//! vector registers, and sequential chunks (the exact-rule scan) read
//! every column at unit stride.
//!
//! **Bit-reproducibility contract.** Every helper here accumulates a
//! row's dot product the same way: `z = 0; for j in 0..d { z += x[i][j]
//! * t[j] }` — one scalar FP addition chain per (row, parameter) pair in
//! feature order. The sequential-block, gathered-block and single-row
//! variants therefore return *identical bits* for the same row, which is
//! what lets the fused uncached kernel, the cached proposal-side kernel
//! and the stale-entry recompute path share one numerical definition
//! (see DESIGN.md §Data layout). Lane blocking only changes how the
//! *population* sums `sum l` / `sum l^2` are associated, never a row's
//! `z`.

use crate::data::sharded::{check_u32_indexable, DataTooLarge};
use crate::data::Dataset;

/// Rows per lane block. Eight f64 lanes = two AVX2 / one AVX-512 vector
/// per accumulator array; also the padding quantum of every column.
pub const LANES: usize = 8;

/// Feature-major dataset: `d` columns of `padded_n` doubles each
/// (`n` real values, zero-padded up to the lane quantum), labels packed
/// separately. Built once from the row-major `Dataset`; the models keep
/// both views (row-major for gradients/predictions, columnar for the
/// moments hot path).
#[derive(Clone, Debug)]
pub struct Columnar {
    /// `d * padded_n` doubles; column `j` occupies
    /// `[j * padded_n, (j + 1) * padded_n)`.
    cols: Vec<f64>,
    /// Labels (classification: ±1; regression: targets), length `n`.
    y: Vec<f64>,
    n: usize,
    d: usize,
    padded_n: usize,
}

impl Columnar {
    /// Transpose a row-major dataset into lane-padded columns. Errors
    /// (never panics) when the row count exceeds the `u32` index space,
    /// so model constructors surface it as a launch failure.
    pub fn from_dataset(data: &Dataset) -> Result<Self, DataTooLarge> {
        Self::from_rows(data, 0, data.n())
    }

    /// Transpose rows `[start, end)` into lane-padded columns — one
    /// segment of a sharded store. Validates the segment's row count
    /// against the `u32` index space *before* allocating.
    pub fn from_rows(data: &Dataset, start: usize, end: usize) -> Result<Self, DataTooLarge> {
        assert!(start <= end && end <= data.n(), "segment range out of bounds");
        let n = end - start;
        check_u32_indexable("columnar segment", n)?;
        let d = data.d();
        let padded_n = n.div_ceil(LANES) * LANES;
        let mut cols = vec![0.0; d * padded_n];
        for i in 0..n {
            let row = data.row(start + i);
            for j in 0..d {
                cols[j * padded_n + i] = row[j];
            }
        }
        Ok(Columnar { cols, y: data.labels()[start..end].to_vec(), n, d, padded_n })
    }

    #[inline]
    pub fn n(&self) -> usize {
        self.n
    }

    #[inline]
    pub fn d(&self) -> usize {
        self.d
    }

    /// Column length including lane padding.
    #[inline]
    pub fn padded_n(&self) -> usize {
        self.padded_n
    }

    /// Feature column `j` (padded to `padded_n`).
    #[inline]
    pub fn col(&self, j: usize) -> &[f64] {
        &self.cols[j * self.padded_n..(j + 1) * self.padded_n]
    }

    #[inline]
    pub fn label(&self, i: usize) -> f64 {
        self.y[i]
    }

    #[inline]
    pub fn labels(&self) -> &[f64] {
        &self.y
    }

    /// Feature value `(i, j)`.
    #[inline]
    pub fn value(&self, i: usize, j: usize) -> f64 {
        self.cols[j * self.padded_n + i]
    }

    /// Single-row dot product `x_i . t` (sequential over features — the
    /// canonical accumulation order every block variant reproduces).
    #[inline]
    pub fn row_dot(&self, i: usize, t: &[f64]) -> f64 {
        let pn = self.padded_n;
        let mut z = 0.0;
        for (j, &tj) in t.iter().enumerate() {
            z += self.cols[j * pn + i] * tj;
        }
        z
    }

    /// Single-row dual dot product against two parameter vectors in one
    /// data pass; each side bit-identical to `row_dot`.
    #[inline]
    pub fn row_dot2(&self, i: usize, a: &[f64], b: &[f64]) -> (f64, f64) {
        let pn = self.padded_n;
        let (mut z0, mut z1) = (0.0, 0.0);
        for (j, (&ta, &tb)) in a.iter().zip(b).enumerate() {
            let x = self.cols[j * pn + i];
            z0 += x * ta;
            z1 += x * tb;
        }
        (z0, z1)
    }

    /// Dual dot products for `LANES` consecutive rows starting at
    /// `base`: contiguous column loads, one independent accumulator
    /// chain per lane (the full-scan fast path).
    #[inline]
    pub fn block_dot2_seq(
        &self,
        base: usize,
        a: &[f64],
        b: &[f64],
        z0: &mut [f64; LANES],
        z1: &mut [f64; LANES],
    ) {
        debug_assert!(base + LANES <= self.padded_n);
        *z0 = [0.0; LANES];
        *z1 = [0.0; LANES];
        let pn = self.padded_n;
        for (j, (&ta, &tb)) in a.iter().zip(b).enumerate() {
            let col = &self.cols[j * pn + base..j * pn + base + LANES];
            for k in 0..LANES {
                z0[k] += col[k] * ta;
                z1[k] += col[k] * tb;
            }
        }
    }

    /// Dual dot products for the first `LANES` gathered rows of `idx`
    /// (the minibatch path); per-row bits identical to `block_dot2_seq`.
    #[inline]
    pub fn block_dot2_gather(
        &self,
        idx: &[u32],
        a: &[f64],
        b: &[f64],
        z0: &mut [f64; LANES],
        z1: &mut [f64; LANES],
    ) {
        debug_assert!(idx.len() >= LANES);
        *z0 = [0.0; LANES];
        *z1 = [0.0; LANES];
        let pn = self.padded_n;
        for (j, (&ta, &tb)) in a.iter().zip(b).enumerate() {
            let col = &self.cols[j * pn..(j + 1) * pn];
            for k in 0..LANES {
                let x = col[idx[k] as usize];
                z0[k] += x * ta;
                z1[k] += x * tb;
            }
        }
    }

    /// Single-parameter variant of `block_dot2_seq` (cached path:
    /// proposal side only).
    #[inline]
    pub fn block_dot_seq(&self, base: usize, t: &[f64], z: &mut [f64; LANES]) {
        debug_assert!(base + LANES <= self.padded_n);
        *z = [0.0; LANES];
        let pn = self.padded_n;
        for (j, &tj) in t.iter().enumerate() {
            let col = &self.cols[j * pn + base..j * pn + base + LANES];
            for k in 0..LANES {
                z[k] += col[k] * tj;
            }
        }
    }

    /// Single-parameter variant of `block_dot2_gather`.
    #[inline]
    pub fn block_dot_gather(&self, idx: &[u32], t: &[f64], z: &mut [f64; LANES]) {
        debug_assert!(idx.len() >= LANES);
        *z = [0.0; LANES];
        let pn = self.padded_n;
        for (j, &tj) in t.iter().enumerate() {
            let col = &self.cols[j * pn..(j + 1) * pn];
            for k in 0..LANES {
                z[k] += col[idx[k] as usize] * tj;
            }
        }
    }
}

/// Fixed-order reduction of one lane-accumulator array:
/// `((a0+a1)+(a2+a3)) + ((a4+a5)+(a6+a7))`. Every kernel that blocks
/// its population sums over `LANES` lanes must fold them through this
/// one function so cached/uncached and serial/parallel paths associate
/// identically.
#[inline]
pub fn reduce_lanes(acc: &[f64; LANES]) -> f64 {
    ((acc[0] + acc[1]) + (acc[2] + acc[3])) + ((acc[4] + acc[5]) + (acc[6] + acc[7]))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stats::Pcg64;

    fn random_dataset(n: usize, d: usize, seed: u64) -> Dataset {
        let mut rng = Pcg64::seeded(seed);
        let x: Vec<f64> = (0..n * d).map(|_| rng.normal()).collect();
        let y: Vec<f64> = (0..n).map(|_| if rng.uniform() < 0.5 { -1.0 } else { 1.0 }).collect();
        Dataset::new(x, y, n, d)
    }

    #[test]
    fn transpose_round_trips_values_and_pads_with_zeros() {
        let data = random_dataset(13, 5, 0);
        let cols = Columnar::from_dataset(&data).unwrap();
        assert_eq!(cols.n(), 13);
        assert_eq!(cols.d(), 5);
        assert_eq!(cols.padded_n(), 16);
        for i in 0..13 {
            let row = data.row(i);
            for j in 0..5 {
                assert_eq!(cols.value(i, j).to_bits(), row[j].to_bits());
            }
            assert_eq!(cols.label(i), data.label(i));
        }
        for j in 0..5 {
            assert_eq!(&cols.col(j)[13..], &[0.0; 3]);
        }
    }

    #[test]
    fn row_dot_matches_reference_sum() {
        let data = random_dataset(40, 7, 1);
        let cols = Columnar::from_dataset(&data).unwrap();
        let mut rng = Pcg64::seeded(2);
        let t: Vec<f64> = (0..7).map(|_| rng.normal()).collect();
        for i in [0usize, 17, 39] {
            let mut want = 0.0;
            for j in 0..7 {
                want += data.row(i)[j] * t[j];
            }
            assert_eq!(cols.row_dot(i, &t).to_bits(), want.to_bits());
        }
    }

    #[test]
    fn block_variants_are_bit_identical_to_row_dots() {
        let data = random_dataset(64, 11, 3);
        let cols = Columnar::from_dataset(&data).unwrap();
        let mut rng = Pcg64::seeded(4);
        let a: Vec<f64> = (0..11).map(|_| rng.normal()).collect();
        let b: Vec<f64> = (0..11).map(|_| rng.normal()).collect();
        let (mut z0, mut z1) = ([0.0; LANES], [0.0; LANES]);

        // sequential block
        cols.block_dot2_seq(16, &a, &b, &mut z0, &mut z1);
        for k in 0..LANES {
            let (w0, w1) = cols.row_dot2(16 + k, &a, &b);
            assert_eq!(z0[k].to_bits(), w0.to_bits());
            assert_eq!(z1[k].to_bits(), w1.to_bits());
            assert_eq!(w0.to_bits(), cols.row_dot(16 + k, &a).to_bits());
        }

        // gathered block over the same rows must match the sequential one
        let idx: Vec<u32> = (16u32..24).collect();
        let (mut g0, mut g1) = ([0.0; LANES], [0.0; LANES]);
        cols.block_dot2_gather(&idx, &a, &b, &mut g0, &mut g1);
        assert_eq!(z0.map(f64::to_bits), g0.map(f64::to_bits));
        assert_eq!(z1.map(f64::to_bits), g1.map(f64::to_bits));

        // scattered gather agrees with per-row dots
        let scat: Vec<u32> = vec![5, 63, 0, 31, 8, 41, 2, 57];
        cols.block_dot2_gather(&scat, &a, &b, &mut g0, &mut g1);
        let mut s = [0.0; LANES];
        cols.block_dot_gather(&scat, &b, &mut s);
        for k in 0..LANES {
            let (w0, w1) = cols.row_dot2(scat[k] as usize, &a, &b);
            assert_eq!(g0[k].to_bits(), w0.to_bits());
            assert_eq!(g1[k].to_bits(), w1.to_bits());
            assert_eq!(s[k].to_bits(), w1.to_bits());
        }

        let mut sq = [0.0; LANES];
        cols.block_dot_seq(16, &b, &mut sq);
        assert_eq!(sq.map(f64::to_bits), z1.map(f64::to_bits));
    }

    #[test]
    fn from_rows_extracts_a_padded_segment() {
        let data = random_dataset(21, 3, 5);
        let seg = Columnar::from_rows(&data, 8, 19).unwrap();
        assert_eq!(seg.n(), 11);
        assert_eq!(seg.padded_n(), 16);
        for i in 0..11 {
            let row = data.row(8 + i);
            for j in 0..3 {
                assert_eq!(seg.value(i, j).to_bits(), row[j].to_bits());
            }
            assert_eq!(seg.label(i), data.label(8 + i));
        }
        for j in 0..3 {
            assert_eq!(&seg.col(j)[11..], &[0.0; 5]);
        }
    }

    #[test]
    fn reduce_lanes_is_the_documented_tree() {
        let acc = [1.0, 2.0, 4.0, 8.0, 16.0, 32.0, 64.0, 128.0];
        assert_eq!(reduce_lanes(&acc), 255.0);
        let acc = [0.1, 0.2, 0.3, 0.4, 0.5, 0.6, 0.7, 0.8];
        let want = ((0.1 + 0.2) + (0.3 + 0.4)) + ((0.5 + 0.6) + (0.7 + 0.8));
        assert_eq!(reduce_lanes(&acc).to_bits(), want.to_bits());
    }
}

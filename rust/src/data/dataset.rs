//! Dense row-major dataset containers shared by all models.

/// Supervised dataset: features `x` (n x d, row-major) and labels `y`.
/// For classification models labels are +/- 1.0; for regression they are
/// real-valued targets.
#[derive(Clone, Debug)]
pub struct Dataset {
    x: Vec<f64>,
    y: Vec<f64>,
    n: usize,
    d: usize,
}

impl Dataset {
    pub fn new(x: Vec<f64>, y: Vec<f64>, n: usize, d: usize) -> Self {
        assert_eq!(x.len(), n * d, "feature matrix shape mismatch");
        assert_eq!(y.len(), n, "label length mismatch");
        Dataset { x, y, n, d }
    }

    #[inline]
    pub fn n(&self) -> usize {
        self.n
    }

    #[inline]
    pub fn d(&self) -> usize {
        self.d
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        &self.x[i * self.d..(i + 1) * self.d]
    }

    #[inline]
    pub fn label(&self, i: usize) -> f64 {
        self.y[i]
    }

    pub fn features(&self) -> &[f64] {
        &self.x
    }

    pub fn labels(&self) -> &[f64] {
        &self.y
    }

    /// Deterministic split into (train, test) by a shuffled index set.
    pub fn split(&self, train_frac: f64, rng: &mut crate::stats::Pcg64) -> (Dataset, Dataset) {
        assert!((0.0..=1.0).contains(&train_frac));
        let mut idx: Vec<usize> = (0..self.n).collect();
        rng.shuffle(&mut idx);
        let n_train = ((self.n as f64) * train_frac).round() as usize;
        let take = |ids: &[usize]| {
            let mut x = Vec::with_capacity(ids.len() * self.d);
            let mut y = Vec::with_capacity(ids.len());
            for &i in ids {
                x.extend_from_slice(self.row(i));
                y.push(self.y[i]);
            }
            Dataset::new(x, y, ids.len(), self.d)
        };
        (take(&idx[..n_train]), take(&idx[n_train..]))
    }

    /// Copy the contiguous rows `[start, end)` into a standalone
    /// dataset — the per-shard split of the embarrassingly-parallel
    /// mode.
    pub fn slice_rows(&self, start: usize, end: usize) -> Dataset {
        assert!(start <= end && end <= self.n, "row range out of bounds");
        Dataset::new(
            self.x[start * self.d..end * self.d].to_vec(),
            self.y[start..end].to_vec(),
            end - start,
            self.d,
        )
    }

    /// Subset by explicit row indices.
    pub fn subset(&self, ids: &[usize]) -> Dataset {
        let mut x = Vec::with_capacity(ids.len() * self.d);
        let mut y = Vec::with_capacity(ids.len());
        for &i in ids {
            x.extend_from_slice(self.row(i));
            y.push(self.y[i]);
        }
        Dataset::new(x, y, ids.len(), self.d)
    }
}

/// Unsupervised dataset (ICA): observations only.
#[derive(Clone, Debug)]
pub struct Unsupervised {
    x: Vec<f64>,
    n: usize,
    d: usize,
}

impl Unsupervised {
    pub fn new(x: Vec<f64>, n: usize, d: usize) -> Self {
        assert_eq!(x.len(), n * d);
        Unsupervised { x, n, d }
    }

    #[inline]
    pub fn n(&self) -> usize {
        self.n
    }

    #[inline]
    pub fn d(&self) -> usize {
        self.d
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        &self.x[i * self.d..(i + 1) * self.d]
    }

    pub fn features(&self) -> &[f64] {
        &self.x
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stats::Pcg64;

    fn toy() -> Dataset {
        Dataset::new(vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0], vec![1.0, -1.0, 1.0], 3, 2)
    }

    #[test]
    fn rows_and_labels() {
        let d = toy();
        assert_eq!(d.row(0), &[1.0, 2.0]);
        assert_eq!(d.row(2), &[5.0, 6.0]);
        assert_eq!(d.label(1), -1.0);
    }

    #[test]
    #[should_panic(expected = "shape mismatch")]
    fn shape_mismatch_panics() {
        Dataset::new(vec![1.0; 5], vec![0.0; 2], 2, 2);
    }

    #[test]
    fn split_partitions_everything() {
        let mut rng = Pcg64::seeded(0);
        let n = 100;
        let x: Vec<f64> = (0..n * 3).map(|i| i as f64).collect();
        let y: Vec<f64> = (0..n).map(|i| i as f64).collect();
        let d = Dataset::new(x, y, n, 3);
        let (tr, te) = d.split(0.8, &mut rng);
        assert_eq!(tr.n() + te.n(), n);
        assert_eq!(tr.n(), 80);
        // every original label appears exactly once across the split
        let mut seen: Vec<f64> = tr.labels().iter().chain(te.labels()).copied().collect();
        seen.sort_by(|a, b| a.partial_cmp(b).unwrap());
        assert_eq!(seen, (0..n).map(|i| i as f64).collect::<Vec<_>>());
    }

    #[test]
    fn slice_rows_copies_a_contiguous_range() {
        let d = toy();
        let s = d.slice_rows(1, 3);
        assert_eq!(s.n(), 2);
        assert_eq!(s.row(0), &[3.0, 4.0]);
        assert_eq!(s.row(1), &[5.0, 6.0]);
        assert_eq!(s.labels(), &[-1.0, 1.0]);
        assert_eq!(d.slice_rows(2, 2).n(), 0);
    }

    #[test]
    fn subset_picks_rows() {
        let d = toy();
        let s = d.subset(&[2, 0]);
        assert_eq!(s.row(0), &[5.0, 6.0]);
        assert_eq!(s.row(1), &[1.0, 2.0]);
        assert_eq!(s.labels(), &[1.0, 1.0]);
    }
}

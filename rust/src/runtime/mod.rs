//! AOT artifact execution through the PJRT C API: manifest parsing,
//! executable cache, and `LlDiffModel` backends that serve moments from
//! the compiled Pallas kernels. Python never runs here — artifacts are
//! loaded from `artifacts/*.hlo.txt`.
//!
//! The real runtime needs the `xla` (PJRT bindings) and `anyhow` crates,
//! which only exist in the internal artifact environment; it is compiled
//! under the `pjrt` feature, and enabling that feature also requires
//! declaring those two crates in Cargo.toml (see the note there).
//! Without the feature a stub with the same API is built:
//! `PjrtRuntime::available()` is false and `new` always errors, so
//! callers gate on availability before touching artifacts.

pub mod manifest;

#[cfg(feature = "pjrt")]
pub mod backend;
#[cfg(feature = "pjrt")]
pub mod pjrt;
#[cfg(not(feature = "pjrt"))]
pub mod stub;

pub use manifest::{load_manifest, parse_manifest, ArtifactSpec, TensorSpec};

#[cfg(feature = "pjrt")]
pub use backend::{PjrtIca, PjrtLogistic, PjrtPredictor};
#[cfg(feature = "pjrt")]
pub use pjrt::PjrtRuntime;
#[cfg(not(feature = "pjrt"))]
pub use stub::{PjrtIca, PjrtLogistic, PjrtPredictor, PjrtRuntime};

/// Error type of the dependency-free runtime surface (manifest parsing
/// and the stub). Implements `std::error::Error`, so it converts into
/// `anyhow::Error` transparently when the real runtime is compiled.
#[derive(Clone, Debug)]
pub struct RuntimeError(String);

impl RuntimeError {
    pub fn new(msg: impl Into<String>) -> Self {
        RuntimeError(msg.into())
    }
}

impl std::fmt::Display for RuntimeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for RuntimeError {}

//! AOT artifact execution through the PJRT C API (the `xla` crate):
//! manifest parsing, executable cache, and `LlDiffModel` backends that
//! serve moments from the compiled Pallas kernels. Python never runs
//! here — artifacts are loaded from `artifacts/*.hlo.txt`.

pub mod backend;
pub mod manifest;
pub mod pjrt;

pub use backend::{PjrtIca, PjrtLogistic, PjrtPredictor};
pub use manifest::{load_manifest, parse_manifest, ArtifactSpec, TensorSpec};
pub use pjrt::PjrtRuntime;

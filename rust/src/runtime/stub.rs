//! Stub runtime compiled when the `pjrt` feature is off (the default in
//! offline builds, where the `xla` PJRT bindings are unavailable).
//!
//! Mirrors the public API of `runtime::{pjrt,backend}` so every caller
//! compiles unchanged; `PjrtRuntime::available()` is `false` and
//! `PjrtRuntime::new` always errors, which makes the backends
//! unconstructible. Callers must gate on `available()` (not just on the
//! artifacts being present on disk) before constructing the runtime.
//! The `LlDiffModel` impls delegate to the native models, so even
//! hypothetical use stays semantically correct.

use std::path::{Path, PathBuf};

use super::manifest::ArtifactSpec;
use super::RuntimeError;
use crate::models::traits::LlDiffModel;
use crate::models::{IcaModel, LogisticModel};

type Result<T> = std::result::Result<T, RuntimeError>;

fn unavailable<T>() -> Result<T> {
    Err(RuntimeError::new(
        "PJRT runtime not compiled in: rebuild with `--features pjrt` in an \
         environment providing the `xla` crate (see DESIGN.md §Layers)",
    ))
}

/// Stub of the PJRT CPU runtime: construction always fails.
pub struct PjrtRuntime {
    _private: (),
}

impl PjrtRuntime {
    /// Whether this build can execute PJRT artifacts at all (false: the
    /// `pjrt` feature is off and this is the stub).
    pub fn available() -> bool {
        false
    }

    pub fn new(_dir: &Path) -> Result<Self> {
        unavailable()
    }

    /// Default artifact directory (repo-root `artifacts/`); kept so the
    /// "artifacts present?" gates in examples/benches still work.
    pub fn default_dir() -> PathBuf {
        std::env::var_os("AUSTERITY_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|| Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts"))
    }

    pub fn platform(&self) -> String {
        "stub".to_string()
    }

    pub fn artifact_names(&self) -> Vec<String> {
        Vec::new()
    }

    pub fn spec(&self, _name: &str) -> Option<&ArtifactSpec> {
        None
    }

    pub fn exec(&mut self, _name: &str, _inputs: &[&[f32]]) -> Result<Vec<Vec<f32>>> {
        unavailable()
    }
}

/// Stub logistic backend; delegates to the native model.
pub struct PjrtLogistic<'a> {
    model: &'a LogisticModel,
}

impl<'a> PjrtLogistic<'a> {
    pub fn new(_model: &'a LogisticModel, _rt: PjrtRuntime) -> Result<Self> {
        unavailable()
    }

    pub fn batch_capacity(&self) -> usize {
        0
    }
}

impl LlDiffModel for PjrtLogistic<'_> {
    type Param = Vec<f64>;

    fn n(&self) -> usize {
        self.model.n()
    }

    fn lldiff(&self, i: usize, cur: &Vec<f64>, prop: &Vec<f64>) -> f64 {
        self.model.lldiff(i, cur, prop)
    }

    fn lldiff_moments(&self, idx: &[u32], cur: &Vec<f64>, prop: &Vec<f64>) -> (f64, f64) {
        self.model.lldiff_moments(idx, cur, prop)
    }

    fn lldiff_range_moments(
        &self,
        start: usize,
        end: usize,
        cur: &Vec<f64>,
        prop: &Vec<f64>,
    ) -> (f64, f64) {
        self.model.lldiff_range_moments(start, end, cur, prop)
    }

    fn session_backend(&self) -> &'static str {
        // mirror the real backend's label (the stub is never
        // constructible, but the API must match)
        "pjrt"
    }
}

/// Stub ICA backend; delegates to the native model.
pub struct PjrtIca<'a> {
    model: &'a IcaModel,
}

impl<'a> PjrtIca<'a> {
    pub fn new(_model: &'a IcaModel, _rt: PjrtRuntime) -> Result<Self> {
        unavailable()
    }
}

impl LlDiffModel for PjrtIca<'_> {
    type Param = crate::data::Mat;

    fn n(&self) -> usize {
        self.model.n()
    }

    fn lldiff(&self, i: usize, cur: &Self::Param, prop: &Self::Param) -> f64 {
        self.model.lldiff(i, cur, prop)
    }

    fn lldiff_moments(&self, idx: &[u32], cur: &Self::Param, prop: &Self::Param) -> (f64, f64) {
        self.model.lldiff_moments(idx, cur, prop)
    }

    fn lldiff_range_moments(
        &self,
        start: usize,
        end: usize,
        cur: &Self::Param,
        prop: &Self::Param,
    ) -> (f64, f64) {
        self.model.lldiff_range_moments(start, end, cur, prop)
    }
}

/// Stub predictive-panel backend.
pub struct PjrtPredictor {
    _private: (),
}

impl PjrtPredictor {
    pub fn new(_rt: PjrtRuntime) -> Result<Self> {
        unavailable()
    }

    pub fn predict(&self, _rows: &[&[f64]], _theta: &[f64]) -> Result<Vec<f64>> {
        unavailable()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_construction_fails_with_guidance() {
        let err = PjrtRuntime::new(&PjrtRuntime::default_dir()).err().unwrap();
        assert!(format!("{err}").contains("pjrt"), "{err}");
    }

    #[test]
    fn default_dir_respects_env_override() {
        // don't mutate the env (tests run in parallel): just check shape
        let d = PjrtRuntime::default_dir();
        assert!(d.as_os_str().len() > 0);
    }
}

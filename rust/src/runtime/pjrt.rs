//! PJRT execution of the AOT artifacts: load HLO text, compile once per
//! artifact on the CPU PJRT client, execute from the sampling hot path.
//!
//! Mirrors /opt/xla-example/load_hlo: text (not serialized proto) is the
//! interchange format because xla_extension 0.5.1 rejects jax>=0.5's
//! 64-bit instruction ids; the text parser reassigns ids.

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use super::manifest::{load_manifest, ArtifactSpec};

/// A compiled artifact plus its signature.
pub struct LoadedArtifact {
    pub spec: ArtifactSpec,
    exe: xla::PjRtLoadedExecutable,
}

/// PJRT CPU runtime with an executable cache keyed by artifact name.
pub struct PjrtRuntime {
    client: xla::PjRtClient,
    dir: PathBuf,
    specs: HashMap<String, ArtifactSpec>,
    cache: HashMap<String, LoadedArtifact>,
}

impl PjrtRuntime {
    /// Whether this build can execute PJRT artifacts at all (true: the
    /// `pjrt` feature compiled the real runtime).
    pub fn available() -> bool {
        true
    }

    /// Create a CPU client and read the manifest in `dir`.
    pub fn new(dir: &Path) -> Result<Self> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e:?}"))?;
        let specs = load_manifest(dir)?
            .into_iter()
            .map(|s| (s.name.clone(), s))
            .collect();
        Ok(PjrtRuntime { client, dir: dir.to_path_buf(), specs, cache: HashMap::new() })
    }

    /// Default artifact directory (repo-root `artifacts/`).
    pub fn default_dir() -> PathBuf {
        std::env::var_os("AUSTERITY_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|| Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts"))
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn artifact_names(&self) -> Vec<String> {
        let mut v: Vec<String> = self.specs.keys().cloned().collect();
        v.sort();
        v
    }

    pub fn spec(&self, name: &str) -> Option<&ArtifactSpec> {
        self.specs.get(name)
    }

    /// Compile (once) and return the loaded artifact.
    pub fn load(&mut self, name: &str) -> Result<&LoadedArtifact> {
        if !self.cache.contains_key(name) {
            let spec = self
                .specs
                .get(name)
                .with_context(|| format!("unknown artifact {name}"))?
                .clone();
            let path = self.dir.join(&spec.file);
            let proto = xla::HloModuleProto::from_text_file(&path)
                .map_err(|e| anyhow!("parse {path:?}: {e:?}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| anyhow!("compile {name}: {e:?}"))?;
            self.cache.insert(name.to_string(), LoadedArtifact { spec, exe });
        }
        Ok(&self.cache[name])
    }

    /// Execute an artifact on f32 host buffers (shape-checked against the
    /// manifest); returns one flat f32 vector per output.
    ///
    /// Inputs are staged with `buffer_from_host_buffer` (one host->device
    /// copy) and dispatched via `execute_b`, skipping the Literal
    /// intermediate of the naive path (§Perf: ~2x on the 512-row kernel).
    pub fn exec(&mut self, name: &str, inputs: &[&[f32]]) -> Result<Vec<Vec<f32>>> {
        self.load(name)?;
        let art = &self.cache[name];
        let spec = &art.spec;
        if inputs.len() != spec.inputs.len() {
            bail!("{name}: expected {} inputs, got {}", spec.inputs.len(), inputs.len());
        }
        let mut buffers = Vec::with_capacity(inputs.len());
        for (buf, tin) in inputs.iter().zip(&spec.inputs) {
            if buf.len() != tin.numel() {
                bail!(
                    "{name}: input {} expects {} elements ({:?}), got {}",
                    tin.name,
                    tin.numel(),
                    tin.dims,
                    buf.len()
                );
            }
            let dims: Vec<usize> =
                if tin.dims.is_empty() { vec![1] } else { tin.dims.clone() };
            let b = self
                .client
                .buffer_from_host_buffer::<f32>(buf, &dims, None)
                .map_err(|e| anyhow!("host buffer {}: {e:?}", tin.name))?;
            buffers.push(b);
        }
        let result = art
            .exe
            .execute_b::<xla::PjRtBuffer>(&buffers)
            .map_err(|e| anyhow!("execute {name}: {e:?}"))?;
        let tuple = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("to_literal: {e:?}"))?
            .to_tuple()
            .map_err(|e| anyhow!("to_tuple: {e:?}"))?;
        if tuple.len() != spec.outputs.len() {
            bail!("{name}: expected {} outputs, got {}", spec.outputs.len(), tuple.len());
        }
        let mut outs = Vec::with_capacity(tuple.len());
        for (lit, tout) in tuple.into_iter().zip(&spec.outputs) {
            let v = lit.to_vec::<f32>().map_err(|e| anyhow!("to_vec: {e:?}"))?;
            if v.len() != tout.numel() {
                bail!("{name}: output expects {} elements, got {}", tout.numel(), v.len());
            }
            outs.push(v);
        }
        Ok(outs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> PathBuf {
        PjrtRuntime::default_dir()
    }

    fn have_artifacts() -> bool {
        artifacts_dir().join("manifest.txt").exists()
    }

    #[test]
    fn loads_manifest_and_compiles() {
        if !have_artifacts() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let mut rt = PjrtRuntime::new(&artifacts_dir()).unwrap();
        assert_eq!(rt.platform(), "cpu");
        assert!(rt.artifact_names().contains(&"logistic_lldiff".to_string()));
        rt.load("logistic_predict").unwrap();
    }

    #[test]
    fn shape_mismatch_rejected() {
        if !have_artifacts() {
            return;
        }
        let mut rt = PjrtRuntime::new(&artifacts_dir()).unwrap();
        let bad = vec![0f32; 3];
        let spec_len = rt.spec("logistic_predict").unwrap().inputs.len();
        assert_eq!(spec_len, 2);
        let theta = vec![0f32; 50];
        let err = rt.exec("logistic_predict", &[&bad, &theta]).unwrap_err();
        assert!(format!("{err}").contains("expects"), "{err}");
    }

    #[test]
    fn predict_executes_with_correct_values() {
        if !have_artifacts() {
            return;
        }
        let mut rt = PjrtRuntime::new(&artifacts_dir()).unwrap();
        let t = 2048usize;
        let d = 50usize;
        // x row i = e_i-ish pattern; theta = ones/10
        let mut x = vec![0f32; t * d];
        for i in 0..t {
            x[i * d + (i % d)] = 1.0;
        }
        let theta = vec![0.1f32; d];
        let outs = rt.exec("logistic_predict", &[&x, &theta]).unwrap();
        assert_eq!(outs.len(), 1);
        assert_eq!(outs[0].len(), t);
        let want = 1.0 / (1.0 + (-0.1f32).exp());
        for &p in &outs[0] {
            assert!((p - want).abs() < 1e-5, "{p} vs {want}");
        }
    }
}

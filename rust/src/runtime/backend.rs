//! PJRT-served model backends: the three-layer hot path.
//!
//! `PjrtLogistic` implements the same `LlDiffModel` contract as the
//! native Rust model but serves `lldiff_moments` by executing the
//! AOT-compiled Pallas kernel (`logistic_lldiff.hlo.txt`). An
//! integration test asserts native and PJRT moments agree to f32
//! tolerance on random mini-batches — the cross-layer correctness proof.

use std::sync::Mutex;

use anyhow::Result;

use super::pjrt::PjrtRuntime;
use crate::models::logistic::LogisticModel;
use crate::models::traits::LlDiffModel;

/// Logistic-regression population served by the PJRT runtime.
pub struct PjrtLogistic<'a> {
    model: &'a LogisticModel,
    /// runtime + reusable host staging buffers behind one lock
    inner: Mutex<PjrtScratch>,
    /// dataset pre-converted to f32, padded row-major to d_cap columns
    /// (gathering a mini-batch is then a memcpy per row — §Perf)
    x_f32: Vec<f32>,
    /// iota table `0..n` sliced by the full-scan range path, so range
    /// scans stage no per-chunk index allocation (§Perf)
    iota: Vec<u32>,
    y_f32: Vec<f32>,
    /// batch capacity of the compiled kernel (manifest `x` leading dim)
    batch_cap: usize,
    /// feature capacity of the compiled kernel
    d_cap: usize,
}

struct PjrtScratch {
    rt: PjrtRuntime,
    x: Vec<f32>,
    y: Vec<f32>,
    mask: Vec<f32>,
}

impl<'a> PjrtLogistic<'a> {
    /// Wrap a native model; the dataset's feature dim must not exceed the
    /// artifact's compiled width (features/theta are zero-padded up to it).
    pub fn new(model: &'a LogisticModel, mut rt: PjrtRuntime) -> Result<Self> {
        let spec = rt
            .spec("logistic_lldiff")
            .ok_or_else(|| anyhow::anyhow!("logistic_lldiff missing from manifest"))?
            .clone();
        let batch_cap = spec.inputs[0].dims[0];
        let d_cap = spec.inputs[0].dims[1];
        anyhow::ensure!(
            model.d() <= d_cap,
            "model d={} exceeds compiled width {d_cap}",
            model.d()
        );
        rt.load("logistic_lldiff")?;
        // pre-convert + pad the dataset once (f64 -> f32 casts off the
        // per-step path; see EXPERIMENTS.md §Perf)
        let n = model.n();
        let d = model.d();
        let mut x_f32 = vec![0f32; n * d_cap];
        let mut y_f32 = vec![0f32; n];
        for i in 0..n {
            let row = model.data().row(i);
            for j in 0..d {
                x_f32[i * d_cap + j] = row[j] as f32;
            }
            y_f32[i] = model.data().label(i) as f32;
        }
        let scratch = PjrtScratch {
            rt,
            x: vec![0f32; batch_cap * d_cap],
            y: vec![0f32; batch_cap],
            mask: vec![0f32; batch_cap],
        };
        let iota: Vec<u32> = (0..n as u32).collect();
        Ok(PjrtLogistic { model, inner: Mutex::new(scratch), x_f32, y_f32, iota, batch_cap, d_cap })
    }

    pub fn batch_capacity(&self) -> usize {
        self.batch_cap
    }

    fn pad_theta(&self, theta: &[f64]) -> Vec<f32> {
        let mut t = vec![0f32; self.d_cap];
        for (o, &v) in t.iter_mut().zip(theta) {
            *o = v as f32;
        }
        t
    }

    /// One kernel execution over up to `batch_cap` rows.
    fn exec_chunk(
        &self,
        idx: &[u32],
        theta: &[f32],
        theta_p: &[f32],
    ) -> (f64, f64) {
        debug_assert!(idx.len() <= self.batch_cap);
        let dc = self.d_cap;
        let mut inner = self.inner.lock().expect("runtime poisoned");
        let inner = &mut *inner;
        // gather rows from the pre-converted f32 matrix (memcpy per row)
        for (r, &i) in idx.iter().enumerate() {
            let i = i as usize;
            inner.x[r * dc..(r + 1) * dc]
                .copy_from_slice(&self.x_f32[i * dc..(i + 1) * dc]);
            inner.y[r] = self.y_f32[i];
            inner.mask[r] = 1.0;
        }
        for r in idx.len()..self.batch_cap {
            inner.x[r * dc..(r + 1) * dc].fill(0.0);
            inner.y[r] = 0.0;
            inner.mask[r] = 0.0;
        }
        let outs = inner
            .rt
            .exec("logistic_lldiff", &[&inner.x, &inner.y, &inner.mask, theta, theta_p])
            .expect("pjrt exec failed");
        (outs[0][0] as f64, outs[1][0] as f64)
    }
}

impl LlDiffModel for PjrtLogistic<'_> {
    type Param = Vec<f64>;

    fn n(&self) -> usize {
        self.model.n()
    }

    fn lldiff(&self, i: usize, cur: &Vec<f64>, prop: &Vec<f64>) -> f64 {
        // single-point fallback: exact native value (used by diagnostics)
        self.model.lldiff(i, cur, prop)
    }

    fn lldiff_moments(&self, idx: &[u32], cur: &Vec<f64>, prop: &Vec<f64>) -> (f64, f64) {
        let theta = self.pad_theta(cur);
        let theta_p = self.pad_theta(prop);
        let (mut s, mut s2) = (0.0, 0.0);
        for chunk in idx.chunks(self.batch_cap) {
            let (cs, cs2) = self.exec_chunk(chunk, &theta, &theta_p);
            s += cs;
            s2 += cs2;
        }
        (s, s2)
    }

    fn lldiff_range_moments(
        &self,
        start: usize,
        end: usize,
        cur: &Vec<f64>,
        prop: &Vec<f64>,
    ) -> (f64, f64) {
        // full scans must keep hitting the AOT kernel (and match the
        // gathered path bit for bit), so route the range through the
        // same chunked dispatch, slicing the precomputed iota table
        // instead of staging a fresh index Vec per chunk per scan
        self.lldiff_moments(&self.iota[start..end], cur, prop)
    }

    fn session_backend(&self) -> &'static str {
        // uncached engine path, but the likelihood is served by the AOT
        // Pallas kernel — label reports (and `sample --json`) accordingly
        "pjrt"
    }
}

/// ICA population served by the PJRT runtime (`ica_lldiff` artifact).
pub struct PjrtIca<'a> {
    model: &'a crate::models::IcaModel,
    rt: Mutex<PjrtRuntime>,
    /// iota table `0..n` sliced by the full-scan range path (see
    /// `PjrtLogistic::iota`)
    iota: Vec<u32>,
    batch_cap: usize,
    d: usize,
}

impl<'a> PjrtIca<'a> {
    pub fn new(model: &'a crate::models::IcaModel, mut rt: PjrtRuntime) -> Result<Self> {
        let spec = rt
            .spec("ica_lldiff")
            .ok_or_else(|| anyhow::anyhow!("ica_lldiff missing from manifest"))?
            .clone();
        let batch_cap = spec.inputs[0].dims[0];
        let d = spec.inputs[0].dims[1];
        anyhow::ensure!(
            model.d() == d,
            "ICA artifact compiled for D={d}, model has D={}",
            model.d()
        );
        rt.load("ica_lldiff")?;
        let iota: Vec<u32> = (0..model.n() as u32).collect();
        Ok(PjrtIca { model, rt: Mutex::new(rt), iota, batch_cap, d })
    }

    fn mat_f32(&self, m: &crate::data::Mat) -> Vec<f32> {
        m.a.iter().map(|&v| v as f32).collect()
    }

    fn exec_chunk(&self, idx: &[u32], w: &[f32], w_p: &[f32], const_shift: f32) -> (f64, f64) {
        debug_assert!(idx.len() <= self.batch_cap);
        let (bc, d) = (self.batch_cap, self.d);
        let mut x = vec![0f32; bc * d];
        let mut mask = vec![0f32; bc];
        for (r, &i) in idx.iter().enumerate() {
            for (j, &v) in self.model.data().row(i as usize).iter().enumerate() {
                x[r * d + j] = v as f32;
            }
            mask[r] = 1.0;
        }
        let cs = [const_shift];
        let mut rt = self.rt.lock().expect("runtime poisoned");
        let outs = rt
            .exec("ica_lldiff", &[&x, &mask, w, w_p, &cs])
            .expect("pjrt exec failed");
        (outs[0][0] as f64, outs[1][0] as f64)
    }
}

impl LlDiffModel for PjrtIca<'_> {
    type Param = crate::data::Mat;

    fn n(&self) -> usize {
        self.model.n()
    }

    fn lldiff(&self, i: usize, cur: &Self::Param, prop: &Self::Param) -> f64 {
        self.model.lldiff(i, cur, prop)
    }

    fn lldiff_range_moments(
        &self,
        start: usize,
        end: usize,
        cur: &Self::Param,
        prop: &Self::Param,
    ) -> (f64, f64) {
        // same chunked kernel dispatch as the gathered path (bit-equal),
        // sliced from the precomputed iota table — no per-chunk staging
        self.lldiff_moments(&self.iota[start..end], cur, prop)
    }

    fn lldiff_moments(&self, idx: &[u32], cur: &Self::Param, prop: &Self::Param) -> (f64, f64) {
        let w = self.mat_f32(cur);
        let w_p = self.mat_f32(prop);
        // logdet difference computed host-side (the artifact takes it as
        // a scalar: slogdet's LAPACK custom-call cannot run on this PJRT)
        let (_, ld_cur) = cur.slogdet();
        let (_, ld_prop) = prop.slogdet();
        let const_shift = (ld_prop - ld_cur) as f32;
        let (mut s, mut s2) = (0.0, 0.0);
        for chunk in idx.chunks(self.batch_cap) {
            let (cs, cs2) = self.exec_chunk(chunk, &w, &w_p, const_shift);
            s += cs;
            s2 += cs2;
        }
        (s, s2)
    }
}

/// Predictive-probability panel served by the `logistic_predict` artifact.
pub struct PjrtPredictor {
    rt: Mutex<PjrtRuntime>,
    t_cap: usize,
    d_cap: usize,
}

impl PjrtPredictor {
    pub fn new(mut rt: PjrtRuntime) -> Result<Self> {
        let spec = rt
            .spec("logistic_predict")
            .ok_or_else(|| anyhow::anyhow!("logistic_predict missing from manifest"))?
            .clone();
        let t_cap = spec.inputs[0].dims[0];
        let d_cap = spec.inputs[0].dims[1];
        rt.load("logistic_predict")?;
        Ok(PjrtPredictor { rt: Mutex::new(rt), t_cap, d_cap })
    }

    /// sigmoid(X theta) for up to `t_cap` test rows of width <= d_cap.
    pub fn predict(&self, rows: &[&[f64]], theta: &[f64]) -> Result<Vec<f64>> {
        anyhow::ensure!(!rows.is_empty());
        let mut out = Vec::with_capacity(rows.len());
        let mut th = vec![0f32; self.d_cap];
        for (o, &v) in th.iter_mut().zip(theta) {
            *o = v as f32;
        }
        for chunk in rows.chunks(self.t_cap) {
            let mut x = vec![0f32; self.t_cap * self.d_cap];
            for (r, row) in chunk.iter().enumerate() {
                for (j, &v) in row.iter().enumerate() {
                    x[r * self.d_cap + j] = v as f32;
                }
            }
            let mut rt = self.rt.lock().expect("runtime poisoned");
            let outs = rt.exec("logistic_predict", &[&x, &th])?;
            out.extend(outs[0][..chunk.len()].iter().map(|&p| p as f64));
        }
        Ok(out)
    }
}

//! Parser for `artifacts/manifest.txt`, the contract between the Python
//! AOT pipeline (python/compile/aot.py) and the Rust runtime.
//!
//! Line format (one artifact per line):
//!   <name> <file> in=<arg>:<dtype>:<d0>x<d1>,... out=<dtype>:<dims>,...
//! dims are `x`-separated or the literal `scalar`.

use super::RuntimeError;

type Result<T> = std::result::Result<T, RuntimeError>;

/// Shape of one tensor in an artifact signature.
#[derive(Clone, Debug, PartialEq)]
pub struct TensorSpec {
    pub name: String,
    pub dtype: String,
    /// empty = scalar
    pub dims: Vec<usize>,
}

impl TensorSpec {
    pub fn numel(&self) -> usize {
        self.dims.iter().product()
    }
}

/// One AOT-compiled computation.
#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    pub name: String,
    pub file: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

fn parse_dims(s: &str) -> Result<Vec<usize>> {
    if s == "scalar" {
        return Ok(Vec::new());
    }
    s.split('x')
        .map(|d| {
            d.parse::<usize>()
                .map_err(|_| RuntimeError::new(format!("bad dim {d:?}")))
        })
        .collect()
}

fn parse_tensor(part: &str, with_name: bool) -> Result<TensorSpec> {
    let fields: Vec<&str> = part.split(':').collect();
    match (with_name, fields.as_slice()) {
        (true, [name, dtype, dims]) => Ok(TensorSpec {
            name: name.to_string(),
            dtype: dtype.to_string(),
            dims: parse_dims(dims)?,
        }),
        (false, [dtype, dims]) => Ok(TensorSpec {
            name: String::new(),
            dtype: dtype.to_string(),
            dims: parse_dims(dims)?,
        }),
        _ => Err(RuntimeError::new(format!("malformed tensor spec: {part}"))),
    }
}

/// Parse a full manifest.
pub fn parse_manifest(text: &str) -> Result<Vec<ArtifactSpec>> {
    let mut specs = Vec::new();
    for (lineno, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let fields: Vec<&str> = line.split_whitespace().collect();
        if fields.len() != 4 {
            return Err(RuntimeError::new(format!(
                "manifest line {}: expected 4 fields, got {}",
                lineno + 1,
                fields.len()
            )));
        }
        let ins = fields[2]
            .strip_prefix("in=")
            .ok_or_else(|| RuntimeError::new(format!("line {}: missing in=", lineno + 1)))?;
        let outs = fields[3]
            .strip_prefix("out=")
            .ok_or_else(|| RuntimeError::new(format!("line {}: missing out=", lineno + 1)))?;
        specs.push(ArtifactSpec {
            name: fields[0].to_string(),
            file: fields[1].to_string(),
            inputs: ins
                .split(',')
                .map(|p| parse_tensor(p, true))
                .collect::<Result<_>>()?,
            outputs: outs
                .split(',')
                .map(|p| parse_tensor(p, false))
                .collect::<Result<_>>()?,
        });
    }
    Ok(specs)
}

/// Load and parse `<dir>/manifest.txt`.
pub fn load_manifest(dir: &std::path::Path) -> Result<Vec<ArtifactSpec>> {
    let path = dir.join("manifest.txt");
    let text = std::fs::read_to_string(&path).map_err(|e| {
        RuntimeError::new(format!("reading {path:?} (run `make artifacts` first): {e}"))
    })?;
    parse_manifest(&text)
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
logistic_lldiff logistic_lldiff.hlo.txt in=x:float32:512x50,y:float32:512 out=float32:scalar,float32:scalar
logistic_predict logistic_predict.hlo.txt in=x:float32:2048x50,theta:float32:50 out=float32:2048
";

    #[test]
    fn parses_sample() {
        let specs = parse_manifest(SAMPLE).unwrap();
        assert_eq!(specs.len(), 2);
        let s = &specs[0];
        assert_eq!(s.name, "logistic_lldiff");
        assert_eq!(s.inputs[0].dims, vec![512, 50]);
        assert_eq!(s.inputs[0].name, "x");
        assert_eq!(s.inputs[0].numel(), 512 * 50);
        assert_eq!(s.outputs[0].dims, Vec::<usize>::new());
        assert_eq!(s.outputs[0].numel(), 1);
        assert_eq!(specs[1].outputs[0].dims, vec![2048]);
    }

    #[test]
    fn skips_blank_and_comment_lines() {
        let text = format!("# comment\n\n{SAMPLE}\n");
        assert_eq!(parse_manifest(&text).unwrap().len(), 2);
    }

    #[test]
    fn rejects_malformed_lines() {
        assert!(parse_manifest("name only").is_err());
        assert!(parse_manifest("a b c=bad d=also").is_err());
        assert!(parse_manifest("a b in=x:f32:2xq out=f32:1").is_err());
    }

    #[test]
    fn parses_real_generated_manifest_if_present() {
        // When artifacts were built (make artifacts), validate for real.
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if dir.join("manifest.txt").exists() {
            let specs = load_manifest(&dir).unwrap();
            assert!(specs.iter().any(|s| s.name == "logistic_lldiff"));
            for s in &specs {
                assert!(dir.join(&s.file).exists(), "missing {}", s.file);
                assert!(!s.inputs.is_empty() && !s.outputs.is_empty());
            }
        }
    }
}

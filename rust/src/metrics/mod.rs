//! Evaluation metrics: risk-vs-time curves, predictive means, ground
//! truth estimation — the measurement half of every §6 figure.

pub mod predictive;
pub mod risk;

pub use predictive::PredictiveMean;
pub use risk::{risk_curve, Checkpoints, RiskCurve};

//! Evaluation metrics: risk-vs-time curves, predictive means, ground
//! truth estimation, cross-chain convergence diagnostics — the
//! measurement half of every §6 figure and of the multi-chain engine.

pub mod convergence;
pub mod predictive;
pub mod risk;

pub use convergence::{cross_chain, split_rhat, Convergence};
pub use predictive::PredictiveMean;
pub use risk::{risk_curve, Checkpoints, RiskCurve};

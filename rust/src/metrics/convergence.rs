//! Cross-chain convergence diagnostics for the multi-chain engine:
//! split R-hat (Gelman-Rubin with split chains) and pooled effective
//! sample size, both over a scalar test function.
//!
//! R-hat compares between-chain and within-chain variance; values near 1
//! mean the K chains are sampling the same distribution. ESS sums the
//! per-chain `T / tau` of `stats::autocorr` (chains are independent, so
//! their effective sizes add).

use crate::stats::autocorr::effective_sample_size;

/// Summary of a multi-chain run's recorded values.
#[derive(Clone, Debug)]
pub struct Convergence {
    /// Split R-hat; NaN when there are too few samples to estimate.
    pub rhat: f64,
    /// Total effective sample size across chains.
    pub ess: f64,
    /// Mean over all recorded values of all chains (NaN if none).
    pub pooled_mean: f64,
    /// Total number of recorded values.
    pub n_samples: usize,
}

/// Split R-hat over per-chain value series. Each chain is split in half
/// (guarding against within-chain drift), all half-chains truncated to a
/// common length. Returns NaN when fewer than 4 values per chain exist.
pub fn split_rhat(chains: &[Vec<f64>]) -> f64 {
    let min_len = chains.iter().map(|c| c.len()).min().unwrap_or(0);
    if chains.is_empty() || min_len < 4 {
        return f64::NAN;
    }
    let half = min_len / 2;
    let mut groups: Vec<&[f64]> = Vec::with_capacity(chains.len() * 2);
    for c in chains {
        groups.push(&c[..half]);
        groups.push(&c[half..2 * half]);
    }
    let m = groups.len() as f64;
    let n = half as f64;
    let means: Vec<f64> = groups.iter().map(|g| g.iter().sum::<f64>() / n).collect();
    let grand = means.iter().sum::<f64>() / m;
    // between-half-chain variance B and mean within variance W
    let b = n / (m - 1.0) * means.iter().map(|mu| (mu - grand) * (mu - grand)).sum::<f64>();
    let w = groups
        .iter()
        .zip(&means)
        .map(|(g, &mu)| g.iter().map(|x| (x - mu) * (x - mu)).sum::<f64>() / (n - 1.0))
        .sum::<f64>()
        / m;
    if w <= 0.0 {
        // all half-chains constant: identical means => converged trivially
        return if b <= 0.0 { 1.0 } else { f64::INFINITY };
    }
    let var_plus = (n - 1.0) / n * w + b / n;
    (var_plus / w).sqrt()
}

/// Full cross-chain summary of per-chain value series.
pub fn cross_chain(chains: &[Vec<f64>]) -> Convergence {
    let n_samples: usize = chains.iter().map(|c| c.len()).sum();
    let pooled_mean = if n_samples == 0 {
        f64::NAN
    } else {
        chains.iter().flat_map(|c| c.iter()).sum::<f64>() / n_samples as f64
    };
    let ess = chains
        .iter()
        .filter(|c| c.len() >= 4)
        .map(|c| effective_sample_size(c.as_slice()))
        .sum::<f64>();
    Convergence { rhat: split_rhat(chains), ess, pooled_mean, n_samples }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stats::Pcg64;

    fn iid_chain(seed: u64, n: usize, mu: f64) -> Vec<f64> {
        let mut rng = Pcg64::seeded(seed);
        (0..n).map(|_| mu + rng.normal()).collect()
    }

    #[test]
    fn iid_same_target_rhat_near_one() {
        let chains: Vec<Vec<f64>> = (0..4).map(|s| iid_chain(s, 5_000, 0.0)).collect();
        let r = split_rhat(&chains);
        assert!((r - 1.0).abs() < 0.02, "rhat {r}");
    }

    #[test]
    fn shifted_chain_inflates_rhat() {
        let mut chains: Vec<Vec<f64>> = (0..3).map(|s| iid_chain(s, 2_000, 0.0)).collect();
        chains.push(iid_chain(9, 2_000, 3.0)); // one chain stuck elsewhere
        let r = split_rhat(&chains);
        assert!(r > 1.5, "rhat {r}");
    }

    #[test]
    fn within_chain_drift_detected_by_split() {
        // a single drifting chain: plain R-hat can't see it, split can
        let n = 4_000;
        let mut rng = Pcg64::seeded(3);
        let drift: Vec<f64> = (0..n)
            .map(|i| 4.0 * i as f64 / n as f64 + 0.1 * rng.normal())
            .collect();
        let r = split_rhat(&[drift].to_vec());
        assert!(r > 1.5, "rhat {r}");
    }

    #[test]
    fn degenerate_inputs() {
        assert!(split_rhat(&[]).is_nan());
        assert!(split_rhat(&[vec![1.0, 2.0]].to_vec()).is_nan());
        assert_eq!(split_rhat(&[vec![2.0; 100], vec![2.0; 100]].to_vec()), 1.0);
    }

    #[test]
    fn cross_chain_pools_mean_and_ess() {
        let chains: Vec<Vec<f64>> = (0..2).map(|s| iid_chain(s, 10_000, 1.0)).collect();
        let c = cross_chain(&chains);
        assert_eq!(c.n_samples, 20_000);
        assert!((c.pooled_mean - 1.0).abs() < 0.05);
        // iid: ESS close to the pooled count
        assert!(c.ess > 15_000.0, "ess {}", c.ess);
        assert!((c.rhat - 1.0).abs() < 0.02);
        let empty = cross_chain(&[]);
        assert!(empty.pooled_mean.is_nan() && empty.n_samples == 0);
    }
}

//! Streaming predictive-mean estimator: the test function of the
//! logistic-regression and RJMCMC risk figures (Figs. 2 and 4).
//!
//! The predictive mean of test point x* is E_{p(theta|X)}[p(x*|theta)];
//! a chain estimates it by averaging p(x*|theta_t) over collected
//! samples. This accumulator streams that average over a panel of test
//! points without storing samples.

/// Running mean of a vector-valued test function (one entry per test point).
#[derive(Clone, Debug)]
pub struct PredictiveMean {
    sums: Vec<f64>,
    count: u64,
}

impl PredictiveMean {
    pub fn new(n_points: usize) -> Self {
        PredictiveMean { sums: vec![0.0; n_points], count: 0 }
    }

    pub fn len(&self) -> usize {
        self.sums.len()
    }

    pub fn is_empty(&self) -> bool {
        self.sums.is_empty()
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    /// Fold in the per-point predictive probabilities of one sample.
    pub fn add(&mut self, probs: &[f64]) {
        assert_eq!(probs.len(), self.sums.len());
        for (s, p) in self.sums.iter_mut().zip(probs) {
            *s += p;
        }
        self.count += 1;
    }

    /// Merge another accumulator over the same test panel (per-chain
    /// accumulators from the parallel engine combine into one estimate).
    pub fn merge(&mut self, other: &PredictiveMean) {
        assert_eq!(other.sums.len(), self.sums.len(), "panel size mismatch");
        for (s, o) in self.sums.iter_mut().zip(&other.sums) {
            *s += o;
        }
        self.count += other.count;
    }

    /// Current estimate per test point.
    pub fn mean(&self) -> Vec<f64> {
        assert!(self.count > 0, "no samples accumulated");
        self.sums.iter().map(|s| s / self.count as f64).collect()
    }

    /// Mean squared error against a ground-truth predictive mean,
    /// averaged over test points — the risk integrand of Figs. 2/4.
    pub fn mse_against(&self, truth: &[f64]) -> f64 {
        assert_eq!(truth.len(), self.sums.len());
        assert!(self.count > 0);
        let c = self.count as f64;
        self.sums
            .iter()
            .zip(truth)
            .map(|(s, t)| {
                let d = s / c - t;
                d * d
            })
            .sum::<f64>()
            / self.sums.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_of_constant_stream() {
        let mut pm = PredictiveMean::new(3);
        for _ in 0..10 {
            pm.add(&[0.2, 0.5, 0.9]);
        }
        assert_eq!(pm.count(), 10);
        let m = pm.mean();
        assert!((m[0] - 0.2).abs() < 1e-12);
        assert!((m[2] - 0.9).abs() < 1e-12);
        assert!(pm.mse_against(&[0.2, 0.5, 0.9]) < 1e-24);
    }

    #[test]
    fn mse_measures_bias() {
        let mut pm = PredictiveMean::new(2);
        pm.add(&[0.0, 1.0]);
        pm.add(&[1.0, 1.0]);
        // means = [0.5, 1.0]; truth = [0.5, 0.5] -> mse = (0 + 0.25)/2
        assert!((pm.mse_against(&[0.5, 0.5]) - 0.125).abs() < 1e-12);
    }

    #[test]
    #[should_panic]
    fn empty_mean_panics() {
        PredictiveMean::new(2).mean();
    }

    #[test]
    fn merge_equals_sequential_accumulation() {
        let mut whole = PredictiveMean::new(2);
        let mut a = PredictiveMean::new(2);
        let mut b = PredictiveMean::new(2);
        for i in 0..10 {
            let v = [0.1 * i as f64, 1.0 - 0.05 * i as f64];
            whole.add(&v);
            if i % 2 == 0 {
                a.add(&v);
            } else {
                b.add(&v);
            }
        }
        a.merge(&b);
        assert_eq!(a.count(), whole.count());
        let (ma, mw) = (a.mean(), whole.mean());
        assert!((ma[0] - mw[0]).abs() < 1e-12);
        assert!((ma[1] - mw[1]).abs() < 1e-12);
    }
}

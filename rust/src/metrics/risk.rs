//! Risk-vs-time machinery (paper §3): risk R = B^2 + V estimated as the
//! mean squared error of per-chain estimates against ground truth,
//! averaged over independent chains, evaluated at wall-clock checkpoints.

/// Logarithmically spaced wall-clock checkpoints (seconds).
#[derive(Clone, Debug)]
pub struct Checkpoints {
    pub at_secs: Vec<f64>,
}

impl Checkpoints {
    /// `count` points log-spaced between `first` and `last` seconds.
    pub fn log_spaced(first: f64, last: f64, count: usize) -> Self {
        assert!(first > 0.0 && last > first && count >= 2);
        let ratio = (last / first).powf(1.0 / (count - 1) as f64);
        let at_secs = (0..count).map(|i| first * ratio.powi(i as i32)).collect();
        Checkpoints { at_secs }
    }

    pub fn len(&self) -> usize {
        self.at_secs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.at_secs.is_empty()
    }

    /// Index of the first checkpoint not yet passed at `t` seconds.
    pub fn next_after(&self, t: f64) -> usize {
        self.at_secs.partition_point(|&c| c <= t)
    }
}

/// A risk curve: per checkpoint, the chain-averaged squared error.
#[derive(Clone, Debug)]
pub struct RiskCurve {
    pub at_secs: Vec<f64>,
    pub risk: Vec<f64>,
    /// number of chains contributing at each checkpoint
    pub chains: Vec<usize>,
}

/// Combine per-chain per-checkpoint squared errors into a risk curve.
/// `errors[c][k]` = squared error of chain c's estimate at checkpoint k
/// (NaN if the chain had no samples yet at that checkpoint).
pub fn risk_curve(at_secs: &[f64], errors: &[Vec<f64>]) -> RiskCurve {
    let k = at_secs.len();
    let mut risk = vec![0.0; k];
    let mut chains = vec![0usize; k];
    for chain in errors {
        assert_eq!(chain.len(), k);
        for (i, &e) in chain.iter().enumerate() {
            if e.is_finite() {
                risk[i] += e;
                chains[i] += 1;
            }
        }
    }
    for i in 0..k {
        risk[i] = if chains[i] > 0 { risk[i] / chains[i] as f64 } else { f64::NAN };
    }
    RiskCurve { at_secs: at_secs.to_vec(), risk, chains }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn log_spacing_monotone_and_bounded() {
        let c = Checkpoints::log_spaced(0.1, 100.0, 13);
        assert_eq!(c.len(), 13);
        assert!((c.at_secs[0] - 0.1).abs() < 1e-12);
        assert!((c.at_secs[12] - 100.0).abs() < 1e-9);
        assert!(c.at_secs.windows(2).all(|w| w[1] > w[0]));
    }

    #[test]
    fn next_after_partitions() {
        let c = Checkpoints::log_spaced(1.0, 8.0, 4); // 1, 2, 4, 8
        assert_eq!(c.next_after(0.5), 0);
        assert_eq!(c.next_after(1.0), 1);
        assert_eq!(c.next_after(3.0), 2);
        assert_eq!(c.next_after(100.0), 4);
    }

    #[test]
    fn risk_curve_averages_and_skips_nan() {
        let at = [1.0, 2.0];
        let errors = vec![vec![0.4, 0.2], vec![f64::NAN, 0.4]];
        let rc = risk_curve(&at, &errors);
        assert_eq!(rc.chains, vec![1, 2]);
        assert!((rc.risk[0] - 0.4).abs() < 1e-12);
        assert!((rc.risk[1] - 0.3).abs() < 1e-12);
    }
}

//! Cross-method risk-vs-budget comparison of the four acceptance rules
//! (exact / austerity / barker / confidence) on the §6.1
//! logistic-regression workload.
//!
//! Unlike the wall-clock risk figures (fig 2-4), the x-axis here is
//! `Budget::Data` — cumulative datapoint evaluations — so the comparison
//! is deterministic and hardware-independent: every rule gets the same
//! number of likelihood evaluations and we measure how much posterior
//! accuracy it buys. Risk is the chain-averaged squared error of the
//! posterior-mean estimate of theta_0 against a long exact run, over
//! K = 4 engine chains per (rule, budget) cell.

use crate::coordinator::accept::AcceptanceTest;
use crate::coordinator::chain::Budget;
use crate::coordinator::mh::MhMode;
use crate::coordinator::record::Param;
use crate::coordinator::session::Session;
use crate::exp::common::{FigureSink, Scale};
use crate::exp::population::mnist_like_model;
use crate::samplers::GaussianRandomWalk;

/// One rule's risk curve over the shared budget grid.
#[derive(Clone, Debug)]
pub struct RuleRisk {
    pub rule: &'static str,
    /// Datapoint budgets (shared across rules).
    pub budgets: Vec<u64>,
    /// Chain-averaged squared error at each budget.
    pub risk: Vec<f64>,
    /// Mean fraction of the dataset per decision at the largest budget.
    pub data_fraction: f64,
    /// Acceptance rate at the largest budget.
    pub acceptance: f64,
}

/// Run the comparison; returns one `RuleRisk` per rule and writes
/// `fig_accept_risk.csv`.
pub fn run_fig_accept(scale: Scale) -> Vec<RuleRisk> {
    let n = scale.n(12_214);
    let model = mnist_like_model(n, 42);
    let map = model.map_estimate(80);
    let kernel = GaussianRandomWalk::new(0.01, model.prior_precision);
    let batch = 500.min(n / 4).max(16);

    // ground truth: long exact run on K = 4 chains (Session picks the
    // cached fast path for the logistic model)
    let gt = Session::new(&model)
        .kernel(&kernel)
        .chains(4)
        .seed(5)
        .budget(Budget::Steps(scale.steps(4_000)))
        .burn_in(scale.steps(400))
        .record(Param::index(0))
        .init(map.clone())
        .run();
    let truth = {
        let (mut s, mut k) = (0.0, 0usize);
        for run in &gt.runs {
            for smp in &run.samples {
                s += smp.value;
                k += 1;
            }
        }
        s / k.max(1) as f64
    };

    let rules: Vec<MhMode> = vec![
        MhMode::Exact,
        MhMode::approx(0.05, batch),
        MhMode::barker(1.0, batch),
        MhMode::confidence(0.05, batch),
    ];
    // budget grid in units of full scans; burn-in is 20 steps, so even
    // the exact rule has >= 30 post-burn-in decisions at the smallest
    let budgets: Vec<u64> = [50u64, 100, 200, 400].iter().map(|k| k * n as u64).collect();
    let burn_in = 20usize;

    let mut sink = FigureSink::new("fig_accept_risk");
    sink.header(&["rule", "budget", "risk", "acceptance", "data_fraction", "steps"]);
    let mut out = Vec::new();
    for mode in &rules {
        let rule = mode.name();
        let mut risk = Vec::with_capacity(budgets.len());
        let (mut last_frac, mut last_acc) = (0.0, 0.0);
        for (bi, &b) in budgets.iter().enumerate() {
            let res = Session::new(&model)
                .kernel(&kernel)
                .rule(mode.clone())
                .chains(4)
                .seed(900 + bi as u64)
                .budget(Budget::Data(b))
                .burn_in(burn_in)
                .record(Param::index(0))
                .init(map.clone())
                .run();
            let mut sq = 0.0;
            let mut chains = 0usize;
            for run in &res.runs {
                if run.samples.is_empty() {
                    continue;
                }
                let m: f64 = run.samples.iter().map(|s| s.value).sum::<f64>()
                    / run.samples.len() as f64;
                sq += (m - truth) * (m - truth);
                chains += 1;
            }
            let r = if chains > 0 { sq / chains as f64 } else { f64::NAN };
            last_frac = res.merged.mean_data_fraction(n);
            last_acc = res.merged.acceptance_rate();
            sink.row_tagged(
                rule,
                &[b as f64, r, last_acc, last_frac, res.merged.steps as f64],
            );
            risk.push(r);
        }
        out.push(RuleRisk {
            rule,
            budgets: budgets.clone(),
            risk,
            data_fraction: last_frac,
            acceptance: last_acc,
        });
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig_accept_smoke_runs_all_four_rules() {
        std::env::set_var("AUSTERITY_FIGURES", "/tmp/austerity_fig_smoke");
        let out = run_fig_accept(Scale(0.02));
        assert_eq!(out.len(), 4);
        let names: Vec<&str> = out.iter().map(|r| r.rule).collect();
        assert_eq!(names, ["exact", "austerity", "barker", "confidence"]);
        for r in &out {
            assert_eq!(r.risk.len(), 4);
            assert!(r.risk.iter().all(|v| v.is_finite()), "{r:?}");
            assert!(r.acceptance > 0.0 && r.acceptance <= 1.0, "{r:?}");
        }
        // the subsampling rules touch less data per decision than exact
        assert!((out[0].data_fraction - 1.0).abs() < 1e-9);
        assert!(out[1].data_fraction < 1.0);
    }
}

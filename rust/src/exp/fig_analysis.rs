//! Analysis figures driven by the logistic population:
//!   Fig. 1  — sequential-test error: simulation vs DP vs worst bound
//!   Fig. 7  — t-statistic distribution vs Student-t / normal
//!   Fig. 8  — random-walk realizations + analytic envelope
//!   Fig. 10 — data usage: simulation vs DP vs worst case

use crate::coordinator::austerity::{seq_mh_test, SeqTestConfig};
use crate::coordinator::dp::{analyze_pocock, stage_coeffs, uniform_pis};
use crate::coordinator::scheduler::MinibatchScheduler;
use crate::exp::common::{FigureSink, Scale};
use crate::exp::population::{harvest_pairs, mnist_like_model, FixedLs};
use crate::stats::normal::phi_pdf;
use crate::stats::student_t::t_pdf;
use crate::stats::welford::MomentAccumulator;
use crate::stats::{Histogram, Pcg64};

/// Figs. 1 and 10 share the simulation: run real sequential tests on a
/// real l-population at chosen mu_std values, measure error and usage.
pub fn run_fig1_and_fig10(scale: Scale) {
    let n = scale.n(12_214);
    let m = 500usize.min(n / 4).max(16);
    let model = mnist_like_model(n, 42);
    let pop = &harvest_pairs(&model, 0.01, 1, 5, 7)[0];

    let trials = scale.steps(1_000);
    let eps_values = [0.01, 0.05, 0.1];
    let mu_stds = [0.0, 0.25, 0.5, 0.75, 1.0, 1.5, 2.0, 3.0, 4.0, 6.0];

    let mut f1 = FigureSink::new("fig1_error");
    f1.header(&["eps", "mu_std", "sim_error", "sim_stderr", "dp_error", "worst_bound"]);
    let mut f10 = FigureSink::new("fig10_data_usage");
    f10.header(&["eps", "mu_std", "sim_pi", "dp_pi", "worst_pi"]);

    let sqrt_n1 = ((n - 1) as f64).sqrt();
    for &eps in &eps_values {
        let worst = analyze_pocock(0.0, m, n, eps, 256);
        for &mu_std in &mu_stds {
            // mu0 placed so the pair has exactly this standardized mean
            let mu0 = pop.mu - mu_std * pop.sigma_l / sqrt_n1;
            let truth = pop.mu > mu0 || mu_std == 0.0;
            let cfg = SeqTestConfig::new(eps, m);
            let fixed = FixedLs(&pop.ls);
            let mut sched = MinibatchScheduler::new(n).expect("population exceeds the u32 index space");
            let mut rng = Pcg64::new(1000 + (eps * 1e4) as u64, mu_std.to_bits());
            let mut wrong = 0usize;
            let mut used = 0u64;
            for _ in 0..trials {
                let out = seq_mh_test(&fixed, &(), &(), mu0, &cfg, &mut sched, &mut rng);
                used += out.n_used as u64;
                if mu_std == 0.0 {
                    // worst case: any early decision counts half (Eqn. 21)
                    if out.n_used < n {
                        wrong += 1;
                    }
                } else if out.accept != truth {
                    wrong += 1;
                }
            }
            let mut sim_err = wrong as f64 / trials as f64;
            if mu_std == 0.0 {
                sim_err *= 0.5;
            }
            let stderr = (sim_err * (1.0 - sim_err) / trials as f64).sqrt();
            let dp = analyze_pocock(mu_std, m, n, eps, 256);
            f1.row(&[eps, mu_std, sim_err, stderr, dp.error, worst.error]);
            f10.row(&[
                eps,
                mu_std,
                used as f64 / (trials as f64 * n as f64),
                dp.expected_pi,
                worst.expected_pi,
            ]);
        }
    }
}

/// Fig. 7: empirical t-statistic distribution under resampling without
/// replacement at mu = mu0, vs Student-t(n-1) and standard normal pdfs.
pub fn run_fig7(scale: Scale) {
    let n = scale.n(12_214);
    let model = mnist_like_model(n, 42);
    let pop = &harvest_pairs(&model, 0.01, 1, 5, 9)[0];
    let resamples = scale.steps(100_000);

    let mut sink = FigureSink::new("fig7_tstat");
    sink.header(&["n", "bin_center", "empirical_density", "student_t_pdf", "normal_pdf"]);

    let mut rng = Pcg64::seeded(11);
    for &batch in &[50usize, 500, 5_000] {
        let batch = batch.min(n / 2);
        let mut sched = MinibatchScheduler::new(n).expect("population exceeds the u32 index space");
        let mut hist = Histogram::new(-5.0, 5.0, 50);
        for _ in 0..resamples {
            sched.reset();
            let ids = sched.next_batch(batch, &mut rng);
            let mut acc = MomentAccumulator::new();
            for &i in ids {
                acc.add(pop.ls[i as usize]);
            }
            // t statistic at mu0 = true mean (the null of Fig. 7)
            let t = acc.t_statistic(pop.mu, n);
            if t.is_finite() {
                hist.add(t);
            }
        }
        for b in 0..hist.bins() {
            let c = hist.center(b);
            sink.row(&[
                batch as f64,
                c,
                hist.density(b),
                t_pdf(c, (batch - 1) as f64),
                phi_pdf(c),
            ]);
        }
    }
}

/// Fig. 8: a few z random-walk realizations plus the analytic mean and
/// 95% envelope as functions of pi (Proposition 2).
pub fn run_fig8(_scale: Scale) {
    let n = 10_000usize;
    let m = 500usize;
    let mu_std = 1.5f64;
    let pis = uniform_pis(m, n);
    let mut sink = FigureSink::new("fig8_walk");
    sink.header(&["pi", "mean", "lo95", "hi95", "path0", "path1", "path2", "path3"]);

    let paths = 4usize;
    let mut zs = vec![0.0f64; paths];
    let mut rng = Pcg64::seeded(8);
    for (j, &pi) in pis.iter().enumerate() {
        if pi >= 1.0 {
            break;
        }
        let pi_prev = if j == 0 { 0.0 } else { pis[j - 1] };
        let (a, b, sd) = stage_coeffs(mu_std, pi_prev, pi);
        for z in zs.iter_mut() {
            *z = a + b * *z + sd * rng.normal();
        }
        // analytic marginal: mean mu_std sqrt(pi/(1-pi)), var 1
        let mean = mu_std * (pi / (1.0 - pi)).sqrt();
        let mut row = vec![pi, mean, mean - 1.96, mean + 1.96];
        row.extend(zs.iter().copied());
        sink.row(&row);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig1_and_10_smoke() {
        std::env::set_var("AUSTERITY_FIGURES", "/tmp/austerity_fig_smoke");
        run_fig1_and_fig10(Scale(0.02));
        let text =
            std::fs::read_to_string("/tmp/austerity_fig_smoke/fig1_error.csv").unwrap();
        assert!(text.lines().count() > 10);
        let usage =
            std::fs::read_to_string("/tmp/austerity_fig_smoke/fig10_data_usage.csv").unwrap();
        assert!(usage.lines().count() > 10);
    }

    #[test]
    fn fig7_smoke() {
        std::env::set_var("AUSTERITY_FIGURES", "/tmp/austerity_fig_smoke");
        run_fig7(Scale(0.01));
        let text =
            std::fs::read_to_string("/tmp/austerity_fig_smoke/fig7_tstat.csv").unwrap();
        assert!(text.lines().count() > 100);
    }

    #[test]
    fn fig8_smoke() {
        std::env::set_var("AUSTERITY_FIGURES", "/tmp/austerity_fig_smoke");
        run_fig8(Scale(1.0));
        let text =
            std::fs::read_to_string("/tmp/austerity_fig_smoke/fig8_walk.csv").unwrap();
        assert!(text.lines().count() >= 15);
    }
}

//! Fig. 6 — optimal sequential test design on the ICA posterior (§6.5):
//! average design (Eqn. 7) vs fixed-m heuristic vs worst-case design
//! (Eqn. 8), evaluated on held-out (theta, theta') pairs: achieved
//! average |Delta| and data usage vs the target training error.

use crate::coordinator::delta::PairStats;
use crate::coordinator::design::{
    average_design, evaluate_design, fixed_m_design, worst_case_design, DesignGrid,
};
use crate::coordinator::mh::{mh_step, MhMode, MhScratch};
use crate::data::synthetic::ica_mixture;
use crate::exp::common::{FigureSink, Scale};
use crate::models::traits::{LlDiffModel, ProposalKernel};
use crate::models::IcaModel;
use crate::samplers::StiefelRandomWalk;
use crate::stats::Pcg64;

/// Harvest (mu, sigma_l) pair statistics from an exact ICA trial chain.
/// log_correction = 0: symmetric proposal, uniform manifold prior.
pub fn harvest_ica_pairs(model: &IcaModel, count: usize, stride: usize, seed: u64) -> Vec<PairStats> {
    let kernel = StiefelRandomWalk::new(0.03);
    let mut rng = Pcg64::new(seed, 31);
    let mut scratch = MhScratch::new(model.n());
    let mut cur = crate::data::linalg::random_orthonormal(model.d(), &mut rng);
    let mode = MhMode::Exact;
    let mut out = Vec::with_capacity(count);
    while out.len() < count {
        for _ in 0..stride {
            let prop = kernel.propose(&cur, &mut rng);
            mh_step(model, &mut cur, prop, &mode, &mut scratch, &mut rng);
        }
        let prop = kernel.propose(&cur, &mut rng);
        let mu = model.full_mean(&cur, &prop.param);
        let sigma_l = model.full_std(&cur, &prop.param);
        out.push(PairStats { mu, sigma_l, log_correction: 0.0 });
    }
    out
}

pub struct Fig6Row {
    pub method: &'static str,
    pub target: f64,
    pub m: usize,
    pub eps: f64,
    pub test_error: f64,
    pub test_usage: f64,
}

pub fn run_fig6(scale: Scale) -> Vec<Fig6Row> {
    let n = scale.n(195_000);
    let (obs, _) = ica_mixture(n, 21);
    let model = IcaModel::new(obs);
    let pair_count = scale.steps(100).min(100).max(8);
    let train = harvest_ica_pairs(&model, pair_count, 3, 1);
    let test = harvest_ica_pairs(&model, pair_count, 3, 2);

    let grid = DesignGrid {
        m_grid: vec![100, 200, 400, 600, 1000, 2000],
        eps_grid: vec![0.0005, 0.001, 0.005, 0.01, 0.02, 0.05, 0.1, 0.2],
        dp_grid: 64,
        table_points: 17,
        mu_max: 12.0,
        panels: 12,
    };
    let targets = [0.001, 0.005, 0.01, 0.02, 0.05];

    let mut sink = FigureSink::new("fig6_design");
    sink.header(&["method", "target", "m", "eps", "test_error", "test_usage"]);
    let mut rows = Vec::new();
    let n = model.n();

    let push = |sink: &mut FigureSink,
                    rows: &mut Vec<Fig6Row>,
                    method: &'static str,
                    target: f64,
                    m: usize,
                    eps: f64| {
        let (err, usage) = evaluate_design(n, &test, m, eps, &grid);
        sink.row_tagged(method, &[target, m as f64, eps, err, usage]);
        rows.push(Fig6Row { method, target, m, eps, test_error: err, test_usage: usage });
    };

    for &target in &targets {
        if let Some(d) = average_design(n, &train, target, &grid) {
            push(&mut sink, &mut rows, "average", target, d.m, d.eps);
        }
        if let Some(d) = fixed_m_design(n, &train, 600, target, &grid) {
            push(&mut sink, &mut rows, "fixed_m600", target, d.m, d.eps);
        }
        if let Some(d) = worst_case_design(n, target, &grid) {
            push(&mut sink, &mut rows, "worst_case", target, d.m, d.eps);
        }
    }
    rows
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig6_average_design_uses_less_data_than_worst() {
        std::env::set_var("AUSTERITY_FIGURES", "/tmp/austerity_fig_smoke");
        let rows = run_fig6(Scale(0.01));
        assert!(!rows.is_empty());
        // compare at matched targets where both methods are feasible
        for t in [0.01f64, 0.02, 0.05] {
            let avg = rows.iter().find(|r| r.method == "average" && r.target == t);
            let worst = rows.iter().find(|r| r.method == "worst_case" && r.target == t);
            if let (Some(a), Some(w)) = (avg, worst) {
                assert!(
                    a.test_usage <= w.test_usage + 1e-9,
                    "target {t}: avg {} vs worst {}",
                    a.test_usage,
                    w.test_usage
                );
                // worst-case achieves much smaller error than requested
                assert!(w.test_error <= t + 1e-9);
            }
        }
    }
}

//! Shared setup for the analysis figures: the synthetic-MNIST logistic
//! population (§6.1) and (theta, theta') pairs harvested from a trial
//! chain, reduced to the (mu, sigma_l, log_correction) statistics the
//! §5 analysis consumes.

use crate::coordinator::delta::PairStats;
use crate::coordinator::mh::{mh_step, MhMode, MhScratch};
use crate::data::synthetic::two_class_gaussian;
use crate::models::traits::{LlDiffModel, ProposalKernel};
use crate::models::LogisticModel;
use crate::samplers::GaussianRandomWalk;
use crate::stats::Pcg64;

/// The paper's §6.1 target at a configurable N (default 12214, D=50).
pub fn mnist_like_model(n: usize, seed: u64) -> LogisticModel {
    LogisticModel::new(two_class_gaussian(n, 50, 1.2, seed), 10.0).expect("population exceeds the u32 index space")
}

/// The l_i population for one (theta, theta') pair.
pub struct LPopulation {
    pub ls: Vec<f64>,
    pub mu: f64,
    pub sigma_l: f64,
    pub log_correction: f64,
}

/// Run a short exact trial chain and harvest `count` (theta, theta')
/// pairs (every `stride` steps), returning their l-populations.
pub fn harvest_pairs(
    model: &LogisticModel,
    sigma_rw: f64,
    count: usize,
    stride: usize,
    seed: u64,
) -> Vec<LPopulation> {
    let kernel = GaussianRandomWalk::new(sigma_rw, model.prior_precision);
    let mut rng = Pcg64::new(seed, 21);
    let mut scratch = MhScratch::new(model.n());
    let mut cur = model.map_estimate(50);
    let mode = MhMode::Exact;
    let mut out = Vec::with_capacity(count);

    while out.len() < count {
        for _ in 0..stride {
            let prop = kernel.propose(&cur, &mut rng);
            mh_step(model, &mut cur, prop, &mode, &mut scratch, &mut rng);
        }
        let prop = kernel.propose(&cur, &mut rng);
        let ls: Vec<f64> = (0..model.n())
            .map(|i| model.lldiff(i, &cur, &prop.param))
            .collect();
        let n = ls.len() as f64;
        let mu = ls.iter().sum::<f64>() / n;
        let var = ls.iter().map(|l| (l - mu) * (l - mu)).sum::<f64>() / n;
        out.push(LPopulation {
            ls,
            mu,
            sigma_l: var.sqrt(),
            log_correction: prop.log_correction,
        });
    }
    out
}

impl LPopulation {
    pub fn stats(&self) -> PairStats {
        PairStats { mu: self.mu, sigma_l: self.sigma_l, log_correction: self.log_correction }
    }
}

/// A fixed l-population as an `LlDiffModel` (for running sequential tests
/// directly against a chosen mu_0).
pub struct FixedLs<'a>(pub &'a [f64]);

impl LlDiffModel for FixedLs<'_> {
    type Param = ();

    fn n(&self) -> usize {
        self.0.len()
    }

    fn lldiff(&self, i: usize, _: &(), _: &()) -> f64 {
        self.0[i]
    }

    fn lldiff_moments(&self, idx: &[u32], _: &(), _: &()) -> (f64, f64) {
        let (mut s, mut s2) = (0.0, 0.0);
        for &i in idx {
            let l = self.0[i as usize];
            s += l;
            s2 += l * l;
        }
        (s, s2)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn harvest_produces_finite_stats() {
        let model = mnist_like_model(2_000, 0);
        let pops = harvest_pairs(&model, 0.01, 3, 2, 1);
        assert_eq!(pops.len(), 3);
        for p in &pops {
            assert_eq!(p.ls.len(), 2_000);
            assert!(p.sigma_l > 0.0 && p.sigma_l.is_finite());
            assert!(p.mu.is_finite() && p.log_correction.is_finite());
            // mu should be small relative to sigma_l * sqrt(N) (near-
            // stationary proposals are near-ties)
            assert!(p.mu.abs() < 1.0);
        }
    }
}

//! Generic risk-vs-time driver behind Figs. 2, 3 and 4: run replica
//! chains per epsilon, stream a vector test function, and report the
//! chain-averaged MSE against ground truth at wall-clock checkpoints.

use std::time::Instant;

use crate::coordinator::engine::parallel_map;
use crate::coordinator::mh::{mh_step, MhMode, MhScratch};
use crate::metrics::risk::{risk_curve, Checkpoints, RiskCurve};
use crate::models::traits::{LlDiffModel, ProposalKernel};
use crate::stats::Pcg64;

/// Configuration for one risk experiment.
#[derive(Clone, Debug)]
pub struct RiskConfig {
    /// epsilon = 0 means the exact MH baseline.
    pub eps_values: Vec<f64>,
    pub batch: usize,
    pub chains: usize,
    /// wall-clock budget per chain (seconds)
    pub secs: f64,
    pub checkpoints: usize,
    pub burn_in_steps: usize,
    pub thin: usize,
    pub base_seed: u64,
}

/// Per-epsilon result.
pub struct EpsRisk {
    pub eps: f64,
    pub curve: RiskCurve,
    /// mean fraction of data used per MH test (averaged over chains)
    pub data_fraction: f64,
    pub acceptance: f64,
    pub steps_per_sec: f64,
}

/// Run one chain, recording MSE against `truth` at each checkpoint.
#[allow(clippy::too_many_arguments)]
fn run_one_chain<M, K, F>(
    model: &M,
    kernel: &K,
    mode: &MhMode,
    init: M::Param,
    truth: &[f64],
    test_fn: &F,
    cfg: &RiskConfig,
    checks: &Checkpoints,
    seed: u64,
) -> (Vec<f64>, f64, f64, f64)
where
    M: LlDiffModel + Sync,
    K: ProposalKernel<M::Param>,
    F: Fn(&M::Param) -> Vec<f64>,
{
    let mut rng = Pcg64::new(seed, 11);
    let mut scratch = MhScratch::new(model.n());
    let mut cur = init;
    let mut sums = vec![0.0f64; truth.len()];
    let mut count = 0u64;
    let mut errors = vec![f64::NAN; checks.len()];
    let mut next_cp = 0usize;
    let mut steps = 0usize;
    let mut accepted = 0usize;
    let mut data_used = 0u64;
    let start = Instant::now();

    loop {
        let elapsed = start.elapsed().as_secs_f64();
        while next_cp < checks.len() && elapsed >= checks.at_secs[next_cp] {
            if count > 0 {
                let mse = sums
                    .iter()
                    .zip(truth)
                    .map(|(s, t)| {
                        let d = s / count as f64 - t;
                        d * d
                    })
                    .sum::<f64>()
                    / truth.len() as f64;
                errors[next_cp] = mse;
            }
            next_cp += 1;
        }
        if next_cp >= checks.len() || elapsed >= cfg.secs {
            break;
        }
        let proposal = kernel.propose(&cur, &mut rng);
        let info = mh_step(model, &mut cur, proposal, mode, &mut scratch, &mut rng);
        steps += 1;
        accepted += info.accepted as usize;
        data_used += info.n_used as u64;
        if steps > cfg.burn_in_steps && steps % cfg.thin == 0 {
            let v = test_fn(&cur);
            debug_assert_eq!(v.len(), truth.len());
            for (s, x) in sums.iter_mut().zip(&v) {
                *s += x;
            }
            count += 1;
        }
    }
    let wall = start.elapsed().as_secs_f64();
    (
        errors,
        data_used as f64 / (steps.max(1) as f64 * model.n() as f64),
        accepted as f64 / steps.max(1) as f64,
        steps as f64 / wall,
    )
}

/// Run the full experiment: all epsilons, all chains (chains fan out
/// over the engine's worker pool).
pub fn risk_vs_time<M, K, F>(
    model: &M,
    kernel: &K,
    init: M::Param,
    truth: &[f64],
    test_fn: F,
    cfg: &RiskConfig,
) -> Vec<EpsRisk>
where
    M: LlDiffModel + Sync,
    K: ProposalKernel<M::Param> + Sync,
    M::Param: Clone + Send + Sync,
    F: Fn(&M::Param) -> Vec<f64> + Sync,
{
    let checks = Checkpoints::log_spaced(
        (cfg.secs / 100.0).max(0.05),
        cfg.secs,
        cfg.checkpoints,
    );
    let mut out = Vec::new();
    for (ei, &eps) in cfg.eps_values.iter().enumerate() {
        let mode = MhMode::approx(eps, cfg.batch);
        let results: Vec<(Vec<f64>, f64, f64, f64)> =
            parallel_map(cfg.chains, 0, |c| {
                run_one_chain(
                    model,
                    kernel,
                    &mode,
                    init.clone(),
                    truth,
                    &test_fn,
                    cfg,
                    &checks,
                    cfg.base_seed + (ei * 1000 + c) as u64,
                )
            });
        let errors: Vec<Vec<f64>> = results.iter().map(|r| r.0.clone()).collect();
        let k = results.len() as f64;
        out.push(EpsRisk {
            eps,
            curve: risk_curve(&checks.at_secs, &errors),
            data_fraction: results.iter().map(|r| r.1).sum::<f64>() / k,
            acceptance: results.iter().map(|r| r.2).sum::<f64>() / k,
            steps_per_sec: results.iter().map(|r| r.3).sum::<f64>() / k,
        });
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::two_class_gaussian;
    use crate::models::LogisticModel;
    use crate::samplers::GaussianRandomWalk;

    #[test]
    fn smoke_risk_driver_orders_data_usage() {
        let model = LogisticModel::new(two_class_gaussian(4_000, 5, 1.2, 0), 10.0).expect("population exceeds the u32 index space");
        let map = model.map_estimate(40);
        let kernel = GaussianRandomWalk::new(0.02, 10.0);
        let truth: Vec<f64> = (0..model.n().min(50))
            .map(|i| model.predict(model.data().row(i), &map))
            .collect();
        let rows: Vec<usize> = (0..50).collect();
        let cfg = RiskConfig {
            eps_values: vec![0.0, 0.1],
            batch: 500,
            chains: 2,
            secs: 0.6,
            checkpoints: 4,
            burn_in_steps: 5,
            thin: 1,
            base_seed: 3,
        };
        let out = risk_vs_time(
            &model,
            &kernel,
            map.clone(),
            &truth,
            |p| rows.iter().map(|&i| model.predict(model.data().row(i), p)).collect(),
            &cfg,
        );
        assert_eq!(out.len(), 2);
        // exact uses the full dataset every step; approximate uses less
        assert!((out[0].data_fraction - 1.0).abs() < 1e-9);
        assert!(out[1].data_fraction < 1.0);
        // approximate generates more steps per second
        assert!(out[1].steps_per_sec > out[0].steps_per_sec);
        // risk columns populated at the late checkpoints
        assert!(out[0].curve.risk.last().unwrap().is_finite());
        assert!(out[1].curve.risk.last().unwrap().is_finite());
    }
}

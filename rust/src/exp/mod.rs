//! Experiment drivers regenerating every figure in the paper's
//! evaluation (see the per-experiment index in DESIGN.md §Experiment
//! index):
//!
//! | driver                 | paper figure(s)              |
//! |------------------------|------------------------------|
//! | `fig_analysis`         | 1, 7, 8, 10                  |
//! | `fig_risk`             | 2, 3, 4                      |
//! | `fig_sgld`             | 5                            |
//! | `fig_design`           | 6                            |
//! | `fig_delta`            | 11, 12                       |
//! | `fig_rj`               | 13                           |
//! | `fig_gibbs`            | 14, 15                       |
//! | `fig_accept`           | acceptance-rule comparison (extension) |
//!
//! All drivers write CSV series to `target/figures/` (override with
//! `AUSTERITY_FIGURES`) and take a `Scale` so the bench harness, the CLI
//! and the test suite can run them at different sizes.

pub mod ablation;
pub mod common;
pub mod fig_accept;
pub mod fig_analysis;
pub mod fig_delta;
pub mod fig_design;
pub mod fig_gibbs;
pub mod fig_risk;
pub mod fig_rj;
pub mod fig_sgld;
pub mod population;
pub mod risk_driver;

pub use common::{figures_dir, FigureSink, Scale};

/// Run a named figure at the given scale; returns false for unknown names.
pub fn run_figure(name: &str, scale: Scale) -> bool {
    match name {
        "fig1" | "fig10" => fig_analysis::run_fig1_and_fig10(scale),
        "fig2" => {
            fig_risk::run_fig2(scale);
        }
        "fig3" => {
            fig_risk::run_fig3(scale);
        }
        "fig4" => {
            fig_risk::run_fig4(scale);
        }
        "fig5" => {
            fig_sgld::run_fig5(scale);
        }
        "fig6" => {
            fig_design::run_fig6(scale);
        }
        "fig7" => fig_analysis::run_fig7(scale),
        "fig8" => fig_analysis::run_fig8(scale),
        "fig11" | "fig12" => {
            fig_delta::run_fig11_and_fig12(scale);
        }
        "fig13" => {
            fig_rj::run_fig13(scale);
        }
        "fig14" => {
            fig_gibbs::run_fig14(scale);
        }
        "fig15" => {
            fig_gibbs::run_fig15(scale);
        }
        "fig_accept" => {
            fig_accept::run_fig_accept(scale);
        }
        "ablations" => ablation::run_all(scale),
        _ => return false,
    }
    true
}

/// All figure names in paper order (plus the acceptance-rule extension).
pub const ALL_FIGURES: &[&str] = &[
    "fig1", "fig2", "fig3", "fig4", "fig5", "fig6", "fig7", "fig8", "fig10", "fig11",
    "fig12", "fig13", "fig14", "fig15", "fig_accept",
];

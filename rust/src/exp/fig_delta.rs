//! Figs. 11 and 12 (supplementary B): the acceptance-probability error.
//!   Fig. 11 — Delta vs exact Pa, with E_u|E| and the worst-case bound
//!   Fig. 12 — approximate Pa (analytic and measured) vs true Pa

use crate::coordinator::austerity::SeqTestConfig;
use crate::coordinator::delta::{
    approx_accept_prob, delta_accept_prob, exact_accept_prob, mean_abs_error, SeqTestTable,
};
use crate::coordinator::scheduler::MinibatchScheduler;
use crate::exp::common::{FigureSink, Scale};
use crate::exp::population::{harvest_pairs, mnist_like_model, FixedLs};
use crate::stats::Pcg64;

pub struct DeltaPoint {
    pub pa: f64,
    pub delta: f64,
    pub mean_abs_e: f64,
    pub pa_approx_analytic: f64,
    pub pa_approx_measured: f64,
}

pub fn run_fig11_and_fig12(scale: Scale) -> Vec<DeltaPoint> {
    let n = scale.n(12_214);
    let m = 500usize.min(n / 4).max(16);
    let eps = 0.05;
    let model = mnist_like_model(n, 42);
    let pair_count = scale.steps(40).min(60).max(6);
    let pops = harvest_pairs(&model, 0.01, pair_count, 2, 3);
    let table = SeqTestTable::build(m, n, eps, 12.0, 21, 128);
    let worst = table.error(0.0);

    let mut f11 = FigureSink::new("fig11_delta_vs_pa");
    f11.header(&["pa", "delta", "mean_abs_e", "worst_bound"]);
    let mut f12 = FigureSink::new("fig12_approx_pa");
    f12.header(&["pa_true", "pa_approx_analytic", "pa_approx_measured"]);

    let trials = scale.steps(400).max(50);
    let cfg = SeqTestConfig::new(eps, m);
    let mut out = Vec::new();
    let mut rng = Pcg64::seeded(17);

    for pop in &pops {
        let stats = pop.stats();
        let pa = exact_accept_prob(n, &stats);
        let delta = delta_accept_prob(n, &stats, &table, 24);
        let mean_e = mean_abs_error(n, &stats, &table, 24);
        let pa_analytic = approx_accept_prob(n, &stats, &table, 24);

        // measured: run the actual sequential test with fresh u each time
        let fixed = FixedLs(&pop.ls);
        let mut sched = MinibatchScheduler::new(n).expect("population exceeds the u32 index space");
        let mut accepts = 0usize;
        for _ in 0..trials {
            let u = rng.uniform_pos();
            let mu0 = (u.ln() + pop.log_correction) / n as f64;
            let o = crate::coordinator::austerity::seq_mh_test(
                &fixed, &(), &(), mu0, &cfg, &mut sched, &mut rng,
            );
            accepts += o.accept as usize;
        }
        let pa_measured = accepts as f64 / trials as f64;

        f11.row(&[pa, delta, mean_e, worst]);
        f12.row(&[pa, pa_analytic, pa_measured]);
        out.push(DeltaPoint {
            pa,
            delta,
            mean_abs_e: mean_e,
            pa_approx_analytic: pa_analytic,
            pa_approx_measured: pa_measured,
        });
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig11_12_deltas_bounded_and_consistent() {
        std::env::set_var("AUSTERITY_FIGURES", "/tmp/austerity_fig_smoke");
        let pts = run_fig11_and_fig12(Scale(0.05));
        assert!(!pts.is_empty());
        for p in &pts {
            // |Delta| <= E_u|E| <= worst bound (cancellation claim)
            assert!(p.delta.abs() <= p.mean_abs_e + 1e-9, "{} vs {}", p.delta, p.mean_abs_e);
            // analytic and measured approximate Pa agree reasonably
            assert!(
                (p.pa_approx_analytic - p.pa_approx_measured).abs() < 0.2,
                "analytic {} measured {}",
                p.pa_approx_analytic,
                p.pa_approx_measured
            );
        }
    }
}

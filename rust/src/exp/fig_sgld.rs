//! Fig. 5 — the SGLD pitfall (paper §6.4): (a) true posterior density,
//! (b) gradient of the log posterior, (c) histogram of uncorrected SGLD
//! samples, (d) histogram of SGLD corrected by the approximate MH test.
//!
//! The pitfall at the posterior's own resolution: the L1 prior makes the
//! gradient jump by 2*lam0 = 9900 at theta = 0 and grow fast left of the
//! mode, so uncorrected SGLD at alpha = 5e-6 takes steps ~10x the true
//! posterior std — the empirical histogram is right-shifted and an order
//! of magnitude too wide, while the corrected chain matches the truth.
//!
//! Both samplers run as `SgldKernel` chains on the multi-chain engine
//! (K = 2), so the histograms pool independent streams and the summary
//! carries cross-chain R-hat / ESS.

use crate::coordinator::austerity::SeqTestConfig;
use crate::coordinator::chain::Budget;
use crate::coordinator::record::Param;
use crate::coordinator::session::{KernelSession, RunReport};
use crate::data::synthetic::linreg_toy;
use crate::exp::common::{FigureSink, Scale};
use crate::models::LinRegModel;
use crate::samplers::sgld::{SgldConfig, SgldKernel};
use crate::stats::welford::Welford;
use crate::stats::Histogram;

pub struct Fig5Summary {
    pub true_mean: f64,
    pub true_std: f64,
    pub mean_uncorrected: f64,
    pub std_uncorrected: f64,
    pub mean_corrected: f64,
    pub std_corrected: f64,
    /// L1 distance of each histogram to the true posterior density
    pub l1_uncorrected: f64,
    pub l1_corrected: f64,
    /// Cross-chain split R-hat of each sampler (engine diagnostics).
    pub rhat_uncorrected: f64,
    pub rhat_corrected: f64,
    pub ess_corrected: f64,
}

/// 2-chain `KernelSession` launch of the SGLD kernel; the default
/// recorder streams theta (the scalar chain state).
fn run_sgld_engine(
    model: &LinRegModel,
    cfg: SgldConfig,
    init: f64,
    steps: usize,
    burn_in: usize,
    seed: u64,
) -> RunReport<Param> {
    let chains = 2usize;
    let kernel = SgldKernel { model, cfg };
    KernelSession::new(&kernel)
        .label("sgld")
        .chains(chains)
        .seed(seed)
        .budget(Budget::Steps((steps / chains).max(1)))
        .burn_in(burn_in / chains)
        .init(init)
        .run()
}

pub fn run_fig5(scale: Scale) -> Fig5Summary {
    let model = LinRegModel::new(linreg_toy(10_000, 0), 3.0, 4950.0).expect("population exceeds the u32 index space");

    // locate the true posterior on a wide grid first
    let (wide_grid, wide_dens) = model.posterior_density(-0.2, 0.8, 2_000);
    let (mut t_mean, mut t2) = (0.0, 0.0);
    let h = wide_grid[1] - wide_grid[0];
    for (t, d) in wide_grid.iter().zip(&wide_dens) {
        t_mean += t * d * h;
        t2 += t * t * d * h;
    }
    let t_std = (t2 - t_mean * t_mean).max(0.0).sqrt();

    // panels (a) and (b) on a window around the mode (paper Fig. 5a/b)
    let (lo, hi) = (t_mean - 15.0 * t_std, t_mean + 15.0 * t_std);
    let mut sink_ab = FigureSink::new("fig5ab_density_grad");
    sink_ab.header(&["theta", "posterior_density", "grad_log_post"]);
    let (grid, dens) = model.posterior_density(lo, hi, 200);
    let all: Vec<usize> = (0..model.data().n()).collect();
    for (t, d) in grid.iter().zip(&dens) {
        sink_ab.row(&[*t, *d, model.grad_log_post(*t, &all)]);
    }

    // panels (c) and (d): SGLD histograms at the same resolution.
    // The paper does not specify the SGLD gradient mini-batch size; 50
    // makes the stochastic-gradient noise (scaled by N/n) pronounced, as
    // in the paper's Fig. 5(c) histogram.
    let steps = scale.steps(100_000);
    let burn = steps / 5;
    let uncorrected = SgldConfig { alpha: 5e-6, grad_batch: 50, correction: None };
    let res_un = run_sgld_engine(&model, uncorrected, t_mean, steps, burn, 3);
    let corrected = SgldConfig {
        alpha: 5e-6,
        grad_batch: 50,
        correction: Some(SeqTestConfig::new(0.5, 500)),
    };
    let res_co = run_sgld_engine(&model, corrected, t_mean, steps, burn, 4);
    let s_un: Vec<f64> = res_un.values().into_iter().flatten().collect();
    let s_co: Vec<f64> = res_co.values().into_iter().flatten().collect();

    let bins = 60usize;
    let mut h_un = Histogram::new(lo, hi, bins);
    h_un.add_all(&s_un);
    let mut h_co = Histogram::new(lo, hi, bins);
    h_co.add_all(&s_co);

    let mut sink_cd = FigureSink::new("fig5cd_histograms");
    sink_cd.header(&["theta", "uncorrected_density", "corrected_density", "true_density"]);
    let dens_at = |t: f64| {
        let idx = (((t - lo) / (hi - lo)) * 199.0).round().clamp(0.0, 199.0) as usize;
        dens[idx]
    };
    for b in 0..bins {
        let c = h_un.center(b);
        sink_cd.row(&[c, h_un.density(b), h_co.density(b), dens_at(c)]);
    }

    let moments = |s: &[f64]| {
        let mut w = Welford::new();
        for &v in s {
            w.add(v);
        }
        (w.mean(), w.var_pop().sqrt())
    };
    let (m_un, sd_un) = moments(&s_un);
    let (m_co, sd_co) = moments(&s_co);
    let summary = Fig5Summary {
        true_mean: t_mean,
        true_std: t_std,
        mean_uncorrected: m_un,
        std_uncorrected: sd_un,
        mean_corrected: m_co,
        std_corrected: sd_co,
        l1_uncorrected: h_un.l1_vs_density(dens_at),
        l1_corrected: h_co.l1_vs_density(dens_at),
        rhat_uncorrected: res_un.convergence.rhat,
        rhat_corrected: res_co.convergence.rhat,
        ess_corrected: res_co.convergence.ess,
    };
    let mut meta = FigureSink::new("fig5_summary");
    meta.header(&[
        "true_mean",
        "true_std",
        "mean_unc",
        "std_unc",
        "mean_cor",
        "std_cor",
        "l1_unc",
        "l1_cor",
        "accept_rate_cor",
        "rhat_unc",
        "rhat_cor",
        "ess_cor",
    ]);
    meta.row(&[
        summary.true_mean,
        summary.true_std,
        summary.mean_uncorrected,
        summary.std_uncorrected,
        summary.mean_corrected,
        summary.std_corrected,
        summary.l1_uncorrected,
        summary.l1_corrected,
        res_co.merged.acceptance_rate(),
        summary.rhat_uncorrected,
        summary.rhat_corrected,
        summary.ess_corrected,
    ]);
    summary
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig5_corrected_tracks_posterior_better() {
        std::env::set_var("AUSTERITY_FIGURES", "/tmp/austerity_fig_smoke");
        let s = run_fig5(Scale(0.3));
        // The paper's qualitative claim, quantified at the posterior's
        // own resolution: the uncorrected sampler is far too wide, the
        // corrected one matches the truth much more closely.
        assert!(
            s.std_uncorrected > 2.0 * s.std_corrected,
            "unc std {} vs cor std {}",
            s.std_uncorrected,
            s.std_corrected
        );
        assert!(
            s.l1_corrected < s.l1_uncorrected,
            "corrected L1 {} vs uncorrected {}",
            s.l1_corrected,
            s.l1_uncorrected
        );
        // corrected mean within a few true-stds of the true mean
        assert!(
            (s.mean_corrected - s.true_mean).abs() < 6.0 * s.true_std,
            "cor mean {} vs true {} (std {})",
            s.mean_corrected,
            s.true_mean,
            s.true_std
        );
        // engine diagnostics are populated for both samplers
        assert!(s.rhat_corrected.is_finite(), "rhat {}", s.rhat_corrected);
        assert!(s.ess_corrected > 0.0, "ess {}", s.ess_corrected);
    }
}

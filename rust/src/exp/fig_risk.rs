//! Risk-vs-time figures:
//!   Fig. 2 — random-walk logistic regression, risk of predictive mean
//!   Fig. 3 — ICA on the Stiefel manifold, risk of E[Amari distance]
//!   Fig. 4 — reversible-jump variable selection, risk of predictive mean
//!
//! Each: estimate ground truth from parallel exact chains (the engine
//! merges their streams), then run replica chains per epsilon and report
//! chain-averaged MSE at time checkpoints.

use std::time::Duration;

use crate::coordinator::chain::Budget;
use crate::coordinator::record::{ScalarFn, VecMean};
use crate::coordinator::session::Session;
use crate::data::linalg::Mat;
use crate::data::synthetic::{ica_mixture, sparse_logistic};
use crate::exp::common::{FigureSink, Scale};
use crate::exp::population::mnist_like_model;
use crate::exp::risk_driver::{risk_vs_time, RiskConfig};
use crate::models::ica::amari_distance;
use crate::models::rjlogistic::{RjLogisticModel, RjState};
use crate::models::{IcaModel, LlDiffModel};
use crate::samplers::{GaussianRandomWalk, RjKernel, StiefelRandomWalk};
use crate::stats::Pcg64;

fn emit(sink: &mut FigureSink, results: &[crate::exp::risk_driver::EpsRisk]) {
    sink.header(&["eps", "t_secs", "risk", "chains", "data_fraction", "acceptance", "steps_per_sec"]);
    for r in results {
        for (i, &t) in r.curve.at_secs.iter().enumerate() {
            sink.row(&[
                r.eps,
                t,
                r.curve.risk[i],
                r.curve.chains[i] as f64,
                r.data_fraction,
                r.acceptance,
                r.steps_per_sec,
            ]);
        }
    }
}

/// Fig. 2. Returns (eps, final risk) pairs for assertions.
pub fn run_fig2(scale: Scale) -> Vec<(f64, f64)> {
    let n = scale.n(12_214);
    let n_test = scale.n(2_037).min(n);
    let model = mnist_like_model(n, 42);
    let test = mnist_like_model(n_test, 43); // held-out panel
    let map = model.map_estimate(80);
    let kernel = GaussianRandomWalk::new(0.01, model.prior_precision);

    let predict = |theta: &Vec<f64>| -> Vec<f64> {
        (0..test.n()).map(|i| test.predict(test.data().row(i), theta)).collect()
    };

    // ground truth: parallel exact chains (the Session picks the cached
    // fast path for the logistic model; stands in for the paper's HMC
    // run)
    let gt_secs = scale.secs(60.0);
    let gt = Session::new(&model)
        .kernel(&kernel)
        .chains(2)
        .seed(5)
        .budget(Budget::Wall(Duration::from_secs_f64(gt_secs)))
        .burn_in(50)
        .thin(2)
        .record_with(|_c| VecMean::new(test.n(), &predict))
        .init(map.clone())
        .run();
    let truth = VecMean::merged(&gt.observers).mean();

    let cfg = RiskConfig {
        eps_values: vec![0.0, 0.01, 0.05, 0.1, 0.2],
        batch: 500.min(n / 4).max(16),
        chains: 5,
        secs: scale.secs(30.0),
        checkpoints: 10,
        burn_in_steps: 20,
        thin: 2,
        base_seed: 77,
    };
    let results = risk_vs_time(&model, &kernel, map, &truth, predict, &cfg);
    let mut sink = FigureSink::new("fig2_logistic_risk");
    emit(&mut sink, &results);
    results
        .iter()
        .map(|r| (r.eps, *r.curve.risk.last().unwrap()))
        .collect()
}

/// Fig. 3. Returns (eps, final risk).
pub fn run_fig3(scale: Scale) -> Vec<(f64, f64)> {
    let n = scale.n(195_000);
    let (obs, w0) = ica_mixture(n, 11);
    let model = IcaModel::new(obs);
    let kernel = StiefelRandomWalk::new(0.03);
    let init = w0.clone(); // start near truth; burn-in handles the rest

    let test_fn = {
        let w0 = w0.clone();
        move |w: &Mat| vec![amari_distance(w, &w0)]
    };

    // ground truth E[amari] from parallel exact chains
    let gt_secs = scale.secs(120.0);
    let w0c = w0.clone();
    let gt = Session::new(&model)
        .kernel(&kernel)
        .chains(2)
        .seed(6)
        .budget(Budget::Wall(Duration::from_secs_f64(gt_secs)))
        .burn_in(20)
        .record(ScalarFn::new(move |w: &Mat| amari_distance(w, &w0c)))
        .init(init.clone())
        .run();
    let truth = vec![if gt.convergence.n_samples > 0 { gt.pooled_mean() } else { 0.0 }];

    let cfg = RiskConfig {
        eps_values: vec![0.0, 0.01, 0.05, 0.1, 0.2],
        batch: 600.min(model.n() / 4).max(16),
        chains: 5,
        secs: scale.secs(60.0),
        checkpoints: 10,
        burn_in_steps: 20,
        thin: 1,
        base_seed: 78,
    };
    let results = risk_vs_time(&model, &kernel, init, &truth, test_fn, &cfg);
    let mut sink = FigureSink::new("fig3_ica_risk");
    emit(&mut sink, &results);
    results.iter().map(|r| (r.eps, *r.curve.risk.last().unwrap())).collect()
}

/// Fig. 4. Returns (eps, final risk).
pub fn run_fig4(scale: Scale) -> Vec<(f64, f64)> {
    let n = scale.n(130_065);
    let d = 51;
    let (ds, _beta) = sparse_logistic(n, d, 12, 0.28, 13);
    let mut rng = Pcg64::seeded(9);
    let (train, test) = ds.split(0.8, &mut rng);
    let model = RjLogisticModel::new(train, 1e-10);
    let kernel = RjKernel::new(&model);
    let init = RjState::with_active(d, &[0], &[-0.9]);
    let n_test = test.n().min(scale.n(2_000));

    let predict = {
        let test = test.clone();
        move |s: &RjState| -> Vec<f64> {
            (0..n_test).map(|i| model_predict(&test, i, s)).collect()
        }
    };

    let gt_secs = scale.secs(90.0);
    let gt = Session::new(&model)
        .kernel(&kernel)
        .chains(2)
        .seed(10)
        .budget(Budget::Wall(Duration::from_secs_f64(gt_secs)))
        .burn_in(100)
        .thin(2)
        .record_with(|_c| VecMean::new(n_test, &predict))
        .init(init.clone())
        .run();
    let truth = VecMean::merged(&gt.observers).mean();

    let cfg = RiskConfig {
        eps_values: vec![0.0, 0.01, 0.05, 0.1],
        batch: 500.min(model.n() / 4).max(16),
        chains: 5,
        secs: scale.secs(45.0),
        checkpoints: 10,
        burn_in_steps: 50,
        thin: 2,
        base_seed: 79,
    };
    let results = risk_vs_time(&model, &kernel, init, &truth, predict, &cfg);
    let mut sink = FigureSink::new("fig4_rjmcmc_risk");
    emit(&mut sink, &results);
    results.iter().map(|r| (r.eps, *r.curve.risk.last().unwrap())).collect()
}

fn model_predict(test: &crate::data::Dataset, i: usize, s: &RjState) -> f64 {
    crate::models::logistic::sigmoid(s.logit(test.row(i)))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig2_smoke_exact_uses_all_data() {
        std::env::set_var("AUSTERITY_FIGURES", "/tmp/austerity_fig_smoke");
        let out = run_fig2(Scale(0.01));
        assert_eq!(out.len(), 5);
        for (_, risk) in &out {
            assert!(risk.is_finite(), "{out:?}");
        }
    }

    #[test]
    fn fig3_smoke() {
        std::env::set_var("AUSTERITY_FIGURES", "/tmp/austerity_fig_smoke");
        let out = run_fig3(Scale(0.005));
        assert_eq!(out.len(), 5);
    }

    #[test]
    fn fig4_smoke() {
        std::env::set_var("AUSTERITY_FIGURES", "/tmp/austerity_fig_smoke");
        let out = run_fig4(Scale(0.005));
        assert_eq!(out.len(), 4);
    }
}

//! Shared experiment infrastructure: CSV emission (stdout + file under
//! `target/figures/`) and run-scale control so benches and the CLI can
//! run the same drivers at different sizes.

use std::io::Write;
use std::path::PathBuf;

/// Output sink for one figure: echoes rows to stdout and writes a CSV.
pub struct FigureSink {
    name: String,
    file: Option<std::fs::File>,
    quiet: bool,
}

impl FigureSink {
    pub fn new(name: &str) -> Self {
        let dir = figures_dir();
        let _ = std::fs::create_dir_all(&dir);
        let path = dir.join(format!("{name}.csv"));
        let file = std::fs::File::create(&path).ok();
        FigureSink { name: name.to_string(), file, quiet: false }
    }

    pub fn quiet(name: &str) -> Self {
        let mut s = Self::new(name);
        s.quiet = true;
        s
    }

    pub fn header(&mut self, cols: &[&str]) {
        self.line(&cols.join(","));
    }

    pub fn row(&mut self, values: &[f64]) {
        let s = values
            .iter()
            .map(|v| {
                if v.is_nan() {
                    "nan".to_string()
                } else {
                    format!("{v:.6e}")
                }
            })
            .collect::<Vec<_>>()
            .join(",");
        self.line(&s);
    }

    pub fn row_tagged(&mut self, tag: &str, values: &[f64]) {
        let mut s = tag.to_string();
        for v in values {
            s.push(',');
            if v.is_nan() {
                s.push_str("nan");
            } else {
                s.push_str(&format!("{v:.6e}"));
            }
        }
        self.line(&s);
    }

    fn line(&mut self, s: &str) {
        if !self.quiet {
            println!("[{}] {s}", self.name);
        }
        if let Some(f) = &mut self.file {
            let _ = writeln!(f, "{s}");
        }
    }
}

/// Where figure CSVs land.
pub fn figures_dir() -> PathBuf {
    std::env::var_os("AUSTERITY_FIGURES")
        .map(PathBuf::from)
        .unwrap_or_else(|| {
            PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("target").join("figures")
        })
}

/// Run-scale knob: 1.0 = paper scale, smaller = faster smoke runs.
#[derive(Clone, Copy, Debug)]
pub struct Scale(pub f64);

impl Scale {
    pub fn n(&self, full: usize) -> usize {
        ((full as f64 * self.0).round() as usize).max(16)
    }

    pub fn steps(&self, full: usize) -> usize {
        ((full as f64 * self.0).round() as usize).max(10)
    }

    pub fn secs(&self, full: f64) -> f64 {
        (full * self.0).max(0.2)
    }
}

impl Default for Scale {
    fn default() -> Self {
        Scale(1.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sink_writes_csv() {
        std::env::set_var("AUSTERITY_FIGURES", "/tmp/austerity_fig_test");
        let mut s = FigureSink::quiet("unit_test_sink");
        s.header(&["a", "b"]);
        s.row(&[1.0, f64::NAN]);
        s.row_tagged("tag", &[2.5]);
        drop(s);
        let text =
            std::fs::read_to_string("/tmp/austerity_fig_test/unit_test_sink.csv").unwrap();
        assert!(text.contains("a,b"));
        assert!(text.contains("nan"));
        assert!(text.contains("tag,2.5"));
        std::env::remove_var("AUSTERITY_FIGURES");
    }

    #[test]
    fn scale_clamps() {
        let s = Scale(0.001);
        assert_eq!(s.n(1000), 16);
        assert!(s.secs(10.0) >= 0.2);
        let full = Scale::default();
        assert_eq!(full.n(1000), 1000);
    }
}

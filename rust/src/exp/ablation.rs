//! Ablations for the design choices DESIGN.md calls out:
//!
//!  A1 — mini-batch size m: worst-case error vs data usage trade-off
//!  A2 — bound family: Pocock vs O'Brien-Fleming vs Wang-Tsiatis at
//!       matched worst-case error
//!  A3 — with- vs without-replacement mini-batches (the FPC term of
//!       Eqn. 4 assumes without)
//!  A4 — adaptive epsilon schedule vs fixed epsilons (paper §7
//!       future work)
//!  A5 — pseudo-marginal Poisson-estimator baseline vs the sequential
//!       test (the paper's §4 argument)

use crate::coordinator::adaptive::{run_adaptive_chain, EpsSchedule};
use crate::coordinator::austerity::{seq_mh_test, SeqTestConfig};
use crate::coordinator::chain::Budget;
use crate::coordinator::dp::{analyze_walk, uniform_pis};
use crate::coordinator::mh::MhMode;
use crate::coordinator::record::{Param, ScalarFn};
use crate::coordinator::session::{KernelSession, Session};
use crate::coordinator::scheduler::MinibatchScheduler;
use crate::exp::common::{FigureSink, Scale};
use crate::exp::population::{harvest_pairs, mnist_like_model, FixedLs};
use crate::samplers::pseudo_marginal::{PmKernel, PmPathology, PoissonEstimator};
use crate::samplers::GaussianRandomWalk;
use crate::stats::welford::Welford;
use crate::stats::{MomentAccumulator, Pcg64};
use crate::stats::student_t::t_sf;

/// A1: sweep m at fixed worst-case error target; report (m, eps needed,
/// usage at mu_std = 0 and at mu_std = 2).
pub fn ablation_batch_size(_scale: Scale) -> Vec<(usize, f64, f64)> {
    let n = 100_000;
    let mut sink = FigureSink::new("ablation_batch_size");
    sink.header(&["m", "worst_error", "usage_mu0", "usage_mu2"]);
    let mut out = Vec::new();
    for m in [50usize, 100, 200, 500, 1000, 2000, 5000] {
        let eps = 0.01;
        let worst = crate::coordinator::dp::analyze_pocock(0.0, m, n, eps, 128);
        let far = crate::coordinator::dp::analyze_pocock(2.0, m, n, eps, 128);
        sink.row(&[m as f64, worst.error, worst.expected_pi, far.expected_pi]);
        out.push((m, worst.expected_pi, far.expected_pi));
    }
    out
}

/// A2: bound families at matched G0 scale.
pub fn ablation_bound_family(_scale: Scale) -> Vec<(String, f64, f64)> {
    let n = 100_000;
    let m = 500;
    let pis = uniform_pis(m, n);
    let mut sink = FigureSink::new("ablation_bound_family");
    sink.header(&["family", "worst_error", "usage_mu0", "usage_mu2"]);
    let mut out = Vec::new();
    for (label, delta_exp) in [("pocock", 0.0), ("wt-0.25", -0.25), ("obf", -0.5)] {
        // calibrate G0 so each family hits the same worst-case error
        let target = 0.05;
        let mut lo = 0.5f64;
        let mut hi = 6.0f64;
        for _ in 0..30 {
            let g0 = 0.5 * (lo + hi);
            let bounds: Vec<f64> =
                pis[..pis.len() - 1].iter().map(|&p| g0 * p.powf(delta_exp)).collect();
            let e = analyze_walk(0.0, &pis, &bounds, 128).error;
            if e > target {
                lo = g0;
            } else {
                hi = g0;
            }
        }
        let g0 = 0.5 * (lo + hi);
        let bounds: Vec<f64> =
            pis[..pis.len() - 1].iter().map(|&p| g0 * p.powf(delta_exp)).collect();
        let worst = analyze_walk(0.0, &pis, &bounds, 128);
        let far = analyze_walk(2.0, &pis, &bounds, 128);
        sink.row_tagged(label, &[worst.error, worst.expected_pi, far.expected_pi]);
        out.push((label.to_string(), worst.expected_pi, far.expected_pi));
    }
    out
}

/// A3: without- vs with-replacement mini-batches on a real population.
/// With replacement, the FPC is wrong (variance never reaches 0), so the
/// test needs more data and can even fail to terminate by exhaustion —
/// we emulate the with-replacement variant explicitly.
pub fn ablation_replacement(scale: Scale) -> (f64, f64) {
    let n = scale.n(12_214);
    let m = 500.min(n / 4).max(16);
    let model = mnist_like_model(n, 42);
    let pop = &harvest_pairs(&model, 0.01, 1, 5, 7)[0];
    let trials = scale.steps(400).max(50);
    let mu0 = pop.mu - 1.0 * pop.sigma_l / ((n - 1) as f64).sqrt();

    // without replacement: the real sequential test
    let fixed = FixedLs(&pop.ls);
    let cfg = SeqTestConfig::new(0.05, m);
    let mut sched = MinibatchScheduler::new(n).expect("population exceeds the u32 index space");
    let mut rng = Pcg64::seeded(11);
    let mut used_wo = 0u64;
    for _ in 0..trials {
        let o = seq_mh_test(&fixed, &(), &(), mu0, &cfg, &mut sched, &mut rng);
        used_wo += o.n_used as u64;
    }

    // with replacement: same decision rule, iid batches, no FPC
    let mut used_w = 0u64;
    for _ in 0..trials {
        let mut acc = MomentAccumulator::new();
        loop {
            let (mut s, mut s2) = (0.0, 0.0);
            for _ in 0..m {
                let l = pop.ls[rng.below(n)];
                s += l;
                s2 += l * l;
            }
            acc.add_batch(s, s2, m);
            let nn = acc.n();
            // plain (no-FPC) t statistic
            let std = acc.sample_std() / (nn as f64).sqrt();
            let t = if std == 0.0 {
                f64::INFINITY
            } else {
                (acc.mean() - mu0) / std
            };
            let delta = t_sf(t.abs(), (nn - 1) as f64);
            if delta < 0.05 || nn >= 4 * n {
                used_w += nn as u64;
                break;
            }
        }
    }

    let wo = used_wo as f64 / (trials as f64 * n as f64);
    let w = used_w as f64 / (trials as f64 * n as f64);
    let mut sink = FigureSink::new("ablation_replacement");
    sink.header(&["without_replacement_usage", "with_replacement_usage"]);
    sink.row(&[wo, w]);
    (wo, w)
}

/// A4: adaptive epsilon schedule vs fixed epsilons — final estimate error
/// of E[theta_0] at a fixed step budget.
pub fn ablation_adaptive(scale: Scale) -> Vec<(String, f64, f64)> {
    let n = scale.n(12_214);
    let model = mnist_like_model(n, 42);
    let init = model.map_estimate(60);
    let kernel = GaussianRandomWalk::new(0.01, model.prior_precision);
    let steps = scale.steps(20_000);

    // truth from parallel exact chains (Session picks the cached fast
    // path; same total step budget as the old single long run)
    let truth_res = Session::new(&model)
        .kernel(&kernel)
        .chains(2)
        .seed(1)
        .budget(Budget::Steps(steps))
        .burn_in(steps / 10)
        .record(Param::index(0))
        .init(init.clone())
        .run();
    let truth = truth_res.pooled_mean();

    let mut sink = FigureSink::new("ablation_adaptive");
    sink.header(&["schedule", "sq_error", "data_fraction"]);
    let mut out = Vec::new();
    let schedules: Vec<(String, EpsSchedule)> = vec![
        ("fixed_0.01".into(), EpsSchedule::Fixed(0.01)),
        ("fixed_0.1".into(), EpsSchedule::Fixed(0.1)),
        ("anneal".into(), EpsSchedule::default_anneal()),
    ];
    for (label, sched) in schedules {
        let mut rng = Pcg64::seeded(2);
        let (samples, stats) = run_adaptive_chain(
            &model,
            &kernel,
            &sched,
            500.min(n / 4).max(16),
            init.clone(),
            Budget::Steps(steps),
            steps / 10,
            1,
            |t| t[0],
            &mut rng,
        );
        let mut w = Welford::new();
        for s in &samples {
            w.add(s.value);
        }
        let sq = (w.mean() - truth) * (w.mean() - truth);
        let frac = stats.mean_data_fraction(n);
        sink.row_tagged(&label, &[sq, frac]);
        out.push((label, sq, frac));
    }
    out
}

/// A5: the pseudo-marginal baseline vs the sequential test.
pub fn ablation_pseudo_marginal(scale: Scale) -> (f64, f64, usize) {
    let n = scale.n(12_214);
    let model = mnist_like_model(n, 42);
    let init = model.map_estimate(50);
    let kernel = GaussianRandomWalk::new(0.02, model.prior_precision);
    let steps = scale.steps(600).max(100);

    let est = PoissonEstimator { batch: 100.min(n / 8).max(8), lambda: 3.0, center: 0.0 };
    let pm_kernel = PmKernel::new(&model, &kernel, &est, init.clone());
    let pm_res = KernelSession::new(&pm_kernel)
        .label("pseudo-marginal")
        .data_size(n)
        .seed(3)
        .budget(Budget::Steps(steps))
        .record_with(|_c| PmPathology::default())
        .init(pm_kernel.init_state())
        .run();
    let pm = &pm_res.merged;
    let path = &pm_res.observers[0];

    let seq_res = Session::new(&model)
        .kernel(&kernel)
        .rule(MhMode::approx(0.05, 500.min(n / 4).max(16)))
        .seed(3)
        .budget(Budget::Steps(steps))
        .record(ScalarFn::new(|_: &Vec<f64>| 0.0))
        .init(init)
        .run();
    let seq = &seq_res.merged;

    let pm_acc = pm.acceptance_rate();
    let seq_acc = seq.acceptance_rate();
    let mut sink = FigureSink::new("ablation_pseudo_marginal");
    sink.header(&["pm_accept", "seq_accept", "pm_longest_stuck", "pm_clamped_frac"]);
    sink.row(&[
        pm_acc,
        seq_acc,
        path.longest_stuck as f64,
        path.clamped as f64 / pm.steps as f64,
    ]);
    (pm_acc, seq_acc, path.longest_stuck)
}

/// Run all ablations.
pub fn run_all(scale: Scale) {
    ablation_batch_size(scale);
    ablation_bound_family(scale);
    ablation_replacement(scale);
    ablation_adaptive(scale);
    ablation_pseudo_marginal(scale);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batch_size_tradeoff_holds() {
        std::env::set_var("AUSTERITY_FIGURES", "/tmp/austerity_fig_smoke");
        let rows = ablation_batch_size(Scale(1.0));
        // at mu_std = 2, smaller m should let the test stop earlier
        let first = rows.first().unwrap().2;
        let last = rows.last().unwrap().2;
        assert!(first < last, "usage@mu2: m=50 {first} vs m=5000 {last}");
    }

    #[test]
    fn replacement_ablation_favors_without() {
        std::env::set_var("AUSTERITY_FIGURES", "/tmp/austerity_fig_smoke");
        let (wo, w) = ablation_replacement(Scale(0.3));
        assert!(
            wo <= w + 0.05,
            "without-replacement {wo} should not use more than with {w}"
        );
    }

    #[test]
    fn pseudo_marginal_underperforms_sequential() {
        std::env::set_var("AUSTERITY_FIGURES", "/tmp/austerity_fig_smoke");
        let (pm, seq, stuck) = ablation_pseudo_marginal(Scale(0.3));
        assert!(pm < seq, "pm {pm} vs seq {seq}");
        assert!(stuck >= 5, "stuck {stuck}");
    }

    #[test]
    fn adaptive_between_fixed_extremes_in_data_usage() {
        std::env::set_var("AUSTERITY_FIGURES", "/tmp/austerity_fig_smoke");
        let rows = ablation_adaptive(Scale(0.05));
        let by = |name: &str| rows.iter().find(|r| r.0 == name).unwrap().2;
        let tight = by("fixed_0.01");
        let loose = by("fixed_0.1");
        let anneal = by("anneal");
        assert!(
            anneal <= tight + 0.05 && anneal >= loose - 0.05,
            "anneal {anneal} vs tight {tight} loose {loose}"
        );
    }

    #[test]
    fn bound_family_matched_error() {
        std::env::set_var("AUSTERITY_FIGURES", "/tmp/austerity_fig_smoke");
        let rows = ablation_bound_family(Scale(1.0));
        assert_eq!(rows.len(), 3);
        // all usable (usage in (0, 1])
        for (label, u0, u2) in &rows {
            assert!(*u0 > 0.0 && *u0 <= 1.0, "{label}: {u0}");
            assert!(*u2 > 0.0 && *u2 <= 1.0, "{label}: {u2}");
        }
    }
}

//! Fig. 13 (supplementary E): marginal posterior inclusion probabilities
//! p(gamma_j = 1 | data) from the exact reversible-jump chain vs the
//! approximate chain, started from the same initialization. Both run on
//! the multi-chain engine; the recorded test function is the model size
//! k, so cross-chain R-hat / ESS come out of the same launch.

use crate::coordinator::chain::Budget;
use crate::coordinator::engine::ChainObserver;
use crate::coordinator::mh::MhMode;
use crate::coordinator::session::Session;
use crate::data::synthetic::sparse_logistic;
use crate::exp::common::{FigureSink, Scale};
use crate::metrics::convergence::Convergence;
use crate::models::rjlogistic::{RjLogisticModel, RjState};
use crate::samplers::RjKernel;

pub struct Fig13Result {
    pub exact: Vec<f64>,
    pub approx: Vec<f64>,
    pub beta_true: Vec<f64>,
    /// Cross-chain diagnostics over the model size k, per mode.
    pub conv_exact: Convergence,
    pub conv_approx: Convergence,
}

/// Per-chain inclusion counter; chains merge after the engine returns.
/// The recorded scalar is k, feeding the engine's R-hat / ESS.
struct InclObserver {
    incl: Vec<u64>,
    count: u64,
}

impl ChainObserver<RjState> for InclObserver {
    fn observe(&mut self, s: &RjState) -> f64 {
        for &j in &s.active {
            self.incl[j] += 1;
        }
        self.count += 1;
        s.k() as f64
    }
}

fn inclusion_probs(
    model: &RjLogisticModel,
    mode: &MhMode,
    init: RjState,
    steps: usize,
    seed: u64,
) -> (Vec<f64>, Convergence) {
    let kernel = RjKernel::new(model);
    let d = model.d();
    let chains = 2usize;
    let per_chain = (steps / chains).max(1);
    let res = Session::new(model)
        .kernel(&kernel)
        .rule(mode.clone())
        .chains(chains)
        .seed(seed)
        .budget(Budget::Steps(per_chain))
        .burn_in(per_chain / 5)
        .record_with(|_c| InclObserver { incl: vec![0; d], count: 0 })
        .init(init)
        .run();
    let mut incl = vec![0u64; d];
    let mut count = 0u64;
    for o in &res.observers {
        for (t, v) in incl.iter_mut().zip(&o.incl) {
            *t += v;
        }
        count += o.count;
    }
    let probs = incl.iter().map(|&c| c as f64 / count.max(1) as f64).collect();
    (probs, res.convergence)
}

pub fn run_fig13(scale: Scale) -> Fig13Result {
    let n = scale.n(40_000);
    let d = 21;
    let (ds, beta_true) = sparse_logistic(n, d, 5, 0.28, 31);
    let model = RjLogisticModel::new(ds, 1e-10);
    let steps = scale.steps(30_000);
    let init = RjState::with_active(d, &[0], &[-0.9]);

    let (exact, conv_exact) =
        inclusion_probs(&model, &MhMode::Exact, init.clone(), steps, 41);
    let (approx, conv_approx) =
        inclusion_probs(&model, &MhMode::approx(0.05, 500), init, steps, 41);

    let mut sink = FigureSink::new("fig13_inclusion");
    sink.header(&["feature", "beta_true", "p_incl_exact", "p_incl_approx"]);
    for j in 0..d {
        sink.row(&[j as f64, beta_true[j], exact[j], approx[j]]);
    }
    let mut conv_sink = FigureSink::new("fig13_convergence");
    conv_sink.header(&["mode", "rhat_k", "ess_k", "n_samples"]);
    conv_sink.row_tagged("exact", &[
        conv_exact.rhat,
        conv_exact.ess,
        conv_exact.n_samples as f64,
    ]);
    conv_sink.row_tagged("approx", &[
        conv_approx.rhat,
        conv_approx.ess,
        conv_approx.n_samples as f64,
    ]);
    Fig13Result { exact, approx, beta_true, conv_exact, conv_approx }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig13_exact_and_approx_agree_on_support() {
        std::env::set_var("AUSTERITY_FIGURES", "/tmp/austerity_fig_smoke");
        let r = run_fig13(Scale(0.05));
        let d = r.beta_true.len();
        // mean absolute inclusion-probability gap between the chains
        let gap: f64 = (0..d)
            .map(|j| (r.exact[j] - r.approx[j]).abs())
            .sum::<f64>()
            / d as f64;
        assert!(gap < 0.3, "inclusion gap {gap}");
        // engine diagnostics are populated over the model-size series
        assert!(r.conv_exact.n_samples > 0);
        assert!(!r.conv_approx.rhat.is_nan(), "rhat {}", r.conv_approx.rhat);
        assert!(r.conv_approx.ess > 0.0, "ess {}", r.conv_approx.ess);
    }
}

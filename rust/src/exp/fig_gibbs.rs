//! Gibbs-sampling figures (supplementary F.1):
//!   Fig. 14 — empirical vs exact conditional probability, eps sweep
//!   Fig. 15 — average L1 error over 5-variable joint marginals vs time
//!
//! Fig. 15's chains run as `GibbsSweepKernel` launches on the multi-chain
//! engine: the ground truth fans out over two exact chains (marginals
//! merged), and each timed run is an engine launch whose observer records
//! marginals and checkpoints the L1 error as it goes.

use std::time::{Duration, Instant};

use crate::coordinator::chain::Budget;
use crate::coordinator::engine::ChainObserver;
use crate::coordinator::session::KernelSession;
use crate::exp::common::{FigureSink, Scale};
use crate::models::MrfModel;
use crate::samplers::gibbs::{
    gibbs_sweep, gibbs_update, GibbsMode, GibbsScratch, GibbsStats, GibbsSweepKernel,
    SubsetMarginal,
};
use crate::stats::Pcg64;

/// Fig. 14: for random (variable, neighborhood) pairs, the frequency of
/// assigning X_v = 1 under repeated approximate updates vs the exact
/// conditional.
pub fn run_fig14(scale: Scale) -> Vec<(f64, f64, f64)> {
    let d = scale.n(100).clamp(12, 100);
    let model = MrfModel::random(d, 0.02, 5);
    let states = scale.steps(30).clamp(8, 60);
    let trials = scale.steps(300).max(60);
    let eps_values = [0.01, 0.1, 0.25];

    let mut sink = FigureSink::new("fig14_conditionals");
    sink.header(&["eps", "exact_conditional", "empirical_conditional"]);

    let mut rng = Pcg64::seeded(14);
    let mut scratch = GibbsScratch::new(&model);
    let mut out = Vec::new();

    // warm the state with a few exact sweeps so neighborhoods are typical
    let mut x: Vec<bool> = (0..d).map(|_| rng.uniform() < 0.5).collect();
    let mut stats = GibbsStats::default();
    for _ in 0..3 {
        gibbs_sweep(&model, &mut x, &GibbsMode::Exact, &mut scratch, &mut stats, &mut rng);
    }

    for _ in 0..states {
        // random neighborhood tweak + random variable
        let flip = rng.below(d);
        x[flip] = !x[flip];
        let v = rng.below(d);
        let exact = model.exact_conditional(v, &x);
        for &eps in &eps_values {
            let mode = GibbsMode::Approx { eps, batch: 500.min(model.n_pairs() / 2).max(8) };
            let mut ones = 0usize;
            for _ in 0..trials {
                let mut xx = x.clone();
                gibbs_update(&model, v, &mut xx, &mode, &mut scratch, &mut rng);
                ones += xx[v] as usize;
            }
            let emp = ones as f64 / trials as f64;
            sink.row(&[eps, exact, emp]);
            out.push((eps, exact, emp));
        }
    }
    out
}

/// Per-chain marginal recorder for the ground-truth launch; the recorded
/// scalar is the fraction of ones (a cheap whole-state test function).
struct MarginalObserver {
    marginals: Vec<SubsetMarginal>,
}

impl MarginalObserver {
    fn new(subsets: &[Vec<usize>]) -> Self {
        MarginalObserver {
            marginals: subsets.iter().map(|s| SubsetMarginal::new(s.clone())).collect(),
        }
    }
}

fn frac_ones(x: &[bool]) -> f64 {
    x.iter().filter(|&&b| b).count() as f64 / x.len() as f64
}

impl ChainObserver<Vec<bool>> for MarginalObserver {
    fn observe(&mut self, x: &Vec<bool>) -> f64 {
        for m in self.marginals.iter_mut() {
            m.record(x);
        }
        frac_ones(x)
    }
}

/// Timed-run observer: records marginals every sweep and snapshots the
/// mean L1 error to the truth at each wall-clock checkpoint.
struct CheckpointObserver<'a> {
    marginals: Vec<SubsetMarginal>,
    truth: &'a [Vec<f64>],
    checkpoints: &'a [f64],
    start: Instant,
    next_cp: usize,
    sweeps: usize,
    /// (elapsed secs, mean L1 error, sweeps done) per checkpoint
    rows: Vec<(f64, f64, usize)>,
}

impl<'a> CheckpointObserver<'a> {
    fn new(subsets: &[Vec<usize>], truth: &'a [Vec<f64>], checkpoints: &'a [f64]) -> Self {
        CheckpointObserver {
            marginals: subsets.iter().map(|s| SubsetMarginal::new(s.clone())).collect(),
            truth,
            checkpoints,
            start: Instant::now(),
            next_cp: 0,
            sweeps: 0,
            rows: Vec::new(),
        }
    }

    fn err(&self) -> f64 {
        self.marginals
            .iter()
            .zip(self.truth)
            .map(|(m, t)| m.l1_to(t))
            .sum::<f64>()
            / self.marginals.len() as f64
    }

    /// Emit any checkpoints the wall budget cut off (at least the final
    /// one, so every mode reports a terminal error).
    fn flush(&mut self, final_secs: f64) {
        while self.next_cp < self.checkpoints.len() {
            self.rows.push((final_secs, self.err(), self.sweeps));
            self.next_cp += 1;
        }
    }
}

impl ChainObserver<Vec<bool>> for CheckpointObserver<'_> {
    fn observe(&mut self, x: &Vec<bool>) -> f64 {
        self.sweeps += 1;
        for m in self.marginals.iter_mut() {
            m.record(x);
        }
        let el = self.start.elapsed().as_secs_f64();
        while self.next_cp < self.checkpoints.len() && el >= self.checkpoints[self.next_cp] {
            self.rows.push((el, self.err(), self.sweeps));
            self.next_cp += 1;
        }
        frac_ones(x)
    }
}

/// Fig. 15: L1 error of 5-variable joint marginals vs running time for
/// exact Gibbs and an eps sweep. Ground truth from a long exact run.
pub fn run_fig15(scale: Scale) -> Vec<(f64, f64)> {
    let d = scale.n(100).clamp(12, 100);
    let model = MrfModel::random(d, 0.02, 6);
    let n_subsets = scale.steps(1_600).clamp(50, 1_600);
    let mut rng = Pcg64::seeded(15);

    // random 5-variable subsets
    let subsets: Vec<Vec<usize>> = (0..n_subsets)
        .map(|_| {
            let mut vars = std::collections::BTreeSet::new();
            while vars.len() < 5.min(d) {
                vars.insert(rng.below(d));
            }
            vars.into_iter().collect()
        })
        .collect();

    let x0: Vec<bool> = (0..d).map(|_| rng.uniform() < 0.5).collect();

    // ground truth: two exact chains on the engine, marginals merged
    let gt_sweeps = scale.steps(4_000).max(300);
    let per_chain = (gt_sweeps / 2).max(10);
    let gt_kernel = GibbsSweepKernel { model: &model, mode: GibbsMode::Exact };
    let gt_res = KernelSession::new(&gt_kernel)
        .label("gibbs-exact")
        .chains(2)
        .seed(1500)
        .budget(Budget::Steps(per_chain))
        .burn_in(per_chain / 10)
        .record_with(|_c| MarginalObserver::new(&subsets))
        .init(x0.clone())
        .run();
    let mut truth_marginals: Vec<SubsetMarginal> =
        subsets.iter().map(|s| SubsetMarginal::new(s.clone())).collect();
    for obs in &gt_res.observers {
        for (t, m) in truth_marginals.iter_mut().zip(&obs.marginals) {
            t.merge(m).expect("ground-truth chains record the same subsets");
        }
    }
    let truth: Vec<Vec<f64>> = truth_marginals.iter().map(|m| m.probs()).collect();

    // timed runs
    let budget_secs = scale.secs(30.0);
    let checkpoints: Vec<f64> = (1..=8)
        .map(|i| budget_secs * (i as f64 / 8.0).powi(2))
        .collect();
    let modes: Vec<(f64, GibbsMode)> = vec![
        (0.0, GibbsMode::Exact),
        (0.05, GibbsMode::Approx { eps: 0.05, batch: 500.min(model.n_pairs() / 2).max(8) }),
        (0.1, GibbsMode::Approx { eps: 0.1, batch: 500.min(model.n_pairs() / 2).max(8) }),
        (0.2, GibbsMode::Approx { eps: 0.2, batch: 500.min(model.n_pairs() / 2).max(8) }),
    ];

    let mut sink = FigureSink::new("fig15_l1_error");
    sink.header(&["eps", "t_secs", "l1_error", "sweeps", "pairs_used"]);
    let mut finals = Vec::new();

    for (eps, mode) in &modes {
        let kernel = GibbsSweepKernel { model: &model, mode: mode.clone() };
        let res = KernelSession::new(&kernel)
            .label("gibbs")
            .seed(150 + (eps * 1e4) as u64)
            .budget(Budget::Wall(Duration::from_secs_f64(budget_secs)))
            .record_with(|_c| CheckpointObserver::new(&subsets, &truth, &checkpoints))
            .init(x0.clone())
            .run();
        let run = res.runs.into_iter().next().expect("one chain");
        let mut obs = res.observers.into_iter().next().expect("one chain");
        obs.flush(run.stats.wall.as_secs_f64());
        for &(el, err, sweeps) in &obs.rows {
            let pairs = if sweeps == 0 {
                0.0
            } else {
                run.samples[sweeps - 1].at_data as f64
            };
            sink.row(&[*eps, el, err, sweeps as f64, pairs]);
        }
        let last_err = obs.rows.last().map(|r| r.1).unwrap_or(f64::NAN);
        finals.push((*eps, last_err));
    }
    finals
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig14_small_eps_tracks_exact() {
        std::env::set_var("AUSTERITY_FIGURES", "/tmp/austerity_fig_smoke");
        let pts = run_fig14(Scale(0.15));
        assert!(!pts.is_empty());
        // eps = 0.01 rows should hug the diagonal
        let (mut err, mut n) = (0.0, 0);
        for &(eps, exact, emp) in &pts {
            if eps == 0.01 {
                err += (exact - emp).abs();
                n += 1;
            }
        }
        assert!(n > 0);
        let mean_gap = err / n as f64;
        assert!(mean_gap < 0.15, "mean gap {mean_gap}");
    }

    #[test]
    fn fig15_runs_and_reports() {
        std::env::set_var("AUSTERITY_FIGURES", "/tmp/austerity_fig_smoke");
        let finals = run_fig15(Scale(0.02));
        assert_eq!(finals.len(), 4);
        for (_, err) in &finals {
            assert!(err.is_finite() && *err >= 0.0);
        }
    }
}

//! Gibbs-sampling figures (supplementary F.1):
//!   Fig. 14 — empirical vs exact conditional probability, eps sweep
//!   Fig. 15 — average L1 error over 5-variable joint marginals vs time

use std::time::Instant;

use crate::exp::common::{FigureSink, Scale};
use crate::models::MrfModel;
use crate::samplers::gibbs::{
    gibbs_sweep, gibbs_update, GibbsMode, GibbsScratch, GibbsStats, SubsetMarginal,
};
use crate::stats::Pcg64;

/// Fig. 14: for random (variable, neighborhood) pairs, the frequency of
/// assigning X_v = 1 under repeated approximate updates vs the exact
/// conditional.
pub fn run_fig14(scale: Scale) -> Vec<(f64, f64, f64)> {
    let d = scale.n(100).clamp(12, 100);
    let model = MrfModel::random(d, 0.02, 5);
    let states = scale.steps(30).clamp(8, 60);
    let trials = scale.steps(300).max(60);
    let eps_values = [0.01, 0.1, 0.25];

    let mut sink = FigureSink::new("fig14_conditionals");
    sink.header(&["eps", "exact_conditional", "empirical_conditional"]);

    let mut rng = Pcg64::seeded(14);
    let mut scratch = GibbsScratch::new(&model);
    let mut out = Vec::new();

    // warm the state with a few exact sweeps so neighborhoods are typical
    let mut x: Vec<bool> = (0..d).map(|_| rng.uniform() < 0.5).collect();
    let mut stats = GibbsStats::default();
    for _ in 0..3 {
        gibbs_sweep(&model, &mut x, &GibbsMode::Exact, &mut scratch, &mut stats, &mut rng);
    }

    for _ in 0..states {
        // random neighborhood tweak + random variable
        let flip = rng.below(d);
        x[flip] = !x[flip];
        let v = rng.below(d);
        let exact = model.exact_conditional(v, &x);
        for &eps in &eps_values {
            let mode = GibbsMode::Approx { eps, batch: 500.min(model.n_pairs() / 2).max(8) };
            let mut ones = 0usize;
            for _ in 0..trials {
                let mut xx = x.clone();
                gibbs_update(&model, v, &mut xx, &mode, &mut scratch, &mut rng);
                ones += xx[v] as usize;
            }
            let emp = ones as f64 / trials as f64;
            sink.row(&[eps, exact, emp]);
            out.push((eps, exact, emp));
        }
    }
    out
}

/// Fig. 15: L1 error of 5-variable joint marginals vs running time for
/// exact Gibbs and an eps sweep. Ground truth from a long exact run.
pub fn run_fig15(scale: Scale) -> Vec<(f64, f64)> {
    let d = scale.n(100).clamp(12, 100);
    let model = MrfModel::random(d, 0.02, 6);
    let n_subsets = scale.steps(1_600).clamp(50, 1_600);
    let mut rng = Pcg64::seeded(15);

    // random 5-variable subsets
    let subsets: Vec<Vec<usize>> = (0..n_subsets)
        .map(|_| {
            let mut vars = std::collections::BTreeSet::new();
            while vars.len() < 5.min(d) {
                vars.insert(rng.below(d));
            }
            vars.into_iter().collect()
        })
        .collect();

    // ground truth from a long exact run
    let gt_sweeps = scale.steps(4_000).max(300);
    let mut truth_marginals: Vec<SubsetMarginal> =
        subsets.iter().map(|s| SubsetMarginal::new(s.clone())).collect();
    {
        let mut x: Vec<bool> = (0..d).map(|_| rng.uniform() < 0.5).collect();
        let mut scratch = GibbsScratch::new(&model);
        let mut stats = GibbsStats::default();
        for s in 0..gt_sweeps {
            gibbs_sweep(&model, &mut x, &GibbsMode::Exact, &mut scratch, &mut stats, &mut rng);
            if s >= gt_sweeps / 10 {
                for m in truth_marginals.iter_mut() {
                    m.record(&x);
                }
            }
        }
    }
    let truth: Vec<Vec<f64>> = truth_marginals.iter().map(|m| m.probs()).collect();

    // timed runs
    let budget_secs = scale.secs(30.0);
    let checkpoints: Vec<f64> = (1..=8)
        .map(|i| budget_secs * (i as f64 / 8.0).powi(2))
        .collect();
    let modes: Vec<(f64, GibbsMode)> = vec![
        (0.0, GibbsMode::Exact),
        (0.05, GibbsMode::Approx { eps: 0.05, batch: 500.min(model.n_pairs() / 2).max(8) }),
        (0.1, GibbsMode::Approx { eps: 0.1, batch: 500.min(model.n_pairs() / 2).max(8) }),
        (0.2, GibbsMode::Approx { eps: 0.2, batch: 500.min(model.n_pairs() / 2).max(8) }),
    ];

    let mut sink = FigureSink::new("fig15_l1_error");
    sink.header(&["eps", "t_secs", "l1_error", "sweeps", "pairs_used"]);
    let mut finals = Vec::new();

    for (eps, mode) in &modes {
        let mut rng = Pcg64::new(150, (eps * 1e4) as u64);
        let mut x: Vec<bool> = (0..d).map(|_| rng.uniform() < 0.5).collect();
        let mut scratch = GibbsScratch::new(&model);
        let mut stats = GibbsStats::default();
        let mut marginals: Vec<SubsetMarginal> =
            subsets.iter().map(|s| SubsetMarginal::new(s.clone())).collect();
        let start = Instant::now();
        let mut next_cp = 0usize;
        let mut sweeps = 0usize;
        let mut last_err = f64::NAN;
        while next_cp < checkpoints.len() {
            gibbs_sweep(&model, &mut x, mode, &mut scratch, &mut stats, &mut rng);
            sweeps += 1;
            for m in marginals.iter_mut() {
                m.record(&x);
            }
            let el = start.elapsed().as_secs_f64();
            while next_cp < checkpoints.len() && el >= checkpoints[next_cp] {
                let err: f64 = marginals
                    .iter()
                    .zip(&truth)
                    .map(|(m, t)| m.l1_to(t))
                    .sum::<f64>()
                    / marginals.len() as f64;
                sink.row(&[*eps, el, err, sweeps as f64, stats.pairs_used as f64]);
                last_err = err;
                next_cp += 1;
            }
        }
        finals.push((*eps, last_err));
    }
    finals
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig14_small_eps_tracks_exact() {
        std::env::set_var("AUSTERITY_FIGURES", "/tmp/austerity_fig_smoke");
        let pts = run_fig14(Scale(0.15));
        assert!(!pts.is_empty());
        // eps = 0.01 rows should hug the diagonal
        let (mut err, mut n) = (0.0, 0);
        for &(eps, exact, emp) in &pts {
            if eps == 0.01 {
                err += (exact - emp).abs();
                n += 1;
            }
        }
        assert!(n > 0);
        let mean_gap = err / n as f64;
        assert!(mean_gap < 0.15, "mean gap {mean_gap}");
    }

    #[test]
    fn fig15_runs_and_reports() {
        std::env::set_var("AUSTERITY_FIGURES", "/tmp/austerity_fig_smoke");
        let finals = run_fig15(Scale(0.02));
        assert_eq!(finals.len(), 4);
        for (_, err) in &finals {
            assert!(err.is_finite() && *err >= 0.0);
        }
    }
}

//! Symmetric Gaussian random-walk proposal (paper §6.1).
//!
//! q(theta'|theta) = N(theta, sigma_RW^2 I) is symmetric, so only the
//! prior ratio enters the MH correction:
//! mu_0 = (1/N) log[u rho(theta_t) / rho(theta')]   (§6.1).

use crate::models::traits::{Proposal, ProposalKernel};
use crate::stats::Pcg64;

/// Random walk for a vector parameter with a spherical Gaussian prior of
/// the given precision (set `prior_precision = 0` for a flat prior).
pub struct GaussianRandomWalk {
    pub sigma: f64,
    pub prior_precision: f64,
}

impl GaussianRandomWalk {
    pub fn new(sigma: f64, prior_precision: f64) -> Self {
        assert!(sigma > 0.0);
        GaussianRandomWalk { sigma, prior_precision }
    }
}

impl ProposalKernel<Vec<f64>> for GaussianRandomWalk {
    fn propose(&self, cur: &Vec<f64>, rng: &mut Pcg64) -> Proposal<Vec<f64>> {
        let prop: Vec<f64> = cur.iter().map(|&t| t + self.sigma * rng.normal()).collect();
        // log[rho(cur)/rho(prop)] for N(0, I/precision):
        // -p/2 (|cur|^2 - |prop|^2)
        let (mut nc, mut np) = (0.0, 0.0);
        for (c, p) in cur.iter().zip(&prop) {
            nc += c * c;
            np += p * p;
        }
        let log_correction = -0.5 * self.prior_precision * (nc - np);
        Proposal { param: prop, log_correction }
    }
}

/// Random walk for a scalar parameter with an arbitrary log-prior
/// provided as a closure (used by the SGLD toy's exact-MH baseline).
pub struct ScalarRandomWalk<F: Fn(f64) -> f64> {
    pub sigma: f64,
    pub log_prior: F,
}

impl<F: Fn(f64) -> f64> ProposalKernel<f64> for ScalarRandomWalk<F> {
    fn propose(&self, cur: &f64, rng: &mut Pcg64) -> Proposal<f64> {
        let prop = cur + self.sigma * rng.normal();
        let log_correction = (self.log_prior)(*cur) - (self.log_prior)(prop);
        Proposal { param: prop, log_correction }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{run_chain, Budget, MhMode};
    use crate::data::synthetic::two_class_gaussian;
    use crate::models::{LlDiffModel, LogisticModel};
    use crate::stats::welford::Welford;

    #[test]
    fn proposal_perturbs_every_coordinate() {
        let k = GaussianRandomWalk::new(0.1, 10.0);
        let mut rng = Pcg64::seeded(0);
        let cur = vec![0.0; 5];
        let p = k.propose(&cur, &mut rng);
        assert_eq!(p.param.len(), 5);
        assert!(p.param.iter().all(|&v| v != 0.0));
    }

    #[test]
    fn flat_prior_no_correction() {
        let k = GaussianRandomWalk::new(0.1, 0.0);
        let mut rng = Pcg64::seeded(1);
        let p = k.propose(&vec![1.0, 2.0], &mut rng);
        assert_eq!(p.log_correction, 0.0);
    }

    #[test]
    fn correction_sign_favors_prior_mode() {
        // moving towards 0 from far out: rho(prop) > rho(cur), so
        // log[rho(cur)/rho(prop)] < 0 (easier to accept).
        let k = GaussianRandomWalk::new(0.0001, 10.0);
        let mut rng = Pcg64::seeded(2);
        let cur = vec![5.0];
        let mut signs = 0;
        for _ in 0..100 {
            let p = k.propose(&cur, &mut rng);
            if p.param[0].abs() < 5.0 {
                assert!(p.log_correction < 0.0);
                signs += 1;
            }
        }
        assert!(signs > 20);
    }

    #[test]
    fn exact_chain_matches_map_region() {
        // short exact chain on a small logistic posterior stays near MAP
        let model = LogisticModel::new(two_class_gaussian(300, 4, 1.5, 0), 10.0).expect("population exceeds the u32 index space");
        let map = model.map_estimate(60);
        let kernel = GaussianRandomWalk::new(0.05, model.prior_precision);
        let mut rng = Pcg64::seeded(3);
        let (samples, stats) = run_chain(
            &model,
            &kernel,
            &MhMode::Exact,
            map.clone(),
            Budget::Steps(3_000),
            500,
            5,
            |p| p.iter().zip(&map).map(|(a, b)| (a - b) * (a - b)).sum::<f64>().sqrt(),
            &mut rng,
        );
        assert!(stats.acceptance_rate() > 0.05, "acc {}", stats.acceptance_rate());
        let mut w = Welford::new();
        for s in &samples {
            w.add(s.value);
        }
        // posterior concentrates near MAP for N=300, d=4
        assert!(w.mean() < 1.5, "mean dist from MAP {}", w.mean());
        let _ = model.n();
    }
}

//! Stochastic Gradient Langevin Dynamics for the 1-d toy model
//! (paper §6.4): the uncorrected sampler that exhibits the pitfall, and
//! the version corrected by the approximate MH test.
//!
//! Proposal (Eqn. 9):
//!   theta' ~ N( theta + alpha/2 * [ (N/n) sum_{x in Xn} grad log p(x|theta)
//!                                   + grad log rho(theta) ],  alpha )
//!
//! The corrected variant treats the SGLD kernel as a mixture over
//! mini-batches and enforces detailed balance against each component:
//!   mu_0 = (1/N) log[ u rho(theta) q(theta'|theta, Xn)
//!                       / (rho(theta') q(theta|theta', Xn)) ].

use crate::coordinator::austerity::{seq_mh_test, SeqTestConfig};
use crate::coordinator::checkpoint::{BinReader, BinWriter, CkptError, Persist};
use crate::coordinator::kernel::{restore_sched, StepOutcome, TransitionKernel};
use crate::coordinator::scheduler::MinibatchScheduler;
use crate::models::linreg::LinRegModel;
use crate::models::traits::LlDiffModel;
use crate::stats::Pcg64;

/// SGLD driver configuration.
#[derive(Clone, Debug)]
pub struct SgldConfig {
    /// Step size alpha (paper: 5e-6).
    pub alpha: f64,
    /// Gradient mini-batch size n (paper style; we default 500).
    pub grad_batch: usize,
    /// None = uncorrected SGLD (always accept); Some = approximate MH
    /// correction with this sequential test config.
    pub correction: Option<SeqTestConfig>,
}

/// Outcome counters of an SGLD run.
#[derive(Clone, Debug, Default)]
pub struct SgldStats {
    pub steps: usize,
    pub accepted: usize,
    pub data_used: u64,
}

/// log N(x; mean, var).
#[inline]
fn log_normal_pdf(x: f64, mean: f64, var: f64) -> f64 {
    let d = x - mean;
    -0.5 * (d * d / var) - 0.5 * (var * 2.0 * std::f64::consts::PI).ln()
}

/// SGLD (± the approximate-MH correction) as a `TransitionKernel`, so
/// the §6.4 experiment runs on the multi-chain engine like every other
/// family. A step draws a fresh gradient mini-batch, takes the Langevin
/// proposal (Eqn. 9), and — when `cfg.correction` is set — decides it
/// with the sequential test against the same mini-batch's reverse move.
/// Step-for-step RNG-identical to the bespoke `run_sgld` loop
/// (regression-tested in `tests/integration_engine.rs`).
pub struct SgldKernel<'a> {
    pub model: &'a LinRegModel,
    pub cfg: SgldConfig,
}

/// Chain-local SGLD workspace: one scheduler per population role plus
/// the shared index buffer, reused across steps.
pub struct SgldScratch {
    grad_sched: MinibatchScheduler,
    test_sched: MinibatchScheduler,
    idx_buf: Vec<usize>,
}

impl TransitionKernel for SgldKernel<'_> {
    type State = f64;
    type Scratch = SgldScratch;

    fn scratch(&self, _init: &f64) -> SgldScratch {
        let n = self.model.n();
        SgldScratch {
            grad_sched: MinibatchScheduler::new(n).expect("population exceeds the u32 index space"),
            test_sched: MinibatchScheduler::new(n).expect("population exceeds the u32 index space"),
            idx_buf: Vec::new(),
        }
    }

    fn step(&self, theta: &mut f64, s: &mut SgldScratch, rng: &mut Pcg64) -> StepOutcome {
        let model = self.model;
        let cfg = &self.cfg;
        let n_total = model.n();

        // Draw the gradient mini-batch Xn (fresh without-replacement draw).
        s.grad_sched.reset();
        let batch = s.grad_sched.next_batch(cfg.grad_batch, rng);
        s.idx_buf.clear();
        s.idx_buf.extend(batch.iter().map(|&i| i as usize));

        let drift = 0.5 * cfg.alpha * model.grad_log_post(*theta, &s.idx_buf);
        let mean_fwd = *theta + drift;
        let prop = mean_fwd + cfg.alpha.sqrt() * rng.normal();
        let mut data_used = s.idx_buf.len() as u64;

        let accepted = match &cfg.correction {
            None => true,
            Some(test_cfg) => {
                // Reverse-move drift uses the SAME mini-batch Xn.
                let drift_rev = 0.5 * cfg.alpha * model.grad_log_post(prop, &s.idx_buf);
                let mean_rev = prop + drift_rev;
                let log_q_fwd = log_normal_pdf(prop, mean_fwd, cfg.alpha);
                let log_q_rev = log_normal_pdf(*theta, mean_rev, cfg.alpha);
                // c = log[rho(cur) q(prop|cur,Xn) / (rho(prop) q(cur|prop,Xn))]
                let c = model.log_prior(*theta) - model.log_prior(prop) + log_q_fwd - log_q_rev;
                let u = rng.uniform_pos();
                let mu0 = (u.ln() + c) / n_total as f64;
                let out =
                    seq_mh_test(model, theta, &prop, mu0, test_cfg, &mut s.test_sched, rng);
                data_used += out.n_used as u64;
                out.accept
            }
        };

        if accepted {
            *theta = prop;
        }
        StepOutcome { accepted, data_used, guard_trips: 0 }
    }

    // Both scheduler permutations carry across steps and feed future
    // mini-batch draws, so resume bit-identity needs them verbatim
    // (idx_buf is rebuilt every step).
    fn save_scratch(&self, scratch: &SgldScratch, w: &mut BinWriter) {
        scratch.grad_sched.persist(w);
        scratch.test_sched.persist(w);
    }

    fn restore_scratch(
        &self,
        scratch: &mut SgldScratch,
        r: &mut BinReader<'_>,
    ) -> Result<(), CkptError> {
        restore_sched(&mut scratch.grad_sched, self.model.n(), r)?;
        restore_sched(&mut scratch.test_sched, self.model.n(), r)
    }
}

/// Run SGLD on the toy model, collecting every post-burn-in sample of
/// theta. Returns (samples, stats).
///
/// Pre-refactor bespoke loop, retained for one release as the
/// same-seed equivalence oracle of `SgldKernel` (see
/// `tests/integration_engine.rs`); new code should drive `SgldKernel`
/// through `drive_chain` / `run_engine_kernel` instead.
pub fn run_sgld(
    model: &LinRegModel,
    cfg: &SgldConfig,
    init: f64,
    steps: usize,
    burn_in: usize,
    rng: &mut Pcg64,
) -> (Vec<f64>, SgldStats) {
    let n_total = model.n();
    let mut grad_sched = MinibatchScheduler::new(n_total).expect("population exceeds the u32 index space");
    let mut test_sched = MinibatchScheduler::new(n_total).expect("population exceeds the u32 index space");
    let mut idx_buf: Vec<usize> = Vec::new();
    let mut theta = init;
    let mut out = Vec::with_capacity(steps.saturating_sub(burn_in));
    let mut stats = SgldStats::default();

    for step in 0..steps {
        // Draw the gradient mini-batch Xn (fresh without-replacement draw).
        grad_sched.reset();
        let batch = grad_sched.next_batch(cfg.grad_batch, rng);
        idx_buf.clear();
        idx_buf.extend(batch.iter().map(|&i| i as usize));

        let drift = 0.5 * cfg.alpha * model.grad_log_post(theta, &idx_buf);
        let mean_fwd = theta + drift;
        let prop = mean_fwd + cfg.alpha.sqrt() * rng.normal();
        stats.data_used += idx_buf.len() as u64;

        let accepted = match &cfg.correction {
            None => true,
            Some(test_cfg) => {
                // Reverse-move drift uses the SAME mini-batch Xn.
                let drift_rev = 0.5 * cfg.alpha * model.grad_log_post(prop, &idx_buf);
                let mean_rev = prop + drift_rev;
                let log_q_fwd = log_normal_pdf(prop, mean_fwd, cfg.alpha);
                let log_q_rev = log_normal_pdf(theta, mean_rev, cfg.alpha);
                // c = log[rho(cur) q(prop|cur,Xn) / (rho(prop) q(cur|prop,Xn))]
                let c = model.log_prior(theta) - model.log_prior(prop) + log_q_fwd - log_q_rev;
                let u = rng.uniform_pos();
                let mu0 = (u.ln() + c) / n_total as f64;
                let out = seq_mh_test(model, &theta, &prop, mu0, test_cfg, &mut test_sched, rng);
                stats.data_used += out.n_used as u64;
                out.accept
            }
        };

        if accepted {
            theta = prop;
            stats.accepted += 1;
        }
        stats.steps += 1;
        if step >= burn_in {
            out.push(theta);
        }
    }
    (out, stats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::linreg_toy;
    use crate::stats::Histogram;

    fn model() -> LinRegModel {
        LinRegModel::new(linreg_toy(10_000, 0), 3.0, 4950.0).expect("population exceeds the u32 index space")
    }

    #[test]
    fn uncorrected_always_accepts() {
        let m = model();
        let cfg = SgldConfig { alpha: 5e-6, grad_batch: 500, correction: None };
        let mut rng = Pcg64::seeded(0);
        let (samples, stats) = run_sgld(&m, &cfg, 0.45, 500, 0, &mut rng);
        assert_eq!(stats.accepted, stats.steps);
        assert_eq!(samples.len(), 500);
    }

    #[test]
    fn corrected_rejects_some_moves() {
        let m = model();
        let cfg = SgldConfig {
            alpha: 5e-6,
            grad_batch: 500,
            correction: Some(SeqTestConfig::new(0.5, 500)),
        };
        let mut rng = Pcg64::seeded(1);
        let (_, stats) = run_sgld(&m, &cfg, 0.45, 2_000, 0, &mut rng);
        assert!(stats.accepted < stats.steps, "no rejections?");
        assert!(stats.accepted as f64 / stats.steps as f64 > 0.3, "too many rejections");
    }

    #[test]
    fn corrected_concentrates_at_mode() {
        // The paper's headline qualitative claim: with the MH correction
        // the mass far to the right of the mode (the pitfall region)
        // disappears.
        let m = model();
        let steps = 20_000;
        let mut rng = Pcg64::seeded(2);
        let un = SgldConfig { alpha: 5e-6, grad_batch: 500, correction: None };
        let (s_un, _) = run_sgld(&m, &un, 0.45, steps, 1000, &mut rng);
        let co = SgldConfig {
            alpha: 5e-6,
            grad_batch: 500,
            correction: Some(SeqTestConfig::new(0.5, 500)),
        };
        let (s_co, _) = run_sgld(&m, &co, 0.45, steps, 1000, &mut rng);

        let far = |s: &[f64]| s.iter().filter(|&&t| t > 0.6).count() as f64 / s.len() as f64;
        assert!(
            far(&s_co) < far(&s_un) + 0.02,
            "corrected {} vs uncorrected {}",
            far(&s_co),
            far(&s_un)
        );

        // corrected samples should track the true posterior around the mode
        let mut h = Histogram::new(0.2, 0.8, 30);
        h.add_all(&s_co);
        let (grid, dens) = m.posterior_density(0.2, 0.8, 30);
        let mode_idx = dens
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0;
        // histogram peak within 2 bins of the true mode
        let h_peak = (0..h.bins())
            .max_by(|&a, &b| h.density(a).partial_cmp(&h.density(b)).unwrap())
            .unwrap();
        assert!(
            (h_peak as i64 - mode_idx as i64).abs() <= 3,
            "peak bin {h_peak} vs mode bin {mode_idx} (grid {:?})",
            &grid[mode_idx]
        );
    }

    #[test]
    fn log_normal_pdf_normalizes() {
        // integrate over a grid
        let var = 0.3;
        let mean = -0.2;
        let n = 4000;
        let (lo, hi) = (-6.0, 6.0);
        let h = (hi - lo) / n as f64;
        let mut s = 0.0;
        for i in 0..n {
            let x = lo + (i as f64 + 0.5) * h;
            s += log_normal_pdf(x, mean, var).exp() * h;
        }
        assert!((s - 1.0).abs() < 1e-6);
    }
}

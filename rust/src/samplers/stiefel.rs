//! Random walk on the Stiefel manifold of orthonormal matrices
//! (paper §6.2, following Ouyang 2008): W' = exp(K) W with K a random
//! skew-symmetric matrix. Left-multiplication by exp(K) preserves
//! orthonormality; flipping the sign of K gives the reverse move and K
//! and -K are equally likely, so the proposal is symmetric and only
//! log(u) enters mu_0 (the prior is uniform on the manifold).

use crate::data::linalg::{random_skew, Mat};
use crate::models::traits::{Proposal, ProposalKernel};
use crate::stats::Pcg64;

pub struct StiefelRandomWalk {
    /// Std-dev of the skew generator entries (step size on the manifold).
    pub sigma: f64,
}

impl StiefelRandomWalk {
    pub fn new(sigma: f64) -> Self {
        assert!(sigma > 0.0);
        StiefelRandomWalk { sigma }
    }
}

impl ProposalKernel<Mat> for StiefelRandomWalk {
    fn propose(&self, cur: &Mat, rng: &mut Pcg64) -> Proposal<Mat> {
        let k = random_skew(cur.d, self.sigma, rng);
        let rot = k.expm();
        Proposal { param: rot.matmul(cur), log_correction: 0.0 }
    }
}

/// Re-orthonormalize a drifting state (numerical hygiene on long chains).
pub fn reorthonormalize(w: &Mat) -> Mat {
    // one Newton iteration of the polar decomposition:
    // W <- W (3 I - W^T W) / 2 (quadratically convergent near the manifold)
    let d = w.d;
    let wtw = w.transpose().matmul(w);
    let mut corr = Mat::eye(d).scale(3.0);
    for i in 0..d {
        for j in 0..d {
            corr[(i, j)] -= wtw[(i, j)];
        }
    }
    w.matmul(&corr).scale(0.5)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::linalg::random_orthonormal;
    use crate::testkit;

    #[test]
    fn proposal_stays_on_manifold() {
        testkit::forall(32, |rng| {
            let d = rng.below(5) + 2;
            let w = random_orthonormal(d, rng);
            let k = StiefelRandomWalk::new(0.1);
            let p = k.propose(&w, rng);
            assert!(p.param.orthonormal_defect() < 1e-8);
            assert_eq!(p.log_correction, 0.0);
        });
    }

    #[test]
    fn step_size_controls_distance() {
        let mut rng = Pcg64::seeded(0);
        let w = random_orthonormal(4, &mut rng);
        let small = StiefelRandomWalk::new(0.01);
        let large = StiefelRandomWalk::new(0.5);
        let mut ds = 0.0;
        let mut dl = 0.0;
        for _ in 0..50 {
            ds += small.propose(&w, &mut rng).param.frobenius_dist(&w);
            dl += large.propose(&w, &mut rng).param.frobenius_dist(&w);
        }
        assert!(dl > 5.0 * ds, "small {ds} large {dl}");
    }

    #[test]
    fn reorthonormalize_projects_back() {
        let mut rng = Pcg64::seeded(1);
        let w = random_orthonormal(4, &mut rng);
        // perturb off the manifold slightly
        let mut drift = w.clone();
        for v in drift.a.iter_mut() {
            *v += 1e-4 * rng.normal();
        }
        let before = drift.orthonormal_defect();
        let fixed = reorthonormalize(&drift);
        assert!(fixed.orthonormal_defect() < before / 50.0);
    }

    #[test]
    fn chain_of_proposals_does_not_drift() {
        let mut rng = Pcg64::seeded(2);
        let mut w = random_orthonormal(4, &mut rng);
        let k = StiefelRandomWalk::new(0.2);
        for _ in 0..500 {
            w = k.propose(&w, &mut rng).param;
        }
        assert!(w.orthonormal_defect() < 1e-6, "defect {}", w.orthonormal_defect());
    }
}

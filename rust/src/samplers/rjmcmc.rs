//! Reversible-jump MCMC for variable selection in logistic regression
//! (paper §6.3, supp. E, following Chen et al. 2011): a mixture of
//! update / birth / death moves over (beta, gamma).
//!
//! The MH correction for each move is computed from the model's
//! (nu-integrated) prior plus the move proposal densities; the supp.-E
//! expressions (Eqns. 37-39) are recovered exactly — a unit test checks
//! the birth/death forms against the closed formulas.

use crate::models::rjlogistic::{RjLogisticModel, RjState};
use crate::models::traits::{Proposal, ProposalKernel};
use crate::stats::Pcg64;

/// Move-type probabilities (boundary-adjusted at k = 1 and k = D).
#[derive(Clone, Copy, Debug)]
pub struct MoveProbs {
    pub update: f64,
    pub birth: f64,
    pub death: f64,
}

impl Default for MoveProbs {
    fn default() -> Self {
        MoveProbs { update: 0.5, birth: 0.25, death: 0.25 }
    }
}

/// The RJ proposal kernel.
pub struct RjKernel<'a> {
    pub model: &'a RjLogisticModel,
    pub sigma_update: f64,
    pub sigma_birth: f64,
    pub probs: MoveProbs,
}

impl<'a> RjKernel<'a> {
    pub fn new(model: &'a RjLogisticModel) -> Self {
        // paper supp. E: sigma_update = 0.01, sigma_birth = 0.1
        RjKernel { model, sigma_update: 0.01, sigma_birth: 0.1, probs: MoveProbs::default() }
    }

    /// Probability of selecting a birth move in state with k active.
    fn p_birth(&self, k: usize) -> f64 {
        if k < self.model.d() {
            self.probs.birth
        } else {
            0.0
        }
    }

    /// Probability of selecting a death move in state with k active.
    fn p_death(&self, k: usize) -> f64 {
        if k > 1 {
            self.probs.death
        } else {
            0.0
        }
    }

    /// Total unnormalized move mass at state k (boundaries drop moves, so
    /// selection probabilities are p_move(k)/total(k)).
    fn total(&self, k: usize) -> f64 {
        self.probs.update + self.p_birth(k) + self.p_death(k)
    }

    /// Normalized selection probability of a birth at state k.
    fn sel_birth(&self, k: usize) -> f64 {
        self.p_birth(k) / self.total(k)
    }

    /// Normalized selection probability of a death at state k.
    fn sel_death(&self, k: usize) -> f64 {
        self.p_death(k) / self.total(k)
    }
}

/// log N(x; 0, sigma^2).
#[inline]
fn log_normal0(x: f64, sigma: f64) -> f64 {
    -0.5 * (x * x) / (sigma * sigma)
        - 0.5 * (2.0 * std::f64::consts::PI).ln()
        - sigma.ln()
}

impl ProposalKernel<RjState> for RjKernel<'_> {
    fn propose(&self, cur: &RjState, rng: &mut Pcg64) -> Proposal<RjState> {
        let d = self.model.d();
        let k = cur.k();
        debug_assert!(k >= 1);
        let r = rng.uniform();
        let pb = self.p_birth(k);
        let pd = self.p_death(k);
        // renormalize over available moves
        let total = self.probs.update + pb + pd;
        let r = r * total;

        if r < self.probs.update {
            // ---- update move: perturb one active coefficient ----
            let pick = cur.active[rng.below(k)];
            let mut prop = cur.clone();
            prop.beta[pick] += self.sigma_update * rng.normal();
            // symmetric in beta; prior ratio only (Eqn. 37)
            let c = self.model.log_prior(cur) - self.model.log_prior(&prop);
            Proposal { param: prop, log_correction: c }
        } else if r < self.probs.update + pb {
            // ---- birth move: activate a random inactive feature ----
            let inactive: Vec<usize> =
                (0..d).filter(|j| !cur.active.contains(j)).collect();
            let pick = inactive[rng.below(inactive.len())];
            let new_beta = self.sigma_birth * rng.normal();
            let mut prop = cur.clone();
            prop.beta[pick] = new_beta;
            prop.active.push(pick);
            prop.active.sort_unstable();

            // q(prop|cur) = sel_birth(k) * 1/(D-k) * N(new_beta; 0, sb)
            // q(cur|prop) = sel_death(k+1) * 1/(k+1)
            let log_q_fwd = self.sel_birth(k).ln() - ((d - k) as f64).ln()
                + log_normal0(new_beta, self.sigma_birth);
            let log_q_rev = self.sel_death(k + 1).ln() - ((k + 1) as f64).ln();
            let c = self.model.log_prior(cur) - self.model.log_prior(&prop) + log_q_fwd
                - log_q_rev;
            Proposal { param: prop, log_correction: c }
        } else {
            // ---- death move: deactivate a random active feature ----
            let pos = rng.below(k);
            let pick = cur.active[pos];
            let removed_beta = cur.beta[pick];
            let mut prop = cur.clone();
            prop.beta[pick] = 0.0;
            prop.active.remove(pos);

            // q(prop|cur) = sel_death(k) * 1/k
            // q(cur|prop) = sel_birth(k-1) * 1/(D-(k-1)) * N(removed; 0, sb)
            let log_q_fwd = self.sel_death(k).ln() - (k as f64).ln();
            let log_q_rev = self.sel_birth(k - 1).ln() - ((d - (k - 1)) as f64).ln()
                + log_normal0(removed_beta, self.sigma_birth);
            let c = self.model.log_prior(cur) - self.model.log_prior(&prop) + log_q_fwd
                - log_q_rev;
            Proposal { param: prop, log_correction: c }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{run_chain, Budget, MhMode};
    use crate::data::synthetic::sparse_logistic;
    use crate::models::rjlogistic::ln_beta;

    fn setup() -> (RjLogisticModel, Vec<f64>) {
        let (ds, beta) = sparse_logistic(2_000, 11, 3, 0.3, 0);
        (RjLogisticModel::new(ds, 1e-10), beta)
    }

    #[test]
    fn moves_preserve_state_invariants() {
        let (m, _) = setup();
        let kernel = RjKernel::new(&m);
        let mut rng = Pcg64::seeded(1);
        let mut cur = RjState::with_active(11, &[0, 3], &[0.2, -0.1]);
        for _ in 0..2_000 {
            let p = kernel.propose(&cur, &mut rng);
            let s = &p.param;
            // active sorted + unique, k in [1, D]
            assert!(s.k() >= 1 && s.k() <= 11);
            assert!(s.active.windows(2).all(|w| w[0] < w[1]), "{:?}", s.active);
            // inactive betas are zeroed
            for j in 0..11 {
                if !s.active.contains(&j) {
                    assert_eq!(s.beta[j], 0.0, "ghost beta at {j}");
                }
            }
            assert!(p.log_correction.is_finite());
            // randomly adopt some proposals to explore state space
            if rng.uniform() < 0.5 {
                cur = p.param;
            }
        }
    }

    #[test]
    fn birth_correction_matches_eqn38() {
        // Validate our prior+proposal bookkeeping against the closed form
        // of supp. Eqn. 38 (up to the same-move-probability convention).
        let (m, _) = setup();
        let d = 11f64;
        let cur = RjState::with_active(11, &[1, 2], &[0.5, -0.5]);
        let k = 2f64;
        let new_beta = 0.07;
        let mut prop = cur.clone();
        prop.beta[5] = new_beta;
        prop.active.push(5);
        prop.active.sort_unstable();

        let kernel = RjKernel::new(&m);
        // hand-evaluate the kernel's expression
        let pb = kernel.sel_birth(2);
        let log_q_fwd = pb.ln() - (d - k).ln() + log_normal0(new_beta, kernel.sigma_birth);
        let log_q_rev = kernel.sel_death(3).ln() - 3f64.ln();
        let c_kernel =
            m.log_prior(&cur) - m.log_prior(&prop) + log_q_fwd - log_q_rev;

        // Eqn. 38 (with B-function ratio expanded):
        // c = log[ l1^{-k} p(g->g') N(b;0,sb) (D-k) / ( l1'^{-(k+1)} p(g'->g) lam k~ ) ]
        // where the beta-function ratio B(k+1, D-k)/B(k, D-k+1) = k/(D-k)
        // enters the prior difference; reconstruct from the model prior:
        let lam = m.lambda;
        let l1 = cur.l1();
        let l1p = prop.l1();
        let prior_ratio = (-k * l1.ln() + k * lam.ln() + ln_beta(k, d - k + 1.0))
            - (-(k + 1.0) * l1p.ln() + (k + 1.0) * lam.ln() + ln_beta(k + 1.0, d - k));
        let want = prior_ratio
            + (pb.ln() - (d - k).ln() + log_normal0(new_beta, kernel.sigma_birth))
            - (kernel.sel_death(3).ln() - 3f64.ln());
        assert!((c_kernel - want).abs() < 1e-12, "{c_kernel} vs {want}");
    }

    #[test]
    fn death_is_reverse_of_birth() {
        // detailed-balance bookkeeping: c_death(prop->cur) = -c_birth(cur->prop)
        let (m, _) = setup();
        let kernel = RjKernel::new(&m);
        let cur = RjState::with_active(11, &[1, 2], &[0.5, -0.5]);
        let new_beta = -0.3;
        let mut prop = cur.clone();
        prop.beta[7] = new_beta;
        prop.active.push(7);
        prop.active.sort_unstable();

        let d = 11f64;
        let k = 2f64;
        let c_birth = m.log_prior(&cur) - m.log_prior(&prop)
            + (kernel.sel_birth(2).ln() - (d - k).ln()
                + log_normal0(new_beta, kernel.sigma_birth))
            - (kernel.sel_death(3).ln() - (k + 1.0).ln());
        let c_death = m.log_prior(&prop) - m.log_prior(&cur)
            + (kernel.sel_death(3).ln() - (k + 1.0).ln())
            - (kernel.sel_birth(2).ln() - (d - k).ln()
                + log_normal0(new_beta, kernel.sigma_birth));
        assert!((c_birth + c_death).abs() < 1e-12);
    }

    #[test]
    fn chain_recovers_sparse_support() {
        // With exact MH, the RJ chain should concentrate on the true
        // active features (plus intercept) of the synthetic data.
        let (m, beta_true) = setup();
        let kernel = RjKernel::new(&m);
        let mut rng = Pcg64::seeded(4);
        // nonzero init coefficient: ||beta||_1 = 0 has infinite prior density
        let init = RjState::with_active(11, &[0], &[-0.5]);
        let mut inclusion = vec![0u64; 11];
        let mut count = 0u64;
        let (_, stats) = run_chain(
            &m,
            &kernel,
            &MhMode::Exact,
            init,
            Budget::Steps(12_000),
            2_000,
            1,
            |s| {
                for &j in &s.active {
                    inclusion[j] += 1;
                }
                count += 1;
                s.k() as f64
            },
            &mut rng,
        );
        assert!(stats.acceptance_rate() > 0.02);
        let truly_active: Vec<usize> =
            (1..11).filter(|&j| beta_true[j] != 0.0).collect();
        let truly_inactive: Vec<usize> =
            (1..11).filter(|&j| beta_true[j] == 0.0).collect();
        let mean_incl = |ids: &[usize]| {
            ids.iter().map(|&j| inclusion[j] as f64 / count as f64).sum::<f64>()
                / ids.len() as f64
        };
        let on = mean_incl(&truly_active);
        let off = mean_incl(&truly_inactive);
        assert!(on > off + 0.2, "active incl {on} vs inactive {off}");
    }

    #[test]
    fn k_never_hits_zero() {
        let (m, _) = setup();
        let kernel = RjKernel::new(&m);
        let mut rng = Pcg64::seeded(5);
        let mut cur = RjState::with_active(11, &[2], &[0.1]);
        for _ in 0..5_000 {
            let p = kernel.propose(&cur, &mut rng);
            assert!(p.param.k() >= 1);
            if rng.uniform() < 0.3 {
                cur = p.param;
            }
        }
    }
}

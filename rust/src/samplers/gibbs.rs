//! Exact and approximate Gibbs sampling for dense binary MRFs
//! (paper supp. F): the conditional flip of variable v is decided by the
//! same sequential test, run over the population of potential pairs.
//!
//! The accept threshold is mu_0 = (1/Np) log(u / (1 - u)) — note the
//! paper's Eqn. 42 prints log u / log(1-u), a typo: u < p1/(p0+p1) is
//! equivalent to mean lldiff > log(u/(1-u))/Np (see DESIGN.md).

use crate::coordinator::austerity::BoundSeq;
use crate::coordinator::checkpoint::{BinReader, BinWriter, CkptError, Persist};
use crate::coordinator::kernel::{restore_sched, StepOutcome, TransitionKernel};
use crate::coordinator::scheduler::MinibatchScheduler;
use crate::models::mrf::MrfModel;
use crate::stats::student_t::t_sf;
use crate::stats::welford::MomentAccumulator;
use crate::stats::Pcg64;

/// Gibbs update mode.
#[derive(Clone, Debug)]
pub enum GibbsMode {
    Exact,
    /// Sequential test over pair mini-batches.
    Approx { eps: f64, batch: usize },
}

/// Counters for one run.
#[derive(Clone, Debug, Default)]
pub struct GibbsStats {
    pub updates: usize,
    /// Total potential-pair evaluations.
    pub pairs_used: u64,
    pub ones_assigned: u64,
}

/// Scratch to avoid per-update allocation.
pub struct GibbsScratch {
    sched: MinibatchScheduler,
    ranks: Vec<usize>,
}

impl GibbsScratch {
    pub fn new(model: &MrfModel) -> Self {
        let sched = MinibatchScheduler::new(model.n_pairs())
            .expect("MRF pair population exceeds the u32 index space");
        GibbsScratch { sched, ranks: Vec::new() }
    }
}

/// One Gibbs update of variable `v`; returns pairs consumed.
pub fn gibbs_update(
    model: &MrfModel,
    v: usize,
    x: &mut [bool],
    mode: &GibbsMode,
    scratch: &mut GibbsScratch,
    rng: &mut Pcg64,
) -> usize {
    let np = model.n_pairs();
    let u = rng.uniform_pos();
    // guard against u == 1 (log(u/(1-u)) = inf)
    let u = u.min(1.0 - 1e-16);
    let mu0 = (u / (1.0 - u)).ln() / np as f64;

    match mode {
        GibbsMode::Exact => {
            let mu = model.exact_log_ratio(v, x) / np as f64;
            x[v] = mu > mu0;
            np
        }
        GibbsMode::Approx { eps, batch } => {
            let bound = BoundSeq::Pocock { eps: *eps };
            scratch.sched.reset();
            let mut acc = MomentAccumulator::new();
            loop {
                let b = scratch.sched.next_batch(*batch, rng);
                debug_assert!(!b.is_empty());
                scratch.ranks.clear();
                scratch.ranks.extend(b.iter().map(|&i| i as usize));
                let (s, s2) = model.pair_moments(v, &scratch.ranks, x);
                acc.add_batch(s, s2, scratch.ranks.len());

                let n = acc.n();
                let t = acc.t_statistic(mu0, np);
                let delta = t_sf(t.abs(), (n - 1).max(1) as f64);
                let pi = n as f64 / np as f64;
                if delta < bound.eps_at(pi) || n == np {
                    x[v] = acc.mean() > mu0;
                    return n;
                }
            }
        }
    }
}

/// One full sweep (each variable once, in order), updating stats.
pub fn gibbs_sweep(
    model: &MrfModel,
    x: &mut [bool],
    mode: &GibbsMode,
    scratch: &mut GibbsScratch,
    stats: &mut GibbsStats,
    rng: &mut Pcg64,
) {
    for v in 0..model.d() {
        let used = gibbs_update(model, v, x, mode, scratch, rng);
        stats.updates += 1;
        stats.pairs_used += used as u64;
        stats.ones_assigned += x[v] as u64;
    }
}

/// One full Gibbs sweep as a `TransitionKernel`: the engine's "step" is
/// a systematic-scan sweep (each variable once, in order), its cost the
/// potential-pair evaluations the sweep consumed. Runs the MRF
/// experiments (supp. F) on the same K-chain engine as the MH families.
pub struct GibbsSweepKernel<'a> {
    pub model: &'a MrfModel,
    pub mode: GibbsMode,
}

impl TransitionKernel for GibbsSweepKernel<'_> {
    type State = Vec<bool>;
    type Scratch = GibbsScratch;

    fn scratch(&self, _init: &Vec<bool>) -> GibbsScratch {
        GibbsScratch::new(self.model)
    }

    fn step(&self, x: &mut Vec<bool>, scratch: &mut GibbsScratch, rng: &mut Pcg64) -> StepOutcome {
        let mut stats = GibbsStats::default();
        gibbs_sweep(self.model, x, &self.mode, scratch, &mut stats, rng);
        // a sweep always advances the state; cost is in pair evaluations
        StepOutcome { accepted: true, data_used: stats.pairs_used, guard_trips: 0 }
    }

    // The approximate mode's scheduler permutation carries across sweeps;
    // the exact mode writes an untouched (fresh-equivalent) buffer.
    fn save_scratch(&self, scratch: &GibbsScratch, w: &mut BinWriter) {
        scratch.sched.persist(w);
    }

    fn restore_scratch(
        &self,
        scratch: &mut GibbsScratch,
        r: &mut BinReader<'_>,
    ) -> Result<(), CkptError> {
        restore_sched(&mut scratch.sched, self.model.n_pairs(), r)
    }
}

/// Empirical joint distribution over a subset of variables, as
/// probabilities over the 2^|subset| configurations (supp. F.1 metric).
pub struct SubsetMarginal {
    pub vars: Vec<usize>,
    counts: Vec<u64>,
    total: u64,
}

impl SubsetMarginal {
    pub fn new(vars: Vec<usize>) -> Self {
        assert!(vars.len() <= 20);
        let k = vars.len();
        SubsetMarginal { vars, counts: vec![0; 1 << k], total: 0 }
    }

    pub fn record(&mut self, x: &[bool]) {
        let mut idx = 0usize;
        for (b, &v) in self.vars.iter().enumerate() {
            idx |= (x[v] as usize) << b;
        }
        self.counts[idx] += 1;
        self.total += 1;
    }

    /// Fold another chain's counts into this marginal (for merging
    /// per-chain or per-shard observers after an engine run). Validates
    /// the subsets match and that no counter overflows; on error the
    /// receiver is left untouched (no partial merge).
    pub fn merge(&mut self, other: &SubsetMarginal) -> Result<(), MergeError> {
        if self.vars != other.vars {
            return Err(MergeError::VarsMismatch);
        }
        // stage every checked sum before committing any of them
        let mut summed = Vec::with_capacity(self.counts.len());
        for (a, b) in self.counts.iter().zip(&other.counts) {
            summed.push(a.checked_add(*b).ok_or(MergeError::CountOverflow)?);
        }
        let total = self.total.checked_add(other.total).ok_or(MergeError::CountOverflow)?;
        self.counts = summed;
        self.total = total;
        Ok(())
    }

    pub fn probs(&self) -> Vec<f64> {
        if self.total == 0 {
            return vec![0.0; self.counts.len()];
        }
        self.counts.iter().map(|&c| c as f64 / self.total as f64).collect()
    }

    /// L1 distance to another probability vector.
    pub fn l1_to(&self, other: &[f64]) -> f64 {
        self.probs()
            .iter()
            .zip(other)
            .map(|(a, b)| (a - b).abs())
            .sum()
    }
}

/// Why a cross-chain / cross-shard combine was refused.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum MergeError {
    /// The two marginals track different variable subsets.
    VarsMismatch,
    /// A configuration counter (or the total) would overflow `u64`.
    CountOverflow,
    /// A sub-posterior contributes no usable mass (no parts, a
    /// non-finite moment, or a non-positive variance).
    Degenerate,
}

impl std::fmt::Display for MergeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            MergeError::VarsMismatch => write!(f, "marginals track different variable subsets"),
            MergeError::CountOverflow => write!(f, "merged count would overflow u64"),
            MergeError::Degenerate => {
                write!(f, "sub-posterior is degenerate (empty, non-finite, or zero-variance)")
            }
        }
    }
}

impl std::error::Error for MergeError {}

/// First two moments of one shard's marginal posterior over a scalar
/// parameter, plus the draw count behind them.
#[derive(Clone, Copy, Debug)]
pub struct GaussianMoments {
    pub mean: f64,
    pub var: f64,
    /// Number of posterior draws the moments were estimated from.
    pub n: u64,
}

/// Consensus / subset-posterior combination for continuous parameters
/// (Scott et al. CMC; Neiswanger et al. embarrassingly-parallel MCMC):
/// treat each shard's sub-posterior as Gaussian and form the product
/// density, which is again Gaussian with precision the sum of shard
/// precisions and mean the precision-weighted average:
///
///   Lambda = sum_s 1/var_s,   mean = (sum_s mean_s/var_s) / Lambda,
///   var = 1/Lambda.
///
/// Exact when the sub-posteriors really are Gaussian (e.g. conjugate
/// models under the 1/k-tempered prior); an asymptotically-justified
/// approximation otherwise. Refuses degenerate inputs instead of
/// emitting NaN/inf.
pub fn gaussian_product(parts: &[GaussianMoments]) -> Result<GaussianMoments, MergeError> {
    if parts.is_empty() {
        return Err(MergeError::Degenerate);
    }
    let mut lambda = 0.0f64;
    let mut weighted = 0.0f64;
    let mut n = 0u64;
    for p in parts {
        if !p.mean.is_finite() || !p.var.is_finite() || p.var <= 0.0 {
            return Err(MergeError::Degenerate);
        }
        let prec = 1.0 / p.var;
        lambda += prec;
        weighted += p.mean * prec;
        n = n.checked_add(p.n).ok_or(MergeError::CountOverflow)?;
    }
    if !lambda.is_finite() || !weighted.is_finite() {
        return Err(MergeError::Degenerate);
    }
    Ok(GaussianMoments { mean: weighted / lambda, var: 1.0 / lambda, n })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> MrfModel {
        MrfModel::random(6, 0.3, 0)
    }

    #[test]
    fn exact_update_matches_conditional_frequency() {
        // repeated exact updates at a fixed neighborhood assign 1 with
        // the exact conditional probability.
        let m = tiny();
        let mut rng = Pcg64::seeded(1);
        let mut scratch = GibbsScratch::new(&m);
        let base: Vec<bool> = (0..6).map(|i| i % 2 == 0).collect();
        let v = 2;
        let want = m.exact_conditional(v, &base);
        let trials = 40_000;
        let mut ones = 0;
        for _ in 0..trials {
            let mut x = base.clone();
            gibbs_update(&m, v, &mut x, &GibbsMode::Exact, &mut scratch, &mut rng);
            ones += x[v] as usize;
        }
        let got = ones as f64 / trials as f64;
        assert!((got - want).abs() < 0.01, "got {got} want {want}");
    }

    #[test]
    fn approx_update_tracks_conditional() {
        let m = MrfModel::random(24, 0.1, 2);
        let mut rng = Pcg64::seeded(3);
        let mut scratch = GibbsScratch::new(&m);
        let base: Vec<bool> = (0..24).map(|i| i % 3 == 0).collect();
        let v = 5;
        let want = m.exact_conditional(v, &base);
        let trials = 8_000;
        let mut ones = 0;
        let mode = GibbsMode::Approx { eps: 0.05, batch: 40 };
        for _ in 0..trials {
            let mut x = base.clone();
            gibbs_update(&m, v, &mut x, &mode, &mut scratch, &mut rng);
            ones += x[v] as usize;
        }
        let got = ones as f64 / trials as f64;
        assert!((got - want).abs() < 0.05, "got {got} want {want}");
    }

    #[test]
    fn approx_uses_fewer_pairs_with_larger_eps() {
        let m = MrfModel::random(40, 0.05, 4);
        let mut rng = Pcg64::seeded(5);
        let mut scratch = GibbsScratch::new(&m);
        let mut x: Vec<bool> = (0..40).map(|_| rng.uniform() < 0.5).collect();
        let mut used = Vec::new();
        for &eps in &[0.01, 0.2] {
            let mode = GibbsMode::Approx { eps, batch: 50 };
            let mut stats = GibbsStats::default();
            let mut r = Pcg64::seeded(6);
            for _ in 0..5 {
                gibbs_sweep(&m, &mut x, &mode, &mut scratch, &mut stats, &mut r);
            }
            used.push(stats.pairs_used);
        }
        assert!(used[1] <= used[0], "{used:?}");
    }

    #[test]
    fn exact_sweep_counts() {
        let m = tiny();
        let mut rng = Pcg64::seeded(7);
        let mut scratch = GibbsScratch::new(&m);
        let mut x = vec![false; 6];
        let mut stats = GibbsStats::default();
        gibbs_sweep(&m, &mut x, &GibbsMode::Exact, &mut scratch, &mut stats, &mut rng);
        assert_eq!(stats.updates, 6);
        assert_eq!(stats.pairs_used, (6 * m.n_pairs()) as u64);
    }

    #[test]
    fn exact_chain_matches_bruteforce_marginals() {
        // D=6: enumerate the joint exactly and compare Gibbs marginals.
        let m = tiny();
        let d = 6;
        // brute force P(x)
        let mut probs = vec![0.0f64; 1 << d];
        let mut logs = vec![0.0f64; 1 << d];
        for cfg in 0..(1usize << d) {
            let x: Vec<bool> = (0..d).map(|b| (cfg >> b) & 1 == 1).collect();
            logs[cfg] = m.log_joint(&x);
        }
        let max = logs.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        let mut z = 0.0;
        for cfg in 0..(1 << d) {
            probs[cfg] = (logs[cfg] - max).exp();
            z += probs[cfg];
        }
        for p in probs.iter_mut() {
            *p /= z;
        }
        let want_marginal: Vec<f64> = (0..d)
            .map(|v| {
                (0..(1usize << d))
                    .filter(|cfg| (cfg >> v) & 1 == 1)
                    .map(|cfg| probs[cfg])
                    .sum()
            })
            .collect();

        let mut rng = Pcg64::seeded(8);
        let mut scratch = GibbsScratch::new(&m);
        let mut x = vec![false; d];
        let mut stats = GibbsStats::default();
        let sweeps = 30_000;
        let mut ones = vec![0u64; d];
        for s in 0..sweeps {
            gibbs_sweep(&m, &mut x, &GibbsMode::Exact, &mut scratch, &mut stats, &mut rng);
            if s >= 1000 {
                for v in 0..d {
                    ones[v] += x[v] as u64;
                }
            }
        }
        for v in 0..d {
            let got = ones[v] as f64 / (sweeps - 1000) as f64;
            assert!(
                (got - want_marginal[v]).abs() < 0.02,
                "var {v}: got {got} want {}",
                want_marginal[v]
            );
        }
    }

    #[test]
    fn subset_marginal_bookkeeping() {
        let mut sm = SubsetMarginal::new(vec![0, 2]);
        sm.record(&[true, false, false]);
        sm.record(&[true, false, true]);
        sm.record(&[false, false, true]);
        let p = sm.probs();
        // configs: bit0 = x[0], bit1 = x[2]
        assert!((p[0b01] - 1.0 / 3.0).abs() < 1e-12); // x0=1, x2=0
        assert!((p[0b11] - 1.0 / 3.0).abs() < 1e-12);
        assert!((p[0b10] - 1.0 / 3.0).abs() < 1e-12);
        assert!((sm.l1_to(&[0.0, 1.0 / 3.0, 1.0 / 3.0, 1.0 / 3.0])).abs() < 1e-12);
    }

    #[test]
    fn merge_folds_counts_and_rejects_mismatched_subsets() {
        let mut a = SubsetMarginal::new(vec![0, 2]);
        let mut b = SubsetMarginal::new(vec![0, 2]);
        a.record(&[true, false, false]);
        b.record(&[true, false, false]);
        b.record(&[false, false, true]);
        a.merge(&b).unwrap();
        let p = a.probs();
        assert!((p[0b01] - 2.0 / 3.0).abs() < 1e-12);
        assert!((p[0b10] - 1.0 / 3.0).abs() < 1e-12);
        // different subsets: typed error, receiver untouched
        let other = SubsetMarginal::new(vec![0, 1]);
        assert_eq!(a.merge(&other).unwrap_err(), MergeError::VarsMismatch);
        assert_eq!(a.probs(), p);
    }

    #[test]
    fn merge_overflow_is_an_error_not_a_wrap() {
        let mut a = SubsetMarginal::new(vec![0]);
        let mut b = SubsetMarginal::new(vec![0]);
        // drive one counter to the brink through the public API surface
        // of the test module (fields are visible here)
        a.counts[0] = u64::MAX - 1;
        a.total = u64::MAX - 1;
        b.counts[0] = 5;
        b.total = 5;
        assert_eq!(a.merge(&b).unwrap_err(), MergeError::CountOverflow);
        // no partial merge: the near-saturated counters are unchanged
        assert_eq!(a.counts[0], u64::MAX - 1);
        assert_eq!(a.total, u64::MAX - 1);
    }

    #[test]
    fn gaussian_product_matches_closed_form() {
        // two Gaussians: N(0, 1) * N(2, 1) = N(1, 1/2)
        let parts = [
            GaussianMoments { mean: 0.0, var: 1.0, n: 100 },
            GaussianMoments { mean: 2.0, var: 1.0, n: 200 },
        ];
        let g = gaussian_product(&parts).unwrap();
        assert!((g.mean - 1.0).abs() < 1e-12);
        assert!((g.var - 0.5).abs() < 1e-12);
        assert_eq!(g.n, 300);
        // a single part is the identity
        let one = gaussian_product(&parts[..1]).unwrap();
        assert_eq!(one.mean.to_bits(), 0.0f64.to_bits());
        assert!((one.var - 1.0).abs() < 1e-12);
    }

    #[test]
    fn gaussian_product_weighting_favors_tight_shards() {
        let parts = [
            GaussianMoments { mean: 0.0, var: 0.01, n: 10 },
            GaussianMoments { mean: 10.0, var: 100.0, n: 10 },
        ];
        let g = gaussian_product(&parts).unwrap();
        assert!(g.mean < 0.01, "tight shard dominates: {}", g.mean);
        assert!(g.var < 0.01);
    }

    #[test]
    fn gaussian_product_refuses_degenerate_parts() {
        assert_eq!(gaussian_product(&[]).unwrap_err(), MergeError::Degenerate);
        let bad_var = [GaussianMoments { mean: 0.0, var: 0.0, n: 1 }];
        assert_eq!(gaussian_product(&bad_var).unwrap_err(), MergeError::Degenerate);
        let bad_mean = [GaussianMoments { mean: f64::NAN, var: 1.0, n: 1 }];
        assert_eq!(gaussian_product(&bad_mean).unwrap_err(), MergeError::Degenerate);
        let overflow = [
            GaussianMoments { mean: 0.0, var: 1.0, n: u64::MAX },
            GaussianMoments { mean: 0.0, var: 1.0, n: 1 },
        ];
        assert_eq!(gaussian_product(&overflow).unwrap_err(), MergeError::CountOverflow);
    }
}

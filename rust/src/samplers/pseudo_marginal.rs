//! Pseudo-marginal MCMC baseline (paper §4's counter-argument).
//!
//! The paper contrasts its biased-but-controlled test with *exact*
//! subsampled MCMC via unbiased likelihood estimators (Andrieu & Roberts
//! 2009) such as the Poisson estimator (Fearnhead et al. 2008): plug an
//! unbiased estimate `Lhat ~ L(theta)` into the MH ratio and the chain
//! still targets the exact posterior — but mini-batch estimators of
//! `exp(sum_i l_i)` have enormous variance for large N, so "once we get
//! a very high estimate of the likelihood, almost all proposed moves are
//! rejected and the algorithm gets stuck".
//!
//! This module implements that baseline so the claim is measurable: a
//! Poisson estimator of the likelihood *ratio* from mini-batch means,
//! and the pseudo-marginal chain that carries `Lhat` in its state. The
//! ablation bench shows acceptance collapsing as N grows while the
//! sequential test keeps mixing.

use crate::coordinator::engine::ChainObserver;
use crate::coordinator::checkpoint::{BinReader, BinWriter, CkptError, Persist};
use crate::coordinator::kernel::{restore_sched, StepOutcome, TransitionKernel};
use crate::coordinator::scheduler::MinibatchScheduler;
use crate::models::traits::{LlDiffModel, Proposal, ProposalKernel};
use crate::stats::Pcg64;

/// Configuration of the Poisson estimator for `exp(N mu)` where
/// `mu = (1/N) sum_i l_i` is estimated from mini-batch means.
#[derive(Clone, Debug)]
pub struct PoissonEstimator {
    /// mini-batch size per likelihood-mean draw
    pub batch: usize,
    /// Poisson rate lambda: expected number of factors per estimate
    pub lambda: f64,
    /// exponent centering constant a (stabilizer); the estimator is
    /// exp(a + lambda) * prod_j (S_j - a) / lambda with J ~ Poisson(lambda)
    /// and S_j independent unbiased estimates of N*mu.
    pub center: f64,
}

/// One unbiased estimate of `N * mu` from a fresh mini-batch.
fn unbiased_log_ratio_estimate<M: LlDiffModel>(
    model: &M,
    cur: &M::Param,
    prop: &M::Param,
    sched: &mut MinibatchScheduler,
    batch: usize,
    rng: &mut Pcg64,
) -> f64 {
    sched.reset();
    let ids = sched.next_batch(batch, rng);
    let (s, _) = model.lldiff_moments(ids, cur, prop);
    s * (model.n() as f64 / ids.len() as f64)
}

/// Outcome of one ratio estimation.
#[derive(Clone, Copy, Debug)]
pub struct RatioEstimate {
    pub value: f64,
    /// number of mini-batches consumed
    pub stages: usize,
    /// the estimator went negative and was clamped (a known pathology)
    pub clamped: bool,
}

impl PoissonEstimator {
    /// Unbiased estimate of the likelihood ratio exp(N mu) via the
    /// Poisson/von-Neumann series. Can be negative; we clamp at 0 and
    /// report it (the standard practical fix, which introduces its own
    /// bias — part of why the paper rejects this route).
    pub fn estimate_ratio<M: LlDiffModel>(
        &self,
        model: &M,
        cur: &M::Param,
        prop: &M::Param,
        sched: &mut MinibatchScheduler,
        rng: &mut Pcg64,
    ) -> RatioEstimate {
        // draw J ~ Poisson(lambda) by inversion (lambda is small)
        let mut j = 0usize;
        let mut p = (-self.lambda).exp();
        let mut cdf = p;
        let u = rng.uniform();
        while u > cdf && j < 1_000 {
            j += 1;
            p *= self.lambda / j as f64;
            cdf += p;
        }

        let mut value = (self.center + self.lambda).exp();
        let mut stages = 0usize;
        for _ in 0..j {
            let s = unbiased_log_ratio_estimate(model, cur, prop, sched, self.batch, rng);
            stages += 1;
            value *= (s - self.center) / self.lambda;
        }
        let clamped = value < 0.0;
        RatioEstimate { value: value.max(0.0), stages, clamped }
    }
}

/// Counters for a pseudo-marginal run.
#[derive(Clone, Debug, Default)]
pub struct PmStats {
    pub steps: usize,
    pub accepted: usize,
    pub data_used: u64,
    pub clamped: usize,
    /// longest run of consecutive rejections (the "stuck" symptom)
    pub longest_stuck: usize,
}

/// Pseudo-marginal chain state: the auxiliary-variable construction
/// carries the likelihood-ratio estimate of the current parameter, so
/// `weight` is genuinely part of the Markov state. The pathology
/// counters ride along (chain-local, observable mid-run) because the
/// engine reports them through observers — see `PmPathology`.
#[derive(Clone, Debug)]
pub struct PmState<P> {
    pub param: P,
    /// `What(param)` — the carried estimate of L(param)/L(anchor).
    pub weight: f64,
    /// Estimates clamped at zero so far (the estimator pathology).
    pub clamped: usize,
    /// Current run of consecutive rejections.
    pub stuck: usize,
    /// Longest rejection run so far (the "stuck" symptom of §4).
    pub longest_stuck: usize,
}

/// The pseudo-marginal family as a `TransitionKernel` (paper §4's
/// counter-argument, on the same engine as everything else). The anchor
/// of the ratio estimator is the chain's initialization. Step-for-step
/// RNG-identical to the bespoke `run_pseudo_marginal` loop
/// (regression-tested in `tests/integration_engine.rs`).
pub struct PmKernel<'a, M: LlDiffModel, K> {
    model: &'a M,
    proposal: &'a K,
    est: &'a PoissonEstimator,
    anchor: M::Param,
}

/// Chain-local estimator workspace.
pub struct PmScratch {
    sched: MinibatchScheduler,
}

impl<'a, M: LlDiffModel, K> PmKernel<'a, M, K> {
    /// `init` becomes both the chain start and the estimator anchor
    /// (W(init) against itself is exactly 1 — no estimation noise).
    pub fn new(model: &'a M, proposal: &'a K, est: &'a PoissonEstimator, init: M::Param) -> Self {
        PmKernel { model, proposal, est, anchor: init }
    }

    /// The matching initial chain state.
    pub fn init_state(&self) -> PmState<M::Param> {
        PmState {
            param: self.anchor.clone(),
            weight: 1.0,
            clamped: 0,
            stuck: 0,
            longest_stuck: 0,
        }
    }
}

impl<M, K> TransitionKernel for PmKernel<'_, M, K>
where
    M: LlDiffModel,
    K: ProposalKernel<M::Param>,
{
    type State = PmState<M::Param>;
    type Scratch = PmScratch;

    fn scratch(&self, _init: &PmState<M::Param>) -> PmScratch {
        PmScratch { sched: MinibatchScheduler::new(self.model.n()).expect("population exceeds the u32 index space") }
    }

    fn step(
        &self,
        state: &mut PmState<M::Param>,
        s: &mut PmScratch,
        rng: &mut Pcg64,
    ) -> StepOutcome {
        let Proposal { param, log_correction } = self.proposal.propose(&state.param, rng);
        let r = self.est.estimate_ratio(self.model, &self.anchor, &param, &mut s.sched, rng);
        let data_used = (r.stages * self.est.batch) as u64;
        state.clamped += r.clamped as usize;
        let a = if state.weight > 0.0 {
            (r.value / state.weight) * (-log_correction).exp()
        } else {
            1.0
        };
        let accepted = rng.uniform() < a.min(1.0);
        if accepted {
            state.param = param;
            state.weight = r.value;
            state.stuck = 0;
        } else {
            state.stuck += 1;
            state.longest_stuck = state.longest_stuck.max(state.stuck);
        }
        StepOutcome { accepted, data_used, guard_trips: 0 }
    }

    fn save_scratch(&self, scratch: &PmScratch, w: &mut BinWriter) {
        scratch.sched.persist(w);
    }

    fn restore_scratch(
        &self,
        scratch: &mut PmScratch,
        r: &mut BinReader<'_>,
    ) -> Result<(), CkptError> {
        restore_sched(&mut scratch.sched, self.model.n(), r)
    }
}

/// The carried weight and pathology counters are genuinely Markov state
/// (see [`PmState`]), so they checkpoint with the parameter.
impl<P: Persist> Persist for PmState<P> {
    fn persist(&self, w: &mut BinWriter) {
        self.param.persist(w);
        w.put_f64(self.weight);
        w.put_usize(self.clamped);
        w.put_usize(self.stuck);
        w.put_usize(self.longest_stuck);
    }

    fn restore(r: &mut BinReader<'_>) -> Result<Self, CkptError> {
        Ok(PmState {
            param: P::restore(r)?,
            weight: r.f64()?,
            clamped: r.usize_()?,
            stuck: r.usize_()?,
            longest_stuck: r.usize_()?,
        })
    }
}

/// Observer that snapshots the pathology counters off the chain state
/// and records the carried weight as the convergence test function.
/// Observers only see recorded states, so the snapshots are the final
/// chain counters exactly when every step is recorded (`burn_in = 0`,
/// `thin = 1` — how every PM driver runs); under thinning they lag by
/// up to `thin - 1` steps.
#[derive(Clone, Debug, Default)]
pub struct PmPathology {
    pub clamped: usize,
    pub longest_stuck: usize,
}

impl<P> ChainObserver<PmState<P>> for PmPathology
where
    P: Clone + Send,
{
    fn observe(&mut self, s: &PmState<P>) -> f64 {
        self.clamped = s.clamped;
        self.longest_stuck = s.longest_stuck;
        s.weight
    }
}

/// Run a pseudo-marginal chain. The auxiliary-variable construction
/// requires the chain to CARRY the likelihood estimate of the current
/// state (re-estimating each step would be Monte-Carlo-within-Metropolis,
/// a different — and still inexact — algorithm). We estimate
/// `W(theta) ~ L(theta)/L(anchor)` against a fixed anchor (the init) and
/// accept with `min(1, What'/What_cur * e^{-c})`; a lucky high `What_cur`
/// then rejects everything until it is displaced — the sticking the
/// paper describes.
///
/// Pre-refactor bespoke loop, retained for one release as the
/// same-seed equivalence oracle of `PmKernel` (see
/// `tests/integration_engine.rs`); new code should drive `PmKernel`
/// through `drive_chain` / `run_engine_kernel` instead.
#[allow(clippy::too_many_arguments)]
pub fn run_pseudo_marginal<M, K>(
    model: &M,
    kernel: &K,
    est: &PoissonEstimator,
    init: M::Param,
    steps: usize,
    rng: &mut Pcg64,
    mut on_sample: impl FnMut(&M::Param),
) -> PmStats
where
    M: LlDiffModel,
    M::Param: Clone,
    K: ProposalKernel<M::Param>,
{
    let mut sched = MinibatchScheduler::new(model.n()).expect("population exceeds the u32 index space");
    let anchor = init.clone();
    let mut cur = init;
    // W(init) vs anchor = init: all l_i are exactly 0, the estimator is
    // exact: exp(0) = 1.
    let mut w_cur = 1.0f64;
    let mut stats = PmStats::default();
    let mut stuck = 0usize;

    for _ in 0..steps {
        let Proposal { param, log_correction } = kernel.propose(&cur, rng);
        let r = est.estimate_ratio(model, &anchor, &param, &mut sched, rng);
        stats.data_used += (r.stages * est.batch) as u64;
        stats.clamped += r.clamped as usize;
        let a = if w_cur > 0.0 {
            (r.value / w_cur) * (-log_correction).exp()
        } else {
            1.0
        };
        let accepted = rng.uniform() < a.min(1.0);
        if accepted {
            cur = param;
            w_cur = r.value;
            stats.accepted += 1;
            stuck = 0;
        } else {
            stuck += 1;
            stats.longest_stuck = stats.longest_stuck.max(stuck);
        }
        stats.steps += 1;
        on_sample(&cur);
    }
    stats
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::two_class_gaussian;
    use crate::models::LogisticModel;
    use crate::samplers::GaussianRandomWalk;

    #[test]
    fn poisson_estimator_unbiased_for_constant_population() {
        // population with identical l_i: every subsample mean is exact,
        // so the estimator should average to exp(N mu) with NO variance
        // from subsampling (only the Poisson series noise).
        struct Const(usize, f64);
        impl LlDiffModel for Const {
            type Param = ();
            fn n(&self) -> usize {
                self.0
            }
            fn lldiff(&self, _: usize, _: &(), _: &()) -> f64 {
                self.1
            }
        }
        let n = 1000;
        let l = -2e-4; // N mu = -0.2
        let model = Const(n, l);
        let est = PoissonEstimator { batch: 50, lambda: 2.0, center: n as f64 * l - 1.0 };
        let mut sched = MinibatchScheduler::new(n).expect("population exceeds the u32 index space");
        let mut rng = Pcg64::seeded(0);
        let trials = 60_000;
        let mut sum = 0.0;
        for _ in 0..trials {
            sum += est.estimate_ratio(&model, &(), &(), &mut sched, &mut rng).value;
        }
        let mean = sum / trials as f64;
        let want = (n as f64 * l).exp(); // ~0.8187
        assert!((mean - want).abs() < 0.02, "mean {mean} want {want}");
    }

    #[test]
    fn estimator_variance_explodes_with_population_noise() {
        // Realistic noisy population: the estimator variance (and clamp
        // rate) is large — the pathology the paper describes.
        let model = LogisticModel::new(two_class_gaussian(10_000, 10, 1.2, 0), 10.0).expect("population exceeds the u32 index space");
        let mut rng = Pcg64::seeded(1);
        let theta = model.map_estimate(40);
        let theta_p: Vec<f64> = theta.iter().map(|t| t + 0.05 * rng.normal()).collect();
        let est = PoissonEstimator { batch: 100, lambda: 3.0, center: 0.0 };
        let mut sched = MinibatchScheduler::new(model.n()).expect("population exceeds the u32 index space");
        let mut vals = Vec::new();
        for _ in 0..500 {
            vals.push(est.estimate_ratio(&model, &theta, &theta_p, &mut sched, &mut rng).value);
        }
        let mean = vals.iter().sum::<f64>() / vals.len() as f64;
        let var = vals.iter().map(|v| (v - mean) * (v - mean)).sum::<f64>()
            / vals.len() as f64;
        // coefficient of variation far above 1: useless signal-to-noise
        assert!(var.sqrt() > mean, "cv {} unexpectedly small", var.sqrt() / mean);
    }

    #[test]
    fn pseudo_marginal_chain_gets_stuck_where_sequential_does_not() {
        let model = LogisticModel::new(two_class_gaussian(10_000, 10, 1.2, 0), 10.0).expect("population exceeds the u32 index space");
        let init = model.map_estimate(40);
        let kernel = GaussianRandomWalk::new(0.02, 10.0);
        let est = PoissonEstimator { batch: 100, lambda: 3.0, center: 0.0 };
        let mut rng = Pcg64::seeded(2);
        let stats = run_pseudo_marginal(&model, &kernel, &est, init.clone(), 400, &mut rng, |_| {});
        let pm_accept = stats.accepted as f64 / stats.steps as f64;

        // the sequential-test chain on the same posterior mixes fine
        let mut rng = Pcg64::seeded(2);
        let (_, seq_stats) = crate::coordinator::run_chain(
            &model,
            &kernel,
            &crate::coordinator::MhMode::approx(0.05, 500),
            init,
            crate::coordinator::Budget::Steps(400),
            0,
            1,
            |_| 0.0,
            &mut rng,
        );
        let seq_accept = seq_stats.acceptance_rate();
        assert!(
            pm_accept < 0.5 * seq_accept,
            "pseudo-marginal {pm_accept} vs sequential {seq_accept}"
        );
        assert!(stats.longest_stuck > 10, "stuck runs {}", stats.longest_stuck);
    }
}

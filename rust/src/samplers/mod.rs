//! Proposal kernels and the non-MH sampler families for every paper
//! experiment: Gaussian random walk (§6.1), Stiefel-manifold walk
//! (§6.2), reversible-jump moves (§6.3), SGLD ± correction (§6.4), and
//! exact/approximate Gibbs for binary and multi-valued MRFs (supp. F).
//!
//! Every family also implements `coordinator::TransitionKernel`
//! (`SgldKernel`, `PmKernel`, `GibbsSweepKernel`, `PottsSweepKernel`;
//! the MH families via `MhKernel`/`CachedMhKernel`), so all of them run
//! on the parallel multi-chain engine with shared budgets, observers and
//! cross-chain diagnostics.

pub mod gibbs;
pub mod gibbs_potts;
pub mod pseudo_marginal;
pub mod random_walk;
pub mod rjmcmc;
pub mod sgld;
pub mod stiefel;

pub use gibbs::{
    gaussian_product, gibbs_sweep, gibbs_update, GaussianMoments, GibbsMode, GibbsScratch,
    GibbsStats, GibbsSweepKernel, MergeError, SubsetMarginal,
};
pub use gibbs_potts::{
    potts_sweep, potts_update, PottsMode, PottsScratch, PottsStats, PottsSweepKernel,
};
pub use pseudo_marginal::{
    run_pseudo_marginal, PmKernel, PmPathology, PmState, PmStats, PoissonEstimator,
};
pub use random_walk::{GaussianRandomWalk, ScalarRandomWalk};
pub use rjmcmc::{MoveProbs, RjKernel};
pub use sgld::{run_sgld, SgldConfig, SgldKernel, SgldScratch, SgldStats};
pub use stiefel::StiefelRandomWalk;

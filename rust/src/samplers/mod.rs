//! Proposal kernels and non-MH samplers for every paper experiment:
//! Gaussian random walk (§6.1), Stiefel-manifold walk (§6.2),
//! reversible-jump moves (§6.3), SGLD ± correction (§6.4), and
//! exact/approximate Gibbs for MRFs (supp. F).

pub mod gibbs;
pub mod gibbs_potts;
pub mod pseudo_marginal;
pub mod random_walk;
pub mod rjmcmc;
pub mod sgld;
pub mod stiefel;

pub use gibbs_potts::{potts_sweep, potts_update, PottsMode, PottsScratch, PottsStats};
pub use pseudo_marginal::{run_pseudo_marginal, PmStats, PoissonEstimator};
pub use gibbs::{gibbs_sweep, gibbs_update, GibbsMode, GibbsScratch, GibbsStats, SubsetMarginal};
pub use random_walk::{GaussianRandomWalk, ScalarRandomWalk};
pub use rjmcmc::{MoveProbs, RjKernel};
pub use sgld::{run_sgld, SgldConfig, SgldStats};
pub use stiefel::StiefelRandomWalk;

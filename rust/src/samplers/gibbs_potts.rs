//! Approximate Gibbs for multi-valued variables — the supp.-F extension.
//!
//! For a K-state conditional P(X_v = a | x_-v) ∝ exp(S_a) with
//! S_a = sum over the pair population of log psi(a, x_j, x_k), exact
//! sampling is equivalent to the Gumbel-max trick:
//!
//! ```text
//! X_v = argmax_a ( S_a + G_a ),   G_a iid standard Gumbel.
//! ```
//!
//! Deciding the argmax is a tournament of K-1 pairwise comparisons
//! "(S_a + G_a) > (S_b + G_b)?", and each comparison is precisely the
//! paper's population-mean threshold test with
//!
//! ```text
//! mu    = (1/Np) sum_pairs [f_pair(a) - f_pair(b)]
//! mu_0  = (G_b - G_a) / Np
//! ```
//!
//! so the binary sequential test (Alg. 1) applies unchanged. With exact
//! comparisons the update is exactly Gibbs; with epsilon > 0 each
//! comparison errs with the controlled probability of §5.

use crate::coordinator::austerity::BoundSeq;
use crate::coordinator::checkpoint::{BinReader, BinWriter, CkptError, Persist};
use crate::coordinator::kernel::{restore_sched, StepOutcome, TransitionKernel};
use crate::coordinator::scheduler::MinibatchScheduler;
use crate::models::potts::PottsModel;
use crate::stats::student_t::t_sf;
use crate::stats::welford::MomentAccumulator;
use crate::stats::Pcg64;

/// Update mode for the categorical Gibbs sampler.
#[derive(Clone, Debug)]
pub enum PottsMode {
    /// exact conditional (full pair scan, inverse-CDF draw)
    Exact,
    /// Gumbel-max tournament of sequential tests
    Approx { eps: f64, batch: usize },
}

#[derive(Clone, Debug, Default)]
pub struct PottsStats {
    pub updates: usize,
    pub pairs_used: u64,
}

pub struct PottsScratch {
    sched: MinibatchScheduler,
    ranks: Vec<usize>,
    gumbels: Vec<f64>,
}

impl PottsScratch {
    pub fn new(model: &PottsModel) -> Self {
        PottsScratch {
            sched: MinibatchScheduler::new(model.n_pairs()).expect("population exceeds the u32 index space"),
            ranks: Vec::new(),
            gumbels: vec![0.0; model.k()],
        }
    }
}

/// Standard Gumbel draw.
#[inline]
fn gumbel(rng: &mut Pcg64) -> f64 {
    -(-rng.uniform_pos().ln()).ln()
}

/// One sequential comparison: decide sign of mean lldiff(a,b) - mu0.
#[allow(clippy::too_many_arguments)]
fn seq_compare(
    model: &PottsModel,
    v: usize,
    a: usize,
    b: usize,
    mu0: f64,
    eps: f64,
    batch: usize,
    x: &[usize],
    scratch: &mut PottsScratch,
    rng: &mut Pcg64,
) -> (bool, usize) {
    let np = model.n_pairs();
    let bound = BoundSeq::Pocock { eps };
    scratch.sched.reset();
    let mut acc = MomentAccumulator::new();
    loop {
        let bt = scratch.sched.next_batch(batch, rng);
        debug_assert!(!bt.is_empty());
        scratch.ranks.clear();
        scratch.ranks.extend(bt.iter().map(|&i| i as usize));
        let (s, s2) = model.pair_moments(v, &scratch.ranks, a, b, x);
        acc.add_batch(s, s2, scratch.ranks.len());
        let n = acc.n();
        let t = acc.t_statistic(mu0, np);
        let delta = t_sf(t.abs(), (n - 1).max(1) as f64);
        if delta < bound.eps_at(n as f64 / np as f64) || n == np {
            return (acc.mean() > mu0, n);
        }
    }
}

/// One Gibbs update of variable v; returns pairs consumed.
pub fn potts_update(
    model: &PottsModel,
    v: usize,
    x: &mut [usize],
    mode: &PottsMode,
    scratch: &mut PottsScratch,
    rng: &mut Pcg64,
) -> usize {
    let np = model.n_pairs();
    match mode {
        PottsMode::Exact => {
            let cond = model.exact_conditional(v, x);
            let u = rng.uniform();
            let mut cum = 0.0;
            let mut pick = model.k() - 1;
            for (state, &p) in cond.iter().enumerate() {
                cum += p;
                if u < cum {
                    pick = state;
                    break;
                }
            }
            x[v] = pick;
            np * model.k()
        }
        PottsMode::Approx { eps, batch } => {
            // Gumbel-max tournament
            for g in scratch.gumbels.iter_mut() {
                *g = gumbel(rng);
            }
            let mut used = 0usize;
            let mut champ = 0usize;
            for cand in 1..model.k() {
                // (S_champ + G_champ) > (S_cand + G_cand)?
                let mu0 = (scratch.gumbels[cand] - scratch.gumbels[champ]) / np as f64;
                let (champ_wins, n) =
                    seq_compare(model, v, champ, cand, mu0, *eps, *batch, x, scratch, rng);
                used += n;
                if !champ_wins {
                    champ = cand;
                }
            }
            x[v] = champ;
            used
        }
    }
}

/// One full categorical-Gibbs sweep as a `TransitionKernel` (the
/// multi-valued analogue of `GibbsSweepKernel`), so the Potts extension
/// runs on the multi-chain engine too.
pub struct PottsSweepKernel<'a> {
    pub model: &'a PottsModel,
    pub mode: PottsMode,
}

impl TransitionKernel for PottsSweepKernel<'_> {
    type State = Vec<usize>;
    type Scratch = PottsScratch;

    fn scratch(&self, _init: &Vec<usize>) -> PottsScratch {
        PottsScratch::new(self.model)
    }

    fn step(&self, x: &mut Vec<usize>, scratch: &mut PottsScratch, rng: &mut Pcg64) -> StepOutcome {
        let mut stats = PottsStats::default();
        potts_sweep(self.model, x, &self.mode, scratch, &mut stats, rng);
        StepOutcome { accepted: true, data_used: stats.pairs_used, guard_trips: 0 }
    }

    // Only the scheduler permutation carries across sweeps; the Gumbel
    // buffer is redrawn per update and `ranks` is rebuilt per batch.
    fn save_scratch(&self, scratch: &PottsScratch, w: &mut BinWriter) {
        scratch.sched.persist(w);
    }

    fn restore_scratch(
        &self,
        scratch: &mut PottsScratch,
        r: &mut BinReader<'_>,
    ) -> Result<(), CkptError> {
        restore_sched(&mut scratch.sched, self.model.n_pairs(), r)
    }
}

/// Full sweep over all variables.
pub fn potts_sweep(
    model: &PottsModel,
    x: &mut [usize],
    mode: &PottsMode,
    scratch: &mut PottsScratch,
    stats: &mut PottsStats,
    rng: &mut Pcg64,
) {
    for v in 0..model.d() {
        let used = potts_update(model, v, x, mode, scratch, rng);
        stats.updates += 1;
        stats.pairs_used += used as u64;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gumbel_max_with_exact_scores_samples_conditional() {
        // sanity of the trick itself: argmax(S + G) ~ softmax(S)
        let scores = [1.0f64, 0.0, -0.5];
        let mut rng = Pcg64::seeded(0);
        let mut counts = [0usize; 3];
        let trials = 60_000;
        for _ in 0..trials {
            let mut best = 0;
            let mut best_v = f64::NEG_INFINITY;
            for (i, &s) in scores.iter().enumerate() {
                let v = s + gumbel(&mut rng);
                if v > best_v {
                    best_v = v;
                    best = i;
                }
            }
            counts[best] += 1;
        }
        let z: f64 = scores.iter().map(|s| s.exp()).sum();
        for i in 0..3 {
            let want = scores[i].exp() / z;
            let got = counts[i] as f64 / trials as f64;
            assert!((got - want).abs() < 0.01, "state {i}: {got} vs {want}");
        }
    }

    #[test]
    fn approx_update_tracks_exact_conditional() {
        let m = PottsModel::random(20, 3, 0.08, 1);
        let mut rng = Pcg64::seeded(2);
        let mut scratch = PottsScratch::new(&m);
        let base: Vec<usize> = (0..20).map(|i| i % 3).collect();
        let v = 4;
        let want = m.exact_conditional(v, &base);
        let mode = PottsMode::Approx { eps: 0.05, batch: 40 };
        let trials = 6_000;
        let mut counts = vec![0usize; 3];
        for _ in 0..trials {
            let mut x = base.clone();
            potts_update(&m, v, &mut x, &mode, &mut scratch, &mut rng);
            counts[x[v]] += 1;
        }
        for state in 0..3 {
            let got = counts[state] as f64 / trials as f64;
            assert!(
                (got - want[state]).abs() < 0.06,
                "state {state}: {got} vs {}",
                want[state]
            );
        }
    }

    #[test]
    fn approx_uses_fewer_pairs_than_exact_scan() {
        let m = PottsModel::random(40, 3, 0.02, 3);
        let mut rng = Pcg64::seeded(4);
        let mut scratch = PottsScratch::new(&m);
        let mut x: Vec<usize> = (0..40).map(|_| rng.below(3)).collect();
        let mode = PottsMode::Approx { eps: 0.2, batch: 100 };
        let mut stats = PottsStats::default();
        for _ in 0..5 {
            potts_sweep(&m, &mut x, &mode, &mut scratch, &mut stats, &mut rng);
        }
        let per_update = stats.pairs_used as f64 / stats.updates as f64;
        // exact cost would be n_pairs * K
        assert!(
            per_update < (m.n_pairs() * m.k()) as f64,
            "per-update {per_update} vs exact {}",
            m.n_pairs() * m.k()
        );
    }

    #[test]
    fn exact_chain_matches_bruteforce_marginals() {
        let m = PottsModel::random(5, 3, 0.25, 5);
        let d = 5;
        // brute-force marginals
        let total = 3usize.pow(5);
        let mut probs = vec![0.0f64; total];
        let mut logs = vec![0.0f64; total];
        let decode = |mut cfg: usize| -> Vec<usize> {
            let mut x = vec![0usize; d];
            for v in x.iter_mut() {
                *v = cfg % 3;
                cfg /= 3;
            }
            x
        };
        for cfg in 0..total {
            logs[cfg] = m.log_joint(&decode(cfg));
        }
        let max = logs.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        let mut z = 0.0;
        for cfg in 0..total {
            probs[cfg] = (logs[cfg] - max).exp();
            z += probs[cfg];
        }
        let want: Vec<Vec<f64>> = (0..d)
            .map(|v| {
                (0..3)
                    .map(|s| {
                        (0..total)
                            .filter(|&cfg| decode(cfg)[v] == s)
                            .map(|cfg| probs[cfg] / z)
                            .sum()
                    })
                    .collect()
            })
            .collect();

        let mut rng = Pcg64::seeded(6);
        let mut scratch = PottsScratch::new(&m);
        let mut x = vec![0usize; d];
        let mut stats = PottsStats::default();
        let sweeps = 20_000;
        let mut counts = vec![vec![0u64; 3]; d];
        for s in 0..sweeps {
            potts_sweep(&m, &mut x, &PottsMode::Exact, &mut scratch, &mut stats, &mut rng);
            if s >= 1_000 {
                for v in 0..d {
                    counts[v][x[v]] += 1;
                }
            }
        }
        for v in 0..d {
            for s in 0..3 {
                let got = counts[v][s] as f64 / (sweeps - 1_000) as f64;
                assert!(
                    (got - want[v][s]).abs() < 0.02,
                    "v={v} s={s}: {got} vs {}",
                    want[v][s]
                );
            }
        }
    }
}

//! Bayesian logistic regression (paper §6.1 and the likelihood of §6.3).
//!
//! Model: p(y_i | x_i, theta) = sigmoid(y_i x_i^T theta), y_i in {-1, +1},
//! spherical Gaussian prior N(0, I / precision).
//!
//! The moments hot path runs on the columnar (feature-major) view of the
//! dataset with `LANES`-blocked kernels: per block of 8 rows the
//! activations `z = x_i . theta` are accumulated feature-by-feature into
//! 8 independent lane chains (vectorizable mul-adds, contiguous column
//! loads on the full-scan path), then `l_i` and the population sums
//! `(sum l, sum l^2)` accumulate in 8 lane partials folded through
//! `reduce_lanes`. The gathered (minibatch), range (full-scan), cached
//! and uncached kernels all share this one skeleton, which is what makes
//! their results bit-identical — see DESIGN.md §Data layout. Note the
//! lane-blocked population sums associate differently from a plain
//! scalar loop, so same-seed decision sequences differ from the
//! pre-SoA scalar kernels (documented there; the scalar reference is
//! retained as `lldiff_moments_ref` for benches and tolerance tests).

use crate::data::columnar::{reduce_lanes, LANES};
use crate::data::sharded::even_rows;
use crate::data::{DataTooLarge, Dataset, ShardedColumnar};
use crate::models::traits::{
    cached_scan_par, CacheLanes, CachedLlDiff, LlDiffModel, ScanScratch, ShardableModel,
};

/// Stable log sigmoid: log sig(z) = -softplus(-z).
#[inline]
pub fn log_sigmoid(z: f64) -> f64 {
    -((-z).max(0.0) + (-(-z).abs()).exp().ln_1p())
}

/// Logistic-regression posterior target over a dataset.
pub struct LogisticModel {
    data: Dataset,
    /// Feature-major, lane-padded mirror of `data` — the moments hot
    /// path (gradients/predictions stay row-major). Sharded into
    /// `SEGMENT_ALIGN`-aligned segments; a one-segment store behaves
    /// exactly like the plain `Columnar` it wraps.
    cols: ShardedColumnar,
    /// Gaussian prior precision (paper uses 10).
    pub prior_precision: f64,
}

impl LogisticModel {
    pub fn new(data: Dataset, prior_precision: f64) -> Result<Self, DataTooLarge> {
        Self::with_shards(data, prior_precision, 1)
    }

    /// Build the model over a store sharded `shards` ways (scan results
    /// are bit-identical at any shard count; sharding only bounds the
    /// per-segment allocation).
    pub fn with_shards(
        data: Dataset,
        prior_precision: f64,
        shards: usize,
    ) -> Result<Self, DataTooLarge> {
        let cols = ShardedColumnar::from_dataset(&data, shards)?;
        Ok(LogisticModel { data, cols, prior_precision })
    }

    pub fn data(&self) -> &Dataset {
        &self.data
    }

    /// The columnar view the moments kernels run on.
    pub fn columns(&self) -> &ShardedColumnar {
        &self.cols
    }

    pub fn d(&self) -> usize {
        self.data.d()
    }

    /// Log prior log rho(theta) up to a constant.
    pub fn log_prior(&self, theta: &[f64]) -> f64 {
        -0.5 * self.prior_precision * theta.iter().map(|t| t * t).sum::<f64>()
    }

    /// Per-datapoint log-likelihood.
    pub fn loglik_point(&self, i: usize, theta: &[f64]) -> f64 {
        let z: f64 = self
            .data
            .row(i)
            .iter()
            .zip(theta)
            .map(|(x, t)| x * t)
            .sum();
        log_sigmoid(self.data.label(i) * z)
    }

    /// Full-data log-likelihood (ground-truth / diagnostics only).
    pub fn loglik_full(&self, theta: &[f64]) -> f64 {
        (0..self.data.n()).map(|i| self.loglik_point(i, theta)).sum()
    }

    /// Gradient of the log-posterior (for MAP initialization and SGLD).
    /// `idx` selects a mini-batch; the likelihood part is scaled by N/n.
    pub fn grad_log_post(&self, theta: &[f64], idx: &[usize], grad: &mut [f64]) {
        let d = self.d();
        let scale = self.data.n() as f64 / idx.len() as f64;
        for g in grad.iter_mut() {
            *g = 0.0;
        }
        for &i in idx {
            let row = self.data.row(i);
            let y = self.data.label(i);
            let z: f64 = row.iter().zip(theta.iter()).map(|(x, t)| x * t).sum();
            // d/dtheta log sig(y z) = y sig(-y z) x
            let w = y * sigmoid(-y * z);
            for j in 0..d {
                grad[j] += w * row[j];
            }
        }
        for j in 0..d {
            grad[j] = scale * grad[j] - self.prior_precision * theta[j];
        }
    }

    /// MAP estimate by gradient ascent with backtracking (initialization
    /// for ground-truth chains).
    pub fn map_estimate(&self, iters: usize) -> Vec<f64> {
        let d = self.d();
        let idx: Vec<usize> = (0..self.data.n()).collect();
        let mut theta = vec![0.0; d];
        let mut grad = vec![0.0; d];
        let mut step = 1.0 / self.data.n() as f64;
        let mut obj = self.loglik_full(&theta) + self.log_prior(&theta);
        for _ in 0..iters {
            self.grad_log_post(&theta, &idx, &mut grad);
            loop {
                let cand: Vec<f64> = theta
                    .iter()
                    .zip(&grad)
                    .map(|(t, g)| t + step * g)
                    .collect();
                let cand_obj = self.loglik_full(&cand) + self.log_prior(&cand);
                if cand_obj > obj {
                    theta = cand;
                    obj = cand_obj;
                    step *= 1.5;
                    break;
                }
                step *= 0.5;
                if step < 1e-14 {
                    return theta;
                }
            }
        }
        theta
    }

    /// Predictive probability p(y=+1 | x, theta).
    pub fn predict(&self, x: &[f64], theta: &[f64]) -> f64 {
        let z: f64 = x.iter().zip(theta).map(|(a, b)| a * b).sum();
        sigmoid(z)
    }

    /// Retained row-major scalar reference kernel (the pre-SoA fused
    /// dual-dot pass): the correctness baseline the SoA kernels are
    /// checked against (≤ 1e-12 relative) and the denominator of the
    /// `speedup_soa_vs_fused_x` bench ratio. Not on any production path.
    pub fn lldiff_moments_ref(&self, idx: &[u32], cur: &[f64], prop: &[f64]) -> (f64, f64) {
        let d = self.d();
        let cur = &cur[..d];
        let prop = &prop[..d];
        let (mut s, mut s2) = (0.0, 0.0);
        for &i in idx {
            let (z0, z1) = dot2_chunked(self.data.row(i as usize), cur, prop);
            let y = self.data.label(i as usize);
            let l = log_sigmoid(y * z1) - log_sigmoid(y * z0);
            s += l;
            s2 += l * l;
        }
        (s, s2)
    }

    /// One lane block of the uncached kernel: l for 8 rows with known
    /// activations, folded into the lane partials.
    #[inline]
    fn accum_block(
        &self,
        rows: impl Fn(usize) -> usize,
        z0: &[f64; LANES],
        z1: &[f64; LANES],
        sa: &mut [f64; LANES],
        s2a: &mut [f64; LANES],
    ) {
        for k in 0..LANES {
            let y = self.cols.label(rows(k));
            let l = log_sigmoid(y * z1[k]) - log_sigmoid(y * z0[k]);
            sa[k] += l;
            s2a[k] += l * l;
        }
    }

    /// Scalar tail of every kernel: rows past the last full lane block,
    /// accumulated after the lane reduction (same order in all paths).
    #[inline]
    fn tail_uncached(&self, i: usize, cur: &[f64], prop: &[f64], s: &mut f64, s2: &mut f64) {
        let (z0, z1) = self.cols.row_dot2(i, cur, prop);
        let y = self.cols.label(i);
        let l = log_sigmoid(y * z1) - log_sigmoid(y * z0);
        *s += l;
        *s2 += l * l;
    }

    /// One row of the cached kernels — THE single definition of the
    /// lazy-revalidation step (read-or-recompute `z_cur`, record the
    /// proposal activation + stamp, return `l`). Every cached call site
    /// (gathered lane blocks and tails, chunked scan lane blocks and
    /// tails) goes through here, so the revalidation rule cannot
    /// diverge between them.
    #[inline]
    #[allow(clippy::too_many_arguments)]
    fn cached_row(
        &self,
        i: usize,
        z1: f64,
        z_cur: &mut f64,
        ver_cur: &mut u64,
        z_prop: &mut f64,
        stamp: &mut u64,
        theta_cur: &[f64],
        version: u64,
        step: u64,
    ) -> f64 {
        let z0 = if *ver_cur == version {
            *z_cur
        } else {
            let z = self.cols.row_dot(i, theta_cur);
            *z_cur = z;
            *ver_cur = version;
            z
        };
        *z_prop = z1;
        *stamp = step;
        let y = self.cols.label(i);
        log_sigmoid(y * z1) - log_sigmoid(y * z0)
    }

    /// One chunk of the cached scan/minibatch kernels: proposal-side
    /// activations computed lane-blocked, current side served from the
    /// cache lanes (recomputed and cached when stale). `lanes` index 0
    /// is population index `start`.
    #[allow(clippy::too_many_arguments)]
    fn cached_chunk(
        &self,
        start: usize,
        end: usize,
        lanes: &mut CacheLanes<'_>,
        theta_cur: &[f64],
        prop: &[f64],
        version: u64,
        step: u64,
    ) -> (f64, f64) {
        let mut sa = [0.0f64; LANES];
        let mut s2a = [0.0f64; LANES];
        let mut z1 = [0.0f64; LANES];
        let mut base = start;
        while base + LANES <= end {
            self.cols.block_dot_seq(base, prop, &mut z1);
            for k in 0..LANES {
                let i = base + k;
                let o = i - start;
                let l = self.cached_row(
                    i,
                    z1[k],
                    &mut lanes.val_cur[o],
                    &mut lanes.ver_cur[o],
                    &mut lanes.val_prop[o],
                    &mut lanes.stamp[o],
                    theta_cur,
                    version,
                    step,
                );
                sa[k] += l;
                s2a[k] += l * l;
            }
            base += LANES;
        }
        let mut s = reduce_lanes(&sa);
        let mut s2 = reduce_lanes(&s2a);
        for i in base..end {
            let o = i - start;
            let zp = self.cols.row_dot(i, prop);
            let l = self.cached_row(
                i,
                zp,
                &mut lanes.val_cur[o],
                &mut lanes.ver_cur[o],
                &mut lanes.val_prop[o],
                &mut lanes.stamp[o],
                theta_cur,
                version,
                step,
            );
            s += l;
            s2 += l * l;
        }
        (s, s2)
    }
}

#[inline]
pub fn sigmoid(z: f64) -> f64 {
    if z >= 0.0 {
        1.0 / (1.0 + (-z).exp())
    } else {
        let e = z.exp();
        e / (1.0 + e)
    }
}

/// Blocked dual dot product (row-major reference path only; the
/// production kernels live on `Columnar`).
#[inline]
fn dot2_chunked(row: &[f64], a: &[f64], b: &[f64]) -> (f64, f64) {
    let mut a0 = [0.0f64; 4];
    let mut a1 = [0.0f64; 4];
    let mut cr = row.chunks_exact(4);
    let mut ca = a.chunks_exact(4);
    let mut cb = b.chunks_exact(4);
    for ((r, x), y) in (&mut cr).zip(&mut ca).zip(&mut cb) {
        for k in 0..4 {
            a0[k] += r[k] * x[k];
            a1[k] += r[k] * y[k];
        }
    }
    let mut z0 = (a0[0] + a0[1]) + (a0[2] + a0[3]);
    let mut z1 = (a1[0] + a1[1]) + (a1[2] + a1[3]);
    for ((r, x), y) in cr
        .remainder()
        .iter()
        .zip(ca.remainder())
        .zip(cb.remainder())
    {
        z0 += r * x;
        z1 += r * y;
    }
    (z0, z1)
}

/// Per-chain activation cache: `z_cur[i] = x_i . theta_cur` persists
/// across MH steps with *lazy* revalidation, so each sequential-test
/// stage computes one activation per fresh index (vs two uncached) and
/// an accepted step costs only an O(N) stamp sweep — never a bulk
/// recomputation of untouched activations.
pub struct LogisticCache {
    /// copy of the current parameter (for lazy recomputation of stale
    /// entries on their next read)
    theta_cur: Vec<f64>,
    /// `z_cur[i]` is valid iff `cur_ver[i] == version`
    z_cur: Vec<f64>,
    cur_ver: Vec<u64>,
    /// bumped on every accepted step
    version: u64,
    z_prop: Vec<f64>,
    /// `stamp[i] == step` iff `z_prop[i]` was computed this step.
    stamp: Vec<u64>,
    step: u64,
}

impl LlDiffModel for LogisticModel {
    type Param = Vec<f64>;

    fn n(&self) -> usize {
        self.data.n()
    }

    fn lldiff(&self, i: usize, cur: &Vec<f64>, prop: &Vec<f64>) -> f64 {
        let row = self.data.row(i);
        let y = self.data.label(i);
        let (mut z0, mut z1) = (0.0, 0.0);
        for j in 0..row.len() {
            z0 += row[j] * cur[j];
            z1 += row[j] * prop[j];
        }
        log_sigmoid(y * z1) - log_sigmoid(y * z0)
    }

    fn lldiff_moments(&self, idx: &[u32], cur: &Vec<f64>, prop: &Vec<f64>) -> (f64, f64) {
        // SoA gathered kernel: lane blocks of 8 rows, both activations
        // in one column pass, lane-partial population sums.
        let d = self.d();
        let cur = &cur[..d];
        let prop = &prop[..d];
        let mut sa = [0.0f64; LANES];
        let mut s2a = [0.0f64; LANES];
        let mut z0 = [0.0f64; LANES];
        let mut z1 = [0.0f64; LANES];
        let mut blocks = idx.chunks_exact(LANES);
        for block in &mut blocks {
            self.cols.block_dot2_gather(block, cur, prop, &mut z0, &mut z1);
            self.accum_block(|k| block[k] as usize, &z0, &z1, &mut sa, &mut s2a);
        }
        let mut s = reduce_lanes(&sa);
        let mut s2 = reduce_lanes(&s2a);
        for &i in blocks.remainder() {
            self.tail_uncached(i as usize, cur, prop, &mut s, &mut s2);
        }
        (s, s2)
    }

    fn lldiff_range_moments(
        &self,
        start: usize,
        end: usize,
        cur: &Vec<f64>,
        prop: &Vec<f64>,
    ) -> (f64, f64) {
        // SoA range kernel: same skeleton as the gathered kernel with
        // contiguous column loads — bit-identical to
        // `lldiff_moments(&[start..end])` by construction.
        let d = self.d();
        let cur = &cur[..d];
        let prop = &prop[..d];
        let mut sa = [0.0f64; LANES];
        let mut s2a = [0.0f64; LANES];
        let mut z0 = [0.0f64; LANES];
        let mut z1 = [0.0f64; LANES];
        let mut base = start;
        while base + LANES <= end {
            self.cols.block_dot2_seq(base, cur, prop, &mut z0, &mut z1);
            self.accum_block(|k| base + k, &z0, &z1, &mut sa, &mut s2a);
            base += LANES;
        }
        let mut s = reduce_lanes(&sa);
        let mut s2 = reduce_lanes(&s2a);
        for i in base..end {
            self.tail_uncached(i, cur, prop, &mut s, &mut s2);
        }
        (s, s2)
    }

    // Session dispatch: this model keeps per-datapoint activations
    // alive across steps, so launches ride the cached fast path.
    crate::models::traits::cached_session_dispatch!();
}

impl ShardableModel for LogisticModel {
    fn shard_model(&self, shard: usize, shards: usize) -> Result<Self, DataTooLarge> {
        let (start, end) = even_rows(self.data.n(), shard, shards);
        LogisticModel::new(self.data.slice_rows(start, end), self.prior_precision)
    }
}

impl CachedLlDiff for LogisticModel {
    type Cache = LogisticCache;

    fn init_cache(&self, cur: &Vec<f64>) -> LogisticCache {
        let d = self.d();
        let n = self.n();
        // entries start stale (cur_ver 0 != version 1) and fill lazily
        // on first read, so building a cache is O(d), not O(N d)
        LogisticCache {
            theta_cur: cur[..d].to_vec(),
            z_cur: vec![0.0; n],
            cur_ver: vec![0; n],
            version: 1,
            z_prop: vec![0.0; n],
            stamp: vec![0; n],
            step: 0,
        }
    }

    fn begin_step(&self, cache: &mut LogisticCache) {
        cache.step += 1;
    }

    fn cached_moments(
        &self,
        cache: &mut LogisticCache,
        idx: &[u32],
        prop: &Vec<f64>,
    ) -> (f64, f64) {
        // Fresh current-side activations come from the cache (one
        // activation per row instead of two); stale ones are recomputed
        // on read and cached — amortized never worse than the fused
        // pass. Same lane skeleton as `lldiff_moments`, so the bits
        // match it exactly.
        let d = self.d();
        let prop = &prop[..d];
        let LogisticCache { theta_cur, z_cur, cur_ver, version, z_prop, stamp, step } = cache;
        let theta_cur: &[f64] = theta_cur;
        let (version, step) = (*version, *step);
        let mut sa = [0.0f64; LANES];
        let mut s2a = [0.0f64; LANES];
        let mut z1 = [0.0f64; LANES];
        let mut blocks = idx.chunks_exact(LANES);
        for block in &mut blocks {
            self.cols.block_dot_gather(block, prop, &mut z1);
            for k in 0..LANES {
                let i = block[k] as usize;
                let l = self.cached_row(
                    i,
                    z1[k],
                    &mut z_cur[i],
                    &mut cur_ver[i],
                    &mut z_prop[i],
                    &mut stamp[i],
                    theta_cur,
                    version,
                    step,
                );
                sa[k] += l;
                s2a[k] += l * l;
            }
        }
        let mut s = reduce_lanes(&sa);
        let mut s2 = reduce_lanes(&s2a);
        for &iu in blocks.remainder() {
            let i = iu as usize;
            let zp = self.cols.row_dot(i, prop);
            let l = self.cached_row(
                i,
                zp,
                &mut z_cur[i],
                &mut cur_ver[i],
                &mut z_prop[i],
                &mut stamp[i],
                theta_cur,
                version,
                step,
            );
            s += l;
            s2 += l * l;
        }
        (s, s2)
    }

    fn cached_full_scan(
        &self,
        cache: &mut LogisticCache,
        prop: &Vec<f64>,
        scan: &mut ScanScratch,
    ) -> (f64, f64) {
        let d = self.d();
        let prop = &prop[..d];
        let LogisticCache { theta_cur, z_cur, cur_ver, version, z_prop, stamp, step } = cache;
        let theta_cur: &[f64] = theta_cur;
        let (version, step) = (*version, *step);
        let lanes = CacheLanes { val_cur: z_cur, ver_cur: cur_ver, val_prop: z_prop, stamp };
        cached_scan_par(self.n(), scan, lanes, |start, end, mut sub| {
            self.cached_chunk(start, end, &mut sub, theta_cur, prop, version, step)
        })
    }

    fn end_step(&self, cache: &mut LogisticCache, prop: &Vec<f64>, accepted: bool) {
        if !accepted {
            return;
        }
        // Accept: proposal activations computed this step become current;
        // everything else is invalidated by the version bump and will be
        // recomputed lazily if and when it is read. No activation work
        // here — an accepted austere step stays O(touched) + O(N) stamp
        // sweep.
        let d = self.d();
        cache.theta_cur.copy_from_slice(&prop[..d]);
        cache.version += 1;
        let (step, version) = (cache.step, cache.version);
        for i in 0..self.n() {
            if cache.stamp[i] == step {
                cache.z_cur[i] = cache.z_prop[i];
                cache.cur_ver[i] = version;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::two_class_gaussian;
    use crate::stats::Pcg64;
    use crate::testkit;

    fn model() -> LogisticModel {
        LogisticModel::new(two_class_gaussian(500, 8, 1.2, 0), 10.0).unwrap()
    }

    #[test]
    fn log_sigmoid_stable_and_correct() {
        assert!((log_sigmoid(0.0) - 0.5f64.ln()).abs() < 1e-12);
        assert!((log_sigmoid(2.0) - (1.0 / (1.0 + (-2.0f64).exp())).ln()).abs() < 1e-12);
        // extreme values do not overflow
        assert!(log_sigmoid(800.0).abs() < 1e-12);
        assert!((log_sigmoid(-800.0) + 800.0).abs() < 1e-9);
    }

    #[test]
    fn sigmoid_matches_exp_form() {
        for &z in &[-30.0, -2.0, 0.0, 1.5, 40.0] {
            let want = 1.0 / (1.0 + (-z as f64).exp());
            assert!((sigmoid(z) - want).abs() < 1e-12);
        }
    }

    #[test]
    fn lldiff_consistent_with_loglik() {
        let m = model();
        let mut rng = Pcg64::seeded(1);
        let cur: Vec<f64> = (0..8).map(|_| 0.1 * rng.normal()).collect();
        let prop: Vec<f64> = (0..8).map(|_| 0.1 * rng.normal()).collect();
        for i in [0usize, 7, 100, 499] {
            let want = m.loglik_point(i, &prop) - m.loglik_point(i, &cur);
            assert!((m.lldiff(i, &cur, &prop) - want).abs() < 1e-12);
        }
    }

    #[test]
    fn soa_moments_match_default_loop() {
        let m = model();
        testkit::forall(32, |rng| {
            let cur: Vec<f64> = (0..8).map(|_| 0.2 * rng.normal()).collect();
            let prop: Vec<f64> = (0..8).map(|_| 0.2 * rng.normal()).collect();
            let k = rng.below(100) + 1;
            let idx: Vec<u32> = (0..k).map(|_| rng.below(500) as u32).collect();
            let (s, s2) = m.lldiff_moments(&idx, &cur, &prop);
            let (mut ws, mut ws2) = (0.0, 0.0);
            for &i in &idx {
                let l = m.lldiff(i as usize, &cur, &prop);
                ws += l;
                ws2 += l * l;
            }
            assert!((s - ws).abs() < 1e-9, "{s} vs {ws}");
            assert!((s2 - ws2).abs() < 1e-9);
        });
    }

    #[test]
    fn soa_moments_match_rowmajor_reference() {
        // the retained scalar reference agrees to tight relative error
        // (not bitwise: the lane-blocked sums associate differently)
        let m = model();
        testkit::forall(32, |rng| {
            let cur: Vec<f64> = (0..8).map(|_| 0.3 * rng.normal()).collect();
            let prop: Vec<f64> = (0..8).map(|_| 0.3 * rng.normal()).collect();
            let k = rng.below(200) + 1;
            let idx: Vec<u32> = (0..k).map(|_| rng.below(500) as u32).collect();
            let (s, s2) = m.lldiff_moments(&idx, &cur, &prop);
            let (rs, rs2) = m.lldiff_moments_ref(&idx, &cur, &prop);
            assert!((s - rs).abs() <= 1e-12 * rs.abs().max(1.0), "{s} vs {rs}");
            assert!((s2 - rs2).abs() <= 1e-12 * rs2.abs().max(1.0), "{s2} vs {rs2}");
        });
    }

    #[test]
    fn range_kernel_bit_identical_to_gathered() {
        let m = model();
        testkit::forall(16, |rng| {
            let cur: Vec<f64> = (0..8).map(|_| 0.2 * rng.normal()).collect();
            let prop: Vec<f64> = (0..8).map(|_| 0.2 * rng.normal()).collect();
            let a = rng.below(400);
            let b = a + rng.below(100) + 1;
            let idx: Vec<u32> = (a as u32..b as u32).collect();
            let g = m.lldiff_moments(&idx, &cur, &prop);
            let r = m.lldiff_range_moments(a, b, &cur, &prop);
            assert_eq!(g.0.to_bits(), r.0.to_bits());
            assert_eq!(g.1.to_bits(), r.1.to_bits());
        });
    }

    #[test]
    fn cached_moments_bit_identical_to_uncached() {
        let m = model();
        testkit::forall(32, |rng| {
            let cur: Vec<f64> = (0..8).map(|_| 0.2 * rng.normal()).collect();
            let prop: Vec<f64> = (0..8).map(|_| 0.2 * rng.normal()).collect();
            let k = rng.below(100) + 1;
            let idx: Vec<u32> = (0..k).map(|_| rng.below(500) as u32).collect();
            let mut cache = m.init_cache(&cur);
            m.begin_step(&mut cache);
            let cached = m.cached_moments(&mut cache, &idx, &prop);
            let fused = m.lldiff_moments(&idx, &cur, &prop);
            // bitwise: the cached path must make identical MH decisions
            assert_eq!(cached.0.to_bits(), fused.0.to_bits(), "{} vs {}", cached.0, fused.0);
            assert_eq!(cached.1.to_bits(), fused.1.to_bits());
        });
    }

    #[test]
    fn cached_full_scan_bit_identical_to_full_moments() {
        let m = model();
        let mut rng = Pcg64::seeded(7);
        let cur: Vec<f64> = (0..8).map(|_| 0.2 * rng.normal()).collect();
        let prop: Vec<f64> = (0..8).map(|_| 0.2 * rng.normal()).collect();
        let want = m.full_moments(&cur, &prop);
        for threads in [1usize, 2, 4] {
            let mut cache = m.init_cache(&cur);
            m.begin_step(&mut cache);
            let mut scan = ScanScratch::new(threads, m.n());
            let got = m.cached_full_scan(&mut cache, &prop, &mut scan);
            assert_eq!(got.0.to_bits(), want.0.to_bits(), "threads {threads}");
            assert_eq!(got.1.to_bits(), want.1.to_bits(), "threads {threads}");
            // a second scan served from the now-warm cache still agrees
            m.end_step(&mut cache, &prop, false);
            m.begin_step(&mut cache);
            let again = m.cached_full_scan(&mut cache, &prop, &mut scan);
            assert_eq!(again.0.to_bits(), want.0.to_bits());
        }
    }

    #[test]
    fn cache_tracks_accept_reject_sequence() {
        let m = model();
        let mut rng = Pcg64::seeded(5);
        let mut cur: Vec<f64> = (0..8).map(|_| 0.1 * rng.normal()).collect();
        let mut cache = m.init_cache(&cur);
        let all: Vec<u32> = (0..m.n() as u32).collect();
        for step in 0..20 {
            let prop: Vec<f64> = cur.iter().map(|t| t + 0.05 * rng.normal()).collect();
            m.begin_step(&mut cache);
            // touch a random subset, as the sequential test would
            let k = rng.below(200) + 1;
            let idx: Vec<u32> = (0..k).map(|_| rng.below(500) as u32).collect();
            let cached = m.cached_moments(&mut cache, &idx, &prop);
            let plain = m.lldiff_moments(&idx, &cur, &prop);
            assert_eq!(cached.0.to_bits(), plain.0.to_bits(), "step {step}");
            let accept = step % 3 != 0; // mix of accepts and rejects
            m.end_step(&mut cache, &prop, accept);
            if accept {
                cur = prop;
            }
            // after any history, a full-population probe step must still
            // be bit-identical to the uncached pass (the invariant every
            // MH decision rests on)
            let probe: Vec<f64> = cur.iter().map(|t| t + 0.01).collect();
            m.begin_step(&mut cache);
            let cached = m.cached_moments(&mut cache, &all, &probe);
            let plain = m.lldiff_moments(&all, &cur, &probe);
            assert_eq!(cached.0.to_bits(), plain.0.to_bits(), "probe at step {step}");
            assert_eq!(cached.1.to_bits(), plain.1.to_bits(), "probe at step {step}");
            m.end_step(&mut cache, &probe, false); // reject: state unchanged
        }
    }

    #[test]
    fn sharded_kernels_bit_identical_to_unsharded() {
        // the store shard count must never change a result bit, for the
        // gathered, range, and cached kernels alike
        let n = 2 * crate::models::traits::FULL_SCAN_CHUNK + 77;
        let data = two_class_gaussian(n, 8, 1.2, 3);
        let solo = LogisticModel::new(data.clone(), 10.0).unwrap();
        let mut rng = Pcg64::seeded(8);
        let cur: Vec<f64> = (0..8).map(|_| 0.2 * rng.normal()).collect();
        let prop: Vec<f64> = (0..8).map(|_| 0.2 * rng.normal()).collect();
        let idx: Vec<u32> = (0..300).map(|_| rng.below(n) as u32).collect();
        let want_g = solo.lldiff_moments(&idx, &cur, &prop);
        let want_f = solo.full_moments(&cur, &prop);
        for shards in [2usize, 3, 8] {
            let m = LogisticModel::with_shards(data.clone(), 10.0, shards).unwrap();
            let g = m.lldiff_moments(&idx, &cur, &prop);
            assert_eq!(g.0.to_bits(), want_g.0.to_bits(), "shards {shards}");
            assert_eq!(g.1.to_bits(), want_g.1.to_bits(), "shards {shards}");
            let f = m.full_moments(&cur, &prop);
            assert_eq!(f.0.to_bits(), want_f.0.to_bits(), "shards {shards}");
            assert_eq!(f.1.to_bits(), want_f.1.to_bits(), "shards {shards}");
            let mut cache = m.init_cache(&cur);
            m.begin_step(&mut cache);
            let mut scan = ScanScratch::new(1, m.n());
            let c = m.cached_full_scan(&mut cache, &prop, &mut scan);
            assert_eq!(c.0.to_bits(), want_f.0.to_bits(), "cached, shards {shards}");
            assert_eq!(c.1.to_bits(), want_f.1.to_bits(), "cached, shards {shards}");
        }
    }

    #[test]
    fn shard_models_partition_the_population() {
        let m = model();
        let shards: Vec<LogisticModel> =
            (0..3).map(|s| m.shard_model(s, 3).unwrap()).collect();
        assert_eq!(shards.iter().map(|s| s.n()).sum::<usize>(), m.n());
        // row 0 of shard 1 is the row after the last row of shard 0
        let boundary = shards[0].n();
        assert_eq!(shards[1].data().row(0), m.data().row(boundary));
        assert_eq!(shards[1].data().label(0), m.data().label(boundary));
    }

    #[test]
    fn map_improves_loglik_and_classifies() {
        let m = model();
        let theta = m.map_estimate(60);
        let zero = vec![0.0; 8];
        assert!(m.loglik_full(&theta) > m.loglik_full(&zero));
        // MAP should classify most training points correctly
        let correct = (0..m.n())
            .filter(|&i| {
                let p = m.predict(m.data().row(i), &theta);
                (p > 0.5) == (m.data().label(i) > 0.0)
            })
            .count();
        assert!(correct as f64 / m.n() as f64 > 0.7, "acc={}", correct);
    }

    #[test]
    fn grad_matches_finite_difference() {
        let m = model();
        let mut rng = Pcg64::seeded(2);
        let theta: Vec<f64> = (0..8).map(|_| 0.1 * rng.normal()).collect();
        let idx: Vec<usize> = (0..m.n()).collect();
        let mut grad = vec![0.0; 8];
        m.grad_log_post(&theta, &idx, &mut grad);
        let f = |t: &[f64]| m.loglik_full(t) + m.log_prior(t);
        let h = 1e-6;
        for j in 0..8 {
            let mut tp = theta.clone();
            tp[j] += h;
            let mut tm = theta.clone();
            tm[j] -= h;
            let fd = (f(&tp) - f(&tm)) / (2.0 * h);
            assert!((grad[j] - fd).abs() < 1e-4 * (1.0 + fd.abs()), "j={j}: {} vs {fd}", grad[j]);
        }
    }

    #[test]
    fn prior_precision_shrinks_map() {
        let loose = LogisticModel::new(two_class_gaussian(500, 8, 1.2, 0), 0.1).unwrap();
        let tight = LogisticModel::new(two_class_gaussian(500, 8, 1.2, 0), 1000.0).unwrap();
        let norm = |v: &[f64]| v.iter().map(|x| x * x).sum::<f64>().sqrt();
        assert!(norm(&tight.map_estimate(40)) < norm(&loose.map_estimate(40)));
    }
}

//! 1-d L1-regularized linear regression — the SGLD pitfall toy (§6.4).
//!
//! p(y | x, theta) ~ exp(-lam/2 (y - theta x)^2), Laplacian prior
//! p(theta) ~ exp(-lam0 |theta|). The paper uses lam = 3, lam0 = 4950 so
//! the prior spike at 0 competes with the likelihood mode near 0.5,
//! creating the low-density valley that throws uncorrected SGLD off.
//!
//! The moments kernels follow the same `LANES`-blocked SoA skeleton as
//! the logistic model (d = 1: one feature column + the target column):
//! 8 independent lane chains for the per-point terms, population sums in
//! lane partials folded through `reduce_lanes`, scalar tail after the
//! reduction. Gathered/range/cached variants are bit-identical by
//! construction; the pre-SoA scalar loop is retained as
//! `lldiff_moments_ref`.

use crate::data::columnar::{reduce_lanes, LANES};
use crate::data::sharded::even_rows;
use crate::data::{DataTooLarge, Dataset, ShardedColumnar};
use crate::models::traits::{
    cached_scan_par, CacheLanes, CachedLlDiff, LlDiffModel, ScanScratch, ShardableModel,
};

pub struct LinRegModel {
    data: Dataset,
    /// Columnar mirror (single feature column + targets), sharded into
    /// aligned segments; the kernels read it through the routed `xy1`
    /// accessor so they are agnostic to the shard count.
    cols: ShardedColumnar,
    /// Gaussian noise precision lambda (paper: 3).
    pub lam: f64,
    /// Laplace prior rate lambda_0 (paper: 4950).
    pub lam0: f64,
}

impl LinRegModel {
    pub fn new(data: Dataset, lam: f64, lam0: f64) -> Result<Self, DataTooLarge> {
        Self::with_shards(data, lam, lam0, 1)
    }

    /// Build the model over a store sharded `shards` ways (bit-identical
    /// results at any shard count).
    pub fn with_shards(
        data: Dataset,
        lam: f64,
        lam0: f64,
        shards: usize,
    ) -> Result<Self, DataTooLarge> {
        assert_eq!(data.d(), 1, "toy model is 1-d");
        let cols = ShardedColumnar::from_dataset(&data, shards)?;
        Ok(LinRegModel { data, cols, lam, lam0 })
    }

    pub fn data(&self) -> &Dataset {
        &self.data
    }

    pub fn log_prior(&self, theta: f64) -> f64 {
        -self.lam0 * theta.abs()
    }

    pub fn loglik_point(&self, i: usize, theta: f64) -> f64 {
        let x = self.data.row(i)[0];
        let r = self.data.label(i) - theta * x;
        -0.5 * self.lam * r * r
    }

    /// Unnormalized log posterior (for the density panels of Fig. 5).
    pub fn log_post_unnorm(&self, theta: f64) -> f64 {
        let mut s = self.log_prior(theta);
        for i in 0..self.data.n() {
            s += self.loglik_point(i, theta);
        }
        s
    }

    /// d/dtheta log posterior (for the gradient panel of Fig. 5 and SGLD).
    /// Mini-batch version with N/n scaling; pass all indices for exact.
    pub fn grad_log_post(&self, theta: f64, idx: &[usize]) -> f64 {
        let scale = self.data.n() as f64 / idx.len() as f64;
        let mut g = 0.0;
        for &i in idx {
            let x = self.data.row(i)[0];
            let r = self.data.label(i) - theta * x;
            g += self.lam * r * x;
        }
        scale * g - self.lam0 * theta.signum()
    }

    /// Normalized posterior density on a grid (quadrature normalization),
    /// returned as (grid, density).
    pub fn posterior_density(&self, lo: f64, hi: f64, points: usize) -> (Vec<f64>, Vec<f64>) {
        let grid: Vec<f64> = (0..points)
            .map(|i| lo + (hi - lo) * i as f64 / (points - 1) as f64)
            .collect();
        let logs: Vec<f64> = grid.iter().map(|&t| self.log_post_unnorm(t)).collect();
        let max = logs.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        let dens: Vec<f64> = logs.iter().map(|&l| (l - max).exp()).collect();
        let h = (hi - lo) / (points - 1) as f64;
        // trapezoid normalization
        let mut z = 0.0;
        for i in 0..points - 1 {
            z += 0.5 * (dens[i] + dens[i + 1]) * h;
        }
        (grid, dens.iter().map(|d| d / z).collect())
    }

    /// Retained pre-SoA scalar kernel: correctness baseline for the
    /// lane-blocked kernels (≤ 1e-12 relative) and bench denominator.
    pub fn lldiff_moments_ref(&self, idx: &[u32], cur: f64, prop: f64) -> (f64, f64) {
        let (mut s, mut s2) = (0.0, 0.0);
        let half_lam = 0.5 * self.lam;
        for &i in idx {
            let x = self.data.row(i as usize)[0];
            let y = self.data.label(i as usize);
            let (rc, rp) = (y - cur * x, y - prop * x);
            let l = -half_lam * (rp * rp - rc * rc);
            s += l;
            s2 += l * l;
        }
        (s, s2)
    }

    /// The per-point term with pre-squared residuals — the one
    /// arithmetic definition every kernel variant (and the cache, which
    /// stores the squares) shares.
    #[inline]
    fn l_from_squares(&self, sq_prop: f64, sq_cur: f64) -> f64 {
        -(0.5 * self.lam) * (sq_prop - sq_cur)
    }

    /// One row of the cached kernels — THE single definition of the
    /// lazy-revalidation step (read-or-recompute the current-side
    /// squared residual, record the proposal square + stamp, return
    /// `l`). Every cached call site goes through here, so the
    /// revalidation rule cannot diverge between the gathered and
    /// chunked-scan paths.
    #[inline]
    #[allow(clippy::too_many_arguments)]
    fn cached_row(
        &self,
        x: f64,
        y: f64,
        sq_cur: &mut f64,
        ver_cur: &mut u64,
        sq_prop: &mut f64,
        stamp: &mut u64,
        theta_cur: f64,
        prop: f64,
        version: u64,
        step: u64,
    ) -> f64 {
        let sq_c = if *ver_cur == version {
            *sq_cur
        } else {
            let rc = y - theta_cur * x;
            let sq = rc * rc;
            *sq_cur = sq;
            *ver_cur = version;
            sq
        };
        let rp = y - prop * x;
        let sq_p = rp * rp;
        *sq_prop = sq_p;
        *stamp = step;
        self.l_from_squares(sq_p, sq_c)
    }

    /// One chunk of the cached kernels: proposal-side squared residuals
    /// computed fresh, current side served from the cache lanes
    /// (recomputed when stale). `lanes` index 0 is population index
    /// `start`.
    #[allow(clippy::too_many_arguments)]
    fn cached_chunk(
        &self,
        start: usize,
        end: usize,
        lanes: &mut CacheLanes<'_>,
        theta_cur: f64,
        prop: f64,
        version: u64,
        step: u64,
    ) -> (f64, f64) {
        let mut sa = [0.0f64; LANES];
        let mut s2a = [0.0f64; LANES];
        let mut base = start;
        while base + LANES <= end {
            for k in 0..LANES {
                let i = base + k;
                let o = i - start;
                let (x, y) = self.cols.xy1(i);
                let l = self.cached_row(
                    x,
                    y,
                    &mut lanes.val_cur[o],
                    &mut lanes.ver_cur[o],
                    &mut lanes.val_prop[o],
                    &mut lanes.stamp[o],
                    theta_cur,
                    prop,
                    version,
                    step,
                );
                sa[k] += l;
                s2a[k] += l * l;
            }
            base += LANES;
        }
        let mut s = reduce_lanes(&sa);
        let mut s2 = reduce_lanes(&s2a);
        for i in base..end {
            let o = i - start;
            let (x, y) = self.cols.xy1(i);
            let l = self.cached_row(
                x,
                y,
                &mut lanes.val_cur[o],
                &mut lanes.ver_cur[o],
                &mut lanes.val_prop[o],
                &mut lanes.stamp[o],
                theta_cur,
                prop,
                version,
                step,
            );
            s += l;
            s2 += l * l;
        }
        (s, s2)
    }
}

impl LlDiffModel for LinRegModel {
    type Param = f64;

    fn n(&self) -> usize {
        self.data.n()
    }

    fn lldiff(&self, i: usize, cur: &f64, prop: &f64) -> f64 {
        let x = self.data.row(i)[0];
        let y = self.data.label(i);
        let (rc, rp) = (y - cur * x, y - prop * x);
        self.l_from_squares(rp * rp, rc * rc)
    }

    fn lldiff_moments(&self, idx: &[u32], cur: &f64, prop: &f64) -> (f64, f64) {
        let mut sa = [0.0f64; LANES];
        let mut s2a = [0.0f64; LANES];
        let mut blocks = idx.chunks_exact(LANES);
        for block in &mut blocks {
            for k in 0..LANES {
                let (x, y) = self.cols.xy1(block[k] as usize);
                let (rc, rp) = (y - cur * x, y - prop * x);
                let l = self.l_from_squares(rp * rp, rc * rc);
                sa[k] += l;
                s2a[k] += l * l;
            }
        }
        let mut s = reduce_lanes(&sa);
        let mut s2 = reduce_lanes(&s2a);
        for &iu in blocks.remainder() {
            let (x, y) = self.cols.xy1(iu as usize);
            let (rc, rp) = (y - cur * x, y - prop * x);
            let l = self.l_from_squares(rp * rp, rc * rc);
            s += l;
            s2 += l * l;
        }
        (s, s2)
    }

    fn lldiff_range_moments(&self, start: usize, end: usize, cur: &f64, prop: &f64) -> (f64, f64) {
        // contiguous-load twin of the gathered kernel; bit-identical on
        // the same indices
        let mut sa = [0.0f64; LANES];
        let mut s2a = [0.0f64; LANES];
        let mut base = start;
        while base + LANES <= end {
            for k in 0..LANES {
                let (x, y) = self.cols.xy1(base + k);
                let (rc, rp) = (y - cur * x, y - prop * x);
                let l = self.l_from_squares(rp * rp, rc * rc);
                sa[k] += l;
                s2a[k] += l * l;
            }
            base += LANES;
        }
        let mut s = reduce_lanes(&sa);
        let mut s2 = reduce_lanes(&s2a);
        for i in base..end {
            let (x, y) = self.cols.xy1(i);
            let (rc, rp) = (y - cur * x, y - prop * x);
            let l = self.l_from_squares(rp * rp, rc * rc);
            s += l;
            s2 += l * l;
        }
        (s, s2)
    }

    // Session dispatch: residuals are cached across steps, so launches
    // ride the cached fast path.
    crate::models::traits::cached_session_dispatch!();
}

/// Per-chain cache of the squared residuals `(y_i - theta_cur x_i)^2`
/// with lazy revalidation (mirrors `LogisticCache`): fresh entries save
/// the current-side residual, stale ones are recomputed on read, and an
/// accepted step costs only an O(N) stamp sweep.
pub struct LinRegCache {
    theta_cur: f64,
    /// `sq_cur[i]` is valid iff `cur_ver[i] == version`
    sq_cur: Vec<f64>,
    cur_ver: Vec<u64>,
    version: u64,
    sq_prop: Vec<f64>,
    stamp: Vec<u64>,
    step: u64,
}

impl CachedLlDiff for LinRegModel {
    type Cache = LinRegCache;

    fn init_cache(&self, cur: &f64) -> LinRegCache {
        let n = self.n();
        LinRegCache {
            theta_cur: *cur,
            sq_cur: vec![0.0; n],
            cur_ver: vec![0; n],
            version: 1,
            sq_prop: vec![0.0; n],
            stamp: vec![0; n],
            step: 0,
        }
    }

    fn begin_step(&self, cache: &mut LinRegCache) {
        cache.step += 1;
    }

    fn cached_moments(&self, cache: &mut LinRegCache, idx: &[u32], prop: &f64) -> (f64, f64) {
        let prop = *prop;
        let LinRegCache { theta_cur, sq_cur, cur_ver, version, sq_prop, stamp, step } = cache;
        let (theta_cur, version, step) = (*theta_cur, *version, *step);
        let mut sa = [0.0f64; LANES];
        let mut s2a = [0.0f64; LANES];
        let mut blocks = idx.chunks_exact(LANES);
        for block in &mut blocks {
            for k in 0..LANES {
                let i = block[k] as usize;
                let (x, y) = self.cols.xy1(i);
                let l = self.cached_row(
                    x,
                    y,
                    &mut sq_cur[i],
                    &mut cur_ver[i],
                    &mut sq_prop[i],
                    &mut stamp[i],
                    theta_cur,
                    prop,
                    version,
                    step,
                );
                sa[k] += l;
                s2a[k] += l * l;
            }
        }
        let mut s = reduce_lanes(&sa);
        let mut s2 = reduce_lanes(&s2a);
        for &iu in blocks.remainder() {
            let i = iu as usize;
            let (x, y) = self.cols.xy1(i);
            let l = self.cached_row(
                x,
                y,
                &mut sq_cur[i],
                &mut cur_ver[i],
                &mut sq_prop[i],
                &mut stamp[i],
                theta_cur,
                prop,
                version,
                step,
            );
            s += l;
            s2 += l * l;
        }
        (s, s2)
    }

    fn cached_full_scan(
        &self,
        cache: &mut LinRegCache,
        prop: &f64,
        scan: &mut ScanScratch,
    ) -> (f64, f64) {
        let prop = *prop;
        let LinRegCache { theta_cur, sq_cur, cur_ver, version, sq_prop, stamp, step } = cache;
        let (theta_cur, version, step) = (*theta_cur, *version, *step);
        let lanes = CacheLanes { val_cur: sq_cur, ver_cur: cur_ver, val_prop: sq_prop, stamp };
        cached_scan_par(self.n(), scan, lanes, |start, end, mut sub| {
            self.cached_chunk(start, end, &mut sub, theta_cur, prop, version, step)
        })
    }

    fn end_step(&self, cache: &mut LinRegCache, prop: &f64, accepted: bool) {
        if !accepted {
            return;
        }
        cache.theta_cur = *prop;
        cache.version += 1;
        let (step, version) = (cache.step, cache.version);
        for i in 0..self.n() {
            if cache.stamp[i] == step {
                cache.sq_cur[i] = cache.sq_prop[i];
                cache.cur_ver[i] = version;
            }
        }
    }
}

/// Embarrassingly-parallel splitting: shard `s` of `k` keeps the even
/// (unaligned) row range, so every shard is non-empty whenever `k <= n`.
impl ShardableModel for LinRegModel {
    fn shard_model(&self, shard: usize, shards: usize) -> Result<Self, DataTooLarge> {
        let (start, end) = even_rows(self.data.n(), shard, shards);
        LinRegModel::new(self.data.slice_rows(start, end), self.lam, self.lam0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::linreg_toy;
    use crate::testkit;

    fn model() -> LinRegModel {
        // paper scale: N = 10000 (the prior/likelihood balance that
        // creates the valley depends on it)
        LinRegModel::new(linreg_toy(10_000, 0), 3.0, 4950.0).unwrap()
    }

    #[test]
    fn lldiff_matches_pointwise() {
        let m = model();
        for i in [0usize, 10, 1999] {
            let want = m.loglik_point(i, 0.3) - m.loglik_point(i, 0.1);
            assert!((m.lldiff(i, &0.1, &0.3) - want).abs() < 1e-12);
        }
    }

    #[test]
    fn moments_match_loop() {
        let m = model();
        testkit::forall(32, |rng| {
            let cur = rng.normal_scaled(0.3, 0.2);
            let prop = rng.normal_scaled(0.3, 0.2);
            let k = rng.below(200) + 1;
            let idx: Vec<u32> = (0..k).map(|_| rng.below(2000) as u32).collect();
            let (s, s2) = m.lldiff_moments(&idx, &cur, &prop);
            let (mut ws, mut ws2) = (0.0, 0.0);
            for &i in &idx {
                let l = m.lldiff(i as usize, &cur, &prop);
                ws += l;
                ws2 += l * l;
            }
            assert!((s - ws).abs() < 1e-9);
            assert!((s2 - ws2).abs() < 1e-9);
        });
    }

    #[test]
    fn soa_moments_match_scalar_reference() {
        let m = model();
        testkit::forall(32, |rng| {
            let cur = rng.normal_scaled(0.3, 0.2);
            let prop = rng.normal_scaled(0.3, 0.2);
            let k = rng.below(300) + 1;
            let idx: Vec<u32> = (0..k).map(|_| rng.below(10_000) as u32).collect();
            let (s, s2) = m.lldiff_moments(&idx, &cur, &prop);
            let (rs, rs2) = m.lldiff_moments_ref(&idx, cur, prop);
            assert!((s - rs).abs() <= 1e-12 * rs.abs().max(1.0), "{s} vs {rs}");
            assert!((s2 - rs2).abs() <= 1e-12 * rs2.abs().max(1.0), "{s2} vs {rs2}");
        });
    }

    #[test]
    fn range_kernel_bit_identical_to_gathered() {
        let m = model();
        testkit::forall(16, |rng| {
            let cur = rng.normal_scaled(0.3, 0.2);
            let prop = rng.normal_scaled(0.3, 0.2);
            let a = rng.below(9_000);
            let b = a + rng.below(600) + 1;
            let idx: Vec<u32> = (a as u32..b as u32).collect();
            let g = m.lldiff_moments(&idx, &cur, &prop);
            let r = m.lldiff_range_moments(a, b, &cur, &prop);
            assert_eq!(g.0.to_bits(), r.0.to_bits());
            assert_eq!(g.1.to_bits(), r.1.to_bits());
        });
    }

    #[test]
    fn cached_moments_bit_identical_to_uncached() {
        let m = model();
        testkit::forall(32, |rng| {
            let cur = rng.normal_scaled(0.3, 0.2);
            let prop = rng.normal_scaled(0.3, 0.2);
            let k = rng.below(200) + 1;
            let idx: Vec<u32> = (0..k).map(|_| rng.below(2000) as u32).collect();
            let mut cache = m.init_cache(&cur);
            m.begin_step(&mut cache);
            let cached = m.cached_moments(&mut cache, &idx, &prop);
            let plain = m.lldiff_moments(&idx, &cur, &prop);
            assert_eq!(cached.0.to_bits(), plain.0.to_bits());
            assert_eq!(cached.1.to_bits(), plain.1.to_bits());
            // accept, then a full-population probe must still be
            // bit-identical to the uncached pass from the new parameter
            m.end_step(&mut cache, &prop, true);
            let all: Vec<u32> = (0..m.n() as u32).collect();
            let probe = prop + 0.01;
            m.begin_step(&mut cache);
            let cached = m.cached_moments(&mut cache, &all, &probe);
            let plain = m.lldiff_moments(&all, &prop, &probe);
            assert_eq!(cached.0.to_bits(), plain.0.to_bits());
            assert_eq!(cached.1.to_bits(), plain.1.to_bits());
        });
    }

    #[test]
    fn cached_full_scan_bit_identical_to_full_moments() {
        let m = model();
        let want = m.full_moments(&0.45, &0.47);
        for threads in [1usize, 2, 8] {
            let mut cache = m.init_cache(&0.45);
            m.begin_step(&mut cache);
            let mut scan = ScanScratch::new(threads, m.n());
            let got = m.cached_full_scan(&mut cache, &0.47, &mut scan);
            assert_eq!(got.0.to_bits(), want.0.to_bits(), "threads {threads}");
            assert_eq!(got.1.to_bits(), want.1.to_bits(), "threads {threads}");
        }
    }

    #[test]
    fn sharded_kernels_bit_identical_to_unsharded() {
        // non-multiple-of-chunk population so segment tails are exercised
        let n = 2 * crate::models::traits::FULL_SCAN_CHUNK + 91;
        let data = linreg_toy(n, 3);
        let base = LinRegModel::new(data.clone(), 3.0, 4950.0).unwrap();
        let mut rng = crate::stats::Pcg64::seeded(11);
        let idx: Vec<u32> = (0..300).map(|_| rng.below(n) as u32).collect();
        let want_g = base.lldiff_moments(&idx, &0.31, &0.44);
        let want_full = base.full_moments(&0.31, &0.44);
        for shards in [2usize, 3, 8] {
            let m = LinRegModel::with_shards(data.clone(), 3.0, 4950.0, shards).unwrap();
            let g = m.lldiff_moments(&idx, &0.31, &0.44);
            assert_eq!(g.0.to_bits(), want_g.0.to_bits(), "shards {shards}");
            assert_eq!(g.1.to_bits(), want_g.1.to_bits(), "shards {shards}");
            let f = m.full_moments(&0.31, &0.44);
            assert_eq!(f.0.to_bits(), want_full.0.to_bits(), "shards {shards}");
            assert_eq!(f.1.to_bits(), want_full.1.to_bits(), "shards {shards}");
            let mut cache = m.init_cache(&0.31);
            m.begin_step(&mut cache);
            let mut scan = ScanScratch::new(4, m.n());
            let c = m.cached_full_scan(&mut cache, &0.44, &mut scan);
            assert_eq!(c.0.to_bits(), want_full.0.to_bits(), "cached, shards {shards}");
            assert_eq!(c.1.to_bits(), want_full.1.to_bits(), "cached, shards {shards}");
        }
    }

    #[test]
    fn shard_models_partition_the_population() {
        let m = model();
        let shards = 3;
        let mut total = 0;
        for s in 0..shards {
            let sub = m.shard_model(s, shards).unwrap();
            total += sub.n();
        }
        assert_eq!(total, m.n());
        // boundary row of shard 1 matches the even split of the source
        let (start, _) = even_rows(m.n(), 1, shards);
        let sub = m.shard_model(1, shards).unwrap();
        assert_eq!(sub.data().row(0)[0].to_bits(), m.data().row(start)[0].to_bits());
        assert_eq!(sub.data().label(0).to_bits(), m.data().label(start).to_bits());
    }

    #[test]
    fn posterior_density_integrates_to_one() {
        let m = model();
        let (grid, dens) = m.posterior_density(-0.2, 0.8, 400);
        let h = grid[1] - grid[0];
        let z: f64 = dens.windows(2).map(|w| 0.5 * (w[0] + w[1]) * h).sum();
        assert!((z - 1.0).abs() < 1e-9);
    }

    #[test]
    fn posterior_is_bimodal_shaped() {
        // With the paper's lam0 the prior creates a spike near 0 and the
        // likelihood a mode near 0.5; density at the valley between them
        // is much lower than at the likelihood mode.
        let m = model();
        let lp_mode = m.log_post_unnorm(0.49);
        let lp_valley = m.log_post_unnorm(0.1);
        assert!(lp_mode > lp_valley + 10.0, "mode {lp_mode} valley {lp_valley}");
    }

    #[test]
    fn grad_sign_pulls_to_mode() {
        let m = model();
        let all: Vec<usize> = (0..m.n()).collect();
        // to the right of the likelihood mode the gradient is negative
        assert!(m.grad_log_post(0.8, &all) < 0.0);
        // in the valley, gradient pushes right (towards likelihood mode)
        assert!(m.grad_log_post(0.3, &all) > 0.0);
    }

    #[test]
    fn grad_matches_finite_difference_away_from_kink() {
        let m = model();
        let all: Vec<usize> = (0..m.n()).collect();
        for &t in &[0.2, 0.45, 0.7] {
            let h = 1e-6;
            let fd = (m.log_post_unnorm(t + h) - m.log_post_unnorm(t - h)) / (2.0 * h);
            let g = m.grad_log_post(t, &all);
            assert!((g - fd).abs() < 1e-3 * (1.0 + fd.abs()), "t={t}: {g} vs {fd}");
        }
    }
}

//! Model traits: the contract between the sequential-test coordinator and
//! the per-datapoint log-likelihood populations.
//!
//! The approximate MH test (paper Alg. 1) only ever sees the population
//! `{ l_i = log p(x_i; theta') - log p(x_i; theta) }` through mini-batch
//! moments `(sum l, sum l^2)`. `LlDiffModel` is exactly that interface;
//! backends (pure Rust here, PJRT-executed Pallas in `runtime`) provide
//! the `lldiff_moments` implementation.

/// Chunk length for full-population scans. Matches the batch capacity of
/// the AOT Pallas kernels so the chunked scan maps 1:1 onto kernel
/// dispatches on the PJRT backend, and keeps the index buffer small
/// enough to stay resident in L1.
pub const FULL_SCAN_CHUNK: usize = 512;

/// Chunked full-population scan shared by the cached and uncached exact
/// paths: streams `0..n` through `buf` in `FULL_SCAN_CHUNK` pieces and
/// sums the per-chunk moments. Both paths MUST go through this one
/// driver — identical chunking and accumulation order is what makes
/// their results bit-identical by construction.
pub fn full_scan_moments<F: FnMut(&[usize]) -> (f64, f64)>(
    n: usize,
    buf: &mut Vec<usize>,
    mut moments: F,
) -> (f64, f64) {
    let (mut s, mut s2) = (0.0, 0.0);
    let mut start = 0usize;
    while start < n {
        let take = FULL_SCAN_CHUNK.min(n - start);
        buf.clear();
        buf.extend(start..start + take);
        let (bs, bs2) = moments(buf);
        s += bs;
        s2 += bs2;
        start += take;
    }
    (s, s2)
}

/// A target posterior whose likelihood factorizes over `n()` datapoints.
pub trait LlDiffModel {
    /// Parameter state of the Markov chain.
    type Param: Clone + Send + Sync;

    /// Number of datapoints N.
    fn n(&self) -> usize;

    /// Log-likelihood difference of datapoint `i` between `prop` and `cur`:
    /// `l_i = log p(x_i; prop) - log p(x_i; cur)`.
    fn lldiff(&self, i: usize, cur: &Self::Param, prop: &Self::Param) -> f64;

    /// Mini-batch moments `(sum_i l_i, sum_i l_i^2)` over `idx`.
    ///
    /// The default loops `lldiff`; models override with fused batch code
    /// (one dot-product pass, the Pallas kernel, ...) — this is the hot
    /// path of the whole system.
    fn lldiff_moments(&self, idx: &[usize], cur: &Self::Param, prop: &Self::Param) -> (f64, f64) {
        let (mut s, mut s2) = (0.0, 0.0);
        for &i in idx {
            let l = self.lldiff(i, cur, prop);
            s += l;
            s2 += l * l;
        }
        (s, s2)
    }

    /// Full-population moments, streamed through `buf` in
    /// `FULL_SCAN_CHUNK`-sized chunks so the exact-MH path never
    /// materializes a length-N index vector. Callers on the hot path
    /// (`MhScratch`) reuse one buffer across steps, so the steady state
    /// allocates nothing.
    fn full_moments_buf(
        &self,
        cur: &Self::Param,
        prop: &Self::Param,
        buf: &mut Vec<usize>,
    ) -> (f64, f64) {
        full_scan_moments(self.n(), buf, |idx| self.lldiff_moments(idx, cur, prop))
    }

    /// Population mean `mu = (1/N) sum_i l_i` (exact MH path).
    fn full_mean(&self, cur: &Self::Param, prop: &Self::Param) -> f64 {
        let mut buf = Vec::with_capacity(FULL_SCAN_CHUNK.min(self.n()));
        let (s, _) = self.full_moments_buf(cur, prop, &mut buf);
        s / self.n() as f64
    }

    /// Population std sigma_l of the l_i (used by the error analysis /
    /// test design, not by the sampler itself).
    fn full_std(&self, cur: &Self::Param, prop: &Self::Param) -> f64 {
        let mut buf = Vec::with_capacity(FULL_SCAN_CHUNK.min(self.n()));
        let (s, s2) = self.full_moments_buf(cur, prop, &mut buf);
        let n = self.n() as f64;
        let mean = s / n;
        ((s2 / n - mean * mean).max(0.0)).sqrt()
    }
}

/// State-caching fast path: models that can keep per-datapoint sufficient
/// statistics of the *current* parameter alive across MH steps, so each
/// accept/reject test only computes the proposal side (roughly half the
/// FLOPs of the uncached `lldiff_moments`).
///
/// Step protocol (enforced by `mh_step_cached` / `run_chain_cached`):
///
/// 1. `init_cache(theta_init)` once per chain;
/// 2. per MH step: `begin_step`, then any number of `cached_moments`
///    calls over disjoint index sets (the proposal is fixed within a
///    step), then exactly one `end_step` with the decision;
/// 3. after an accepted step the cache reflects `prop` as the new
///    current parameter; after a reject it is unchanged (the win: a
///    rejected step costs nothing beyond the proposal-side evaluations).
///
/// Contract: for identical inputs, `cached_moments` must return exactly
/// the same bits as `lldiff_moments`, so a cached chain makes decisions
/// bit-identical to an uncached one (regression-tested).
pub trait CachedLlDiff: LlDiffModel {
    /// Per-chain cache state (owned by the chain, not the model, so
    /// parallel chains over one shared model never contend).
    type Cache: Send;

    /// Build a cache holding the current-side statistics of `cur`.
    fn init_cache(&self, cur: &Self::Param) -> Self::Cache;

    /// Open a new MH step (invalidates proposal-side entries of the
    /// previous step via a stamp bump; O(1)).
    fn begin_step(&self, cache: &mut Self::Cache);

    /// Mini-batch moments over `idx` against the cached current state,
    /// recording the proposal-side statistics for `idx` in the cache.
    fn cached_moments(
        &self,
        cache: &mut Self::Cache,
        idx: &[usize],
        prop: &Self::Param,
    ) -> (f64, f64);

    /// Close the step: on accept, swap in proposal-side statistics for
    /// every index touched this step and recompute the rest; on reject,
    /// do nothing.
    fn end_step(&self, cache: &mut Self::Cache, prop: &Self::Param, accepted: bool);
}

/// A proposed move plus the proposal/prior correction that enters mu_0:
/// `log_correction = log[ rho(cur) q(prop|cur) / (rho(prop) q(cur|prop)) ]`
/// so that `mu_0(u) = (ln u + log_correction) / N` (paper Eqn. 2).
#[derive(Clone, Debug)]
pub struct Proposal<P> {
    pub param: P,
    pub log_correction: f64,
}

/// Proposal kernel: draws a candidate state given the current one.
pub trait ProposalKernel<P> {
    fn propose(&self, cur: &P, rng: &mut crate::stats::Pcg64) -> Proposal<P>;
}

impl<P, F> ProposalKernel<P> for F
where
    F: Fn(&P, &mut crate::stats::Pcg64) -> Proposal<P>,
{
    fn propose(&self, cur: &P, rng: &mut crate::stats::Pcg64) -> Proposal<P> {
        self(cur, rng)
    }
}

#[cfg(test)]
pub(crate) mod testutil {
    use super::*;

    /// Tiny synthetic model for coordinator unit tests: l_i are fixed
    /// numbers independent of the parameter (the "population" view the
    /// sequential test actually sees).
    pub struct FixedPopulation {
        pub ls: Vec<f64>,
    }

    impl LlDiffModel for FixedPopulation {
        type Param = ();

        fn n(&self) -> usize {
            self.ls.len()
        }

        fn lldiff(&self, i: usize, _: &(), _: &()) -> f64 {
            self.ls[i]
        }
    }
}

#[cfg(test)]
mod tests {
    use super::testutil::FixedPopulation;
    use super::*;

    #[test]
    fn default_moments_match_loop() {
        let m = FixedPopulation { ls: vec![1.0, -2.0, 3.0, 0.5] };
        let (s, s2) = m.lldiff_moments(&[0, 2, 3], &(), &());
        assert!((s - 4.5).abs() < 1e-12);
        assert!((s2 - (1.0 + 9.0 + 0.25)).abs() < 1e-12);
    }

    #[test]
    fn full_mean_and_std() {
        let m = FixedPopulation { ls: vec![1.0, 3.0] };
        assert!((m.full_mean(&(), &()) - 2.0).abs() < 1e-12);
        assert!((m.full_std(&(), &()) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn chunked_full_scan_matches_direct_sum() {
        // population larger than one chunk: the chunked scan must cover
        // every index exactly once.
        let mut rng = crate::stats::Pcg64::seeded(9);
        let ls: Vec<f64> = (0..(2 * FULL_SCAN_CHUNK + 37)).map(|_| rng.normal()).collect();
        let want_s: f64 = ls.iter().sum();
        let want_s2: f64 = ls.iter().map(|l| l * l).sum();
        let m = FixedPopulation { ls };
        let mut buf = Vec::new();
        let (s, s2) = m.full_moments_buf(&(), &(), &mut buf);
        assert!((s - want_s).abs() < 1e-9, "{s} vs {want_s}");
        assert!((s2 - want_s2).abs() < 1e-9);
        assert!(buf.len() <= FULL_SCAN_CHUNK, "buffer stays chunk-sized");
        assert!((m.full_mean(&(), &()) - want_s / m.n() as f64).abs() < 1e-12);
    }

    #[test]
    fn closure_is_a_kernel() {
        let k = |cur: &f64, rng: &mut crate::stats::Pcg64| Proposal {
            param: cur + rng.normal(),
            log_correction: 0.0,
        };
        let mut rng = crate::stats::Pcg64::seeded(0);
        let p = k.propose(&1.0, &mut rng);
        assert!(p.param.is_finite());
    }
}

//! Model traits: the contract between the sequential-test coordinator and
//! the per-datapoint log-likelihood populations.
//!
//! The approximate MH test (paper Alg. 1) only ever sees the population
//! `{ l_i = log p(x_i; theta') - log p(x_i; theta) }` through mini-batch
//! moments `(sum l, sum l^2)`. `LlDiffModel` is exactly that interface;
//! backends (pure Rust here, PJRT-executed Pallas in `runtime`) provide
//! the `lldiff_moments` implementation.
//!
//! Index protocol: mini-batch indices are `&[u32]` — the exact slice the
//! without-replacement scheduler hands out — so the kernels gather
//! directly from the drawn batch and no per-stage `u32 -> usize`
//! widening copy exists anywhere on the hot path. Full-population scans
//! are *range-based* (`lldiff_range_moments`) and never materialize an
//! index vector at all.

use std::sync::Mutex;

use crate::coordinator::chain::{current_chain_step, ScopedChainCtx};
use crate::coordinator::executor::{Executor, IntraPar};

/// Chunk length for full-population scans. Matches the batch capacity of
/// the AOT Pallas kernels so the chunked scan maps 1:1 onto kernel
/// dispatches on the PJRT backend, keeps per-chunk state L1-resident,
/// and is the work quantum of the deterministic parallel scan (worker
/// spans are chunk-aligned; per-chunk moments are reduced in chunk-index
/// order, so the thread count never changes a result bit). Defined as
/// the sharded store's segment alignment: segment boundaries sit on
/// chunk boundaries, so no scan chunk ever straddles two segments.
pub const FULL_SCAN_CHUNK: usize = crate::data::sharded::SEGMENT_ALIGN;

/// Chunked full-population scan over a *gathered* moments closure:
/// streams `0..n` through `buf` in `FULL_SCAN_CHUNK` pieces and sums the
/// per-chunk moments in chunk order. This is the generic fallback for
/// moments sources that only expose batch evaluation (fixed-population
/// tests, ad-hoc closures); model-backed paths use the range-based
/// `full_scan_moments_par`, which is bit-identical by the
/// `lldiff_range_moments` contract.
pub fn full_scan_moments<F: FnMut(&[u32]) -> (f64, f64)>(
    n: usize,
    buf: &mut Vec<u32>,
    mut moments: F,
) -> (f64, f64) {
    assert!(n <= u32::MAX as usize);
    let (mut s, mut s2) = (0.0, 0.0);
    let mut start = 0usize;
    while start < n {
        let take = FULL_SCAN_CHUNK.min(n - start);
        buf.clear();
        buf.extend(start as u32..(start + take) as u32);
        let (bs, bs2) = moments(buf);
        s += bs;
        s2 += bs2;
        start += take;
    }
    (s, s2)
}

/// Reusable workspace of the deterministic (possibly parallel) full
/// scan: the configured intra-step span width, the executor pool the
/// spans run on, and the per-chunk partial-moments buffer. Owned per
/// chain (inside `MhScratch`), so the steady state allocates nothing —
/// and, since the pool threads are persistent, spawns nothing either.
pub struct ScanScratch {
    threads: usize,
    /// Pool the scan spans run on; `None` for serial workspaces.
    exec: Option<Executor>,
    /// Per-chunk `(sum l, sum l^2)`, written by whichever worker owns
    /// the chunk and reduced serially in chunk-index order.
    partials: Vec<(f64, f64)>,
}

impl ScanScratch {
    /// Workspace for scans over an `n`-point population using up to
    /// `threads` concurrent spans (0 or 1 = serial). A parallel
    /// workspace draws its spans from the shared global [`Executor`]
    /// (grown to `threads - 1` background workers up front) and
    /// pre-reserves the per-chunk buffer, so later scans neither spawn
    /// threads nor allocate; the serial fast path touches neither.
    pub fn new(threads: usize, n: usize) -> Self {
        Self::from_intra(&IntraPar::threads(threads.max(1)), n)
    }

    /// Workspace whose spans run on a specific pool — the engine's
    /// pinned per-launch pool, or a small test pool — instead of the
    /// global one. The pool is taken as-is: fewer workers than
    /// `threads` just multiplexes the spans.
    pub fn on_pool(exec: &Executor, threads: usize, n: usize) -> Self {
        Self::from_intra(&IntraPar::on(threads, exec.clone()), n)
    }

    /// Workspace for the grant `intra` (see [`IntraPar`]): up to
    /// `intra.width()` concurrent spans on its pool, serial when the
    /// grant is.
    pub fn from_intra(intra: &IntraPar, n: usize) -> Self {
        let threads = intra.width().max(1);
        let cap = if threads > 1 { n.div_ceil(FULL_SCAN_CHUNK) } else { 0 };
        let exec = if threads > 1 { intra.executor().cloned() } else { None };
        ScanScratch { threads, exec, partials: Vec::with_capacity(cap) }
    }

    pub fn threads(&self) -> usize {
        self.threads
    }
}

/// The single skeleton behind both full-scan flavours (uncached and
/// cached): split the population on `FULL_SCAN_CHUNK` boundaries,
/// evaluate every chunk exactly once — serially, or as contiguous chunk
/// spans on the scratch's executor pool — and reduce the per-chunk
/// moments serially in chunk-index order. Because a chunk's value
/// depends only on the chunk and the reduction order is fixed, the
/// result is bit-identical for any span width and any pool size — and
/// bit-identical to the serial `eval`-in-a-loop scan.
///
/// `lanes` is the per-index payload a chunk may mutate (`()` for the
/// uncached scan, [`CacheLanes`] for the cached one); `split(lanes,
/// len)` carves off the payload of the first `len` remaining population
/// rows for a span, and `eval_chunk(start, end, lanes, rel)` evaluates
/// population rows `[start, end)` against its span payload, in which
/// row `start` lives at local offset `rel`. Chunk regions are disjoint
/// by construction, so the pooled scan is race-free.
fn scan_driver<L, E>(
    n: usize,
    scratch: &mut ScanScratch,
    mut lanes: L,
    mut split: impl FnMut(L, usize) -> (L, L),
    eval_chunk: E,
) -> (f64, f64)
where
    L: Send,
    E: Fn(usize, usize, &mut L, usize) -> (f64, f64) + Sync,
{
    let n_chunks = n.div_ceil(FULL_SCAN_CHUNK);
    let workers = scratch.threads.min(n_chunks);
    let exec = match &scratch.exec {
        Some(e) if workers > 1 => e.clone(),
        _ => {
            // serial fast path: lanes stay whole, so a chunk's local
            // offset is its population offset
            let (mut s, mut s2) = (0.0, 0.0);
            for c in 0..n_chunks {
                let start = c * FULL_SCAN_CHUNK;
                let end = (start + FULL_SCAN_CHUNK).min(n);
                let (bs, bs2) = eval_chunk(start, end, &mut lanes, start);
                s += bs;
                s2 += bs2;
            }
            return (s, s2);
        }
    };
    scratch.partials.clear();
    scratch.partials.resize(n_chunks, (0.0, 0.0));
    /// One worker's pre-carved share: its first chunk index, its slice
    /// of the lane payload, and its slice of the partials buffer.
    struct Span<'p, L> {
        first: usize,
        lanes: L,
        parts: &'p mut [(f64, f64)],
    }
    // carve one contiguous chunk span per worker up front (balanced to
    // within one chunk): determinism comes from the per-chunk
    // evaluation + ordered reduction, not the assignment, but
    // contiguous spans keep each worker's column reads streaming
    let mut spans: Vec<Mutex<Option<Span<'_, L>>>> = Vec::with_capacity(workers);
    {
        let mut rest: &mut [(f64, f64)] = &mut scratch.partials;
        let mut rest_lanes = lanes;
        let mut next_chunk = 0usize;
        for w in 0..workers {
            let len = n_chunks / workers + usize::from(w < n_chunks % workers);
            let first = next_chunk;
            next_chunk += len;
            let span_start = first * FULL_SCAN_CHUNK;
            let span_end = (span_start + len * FULL_SCAN_CHUNK).min(n);
            let (mine, tail) = std::mem::take(&mut rest).split_at_mut(len);
            rest = tail;
            let (my_lanes, lane_tail) = split(rest_lanes, span_end - span_start);
            rest_lanes = lane_tail;
            spans.push(Mutex::new(Some(Span { first, lanes: my_lanes, parts: mine })));
        }
    }
    // span tasks may land on pool workers whose thread-locals belong to
    // whatever chain they served last — propagate this chain's
    // (chain, step) context so scripted faults and diagnostics see the
    // right coordinates
    let ctx = current_chain_step();
    let eval_chunk = &eval_chunk;
    exec.scope(workers, |w| {
        let mut slot = spans[w].lock().unwrap_or_else(|e| e.into_inner());
        let Some(Span { first, mut lanes, parts }) = slot.take() else { return };
        drop(slot);
        let _ctx = ScopedChainCtx::enter(ctx);
        let span_start = first * FULL_SCAN_CHUNK;
        for (off, out) in parts.iter_mut().enumerate() {
            let start = (first + off) * FULL_SCAN_CHUNK;
            let end = (start + FULL_SCAN_CHUNK).min(n);
            *out = eval_chunk(start, end, &mut lanes, start - span_start);
        }
    });
    drop(spans);
    let (mut s, mut s2) = (0.0, 0.0);
    for &(bs, bs2) in &scratch.partials {
        s += bs;
        s2 += bs2;
    }
    (s, s2)
}

/// Deterministic full-population scan over a range-based chunk
/// evaluator: the population splits on `FULL_SCAN_CHUNK` boundaries,
/// each chunk is evaluated exactly once (as pooled chunk spans when
/// `scratch.threads() > 1` — no threads are spawned; the spans run on
/// the scratch's persistent executor), and the per-chunk moments are
/// reduced serially in chunk-index order. Bit-identical to the serial
/// scan for any span width and any pool size.
pub fn full_scan_moments_par<E>(n: usize, scratch: &mut ScanScratch, eval: E) -> (f64, f64)
where
    E: Fn(usize, usize) -> (f64, f64) + Sync,
{
    scan_driver(n, scratch, (), |(), _| ((), ()), |start, end, _: &mut (), _| eval(start, end))
}

/// The per-index arrays of a likelihood cache, borrowed mutably for a
/// full scan so chunk-aligned disjoint regions can be handed to
/// concurrent workers (current-side value, current-side version,
/// proposal-side value, step stamp — the shape `LogisticCache` and
/// `LinRegCache` share).
pub struct CacheLanes<'a> {
    pub val_cur: &'a mut [f64],
    pub ver_cur: &'a mut [u64],
    pub val_prop: &'a mut [f64],
    pub stamp: &'a mut [u64],
}

impl<'a> CacheLanes<'a> {
    /// Reborrow the sub-range `[start, end)` (indices relative to these
    /// lanes).
    fn slice_mut(&mut self, start: usize, end: usize) -> CacheLanes<'_> {
        CacheLanes {
            val_cur: &mut self.val_cur[start..end],
            ver_cur: &mut self.ver_cur[start..end],
            val_prop: &mut self.val_prop[start..end],
            stamp: &mut self.stamp[start..end],
        }
    }

    /// Split into `[0, mid)` and `[mid, len)`.
    fn split_at_mut(self, mid: usize) -> (CacheLanes<'a>, CacheLanes<'a>) {
        let (vc0, vc1) = self.val_cur.split_at_mut(mid);
        let (cv0, cv1) = self.ver_cur.split_at_mut(mid);
        let (vp0, vp1) = self.val_prop.split_at_mut(mid);
        let (st0, st1) = self.stamp.split_at_mut(mid);
        (
            CacheLanes { val_cur: vc0, ver_cur: cv0, val_prop: vp0, stamp: st0 },
            CacheLanes { val_cur: vc1, ver_cur: cv1, val_prop: vp1, stamp: st1 },
        )
    }
}

/// `full_scan_moments_par` for cached models: identical chunking,
/// worker-span and chunk-ordered reduction scheme (the same
/// `scan_driver` skeleton), but each chunk evaluation also receives the
/// mutable cache lanes of exactly that chunk (`eval(start, end, lanes)`
/// with `lanes` rebased so local index 0 is population index `start`).
/// Chunk regions are disjoint, so the scan is race-free by construction
/// and bit-identical for any worker count and pool size.
pub fn cached_scan_par<E>(
    n: usize,
    scratch: &mut ScanScratch,
    lanes: CacheLanes<'_>,
    eval: E,
) -> (f64, f64)
where
    E: Fn(usize, usize, CacheLanes<'_>) -> (f64, f64) + Sync,
{
    debug_assert_eq!(lanes.val_cur.len(), n);
    scan_driver(n, scratch, lanes, CacheLanes::split_at_mut, |start, end, sub, rel| {
        eval(start, end, sub.slice_mut(rel, rel + (end - start)))
    })
}

/// A target posterior whose likelihood factorizes over `n()` datapoints.
pub trait LlDiffModel {
    /// Parameter state of the Markov chain.
    type Param: Clone + Send + Sync;

    /// Number of datapoints N.
    fn n(&self) -> usize;

    /// Log-likelihood difference of datapoint `i` between `prop` and `cur`:
    /// `l_i = log p(x_i; prop) - log p(x_i; cur)`.
    fn lldiff(&self, i: usize, cur: &Self::Param, prop: &Self::Param) -> f64;

    /// Mini-batch moments `(sum_i l_i, sum_i l_i^2)` over the drawn
    /// indices (the scheduler's slice, fed to the kernel directly).
    ///
    /// The default loops `lldiff`; models override with fused batch code
    /// (the lane-blocked SoA kernels, the Pallas kernel, ...) — this is
    /// the hot path of the whole system.
    fn lldiff_moments(&self, idx: &[u32], cur: &Self::Param, prop: &Self::Param) -> (f64, f64) {
        let (mut s, mut s2) = (0.0, 0.0);
        for &i in idx {
            let l = self.lldiff(i as usize, cur, prop);
            s += l;
            s2 += l * l;
        }
        (s, s2)
    }

    /// Moments over the contiguous index range `[start, end)` — the
    /// building block of full-population scans, which therefore never
    /// materialize an index vector.
    ///
    /// **Contract:** must return exactly the bits of
    /// `lldiff_moments(&[start..end], ..)`; overriding models keep the
    /// same per-row arithmetic and lane-accumulation skeleton in both
    /// kernels (regression-tested in `tests/integration_scan.rs`).
    fn lldiff_range_moments(
        &self,
        start: usize,
        end: usize,
        cur: &Self::Param,
        prop: &Self::Param,
    ) -> (f64, f64) {
        let (mut s, mut s2) = (0.0, 0.0);
        for i in start..end {
            let l = self.lldiff(i, cur, prop);
            s += l;
            s2 += l * l;
        }
        (s, s2)
    }

    /// Full-population moments: serial chunked range scan
    /// (`FULL_SCAN_CHUNK` pieces, summed in chunk order) — allocation
    /// free, and bit-identical to `full_scan_moments_par` at any thread
    /// count.
    fn full_moments(&self, cur: &Self::Param, prop: &Self::Param) -> (f64, f64) {
        let n = self.n();
        let (mut s, mut s2) = (0.0, 0.0);
        let mut start = 0usize;
        while start < n {
            let end = (start + FULL_SCAN_CHUNK).min(n);
            let (bs, bs2) = self.lldiff_range_moments(start, end, cur, prop);
            s += bs;
            s2 += bs2;
            start = end;
        }
        (s, s2)
    }

    /// Population mean `mu = (1/N) sum_i l_i` (exact MH path). Chunked
    /// range scan: no scratch buffer, no allocation.
    fn full_mean(&self, cur: &Self::Param, prop: &Self::Param) -> f64 {
        let (s, _) = self.full_moments(cur, prop);
        s / self.n() as f64
    }

    /// Population std sigma_l of the l_i (used by the error analysis /
    /// test design, not by the sampler itself). Allocation-free like
    /// `full_mean`.
    fn full_std(&self, cur: &Self::Param, prop: &Self::Param) -> f64 {
        let (s, s2) = self.full_moments(cur, prop);
        let n = self.n() as f64;
        let mean = s / n;
        ((s2 / n - mean * mean).max(0.0)).sqrt()
    }

    /// Engine-dispatch hook of the `Session` front-end
    /// (`coordinator::session`): launch K MH chains of this model. The
    /// default drives the uncached `MhKernel`; models with a
    /// per-datapoint likelihood cache ([`CachedLlDiff`]) override it to
    /// route the identical launch through `CachedMhKernel` — decisions
    /// are bit-identical by the cache contract, so the override is a
    /// pure speedup. The hook lives on the model because that is where
    /// the capability is known; callers go through `Session`, never
    /// call this directly.
    #[doc(hidden)]
    fn session_launch<K, T, OF, O>(
        &self,
        proposal: &K,
        rule: &T,
        init: Self::Param,
        cfg: &crate::coordinator::engine::EngineConfig,
        make_observer: OF,
    ) -> Result<
        crate::coordinator::engine::EngineResult<O>,
        crate::coordinator::supervise::LaunchError,
    >
    where
        Self: Sized + Sync,
        Self::Param: crate::coordinator::checkpoint::Persist,
        K: ProposalKernel<Self::Param> + Sync,
        T: crate::coordinator::accept::AcceptanceTest + Sync,
        OF: Fn(usize) -> O + Sync,
        O: crate::coordinator::engine::ChainObserver<Self::Param>,
    {
        crate::coordinator::engine::run_engine_result(
            self,
            proposal,
            rule,
            init,
            cfg,
            make_observer,
        )
    }

    /// Which engine path `session_launch` takes: `"uncached"` unless the
    /// model overrides the hook (cached models report `"cached"` via
    /// `cached_session_dispatch!`; the PJRT backend reports `"pjrt"`).
    #[doc(hidden)]
    fn session_backend(&self) -> &'static str {
        "uncached"
    }
}

/// State-caching fast path: models that can keep per-datapoint sufficient
/// statistics of the *current* parameter alive across MH steps, so each
/// accept/reject test only computes the proposal side (roughly half the
/// FLOPs of the uncached `lldiff_moments`).
///
/// Step protocol (enforced by `mh_step_cached` / `run_chain_cached`):
///
/// 1. `init_cache(theta_init)` once per chain;
/// 2. per MH step: `begin_step`, then any number of `cached_moments` /
///    one `cached_full_scan` call over disjoint index sets (the proposal
///    is fixed within a step), then exactly one `end_step` with the
///    decision;
/// 3. after an accepted step the cache reflects `prop` as the new
///    current parameter; after a reject it is unchanged (the win: a
///    rejected step costs nothing beyond the proposal-side evaluations).
///
/// Contract: for identical inputs, `cached_moments` must return exactly
/// the same bits as `lldiff_moments`, and `cached_full_scan` exactly the
/// bits of `full_moments`, so a cached chain makes decisions
/// bit-identical to an uncached one (regression-tested).
pub trait CachedLlDiff: LlDiffModel {
    /// Per-chain cache state (owned by the chain, not the model, so
    /// parallel chains over one shared model never contend).
    type Cache: Send;

    /// Build a cache holding the current-side statistics of `cur`.
    fn init_cache(&self, cur: &Self::Param) -> Self::Cache;

    /// Open a new MH step (invalidates proposal-side entries of the
    /// previous step via a stamp bump; O(1)).
    fn begin_step(&self, cache: &mut Self::Cache);

    /// Mini-batch moments over `idx` against the cached current state,
    /// recording the proposal-side statistics for `idx` in the cache.
    fn cached_moments(
        &self,
        cache: &mut Self::Cache,
        idx: &[u32],
        prop: &Self::Param,
    ) -> (f64, f64);

    /// Full-population moments against the cache: the exact-rule fast
    /// path. Must return the bits of `full_moments` and leave the cache
    /// exactly as a chunked `cached_moments` sweep would (every index
    /// stamped this step). Implementations run the deterministic
    /// chunk-parallel scan (`cached_scan_par`) when `scan` carries
    /// spare workers.
    fn cached_full_scan(
        &self,
        cache: &mut Self::Cache,
        prop: &Self::Param,
        scan: &mut ScanScratch,
    ) -> (f64, f64);

    /// Close the step: on accept, swap in proposal-side statistics for
    /// every index touched this step and recompute the rest; on reject,
    /// do nothing.
    fn end_step(&self, cache: &mut Self::Cache, prop: &Self::Param, accepted: bool);
}

/// Expands to the cached-fast-path `session_launch` / `session_backend`
/// overrides inside an `impl LlDiffModel for $model` block, so every
/// `CachedLlDiff` model opts into the `Session` cached dispatch with one
/// line instead of a copied method body (decisions stay bit-identical to
/// the uncached path by the cache contract).
macro_rules! cached_session_dispatch {
    () => {
        fn session_launch<K, T, OF, O>(
            &self,
            proposal: &K,
            rule: &T,
            init: Self::Param,
            cfg: &crate::coordinator::engine::EngineConfig,
            make_observer: OF,
        ) -> Result<
            crate::coordinator::engine::EngineResult<O>,
            crate::coordinator::supervise::LaunchError,
        >
        where
            Self: Sized + Sync,
            Self::Param: crate::coordinator::checkpoint::Persist,
            K: crate::models::traits::ProposalKernel<Self::Param> + Sync,
            T: crate::coordinator::accept::AcceptanceTest + Sync,
            OF: Fn(usize) -> O + Sync,
            O: crate::coordinator::engine::ChainObserver<Self::Param>,
        {
            crate::coordinator::engine::run_engine_cached_result(
                self,
                proposal,
                rule,
                init,
                cfg,
                make_observer,
            )
        }

        fn session_backend(&self) -> &'static str {
            "cached"
        }
    };
}
pub(crate) use cached_session_dispatch;

/// A proposed move plus the proposal/prior correction that enters mu_0:
/// `log_correction = log[ rho(cur) q(prop|cur) / (rho(prop) q(cur|prop)) ]`
/// so that `mu_0(u) = (ln u + log_correction) / N` (paper Eqn. 2).
#[derive(Clone, Debug)]
pub struct Proposal<P> {
    pub param: P,
    pub log_correction: f64,
}

/// Proposal kernel: draws a candidate state given the current one.
pub trait ProposalKernel<P> {
    fn propose(&self, cur: &P, rng: &mut crate::stats::Pcg64) -> Proposal<P>;
}

impl<P, F> ProposalKernel<P> for F
where
    F: Fn(&P, &mut crate::stats::Pcg64) -> Proposal<P>,
{
    fn propose(&self, cur: &P, rng: &mut crate::stats::Pcg64) -> Proposal<P> {
        self(cur, rng)
    }
}

/// Models that can split themselves into row-range shards — the
/// embarrassingly-parallel mode of `Session::shards(k)`: each shard is
/// a standalone model over its contiguous row slice, sampled by its own
/// independent chains, and the per-shard subset posteriors are merged
/// afterwards (`samplers::gibbs::gaussian_product` for continuous
/// params, `SubsetMarginal::merge` for discrete ones).
pub trait ShardableModel: LlDiffModel + Sized {
    /// Build the model over shard `shard` of `shards` (the even
    /// row-range split `data::sharded::even_rows`). Errors when a
    /// shard's index space would overflow `u32`.
    fn shard_model(&self, shard: usize, shards: usize)
        -> Result<Self, crate::data::DataTooLarge>;
}

/// Wraps a proposal kernel for subset-posterior sampling: a shard must
/// target `p(x_shard | theta) p(theta)^{1/k}` (so the product of the k
/// subset posteriors recovers the full posterior), and in this codebase
/// the prior enters an MH decision *only* through the kernel's
/// `log_correction` — both random-walk kernels emit the pure prior
/// ratio `log rho(cur) - log rho(prop)` with a symmetric q. Scaling the
/// correction by `1/k` therefore tempers the prior exactly; with `k = 1`
/// the multiply by 1.0 leaves the bits unchanged.
pub struct PriorTempered<'a, K> {
    inner: &'a K,
    inv_shards: f64,
}

impl<'a, K> PriorTempered<'a, K> {
    pub fn new(inner: &'a K, shards: usize) -> Self {
        assert!(shards >= 1);
        PriorTempered { inner, inv_shards: 1.0 / shards as f64 }
    }
}

impl<P, K: ProposalKernel<P>> ProposalKernel<P> for PriorTempered<'_, K> {
    fn propose(&self, cur: &P, rng: &mut crate::stats::Pcg64) -> Proposal<P> {
        let mut p = self.inner.propose(cur, rng);
        p.log_correction *= self.inv_shards;
        p
    }
}

#[cfg(test)]
pub(crate) mod testutil {
    use super::*;

    /// Tiny synthetic model for coordinator unit tests: l_i are fixed
    /// numbers independent of the parameter (the "population" view the
    /// sequential test actually sees).
    pub struct FixedPopulation {
        pub ls: Vec<f64>,
    }

    impl LlDiffModel for FixedPopulation {
        type Param = ();

        fn n(&self) -> usize {
            self.ls.len()
        }

        fn lldiff(&self, i: usize, _: &(), _: &()) -> f64 {
            self.ls[i]
        }
    }
}

#[cfg(test)]
mod tests {
    use super::testutil::FixedPopulation;
    use super::*;

    #[test]
    fn default_moments_match_loop() {
        let m = FixedPopulation { ls: vec![1.0, -2.0, 3.0, 0.5] };
        let (s, s2) = m.lldiff_moments(&[0, 2, 3], &(), &());
        assert!((s - 4.5).abs() < 1e-12);
        assert!((s2 - (1.0 + 9.0 + 0.25)).abs() < 1e-12);
    }

    #[test]
    fn default_range_moments_match_gathered() {
        let mut rng = crate::stats::Pcg64::seeded(3);
        let m = FixedPopulation { ls: (0..700).map(|_| rng.normal()).collect() };
        let idx: Vec<u32> = (100u32..400).collect();
        let g = m.lldiff_moments(&idx, &(), &());
        let r = m.lldiff_range_moments(100, 400, &(), &());
        assert_eq!(g.0.to_bits(), r.0.to_bits());
        assert_eq!(g.1.to_bits(), r.1.to_bits());
    }

    #[test]
    fn full_mean_and_std() {
        let m = FixedPopulation { ls: vec![1.0, 3.0] };
        assert!((m.full_mean(&(), &()) - 2.0).abs() < 1e-12);
        assert!((m.full_std(&(), &()) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn chunked_full_scan_matches_direct_sum() {
        // population larger than one chunk: the chunked scan must cover
        // every index exactly once.
        let mut rng = crate::stats::Pcg64::seeded(9);
        let ls: Vec<f64> = (0..(2 * FULL_SCAN_CHUNK + 37)).map(|_| rng.normal()).collect();
        let want_s: f64 = ls.iter().sum();
        let want_s2: f64 = ls.iter().map(|l| l * l).sum();
        let m = FixedPopulation { ls };
        let (s, s2) = m.full_moments(&(), &());
        assert!((s - want_s).abs() < 1e-9, "{s} vs {want_s}");
        assert!((s2 - want_s2).abs() < 1e-9);
        assert!((m.full_mean(&(), &()) - want_s / m.n() as f64).abs() < 1e-12);

        // the gathered-closure scan agrees bit for bit (same chunking)
        let mut buf = Vec::new();
        let (gs, gs2) = full_scan_moments(m.n(), &mut buf, |idx| m.lldiff_moments(idx, &(), &()));
        assert_eq!(gs.to_bits(), s.to_bits());
        assert_eq!(gs2.to_bits(), s2.to_bits());
        assert!(buf.len() <= FULL_SCAN_CHUNK, "buffer stays chunk-sized");
    }

    #[test]
    fn parallel_scan_matches_serial_for_every_worker_count() {
        let mut rng = crate::stats::Pcg64::seeded(11);
        let n = 5 * FULL_SCAN_CHUNK + 123;
        let m = FixedPopulation { ls: (0..n).map(|_| rng.normal()).collect() };
        let serial = m.full_moments(&(), &());
        for threads in [1usize, 2, 3, 8, 32] {
            let mut scan = ScanScratch::new(threads, n);
            let par = full_scan_moments_par(n, &mut scan, |a, b| {
                m.lldiff_range_moments(a, b, &(), &())
            });
            assert_eq!(par.0.to_bits(), serial.0.to_bits(), "threads {threads}");
            assert_eq!(par.1.to_bits(), serial.1.to_bits(), "threads {threads}");
        }
    }

    #[test]
    fn cached_scan_par_visits_every_chunk_once_with_its_own_lanes() {
        // chunk evaluator stamps its lanes; afterwards every index must
        // be stamped exactly once with its owning chunk id, for any
        // worker count.
        let n = 3 * FULL_SCAN_CHUNK + 10;
        for threads in [1usize, 2, 5] {
            let mut scan = ScanScratch::new(threads, n);
            let mut val_cur = vec![0.0; n];
            let mut ver_cur = vec![0u64; n];
            let mut val_prop = vec![0.0; n];
            let mut stamp = vec![0u64; n];
            let lanes = CacheLanes {
                val_cur: &mut val_cur,
                ver_cur: &mut ver_cur,
                val_prop: &mut val_prop,
                stamp: &mut stamp,
            };
            let (s, s2) = cached_scan_par(n, &mut scan, lanes, |start, end, sub| {
                assert_eq!(sub.stamp.len(), end - start);
                let chunk = (start / FULL_SCAN_CHUNK) as u64 + 1;
                for t in sub.stamp.iter_mut() {
                    *t += chunk;
                }
                ((end - start) as f64, start as f64)
            });
            assert_eq!(s, n as f64, "threads {threads}");
            let want_s2: f64 = (0..n.div_ceil(FULL_SCAN_CHUNK))
                .map(|c| (c * FULL_SCAN_CHUNK) as f64)
                .sum();
            assert_eq!(s2, want_s2);
            for (i, &t) in stamp.iter().enumerate() {
                assert_eq!(t, (i / FULL_SCAN_CHUNK) as u64 + 1, "index {i} threads {threads}");
            }
        }
    }

    #[test]
    fn prior_tempered_scales_only_the_correction() {
        let k = |cur: &f64, rng: &mut crate::stats::Pcg64| Proposal {
            param: cur + rng.normal(),
            log_correction: 0.6,
        };
        let mut rng_a = crate::stats::Pcg64::seeded(5);
        let mut rng_b = rng_a.clone();
        let mut rng_c = rng_a.clone();
        let plain = k.propose(&2.0, &mut rng_a);
        let solo = PriorTempered::new(&k, 1).propose(&2.0, &mut rng_b);
        let quartered = PriorTempered::new(&k, 4).propose(&2.0, &mut rng_c);
        // k = 1 is a bit-exact no-op; k = 4 tempers the prior ratio
        assert_eq!(solo.param.to_bits(), plain.param.to_bits());
        assert_eq!(solo.log_correction.to_bits(), plain.log_correction.to_bits());
        assert_eq!(quartered.param.to_bits(), plain.param.to_bits());
        assert_eq!(quartered.log_correction.to_bits(), (0.6f64 * 0.25).to_bits());
    }

    #[test]
    fn closure_is_a_kernel() {
        let k = |cur: &f64, rng: &mut crate::stats::Pcg64| Proposal {
            param: cur + rng.normal(),
            log_correction: 0.0,
        };
        let mut rng = crate::stats::Pcg64::seeded(0);
        let p = k.propose(&1.0, &mut rng);
        assert!(p.param.is_finite());
    }
}

//! Model traits: the contract between the sequential-test coordinator and
//! the per-datapoint log-likelihood populations.
//!
//! The approximate MH test (paper Alg. 1) only ever sees the population
//! `{ l_i = log p(x_i; theta') - log p(x_i; theta) }` through mini-batch
//! moments `(sum l, sum l^2)`. `LlDiffModel` is exactly that interface;
//! backends (pure Rust here, PJRT-executed Pallas in `runtime`) provide
//! the `lldiff_moments` implementation.

/// A target posterior whose likelihood factorizes over `n()` datapoints.
pub trait LlDiffModel {
    /// Parameter state of the Markov chain.
    type Param: Clone + Send + Sync;

    /// Number of datapoints N.
    fn n(&self) -> usize;

    /// Log-likelihood difference of datapoint `i` between `prop` and `cur`:
    /// `l_i = log p(x_i; prop) - log p(x_i; cur)`.
    fn lldiff(&self, i: usize, cur: &Self::Param, prop: &Self::Param) -> f64;

    /// Mini-batch moments `(sum_i l_i, sum_i l_i^2)` over `idx`.
    ///
    /// The default loops `lldiff`; models override with fused batch code
    /// (one dot-product pass, the Pallas kernel, ...) — this is the hot
    /// path of the whole system.
    fn lldiff_moments(&self, idx: &[usize], cur: &Self::Param, prop: &Self::Param) -> (f64, f64) {
        let (mut s, mut s2) = (0.0, 0.0);
        for &i in idx {
            let l = self.lldiff(i, cur, prop);
            s += l;
            s2 += l * l;
        }
        (s, s2)
    }

    /// Population mean `mu = (1/N) sum_i l_i` (exact MH path).
    fn full_mean(&self, cur: &Self::Param, prop: &Self::Param) -> f64 {
        let idx: Vec<usize> = (0..self.n()).collect();
        let (s, _) = self.lldiff_moments(&idx, cur, prop);
        s / self.n() as f64
    }

    /// Population std sigma_l of the l_i (used by the error analysis /
    /// test design, not by the sampler itself).
    fn full_std(&self, cur: &Self::Param, prop: &Self::Param) -> f64 {
        let idx: Vec<usize> = (0..self.n()).collect();
        let (s, s2) = self.lldiff_moments(&idx, cur, prop);
        let n = self.n() as f64;
        let mean = s / n;
        ((s2 / n - mean * mean).max(0.0)).sqrt()
    }
}

/// A proposed move plus the proposal/prior correction that enters mu_0:
/// `log_correction = log[ rho(cur) q(prop|cur) / (rho(prop) q(cur|prop)) ]`
/// so that `mu_0(u) = (ln u + log_correction) / N` (paper Eqn. 2).
#[derive(Clone, Debug)]
pub struct Proposal<P> {
    pub param: P,
    pub log_correction: f64,
}

/// Proposal kernel: draws a candidate state given the current one.
pub trait ProposalKernel<P> {
    fn propose(&self, cur: &P, rng: &mut crate::stats::Pcg64) -> Proposal<P>;
}

impl<P, F> ProposalKernel<P> for F
where
    F: Fn(&P, &mut crate::stats::Pcg64) -> Proposal<P>,
{
    fn propose(&self, cur: &P, rng: &mut crate::stats::Pcg64) -> Proposal<P> {
        self(cur, rng)
    }
}

#[cfg(test)]
pub(crate) mod testutil {
    use super::*;

    /// Tiny synthetic model for coordinator unit tests: l_i are fixed
    /// numbers independent of the parameter (the "population" view the
    /// sequential test actually sees).
    pub struct FixedPopulation {
        pub ls: Vec<f64>,
    }

    impl LlDiffModel for FixedPopulation {
        type Param = ();

        fn n(&self) -> usize {
            self.ls.len()
        }

        fn lldiff(&self, i: usize, _: &(), _: &()) -> f64 {
            self.ls[i]
        }
    }
}

#[cfg(test)]
mod tests {
    use super::testutil::FixedPopulation;
    use super::*;

    #[test]
    fn default_moments_match_loop() {
        let m = FixedPopulation { ls: vec![1.0, -2.0, 3.0, 0.5] };
        let (s, s2) = m.lldiff_moments(&[0, 2, 3], &(), &());
        assert!((s - 4.5).abs() < 1e-12);
        assert!((s2 - (1.0 + 9.0 + 0.25)).abs() < 1e-12);
    }

    #[test]
    fn full_mean_and_std() {
        let m = FixedPopulation { ls: vec![1.0, 3.0] };
        assert!((m.full_mean(&(), &()) - 2.0).abs() < 1e-12);
        assert!((m.full_std(&(), &()) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn closure_is_a_kernel() {
        let k = |cur: &f64, rng: &mut crate::stats::Pcg64| Proposal {
            param: cur + rng.normal(),
            log_correction: 0.0,
        };
        let mut rng = crate::stats::Pcg64::seeded(0);
        let p = k.propose(&1.0, &mut rng);
        assert!(p.param.is_finite());
    }
}

//! Multi-valued (Potts-style) dense MRF — the supp.-F extension:
//! "the extension to multi-valued variables is also possible".
//!
//! D categorical variables with K states, triple-clique potentials
//! psi_{ijk} over all C(D,3) triples (log tables of K^3 entries, drawn
//! N(0, sigma^2) like the binary model). The Gibbs population for
//! variable v is again the (D-1)(D-2)/2 pairs (j,k); the per-pair factor
//! of state `a` is log psi(a, x_j, x_k), and a *comparison* population
//! between states a and b is l_pair = f_pair(a) - f_pair(b) — exactly
//! the shape the sequential test consumes (see samplers::gibbs_potts).

use crate::models::mrf::{n_triples, triple_index};
use crate::stats::Pcg64;

pub struct PottsModel {
    d: usize,
    k: usize,
    /// triple (i<j<k) tables: k^3 entries indexed (xi*k + xj)*k + xk
    log_psi: Vec<f64>,
}

impl PottsModel {
    pub fn new(d: usize, k: usize, log_psi: Vec<f64>) -> Self {
        assert!(d >= 3 && k >= 2);
        assert_eq!(log_psi.len(), n_triples(d) * k * k * k);
        PottsModel { d, k, log_psi }
    }

    pub fn random(d: usize, k: usize, sigma: f64, seed: u64) -> Self {
        let mut rng = Pcg64::new(seed, 6);
        let tables = (0..n_triples(d) * k * k * k)
            .map(|_| rng.normal_scaled(0.0, sigma))
            .collect();
        Self::new(d, k, tables)
    }

    pub fn d(&self) -> usize {
        self.d
    }

    pub fn k(&self) -> usize {
        self.k
    }

    pub fn n_pairs(&self) -> usize {
        (self.d - 1) * (self.d - 2) / 2
    }

    /// Log potential of triple {a,b,c} with values (va,vb,vc).
    pub fn log_potential(
        &self,
        mut a: usize,
        mut b: usize,
        mut c: usize,
        mut va: usize,
        mut vb: usize,
        mut vc: usize,
    ) -> f64 {
        if a > b {
            std::mem::swap(&mut a, &mut b);
            std::mem::swap(&mut va, &mut vb);
        }
        if b > c {
            std::mem::swap(&mut b, &mut c);
            std::mem::swap(&mut vb, &mut vc);
        }
        if a > b {
            std::mem::swap(&mut a, &mut b);
            std::mem::swap(&mut va, &mut vb);
        }
        let t = triple_index(a, b, c);
        let k = self.k;
        self.log_psi[t * k * k * k + (va * k + vb) * k + vc]
    }

    /// Decode pair rank into (j, k), j < k, both != v (same enumeration
    /// as the binary model).
    pub fn pair_at(&self, v: usize, rank: usize) -> (usize, usize) {
        debug_assert!(rank < self.n_pairs());
        let m = self.d - 1;
        let mut p = 0usize;
        let mut r = rank;
        loop {
            let row = m - 1 - p;
            if r < row {
                break;
            }
            r -= row;
            p += 1;
        }
        let q = p + 1 + r;
        let map = |t: usize| if t < v { t } else { t + 1 };
        (map(p), map(q))
    }

    /// Factor value: log psi(v=state, x_j, x_k) for one pair.
    #[inline]
    pub fn pair_factor(&self, v: usize, rank: usize, state: usize, x: &[usize]) -> f64 {
        let (j, k) = self.pair_at(v, rank);
        self.log_potential(v, j, k, state, x[j], x[k])
    }

    /// Comparison population item between states a and b.
    #[inline]
    pub fn pair_lldiff(&self, v: usize, rank: usize, a: usize, b: usize, x: &[usize]) -> f64 {
        self.pair_factor(v, rank, a, x) - self.pair_factor(v, rank, b, x)
    }

    /// Moments of the comparison population over given ranks.
    pub fn pair_moments(
        &self,
        v: usize,
        ranks: &[usize],
        a: usize,
        b: usize,
        x: &[usize],
    ) -> (f64, f64) {
        let (mut s, mut s2) = (0.0, 0.0);
        for &r in ranks {
            let l = self.pair_lldiff(v, r, a, b, x);
            s += l;
            s2 += l * l;
        }
        (s, s2)
    }

    /// Exact unnormalized log conditional of each state of v.
    pub fn exact_scores(&self, v: usize, x: &[usize]) -> Vec<f64> {
        (0..self.k)
            .map(|state| {
                (0..self.n_pairs()).map(|r| self.pair_factor(v, r, state, x)).sum()
            })
            .collect()
    }

    /// Exact conditional distribution of X_v.
    pub fn exact_conditional(&self, v: usize, x: &[usize]) -> Vec<f64> {
        let scores = self.exact_scores(v, x);
        let max = scores.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        let exps: Vec<f64> = scores.iter().map(|s| (s - max).exp()).collect();
        let z: f64 = exps.iter().sum();
        exps.iter().map(|e| e / z).collect()
    }

    /// Unnormalized log joint (small-D checks only).
    pub fn log_joint(&self, x: &[usize]) -> f64 {
        let d = self.d;
        let mut s = 0.0;
        for i in 0..d {
            for j in i + 1..d {
                for k in j + 1..d {
                    s += self.log_potential(i, j, k, x[i], x[j], x[k]);
                }
            }
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit;

    #[test]
    fn exact_conditional_matches_joint() {
        let m = PottsModel::random(5, 3, 0.3, 0);
        testkit::forall(24, |rng| {
            let v = rng.below(5);
            let x: Vec<usize> = (0..5).map(|_| rng.below(3)).collect();
            let cond = m.exact_conditional(v, &x);
            // brute force from the joint
            let mut logs = Vec::new();
            for state in 0..3 {
                let mut xx = x.clone();
                xx[v] = state;
                logs.push(m.log_joint(&xx));
            }
            let max = logs.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
            let exps: Vec<f64> = logs.iter().map(|l| (l - max).exp()).collect();
            let z: f64 = exps.iter().sum();
            for state in 0..3 {
                assert!(
                    (cond[state] - exps[state] / z).abs() < 1e-10,
                    "v={v} state={state}"
                );
            }
        });
    }

    #[test]
    fn pair_moments_match_loop() {
        let m = PottsModel::random(10, 3, 0.1, 1);
        testkit::forall(24, |rng| {
            let v = rng.below(10);
            let x: Vec<usize> = (0..10).map(|_| rng.below(3)).collect();
            let a = rng.below(3);
            let b = rng.below(3);
            let n = rng.below(m.n_pairs()) + 1;
            let ranks: Vec<usize> = (0..n).map(|_| rng.below(m.n_pairs())).collect();
            let (s, s2) = m.pair_moments(v, &ranks, a, b, &x);
            let (mut ws, mut ws2) = (0.0, 0.0);
            for &r in &ranks {
                let l = m.pair_lldiff(v, r, a, b, &x);
                ws += l;
                ws2 += l * l;
            }
            assert!((s - ws).abs() < 1e-12);
            assert!((s2 - ws2).abs() < 1e-12);
        });
    }

    #[test]
    fn conditional_sums_to_one() {
        let m = PottsModel::random(8, 4, 0.2, 2);
        let x: Vec<usize> = (0..8).map(|i| i % 4).collect();
        for v in 0..8 {
            let c = m.exact_conditional(v, &x);
            assert!((c.iter().sum::<f64>() - 1.0).abs() < 1e-12);
            assert!(c.iter().all(|&p| p >= 0.0));
        }
    }

    #[test]
    fn binary_potts_matches_binary_mrf_shape() {
        // K = 2 Potts with the same enumeration should expose the same
        // pair structure as MrfModel.
        let m = PottsModel::random(12, 2, 0.1, 3);
        assert_eq!(m.n_pairs(), 55);
        let b = crate::models::MrfModel::random(12, 0.1, 3);
        for v in 0..12 {
            for r in 0..m.n_pairs() {
                assert_eq!(m.pair_at(v, r), b.pair_at(v, r));
            }
        }
    }
}

//! Dense binary Markov Random Field with triple-clique potentials
//! (paper supp. F.1): D variables, all C(D,3) potentials psi_{ijk},
//! log psi drawn N(0, sigma^2).
//!
//! For a Gibbs update of variable v the "population" the sequential test
//! subsamples is the set of (D-1)(D-2)/2 pairs (j, k):
//!     l_pair = log psi(X_v=1, x_j, x_k) - log psi(X_v=0, x_j, x_k)
//! and the exact conditional is sigmoid(sum over all pairs).

use crate::data::synthetic::mrf_potentials;

/// Binary MRF with all-triples log-potential tables.
pub struct MrfModel {
    d: usize,
    /// Flattened tables: triple (i<j<k) at `triple_index`, 8 entries each
    /// indexed by (x_i << 2) | (x_j << 1) | x_k.
    log_psi: Vec<f64>,
}

impl MrfModel {
    pub fn new(d: usize, log_psi: Vec<f64>) -> Self {
        assert!(d >= 3);
        assert_eq!(log_psi.len(), n_triples(d) * 8);
        MrfModel { d, log_psi }
    }

    /// Random instance matching the paper: log psi ~ N(0, sigma^2).
    pub fn random(d: usize, sigma: f64, seed: u64) -> Self {
        Self::new(d, mrf_potentials(d, sigma, seed))
    }

    pub fn d(&self) -> usize {
        self.d
    }

    /// Number of (j,k) pairs in one variable's Gibbs population.
    pub fn n_pairs(&self) -> usize {
        (self.d - 1) * (self.d - 2) / 2
    }

    /// Log potential of triple {a,b,c} (any order) at the given state.
    pub fn log_potential(&self, mut a: usize, mut b: usize, mut c: usize, xa: bool, xb: bool, xc: bool) -> f64 {
        let (mut va, mut vb, mut vc) = (xa, xb, xc);
        // sort (a,b,c) carrying values along
        if a > b {
            std::mem::swap(&mut a, &mut b);
            std::mem::swap(&mut va, &mut vb);
        }
        if b > c {
            std::mem::swap(&mut b, &mut c);
            std::mem::swap(&mut vb, &mut vc);
        }
        if a > b {
            std::mem::swap(&mut a, &mut b);
            std::mem::swap(&mut va, &mut vb);
        }
        let t = triple_index(a, b, c);
        let bits = ((va as usize) << 2) | ((vb as usize) << 1) | (vc as usize);
        self.log_psi[t * 8 + bits]
    }

    /// The pair population item for a Gibbs update of variable `v`:
    /// pair_rank enumerates the (j,k), j<k, j,k != v pairs.
    pub fn pair_lldiff(&self, v: usize, pair_rank: usize, x: &[bool]) -> f64 {
        let (j, k) = self.pair_at(v, pair_rank);
        self.log_potential(v, j, k, true, x[j], x[k])
            - self.log_potential(v, j, k, false, x[j], x[k])
    }

    /// Decode pair_rank into the actual (j, k), j < k, both != v.
    pub fn pair_at(&self, v: usize, rank: usize) -> (usize, usize) {
        debug_assert!(rank < self.n_pairs());
        // others = [0..d) \ {v}; rank indexes pairs of `others`.
        // decode rank -> (p, q) over m = d-1 items, p < q
        let m = self.d - 1;
        // row p contributes (m - 1 - p) pairs; find p.
        let mut p = 0usize;
        let mut r = rank;
        loop {
            let row = m - 1 - p;
            if r < row {
                break;
            }
            r -= row;
            p += 1;
        }
        let q = p + 1 + r;
        let map = |t: usize| if t < v { t } else { t + 1 };
        (map(p), map(q))
    }

    /// Exact log ratio sum over all pairs: log P(Xv=1,x_-v)/P(Xv=0,x_-v).
    pub fn exact_log_ratio(&self, v: usize, x: &[bool]) -> f64 {
        (0..self.n_pairs()).map(|r| self.pair_lldiff(v, r, x)).sum()
    }

    /// Exact Gibbs conditional P(X_v = 1 | x_{-v}).
    pub fn exact_conditional(&self, v: usize, x: &[bool]) -> f64 {
        crate::models::logistic::sigmoid(self.exact_log_ratio(v, x))
    }

    /// Moments (sum, sum of squares) of pair lldiffs over given ranks.
    pub fn pair_moments(&self, v: usize, ranks: &[usize], x: &[bool]) -> (f64, f64) {
        let (mut s, mut s2) = (0.0, 0.0);
        for &r in ranks {
            let l = self.pair_lldiff(v, r, x);
            s += l;
            s2 += l * l;
        }
        (s, s2)
    }

    /// Unnormalized log joint (for small-D exact checks only).
    pub fn log_joint(&self, x: &[bool]) -> f64 {
        let d = self.d;
        let mut s = 0.0;
        for i in 0..d {
            for j in i + 1..d {
                for k in j + 1..d {
                    s += self.log_potential(i, j, k, x[i], x[j], x[k]);
                }
            }
        }
        s
    }
}

pub fn n_triples(d: usize) -> usize {
    d * (d - 1) * (d - 2) / 6
}

/// Rank of the triple (i < j < k) in the combinatorial number system.
pub fn triple_index(i: usize, j: usize, k: usize) -> usize {
    debug_assert!(i < j && j < k);
    k * (k - 1) * (k - 2) / 6 + j * (j - 1) / 2 + i
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit;

    #[test]
    fn triple_index_is_bijective() {
        let d = 12;
        let mut seen = vec![false; n_triples(d)];
        for i in 0..d {
            for j in i + 1..d {
                for k in j + 1..d {
                    let t = triple_index(i, j, k);
                    assert!(t < seen.len(), "({i},{j},{k}) -> {t}");
                    assert!(!seen[t], "collision at ({i},{j},{k})");
                    seen[t] = true;
                }
            }
        }
        assert!(seen.iter().all(|&b| b));
    }

    #[test]
    fn pair_at_enumerates_all_pairs() {
        let m = MrfModel::random(9, 0.02, 0);
        for v in 0..9 {
            let mut seen = std::collections::HashSet::new();
            for r in 0..m.n_pairs() {
                let (j, k) = m.pair_at(v, r);
                assert!(j < k && j != v && k != v, "v={v} r={r} -> ({j},{k})");
                assert!(seen.insert((j, k)), "dup pair ({j},{k})");
            }
            assert_eq!(seen.len(), m.n_pairs());
        }
    }

    #[test]
    fn log_potential_order_invariant() {
        let m = MrfModel::random(7, 0.5, 1);
        testkit::forall(64, |rng| {
            let mut ids = [0usize; 3];
            loop {
                for v in ids.iter_mut() {
                    *v = rng.below(7);
                }
                if ids[0] != ids[1] && ids[1] != ids[2] && ids[0] != ids[2] {
                    break;
                }
            }
            let vals = [rng.uniform() < 0.5, rng.uniform() < 0.5, rng.uniform() < 0.5];
            let a = m.log_potential(ids[0], ids[1], ids[2], vals[0], vals[1], vals[2]);
            let b = m.log_potential(ids[2], ids[0], ids[1], vals[2], vals[0], vals[1]);
            let c = m.log_potential(ids[1], ids[2], ids[0], vals[1], vals[2], vals[0]);
            assert!((a - b).abs() < 1e-15 && (a - c).abs() < 1e-15);
        });
    }

    #[test]
    fn exact_conditional_matches_joint() {
        // P(Xv=1 | x_-v) from pair sums must equal the ratio of joints.
        let m = MrfModel::random(6, 0.3, 2);
        testkit::forall(32, |rng| {
            let v = rng.below(6);
            let mut x: Vec<bool> = (0..6).map(|_| rng.uniform() < 0.5).collect();
            x[v] = true;
            let lp1 = m.log_joint(&x);
            x[v] = false;
            let lp0 = m.log_joint(&x);
            let want = 1.0 / (1.0 + (lp0 - lp1).exp());
            let got = m.exact_conditional(v, &x);
            assert!((got - want).abs() < 1e-10, "v={v}: {got} vs {want}");
        });
    }

    #[test]
    fn pair_moments_match_loop() {
        let m = MrfModel::random(10, 0.02, 3);
        testkit::forall(32, |rng| {
            let v = rng.below(10);
            let x: Vec<bool> = (0..10).map(|_| rng.uniform() < 0.5).collect();
            let n = rng.below(m.n_pairs()) + 1;
            let ranks: Vec<usize> = (0..n).map(|_| rng.below(m.n_pairs())).collect();
            let (s, s2) = m.pair_moments(v, &ranks, &x);
            let (mut ws, mut ws2) = (0.0, 0.0);
            for &r in &ranks {
                let l = m.pair_lldiff(v, r, &x);
                ws += l;
                ws2 += l * l;
            }
            assert!((s - ws).abs() < 1e-12);
            assert!((s2 - ws2).abs() < 1e-12);
        });
    }

    #[test]
    fn paper_scale_pair_count() {
        // D=100: 4851 pairs per variable (paper supp. F.1).
        let m = MrfModel::random(100, 0.02, 4);
        assert_eq!(m.n_pairs(), 4851);
        assert_eq!(n_triples(100), 161_700);
    }
}

//! Variable-selection logistic regression for reversible-jump MCMC
//! (paper §6.3, supp. E): theta = (beta, gamma) with a Laplace shrinkage
//! prior on active coefficients, a right-truncated Poisson prior on the
//! model size, and the MiniBooNE-like likelihood.

use crate::coordinator::checkpoint::{BinReader, BinWriter, CkptError, Persist};
use crate::data::Dataset;
use crate::models::logistic::log_sigmoid;
use crate::models::traits::LlDiffModel;
use crate::stats::student_t::ln_gamma;

/// Sparse parameter state: full-length beta plus the sorted active set.
/// beta[j] is meaningful only when j is in `active`.
#[derive(Clone, Debug)]
pub struct RjState {
    pub beta: Vec<f64>,
    pub active: Vec<usize>,
}

impl Persist for RjState {
    fn persist(&self, w: &mut BinWriter) {
        self.beta.persist(w);
        self.active.persist(w);
    }

    fn restore(r: &mut BinReader<'_>) -> Result<Self, CkptError> {
        Ok(RjState { beta: Vec::restore(r)?, active: Vec::restore(r)? })
    }
}

impl RjState {
    pub fn new(d: usize) -> Self {
        RjState { beta: vec![0.0; d], active: Vec::new() }
    }

    pub fn with_active(d: usize, active: &[usize], values: &[f64]) -> Self {
        let mut s = RjState::new(d);
        for (&j, &v) in active.iter().zip(values) {
            s.beta[j] = v;
        }
        s.active = active.to_vec();
        s.active.sort_unstable();
        s
    }

    pub fn k(&self) -> usize {
        self.active.len()
    }

    /// L1 norm over the active set.
    pub fn l1(&self) -> f64 {
        self.active.iter().map(|&j| self.beta[j].abs()).sum()
    }

    #[inline]
    pub fn logit(&self, row: &[f64]) -> f64 {
        let mut z = 0.0;
        for &j in &self.active {
            z += self.beta[j] * row[j];
        }
        z
    }
}

/// The RJ variable-selection target.
pub struct RjLogisticModel {
    data: Dataset,
    /// Model-size Poisson rate lambda (paper: 1e-10).
    pub lambda: f64,
}

impl RjLogisticModel {
    pub fn new(data: Dataset, lambda: f64) -> Self {
        RjLogisticModel { data, lambda }
    }

    pub fn data(&self) -> &Dataset {
        &self.data
    }

    pub fn d(&self) -> usize {
        self.data.d()
    }

    /// Log of the (nu-integrated-out) prior factor:
    /// ||beta||_1^{-k} lambda^k B(k, D-k+1)   (paper §6.3).
    pub fn log_prior(&self, s: &RjState) -> f64 {
        let k = s.k() as f64;
        let d = self.d() as f64;
        if s.k() == 0 {
            // empty model: the beta-function factor with k=0 (B(0,.) is
            // divergent; the paper starts at k=1 — treat k=0 as k=1 with
            // zero coefficient mass to keep the chain well-defined).
            return f64::NEG_INFINITY;
        }
        let l1 = s.l1();
        -k * l1.ln() + k * self.lambda.ln() + ln_beta(k, d - k + 1.0)
    }

    pub fn loglik_point(&self, i: usize, s: &RjState) -> f64 {
        log_sigmoid(self.data.label(i) * s.logit(self.data.row(i)))
    }

    pub fn predict(&self, row: &[f64], s: &RjState) -> f64 {
        crate::models::logistic::sigmoid(s.logit(row))
    }
}

/// log Beta(a, b).
pub fn ln_beta(a: f64, b: f64) -> f64 {
    ln_gamma(a) + ln_gamma(b) - ln_gamma(a + b)
}

impl LlDiffModel for RjLogisticModel {
    type Param = RjState;

    fn n(&self) -> usize {
        self.data.n()
    }

    fn lldiff(&self, i: usize, cur: &RjState, prop: &RjState) -> f64 {
        let row = self.data.row(i);
        let y = self.data.label(i);
        log_sigmoid(y * prop.logit(row)) - log_sigmoid(y * cur.logit(row))
    }

    fn lldiff_moments(&self, idx: &[u32], cur: &RjState, prop: &RjState) -> (f64, f64) {
        let (mut s, mut s2) = (0.0, 0.0);
        for &i in idx {
            let row = self.data.row(i as usize);
            let y = self.data.label(i as usize);
            let l = log_sigmoid(y * prop.logit(row)) - log_sigmoid(y * cur.logit(row));
            s += l;
            s2 += l * l;
        }
        (s, s2)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::sparse_logistic;

    fn model() -> (RjLogisticModel, Vec<f64>) {
        let (ds, beta) = sparse_logistic(1000, 11, 3, 0.3, 0);
        (RjLogisticModel::new(ds, 1e-10), beta)
    }

    #[test]
    fn ln_beta_matches_definition() {
        // B(2,3) = 1/12
        assert!((ln_beta(2.0, 3.0) - (1.0f64 / 12.0).ln()).abs() < 1e-12);
        // B(1,1) = 1
        assert!(ln_beta(1.0, 1.0).abs() < 1e-12);
    }

    #[test]
    fn state_logit_uses_only_active() {
        let s = RjState::with_active(5, &[1, 3], &[2.0, -1.0]);
        let row = [10.0, 1.0, 10.0, 2.0, 10.0];
        assert!((s.logit(&row) - (2.0 - 2.0)).abs() < 1e-12);
        assert_eq!(s.k(), 2);
        assert!((s.l1() - 3.0).abs() < 1e-12);
    }

    #[test]
    fn lldiff_zero_for_same_state() {
        let (m, _) = model();
        let s = RjState::with_active(11, &[0, 2], &[0.5, -0.3]);
        let idx: Vec<u32> = (0..100).collect();
        let (sum, sum2) = m.lldiff_moments(&idx, &s, &s);
        assert_eq!(sum, 0.0);
        assert_eq!(sum2, 0.0);
    }

    #[test]
    fn true_support_improves_loglik() {
        let (m, beta_true) = model();
        let active: Vec<usize> =
            (0..11).filter(|&j| beta_true[j] != 0.0).collect();
        let values: Vec<f64> = active.iter().map(|&j| beta_true[j]).collect();
        let truth = RjState::with_active(11, &active, &values);
        let null = RjState::with_active(11, &[0], &[0.0]);
        let idx: Vec<u32> = (0..m.n() as u32).collect();
        let (s, _) = m.lldiff_moments(&idx, &null, &truth);
        assert!(s > 0.0, "truth should beat empty model: {s}");
    }

    #[test]
    fn prior_prefers_small_models_with_tiny_lambda() {
        let (m, _) = model();
        let small = RjState::with_active(11, &[1], &[0.5]);
        let big = RjState::with_active(11, &[1, 2, 3, 4, 5, 6], &[0.5; 6]);
        assert!(m.log_prior(&small) > m.log_prior(&big));
    }

    #[test]
    fn empty_model_has_zero_prior_mass() {
        let (m, _) = model();
        assert_eq!(m.log_prior(&RjState::new(11)), f64::NEG_INFINITY);
    }
}

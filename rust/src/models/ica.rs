//! Independent Component Analysis posterior (paper §6.2).
//!
//! Model: p(x | W) = |det W| prod_j [4 cosh^2(0.5 w_j^T x)]^{-1} with the
//! unmixing matrix W constrained to the Stiefel manifold (uniform prior
//! on the manifold, zero elsewhere). Includes the Amari distance used as
//! the test function in Fig. 3.

use crate::data::linalg::Mat;
use crate::data::Unsupervised;
use crate::models::traits::LlDiffModel;

/// Stable log cosh.
#[inline]
pub fn log_cosh(z: f64) -> f64 {
    let a = z.abs();
    a + (-2.0 * a).exp().ln_1p() - std::f64::consts::LN_2
}

/// ICA posterior target over pre-whitened observations.
pub struct IcaModel {
    data: Unsupervised,
}

impl IcaModel {
    pub fn new(data: Unsupervised) -> Self {
        IcaModel { data }
    }

    pub fn data(&self) -> &Unsupervised {
        &self.data
    }

    pub fn d(&self) -> usize {
        self.data.d()
    }

    /// log p(x_i | W) with the logdet term included.
    pub fn loglik_point(&self, i: usize, w: &Mat) -> f64 {
        let (_, logdet) = w.slogdet();
        logdet + self.cosh_part(i, w)
    }

    /// The -sum_j [2 log 2 + 2 log cosh(0.5 w_j^T x)] part (no logdet).
    fn cosh_part(&self, i: usize, w: &Mat) -> f64 {
        let d = self.d();
        let x = self.data.row(i);
        let mut s = 0.0;
        for j in 0..d {
            let row = w.row(j);
            let mut dot = 0.0;
            for k in 0..d {
                dot += row[k] * x[k];
            }
            s -= 2.0 * std::f64::consts::LN_2 + 2.0 * log_cosh(0.5 * dot);
        }
        s
    }
}

impl LlDiffModel for IcaModel {
    type Param = Mat;

    fn n(&self) -> usize {
        self.data.n()
    }

    fn lldiff(&self, i: usize, cur: &Mat, prop: &Mat) -> f64 {
        let (_, ld_cur) = cur.slogdet();
        let (_, ld_prop) = prop.slogdet();
        (ld_prop - ld_cur) + self.cosh_part(i, prop) - self.cosh_part(i, cur)
    }

    fn lldiff_moments(&self, idx: &[u32], cur: &Mat, prop: &Mat) -> (f64, f64) {
        // slogdet once per call, fused cosh pass per row.
        self.fused_moments(idx.iter().map(|&i| i as usize), cur, prop)
    }

    fn lldiff_range_moments(&self, start: usize, end: usize, cur: &Mat, prop: &Mat) -> (f64, f64) {
        // same fused body over the contiguous range, so the exact path
        // keeps the gathered kernel's cost and bits
        self.fused_moments(start..end, cur, prop)
    }
}

impl IcaModel {
    /// The fused per-row pass shared by the gathered and range moments
    /// kernels (slogdet once per call): identical arithmetic per row, so
    /// the two entry points are bit-identical on the same index sets.
    fn fused_moments(
        &self,
        rows: impl Iterator<Item = usize>,
        cur: &Mat,
        prop: &Mat,
    ) -> (f64, f64) {
        let (_, ld_cur) = cur.slogdet();
        let (_, ld_prop) = prop.slogdet();
        let const_shift = ld_prop - ld_cur;
        let d = self.d();
        let (mut s, mut s2) = (0.0, 0.0);
        for i in rows {
            let x = self.data.row(i);
            let mut l = const_shift;
            for j in 0..d {
                let (rc, rp) = (cur.row(j), prop.row(j));
                let (mut dc, mut dp) = (0.0, 0.0);
                for k in 0..d {
                    dc += rc[k] * x[k];
                    dp += rp[k] * x[k];
                }
                // 2log2 terms cancel between prop and cur.
                l += 2.0 * (log_cosh(0.5 * dc) - log_cosh(0.5 * dp));
            }
            s += l;
            s2 += l * l;
        }
        (s, s2)
    }
}

/// Amari distance between two unmixing matrices (Amari et al., 1996) —
/// permutation- and scale-invariant; 0 iff W recovers W0 up to those.
pub fn amari_distance(w: &Mat, w0: &Mat) -> f64 {
    let d = w.d;
    assert_eq!(d, w0.d);
    // r = W * W0^{-1}
    let r = w.matmul(&w0.inverse());
    let mut total = 0.0;
    for i in 0..d {
        let row_max = (0..d).map(|j| r[(i, j)].abs()).fold(0.0f64, f64::max);
        let row_sum: f64 = (0..d).map(|j| r[(i, j)].abs()).sum();
        total += row_sum / row_max - 1.0;
        let col_max = (0..d).map(|j| r[(j, i)].abs()).fold(0.0f64, f64::max);
        let col_sum: f64 = (0..d).map(|j| r[(j, i)].abs()).sum();
        total += col_sum / col_max - 1.0;
    }
    total / (2.0 * d as f64 * (d as f64 - 1.0))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::linalg::{random_orthonormal, random_skew};
    use crate::data::synthetic::ica_mixture;
    use crate::stats::Pcg64;
    use crate::testkit;

    #[test]
    fn log_cosh_values() {
        assert!(log_cosh(0.0).abs() < 1e-15);
        for &z in &[-3.0, -0.5, 0.2, 5.0] {
            assert!((log_cosh(z) - (z as f64).cosh().ln()).abs() < 1e-12);
        }
        // stability at large |z|: log cosh(z) ~ |z| - ln 2
        assert!((log_cosh(500.0) - (500.0 - std::f64::consts::LN_2)).abs() < 1e-9);
    }

    #[test]
    fn lldiff_matches_pointwise_logliks() {
        let (obs, _) = ica_mixture(200, 0);
        let m = IcaModel::new(obs);
        let mut rng = Pcg64::seeded(1);
        let w = random_orthonormal(4, &mut rng);
        let wp = random_orthonormal(4, &mut rng);
        for i in [0usize, 57, 199] {
            let want = m.loglik_point(i, &wp) - m.loglik_point(i, &w);
            assert!((m.lldiff(i, &w, &wp) - want).abs() < 1e-10);
        }
    }

    #[test]
    fn fused_moments_match_loop() {
        let (obs, _) = ica_mixture(300, 2);
        let m = IcaModel::new(obs);
        testkit::forall(16, |rng| {
            let w = random_orthonormal(4, rng);
            let wp = w.matmul(&random_skew(4, 0.05, rng).expm());
            let k = rng.below(80) + 1;
            let idx: Vec<u32> = (0..k).map(|_| rng.below(300) as u32).collect();
            let (s, s2) = m.lldiff_moments(&idx, &w, &wp);
            let (mut ws, mut ws2) = (0.0, 0.0);
            for &i in &idx {
                let l = m.lldiff(i as usize, &w, &wp);
                ws += l;
                ws2 += l * l;
            }
            assert!((s - ws).abs() < 1e-8);
            assert!((s2 - ws2).abs() < 1e-8);
        });
    }

    #[test]
    fn amari_zero_for_permutation_and_scale() {
        let mut rng = Pcg64::seeded(3);
        let w0 = random_orthonormal(4, &mut rng);
        assert!(amari_distance(&w0, &w0) < 1e-12);
        // permute rows and rescale: distance stays ~0
        let mut perm = Mat::zeros(4);
        perm[(0, 2)] = 3.0;
        perm[(1, 0)] = -0.5;
        perm[(2, 3)] = 1.0;
        perm[(3, 1)] = 2.0;
        let w = perm.matmul(&w0);
        assert!(amari_distance(&w, &w0) < 1e-12);
    }

    #[test]
    fn amari_positive_for_mixing() {
        let mut rng = Pcg64::seeded(4);
        let w0 = random_orthonormal(4, &mut rng);
        let w = random_orthonormal(4, &mut rng);
        assert!(amari_distance(&w, &w0) > 0.05);
        // small perturbation: small but positive distance
        let wp = w0.matmul(&random_skew(4, 0.01, &mut rng).expm());
        let d = amari_distance(&wp, &w0);
        assert!(d > 0.0 && d < 0.05, "d={d}");
    }

    #[test]
    fn true_unmixing_beats_random_in_loglik() {
        let (obs, w0) = ica_mixture(2000, 5);
        let m = IcaModel::new(obs);
        let mut rng = Pcg64::seeded(6);
        let wr = random_orthonormal(4, &mut rng);
        let idx: Vec<u32> = (0..2000).collect();
        // mean lldiff from random W to true W0 should be positive
        let (s, _) = m.lldiff_moments(&idx, &wr, &w0);
        assert!(s > 0.0, "sum lldiff {s}");
    }
}

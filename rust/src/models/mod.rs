//! Target models: each paper experiment's posterior as an `LlDiffModel`
//! population (plus the MRF, whose Gibbs population is pair-indexed).

pub mod ica;
pub mod linreg;
pub mod logistic;
pub mod mrf;
pub mod potts;
pub mod rjlogistic;
pub mod traits;

pub use ica::IcaModel;
pub use linreg::{LinRegCache, LinRegModel};
pub use logistic::{LogisticCache, LogisticModel};
pub use mrf::MrfModel;
pub use potts::PottsModel;
pub use rjlogistic::{RjLogisticModel, RjState};
pub use traits::{
    CachedLlDiff, LlDiffModel, PriorTempered, Proposal, ProposalKernel, ScanScratch,
    ShardableModel,
};

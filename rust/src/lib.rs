//! # Austerity MCMC
//!
//! A complete implementation of **"Austerity in MCMC Land: Cutting the
//! Metropolis-Hastings Budget"** (Korattikara, Chen & Welling, ICML 2014):
//! approximate Metropolis-Hastings via sequential hypothesis tests over
//! mini-batches, the Gaussian-random-walk error analysis, optimal
//! sequential test design, and every application from the paper
//! (random-walk logistic regression, Stiefel-manifold ICA, reversible-jump
//! variable selection, MH-corrected SGLD, approximate Gibbs on dense MRFs).
//!
//! Architecture (see DESIGN.md): this crate is the Layer-3 coordinator of
//! a three-layer stack. The bulk log-likelihood moments can be served
//! either by a pure-Rust backend or by AOT-compiled JAX/Pallas artifacts
//! executed through the PJRT C API (`runtime` module); Python never runs
//! on the sampling path.

pub mod coordinator;
pub mod data;
pub mod exp;
pub mod metrics;
pub mod models;
pub mod runtime;
pub mod samplers;
pub mod server;
pub mod stats;
pub mod testkit;

//! `austerity` — launcher CLI for the Austerity-MCMC reproduction.
//!
//! Subcommands (hand-rolled parser; clap is not in the offline crate set):
//!   austerity info                         runtime + artifact inventory
//!   austerity fig <name|all> [--scale S]   regenerate paper figures
//!   austerity design --n N --tol T         optimal sequential test design
//!   austerity sample [--eps E] [--steps K] [--pjrt]
//!                                          run a logistic RW-MH chain

use std::process::ExitCode;

use austerity::coordinator::design::{worst_case_design, DesignGrid};
use austerity::coordinator::{mh_step, MhMode, MhScratch};
use austerity::exp::{run_figure, Scale, ALL_FIGURES};
use austerity::models::traits::ProposalKernel;
use austerity::runtime::{PjrtLogistic, PjrtRuntime};
use austerity::samplers::GaussianRandomWalk;
use austerity::stats::Pcg64;

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match args.first().map(String::as_str) {
        Some("info") => info(),
        Some("fig") => fig(&args[1..]),
        Some("design") => design(&args[1..]),
        Some("sample") => sample(&args[1..]),
        _ => {
            eprintln!(
                "usage: austerity <info|fig|design|sample> [options]\n\
                 \n\
                 info                          show PJRT platform + artifacts\n\
                 fig <name|all> [--scale S]    regenerate figure CSVs (fig1..fig15, fig_accept)\n\
                 design --n N --tol T          worst-case sequential test design\n\
                 sample [--rule exact|austerity|barker|confidence]\n\
                        [--eps E] [--sigma S] [--delta D] [--steps K] [--n N] [--pjrt]\n\
                 \n\
                 figures: {}",
                ALL_FIGURES.join(" ")
            );
            ExitCode::from(2)
        }
    }
}

fn flag_value(args: &[String], name: &str) -> Option<String> {
    args.iter().position(|a| a == name).and_then(|i| args.get(i + 1).cloned())
}

fn info() -> ExitCode {
    println!("austerity-mcmc: Korattikara, Chen & Welling (ICML 2014) reproduction");
    match PjrtRuntime::new(&PjrtRuntime::default_dir()) {
        Ok(rt) => {
            println!("pjrt platform: {}", rt.platform());
            println!("artifacts ({}):", PjrtRuntime::default_dir().display());
            for name in rt.artifact_names() {
                let spec = rt.spec(&name).unwrap();
                println!(
                    "  {name}: {} inputs -> {} outputs ({})",
                    spec.inputs.len(),
                    spec.outputs.len(),
                    spec.file
                );
            }
            ExitCode::SUCCESS
        }
        Err(e) => {
            eprintln!("runtime unavailable: {e:#} (run `make artifacts`)");
            ExitCode::FAILURE
        }
    }
}

fn fig(args: &[String]) -> ExitCode {
    let name = match args.first() {
        Some(n) => n.clone(),
        None => {
            eprintln!("usage: austerity fig <name|all> [--scale S]");
            return ExitCode::from(2);
        }
    };
    let scale = Scale(
        flag_value(args, "--scale").and_then(|s| s.parse().ok()).unwrap_or(1.0),
    );
    let names: Vec<&str> = if name == "all" {
        ALL_FIGURES.to_vec()
    } else {
        vec![name.as_str()]
    };
    for n in names {
        println!("== {n} (scale {}) ==", scale.0);
        if !run_figure(n, scale) {
            eprintln!("unknown figure {n}; known: {}", ALL_FIGURES.join(" "));
            return ExitCode::from(2);
        }
    }
    println!("CSV output under {}", austerity::exp::figures_dir().display());
    ExitCode::SUCCESS
}

fn design(args: &[String]) -> ExitCode {
    let n: usize =
        flag_value(args, "--n").and_then(|s| s.parse().ok()).unwrap_or(100_000);
    let tol: f64 =
        flag_value(args, "--tol").and_then(|s| s.parse().ok()).unwrap_or(0.01);
    let grid = DesignGrid::default();
    match worst_case_design(n, tol, &grid) {
        Some(d) => {
            println!(
                "worst-case design for N={n}, tol={tol}: m={} eps={} \
                 (predicted data usage {:.3}, worst error {:.4})",
                d.m, d.eps, d.data_usage, d.error
            );
            ExitCode::SUCCESS
        }
        None => {
            eprintln!("no feasible (m, eps) in the default grid for tol={tol}");
            ExitCode::FAILURE
        }
    }
}

fn sample(args: &[String]) -> ExitCode {
    let eps: f64 = flag_value(args, "--eps").and_then(|s| s.parse().ok()).unwrap_or(0.05);
    let sigma: f64 =
        flag_value(args, "--sigma").and_then(|s| s.parse().ok()).unwrap_or(1.0);
    let delta: f64 =
        flag_value(args, "--delta").and_then(|s| s.parse().ok()).unwrap_or(0.05);
    let steps: usize =
        flag_value(args, "--steps").and_then(|s| s.parse().ok()).unwrap_or(200);
    let n: usize =
        flag_value(args, "--n").and_then(|s| s.parse().ok()).unwrap_or(12_214);
    let rule = flag_value(args, "--rule").unwrap_or_else(|| "austerity".into());
    let use_pjrt = args.iter().any(|a| a == "--pjrt");

    let model = austerity::exp::population::mnist_like_model(n, 42);
    let kernel = GaussianRandomWalk::new(0.01, model.prior_precision);
    let batch = 500.min(n / 4).max(16);
    let mode = match rule.as_str() {
        "exact" => MhMode::Exact,
        "austerity" => MhMode::approx(eps, batch),
        "barker" => {
            use austerity::stats::logistic_corr::{SIGMA_MAX, SIGMA_MIN};
            if !(SIGMA_MIN..=SIGMA_MAX).contains(&sigma) {
                eprintln!("--sigma must be in [{SIGMA_MIN}, {SIGMA_MAX}]: got {sigma}");
                return ExitCode::from(2);
            }
            MhMode::barker(sigma, batch)
        }
        "confidence" => {
            if !(delta > 0.0 && delta < 1.0) {
                eprintln!("--delta must be in (0, 1): got {delta}");
                return ExitCode::from(2);
            }
            MhMode::confidence(delta, batch)
        }
        other => {
            eprintln!("unknown rule {other}; known: exact austerity barker confidence");
            return ExitCode::from(2);
        }
    };
    let init = model.map_estimate(60);

    // generic over backend via a per-step closure
    let run = |step: &mut dyn FnMut(&mut Vec<f64>, &mut MhScratch, &mut Pcg64) -> (bool, usize)| {
        let mut cur = init.clone();
        let mut scratch = MhScratch::new(n);
        let mut rng = Pcg64::seeded(1);
        let mut accepted = 0usize;
        let mut used = 0u64;
        let t0 = std::time::Instant::now();
        for _ in 0..steps {
            let (acc, nu) = step(&mut cur, &mut scratch, &mut rng);
            accepted += acc as usize;
            used += nu as u64;
        }
        let dt = t0.elapsed().as_secs_f64();
        println!(
            "steps={steps} accept={:.2} mean-data-fraction={:.4} steps/sec={:.1}",
            accepted as f64 / steps as f64,
            used as f64 / (steps as f64 * n as f64),
            steps as f64 / dt
        );
    };

    if use_pjrt {
        let rt = match PjrtRuntime::new(&PjrtRuntime::default_dir()) {
            Ok(rt) => rt,
            Err(e) => {
                eprintln!("pjrt unavailable: {e:#}");
                return ExitCode::FAILURE;
            }
        };
        let pjrt = match PjrtLogistic::new(&model, rt) {
            Ok(p) => p,
            Err(e) => {
                eprintln!("backend: {e:#}");
                return ExitCode::FAILURE;
            }
        };
        println!("backend: pjrt (AOT Pallas kernel), N={n}, rule={rule}");
        run(&mut |cur, scratch, rng| {
            let prop = kernel.propose(cur, rng);
            let info = mh_step(&pjrt, cur, prop, &mode, scratch, rng);
            (info.accepted, info.n_used)
        });
    } else {
        println!("backend: native, N={n}, rule={rule}");
        run(&mut |cur, scratch, rng| {
            let prop = kernel.propose(cur, rng);
            let info = mh_step(&model, cur, prop, &mode, scratch, rng);
            (info.accepted, info.n_used)
        });
    }
    ExitCode::SUCCESS
}

//! `austerity` — launcher CLI for the Austerity-MCMC reproduction.
//!
//! Subcommands (hand-rolled parser; clap is not in the offline crate set):
//!   austerity info                         runtime + artifact inventory
//!   austerity fig <name|all> [--scale S]   regenerate paper figures
//!   austerity design --n N --tol T         optimal sequential test design
//!   austerity sample [--eps E] [--steps K] [--chains C] [--json] [--pjrt]
//!                                          run logistic RW-MH chains on
//!                                          the Session front-end, with
//!                                          optional --checkpoint-dir /
//!                                          --checkpoint-every / --resume
//!                                          crash recovery, supervised
//!                                          retry (--retries,
//!                                          --retry-backoff-ms, --retain)
//!                                          and the stall watchdog
//!                                          (--stall-after-secs,
//!                                          --min-chains)
//!   austerity serve [--addr A] [--max-jobs J] [--max-queue Q]
//!                                          long-lived JSON job server over
//!                                          the sampling engine; POST specs
//!                                          to /jobs, poll /jobs/:id, fetch
//!                                          /jobs/:id/result

use std::path::PathBuf;
use std::process::ExitCode;
use std::time::Duration;

use austerity::coordinator::design::{worst_case_design, DesignGrid};
use austerity::coordinator::{Budget, MhMode, RetryPolicy, Session};
use austerity::exp::{run_figure, Scale, ALL_FIGURES};
use austerity::models::traits::ShardableModel;
use austerity::models::LlDiffModel;
use austerity::runtime::{PjrtLogistic, PjrtRuntime};
use austerity::samplers::GaussianRandomWalk;

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match args.first().map(String::as_str) {
        Some("info") => info(),
        Some("fig") => fig(&args[1..]),
        Some("design") => design(&args[1..]),
        Some("sample") => sample(&args[1..]),
        Some("serve") => serve(&args[1..]),
        _ => {
            eprintln!(
                "usage: austerity <info|fig|design|sample|serve> [options]\n\
                 \n\
                 info                          show PJRT platform + artifacts\n\
                 fig <name|all> [--scale S]    regenerate figure CSVs (fig1..fig15, fig_accept)\n\
                 design --n N --tol T          worst-case sequential test design\n\
                 sample [--rule exact|austerity|barker|confidence]\n\
                        [--eps E] [--sigma S] [--delta D] [--steps K] [--n N]\n\
                        [--chains C] [--seed S] [--shards S] [--json] [--pjrt]\n\
                        [--checkpoint-dir D --checkpoint-every K] [--resume D]\n\
                        [--retain K] [--retries R] [--retry-backoff-ms MS]\n\
                        [--stall-after-secs S] [--min-chains F]\n\
                 serve  [--addr HOST:PORT] [--max-jobs J] [--max-queue Q]\n\
                        [--drain-secs S] [--threads T]\n\
                        [--checkpoint-root DIR --checkpoint-every K]\n\
                 \n\
                 figures: {}",
                ALL_FIGURES.join(" ")
            );
            ExitCode::from(2)
        }
    }
}

fn flag_value(args: &[String], name: &str) -> Option<String> {
    args.iter().position(|a| a == name).and_then(|i| args.get(i + 1).cloned())
}

fn info() -> ExitCode {
    println!("austerity-mcmc: Korattikara, Chen & Welling (ICML 2014) reproduction");
    match PjrtRuntime::new(&PjrtRuntime::default_dir()) {
        Ok(rt) => {
            println!("pjrt platform: {}", rt.platform());
            println!("artifacts ({}):", PjrtRuntime::default_dir().display());
            for name in rt.artifact_names() {
                let spec = rt.spec(&name).unwrap();
                println!(
                    "  {name}: {} inputs -> {} outputs ({})",
                    spec.inputs.len(),
                    spec.outputs.len(),
                    spec.file
                );
            }
            ExitCode::SUCCESS
        }
        Err(e) => {
            eprintln!("runtime unavailable: {e:#} (run `make artifacts`)");
            ExitCode::FAILURE
        }
    }
}

fn fig(args: &[String]) -> ExitCode {
    let name = match args.first() {
        Some(n) => n.clone(),
        None => {
            eprintln!("usage: austerity fig <name|all> [--scale S]");
            return ExitCode::from(2);
        }
    };
    let scale = Scale(
        flag_value(args, "--scale").and_then(|s| s.parse().ok()).unwrap_or(1.0),
    );
    let names: Vec<&str> = if name == "all" {
        ALL_FIGURES.to_vec()
    } else {
        vec![name.as_str()]
    };
    for n in names {
        println!("== {n} (scale {}) ==", scale.0);
        if !run_figure(n, scale) {
            eprintln!("unknown figure {n}; known: {}", ALL_FIGURES.join(" "));
            return ExitCode::from(2);
        }
    }
    println!("CSV output under {}", austerity::exp::figures_dir().display());
    ExitCode::SUCCESS
}

fn design(args: &[String]) -> ExitCode {
    let n: usize =
        flag_value(args, "--n").and_then(|s| s.parse().ok()).unwrap_or(100_000);
    let tol: f64 =
        flag_value(args, "--tol").and_then(|s| s.parse().ok()).unwrap_or(0.01);
    let grid = DesignGrid::default();
    match worst_case_design(n, tol, &grid) {
        Some(d) => {
            println!(
                "worst-case design for N={n}, tol={tol}: m={} eps={} \
                 (predicted data usage {:.3}, worst error {:.4})",
                d.m, d.eps, d.data_usage, d.error
            );
            ExitCode::SUCCESS
        }
        None => {
            eprintln!("no feasible (m, eps) in the default grid for tol={tol}");
            ExitCode::FAILURE
        }
    }
}

/// Checkpoint/resume and supervision flags of the `sample` subcommand.
struct CkptCli {
    every: Option<usize>,
    dir: Option<PathBuf>,
    resume: Option<PathBuf>,
    retain: Option<usize>,
    retries: usize,
    backoff_ms: u64,
    stall_after_secs: Option<f64>,
    min_chains: f64,
}

impl CkptCli {
    /// Apply the flags to either session flavour's shared builder calls.
    fn retry_policy(&self) -> RetryPolicy {
        RetryPolicy::new(self.retries, Duration::from_millis(self.backoff_ms))
    }
}

/// Run a sample launch on the `Session` front-end and print either the
/// human-readable summary or the machine-readable `RunReport` JSON.
#[allow(clippy::too_many_arguments)]
fn run_sample<M>(
    model: &M,
    kernel: &GaussianRandomWalk,
    mode: &MhMode,
    init: Vec<f64>,
    steps: usize,
    chains: usize,
    seed: u64,
    json: bool,
    ckpt: &CkptCli,
) -> ExitCode
where
    M: LlDiffModel<Param = Vec<f64>> + Sync,
{
    let mut session = Session::new(model)
        .kernel(kernel)
        .rule(mode.clone())
        .chains(chains)
        .seed(seed)
        .budget(Budget::Steps(steps))
        .retry(ckpt.retry_policy())
        .min_chains(ckpt.min_chains)
        .init(init);
    if let Some(every) = ckpt.every {
        session = session.checkpoint_every(every);
    }
    if let Some(dir) = &ckpt.dir {
        session = session.checkpoint_dir(dir.clone());
    }
    if let Some(dir) = &ckpt.resume {
        session = session.resume_from(dir.clone());
    }
    if let Some(k) = ckpt.retain {
        session = session.retain_checkpoints(k);
    }
    if let Some(secs) = ckpt.stall_after_secs {
        session = session.stall_after(Duration::from_secs_f64(secs));
    }
    let report = match session.try_run() {
        Ok(r) => r,
        Err(e) => {
            eprintln!("sample: launch failed: {e}");
            return ExitCode::FAILURE;
        }
    };
    if json {
        println!("{}", report.to_json());
    } else {
        println!(
            "rule={} chains={} steps={} accept={:.2} mean-data-fraction={:.4} \
             steps/sec={:.1} data/sec={:.3e} rhat={:.3}",
            report.rule,
            report.chains,
            report.merged.steps,
            report.acceptance_rate(),
            report.mean_data_fraction(),
            report.steps_per_sec(),
            report.data_per_sec(),
            report.rhat(),
        );
        if report.recovered_chains() > 0 || report.stalled_chains() > 0 {
            println!(
                "supervision: {} chain(s) recovered, {} stalled",
                report.recovered_chains(),
                report.stalled_chains()
            );
        }
    }
    if report.failed_chains() > 0 {
        eprintln!("{} chain(s) failed", report.failed_chains());
        return ExitCode::FAILURE;
    }
    ExitCode::SUCCESS
}

/// Run an embarrassingly-parallel (sharded) launch and print the
/// per-shard accounting plus the consensus combination.
#[allow(clippy::too_many_arguments)]
fn run_sample_sharded<M>(
    model: &M,
    kernel: &GaussianRandomWalk,
    mode: &MhMode,
    init: Vec<f64>,
    steps: usize,
    chains: usize,
    seed: u64,
    shards: usize,
    json: bool,
    ckpt: &CkptCli,
) -> ExitCode
where
    M: ShardableModel<Param = Vec<f64>> + Sync,
{
    let mut session = Session::new(model)
        .kernel(kernel)
        .rule(mode.clone())
        .chains(chains)
        .seed(seed)
        .budget(Budget::Steps(steps))
        .shards(shards)
        .retry(ckpt.retry_policy())
        .min_chains(ckpt.min_chains)
        .init(init);
    if let Some(every) = ckpt.every {
        session = session.checkpoint_every(every);
    }
    if let Some(dir) = &ckpt.dir {
        session = session.checkpoint_dir(dir.clone());
    }
    if let Some(dir) = &ckpt.resume {
        session = session.resume_from(dir.clone());
    }
    if let Some(k) = ckpt.retain {
        session = session.retain_checkpoints(k);
    }
    if let Some(secs) = ckpt.stall_after_secs {
        session = session.stall_after(Duration::from_secs_f64(secs));
    }
    let report = match session.run_sharded() {
        Ok(r) => r,
        Err(e) => {
            eprintln!("sample: sharded launch failed: {e}");
            return ExitCode::FAILURE;
        }
    };
    if json {
        println!("{}", report.to_json());
    } else {
        for r in &report.shards {
            let info = r.shard.expect("sharded reports carry their stamp");
            println!(
                "shard {}/{} rows=[{},{}) steps={} accept={:.2} \
                 mean-data-fraction={:.4} rhat={:.3}",
                info.index,
                info.count,
                info.start,
                info.end,
                r.merged.steps,
                r.acceptance_rate(),
                r.mean_data_fraction(),
                r.rhat(),
            );
        }
        match report.combined() {
            Ok(g) => println!(
                "consensus: mean={:.6} sd={:.6} over {} draws in {} shards",
                g.mean,
                g.var.sqrt(),
                g.n,
                report.shards.len()
            ),
            Err(e) => eprintln!("consensus combination unavailable: {e}"),
        }
    }
    if report.failed_chains() > 0 {
        eprintln!("{} chain(s) failed across shards", report.failed_chains());
        return ExitCode::FAILURE;
    }
    ExitCode::SUCCESS
}

fn sample(args: &[String]) -> ExitCode {
    let eps: f64 = flag_value(args, "--eps").and_then(|s| s.parse().ok()).unwrap_or(0.05);
    let sigma: f64 =
        flag_value(args, "--sigma").and_then(|s| s.parse().ok()).unwrap_or(1.0);
    let delta: f64 =
        flag_value(args, "--delta").and_then(|s| s.parse().ok()).unwrap_or(0.05);
    let steps: usize =
        flag_value(args, "--steps").and_then(|s| s.parse().ok()).unwrap_or(200);
    let n: usize =
        flag_value(args, "--n").and_then(|s| s.parse().ok()).unwrap_or(12_214);
    let chains: usize =
        flag_value(args, "--chains").and_then(|s| s.parse().ok()).unwrap_or(1).max(1);
    let seed: u64 = flag_value(args, "--seed").and_then(|s| s.parse().ok()).unwrap_or(1);
    let shards: usize =
        flag_value(args, "--shards").and_then(|s| s.parse().ok()).unwrap_or(1).max(1);
    let rule = flag_value(args, "--rule").unwrap_or_else(|| "austerity".into());
    let use_pjrt = args.iter().any(|a| a == "--pjrt");
    if use_pjrt && shards > 1 {
        eprintln!("--shards is native-only (the PJRT backend binds one AOT artifact to the whole dataset)");
        return ExitCode::from(2);
    }
    let json = args.iter().any(|a| a == "--json");
    let ckpt = CkptCli {
        every: flag_value(args, "--checkpoint-every").and_then(|s| s.parse().ok()),
        dir: flag_value(args, "--checkpoint-dir").map(PathBuf::from),
        resume: flag_value(args, "--resume").map(PathBuf::from),
        retain: flag_value(args, "--retain").and_then(|s| s.parse().ok()),
        retries: flag_value(args, "--retries").and_then(|s| s.parse().ok()).unwrap_or(0),
        backoff_ms: flag_value(args, "--retry-backoff-ms")
            .and_then(|s| s.parse().ok())
            .unwrap_or(0),
        stall_after_secs: flag_value(args, "--stall-after-secs").and_then(|s| s.parse().ok()),
        min_chains: flag_value(args, "--min-chains").and_then(|s| s.parse().ok()).unwrap_or(0.0),
    };
    if ckpt.every.is_some() != ckpt.dir.is_some() {
        eprintln!("--checkpoint-every and --checkpoint-dir must be given together");
        return ExitCode::from(2);
    }
    if ckpt.every == Some(0) {
        eprintln!("--checkpoint-every must be >= 1");
        return ExitCode::from(2);
    }
    if ckpt.resume.is_some() && ckpt.dir.is_none() {
        eprintln!(
            "--resume requires --checkpoint-dir and --checkpoint-every \
             (resume continues a checkpointed run -- pair the flags)"
        );
        return ExitCode::from(2);
    }
    if let Some(k) = ckpt.retain {
        if k == 0 {
            eprintln!("--retain must be >= 1");
            return ExitCode::from(2);
        }
    }
    if !(0.0..=1.0).contains(&ckpt.min_chains) {
        eprintln!("--min-chains must be in [0, 1]: got {}", ckpt.min_chains);
        return ExitCode::from(2);
    }

    let model = austerity::exp::population::mnist_like_model(n, 42);
    let kernel = GaussianRandomWalk::new(0.01, model.prior_precision);
    let batch = 500.min(n / 4).max(16);
    let mode = match rule.as_str() {
        "exact" => MhMode::Exact,
        "austerity" => MhMode::approx(eps, batch),
        "barker" => {
            use austerity::stats::logistic_corr::{SIGMA_MAX, SIGMA_MIN};
            if !(SIGMA_MIN..=SIGMA_MAX).contains(&sigma) {
                eprintln!("--sigma must be in [{SIGMA_MIN}, {SIGMA_MAX}]: got {sigma}");
                return ExitCode::from(2);
            }
            MhMode::barker(sigma, batch)
        }
        "confidence" => {
            if !(delta > 0.0 && delta < 1.0) {
                eprintln!("--delta must be in (0, 1): got {delta}");
                return ExitCode::from(2);
            }
            MhMode::confidence(delta, batch)
        }
        other => {
            eprintln!("unknown rule {other}; known: exact austerity barker confidence");
            return ExitCode::from(2);
        }
    };
    let init = model.map_estimate(60);

    if use_pjrt {
        let rt = match PjrtRuntime::new(&PjrtRuntime::default_dir()) {
            Ok(rt) => rt,
            Err(e) => {
                eprintln!("pjrt unavailable: {e:#}");
                return ExitCode::FAILURE;
            }
        };
        let pjrt = match PjrtLogistic::new(&model, rt) {
            Ok(p) => p,
            Err(e) => {
                eprintln!("backend: {e:#}");
                return ExitCode::FAILURE;
            }
        };
        if !json {
            println!("backend: pjrt (AOT Pallas kernel), N={n}, rule={rule}");
        }
        return run_sample(&pjrt, &kernel, &mode, init, steps, chains, seed, json, &ckpt);
    } else if shards > 1 {
        if !json {
            println!("backend: native, N={n}, rule={rule}, shards={shards}");
        }
        return run_sample_sharded(
            &model, &kernel, &mode, init, steps, chains, seed, shards, json, &ckpt,
        );
    } else {
        if !json {
            println!("backend: native, N={n}, rule={rule}");
        }
        return run_sample(&model, &kernel, &mode, init, steps, chains, seed, json, &ckpt);
    }
}

fn serve(args: &[String]) -> ExitCode {
    use austerity::server::{signal, ServeConfig, Server};

    if args.iter().any(|a| a == "--help" || a == "-h") {
        println!(
            "usage: austerity serve [options]\n\
             \n\
             Long-lived job server over the sampling engine. Clients POST JSON\n\
             job specs and poll for progress and results:\n\
             \n\
               POST   /jobs            admit a job spec       -> 202 {{\"id\": ...}}\n\
               GET    /jobs/:id        incremental progress (steps, acceptance\n\
                                       rate, running R-hat/ESS)\n\
               GET    /jobs/:id/result full RunReport JSON (409 until finished)\n\
               DELETE /jobs/:id        cooperative cancel\n\
               GET    /healthz         liveness + queue/running counts\n\
               POST   /shutdown        graceful shutdown (same as SIGINT)\n\
             \n\
             options:\n\
               --addr HOST:PORT       listen address (default 127.0.0.1:7878;\n\
                                      port 0 picks a free port)\n\
               --max-jobs J           concurrent jobs / runner threads (default 4)\n\
               --max-queue Q          admission queue capacity; beyond it POST\n\
                                      /jobs returns 429 (default 64)\n\
               --drain-secs S         how long shutdown waits for running jobs\n\
                                      before cancelling them (default 5)\n\
               --threads T            pre-warm T executor workers shared by all\n\
                                      jobs (default 0 = grow on demand)\n\
               --checkpoint-root DIR  checkpoint every job under DIR/job-<id>\n\
                                      (pairs with --checkpoint-every)\n\
               --checkpoint-every K   checkpoint cadence in steps for jobs under\n\
                                      --checkpoint-root (pairs with it)\n\
             \n\
             Determinism: a job's draws depend only on its spec (model, rule,\n\
             seed, budget) — never on server load or job interleaving.\n\
             \n\
             Shutdown: first SIGINT/SIGTERM drains then cancels (running chains\n\
             flush a final checkpoint, so a job resubmitted with \"resume\": true\n\
             finishes the run); a second signal aborts immediately."
        );
        return ExitCode::SUCCESS;
    }

    let mut cfg = ServeConfig::default();
    if let Some(text) = flag_value(args, "--addr") {
        match text.parse() {
            Ok(addr) => cfg.addr = addr,
            Err(_) => {
                eprintln!("--addr must be HOST:PORT (e.g. 127.0.0.1:7878): got {text:?}");
                return ExitCode::from(2);
            }
        }
    }
    if let Some(text) = flag_value(args, "--max-jobs") {
        match text.parse::<usize>() {
            Ok(j) if j >= 1 => cfg.max_jobs = j,
            _ => {
                eprintln!("--max-jobs must be an integer >= 1: got {text:?}");
                return ExitCode::from(2);
            }
        }
    }
    if let Some(text) = flag_value(args, "--max-queue") {
        match text.parse::<usize>() {
            Ok(q) if q >= 1 => cfg.max_queue = q,
            _ => {
                eprintln!("--max-queue must be an integer >= 1: got {text:?}");
                return ExitCode::from(2);
            }
        }
    }
    if let Some(text) = flag_value(args, "--drain-secs") {
        match text.parse::<f64>() {
            Ok(s) if s >= 0.0 && s.is_finite() => cfg.drain = Duration::from_secs_f64(s),
            _ => {
                eprintln!("--drain-secs must be a non-negative number: got {text:?}");
                return ExitCode::from(2);
            }
        }
    }
    if let Some(text) = flag_value(args, "--threads") {
        match text.parse::<usize>() {
            Ok(t) => cfg.threads = t,
            Err(_) => {
                eprintln!("--threads must be a non-negative integer: got {text:?}");
                return ExitCode::from(2);
            }
        }
    }
    cfg.ckpt_root = flag_value(args, "--checkpoint-root").map(PathBuf::from);
    cfg.ckpt_every = match flag_value(args, "--checkpoint-every") {
        None => None,
        Some(text) => match text.parse::<usize>() {
            Ok(k) if k >= 1 => Some(k),
            _ => {
                eprintln!("--checkpoint-every must be an integer >= 1: got {text:?}");
                return ExitCode::from(2);
            }
        },
    };
    // same pairing rule as `sample`: a cadence without a directory (or
    // vice versa) is a config bug, not a default to guess at
    if cfg.ckpt_root.is_some() != cfg.ckpt_every.is_some() {
        eprintln!("--checkpoint-root and --checkpoint-every must be given together");
        return ExitCode::from(2);
    }

    signal::install_signal_handlers();
    let srv = match Server::bind(cfg.clone()) {
        Ok(srv) => srv,
        Err(e) => {
            eprintln!("serve: cannot bind {}: {e}", cfg.addr);
            return ExitCode::FAILURE;
        }
    };
    println!(
        "austerity serve: listening on http://{} (max-jobs {}, max-queue {})",
        srv.local_addr(),
        cfg.max_jobs,
        cfg.max_queue,
    );
    srv.run();
    ExitCode::SUCCESS
}

//! Normal distribution primitives: erf/erfc, Phi, phi, and inverse Phi.
//!
//! erf uses the Cody-style rational approximations from W. J. Cody,
//! "Rational Chebyshev approximation for the error function" (1969),
//! accurate to ~1e-15 over the full range; the inverse CDF uses Acklam's
//! algorithm refined by one Halley step (~1e-15). These feed the
//! Student-t CDF, the random-walk DP, and the design quadrature, all of
//! which are sensitive to tail accuracy.

/// Error function, |err| < 1.5e-15.
pub fn erf(x: f64) -> f64 {
    if x.is_nan() {
        return f64::NAN;
    }
    let ax = x.abs();
    if ax < 0.5 {
        // rational approx of erf(x)/x on [0, 0.5]
        const P: [f64; 5] = [
            3.209377589138469472562e3,
            3.774852376853020208137e2,
            1.138641541510501556495e2,
            3.161123743870565596947e0,
            1.857777061846031526730e-1,
        ];
        const Q: [f64; 5] = [
            2.844236833439170622273e3,
            1.282616526077372275645e3,
            2.440246379344441733056e2,
            2.360129095234412093499e1,
            1.0,
        ];
        let z = x * x;
        let mut num = P[4];
        let mut den = Q[4];
        for i in (0..4).rev() {
            num = num * z + P[i];
            den = den * z + Q[i];
        }
        x * num / den
    } else {
        let s = 1.0 - erfc(ax);
        if x < 0.0 {
            -s
        } else {
            s
        }
    }
}

/// Complementary error function, relative accuracy ~1e-14 in the tails.
pub fn erfc(x: f64) -> f64 {
    if x.is_nan() {
        return f64::NAN;
    }
    let ax = x.abs();
    let r = if ax < 0.5 {
        1.0 - erf(ax)
    } else if ax <= 4.0 {
        // Cody's erfc rational approximation on [0.46875, 4]
        const P: [f64; 9] = [
            1.23033935479799725272e3,
            2.05107837782607146532e3,
            1.71204761263407058314e3,
            8.81952221241769090411e2,
            2.98635138197400131132e2,
            6.61191906371416294775e1,
            8.88314979438837594118e0,
            5.64188496988670089180e-1,
            2.15311535474403846343e-8,
        ];
        const Q: [f64; 9] = [
            1.23033935480374942043e3,
            3.43936767414372163696e3,
            4.36261909014324715820e3,
            3.29079923573345962678e3,
            1.62138957456669018874e3,
            5.37181101862009857509e2,
            1.17693950891312499305e2,
            1.57449261107098347253e1,
            1.0,
        ];
        let mut num = P[8];
        let mut den = Q[8];
        for i in (0..8).rev() {
            num = num * ax + P[i];
            den = den * ax + Q[i];
        }
        (-ax * ax).exp() * num / den
    } else {
        // Cody's asymptotic form for [4, inf)
        const P: [f64; 6] = [
            -6.58749161529837803157e-4,
            -1.60837851487422766278e-2,
            -1.25781726111229246204e-1,
            -3.60344899949804439429e-1,
            -3.05326634961232344035e-1,
            -1.63153871373020978498e-2,
        ];
        const Q: [f64; 6] = [
            2.33520497626869185443e-3,
            6.05183413124413191178e-2,
            5.27905102951428412248e-1,
            1.87295284992346047209e0,
            2.56852019228982242072e0,
            1.0,
        ];
        let z = 1.0 / (ax * ax);
        let mut num = P[5];
        let mut den = Q[5];
        for i in (0..5).rev() {
            num = num * z + P[i];
            den = den * z + Q[i];
        }
        // erfc(x) = exp(-x^2)/x * (1/sqrt(pi) - z R(z)); our P is Cody's
        // negated, so the subtraction becomes an addition.
        let frac = z * num / den;
        (-ax * ax).exp() * (0.564_189_583_547_756_3 + frac) / ax
    };
    if x < 0.0 {
        2.0 - r
    } else {
        r
    }
}

/// Standard normal PDF.
#[inline]
pub fn phi_pdf(x: f64) -> f64 {
    const INV_SQRT_2PI: f64 = 0.398_942_280_401_432_7;
    INV_SQRT_2PI * (-0.5 * x * x).exp()
}

/// Standard normal CDF Phi(x).
#[inline]
pub fn phi_cdf(x: f64) -> f64 {
    0.5 * erfc(-x * std::f64::consts::FRAC_1_SQRT_2)
}

/// Upper tail 1 - Phi(x), computed without cancellation.
#[inline]
pub fn phi_sf(x: f64) -> f64 {
    0.5 * erfc(x * std::f64::consts::FRAC_1_SQRT_2)
}

/// Inverse standard normal CDF (Acklam + one Halley refinement).
pub fn phi_inv(p: f64) -> f64 {
    assert!(p > 0.0 && p < 1.0, "phi_inv domain: got {p}");
    const A: [f64; 6] = [
        -3.969683028665376e+01,
        2.209460984245205e+02,
        -2.759285104469687e+02,
        1.383577518672690e+02,
        -3.066479806614716e+01,
        2.506628277459239e+00,
    ];
    const B: [f64; 5] = [
        -5.447609879822406e+01,
        1.615858368580409e+02,
        -1.556989798598866e+02,
        6.680131188771972e+01,
        -1.328068155288572e+01,
    ];
    const C: [f64; 6] = [
        -7.784894002430293e-03,
        -3.223964580411365e-01,
        -2.400758277161838e+00,
        -2.549732539343734e+00,
        4.374664141464968e+00,
        2.938163982698783e+00,
    ];
    const D: [f64; 4] = [
        7.784695709041462e-03,
        3.224671290700398e-01,
        2.445134137142996e+00,
        3.754408661907416e+00,
    ];
    const P_LOW: f64 = 0.02425;

    let x = if p < P_LOW {
        let q = (-2.0 * p.ln()).sqrt();
        (((((C[0] * q + C[1]) * q + C[2]) * q + C[3]) * q + C[4]) * q + C[5])
            / ((((D[0] * q + D[1]) * q + D[2]) * q + D[3]) * q + 1.0)
    } else if p <= 1.0 - P_LOW {
        let q = p - 0.5;
        let r = q * q;
        (((((A[0] * r + A[1]) * r + A[2]) * r + A[3]) * r + A[4]) * r + A[5]) * q
            / (((((B[0] * r + B[1]) * r + B[2]) * r + B[3]) * r + B[4]) * r + 1.0)
    } else {
        let q = (-2.0 * (1.0 - p).ln()).sqrt();
        -(((((C[0] * q + C[1]) * q + C[2]) * q + C[3]) * q + C[4]) * q + C[5])
            / ((((D[0] * q + D[1]) * q + D[2]) * q + D[3]) * q + 1.0)
    };
    // One Halley step using the exact CDF.
    let e = phi_cdf(x) - p;
    let u = e * (2.0 * std::f64::consts::PI).sqrt() * (x * x / 2.0).exp();
    x - u / (1.0 + x * u / 2.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn erf_known_values() {
        // Reference values from Abramowitz & Stegun / mpmath.
        let cases = [
            (0.0, 0.0),
            (0.5, 0.5204998778130465),
            (1.0, 0.8427007929497149),
            (2.0, 0.9953222650189527),
            (3.0, 0.9999779095030014),
            (-1.0, -0.8427007929497149),
        ];
        for (x, want) in cases {
            assert!((erf(x) - want).abs() < 1e-13, "erf({x})");
        }
    }

    #[test]
    fn erfc_tail_accuracy() {
        // erfc(5) = 1.5374597944280349e-12 (mpmath)
        let got = erfc(5.0);
        assert!((got / 1.537_459_794_428_034_9e-12 - 1.0).abs() < 1e-10, "got {got:e}");
        // erfc(10) = 2.0884875837625447e-45
        let got = erfc(10.0);
        assert!((got / 2.088_487_583_762_544_7e-45 - 1.0).abs() < 1e-9, "got {got:e}");
    }

    #[test]
    fn phi_cdf_known_values() {
        assert!((phi_cdf(0.0) - 0.5).abs() < 1e-15);
        assert!((phi_cdf(1.959963984540054) - 0.975).abs() < 1e-12);
        assert!((phi_cdf(-1.6448536269514722) - 0.05).abs() < 1e-12);
    }

    #[test]
    fn phi_sf_symmetry() {
        for &x in &[0.0, 0.3, 1.0, 2.5, 4.0, 7.0] {
            assert!((phi_sf(x) - phi_cdf(-x)).abs() < 1e-15);
            assert!((phi_cdf(x) + phi_sf(x) - 1.0).abs() < 1e-14);
        }
    }

    #[test]
    fn phi_inv_round_trip() {
        for i in 1..999 {
            let p = i as f64 / 1000.0;
            let x = phi_inv(p);
            assert!((phi_cdf(x) - p).abs() < 1e-12, "p={p}");
        }
        // deep tails
        for &p in &[1e-10, 1e-6, 1.0 - 1e-6] {
            let x = phi_inv(p);
            assert!((phi_cdf(x) - p).abs() / p.min(1.0 - p) < 1e-8, "p={p}");
        }
    }

    #[test]
    fn pdf_integrates_to_cdf_diff() {
        // Trapezoid integration of the pdf matches the cdf difference.
        let (a, b) = (-1.3, 2.1);
        let n = 20_000;
        let h = (b - a) / n as f64;
        let mut s = 0.5 * (phi_pdf(a) + phi_pdf(b));
        for i in 1..n {
            s += phi_pdf(a + i as f64 * h);
        }
        let integral = s * h;
        assert!((integral - (phi_cdf(b) - phi_cdf(a))).abs() < 1e-9);
    }
}

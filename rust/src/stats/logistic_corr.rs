//! The Barker-test correction distribution X_corr (Seita et al. 2017,
//! arXiv:1610.06848, §3).
//!
//! The minibatch Barker test accepts when `Delta_hat + X_nc + X_corr > 0`
//! where `Delta_hat` is the subsampled log MH ratio with (approximately)
//! `N(0, sigma^2)` estimation noise, `X_nc` tops the noise up to exactly
//! `sigma`, and `X_corr` is drawn from the *correction distribution*
//! `C_sigma` defined by the deconvolution identity
//!
//! ```text
//! N(0, sigma^2) * C_sigma = Logistic(0, 1)
//! ```
//!
//! so the total perturbation is standard-logistic and the decision is a
//! Barker (logistic-rule) acceptance — a valid MH acceptance function.
//! An exact integrable deconvolution does not exist, so, like the paper,
//! we solve for a discretized density on a grid: projected Landweber
//! iterations on `min_c ||K c - logistic||^2, c >= 0` with `K` the
//! Gaussian convolution operator. The residual is ~1e-3 in sup norm for
//! `sigma <= 1.1` (it grows sharply beyond; the paper stops at ~1.2).
//!
//! Tables are deterministic (fixed grid, fixed iteration count — no RNG)
//! and cached process-wide by `shared`, so cloning a `BarkerTest` or
//! spawning K chains never rebuilds them.

use std::sync::{Arc, Mutex, OnceLock};

use crate::stats::Pcg64;

/// Variance of the standard Logistic(0, 1): pi^2 / 3.
pub const LOGISTIC_VAR: f64 = std::f64::consts::PI * std::f64::consts::PI / 3.0;

/// Largest Gaussian noise level the tabulated deconvolution supports.
pub const SIGMA_MAX: f64 = 1.1;

/// Smallest supported noise level (below this the fixed grid is too
/// coarse for the Gaussian kernel; the test would waste data anyway).
pub const SIGMA_MIN: f64 = 0.3;

/// Standard logistic density `e^-|x| / (1 + e^-|x|)^2`.
pub fn logistic_pdf(x: f64) -> f64 {
    let e = (-x.abs()).exp();
    e / ((1.0 + e) * (1.0 + e))
}

/// Standard logistic CDF `1 / (1 + e^-x)`, stable in both tails.
pub fn logistic_cdf(x: f64) -> f64 {
    if x >= 0.0 {
        1.0 / (1.0 + (-x).exp())
    } else {
        let e = x.exp();
        e / (1.0 + e)
    }
}

/// Half-width and point count of the tabulation grid. Correction tails
/// decay like `e^(-|x| + sigma^2/2)`, so mass beyond 12 is < 1e-4.
const GRID_HALF: f64 = 12.0;
const GRID_POINTS: usize = 193;
const LANDWEBER_ITERS: usize = 3000;

/// Tabulated correction distribution `C_sigma` with inverse-CDF sampling.
#[derive(Clone)]
pub struct LogisticCorrection {
    sigma: f64,
    lo: f64,
    h: f64,
    pdf: Vec<f64>,
    cdf: Vec<f64>,
    resid: f64,
}

impl std::fmt::Debug for LogisticCorrection {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("LogisticCorrection")
            .field("sigma", &self.sigma)
            .field("points", &self.pdf.len())
            .field("resid", &self.resid)
            .finish()
    }
}

impl LogisticCorrection {
    /// Solve the discretized deconvolution for this `sigma`. Prefer
    /// `shared` — tables are immutable and safely reusable.
    pub fn build(sigma: f64) -> Self {
        assert!(
            (SIGMA_MIN..=SIGMA_MAX).contains(&sigma),
            "barker correction needs sigma in [{SIGMA_MIN}, {SIGMA_MAX}]: got {sigma}"
        );
        let m = GRID_POINTS;
        let h = 2.0 * GRID_HALF / (m - 1) as f64;
        let xs: Vec<f64> = (0..m).map(|i| -GRID_HALF + i as f64 * h).collect();
        // Gaussian convolution kernel by grid offset, mass-normalized row.
        let norm = h / (sigma * (2.0 * std::f64::consts::PI).sqrt());
        let kern: Vec<f64> = (0..m)
            .map(|d| {
                let z = d as f64 * h / sigma;
                norm * (-0.5 * z * z).exp()
            })
            .collect();
        let target: Vec<f64> = xs.iter().map(|&x| logistic_pdf(x)).collect();

        // K is symmetric with spectral norm <= ~1 (rows sum to ~1), so
        // plain Landweber `c += K(t - Kc)` (step 1) converges; projecting
        // onto c >= 0 keeps it a density.
        let conv = |c: &[f64], out: &mut [f64]| {
            for i in 0..m {
                let mut s = 0.0;
                for (j, &cj) in c.iter().enumerate() {
                    s += kern[i.abs_diff(j)] * cj;
                }
                out[i] = s;
            }
        };
        // init at the moment-matched Gaussian (variance pi^2/3 - sigma^2)
        let v0 = (LOGISTIC_VAR - sigma * sigma).max(0.25);
        let mut c: Vec<f64> = xs
            .iter()
            .map(|&x| (-0.5 * x * x / v0).exp() / (v0 * 2.0 * std::f64::consts::PI).sqrt())
            .collect();
        let mut kc = vec![0.0; m];
        let mut step = vec![0.0; m];
        for _ in 0..LANDWEBER_ITERS {
            conv(&c, &mut kc);
            for i in 0..m {
                kc[i] = target[i] - kc[i];
            }
            conv(&kc, &mut step);
            for i in 0..m {
                c[i] = (c[i] + step[i]).max(0.0);
            }
        }
        conv(&c, &mut kc);
        let resid = (0..m).map(|i| (kc[i] - target[i]).abs()).fold(0.0, f64::max);

        // normalize to a proper density (trapezoid mass) and tabulate the CDF
        let mass: f64 = h * (c.iter().sum::<f64>() - 0.5 * (c[0] + c[m - 1]));
        for v in &mut c {
            *v /= mass;
        }
        let mut cdf = vec![0.0; m];
        for i in 1..m {
            cdf[i] = cdf[i - 1] + 0.5 * h * (c[i - 1] + c[i]);
        }
        let end = cdf[m - 1];
        for v in &mut cdf {
            *v /= end;
        }
        LogisticCorrection { sigma, lo: -GRID_HALF, h, pdf: c, cdf, resid }
    }

    /// Process-wide table cache keyed by the exact bits of `sigma`.
    pub fn shared(sigma: f64) -> Arc<LogisticCorrection> {
        static CACHE: OnceLock<Mutex<Vec<Arc<LogisticCorrection>>>> = OnceLock::new();
        let cache = CACHE.get_or_init(|| Mutex::new(Vec::new()));
        let mut guard = cache.lock().unwrap();
        if let Some(hit) = guard.iter().find(|t| t.sigma.to_bits() == sigma.to_bits()) {
            return hit.clone();
        }
        let built = Arc::new(Self::build(sigma));
        guard.push(built.clone());
        built
    }

    pub fn sigma(&self) -> f64 {
        self.sigma
    }

    /// Sup-norm residual `max |N_sigma * c - logistic|` of the solved
    /// table — the per-decision acceptance-probability error scale.
    pub fn max_residual(&self) -> f64 {
        self.resid
    }

    /// Variance of the tabulated correction (ideally pi^2/3 - sigma^2).
    pub fn variance(&self) -> f64 {
        let m = self.pdf.len();
        let mut s = 0.0;
        for (i, &p) in self.pdf.iter().enumerate() {
            let x = self.lo + i as f64 * self.h;
            let w = if i == 0 || i == m - 1 { 0.5 } else { 1.0 };
            s += w * x * x * p;
        }
        s * self.h
    }

    /// Draw one `X_corr` by inverse-CDF with in-cell linear
    /// interpolation. Allocation-free (hot-path safe).
    pub fn sample(&self, rng: &mut Pcg64) -> f64 {
        let u = rng.uniform();
        let cdf = &self.cdf;
        let (mut lo, mut hi) = (0usize, cdf.len() - 1);
        while hi - lo > 1 {
            let mid = (lo + hi) / 2;
            if cdf[mid] <= u {
                lo = mid;
            } else {
                hi = mid;
            }
        }
        let seg = (cdf[hi] - cdf[lo]).max(1e-300);
        self.lo + self.h * (lo as f64 + (u - cdf[lo]) / seg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stats::Histogram;

    #[test]
    fn logistic_pdf_cdf_consistent() {
        assert!((logistic_cdf(0.0) - 0.5).abs() < 1e-15);
        assert!((logistic_pdf(0.0) - 0.25).abs() < 1e-15);
        for &x in &[-8.0, -2.5, -0.3, 0.0, 0.7, 3.1, 9.0] {
            // symmetry and numerical derivative
            assert!((logistic_cdf(x) + logistic_cdf(-x) - 1.0).abs() < 1e-14);
            let h = 1e-6;
            let fd = (logistic_cdf(x + h) - logistic_cdf(x - h)) / (2.0 * h);
            assert!((fd - logistic_pdf(x)).abs() < 1e-8, "x={x}");
        }
    }

    #[test]
    fn correction_convolves_back_to_logistic() {
        let corr = LogisticCorrection::shared(1.0);
        assert!(corr.max_residual() < 5e-3, "resid {}", corr.max_residual());
        // moment identity: Var(X_corr) = pi^2/3 - sigma^2
        let want = LOGISTIC_VAR - 1.0;
        let got = corr.variance();
        assert!((got - want).abs() < 0.05, "var {got} want {want}");
    }

    #[test]
    fn samples_match_table_moments() {
        let corr = LogisticCorrection::shared(1.0);
        let mut rng = Pcg64::seeded(0);
        let n = 200_000;
        let (mut s, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let x = corr.sample(&mut rng);
            s += x;
            s2 += x * x;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - (LOGISTIC_VAR - 1.0)).abs() < 0.08, "var {var}");
    }

    #[test]
    fn normal_plus_correction_is_logistic() {
        // the operational property the Barker test relies on
        let corr = LogisticCorrection::shared(1.0);
        let mut rng = Pcg64::seeded(1);
        let mut h = Histogram::new(-8.0, 8.0, 64);
        for _ in 0..300_000 {
            h.add(corr.sample(&mut rng) + rng.normal());
        }
        let l1 = h.l1_vs_density(logistic_pdf);
        assert!(l1 < 0.05, "l1 {l1}");
    }

    #[test]
    fn shared_cache_reuses_tables() {
        let a = LogisticCorrection::shared(1.0);
        let b = LogisticCorrection::shared(1.0);
        assert!(Arc::ptr_eq(&a, &b));
    }

    #[test]
    #[should_panic(expected = "sigma")]
    fn sigma_out_of_range_panics() {
        let _ = LogisticCorrection::build(2.0);
    }
}
